"""Transformer building blocks (pure JAX, GSPMD-friendly).

All matmuls run in the config dtype with float32 accumulation.  Attention has
three implementations selected at call time:
  * "xla"    -- pure-jnp softmax attention (default; the dry-run path, which
                GSPMD can partition freely),
  * "flash"  -- the Pallas flash_attention kernel (TPU),
  * "kde"    -- the paper's sub-quadratic sampled decode attention
                (jnp mirror of the kde_attention kernel so GSPMD can shard
                 the 500k-token cache; kernel validated allclose in tests).
"""
from __future__ import annotations

import dataclasses
from contextlib import contextmanager
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

from repro.compat import shard_map

_NEG_INF = -1.0e30

# ------------------------------------------------------------- activation
# sharding context: the launchers wrap tracing in ``activation_sharding`` so
# the model code can pin activation layouts (batch over ('pod','data'), TP
# dims over 'model') without threading the mesh through every call.  Without
# constraints GSPMD happily propagates *weight* shardings into the residual
# stream (feature-sharded activations + giant per-layer all-reduces).
_ACT = {"mesh": None, "batch_axes": (), "seq_mode": False}


@contextmanager
def activation_sharding(mesh, batch_axes=("data",), seq_mode: bool = False):
    """seq_mode=True: context parallelism -- activations shard the *sequence*
    dim over 'model' instead of TP dims (heads / d_ff).  Weights then behave
    FSDP-style (gathered per layer); attention queries are seq-sharded while
    keys/values are gathered.  Used for prefill cells whose head counts do
    not divide the TP axis (e.g. qwen2.5's 40 heads on TP16)."""
    old = dict(_ACT)
    _ACT.update(mesh=mesh, batch_axes=tuple(batch_axes), seq_mode=seq_mode)
    try:
        yield
    finally:
        _ACT.update(old)


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape.get(a, 1)
    return n


def constrain(x, *tail):
    """with_sharding_constraint(P(batch_axes, *tail)) -- skipping any axis
    whose mesh extent does not divide the corresponding dim.

    In seq_mode the positional tail is overridden by arity: 3D activations
    (b, s, *) shard s over 'model'; 4D head tensors (b, h, s, hd) shard s."""
    mesh = _ACT["mesh"]
    if mesh is None:
        return x
    if _ACT["seq_mode"]:
        tail = ("model", None) if x.ndim == 3 else (None, "model", None)
    spec = [None] * x.ndim
    baxes = _ACT["batch_axes"]
    if baxes and x.shape[0] % _axes_size(mesh, baxes) == 0:
        spec[0] = baxes
    for i, s in enumerate(tail, start=1):
        if s is None or i >= x.ndim:
            continue
        if x.shape[i] % _axes_size(mesh, s) == 0:
            spec[i] = s
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def dtype_of(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ------------------------------------------------------------------ init
def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / (fan_in ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def init_attention(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, hq * hd)),
        "wk": _dense_init(ks[1], (d, hkv * hd)),
        "wv": _dense_init(ks[2], (d, hkv * hd)),
        "wo": _dense_init(ks[3], (hq * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    return p


def init_mlp(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.is_moe:
        ks = jax.random.split(key, 4)
        e = cfg.num_experts
        return {
            "router": _dense_init(ks[0], (d, e)),
            "w1": jax.vmap(lambda k: _dense_init(k, (d, f)))(
                jax.random.split(ks[1], e)),
            "w3": jax.vmap(lambda k: _dense_init(k, (d, f)))(
                jax.random.split(ks[2], e)),
            "w2": jax.vmap(lambda k: _dense_init(k, (f, d)))(
                jax.random.split(ks[3], e)),
        }
    ks = jax.random.split(key, 3)
    return {"w1": _dense_init(ks[0], (d, f)),
            "w3": _dense_init(ks[1], (d, f)),
            "w2": _dense_init(ks[2], (f, d))}


# ------------------------------------------------------------------ norms
import functools as _ft


@_ft.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x, gain, eps):
    return _rmsnorm_fwd_impl(x, gain, eps)


def _rmsnorm_fwd_impl(x, gain, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * gain).astype(x.dtype)


def _rmsnorm_fwd(x, gain, eps):
    return _rmsnorm_fwd_impl(x, gain, eps), (x, gain)


def _rmsnorm_bwd(eps, res, g):
    """Grad math in f32, but the *returned* x-cotangent is cast back to
    x.dtype: without this the whole backward residual stream (and its TP
    all-reduces) silently runs in f32 -- 2x the collective bytes."""
    x, gain = res
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = x32 * rstd
    dgain = jnp.sum(g32 * xhat, axis=tuple(range(x.ndim - 1)))
    gg = g32 * gain
    dx = rstd * (gg - xhat * jnp.mean(gg * xhat, axis=-1, keepdims=True))
    return dx.astype(x.dtype), dgain.astype(gain.dtype)


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


# ------------------------------------------------------------------ rope
def rope_angles(positions, dim, base=10000.0):
    """positions (...,) -> cos/sin (..., dim/2)."""
    inv = 1.0 / (base ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, style: str = "full"):
    """x (b, h, s, hd); positions (s,) or (b, s).

    style="full": rotate all head dims.  style="glm2d": ChatGLM's 2D RoPE --
    only the first half of the head dims is rotary, the rest pass through.
    """
    hd = x.shape[-1]
    rot = hd if style == "full" else hd // 2
    xr, xp = x[..., :rot], x[..., rot:]
    cos, sin = rope_angles(positions, rot)
    while cos.ndim < xr.ndim - 1:
        cos, sin = cos[None], sin[None]  # broadcast over b, h
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rot < hd else out


# ------------------------------------------------------------------ attention
def _split_heads(x, nh, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def _qkv(p, cfg: ArchConfig, x, positions):
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = constrain(_split_heads(q, hq, hd), "model", None, None)
    k = constrain(_split_heads(k, hkv, hd), "model", None, None)
    v = constrain(_split_heads(v, hkv, hd), "model", None, None)
    q = apply_rope(q, positions, cfg.rope_style)
    k = apply_rope(k, positions, cfg.rope_style)
    return q, k, v


def xla_attention(q, k, v, causal: bool, q_offset=0, kv_valid=None):
    """(b, hq, sq, hd) x (b, hkv, skv, hd) -> (b, hq, sq, hd), f32 softmax.

    GQA is expressed by *expanding* kv heads to hq before the einsums: under
    TP the expansion is a device-local gather (each device only materializes
    the kv copies its own q-heads need), whereas a (hkv, group) reshape
    would destroy the 'model' sharding of the head dim (hkv < mesh axis) and
    force GSPMD into full-score all-reduces.
    """
    b, hq, sq, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    kk = constrain(jnp.repeat(k, g, axis=1), "model", None, None)
    vv = constrain(jnp.repeat(v, g, axis=1), "model", None, None)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / (hd ** 0.5)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if kv_valid is not None:
        mask = mask & (kpos[None, :] < kv_valid)
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        mask = mask & (kpos[None, :] <= qpos)
    s = jnp.where(mask[None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return o.astype(q.dtype)


def xla_attention_chunked(q, k, v, causal: bool, q_offset=0, kv_valid=None,
                          chunk: int = 256):
    """Online-softmax attention scanned over KV chunks -- 'flash in XLA'.

    Peak score memory drops from O(sq * skv) to O(sq * chunk); used for
    long-sequence prefill where dense scores would exceed HBM (32k^2 f32
    scores per head = 4 GiB each).  Same math as the Pallas flash kernel.
    """
    b, hq, sq, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    kk = constrain(jnp.repeat(k, g, axis=1), "model", None, None)
    vv = constrain(jnp.repeat(v, g, axis=1), "model", None, None)
    nc = (skv + chunk - 1) // chunk
    pad = nc * chunk - skv
    if pad:
        kk = jnp.pad(kk, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vv = jnp.pad(vv, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = kk.reshape(b, hq, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = vv.reshape(b, hq, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    scale = 1.0 / (hd ** 0.5)
    qpos = jnp.arange(sq)[:, None] + q_offset
    q32 = q.astype(jnp.float32)

    def step(carry, inp):
        m, l, acc = carry
        kci, vci, ci = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, kci.astype(jnp.float32)) * scale
        kpos = ci * chunk + jnp.arange(chunk)[None, :]
        mask = kpos < (skv if kv_valid is None else kv_valid)
        if causal:
            mask = mask & (kpos <= qpos)
        s = jnp.where(mask[None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vci.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, hq, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    a0 = jnp.zeros((b, hq, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# sequences at or above this length use the chunked path (dense 32k^2
# scores would not fit HBM)
CHUNKED_ATTN_THRESHOLD = 8192


def kde_decode_attention_shardmap(q, k, v, kv_valid, top_p: int, bk: int,
                                  stride: int, mesh, baxes):
    """Distributed KDE decode attention under shard_map.

    The GSPMD mirror's weakness (measured on yi long_500k): the top-P block
    gather over a sequence-sharded cache forces a FULL cache all-gather per
    layer (~1 GiB).  Here each shard instead:
      1. computes strided block-lse estimates for its LOCAL cache slice,
      2. all-gathers only the (b, hq, nb) lse table (KBs),
      3. attends exactly over the selected blocks it OWNS,
      4. combines numerator/denominator (+ estimated residual mass) with one
         log-sum-exp psum -- the flash-decode decomposition.
    Per-layer collective bytes drop from ~cache-sized to ~KBs.

    q (b, hq, 1, hd); k, v (b, hkv, S, hd) with S sharded over
    ``seq_axes = baxes (+ 'model' when kv heads don't shard)``.
    """
    b, hq, _, hd = q.shape
    hkv, s_total = k.shape[1], k.shape[2]
    group = hq // hkv
    msize = mesh.shape.get("model", 1)
    heads_sharded = msize > 1 and hkv % msize == 0 and hkv >= msize
    seq_axes = tuple(baxes) if heads_sharded else tuple(baxes) + ("model",)
    nshards = _axes_size(mesh, seq_axes)
    if s_total % (bk * nshards) != 0:
        return None  # caller falls back to the GSPMD mirror
    scale = 1.0 / (hd ** 0.5)
    nb = s_total // bk

    def local(q_l, k_l, v_l):
        bq, hq_l, _, _ = q_l.shape
        hkv_l, s_loc = k_l.shape[1], k_l.shape[2]
        g_l = hq_l // hkv_l
        nb_loc = s_loc // bk
        # shard offset along the sequence
        idx = 0
        for ax in seq_axes:
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        seq_off = idx * s_loc

        q32 = q_l[:, :, 0, :].astype(jnp.float32)            # (b, hq_l, hd)
        kk = jnp.repeat(k_l, g_l, axis=1).astype(jnp.float32)
        vv = jnp.repeat(v_l, g_l, axis=1).astype(jnp.float32)

        # (1) local strided block-lse estimates
        ks = kk[:, :, ::stride, :]                           # (b,hq,s/stride,hd)
        sc = jnp.einsum("bhd,bhsd->bhs", q32, ks) * scale
        pos = seq_off + jnp.arange(0, s_loc, stride)
        sc = jnp.where(pos[None, None, :] < kv_valid, sc, _NEG_INF)
        sc = sc.reshape(bq, hq_l, nb_loc, -1)
        mloc = jnp.max(sc, axis=-1)
        lse_loc = mloc + jnp.log(jnp.maximum(
            jnp.sum(jnp.exp(sc - mloc[..., None]), -1), 1e-30)) \
            + jnp.log(float(stride))

        # (2) global lse table (tiny) + top-P selection per kv head
        lse = jax.lax.all_gather(lse_loc, seq_axes, axis=2, tiled=True)
        if heads_sharded:
            pass  # heads are local; each shard selects for its own heads
        e = lse.reshape(bq, hkv_l, g_l, -1)
        m_g = jnp.max(e, axis=2)
        lse_kv = m_g + jnp.log(jnp.maximum(
            jnp.sum(jnp.exp(e - m_g[:, :, None]), 2), 1e-30))  # (b,hkv,nb)
        _, sel = jax.lax.top_k(lse_kv, top_p)                  # (b,hkv,P)

        # (3) exact attention over the selected blocks THIS shard owns
        my_first = seq_off // bk
        sel_local = sel - my_first
        owned = (sel_local >= 0) & (sel_local < nb_loc)        # (b,hkv,P)
        sel_c = jnp.clip(sel_local, 0, nb_loc - 1)
        kb = k_l.reshape(bq, hkv_l, nb_loc, bk, hd)
        vb = v_l.reshape(bq, hkv_l, nb_loc, bk, hd)
        ksel = jnp.take_along_axis(kb, sel_c[:, :, :, None, None], axis=2)
        vsel = jnp.take_along_axis(vb, sel_c[:, :, :, None, None], axis=2)
        ksel = jnp.repeat(ksel, g_l, axis=1).astype(jnp.float32)
        vsel = jnp.repeat(vsel, g_l, axis=1).astype(jnp.float32)
        sc2 = jnp.einsum("bhd,bhpkd->bhpk", q32, ksel) * scale
        kpos = (seq_off + sel_c[:, :, :, None] * bk
                + jnp.arange(bk)[None, None, None, :])         # (b,hkv,P,bk)
        valid = (kpos < kv_valid) & owned[..., None]
        valid = jnp.repeat(valid, g_l, axis=1)
        sc2 = jnp.where(valid, sc2, _NEG_INF)

        # (4) combine with a fixed global reference (pmax) + psum
        m_ref = jax.lax.pmax(jnp.max(sc2, axis=(2, 3)), seq_axes)  # (b, hq)
        p = jnp.exp(sc2 - m_ref[..., None, None])
        l_loc = p.sum((2, 3))
        acc_loc = jnp.einsum("bhpk,bhpkd->bhd", p, vsel)
        # residual: local unselected blocks' estimated mass
        sel_q = jnp.repeat(sel, g_l, axis=1) - my_first        # (b,hq,P)
        chosen = jnp.any(
            jnp.arange(nb_loc)[None, None, :, None] == sel_q[:, :, None, :],
            axis=-1)                                           # (b,hq,nb_loc)
        resid_loc = jnp.where(chosen, 0.0,
                              jnp.exp(lse_loc - m_ref[..., None])).sum(-1)
        l = jax.lax.psum(l_loc, seq_axes)
        acc = jax.lax.psum(acc_loc, seq_axes)
        resid = jax.lax.psum(resid_loc, seq_axes)
        out = acc / jnp.maximum(l + resid, 1e-30)[..., None]
        return out[:, :, None, :].astype(q_l.dtype)

    hspec = "model" if heads_sharded else None
    shmap = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, hspec, None, None),
                  P(None, hspec, seq_axes, None),
                  P(None, hspec, seq_axes, None)),
        out_specs=P(None, hspec, None, None),
        check_vma=False,
    )
    return shmap(q, k, v)


def kde_decode_attention(q, k, v, kv_valid, top_p: int, bk: int,
                         stride: int):
    """jnp mirror of the kde_attention kernel, GSPMD-shardable.

    q (b, hq, 1, hd) single decode step; k, v (b, hkv, S, hd)."""
    from repro.kernels.kde_attention.ref import kde_attention_ref
    assert k.shape[2] % bk == 0, (
        f"KDE attention needs cache length {k.shape[2]} to be a multiple of "
        f"the block size {bk} -- allocate the cache rounded up to bk")
    out = kde_attention_ref(q[:, :, 0, :], k, v, top_p=top_p, bk=bk,
                            stride=stride, kv_valid=kv_valid)
    return out[:, :, None, :]


def attention_block(p, cfg: ArchConfig, x, positions, impl: str = "xla",
                    cache: Optional[Tuple] = None, cache_pos=None,
                    kde_cfg: Optional[Dict] = None):
    """Returns (out (b, s, d), new_cache)."""
    q, k, v = _qkv(p, cfg, x, positions)
    if cache is None:
        if impl == "flash":
            from repro.kernels.flash_attention.ops import flash_attention
            o = flash_attention(q, k, v, True)
        elif q.shape[2] >= CHUNKED_ATTN_THRESHOLD:
            # long prefill: dense S^2 scores would blow HBM
            o = xla_attention_chunked(q, k, v, causal=True)
        else:
            o = xla_attention(q, k, v, causal=True)
        new_cache = None
    else:
        ck, cv = cache                       # (b, hkv, S, hd)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_pos, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_pos, axis=2)
        kv_valid = cache_pos + q.shape[2]
        if impl == "kde" and q.shape[2] == 1:
            kc = kde_cfg or {}
            o = None
            if _ACT["mesh"] is not None:
                o = kde_decode_attention_shardmap(
                    q, ck, cv, kv_valid, top_p=kc.get("top_p", 16),
                    bk=kc.get("bk", 512), stride=kc.get("stride", 16),
                    mesh=_ACT["mesh"], baxes=_ACT["batch_axes"])
            if o is None:
                o = kde_decode_attention(q, ck, cv, kv_valid,
                                         top_p=kc.get("top_p", 16),
                                         bk=kc.get("bk", 512),
                                         stride=kc.get("stride", 16))
        else:
            o = xla_attention(q, ck, cv, causal=True,
                              q_offset=cache_pos, kv_valid=kv_valid)
        new_cache = (ck, cv)
    out = constrain(_merge_heads(o) @ p["wo"].astype(x.dtype), None, None)
    return out, new_cache


def cross_attention_block(p, cfg: ArchConfig, x, memory):
    """Encoder-decoder cross attention (no rope on memory keys)."""
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = _split_heads(x @ p["wq"].astype(x.dtype), hq, hd)
    k = _split_heads(memory @ p["wk"].astype(x.dtype), hkv, hd)
    v = _split_heads(memory @ p["wv"].astype(x.dtype), hkv, hd)
    o = xla_attention(q, k, v, causal=False)
    return _merge_heads(o) @ p["wo"].astype(x.dtype)


# ------------------------------------------------------------------ mlp
def swiglu(p, x):
    h = jax.nn.silu(x @ p["w1"].astype(x.dtype)) * (x @ p["w3"].astype(x.dtype))
    h = constrain(h, None, "model")
    return h @ p["w2"].astype(x.dtype)


def moe_block_dense(p, cfg: ArchConfig, x):
    """Reference top-k MoE: every expert runs on every token, outputs
    combined by the gate matrix.  O(e) cost -- test oracle only."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (b,s,e)
    gates, idx = jax.lax.top_k(logits, k)                             # (b,s,k)
    gates = jax.nn.softmax(gates, axis=-1)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)                # (b,s,k,e)
    combine = (gates[..., None] * onehot).sum(2).astype(x.dtype)      # (b,s,e)

    def expert_apply(w1, w3, w2):
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, w1.astype(x.dtype)))
        h = h * jnp.einsum("bsd,df->bsf", x, w3.astype(x.dtype))
        return jnp.einsum("bsf,fd->bsd", h, w2.astype(x.dtype))

    outs = jax.vmap(expert_apply)(p["w1"], p["w3"], p["w2"])          # (e,b,s,d)
    out = jnp.einsum("ebsd,bse->bsd", outs, combine)
    aux = _load_balance_loss(logits, idx, e)
    return out, aux


def moe_block(p, cfg: ArchConfig, x, capacity_factor: float = 1.25):
    """Top-k MoE dispatcher: shard_map expert parallelism when a mesh with a
    divisible 'model' axis is active (one output psum per layer -- see
    _moe_block_shardmap), else the GSPMD scatter/gather fallback."""
    mesh = _ACT["mesh"]
    if (mesh is not None and "model" in mesh.shape
            and cfg.num_experts % mesh.shape["model"] == 0
            and not _ACT["seq_mode"]
            and x.shape[0] % _axes_size(mesh, _ACT["batch_axes"]) == 0):
        return _moe_block_shardmap(p, cfg, x, mesh, _ACT["batch_axes"],
                                   capacity_factor)
    return _moe_block_gspmd(p, cfg, x, capacity_factor)


def _moe_block_shardmap(p, cfg: ArchConfig, x, mesh, baxes,
                        capacity_factor: float = 1.25):
    """Expert-parallel MoE under shard_map: each 'model' shard owns
    e/msize experts, routes the (replicated-over-'model') tokens to its own
    experts only, and the outputs combine with ONE psum of (b_loc, s, d).

    vs the GSPMD fallback, which materializes all-expert buffers and
    all-gathers ~e*cap*d per layer: measured 5.4 GB -> 0.5 GB per layer on
    qwen3-moe train_4k (EXPERIMENTS.md §Perf).
    """
    e, topk = cfg.num_experts, cfg.experts_per_token
    msize = mesh.shape["model"]
    e_loc = e // msize
    b, s, d = x.shape
    cap = max(int(capacity_factor * s * topk / e), 1)

    def local(x_loc, router, w1, w3, w2):
        bl = x_loc.shape[0]
        router_full = jax.lax.all_gather(router.astype(jnp.float32),
                                         "model", axis=1, tiled=True)
        logits = x_loc.astype(jnp.float32) @ router_full      # (bl, s, e)
        gates, idx = jax.lax.top_k(logits, topk)
        gates = jax.nn.softmax(gates, axis=-1)
        eid = idx.reshape(bl, s * topk)
        gate = gates.reshape(bl, s * topk).astype(x_loc.dtype)
        onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)
        slot = (jnp.cumsum(onehot, axis=1) * onehot).max(-1) - 1
        keep = (slot >= 0) & (slot < cap)
        off = jax.lax.axis_index("model") * e_loc
        el = eid - off
        mine = keep & (el >= 0) & (el < e_loc)
        el_c = jnp.clip(el, 0, e_loc - 1)
        slot_c = jnp.clip(slot, 0, cap - 1)
        x_rep = jnp.repeat(x_loc, topk, axis=1)

        def scatter(xg, eg, sg, mg):
            buf = jnp.zeros((e_loc, cap, d), x_loc.dtype)
            return buf.at[eg, sg].add(xg * mg[:, None].astype(x_loc.dtype))

        buf = jax.vmap(scatter)(x_rep, el_c, slot_c, mine)     # (bl,e_loc,cap,d)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf,
                                   w1.astype(x_loc.dtype)))
        h = h * jnp.einsum("becd,edf->becf", buf, w3.astype(x_loc.dtype))
        yb = jnp.einsum("becf,efd->becd", h, w2.astype(x_loc.dtype))

        def gather(ybg, eg, sg, mg, gg):
            return ybg[eg, sg] * (mg.astype(x_loc.dtype) * gg)[:, None]

        y = jax.vmap(gather)(yb, el_c, slot_c, mine, gate)
        y = y.reshape(bl, s, topk, d).sum(2)
        y = jax.lax.psum(y, "model")                           # THE combine
        # aux loss: fractions must be averaged over the GLOBAL batch before
        # the product (aux is nonlinear in the per-shard means)
        probs = jax.nn.softmax(logits, axis=-1)
        frac_tokens = jnp.mean(
            jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
        frac_probs = jnp.mean(probs, axis=(0, 1)).astype(jnp.float32)
        if baxes:
            frac_tokens = jax.lax.pmean(frac_tokens, tuple(baxes))
            frac_probs = jax.lax.pmean(frac_probs, tuple(baxes))
        aux = e * jnp.sum(frac_tokens * frac_probs)
        return y, aux

    shmap = shard_map(
        local, mesh=mesh,
        in_specs=(P(baxes, None, None), P(None, "model"),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(baxes, None, None), P()),
        check_vma=False,
    )
    return shmap(x, p["router"], p["w1"], p["w3"], p["w2"])


def _moe_block_gspmd(p, cfg: ArchConfig, x, capacity_factor: float = 1.25):
    """Production top-k MoE: grouped capacity dispatch via scatter/gather.

    Tokens are grouped along the batch dim (groups align with the 'data'
    sharding, so slotting stays device-local); each group scatters its
    routed tokens into (e, cap) expert buffers, experts matmul on the
    buffers (sharded over 'model' -> expert parallelism), and a gather
    combines.  FLOPs scale with top-k (cap ~ s*k/e), not with num_experts;
    dropped tokens (over capacity) pass through the residual, standard
    Switch behavior.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = max(int(capacity_factor * s * k / e), 1)
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (b,s,e)
    gates, idx = jax.lax.top_k(logits, k)                 # (b, s, k)
    gates = jax.nn.softmax(gates, axis=-1)

    eid = idx.reshape(b, s * k)                           # expert per slot-req
    gate = gates.reshape(b, s * k).astype(x.dtype)
    onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)      # (b, s*k, e)
    slot = (jnp.cumsum(onehot, axis=1) * onehot).max(-1) - 1   # (b, s*k)
    keep = (slot >= 0) & (slot < cap)
    slot_c = jnp.clip(slot, 0, cap - 1)
    x_rep = jnp.repeat(x, k, axis=1)                      # (b, s*k, d)

    def scatter_group(xg, eg, sg, kg):
        buf = jnp.zeros((e, cap, d), x.dtype)
        return buf.at[eg, sg].add(xg * kg[:, None].astype(x.dtype))

    buf = jax.vmap(scatter_group)(x_rep, eid, slot_c, keep)  # (b, e, cap, d)
    buf = constrain(buf, "model", None, None)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w1"].astype(x.dtype)))
    h = h * jnp.einsum("becd,edf->becf", buf, p["w3"].astype(x.dtype))
    yb = jnp.einsum("becf,efd->becd", h, p["w2"].astype(x.dtype))

    def gather_group(ybg, eg, sg, kg, gg):
        return ybg[eg, sg] * (kg.astype(x.dtype) * gg)[:, None]

    y = jax.vmap(gather_group)(yb, eid, slot_c, keep, gate)  # (b, s*k, d)
    y = y.reshape(b, s, k, d).sum(2)
    aux = _load_balance_loss(logits, idx, e)
    return y, aux


def _load_balance_loss(logits, idx, e):
    """Switch-style aux loss: e * sum_i f_i * p_i."""
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1)).astype(jnp.float32)
    return e * jnp.sum(frac_tokens * frac_probs)
