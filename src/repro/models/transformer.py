"""Model assembly: decoder-only / MoE / SSM / hybrid / enc-dec, all
scan-over-layers (compile time and HLO size independent of depth).

Params layout:
  params = {
    "embed":      (V, D),
    "layers":     pytree stacked on a leading L axis (scanned),
    "final_norm": (D,),
    ["lm_head"]:  (D, V)          (absent when tied),
    ["shared_attn"]: {...}        (zamba2's ONE shared attention block),
    ["encoder"]:  {"layers": ..., "final_norm": ...}   (enc-dec),
  }

Decode caches are stacked on the same leading L axis and scanned together
with the layer params.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S


# ------------------------------------------------------------------ init
def _init_dense_layer(key, cfg: ArchConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 2)
    p = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
         "ln2": jnp.ones((cfg.d_model,), jnp.float32),
         "attn": L.init_attention(ks[0], cfg),
         "mlp": L.init_mlp(ks[1], cfg)}
    return p


def _init_rwkv_layer(key, cfg: ArchConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 2)
    return {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "mix": S.init_rwkv6(ks[0], cfg),
            "mlp": L.init_mlp(ks[1], cfg)}


def _init_mamba_layer(key, cfg: ArchConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 2)
    p = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
         "mix": S.init_mamba2(ks[0], cfg)}
    if not cfg.hybrid_attn_every:   # hybrid: the MLP lives in the shared block
        p["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    return p


def _init_encdec_decoder_layer(key, cfg: ArchConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    return {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_x": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": L.init_attention(ks[0], cfg),
            "xattn": L.init_attention(ks[1], cfg),
            "mlp": L.init_mlp(ks[2], cfg)}


def _layer_init_fn(cfg: ArchConfig):
    if cfg.ssm_kind == "rwkv6":
        return _init_rwkv_layer
    if cfg.ssm_kind == "mamba2":
        return _init_mamba_layer
    if cfg.is_encdec:
        return _init_encdec_decoder_layer
    return _init_dense_layer


def init_params(key, cfg: ArchConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    init_one = _layer_init_fn(cfg)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    stacked = jax.vmap(lambda k: init_one(k, cfg))(layer_keys)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(ks[1], (cfg.padded_vocab, cfg.d_model),
                                   jnp.float32) * 0.02,
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            ks[2], (cfg.d_model, cfg.padded_vocab), jnp.float32) * 0.02
    if cfg.ssm_kind == "mamba2" and cfg.hybrid_attn_every:
        params["shared_attn"] = {
            "ln": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": L.init_attention(ks[3], cfg),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "mlp": L.init_mlp(ks[5], cfg),
        }
    if cfg.is_encdec:
        enc_keys = jax.random.split(ks[4], cfg.encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_dense_layer(k, cfg))(enc_keys),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
    return params


def cast_params(params, dtype):
    """Cast weight matrices (not norms/scalars) to the compute dtype."""
    def cast(path, x):
        if x.ndim >= 2:
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map_with_path(cast, params)


# ------------------------------------------------------------------ blocks
def _dense_block(lp, cfg, x, positions, impl, memory=None):
    h, _ = L.attention_block(lp["attn"], cfg, L.rmsnorm(x, lp["ln1"], cfg.norm_eps),
                             positions, impl=impl)
    x = x + h
    if memory is not None:
        h = L.cross_attention_block(lp["xattn"], cfg,
                                    L.rmsnorm(x, lp["ln_x"], cfg.norm_eps),
                                    memory)
        x = x + h
    inner = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        h, aux = L.moe_block(lp["mlp"], cfg, inner)
    else:
        h, aux = L.swiglu(lp["mlp"], inner), 0.0
    return x + h, aux


def _rwkv_block(lp, cfg, x, seq_mixer):
    inner = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if seq_mixer == "chunked":
        h = S.rwkv6_chunked(lp["mix"], cfg, inner)
    else:
        h, _, _ = S.rwkv6_scan(lp["mix"], cfg, inner)
    x = x + h
    x = x + L.swiglu(lp["mlp"], L.rmsnorm(x, lp["ln2"], cfg.norm_eps))
    return x


def _mamba_block(lp, cfg, x, seq_mixer):
    inner = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if seq_mixer == "chunked":
        h = S.mamba2_chunked(lp["mix"], cfg, inner)
    else:
        h, _ = S.mamba2_scan(lp["mix"], cfg, inner)
    x = x + h
    if "mlp" in lp:   # standalone mamba; hybrid keeps the MLP in shared block
        x = x + L.swiglu(lp["mlp"], L.rmsnorm(x, lp["ln2"], cfg.norm_eps))
    return x


def _shared_attn(params, cfg, x, positions, impl):
    sp = params["shared_attn"]
    h, _ = L.attention_block(sp["attn"], cfg,
                             L.rmsnorm(x, sp["ln"], cfg.norm_eps),
                             positions, impl=impl)
    x = x + h
    x = x + L.swiglu(sp["mlp"], L.rmsnorm(x, sp["ln2"], cfg.norm_eps))
    return x


# ------------------------------------------------------------------ forward
def _embed_inputs(params, cfg: ArchConfig, batch) -> Tuple[jnp.ndarray, int]:
    """Returns (x (b, s, d), n_prefix) where the first n_prefix positions are
    frontend embeddings (no loss there)."""
    emb = params["embed"]
    tok = emb[batch["tokens"]]
    dtype = L.dtype_of(cfg)
    tok = tok.astype(dtype)
    if cfg.frontend != "none" and "frontend" in batch:
        fe = batch["frontend"].astype(dtype)
        return jnp.concatenate([fe, tok], axis=1), fe.shape[1]
    return tok, 0


def _run_encoder(params, cfg: ArchConfig, enc_embeds, impl):
    dtype = L.dtype_of(cfg)
    x = enc_embeds.astype(dtype)
    positions = jnp.arange(x.shape[1])

    def body(x, lp):
        # encoder is bidirectional: non-causal attention
        q, k, v = L._qkv(lp["attn"], cfg, L.rmsnorm(x, lp["ln1"], cfg.norm_eps),
                         positions)
        o = L.xla_attention(q, k, v, causal=False)
        x = x + L._merge_heads(o) @ lp["attn"]["wo"].astype(x.dtype)
        x = x + L.swiglu(lp["mlp"], L.rmsnorm(x, lp["ln2"], cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return L.rmsnorm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def forward(params, cfg: ArchConfig, batch, *, impl: str = "xla",
            remat: bool = True, seq_mixer: str = "chunked",
            remat_policy: Optional[str] = "none") -> Tuple[jnp.ndarray, Any]:
    """Train/prefill forward.  Returns (logits (b, s_tok, V), aux_loss)."""
    x, n_prefix = _embed_inputs(params, cfg, batch)
    x = L.constrain(x, None, None)
    positions = jnp.arange(x.shape[1])
    memory = None
    if cfg.is_encdec:
        memory = _run_encoder(params, cfg, batch["frontend"], impl)

    def layer_body(carry, scanned):
        x, aux = carry
        lp, idx = scanned
        if cfg.ssm_kind == "rwkv6":
            x = _rwkv_block(lp, cfg, x, seq_mixer)
        elif cfg.ssm_kind == "mamba2":
            x = _mamba_block(lp, cfg, x, seq_mixer)
            if cfg.hybrid_attn_every:
                x = jax.lax.cond(
                    idx % cfg.hybrid_attn_every == 0,
                    lambda x: _shared_attn(params, cfg, x, positions, impl),
                    lambda x: x, x)
        elif cfg.is_encdec:
            x, a = _dense_block(lp, cfg, x, positions, impl, memory=memory)
            aux = aux + a
        else:
            x, a = _dense_block(lp, cfg, x, positions, impl)
            aux = aux + a
        return (L.constrain(x, None, None), aux), None

    body = layer_body
    if remat:
        policy = None
        if remat_policy == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        body = jax.checkpoint(layer_body, policy=policy, prevent_cse=False)

    idxs = jnp.arange(cfg.num_layers)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                               (params["layers"], idxs))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:]
    logits = _logits(params, cfg, x)
    return logits, aux


def _logits(params, cfg: ArchConfig, x):
    """(b, s, padded_vocab) logits with padded columns masked to -inf."""
    head = params.get("lm_head", params["embed"].T)
    logits = x.astype(jnp.float32) @ head.astype(jnp.float32)
    logits = L.constrain(logits, None, "model")
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask[None, None, :], logits, -1.0e30)
    return logits


# ------------------------------------------------------------------ decode
def init_cache(cfg: ArchConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16, enc_len: int = 0) -> Dict[str, Any]:
    hkv, hd, lcount = cfg.num_kv_heads, cfg.hd, cfg.num_layers
    cache: Dict[str, Any] = {}
    if cfg.ssm_kind == "rwkv6":
        h = cfg.num_heads
        cache["ssm"] = jnp.zeros((lcount, batch_size, h, hd, hd), jnp.float32)
        cache["shift"] = jnp.zeros((lcount, batch_size, cfg.d_model), dtype)
        return cache
    if cfg.ssm_kind == "mamba2":
        hm = (2 * cfg.d_model) // 64
        cache["ssm"] = jnp.zeros((lcount, batch_size, hm, cfg.ssm_state, 64),
                                 jnp.float32)
        if cfg.hybrid_attn_every:
            napp = (lcount + cfg.hybrid_attn_every - 1) // cfg.hybrid_attn_every
            cache["k"] = jnp.zeros((napp, batch_size, hkv, max_len, hd), dtype)
            cache["v"] = jnp.zeros((napp, batch_size, hkv, max_len, hd), dtype)
        return cache
    cache["k"] = jnp.zeros((lcount, batch_size, hkv, max_len, hd), dtype)
    cache["v"] = jnp.zeros((lcount, batch_size, hkv, max_len, hd), dtype)
    if cfg.is_encdec:
        cache["memory"] = jnp.zeros((batch_size, enc_len, cfg.d_model), dtype)
    return cache


def decode_step(params, cfg: ArchConfig, tokens, cache, pos, *,
                impl: str = "xla", kde_cfg: Optional[Dict] = None):
    """One decode step.  tokens (b, 1) int32; pos: scalar int32 (current
    write offset).  Returns (logits (b, 1, V), new_cache)."""
    x = params["embed"][tokens].astype(L.dtype_of(cfg))
    positions = jnp.full((tokens.shape[1],), pos, jnp.int32) + \
        jnp.arange(tokens.shape[1])
    memory = cache.get("memory") if cfg.is_encdec else None

    if cfg.ssm_kind == "rwkv6":
        def body(x, scanned):
            lp, ssm, shift = scanned
            inner = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            h, ssm, shift = S.rwkv6_scan(lp["mix"], cfg, inner, state=ssm,
                                         shift_state=shift)
            x = x + h
            x = x + L.swiglu(lp["mlp"], L.rmsnorm(x, lp["ln2"], cfg.norm_eps))
            return x, (ssm, shift.astype(x.dtype))

        x, (ssm, shift) = jax.lax.scan(
            body, x, (params["layers"], cache["ssm"], cache["shift"]))
        new_cache = {"ssm": ssm, "shift": shift}
    elif cfg.ssm_kind == "mamba2":
        napp_every = cfg.hybrid_attn_every

        def body(carry, scanned):
            x = carry
            lp, ssm, idx = scanned
            inner = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            h, ssm = S.mamba2_scan(lp["mix"], cfg, inner, state=ssm)
            x = x + h
            if "mlp" in lp:
                x = x + L.swiglu(lp["mlp"],
                                 L.rmsnorm(x, lp["ln2"], cfg.norm_eps))
            return x, (ssm, x)

        idxs = jnp.arange(cfg.num_layers)
        # interleave: run mamba scan, applying shared attention outside the
        # scan at the application points (few of them; python loop over apps)
        new_cache = dict(cache)
        if napp_every:
            napp = cache["k"].shape[0]
            ssm_parts, kc, vc = [], [], []
            ssm = cache["ssm"]
            for app in range(napp):
                lo = app * napp_every
                hi = min(lo + napp_every, cfg.num_layers)
                x = _shared_attn_decode(params, cfg, x, cache, app, pos,
                                        impl, kde_cfg, kc, vc)
                seg = jax.tree_util.tree_map(lambda a: a[lo:hi],
                                             params["layers"])
                x, (ssm_seg, _) = jax.lax.scan(body, x, (seg, ssm[lo:hi],
                                                         idxs[lo:hi]))
                ssm_parts.append(ssm_seg)
            new_cache["ssm"] = jnp.concatenate(ssm_parts, axis=0)
            new_cache["k"] = jnp.stack(kc)
            new_cache["v"] = jnp.stack(vc)
        else:
            x, (ssm, _) = jax.lax.scan(body, x, (params["layers"],
                                                 cache["ssm"], idxs))
            new_cache["ssm"] = ssm
    else:
        def body(x, scanned):
            lp, ck, cv = scanned
            h, kv = L.attention_block(
                lp["attn"], cfg, L.rmsnorm(x, lp["ln1"], cfg.norm_eps),
                positions, impl=impl, cache=(ck, cv), cache_pos=pos,
                kde_cfg=kde_cfg)
            x = x + h
            if cfg.is_encdec:
                h = L.cross_attention_block(
                    lp["xattn"], cfg, L.rmsnorm(x, lp["ln_x"], cfg.norm_eps),
                    memory)
                x = x + h
            inner = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
            if cfg.is_moe:
                h, _ = L.moe_block(lp["mlp"], cfg, inner)
            else:
                h = L.swiglu(lp["mlp"], inner)
            return x + h, kv

        x, (ck, cv) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                             cache["v"]))
        new_cache = dict(cache)
        new_cache["k"] = ck
        new_cache["v"] = cv

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, x)
    return logits, new_cache


def _shared_attn_decode(params, cfg, x, cache, app, pos, impl, kde_cfg,
                        kc, vc):
    sp = params["shared_attn"]
    positions = jnp.array([0], jnp.int32) + pos
    h, kv = L.attention_block(
        sp["attn"], cfg, L.rmsnorm(x, sp["ln"], cfg.norm_eps), positions,
        impl=impl, cache=(cache["k"][app], cache["v"][app]), cache_pos=pos,
        kde_cfg=kde_cfg)
    kc.append(kv[0])
    vc.append(kv[1])
    x = x + h
    x = x + L.swiglu(sp["mlp"], L.rmsnorm(x, sp["ln2"], cfg.norm_eps))
    return x
