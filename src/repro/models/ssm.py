"""Attention-free sequence mixers: RWKV6 (Finch) and Mamba2 (SSD).

Both are implemented twice:
  * ``*_scan``   -- the literal per-timestep recurrence (oracle; also the
                    decode step, where the recurrence *is* the algorithm);
  * ``*_chunked``-- the production path: chunkwise-parallel form that turns
                    the recurrence into MXU matmuls (intra-chunk masked
                    attention-like products + an inter-chunk state scan),
                    the standard linear-attention chunking.  Decay ratios are
                    computed in log space with a per-chunk clamp (-30) --
                    contributions below e^-30 are numerically zero anyway.

Simplifications vs the exact HF checkpoints:
rwkv6 uses full-rank decay projections and a SwiGLU channel mix; mamba2
omits the depthwise conv1d (decode state = SSM state only).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import _dense_init, rmsnorm, swiglu

_LOG_CLAMP = -30.0


# =================================================================== RWKV6
def init_rwkv6(key, cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    h, hd = cfg.num_heads, cfg.hd
    dh = h * hd
    ks = jax.random.split(key, 8)
    return {
        "mu": jnp.full((5, d), 0.5, jnp.float32),       # token-shift lerp r,k,v,g,w
        "wr": _dense_init(ks[0], (d, dh)),
        "wk": _dense_init(ks[1], (d, dh)),
        "wv": _dense_init(ks[2], (d, dh)),
        "wg": _dense_init(ks[3], (d, dh)),
        "ww": _dense_init(ks[4], (d, dh), scale=0.01),  # data-dependent decay
        "w0": jnp.full((dh,), -2.0, jnp.float32),
        "u": _dense_init(ks[5], (dh,), scale=0.5).reshape(dh),
        "wo": _dense_init(ks[6], (dh, d)),
    }


def _rwkv6_projections(p, cfg: ArchConfig, x, shift_state):
    """x (b, s, d); shift_state (b, d) = previous token's x (decode carry).

    Returns r, k, v, g (b, s, h, hd), logw (b, s, h, hd) in (-inf, 0)."""
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.hd
    prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    mu = p["mu"].astype(x.dtype)

    def mix(i):
        return x + mu[i] * (prev - x)

    r = (mix(0) @ p["wr"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (mix(1) @ p["wk"].astype(x.dtype)).reshape(b, s, h, hd)
    v = (mix(2) @ p["wv"].astype(x.dtype)).reshape(b, s, h, hd)
    g = (mix(3) @ p["wg"].astype(x.dtype)).reshape(b, s, h, hd)
    wraw = (mix(4).astype(jnp.float32) @ p["ww"].astype(jnp.float32)
            + p["w0"]).reshape(b, s, h, hd)
    logw = -jnp.exp(wraw)                      # log decay, always < 0
    return r, k, v, g, logw


def rwkv6_scan(p, cfg: ArchConfig, x, state=None, shift_state=None):
    """Oracle / decode recurrence.  state (b, h, hd, hd); returns
    (out (b,s,d), state, shift_state)."""
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.hd
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    if shift_state is None:
        shift_state = jnp.zeros((b, d), x.dtype)
    r, k, v, g, logw = _rwkv6_projections(p, cfg, x, shift_state)
    u = p["u"].reshape(h, hd)

    def step(S, inp):
        rt, kt, vt, lw = inp                  # (b, h, hd) each
        rt32, kt32, vt32 = (a.astype(jnp.float32) for a in (rt, kt, vt))
        bonus = (u[None] * kt32)              # (b, h, hd)
        y = jnp.einsum("bhi,bhij->bhj", rt32, S) \
            + jnp.einsum("bhi,bhi->bh", rt32, bonus)[..., None] * vt32
        S = jnp.exp(lw)[..., None] * S + kt32[..., None] * vt32[..., None, :]
        return S, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, logw))  # (s,b,h,hd)
    state, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2, 3)              # (b, s, h, hd)
    y = (y * jax.nn.silu(g.astype(jnp.float32))).reshape(b, s, h * hd)
    out = y.astype(x.dtype) @ p["wo"].astype(x.dtype)
    return out, state, x[:, -1, :]


def rwkv6_chunked(p, cfg: ArchConfig, x, chunk: int = 128):
    """Production chunkwise form; prefix length must divide into chunks."""
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.hd
    c = min(chunk, s)
    assert s % c == 0, "sequence must be a multiple of the chunk size"
    nc = s // c
    shift0 = jnp.zeros((b, d), x.dtype)
    r, k, v, g, logw = _rwkv6_projections(p, cfg, x, shift0)
    u = p["u"].reshape(h, hd)

    def to_chunks(a):                         # (b, s, h, hd) -> (nc, b, h, c, hd)
        return a.reshape(b, nc, c, h, hd).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, logw))
    rc32, kc32, vc32 = (a.astype(jnp.float32) for a in (rc, kc, vc))

    def chunk_step(S, inp):
        rt, kt, vt, lw = inp                  # (b, h, c, hd)
        lp = jnp.cumsum(lw, axis=2) - lw      # exclusive cumsum: P_t
        lp_next = lp + lw                     # P_{t+1}
        lp_end = lp_next[:, :, -1:, :]        # P_C
        q_t = rt * jnp.exp(jnp.maximum(lp, _LOG_CLAMP))
        k_t = kt * jnp.exp(jnp.maximum(-lp_next, _LOG_CLAMP))
        attn = jnp.einsum("bhti,bhsi->bhts", q_t, k_t)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        attn = attn * mask[None, None]
        bonus = jnp.einsum("bhti,bhti->bht", rt, u[None, :, None, :] * kt)
        y = jnp.einsum("bhts,bhsj->bhtj", attn, vt) \
            + jnp.einsum("bhti,bhij->bhtj", q_t, S) \
            + bonus[..., None] * vt
        kS = kt * jnp.exp(jnp.maximum(lp_end - lp_next, _LOG_CLAMP))
        S = jnp.exp(jnp.maximum(lp_end.squeeze(2), _LOG_CLAMP))[..., None] * S \
            + jnp.einsum("bhsi,bhsj->bhij", kS, vt)
        return S, y

    S0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, S0, (rc32, kc32, vc32,
                                          lwc.astype(jnp.float32)))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, hd)
    y = (y * jax.nn.silu(g.astype(jnp.float32))).reshape(b, s, h * hd)
    return y.astype(x.dtype) @ p["wo"].astype(x.dtype)


# =================================================================== Mamba2
def init_mamba2(key, cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    di = 2 * d
    n = cfg.ssm_state
    hm = di // 64                              # SSD head dim 64
    ks = jax.random.split(key, 5)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di)),
        "bc_proj": _dense_init(ks[1], (d, 2 * n)),
        "dt_proj": _dense_init(ks[2], (d, hm)),
        "dt_bias": jnp.zeros((hm,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, float(max(hm, 2)), hm)),
        "d_skip": jnp.ones((hm,), jnp.float32),
        "out_proj": _dense_init(ks[3], (di, d)),
    }


def _mamba2_projections(p, cfg: ArchConfig, x):
    b, s, d = x.shape
    di = 2 * d
    n = cfg.ssm_state
    hm = di // 64
    xz = x @ p["in_proj"].astype(x.dtype)
    xin, z = jnp.split(xz, 2, axis=-1)                    # (b, s, di)
    bc = x @ p["bc_proj"].astype(x.dtype)
    bmat, cmat = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # (b, s, n)
    dt = jax.nn.softplus(x.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"])                  # (b, s, hm)
    a = -jnp.exp(p["a_log"])                              # (hm,)
    logdecay = dt * a[None, None, :]                      # (b, s, hm) < 0
    xh = xin.reshape(b, s, hm, 64)
    return xh, z, bmat, cmat, dt, logdecay


def mamba2_scan(p, cfg: ArchConfig, x, state=None):
    """Oracle / decode recurrence.  state (b, hm, n, 64)."""
    b, s, d = x.shape
    n = cfg.ssm_state
    hm = (2 * d) // 64
    if state is None:
        state = jnp.zeros((b, hm, n, 64), jnp.float32)
    xh, z, bmat, cmat, dt, logdecay = _mamba2_projections(p, cfg, x)

    def step(h, inp):
        xt, bt, ct, dtt, ld = inp             # (b,hm,64),(b,n),(b,n),(b,hm),(b,hm)
        xt32 = xt.astype(jnp.float32)
        h = jnp.exp(ld)[..., None, None] * h \
            + (dtt[..., None] * bt[:, None, :])[..., None] * xt32[:, :, None, :]
        y = jnp.einsum("bn,bhnp->bhp", ct, h) + p["d_skip"][None, :, None] * xt32
        return h, y

    xs = (xh.transpose(1, 0, 2, 3), bmat.transpose(1, 0, 2),
          cmat.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          logdecay.transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, 2 * d)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(x.dtype) @ p["out_proj"].astype(x.dtype), state


def mamba2_chunked(p, cfg: ArchConfig, x, chunk: int = 128):
    b, s, d = x.shape
    n = cfg.ssm_state
    hm = (2 * d) // 64
    c = min(chunk, s)
    assert s % c == 0
    nc = s // c
    xh, z, bmat, cmat, dt, logdecay = _mamba2_projections(p, cfg, x)

    xc = xh.reshape(b, nc, c, hm, 64).transpose(1, 0, 3, 2, 4)   # (nc,b,hm,c,64)
    bc_ = bmat.reshape(b, nc, c, n).transpose(1, 0, 2, 3)        # (nc,b,c,n)
    cc_ = cmat.reshape(b, nc, c, n).transpose(1, 0, 2, 3)
    dtc = dt.reshape(b, nc, c, hm).transpose(1, 0, 3, 2)         # (nc,b,hm,c)
    ldc = logdecay.reshape(b, nc, c, hm).transpose(1, 0, 3, 2)

    def chunk_step(h, inp):
        xt, bt, ct, dtt, ld = inp
        la = jnp.cumsum(ld, axis=2)                  # inclusive (b, hm, c)
        la_end = la[:, :, -1:]
        # intra: y_t = sum_{s<=t} C_t.B_s exp(la_t - la_s) dt_s x_s
        scores = jnp.einsum("btn,bsn->bts", ct, bt)  # (b, c, c)
        # valid (s <= t) region has la_t - la_s <= 0; clamp to [CLAMP, 0] so
        # the masked upper triangle cannot overflow to inf before masking.
        ratio = jnp.exp(jnp.clip(la[:, :, :, None] - la[:, :, None, :],
                                 _LOG_CLAMP, 0.0))   # (b, hm, c, c)
        mask = jnp.tril(jnp.ones((c, c), bool))
        attn = scores[:, None] * ratio * mask[None, None]
        y = jnp.einsum("bhts,bhs,bhsp->bhtp", attn, dtt, xt.astype(jnp.float32))
        # inter: exp(la_t) C_t h0
        y = y + jnp.exp(jnp.maximum(la, _LOG_CLAMP))[..., None] * \
            jnp.einsum("btn,bhnp->bhtp", ct, h)
        # state update
        w = dtt * jnp.exp(jnp.maximum(la_end - la, _LOG_CLAMP))   # (b, hm, c)
        h = jnp.exp(jnp.maximum(la_end.squeeze(2), _LOG_CLAMP))[..., None, None] * h \
            + jnp.einsum("bhs,bsn,bhsp->bhnp", w, bt, xt.astype(jnp.float32))
        y = y + p["d_skip"][None, :, None, None] * xt.astype(jnp.float32)
        return h, y

    h0 = jnp.zeros((b, hm, n, 64), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, (xc, bc_, cc_, dtc, ldc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, 2 * d)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(x.dtype) @ p["out_proj"].astype(x.dtype)
