"""Roofline analysis: analytic terms from the compiled dry-run artifact,
plus a *measured* mode (bytes and seconds observed on a live run against a
backend-configurable chip spec).

Analytic terms (seconds, per step), against a ``ChipSpec``:
  compute    = FLOPs / (chips * spec.peak_flops)
  memory     = HBM bytes / (chips * spec.hbm_bw)
  collective = per-device collective bytes / spec.link_bw

FLOPs / HBM bytes come from the analytic model (roofline/flops.py) because
XLA cost_analysis counts while(=scan) bodies once (measured);
raw cost_analysis values are recorded alongside.  Collective bytes are
parsed from ``compiled.as_text()`` -- the post-SPMD per-device program -- by
summing operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, each multiplied by the product of enclosing
while-loop trip counts (extracted from the loop condition's comparison
constant).

Measured mode (``measured_roofline``) takes a wall time and the modeled
flops/bytes of the program that ran, and reports the achieved fraction of
the spec's roofline: ``max(compute_s, memory_s, collective_s) / time_s``
-- 1.0 means the run sits ON the roofline for its dominant resource.  The
benchmarks' scaling campaigns record this per size so regressions show as
a falling fraction, not just a rising microsecond count.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Peak rates of one accelerator chip (or host core) for roofline
    normalization.  ``link_bw`` is the per-link interconnect rate used by
    the collective term; hosts without a fabric reuse memory bandwidth."""
    name: str
    peak_flops: float          # FLOP/s per chip (dense, preferred dtype)
    hbm_bw: float              # bytes/s per chip
    link_bw: float             # bytes/s per link

    def as_dict(self):
        return dataclasses.asdict(self)


# TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s per ICI link.
TPU_V5E = ChipSpec("tpu_v5e", 197e12, 819e9, 50e9)

# Order-of-magnitude single host core (AVX2-class f32 FMA, DRAM stream):
# the fallback spec when the process runs on the CPU backend, so measured
# fractions stay O(0.1..1) instead of reading as 1e-4 of a TPU.
HOST_CPU = ChipSpec("host_cpu", 5.0e10, 2.0e10, 2.0e10)


def chip_spec_for_backend(backend: Optional[str] = None) -> ChipSpec:
    """Chip spec for an explicit backend name, or the process default
    backend when None.  Unknown / GPU backends get the TPU spec (the
    campaign's normalization target) -- pass an explicit ``ChipSpec`` to
    the term builders to override."""
    if backend is None:
        try:
            import jax
            backend = jax.default_backend()
        except Exception:
            backend = "cpu"
    return HOST_CPU if backend == "cpu" else TPU_V5E


# Back-compat module constants (== TPU_V5E); roofline_terms defaults to
# them so the dry-run artifact numbers are unchanged.
PEAK_FLOPS = TPU_V5E.peak_flops      # bf16 / chip
HBM_BW = TPU_V5E.hbm_bw              # bytes/s / chip
LINK_BW = TPU_V5E.link_bw            # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

def dtype_bytes(dtype: str) -> int:
    """Bytes per element of an HLO/numpy-style dtype name ("f32", "bf16",
    "bfloat16", "float32", ...).  The ONE bytes-per-dtype table -- the
    measured-mode byte models in ``benchmarks/`` use this instead of
    hardcoding 4."""
    alias = {"float64": "f64", "float32": "f32", "bfloat16": "bf16",
             "float16": "f16", "int64": "s64", "int32": "s32",
             "int16": "s16", "int8": "s8", "uint64": "u64", "uint32": "u32",
             "uint16": "u16", "uint8": "u8", "bool": "pred"}
    key = alias.get(str(dtype), str(dtype))
    if key not in _DTYPE_BYTES:
        raise KeyError(f"unknown dtype {dtype!r}")
    return _DTYPE_BYTES[key]


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")


def shape_bytes(type_str: str) -> int:
    """Total bytes of possibly-tuple HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]
    total_bytes: float
    unresolved_trips: int = 0


def _parse_computations(text: str):
    """-> {comp_name: [instruction lines]}"""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry = None
    for line in text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def _instr_shapes(lines: List[str]) -> Dict[str, str]:
    """instr name -> result type string (for operand size lookup)."""
    out = {}
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if m:
            out[m.group(1)] = m.group(2)
    return out


def _trip_count(cond_lines: List[str]) -> Optional[int]:
    """Find the loop bound: the comparison constant in the condition."""
    consts = []
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            consts.append(int(m.group(1)))
    return max(consts) if consts else None


def _references(lines: List[str]) -> List[Tuple[str, List[str], Optional[str]]]:
    """(opcode, referenced computations, cond_name) per call-like instr."""
    refs = []
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        op = m.group(3)
        rest = m.group(4)
        if op == "while":
            body = re.search(r"body=%?([\w\.\-]+)", rest)
            cond = re.search(r"condition=%?([\w\.\-]+)", rest)
            if body:
                refs.append(("while", [body.group(1)],
                             cond.group(1) if cond else None))
        elif op == "conditional":
            bs = re.search(r"branch_computations=\{([^}]*)\}", rest)
            if bs:
                names = [s.strip().lstrip("%") for s in bs.group(1).split(",")]
                refs.append(("conditional", names, None))
            else:
                tb = re.search(r"true_computation=%?([\w\.\-]+)", rest)
                fb = re.search(r"false_computation=%?([\w\.\-]+)", rest)
                names = [x.group(1) for x in (tb, fb) if x]
                if names:
                    refs.append(("conditional", names, None))
        elif op in ("call", "fusion"):
            c = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)", rest)
            if c:
                refs.append((op, [c.group(1)], None))
    return refs


def collective_bytes(text: str,
                     default_trip: int = 1) -> CollectiveStats:
    comps, entry = _parse_computations(text)
    if entry is None:
        entry = next(iter(comps), None)
    # multipliers via BFS over the call graph
    mult: Dict[str, float] = {entry: 1.0} if entry else {}
    unresolved = 0
    frontier = [entry] if entry else []
    seen = set(frontier)
    while frontier:
        nxt = []
        for comp in frontier:
            m = mult.get(comp, 1.0)
            for op, names, cond in _references(comps.get(comp, [])):
                child_mult = m
                if op == "while":
                    trip = None
                    if cond and cond in comps:
                        trip = _trip_count(comps[cond])
                    if trip is None:
                        trip = default_trip
                        unresolved += 1
                    child_mult = m * trip
                for name in names:
                    if name in comps:
                        mult[name] = max(mult.get(name, 0.0), child_mult)
                        if name not in seen:
                            seen.add(name)
                            nxt.append(name)
        frontier = nxt

    bytes_by: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    count_by: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for comp, lines in comps.items():
        m = mult.get(comp, 1.0)
        shapes = _instr_shapes(lines)
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            op = im.group(3)
            kind = next((c for c in _COLLECTIVES
                         if op == c or op == c + "-start"), None)
            if kind is None:
                continue
            # operand sizes: resolve named operands from the symbol table
            opnds = re.findall(r"%([\w\.\-]+)", im.group(4).split(")")[0])
            b = sum(shape_bytes(shapes.get(o, "")) for o in opnds)
            if b == 0:  # fallback: result size
                b = shape_bytes(im.group(2))
            bytes_by[kind] += b * m
            count_by[kind] += 1
    total = sum(bytes_by.values())
    return CollectiveStats(bytes_by, count_by, total, unresolved)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    flops_total: float
    model_flops: float
    useful_ratio: float
    hbm_bytes: float
    collective_bytes_per_device: float
    chips: int
    raw_cost_flops: Optional[float] = None
    raw_cost_bytes: Optional[float] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(flops_total: float, model_flops: float, hbm_bytes: float,
                   coll_bytes_per_device: float, chips: int,
                   raw_cost: Optional[Dict] = None,
                   spec: ChipSpec = TPU_V5E) -> Roofline:
    compute_s = flops_total / (chips * spec.peak_flops)
    memory_s = hbm_bytes / (chips * spec.hbm_bw)
    collective_s = coll_bytes_per_device / spec.link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, flops_total=flops_total, model_flops=model_flops,
        useful_ratio=model_flops / max(flops_total, 1.0),
        hbm_bytes=hbm_bytes, collective_bytes_per_device=coll_bytes_per_device,
        chips=chips,
        raw_cost_flops=(raw_cost or {}).get("flops"),
        raw_cost_bytes=(raw_cost or {}).get("bytes accessed"))


@dataclasses.dataclass
class MeasuredRoofline:
    """One live measurement against a chip spec's roofline.

    ``achieved_fraction = max(compute_s, memory_s, collective_s) / time_s``
    -- the fraction of the roofline bound actually reached (1.0 = the run
    is AT the bound for its dominant resource; > 1 means the byte/flop
    model undercounts, e.g. cache-resident traffic)."""
    time_s: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    achieved_fraction: float
    achieved_flops: float
    achieved_bw: float
    spec: str
    chips: int

    def as_dict(self):
        return dataclasses.asdict(self)


def measured_roofline(time_s: float, flops: float, bytes_moved: float,
                      spec: Optional[ChipSpec] = None, chips: int = 1,
                      coll_bytes_per_device: float = 0.0) -> MeasuredRoofline:
    """Roofline placement of a measured run: modeled flops/bytes of the
    program that ran, observed wall seconds, backend-configurable peaks
    (``chip_spec_for_backend()`` when ``spec`` is None)."""
    if spec is None:
        spec = chip_spec_for_backend()
    compute_s = flops / (chips * spec.peak_flops)
    memory_s = bytes_moved / (chips * spec.hbm_bw)
    collective_s = coll_bytes_per_device / spec.link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    t = max(float(time_s), 1e-12)
    return MeasuredRoofline(
        time_s=float(time_s), compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant,
        achieved_fraction=max(compute_s, memory_s, collective_s) / t,
        achieved_flops=flops / t, achieved_bw=bytes_moved / t,
        spec=spec.name, chips=chips)
