"""Roofline analysis from the compiled dry-run artifact (TPU v5e target).

Terms (seconds, per step):
  compute    = FLOPs / (chips * 197 TF/s bf16)
  memory     = HBM bytes / (chips * 819 GB/s)
  collective = per-device collective bytes / 50 GB/s/link

FLOPs / HBM bytes come from the analytic model (roofline/flops.py) because
XLA cost_analysis counts while(=scan) bodies once (measured);
raw cost_analysis values are recorded alongside.  Collective bytes are
parsed from ``compiled.as_text()`` -- the post-SPMD per-device program -- by
summing operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, each multiplied by the product of enclosing
while-loop trip counts (extracted from the loop condition's comparison
constant).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")


def shape_bytes(type_str: str) -> int:
    """Total bytes of possibly-tuple HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]
    total_bytes: float
    unresolved_trips: int = 0


def _parse_computations(text: str):
    """-> {comp_name: [instruction lines]}"""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry = None
    for line in text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def _instr_shapes(lines: List[str]) -> Dict[str, str]:
    """instr name -> result type string (for operand size lookup)."""
    out = {}
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if m:
            out[m.group(1)] = m.group(2)
    return out


def _trip_count(cond_lines: List[str]) -> Optional[int]:
    """Find the loop bound: the comparison constant in the condition."""
    consts = []
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            consts.append(int(m.group(1)))
    return max(consts) if consts else None


def _references(lines: List[str]) -> List[Tuple[str, List[str], Optional[str]]]:
    """(opcode, referenced computations, cond_name) per call-like instr."""
    refs = []
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        op = m.group(3)
        rest = m.group(4)
        if op == "while":
            body = re.search(r"body=%?([\w\.\-]+)", rest)
            cond = re.search(r"condition=%?([\w\.\-]+)", rest)
            if body:
                refs.append(("while", [body.group(1)],
                             cond.group(1) if cond else None))
        elif op == "conditional":
            bs = re.search(r"branch_computations=\{([^}]*)\}", rest)
            if bs:
                names = [s.strip().lstrip("%") for s in bs.group(1).split(",")]
                refs.append(("conditional", names, None))
            else:
                tb = re.search(r"true_computation=%?([\w\.\-]+)", rest)
                fb = re.search(r"false_computation=%?([\w\.\-]+)", rest)
                names = [x.group(1) for x in (tb, fb) if x]
                if names:
                    refs.append(("conditional", names, None))
        elif op in ("call", "fusion"):
            c = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)", rest)
            if c:
                refs.append((op, [c.group(1)], None))
    return refs


def collective_bytes(text: str,
                     default_trip: int = 1) -> CollectiveStats:
    comps, entry = _parse_computations(text)
    if entry is None:
        entry = next(iter(comps), None)
    # multipliers via BFS over the call graph
    mult: Dict[str, float] = {entry: 1.0} if entry else {}
    unresolved = 0
    frontier = [entry] if entry else []
    seen = set(frontier)
    while frontier:
        nxt = []
        for comp in frontier:
            m = mult.get(comp, 1.0)
            for op, names, cond in _references(comps.get(comp, [])):
                child_mult = m
                if op == "while":
                    trip = None
                    if cond and cond in comps:
                        trip = _trip_count(comps[cond])
                    if trip is None:
                        trip = default_trip
                        unresolved += 1
                    child_mult = m * trip
                for name in names:
                    if name in comps:
                        mult[name] = max(mult.get(name, 0.0), child_mult)
                        if name not in seen:
                            seen.add(name)
                            nxt.append(name)
        frontier = nxt

    bytes_by: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    count_by: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for comp, lines in comps.items():
        m = mult.get(comp, 1.0)
        shapes = _instr_shapes(lines)
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            op = im.group(3)
            kind = next((c for c in _COLLECTIVES
                         if op == c or op == c + "-start"), None)
            if kind is None:
                continue
            # operand sizes: resolve named operands from the symbol table
            opnds = re.findall(r"%([\w\.\-]+)", im.group(4).split(")")[0])
            b = sum(shape_bytes(shapes.get(o, "")) for o in opnds)
            if b == 0:  # fallback: result size
                b = shape_bytes(im.group(2))
            bytes_by[kind] += b * m
            count_by[kind] += 1
    total = sum(bytes_by.values())
    return CollectiveStats(bytes_by, count_by, total, unresolved)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    flops_total: float
    model_flops: float
    useful_ratio: float
    hbm_bytes: float
    collective_bytes_per_device: float
    chips: int
    raw_cost_flops: Optional[float] = None
    raw_cost_bytes: Optional[float] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(flops_total: float, model_flops: float, hbm_bytes: float,
                   coll_bytes_per_device: float, chips: int,
                   raw_cost: Optional[Dict] = None) -> Roofline:
    compute_s = flops_total / (chips * PEAK_FLOPS)
    memory_s = hbm_bytes / (chips * HBM_BW)
    collective_s = coll_bytes_per_device / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, flops_total=flops_total, model_flops=model_flops,
        useful_ratio=model_flops / max(flops_total, 1.0),
        hbm_bytes=hbm_bytes, collective_bytes_per_device=coll_bytes_per_device,
        chips=chips,
        raw_cost_flops=(raw_cost or {}).get("flops"),
        raw_cost_bytes=(raw_cost or {}).get("bytes accessed"))
