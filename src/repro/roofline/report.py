"""Refresh analytic roofline fields in a dry-run JSON and render the
EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.roofline.report results/dryrun.json
"""
from __future__ import annotations

import json
import sys

from repro.configs.base import SHAPES, get_config
from repro.roofline.analysis import roofline_terms
from repro.roofline.flops import cell_cost


def refresh(path: str) -> list:
    with open(path) as f:
        records = json.load(f)
    for r in records:
        if not r.get("ok"):
            continue
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        cost = cell_cost(cfg, shape, kde_decode=r.get("kde_decode", False))
        rl = roofline_terms(cost.flops, cost.model_flops, cost.hbm_bytes,
                            r["collectives"]["total_bytes_per_device"],
                            r["chips"], r.get("raw_cost"))
        r["roofline"] = rl.as_dict()
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
    return records


def _fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def render_markdown(records: list, mesh: str = "16x16") -> str:
    rows = [r for r in records if r.get("ok") and r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | mem/dev GiB | compute ms | memory ms | "
           "collective ms | dominant | useful ratio | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rl = r["roofline"]
        note = "kde-attn" if r.get("kde_decode") else ""
        if r["memory"]["peak_estimate_bytes"] > 16 * 2**30:
            note += (";" if note else "") + "exceeds 16G HBM"
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{_fmt_bytes(r['memory']['peak_estimate_bytes'])} | "
            f"{rl['compute_s'] * 1e3:.2f} | {rl['memory_s'] * 1e3:.2f} | "
            f"{rl['collective_s'] * 1e3:.2f} | {rl['dominant']} | "
            f"{min(rl['useful_ratio'], 1.0):.2f} | {note} |")
    return "\n".join(out)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    recs = refresh(path)
    print(render_markdown(recs))
