"""Analytic FLOPs / bytes model per (arch x shape) cell.

Why analytic: XLA's ``cost_analysis`` counts a scan (while-loop) body ONCE
(measured in this container -- see DESIGN.md §6), so raw HLO FLOPs
understate scanned-layer models by ~L x.  The roofline's compute/memory
terms therefore come from these exact formulas (validated against
cost_analysis on small *unrolled* configs in tests); raw cost_analysis
numbers are recorded alongside for transparency, and collective bytes are
parsed from the HLO with while-trip-count correction (analysis.py).

All counts are *per global step* (whole cluster); the roofline divides by
chip count.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, ShapeConfig
from repro.data.pipeline import token_split


@dataclasses.dataclass
class CellCost:
    flops: float                 # total FLOPs for the step
    model_flops: float           # 6 N D (dense) / 6 N_active D (MoE), train only
    weight_bytes: float          # parameter bytes touched
    hbm_bytes: float             # modeled HBM traffic
    notes: str = ""


def _attn_flops(cfg: ArchConfig, b: int, sq: int, skv: int,
                causal: bool) -> float:
    """scores + AV for one layer's attention."""
    f = 2.0 * b * cfg.num_heads * sq * skv * cfg.hd * 2
    return f * (0.5 if causal and sq == skv else 1.0)


def _layer_fwd_flops(cfg: ArchConfig, b: int, s: int, skv: int = 0,
                     decode: bool = False) -> float:
    n = b * s
    d, f_ff, hd = cfg.d_model, cfg.d_ff, cfg.hd
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    skv = skv or s
    if cfg.ssm_kind == "rwkv6":
        proj = 2.0 * n * d * (5 * hq * hd) + 2.0 * n * hq * hd * d
        chunk = min(128, s)
        wkv = 2.0 * b * hq * s * (chunk * hd + 2 * hd * hd) * 2
        mlp = 6.0 * n * d * f_ff
        return proj + wkv + mlp
    if cfg.ssm_kind == "mamba2":
        di = 2 * d
        nst = cfg.ssm_state
        proj = 2.0 * n * d * (2 * di + 2 * nst + di // 64) + 2.0 * n * di * d
        chunk = min(128, s)
        ssd = 2.0 * b * (di // 64) * s * (chunk * 64 + 2 * nst * 64) * 2
        out = proj + ssd
        if cfg.hybrid_attn_every:
            # shared attention block (attn + MLP), amortized per layer
            attn = 2.0 * n * d * (hq + 2 * hkv) * hd + 2.0 * n * hq * hd * d \
                + _attn_flops(cfg, b, s, skv, causal=not decode) \
                + 6.0 * n * d * f_ff
            out += attn / cfg.hybrid_attn_every
        else:
            out += 6.0 * n * d * f_ff
        return out
    qkvo = 2.0 * n * d * (hq + 2 * hkv) * hd + 2.0 * n * hq * hd * d
    attn = _attn_flops(cfg, b, s, skv, causal=True)
    if cfg.is_moe:
        mlp = 2.0 * n * d * cfg.num_experts \
            + 6.0 * n * cfg.experts_per_token * 1.25 * d * f_ff
    else:
        mlp = 6.0 * n * d * f_ff
    return qkvo + attn + mlp


def _head_flops(cfg: ArchConfig, b: int, s: int) -> float:
    return 2.0 * b * s * cfg.d_model * cfg.vocab_size


def _param_bytes(cfg: ArchConfig, dtype_bytes: int = 2) -> float:
    return float(cfg.param_count()) * dtype_bytes


def _active_no_embed(cfg: ArchConfig) -> float:
    """Active params excluding embedding/head tables (prefill computes the
    head once per sequence, not per token)."""
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return float(cfg.active_param_count() - emb)


def cell_cost(cfg: ArchConfig, shape: ShapeConfig,
              kde_decode: bool = False) -> CellCost:
    split = token_split(cfg, shape)
    b = shape.global_batch
    s_tok = split["tokens"]
    s_all = shape.seq_len
    pbytes = _param_bytes(cfg)

    if shape.kind == "train":
        fwd = cfg.num_layers * _layer_fwd_flops(cfg, b, s_all) \
            + _head_flops(cfg, b, s_tok)
        if cfg.is_encdec:
            fwd += cfg.encoder_layers * _layer_fwd_flops(cfg, b, split["frontend"])
        flops = 3.0 * fwd  # fwd + 2x bwd (standard 6ND accounting)
        model_flops = 6.0 * cfg.active_param_count() * b * s_tok
        if cfg.is_encdec:
            # encoder params only see the (shorter) encoder sequence
            d, f = cfg.d_model, cfg.d_ff
            attn_p = d * (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.hd \
                + cfg.num_heads * cfg.hd * d
            enc_params = cfg.encoder_layers * (attn_p + 3 * d * f)
            model_flops += 6.0 * enc_params * b * (split["frontend"] - s_tok)
        # HBM: params read ~3x (fwd/bwd/opt) + grads + 2x adam state rw
        hbm = pbytes * 3 + pbytes + 4 * cfg.param_count() * 4 \
            + 2.0 * b * s_all * cfg.d_model * 2 * cfg.num_layers  # act traffic
        return CellCost(flops, model_flops, pbytes, hbm, "fwd+bwd+opt")

    if shape.kind == "prefill":
        flops = cfg.num_layers * _layer_fwd_flops(cfg, b, s_all) \
            + _head_flops(cfg, b, 1)
        if cfg.is_encdec:
            flops += cfg.encoder_layers * _layer_fwd_flops(cfg, b, split["frontend"])
        model_flops = 2.0 * _active_no_embed(cfg) * b * s_tok \
            + _head_flops(cfg, b, 1)
        hbm = pbytes + 2.0 * b * s_all * cfg.d_model * 2 * cfg.num_layers
        return CellCost(flops, model_flops, pbytes, hbm, "prefill fwd")

    # decode: one token, cache length = seq_len
    s_cache = s_all
    if cfg.ssm_kind == "rwkv6":
        per_tok = 2.0 * cfg.active_param_count() \
            + cfg.num_layers * 2.0 * cfg.num_heads * cfg.hd * cfg.hd * 2
        cache_bytes = cfg.num_layers * b * cfg.num_heads * cfg.hd * cfg.hd * 4
    elif cfg.ssm_kind == "mamba2":
        napp = (cfg.num_layers + cfg.hybrid_attn_every - 1) \
            // max(cfg.hybrid_attn_every, 1) if cfg.hybrid_attn_every else 0
        per_tok = 2.0 * cfg.active_param_count() \
            + cfg.num_layers * 2.0 * (2 * cfg.d_model // 64) * cfg.ssm_state * 64 * 2
        attn_cache = s_cache
        if kde_decode:
            attn_cache = s_cache // 16 + 16 * 512  # stride-16 sweep + top-16 blocks
        per_tok += napp * 2.0 * b * cfg.num_heads * attn_cache * cfg.hd * 2 / max(b, 1)
        cache_bytes = cfg.num_layers * b * (2 * cfg.d_model // 64) * cfg.ssm_state * 64 * 4 \
            + napp * b * cfg.num_kv_heads * s_cache * cfg.hd * 2 * 2
    else:
        attn_cache = s_cache
        notes = "exact decode"
        if kde_decode:
            attn_cache = s_cache // 16 + 16 * 512
            notes = "kde decode (stride 16, top-16 x 512)"
        per_tok = 2.0 * cfg.active_param_count()
        per_tok += cfg.num_layers * 2.0 * cfg.num_heads * attn_cache * cfg.hd * 2 / max(b, 1)
        cache_bytes = cfg.num_layers * b * cfg.num_kv_heads * s_cache * cfg.hd * 2 * 2
        if kde_decode:
            cache_bytes = cache_bytes / 16 + cfg.num_layers * b * \
                cfg.num_kv_heads * 16 * 512 * cfg.hd * 2 * 2
    flops = per_tok * b
    model_flops = 2.0 * cfg.active_param_count() * b
    hbm = pbytes + cache_bytes
    return CellCost(flops, model_flops, pbytes, hbm,
                    "kde decode" if kde_decode else "exact decode")
