"""seamless-m4t-medium: enc-dec, multimodal [arXiv:2308.11596; hf].
12 encoder + 12 decoder layers; audio frontend stubbed (precomputed frame
embeddings via input_specs())."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless_m4t_medium", family="audio", num_layers=12, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=256206,
    encoder_layers=12, frontend="audio", frontend_tokens=1024,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, encoder_layers=2, frontend_tokens=16)
