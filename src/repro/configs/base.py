"""Architecture and shape configuration system.

One ``ArchConfig`` per assigned architecture (src/repro/configs/<id>.py), a
``ShapeConfig`` per assigned input shape, and a registry used by the
launchers (``--arch <id> --shape <name>``).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | ssm | vlm | hybrid | audio | moe
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    rope_style: str = "full"     # full | glm2d (rotary on half the dims)
    qkv_bias: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    # SSM (rwkv6 / mamba2)
    ssm_state: int = 0
    ssm_kind: str = "none"       # none | rwkv6 | mamba2
    # hybrid (zamba2): one *shared* attention block applied every k layers
    hybrid_attn_every: int = 0
    # enc-dec (seamless): encoder layer count; decoder = num_layers
    encoder_layers: int = 0
    # modality frontend stub: input_specs() provides precomputed embeddings
    frontend: str = "none"       # none | vision | audio
    frontend_tokens: int = 0
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 512 so embed/head always TP-shard cleanly
        (e.g. granite's 49155); padded logit columns are masked to -inf."""
        return ((self.vocab_size + 511) // 512) * 512

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.ssm_kind == "rwkv6"

    def param_count(self) -> int:
        """Analytic parameter count (used by MODEL_FLOPS = 6 N D)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab_size, self.hd
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        if self.qkv_bias:
            attn += (self.num_heads + 2 * self.num_kv_heads) * hd
        if self.is_moe:
            mlp = self.num_experts * 3 * d * f + d * self.num_experts
        else:
            mlp = 3 * d * f
        norms = 2 * d
        if self.ssm_kind == "rwkv6":
            dh = self.num_heads * hd          # projection width (= d here)
            layer = 5 * d * dh + dh * d + 3 * d * f + norms  # r,k,v,g,w + out + ffn
        elif self.ssm_kind == "mamba2":
            di = 2 * d
            layer = d * (2 * di + 2 * self.ssm_state) + di * d + norms
            if not self.hybrid_attn_every:
                layer += 3 * d * f   # standalone mamba keeps a per-layer MLP
        else:
            layer = attn + mlp + norms
        total = self.num_layers * layer
        if self.ssm_kind == "mamba2" and self.hybrid_attn_every:
            # ONE shared attention block (attn + MLP), zamba2-style
            total += attn + 3 * d * f + norms
        if self.is_encdec:
            enc_layer = attn + 3 * d * f + norms
            cross = attn + norms
            total += self.encoder_layers * enc_layer + self.num_layers * cross
        total += v * d  # embeddings
        if not self.tie_embeddings:
            total += v * d
        return int(total)

    def active_param_count(self) -> int:
        """MoE: params touched per token (6 N_active D)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense = self.param_count() - self.num_layers * self.num_experts * 3 * d * f
        return int(dense + self.num_layers * self.experts_per_token * 3 * d * f)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "yi_6b", "qwen2_5_14b", "granite_3_2b", "chatglm3_6b", "rwkv6_3b",
    "internvl2_1b", "zamba2_7b", "seamless_m4t_medium", "qwen3_moe_235b_a22b",
    "granite_moe_1b_a400m",
]


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.CONFIG


def get_reduced(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.reduced()


def _shrink(cfg: ArchConfig, **kw) -> ArchConfig:
    return dataclasses.replace(cfg, **kw)
