"""internvl2-1b: InternViT frontend (stub) + 24L LM backbone
[arXiv:2404.16821; hf].  Patch embeddings come precomputed via input_specs().
"""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2_1b", family="vlm", num_layers=24, d_model=896,
    num_heads=14, num_kv_heads=2, d_ff=4864, vocab_size=151655,
    frontend="vision", frontend_tokens=1024, qkv_bias=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=56, num_heads=2, num_kv_heads=2,
        d_ff=112, vocab_size=256, frontend_tokens=16)
