"""granite-3-2b: dense GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite_3_2b", family="dense", num_layers=40, d_model=2048,
    num_heads=32, num_kv_heads=8, d_ff=8192, vocab_size=49155,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=255)
