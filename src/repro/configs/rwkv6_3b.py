"""rwkv6-3b (Finch): attention-free, data-dependent decay [arXiv:2404.05892; hf].

The paper's KDE-attention technique is inapplicable here (no kernel matrix
is formed; see DESIGN.md §8) -- implemented without it.
"""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6_3b", family="ssm", num_layers=32, d_model=2560,
    num_heads=40, num_kv_heads=40, d_ff=8960, vocab_size=65536,
    ssm_kind="rwkv6", ssm_state=64, head_dim=64,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
        head_dim=32, d_ff=128, vocab_size=256, ssm_state=32)
