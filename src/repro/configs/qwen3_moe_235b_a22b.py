"""qwen3-moe-235b-a22b: 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_moe_235b_a22b", family="moe", num_layers=94, d_model=4096,
    num_heads=64, num_kv_heads=4, d_ff=1536, vocab_size=151936,
    head_dim=128, num_experts=128, experts_per_token=8, tie_embeddings=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=32, vocab_size=256, num_experts=8,
        experts_per_token=2)
