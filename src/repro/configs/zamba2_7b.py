"""zamba2-7b: Mamba2 backbone + ONE shared attention block applied
periodically [arXiv:2411.15242; unverified].  81 layers, shared attn every 6.
"""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2_7b", family="hybrid", num_layers=81, d_model=3584,
    num_heads=32, num_kv_heads=32, d_ff=14336, vocab_size=32000,
    ssm_kind="mamba2", ssm_state=64, hybrid_attn_every=6,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, ssm_state=16, hybrid_attn_every=2)
