"""yi-6b: llama-arch dense GQA [arXiv:2403.04652; hf]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi_6b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=4, d_ff=11008, vocab_size=64000,
    tie_embeddings=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256)
