"""granite-moe-1b-a400m: 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite_moe_1b_a400m", family="moe", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=8, d_ff=512, vocab_size=49155,
    num_experts=32, experts_per_token=8,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=255, num_experts=4, experts_per_token=2)
