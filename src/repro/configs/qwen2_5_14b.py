"""qwen2.5-14b: dense GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_5_14b", family="dense", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, d_ff=13824, vocab_size=152064,
    qkv_bias=True, tie_embeddings=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=256)
