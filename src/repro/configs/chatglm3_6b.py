"""chatglm3-6b: GQA kv=2, 2d (half-dim) RoPE [arXiv:2406.12793; hf]."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3_6b", family="dense", num_layers=28, d_model=4096,
    num_heads=32, num_kv_heads=2, d_ff=13696, vocab_size=65024,
    rope_style="glm2d",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256)
