"""Sharding rules: FSDP('data') x TP/EP('model') x DP('pod').

Every parameter gets a (tp_dim, fsdp_dim) preference by name; dimensions are
sharded only when divisible by the mesh axis (fallback: replicate that dim --
e.g. granite's vocab 49155 is not divisible by 16, so the embed falls back to
sharding d_model; yi's 4 KV heads < 16 leave KV projections TP-replicated).

Stacked (scanned) layer parameters carry a leading L axis that is never
sharded.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig

# name -> (tp_dim, fsdp_dim) in the *unstacked* parameter's dims;
# None entries mean "replicate".
_RULES: Dict[str, Tuple[Optional[int], Optional[int]]] = {
    "wq": (1, 0), "wk": (1, 0), "wv": (1, 0), "wo": (0, 1),
    "bq": (0, None), "bk": (0, None), "bv": (0, None),
    "w1": (None, None),  # resolved per-arity below (dense vs moe)
    "w2": (None, None),
    "w3": (None, None),
    "router": (1, 0),
    "wr": (1, 0), "wg": (1, 0), "ww": (1, 0),
    "w0": (0, None), "u": (0, None),
    "in_proj": (1, 0), "bc_proj": (1, 0), "dt_proj": (1, 0),
    "out_proj": (0, 1),
    # embed/head: TP only (no FSDP) -- keeps the logits matmul collective-free
    # (x(b['data'],s,D) @ head(D, V['model']) is fully local) and the embed
    # lookup a cheap local gather + 'model' psum.
    "embed": (0, None), "lm_head": (1, None),
    "mu": (None, 1),
}


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _maybe(dim_size: int, size: int) -> bool:
    return size > 1 and dim_size % size == 0 and dim_size >= size


def param_spec(path, leaf, mesh: Mesh) -> P:
    names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    name = names[-1] if names else None
    stacked = "layers" in names
    nd = leaf.ndim - (1 if stacked else 0)
    dsize = _axis_size(mesh, "data")
    msize = _axis_size(mesh, "model")

    if nd <= 0 or name is None:
        return P()

    if name in ("w1", "w2", "w3"):
        if nd == 3:        # MoE (E, D, F)/(E, F, D): EP on experts
            tp, fsdp = 0, 1
        elif name == "w2":  # dense (F, D)
            tp, fsdp = 0, 1
        else:               # dense (D, F)
            tp, fsdp = 1, 0
    elif name in _RULES:
        tp, fsdp = _RULES[name]
    else:
        return P()  # norms, scalars, biases -> replicated

    spec = [None] * leaf.ndim
    off = 1 if stacked else 0
    if tp is not None and tp < nd and _maybe(leaf.shape[off + tp], msize):
        spec[off + tp] = "model"
    else:
        tp = None
    if fsdp is not None and fsdp < nd and (off + fsdp) != (off + tp if tp is not None else -1) \
            and _maybe(leaf.shape[off + fsdp], dsize):
        spec[off + fsdp] = "data"
    # embed fallback: vocab not divisible -> TP the d_model dim instead
    if name == "embed" and spec[0] is None and _maybe(leaf.shape[1], msize) \
            and spec[1] != "data":
        spec[1] = "model"
    return P(*spec)


def param_shardings(params, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf, mesh)),
        params)


def param_specs_tree(params, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, mesh), params)


# ------------------------------------------------------------------ data
def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(mesh: Mesh, ndim: int, batch_size: Optional[int] = None) -> P:
    axes = batch_axes(mesh)
    if batch_size is not None:
        nshards = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if batch_size % max(nshards, 1) != 0:
            return P(*([None] * ndim))
    return P(axes, *([None] * (ndim - 1)))


def data_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, specs):
    out = {}
    for k, v in specs.items():
        out[k] = NamedSharding(mesh, batch_spec(mesh, v.ndim))
    return out


def cache_spec(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               leaf_name: str, leaf) -> P:
    """Decode-cache sharding.

    batch >= data shards -> shard batch (and kv-heads over 'model' when
    divisible); batch == 1 (long-context) -> shard the *sequence* dim over
    every available axis (flash-decode logsumexp combine is sound under the
    softmax decomposition; GSPMD inserts the psum).
    """
    baxes = batch_axes(mesh)
    nshards = int(np.prod([mesh.shape[a] for a in baxes]))
    msize = _axis_size(mesh, "model")
    spec = [None] * leaf.ndim
    if leaf_name in ("k", "v"):
        # (L, B, Hkv, S, hd)
        if leaf.shape[1] % nshards == 0 and leaf.shape[1] >= nshards:
            spec[1] = baxes
            if _maybe(leaf.shape[2], msize):
                spec[2] = "model"
            else:
                spec[3] = "model" if _maybe(leaf.shape[3], msize) else None
        else:
            axes = baxes if _maybe(leaf.shape[2], msize) else baxes + ("model",)
            if _maybe(leaf.shape[2], msize):
                spec[2] = "model"
            spec[3] = axes
    elif leaf_name == "ssm":
        # (L, B, H, ., .) -- state is small; shard batch if possible
        if leaf.shape[1] % nshards == 0 and leaf.shape[1] >= nshards:
            spec[1] = baxes
        if _maybe(leaf.shape[2], msize):
            spec[2] = "model"
    elif leaf_name in ("shift", "memory"):
        if leaf.shape[-3 if leaf_name == "memory" else 1] % nshards == 0:
            spec[0 if leaf_name == "memory" else 1] = baxes
        if leaf_name == "memory":
            spec = [baxes if leaf.shape[0] % nshards == 0 else None, None, None]
    return P(*spec)


def cache_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, cache):
    def go(path, leaf):
        name = getattr(path[-1], "key", None) or "k"
        return NamedSharding(mesh, cache_spec(cfg, shape, mesh, name, leaf))
    return jax.tree_util.tree_map_with_path(go, cache)
