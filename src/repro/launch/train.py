"""End-to-end training driver with fault tolerance.

Features exercised here (and in tests/test_ft.py):
  * deterministic restart-safe data (batch = f(seed, step)),
  * atomic checkpoints every --ckpt-every steps with auto-resume,
  * failure injection (--fail-at-step kills the process mid-run; rerunning
    the same command resumes from the last commit),
  * elastic restore: resuming on a different --data/--model mesh re-shards
    the checkpoint (the npz is mesh-agnostic),
  * straggler watchdog fed with per-step times,
  * optional int8 gradient compression across the 'pod' axis.

Example (CPU, reduced config):
  python -m repro.launch.train --arch yi_6b --reduced --steps 50 \
      --batch 8 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 20
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import checkpoint as ckpt
from repro.configs.base import ShapeConfig, get_config, get_reduced
from repro.data.pipeline import make_batch
from repro.distributed import sharding as shard
from repro.ft.watchdog import Watchdog
from repro.models import transformer as T
from repro.models.layers import activation_sharding
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="failure injection: exit(17) before this step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    import dataclasses
    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype=args.dtype)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    mesh = jax.make_mesh((args.data, args.model), ("data", "model"))
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.dtype == "bfloat16":
        params = T.cast_params(params, jnp.bfloat16)
    opt_state = opt.init_adamw(params)
    p_shard = shard.param_shardings(params, mesh)
    o_shard = opt.AdamWState(step=NamedSharding(mesh, P()), m=p_shard,
                             v=jax.tree.map(lambda s: s, p_shard))
    params = jax.tree.map(jax.device_put, params, p_shard)
    opt_state = jax.tree.map(jax.device_put, opt_state, o_shard)

    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start_step = ckpt.restore(
            args.ckpt_dir, (params, opt_state),
            shardings=(p_shard, o_shard))
        print(f"[train] resumed from step {start_step}", flush=True)

    step_fn = make_train_step(
        cfg, opt.AdamWConfig(lr=args.lr), microbatch=args.microbatch)
    batch_sharding = {k: NamedSharding(mesh, shard.batch_spec(mesh, v.ndim))
                      for k, v in make_batch(cfg, shape, 0, args.seed).items()}
    with activation_sharding(mesh, ("data",)):
        jstep = jax.jit(step_fn, in_shardings=(p_shard, o_shard, batch_sharding),
                        out_shardings=(p_shard, o_shard, None),
                        donate_argnums=(0, 1))

    wd = Watchdog(hosts=jax.process_count())
    losses = []
    for step in range(start_step, args.steps):
        if step == args.fail_at_step:
            print(f"[train] INJECTED FAILURE at step {step}", flush=True)
            os._exit(17)
        batch = {k: jax.device_put(v, batch_sharding[k])
                 for k, v in make_batch(cfg, shape, step, args.seed).items()}
        t0 = time.monotonic()
        params, opt_state, metrics = jstep(params, opt_state, batch)
        loss = float(metrics["loss"])
        wd.beat(jax.process_index(), time.monotonic() - t0)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step={step} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"t={time.monotonic()-t0:.2f}s "
                  f"watchdog={wd.decide()}", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, (params, opt_state))
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, (params, opt_state))
    print(f"[train] done. first loss={losses[0]:.4f} last={losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
