"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
devices stand in for 2 pods x 256 chips; ``.lower().compile()`` must succeed
and the compiled artifact yields memory_analysis / cost_analysis / the
collective schedule for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch yi_6b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""
# The first two executable lines, BEFORE any jax-importing import: jax locks
# the device count on first initialization.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import ARCH_IDS, SHAPES, ArchConfig, ShapeConfig, get_config  # noqa: E402
from repro.data.pipeline import input_specs, token_split  # noqa: E402
from repro.distributed import sharding as shard  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.roofline.analysis import collective_bytes, roofline_terms  # noqa: E402
from repro.roofline.flops import cell_cost  # noqa: E402
from repro.train import optimizer as opt  # noqa: E402
from repro.train.train_step import make_decode_step, make_prefill_step, make_train_step  # noqa: E402

KDE_DECODE_CFG = {"top_p": 16, "bk": 512, "stride": 16}


def _uses_kde_decode(cfg: ArchConfig, shape: ShapeConfig) -> bool:
    """long_500k exact attention would be quadratic-in-context; attention
    archs run it with the paper's KDE attention (DESIGN.md §3/§8)."""
    return (shape.name == "long_500k" and not cfg.attention_free
            and shape.kind == "decode")


def _params_struct(cfg: ArchConfig):
    def build():
        p = T.init_params(jax.random.PRNGKey(0), cfg)
        return T.cast_params(p, jnp.bfloat16)
    return jax.eval_shape(build)


def _named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               donate: bool = True, microbatch: int = 4,
               seq_mode_prefill: bool = False) -> Dict[str, Any]:
    from repro.models.layers import activation_sharding

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    kde = _uses_kde_decode(cfg, shape)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "kde_decode": kde,
    }
    t0 = time.time()

    params_s = _params_struct(cfg)
    p_shard = shard.param_shardings(params_s, mesh)
    specs = input_specs(cfg, shape)
    batch_shard = {k: NamedSharding(mesh, shard.batch_spec(mesh, v.ndim,
                                                           v.shape[0]))
                   for k, v in specs.items()}

    use_seq_mode = seq_mode_prefill and shape.kind == "prefill"
    record["seq_mode"] = use_seq_mode
    act_ctx = activation_sharding(mesh, shard.batch_axes(mesh),
                                  seq_mode=use_seq_mode)
    if shape.kind == "train":
        opt_s = jax.eval_shape(opt.init_adamw, params_s)
        o_shard = opt.AdamWState(
            step=NamedSharding(mesh, P()),
            m=jax.tree.map(lambda s: s, p_shard), v=jax.tree.map(lambda s: s, p_shard))
        step = make_train_step(cfg, remat=True, microbatch=microbatch)
        record["microbatch"] = microbatch
        jf = jax.jit(step, in_shardings=(p_shard, o_shard, batch_shard),
                     out_shardings=(p_shard, o_shard, None),
                     donate_argnums=(0, 1) if donate else ())
        with act_ctx:
            lowered = jf.lower(params_s, opt_s, specs)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        jf = jax.jit(step, in_shardings=(p_shard, batch_shard))
        with act_ctx:
            lowered = jf.lower(params_s, specs)
    else:  # decode
        split = token_split(cfg, shape)
        enc_len = split["frontend"] if (cfg.is_encdec or cfg.frontend != "none") else 0
        cache_s = jax.eval_shape(
            lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len,
                                 jnp.bfloat16, enc_len=max(enc_len, 1)))
        c_shard = shard.cache_shardings(cfg, shape, mesh, cache_s)
        step = make_decode_step(cfg, impl="kde" if kde else "xla",
                                kde_cfg=KDE_DECODE_CFG if kde else None)
        tok_s = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tok_shard = NamedSharding(mesh, shard.batch_spec(
            mesh, 2, shape.global_batch))
        pos_s = jax.ShapeDtypeStruct((), jnp.int32)
        jf = jax.jit(step,
                     in_shardings=(p_shard, c_shard, tok_shard,
                                   NamedSharding(mesh, P())),
                     out_shardings=(None, None, c_shard),
                     donate_argnums=(1,) if donate else ())
        with act_ctx:
            lowered = jf.lower(params_s, cache_s, tok_s, pos_s)

    record["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t1, 1)

    ma = compiled.memory_analysis()
    record["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_estimate_bytes": int(ma.argument_size_in_bytes
                                   + ma.output_size_in_bytes
                                   + ma.temp_size_in_bytes
                                   - ma.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    record["raw_cost"] = {"flops": float(ca.get("flops", 0.0)),
                          "bytes accessed": float(ca.get("bytes accessed", 0.0))}

    text = compiled.as_text()
    cs = collective_bytes(text, default_trip=cfg.num_layers)
    record["collectives"] = {
        "bytes_by_kind": {k: float(v) for k, v in cs.bytes_by_kind.items()},
        "count_by_kind": cs.count_by_kind,
        "total_bytes_per_device": float(cs.total_bytes),
        "unresolved_trips": cs.unresolved_trips,
    }

    cost = cell_cost(cfg, shape, kde_decode=kde)
    rl = roofline_terms(cost.flops, cost.model_flops, cost.hbm_bytes,
                        cs.total_bytes, chips, record["raw_cost"])
    record["roofline"] = rl.as_dict()
    record["ok"] = True
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) for the chosen mesh")
    ap.add_argument("--archs", type=str, default="",
                    help="comma-separated subset for --all")
    ap.add_argument("--out", type=str, default="")
    ap.add_argument("--force", action="store_true",
                    help="re-run cells even if cached ok")
    ap.add_argument("--seq-mode-prefill", action="store_true",
                    help="context-parallel prefill (sequence over 'model')")
    ap.add_argument("--microbatch", type=int, default=4)
    args = ap.parse_args()

    cells = []
    if args.all:
        archs = args.archs.split(",") if args.archs else ARCH_IDS
        for a in archs:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = set() if args.force else {
        (r["arch"], r["shape"], r["mesh"]) for r in results if r.get("ok")}

    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    for arch, sh in cells:
        if (arch, sh, mesh_name) in done:
            print(f"[skip] {arch} x {sh} x {mesh_name} (cached)")
            continue
        print(f"[dryrun] {arch} x {sh} x {mesh_name} ...", flush=True)
        try:
            rec = lower_cell(arch, sh, args.multi_pod,
                             seq_mode_prefill=args.seq_mode_prefill,
                             microbatch=args.microbatch)
            rl = rec["roofline"]
            print(f"  ok: compile={rec['compile_s']}s "
                  f"mem/dev={rec['memory']['peak_estimate_bytes']/2**30:.2f}GiB "
                  f"compute={rl['compute_s']*1e3:.2f}ms "
                  f"memory={rl['memory_s']*1e3:.2f}ms "
                  f"collective={rl['collective_s']*1e3:.2f}ms "
                  f"dominant={rl['dominant']}", flush=True)
        except Exception as e:  # record failures -- they are bugs to fix
            rec = {"arch": arch, "shape": sh, "mesh": mesh_name, "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"  FAIL: {rec['error']}", flush=True)
        results = [r for r in results
                   if not (r["arch"] == arch and r["shape"] == sh
                           and r["mesh"] == mesh_name)]
        results.append(rec)
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"[dryrun] {n_ok}/{len(results)} cells ok")


if __name__ == "__main__":
    main()
