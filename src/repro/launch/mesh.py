"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (host-device-count permitting)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
