"""Batched serving driver: prefill a batch of prompts, then decode with the
KV cache -- optionally with the paper's KDE attention for long contexts.

Example (CPU, reduced config):
  python -m repro.launch.serve --arch yi_6b --reduced --batch 4 \
      --prompt-len 64 --gen 16
  python -m repro.launch.serve --arch yi_6b --reduced --attention kde

With ``--attention kde --robust`` every decode step's logits are screened
for NaN/Inf; a flagged step is recomputed with the dense xla attention
from the pre-step cache (per-request graceful degradation, DESIGN.md §11)
and counted in the final report.

``--graph-stream N`` serves the OTHER side of the repo instead: an online
kernel-graph service over a mutating point set (DESIGN.md §12).  Each tick
mutates a fraction of the rows (insert/delete/update), then answers vertex
/ neighbor / edge-batch queries at the new epoch -- the samplers patch
their level-1 / degree / hash state instead of rebuilding.  The final
``[serve] metrics {...}`` line is machine-parsable JSON (per-tick
latencies, epoch, flags); a guard trip under ``REPRO_CHECKS=1`` exits 3:

  python -m repro.launch.serve --graph-stream 4096 --ticks 8 \
      --mutate-frac 0.01 --level1 hash

``--serve-tenants S`` runs the multi-tenant batched servable instead
(DESIGN.md §13): S mixed tenants (blocked + hashed level-1), ``--requests
R`` concurrent mixed requests per tick batched into padded device
programs, with p50/p99 request latency and throughput in the metrics
line:

  python -m repro.launch.serve --serve-tenants 4 --requests 16 --ticks 4
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_config, get_reduced
from repro.data.pipeline import make_batch, token_split
from repro.models import transformer as T
from repro.obs import export as _export
from repro.obs import metrics as _metrics
from repro.train.train_step import make_decode_step


def _emit_metrics(payload: dict) -> None:
    """One schema-stamped JSON-lines metrics record (``obs.export``;
    tests, dashboards and ``tools/check_metrics_schema.py`` grep the
    ``[serve] metrics `` prefix and validate the rest)."""
    _export.emit_jsonl(payload)


def run_graph_stream(args, trace=None) -> int:
    """Online kernel-graph serving loop (DESIGN.md §12): mutate, then
    answer at the new epoch.  Cost per tick: O(m) mutation bookkeeping +
    one coalesced patch (O(w·m) level-1, O(n·m) degrees, O(m) hash
    splices) folded into the first query, vs. the frozen engines' full
    rebuild -- the ratio BENCH_streaming.json tracks.

    ``trace`` optionally scripts the mutations: a list of per-tick dicts
    with any of ``insert`` ((m, d) rows), ``delete`` (slot ids, or the
    string ``"frontier"`` to delete rows of the PREVIOUS tick's query
    frontier -- with ``--reuse-frontier`` this forces an ``EPOCH_STALE``
    consumer-side detection), and ``update`` ((slots, rows)).  Exit codes:
    0 clean; 3 when ``REPRO_CHECKS=1`` promoted a status flag to an
    ``EstimationError``."""
    from repro.core.kernels_fn import gaussian
    from repro.core.streaming import StreamingKernelGraph
    from repro.ft.guards import EstimationError

    n, d = int(args.graph_stream), 16
    rng = np.random.default_rng(args.seed)
    x0 = rng.normal(size=(n, d)).astype(np.float32)
    g = StreamingKernelGraph(x0, gaussian(1.0), level1=args.level1,
                             seed=args.seed)
    m = max(int(n * args.mutate_frac), 1)
    ticks = len(trace) if trace is not None else args.ticks
    reuse = bool(getattr(args, "reuse_frontier", False))
    mut_t = qry_t = 0.0
    ticks_done = 0
    frontier = None
    err = None
    try:
        for tick in range(ticks):
            t0 = time.time()
            if trace is not None:
                step = trace[tick]
                if step.get("insert") is not None:
                    g.insert(np.asarray(step["insert"], np.float32))
                dele = step.get("delete")
                if dele is not None:
                    if isinstance(dele, str) and dele == "frontier":
                        dele = (frontier if frontier is not None else
                                g.dataset.live_slots()[:m])
                    g.delete(np.asarray(dele))
                if step.get("update") is not None:
                    slots, rows = step["update"]
                    g.update(np.asarray(slots),
                             np.asarray(rows, np.float32))
            else:
                live = g.dataset.live_slots()
                g.insert(rng.normal(size=(m, d)).astype(np.float32))
                g.delete(rng.choice(live, size=m, replace=False))
                upd = rng.choice(g.dataset.live_slots(), size=m,
                                 replace=False)
                g.update(upd, rng.normal(size=(m, d)).astype(np.float32))
            mut_t += time.time() - t0
            t0 = time.time()
            u = (frontier if reuse and frontier is not None else
                 g.sample_vertices(min(256, n)))
            v, _ = g.sample_neighbors(u)
            g.sample_edges(min(512, n))
            qry_t += time.time() - t0
            assert g.dataset.is_live(v), "sampled a dead neighbor"
            frontier = u
            ticks_done += 1
    except EstimationError as e:
        err = str(e)
        print(f"[serve] guard tripped at tick {ticks_done}: {e}")
    rep = g.status_report()
    per = max(ticks_done, 1)
    print(f"[serve] graph-stream n={n} ticks={ticks_done}/{ticks} "
          f"mutate_frac={args.mutate_frac} level1={args.level1}")
    print(f"[serve] mutation {1e3 * mut_t / per:.1f} ms/tick, "
          f"queries {1e3 * qry_t / per:.1f} ms/tick "
          f"(patch-on-read, no rebuilds in the hot path)")
    _emit_metrics(dict(
        mode="graph-stream", n=n, ticks=ticks_done, ticks_planned=ticks,
        mutation_ms_per_tick=round(1e3 * mut_t / per, 3),
        query_ms_per_tick=round(1e3 * qry_t / per, 3),
        epoch=int(rep["epoch"]), live=int(rep["num_live"]),
        flags=rep["flags"], degree_rebuilds=int(rep["degree_rebuilds"]),
        hash_rebuilds=int(rep["hash_rebuilds"]), error=err))
    return 3 if err is not None else 0


def run_multi_tenant(args) -> int:
    """Multi-tenant batched serving loop (DESIGN.md §13): S tenants with
    mixed estimator configs, ``--requests`` concurrent mixed requests per
    tick drained into padded batch groups.  Reports p50/p99 submit ->
    completion latency and served-requests/s (steady-state: the first
    tick warms every (op, bucket) program off-clock).  Exit codes: 0
    clean; 3 when ``REPRO_CHECKS=1`` turned a request's status flags into
    a per-request error."""
    from repro.core.kernels_fn import gaussian
    from repro.core.serving import KernelGraphServable

    if args.telemetry:
        _metrics.enable()
    S, R = int(args.serve_tenants), int(args.requests)
    n, d = 2048, 8
    rng = np.random.default_rng(args.seed)
    srv = KernelGraphServable(max_resident=int(args.max_resident))
    for i in range(S):
        x = rng.normal(size=(n, d)).astype(np.float32) + 0.1 * i
        level1 = "hash" if (args.level1 == "hash" and i % 2 == 1) else \
            "blocked"
        # one shared kernel config: tenants with equal static signatures
        # stack into the same batch group (the cross-tenant win)
        srv.add_tenant(f"t{i}", x, gaussian(1.0), level1=level1,
                       seed=args.seed + i)

    def submit_mix(tick):
        reqs = []
        for r in range(R):
            tn = f"t{(r + tick) % S}"
            op = ("sample", "query", "walk", "prob_of")[r % 4]
            seed = args.seed + 1000 * tick + r
            if op == "sample":
                reqs.append(srv.submit(tn, "sample", seed=seed,
                                       src=rng.integers(0, n, size=16)))
            elif op == "query":
                reqs.append(srv.submit(
                    tn, "query", seed=seed,
                    y=rng.normal(size=(8, d)).astype(np.float32)))
            elif op == "walk":
                reqs.append(srv.submit(tn, "walk", seed=seed, length=4,
                                       starts=rng.integers(0, n, size=8)))
            else:
                reqs.append(srv.submit(tn, "prob_of", seed=seed,
                                       src=rng.integers(0, n, size=16),
                                       dst=rng.integers(0, n, size=16)))
        return reqs

    submit_mix(0)
    srv.tick()                       # warmup: compiles every group shape
    lat = []
    failed = stale = 0
    per_tenant: dict = {}
    t0 = time.perf_counter()
    for tick in range(1, args.ticks + 1):
        reqs = submit_mix(tick)
        stale += srv.tick()["stale"]
        for r in reqs:
            lat.append(r.latency)
            pt = per_tenant.setdefault(
                r.tenant, dict(served=0, failed=0, lat_ms=[]))
            pt["lat_ms"].append(1e3 * r.latency)
            if r.error is None:
                pt["served"] += 1
            else:
                pt["failed"] += 1
                failed += 1
    wall = time.perf_counter() - t0
    lat_ms = 1e3 * np.asarray(lat)
    rep = srv.report()
    served = args.ticks * R - failed
    print(f"[serve] multi-tenant S={S} R={R}/tick ticks={args.ticks} "
          f"max_resident={args.max_resident}")
    print(f"[serve] p50 {np.percentile(lat_ms, 50):.1f} ms, "
          f"p99 {np.percentile(lat_ms, 99):.1f} ms, "
          f"{served / max(wall, 1e-9):.1f} req/s "
          f"(admissions={rep['admissions']} evictions={rep['evictions']})")
    _emit_metrics(dict(
        mode="multi-tenant", tenants=S, requests_per_tick=R,
        ticks=args.ticks, served=served, failed=failed, stale=stale,
        p50_ms=round(float(np.percentile(lat_ms, 50)), 3),
        p99_ms=round(float(np.percentile(lat_ms, 99)), 3),
        throughput_rps=round(served / max(wall, 1e-9), 2),
        admissions=rep["admissions"], evictions=rep["evictions"],
        realized_evals=rep["device_counters"]["evals"],
        device_counters=rep["device_counters"],
        per_tenant={
            k: dict(served=v["served"], failed=v["failed"],
                    p50_ms=round(float(np.percentile(v["lat_ms"], 50)), 3))
            for k, v in sorted(per_tenant.items())},
        flags=rep["flags"]))
    if args.metrics_format == "prometheus":
        print(_export.prometheus_text(), end="")
    return 3 if failed else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--attention", choices=["xla", "kde"], default="xla")
    ap.add_argument("--kde-top-p", type=int, default=4)
    ap.add_argument("--kde-bk", type=int, default=32)
    ap.add_argument("--kde-stride", type=int, default=4)
    ap.add_argument("--robust", action="store_true",
                    help="screen decode logits; recompute flagged steps "
                         "with dense xla attention from the pre-step cache")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--graph-stream", type=int, default=0,
                    help="serve an online kernel graph over N points "
                         "instead of the LLM path (DESIGN.md §12)")
    ap.add_argument("--ticks", type=int, default=8)
    ap.add_argument("--mutate-frac", type=float, default=0.01)
    ap.add_argument("--level1", choices=["blocked", "hash"],
                    default="blocked")
    ap.add_argument("--reuse-frontier", action="store_true",
                    help="graph-stream: query the PREVIOUS tick's vertex "
                         "frontier (a scripted delete of those rows then "
                         "trips the EPOCH_STALE consumer check)")
    ap.add_argument("--serve-tenants", type=int, default=0,
                    help="run the multi-tenant batched servable over S "
                         "tenants instead (DESIGN.md §13)")
    ap.add_argument("--requests", type=int, default=16,
                    help="concurrent requests per serving tick")
    ap.add_argument("--max-resident", type=int, default=4,
                    help="LRU bound on tenants holding device state")
    ap.add_argument("--telemetry", action="store_true",
                    help="enable the obs metrics registry (latency "
                         "histograms, counters; off by default so the "
                         "serving hot path stays branch-only)")
    ap.add_argument("--metrics-format", choices=["jsonl", "prometheus"],
                    default="jsonl",
                    help="'prometheus' additionally dumps the registry "
                         "in Prometheus text format after the run")
    args = ap.parse_args(argv)

    if args.serve_tenants:
        return run_multi_tenant(args)
    if args.graph_stream:
        return run_graph_stream(args)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    max_len = args.max_len or (args.prompt_len + args.gen)
    if args.attention == "kde":   # cache length must tile into KDE blocks
        max_len = ((max_len + args.kde_bk - 1) // args.kde_bk) * args.kde_bk
    shape = ShapeConfig("serve", args.prompt_len, args.batch, "prefill")

    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    batch = {k: jnp.asarray(v)
             for k, v in make_batch(cfg, shape, 0, args.seed).items()}
    split = token_split(cfg, shape)

    # ---- prefill: run the forward once, then replay tokens into the cache
    # (teacher-forced cache build keeps one code path; production would use a
    # fused prefill kernel writing the cache directly)
    enc_len = split["frontend"] or 1
    cache = T.init_cache(cfg, args.batch, max_len, jnp.float32,
                         enc_len=enc_len)
    if cfg.is_encdec:
        cache["memory"] = T._run_encoder(params, cfg, batch["frontend"], "xla")

    kde_cfg = {"top_p": args.kde_top_p, "bk": args.kde_bk,
               "stride": args.kde_stride} if args.attention == "kde" else None
    step = jax.jit(make_decode_step(cfg, impl=args.attention, kde_cfg=kde_cfg))
    # staged fallback (DESIGN.md §11): a dense twin of the decode step,
    # built lazily so the happy path never compiles it.  Cache pytrees are
    # immutable, so holding the pre-step reference is free.
    robust = bool(args.robust) and args.attention != "xla"
    dense_step = None
    fallbacks = 0

    def guarded(cache_in, cur, pos):
        nonlocal dense_step, fallbacks
        nxt, logits, cache_out = step(params, cache_in, cur, jnp.int32(pos))
        if robust and not bool(jnp.all(jnp.isfinite(logits))):
            if dense_step is None:
                dense_step = jax.jit(make_decode_step(cfg, impl="xla"))
            fallbacks += 1
            nxt, logits, cache_out = dense_step(params, cache_in, cur,
                                                jnp.int32(pos))
        return nxt, logits, cache_out

    tokens = batch["tokens"]
    t0 = time.time()
    for pos in range(split["tokens"]):
        nxt, logits, cache = guarded(cache, tokens[:, pos:pos + 1], pos)
    prefill_t = time.time() - t0

    # ---- decode
    out = [np.asarray(nxt)]
    t0 = time.time()
    cur = nxt[:, None]
    for i in range(args.gen - 1):
        pos = split["tokens"] + i
        nxt, logits, cache = guarded(cache, cur, pos)
        cur = nxt[:, None]
        out.append(np.asarray(nxt))
    decode_t = time.time() - t0
    gen = np.stack(out, 1)
    print(f"[serve] arch={cfg.name} attention={args.attention} "
          f"batch={args.batch} prompt={split['tokens']} gen={args.gen}")
    print(f"[serve] prefill {prefill_t:.2f}s, decode {decode_t:.2f}s "
          f"({args.gen * args.batch / max(decode_t, 1e-9):.1f} tok/s)")
    if robust:
        print(f"[serve] robust: {fallbacks} step(s) recomputed with dense "
              f"attention")
    print(f"[serve] sample generations: {gen[:2].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
