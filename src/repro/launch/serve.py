"""Batched serving driver: prefill a batch of prompts, then decode with the
KV cache -- optionally with the paper's KDE attention for long contexts.

Example (CPU, reduced config):
  python -m repro.launch.serve --arch yi_6b --reduced --batch 4 \
      --prompt-len 64 --gen 16
  python -m repro.launch.serve --arch yi_6b --reduced --attention kde

With ``--attention kde --robust`` every decode step's logits are screened
for NaN/Inf; a flagged step is recomputed with the dense xla attention
from the pre-step cache (per-request graceful degradation, DESIGN.md §11)
and counted in the final report.

``--graph-stream N`` serves the OTHER side of the repo instead: an online
kernel-graph service over a mutating point set (DESIGN.md §12).  Each tick
mutates a fraction of the rows (insert/delete/update), then answers vertex
/ neighbor / edge-batch queries at the new epoch -- the samplers patch
their level-1 / degree / hash state instead of rebuilding, and the final
report shows per-tick mutation and query latency plus the or-folded
status flags:

  python -m repro.launch.serve --graph-stream 4096 --ticks 8 \
      --mutate-frac 0.01 --level1 hash
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_config, get_reduced
from repro.data.pipeline import make_batch, token_split
from repro.models import transformer as T
from repro.train.train_step import make_decode_step


def run_graph_stream(args) -> int:
    """Online kernel-graph serving loop (DESIGN.md §12): mutate, then
    answer at the new epoch.  Cost per tick: O(m) mutation bookkeeping +
    one coalesced patch (O(w·m) level-1, O(n·m) degrees, O(m) hash
    splices) folded into the first query, vs. the frozen engines' full
    rebuild -- the ratio BENCH_streaming.json tracks."""
    from repro.core.kernels_fn import gaussian
    from repro.core.streaming import StreamingKernelGraph

    n, d = int(args.graph_stream), 16
    rng = np.random.default_rng(args.seed)
    x0 = rng.normal(size=(n, d)).astype(np.float32)
    g = StreamingKernelGraph(x0, gaussian(1.0), level1=args.level1,
                             seed=args.seed)
    m = max(int(n * args.mutate_frac), 1)
    mut_t = qry_t = 0.0
    for tick in range(args.ticks):
        t0 = time.time()
        live = g.dataset.live_slots()
        g.insert(rng.normal(size=(m, d)).astype(np.float32))
        g.delete(rng.choice(live, size=m, replace=False))
        upd = rng.choice(g.dataset.live_slots(), size=m, replace=False)
        g.update(upd, rng.normal(size=(m, d)).astype(np.float32))
        mut_t += time.time() - t0
        t0 = time.time()
        u = g.sample_vertices(256)
        v, _ = g.sample_neighbors(u)
        g.sample_edges(512)
        qry_t += time.time() - t0
        assert g.dataset.is_live(v), "sampled a dead neighbor"
    rep = g.status_report()
    print(f"[serve] graph-stream n={n} ticks={args.ticks} "
          f"mutate_frac={args.mutate_frac} level1={args.level1}")
    print(f"[serve] mutation {1e3 * mut_t / args.ticks:.1f} ms/tick, "
          f"queries {1e3 * qry_t / args.ticks:.1f} ms/tick "
          f"(patch-on-read, no rebuilds in the hot path)")
    print(f"[serve] epoch={rep['epoch']} live={rep['num_live']} "
          f"flags={rep['flags']} degree_rebuilds={rep['degree_rebuilds']} "
          f"hash_rebuilds={rep['hash_rebuilds']}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--attention", choices=["xla", "kde"], default="xla")
    ap.add_argument("--kde-top-p", type=int, default=4)
    ap.add_argument("--kde-bk", type=int, default=32)
    ap.add_argument("--kde-stride", type=int, default=4)
    ap.add_argument("--robust", action="store_true",
                    help="screen decode logits; recompute flagged steps "
                         "with dense xla attention from the pre-step cache")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--graph-stream", type=int, default=0,
                    help="serve an online kernel graph over N points "
                         "instead of the LLM path (DESIGN.md §12)")
    ap.add_argument("--ticks", type=int, default=8)
    ap.add_argument("--mutate-frac", type=float, default=0.01)
    ap.add_argument("--level1", choices=["blocked", "hash"],
                    default="blocked")
    args = ap.parse_args(argv)

    if args.graph_stream:
        return run_graph_stream(args)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    max_len = args.max_len or (args.prompt_len + args.gen)
    if args.attention == "kde":   # cache length must tile into KDE blocks
        max_len = ((max_len + args.kde_bk - 1) // args.kde_bk) * args.kde_bk
    shape = ShapeConfig("serve", args.prompt_len, args.batch, "prefill")

    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    batch = {k: jnp.asarray(v)
             for k, v in make_batch(cfg, shape, 0, args.seed).items()}
    split = token_split(cfg, shape)

    # ---- prefill: run the forward once, then replay tokens into the cache
    # (teacher-forced cache build keeps one code path; production would use a
    # fused prefill kernel writing the cache directly)
    enc_len = split["frontend"] or 1
    cache = T.init_cache(cfg, args.batch, max_len, jnp.float32,
                         enc_len=enc_len)
    if cfg.is_encdec:
        cache["memory"] = T._run_encoder(params, cfg, batch["frontend"], "xla")

    kde_cfg = {"top_p": args.kde_top_p, "bk": args.kde_bk,
               "stride": args.kde_stride} if args.attention == "kde" else None
    step = jax.jit(make_decode_step(cfg, impl=args.attention, kde_cfg=kde_cfg))
    # staged fallback (DESIGN.md §11): a dense twin of the decode step,
    # built lazily so the happy path never compiles it.  Cache pytrees are
    # immutable, so holding the pre-step reference is free.
    robust = bool(args.robust) and args.attention != "xla"
    dense_step = None
    fallbacks = 0

    def guarded(cache_in, cur, pos):
        nonlocal dense_step, fallbacks
        nxt, logits, cache_out = step(params, cache_in, cur, jnp.int32(pos))
        if robust and not bool(jnp.all(jnp.isfinite(logits))):
            if dense_step is None:
                dense_step = jax.jit(make_decode_step(cfg, impl="xla"))
            fallbacks += 1
            nxt, logits, cache_out = dense_step(params, cache_in, cur,
                                                jnp.int32(pos))
        return nxt, logits, cache_out

    tokens = batch["tokens"]
    t0 = time.time()
    for pos in range(split["tokens"]):
        nxt, logits, cache = guarded(cache, tokens[:, pos:pos + 1], pos)
    prefill_t = time.time() - t0

    # ---- decode
    out = [np.asarray(nxt)]
    t0 = time.time()
    cur = nxt[:, None]
    for i in range(args.gen - 1):
        pos = split["tokens"] + i
        nxt, logits, cache = guarded(cache, cur, pos)
        cur = nxt[:, None]
        out.append(np.asarray(nxt))
    decode_t = time.time() - t0
    gen = np.stack(out, 1)
    print(f"[serve] arch={cfg.name} attention={args.attention} "
          f"batch={args.batch} prompt={split['tokens']} gen={args.gen}")
    print(f"[serve] prefill {prefill_t:.2f}s, decode {decode_t:.2f}s "
          f"({args.gen * args.batch / max(decode_t, 1e-9):.1f} tok/s)")
    if robust:
        print(f"[serve] robust: {fallbacks} step(s) recomputed with dense "
              f"attention")
    print(f"[serve] sample generations: {gen[:2].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
