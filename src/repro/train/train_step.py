"""Train / prefill / decode step factories (jit + GSPMD).

``make_train_step`` builds a donated, sharded train step: forward (scanned
layers, remat), next-token cross entropy (+ MoE aux loss), AdamW.  Gradient
reduction across data shards is GSPMD-inserted; the optional microbatch loop
accumulates gradients sequentially (grad-accumulation for large global
batches).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as T
from repro.train import optimizer as opt


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token loss; logits (b, s, v), targets (b, s)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss_fn(params, cfg: ArchConfig, batch, *, impl="xla", remat=True,
            seq_mixer="chunked", aux_weight=0.01, remat_policy="none"):
    tokens = batch["tokens"]
    logits, aux = T.forward(params, cfg, batch, impl=impl, remat=remat,
                            seq_mixer=seq_mixer, remat_policy=remat_policy)
    loss = cross_entropy(logits[:, :-1], tokens[:, 1:])
    return loss + aux_weight * aux, (loss, aux)


def make_train_step(cfg: ArchConfig, adamw: opt.AdamWConfig = opt.AdamWConfig(),
                    *, impl: str = "xla", remat: bool = True,
                    seq_mixer: str = "chunked", microbatch: int = 0,
                    remat_policy: str = "none", donate: bool = True):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def grads_of(params, batch):
        (tot, (loss, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch, impl=impl, remat=remat,
                                   seq_mixer=seq_mixer,
                                   remat_policy=remat_policy)
        return grads, loss, aux

    def train_step(params, opt_state, batch):
        if microbatch and microbatch > 1:
            def mb(carry, mbatch):
                g_acc, l_acc, a_acc = carry
                g, l, a = grads_of(params, mbatch)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l,
                        a_acc + a), None

            split = jax.tree.map(
                lambda x: x.reshape((microbatch, x.shape[0] // microbatch)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, aux), _ = jax.lax.scan(
                mb, (zeros, jnp.float32(0), jnp.float32(0)), split)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss, aux = loss / microbatch, aux / microbatch
        else:
            grads, loss, aux = grads_of(params, batch)
        params, opt_state = opt.adamw_update(adamw, params, grads, opt_state)
        metrics = {"loss": loss, "aux": aux,
                   "grad_norm": opt.global_norm(grads)}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, *, impl: str = "xla",
                      seq_mixer: str = "chunked"):
    """Prefill: forward pass returning last-position logits (no loss)."""

    def prefill_step(params, batch):
        logits, _ = T.forward(params, cfg, batch, impl=impl, remat=False,
                              seq_mixer=seq_mixer)
        return logits[:, -1:]

    return prefill_step


def make_decode_step(cfg: ArchConfig, *, impl: str = "xla",
                     kde_cfg: Optional[Dict] = None):
    """serve_step: one token in, one token out, cache updated in place."""

    def decode_step(params, cache, tokens, pos):
        logits, cache = T.decode_step(params, cfg, tokens, cache, pos,
                                      impl=impl, kde_cfg=kde_cfg)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), \
            logits, cache

    return decode_step
