"""AdamW with optional int8 gradient compression (hand-rolled; no optax).

Optimizer state is a pytree shaped like the params (m, v in float32), so it
inherits the params' FSDP sharding.  ``compress_grads``/``decompress`` give
int8 quantization with an error-feedback residual for the cross-pod
all-reduce (DESIGN.md §5); enabled per-config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, params, grads,
                 state: AdamWState) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)


# ------------------------------------------------------------------ int8
# gradient compression with error feedback (cross-pod all-reduce trick)

def compress(g: jnp.ndarray, residual: jnp.ndarray):
    """Per-tensor symmetric int8 quantization; returns (q, scale, new_resid)."""
    g32 = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g32 - deq


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, residuals, axis_name: str):
    """Quantize -> psum(int32) -> dequantize, carrying error feedback.

    Used inside shard_map for the cross-pod ('pod') gradient reduction:
    8x fewer DCN bytes; the residual re-injects quantization error next step.
    """
    def one(g, r):
        q, scale, new_r = compress(g, r)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_max = jax.lax.pmax(scale, axis_name)
        return decompress(total, scale_max), new_r

    out = jax.tree.map(one, grads, residuals)
    summed = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return summed, new_res
