"""Trace spans + metrics registry (DESIGN.md §15.2).

Host-side telemetry with a hard performance contract:

* **Disabled by default, near-zero overhead.**  One module-level flag
  guards every recording call; while disabled, ``counter_inc`` /
  ``gauge_set`` / ``histogram(...).record`` are a single branch and
  ``span`` returns a shared no-op context manager -- no dict churn, no
  allocation on the hot path.
* **Fenced timing.**  ``Timer`` is the one sanctioned way to time device
  work: it calls ``jax.block_until_ready`` on whatever the timed callable
  returns, so the recorded interval is realized device time, never an
  async-dispatch tail (the PR-9 bench_streaming fencing bug, made
  impossible by construction).  Both the dispatch (unfenced) and fenced
  wall times are kept so benchmarks can report async overlap.
* **Deterministic percentiles.**  Histograms use fixed log-spaced bucket
  edges; p50/p99 are cumulative-count lookups over those buckets, so two
  runs with identical samples report identical quantiles (no
  interpolation of float accumulation order).
* **xprof integration.**  When enabled, spans open a
  ``jax.profiler.TraceAnnotation`` so the same names show up on the
  device timeline under xprof / TensorBoard trace view.
"""
from __future__ import annotations

import bisect
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

_enabled = False
_lock = threading.Lock()

# One registry per process: {kind: {name: metric}}.  Flat dicts keyed by
# full metric name; labels are baked into the name by the caller
# (``serve.latency.t0.sample``) -- no per-call label-dict hashing.
_counters: Dict[str, int] = {}
_gauges: Dict[str, float] = {}
_histograms: Dict[str, "Histogram"] = {}
_events: List[Tuple[str, dict]] = []
_MAX_EVENTS = 4096


def enable() -> None:
    """Turn the registry on (module-level flag; thread-safe)."""
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


def reset() -> None:
    """Drop all recorded metrics and events (tests, run boundaries)."""
    with _lock:
        _counters.clear()
        _gauges.clear()
        _histograms.clear()
        _events.clear()


def counter_inc(name: str, value: int = 1) -> None:
    """Monotone counter; no-op while disabled."""
    if not _enabled:
        return
    with _lock:
        _counters[name] = _counters.get(name, 0) + int(value)


def gauge_set(name: str, value: float) -> None:
    """Last-write-wins gauge; no-op while disabled."""
    if not _enabled:
        return
    with _lock:
        _gauges[name] = float(value)


def event(name: str, **fields) -> None:
    """Append one structured event (watchdog decisions, chaos
    injections); bounded ring, no-op while disabled."""
    if not _enabled:
        return
    with _lock:
        _events.append((name, dict(fields)))
        if len(_events) > _MAX_EVENTS:
            del _events[: len(_events) - _MAX_EVENTS]


def events(prefix: str = "") -> List[Tuple[str, dict]]:
    """Snapshot of recorded events, optionally name-prefix filtered."""
    with _lock:
        return [e for e in _events if e[0].startswith(prefix)]


# Default edges: 1us .. ~100s, 4 buckets per decade (log-spaced).  Fixed
# edges => deterministic quantiles under identical sample streams.
_DEFAULT_EDGES = tuple(
    round(10.0 ** (e / 4.0), 6) for e in range(0, 4 * 8 + 1))


class Histogram:
    """Fixed-bucket histogram (values in microseconds by convention).

    ``record`` is an O(log buckets) bisect + int increment; quantiles are
    read as the upper edge of the first bucket whose cumulative count
    crosses ``q`` -- deterministic and merge-safe (counts add)."""

    __slots__ = ("edges", "counts", "total", "sum")

    def __init__(self, edges: Tuple[float, ...] = _DEFAULT_EDGES):
        self.edges = tuple(float(e) for e in edges)
        self.counts = [0] * (len(self.edges) + 1)
        self.total = 0
        self.sum = 0.0

    def record(self, value: float) -> None:
        self.counts[bisect.bisect_left(self.edges, float(value))] += 1
        self.total += 1
        self.sum += float(value)

    def quantile(self, q: float) -> float:
        """Upper bucket edge at cumulative fraction ``q`` (0 when
        empty); the last bucket reports its lower edge (unbounded)."""
        if self.total == 0:
            return 0.0
        need = q * self.total
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= need and c:
                return self.edges[min(i, len(self.edges) - 1)]
        return self.edges[-1]

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    def as_dict(self) -> dict:
        return dict(count=self.total, sum=self.sum, p50=self.p50,
                    p99=self.p99)


def histogram(name: str,
              edges: Tuple[float, ...] = _DEFAULT_EDGES) -> Histogram:
    """Get-or-create the named histogram.  Recording while disabled is
    the caller's single ``if obs.enabled()`` branch; this accessor always
    returns a live histogram so exporters can read it."""
    with _lock:
        h = _histograms.get(name)
        if h is None:
            h = _histograms[name] = Histogram(edges)
        return h


def observe(name: str, value: float,
            edges: Tuple[float, ...] = _DEFAULT_EDGES) -> None:
    """Record one histogram sample; no-op while disabled."""
    if not _enabled:
        return
    histogram(name, edges).record(value)


class _NullSpan:
    """Shared no-op context manager -- the disabled-mode ``span``."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Enabled-mode span: xprof TraceAnnotation + elapsed histogram."""

    __slots__ = ("name", "_t0", "_ann")

    def __init__(self, name: str):
        self.name = name
        self._ann = None
        self._t0 = 0.0

    def __enter__(self):
        import jax
        self._ann = jax.profiler.TraceAnnotation(self.name)
        self._ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        us = (time.perf_counter() - self._t0) * 1e6
        self._ann.__exit__(*exc)
        observe(f"span.{self.name}.us", us)
        return False


def span(name: str):
    """``with obs.span("serve.tick"): ...`` -- xprof-annotated timed
    region; the shared no-op singleton while disabled."""
    return _Span(name) if _enabled else _NULL_SPAN


class Timer:
    """The sanctioned benchmark/serving timer: fenced device timing.

    ``time(fn)`` calls ``fn``, records the unfenced (dispatch) wall time,
    then ``jax.block_until_ready`` on the return value and records the
    fenced wall time.  ``timeit(fn, repeats, warmup)`` is the
    benchmarks/common loop with the fence built in -- warmup runs are
    fenced too (compiles drained off-clock).

    Results land on the instance (``wall_us`` = fenced median,
    ``dispatch_us``) and -- when the registry is enabled -- in the
    ``timer.<name>.us`` histogram.
    """

    def __init__(self, name: str):
        self.name = name
        self.wall_us: float = 0.0
        self.dispatch_us: float = 0.0
        self.samples_us: List[float] = []

    def _fence(self, out):
        import jax
        try:
            jax.block_until_ready(out)
        except (TypeError, ValueError):
            pass        # non-pytree return (host object): nothing to fence
        return out

    def time(self, fn: Callable):
        """One fenced measurement; returns ``fn``'s result."""
        with span(self.name):
            t0 = time.perf_counter()
            out = fn()
            t_disp = time.perf_counter()
            self._fence(out)
            t1 = time.perf_counter()
        self.dispatch_us = (t_disp - t0) * 1e6
        us = (t1 - t0) * 1e6
        self.wall_us = us
        self.samples_us.append(us)
        observe(f"timer.{self.name}.us", us)
        return out

    def timeit(self, fn: Callable, repeats: int = 3, warmup: int = 1,
               reduce: str = "median") -> float:
        """Fenced replacement of ``benchmarks.common.timeit``: median (or
        ``min``/``mean``) fenced wall microseconds over ``repeats``."""
        for _ in range(warmup):
            self._fence(fn())
        t = []
        for _ in range(repeats):
            self.time(fn)
            t.append(self.wall_us)
        t.sort()
        if reduce == "min":
            self.wall_us = t[0]
        elif reduce == "mean":
            self.wall_us = sum(t) / len(t)
        else:
            self.wall_us = t[len(t) // 2]
        return self.wall_us


def get_registry() -> dict:
    """Snapshot of the whole registry (exporters, tests)."""
    with _lock:
        return dict(
            enabled=_enabled,
            counters=dict(_counters),
            gauges=dict(_gauges),
            histograms={k: h.as_dict() for k, h in _histograms.items()},
            events=list(_events),
        )


def histograms() -> Dict[str, Histogram]:
    """Live histogram objects (exporters need bucket internals)."""
    with _lock:
        return dict(_histograms)
