"""Unified observability layer (DESIGN.md §15).

Three sub-layers, one import surface:

* ``obs.counters`` -- the device counter word: every fused program in
  ``kernels/kde_sampler``, ``kernels/kde_hash`` and their sharded twins
  returns a fixed-width ``(WIDTH,)`` uint32 payload whose slot 0 is the
  PR-6 status bitmask and whose remaining slots count realized device
  work (kernel evals, level-1 reads, draws, rejection retries, FAR
  samples, overflow occupancy, psums).  Words fold through scan carries
  (or slot 0, add the rest) and add ZERO collectives -- the counters are
  trace-time constants or replicated post-psum values.
* ``obs.metrics`` -- host-side trace spans and a metrics registry:
  ``Timer``/``span`` with mandatory ``block_until_ready`` fencing and
  ``jax.profiler.TraceAnnotation`` integration, plus counters / gauges /
  fixed-bucket histograms (deterministic p50/p99).  Near-zero overhead
  while disabled (module flag, no per-call dict churn).
* ``obs.export`` -- versioned exporters: the JSON-lines metrics stream of
  ``launch/serve.py``, a Prometheus-text dump, and the shared telemetry
  schema block every ``BENCH_*.json`` artifact carries.
"""
from repro.obs import counters, export, metrics
from repro.obs.counters import (COUNTER_SLOTS, WIDTH, counter, fold,
                                status_of, totals, word)
from repro.obs.metrics import (Timer, counter_inc, disable, enable, enabled,
                               event, gauge_set, get_registry, histogram,
                               reset, span)

__all__ = [
    "counters", "metrics", "export",
    "WIDTH", "COUNTER_SLOTS", "word", "fold", "status_of", "counter",
    "totals",
    "Timer", "span", "enable", "disable", "enabled", "reset",
    "counter_inc", "gauge_set", "histogram", "event", "get_registry",
]
