"""Device counter words (DESIGN.md §15.1).

The PR-6 scalar uint32 status word generalized in place: every fused
device program returns a ``(WIDTH,)`` uint32 vector instead of a scalar.
Slot 0 carries the exact same status bitmask as before (``ft.guards``
bits); slots 1+ count realized device work.  The widening changes no
call-site unpack arity -- the word rides in the status position -- and
adds no collectives: every counter is either a trace-time constant
derived from static shapes or a replicated post-psum scalar.

Slot layout (all uint32, wrap at 2^32 -- ``EVALS`` wraps after ~4.3e9
kernel evaluations per word, so host accumulation must fold words
frequently, which every consumer already does per call):

===========  ====  =====================================================
slot name     idx  meaning
===========  ====  =====================================================
STATUS          0  ``ft.guards`` status bitmask (or-folded)
EVALS           1  realized kernel evaluations executed by the program
L1_READS        2  level-1 block-structure reads (rows read x 1)
DRAWS           3  categorical / Gumbel draws realized
RETRIES         4  rejection-sampling fallback rows (REJECT_EXHAUSTED)
FAR_SAMPLES     5  Hashing-Based-Estimator FAR samples drawn
OVERFLOW        6  hash overflow-region columns swept
PSUMS           7  collective psums executed by the program
===========  ====  =====================================================

Fold rule (scan carries, host accumulation): slot 0 ors, slots 1+ add.
"""
from __future__ import annotations

from typing import Dict, Iterable

import jax.numpy as jnp
import numpy as np

WIDTH = 8

STATUS = 0
EVALS = 1
L1_READS = 2
DRAWS = 3
RETRIES = 4
FAR_SAMPLES = 5
OVERFLOW = 6
PSUMS = 7

COUNTER_SLOTS: Dict[str, int] = {
    "status": STATUS, "evals": EVALS, "l1_reads": L1_READS, "draws": DRAWS,
    "retries": RETRIES, "far_samples": FAR_SAMPLES, "overflow": OVERFLOW,
    "psums": PSUMS,
}

_MOD = 1 << 32


def _u32(v):
    """Trace-safe uint32 coercion: python ints wrap mod 2^32 (static shape
    products can exceed the word width), traced scalars cast."""
    if isinstance(v, (int, np.integer)):
        return jnp.uint32(int(v) % _MOD)
    return jnp.asarray(v).astype(jnp.uint32)


def word(status=0, evals=0, l1_reads=0, draws=0, retries=0, far_samples=0,
         overflow=0, psums=0) -> jnp.ndarray:
    """Build one ``(WIDTH,)`` counter word.  Every argument is a python
    int (static shape product) or a traced scalar; the result is safe to
    return from inside jit / fold through scan carries."""
    return jnp.stack([
        _u32(status), _u32(evals), _u32(l1_reads), _u32(draws),
        _u32(retries), _u32(far_samples), _u32(overflow), _u32(psums)])


def fold(a, b) -> jnp.ndarray:
    """Fold two counter words: status bits or, counters add (the scan
    carry rule -- associative, commutative, identity ``word()``)."""
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    return jnp.concatenate([a[..., :1] | b[..., :1], a[..., 1:] + b[..., 1:]],
                           axis=-1)


def fold_status(w, status) -> jnp.ndarray:
    """Or extra status bits into a word's slot 0, counters untouched."""
    w = jnp.asarray(w, jnp.uint32)
    return w.at[..., STATUS].set(w[..., STATUS] | _u32(status))


def scale(w, k: int) -> jnp.ndarray:
    """``k`` repetitions of the same program: status unchanged, counters
    multiplied (e.g. a word built once for one scan step, realized
    ``k`` times)."""
    w = jnp.asarray(w, jnp.uint32)
    return jnp.concatenate(
        [w[..., :1], w[..., 1:] * jnp.uint32(int(k) % _MOD)], axis=-1)


def status_of(w):
    """The status bitmask of a scalar status OR a counter word: scalars
    pass through (legacy host ints), words read slot 0, batched ``(R,
    WIDTH)`` words read column 0."""
    if isinstance(w, (int, np.integer)):
        return w
    arr = jnp.asarray(w)
    if arr.ndim == 0:
        return arr
    return arr[..., STATUS]


def is_word(w) -> bool:
    """True when ``w`` is a counter word (trailing dim == WIDTH)."""
    if isinstance(w, (int, np.integer)):
        return False
    arr = np.asarray(jnp.shape(w))
    return arr.size > 0 and int(arr[-1]) == WIDTH


def counter(w, slot) -> int:
    """Host-side read of one counter slot (name or index) of a word --
    batched words sum over the batch axis."""
    idx = COUNTER_SLOTS[slot] if isinstance(slot, str) else int(slot)
    arr = np.asarray(w, np.uint64).reshape(-1, WIDTH)
    if idx == STATUS:
        return int(np.bitwise_or.reduce(arr[:, STATUS].astype(np.uint32)))
    return int(arr[:, idx].sum())


def totals(w) -> Dict[str, int]:
    """Host-side dict view of a word (or a batch of words, fold-reduced):
    ``{"status": ..., "evals": ..., ...}`` with python-int counters."""
    arr = np.asarray(w, np.uint64).reshape(-1, WIDTH)
    out = {"status": int(np.bitwise_or.reduce(
        arr[:, STATUS].astype(np.uint32)))}
    for name, idx in COUNTER_SLOTS.items():
        if idx != STATUS:
            out[name] = int(arr[:, idx].sum())
    return out


class HostTotals:
    """Host-side accumulator reconciling device words against the
    analytic ``.evals`` counters: python-int sums (no uint32 wrap across
    calls), one ``note(word)`` per program return."""

    def __init__(self):
        self.counts: Dict[str, int] = {
            k: 0 for k in COUNTER_SLOTS if k != "status"}
        self.status = 0
        self.words = 0

    def note(self, w) -> int:
        """Fold one device word (or batch of words) in; returns the
        or-folded status bits of the noted word."""
        t = totals(w)
        st = t.pop("status")
        self.status |= st
        self.words += 1
        for k, v in t.items():
            self.counts[k] += v
        return st

    def as_dict(self) -> Dict[str, int]:
        return dict(status=self.status, words=self.words, **self.counts)

    def __getitem__(self, k: str) -> int:
        return self.counts[k]
