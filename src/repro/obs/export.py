"""Versioned metric exporters (DESIGN.md §15.3).

Three consumers, one schema version:

* ``emit_jsonl`` -- the ``launch/serve.py`` metrics stream: one JSON
  object per line behind the stable ``[serve] metrics `` grep prefix,
  stamped with ``schema_version`` (tests and ``tools/
  check_metrics_schema.py`` validate against ``METRICS_REQUIRED``).
* ``prometheus_text`` -- a Prometheus text-format dump of the live
  registry (``--metrics-format prometheus``).
* ``telemetry_block`` -- the shared schema block every ``BENCH_*.json``
  artifact embeds under ``"telemetry"``: fenced wall time, realized
  device evals (from counter words), roofline fraction, backend.

Bump ``SCHEMA_VERSION`` whenever a required key changes meaning; the
validator pins the current version exactly.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, Optional

from repro.obs import metrics as _m

SCHEMA_VERSION = 1
METRICS_PREFIX = "[serve] metrics "

# Every serve.py JSON-lines payload must carry these keys.
METRICS_REQUIRED = ("schema_version", "mode")
# Per-mode required keys (subset check; payloads may carry more).
METRICS_MODE_REQUIRED = {
    "multi-tenant": ("tenants", "ticks", "served", "failed", "p50_ms",
                     "p99_ms", "throughput_rps", "evictions", "stale",
                     "realized_evals", "per_tenant"),
    "graph-stream": ("n", "ticks", "epoch", "live", "flags"),
}
# Every BENCH_*.json telemetry block must carry these keys.
TELEMETRY_REQUIRED = ("schema_version", "backend", "fenced", "wall_us")


def emit_jsonl(payload: dict, stream=None, prefix: str = METRICS_PREFIX
               ) -> str:
    """Print one schema-stamped JSON-lines metrics record; returns the
    emitted line (minus prefix) for tests."""
    rec = dict(payload)
    rec.setdefault("schema_version", SCHEMA_VERSION)
    line = json.dumps(rec, sort_keys=True, default=float)
    print(prefix + line, file=stream or sys.stdout, flush=True)
    return line


def telemetry_block(wall_us: Optional[float] = None,
                    dispatch_us: Optional[float] = None,
                    realized_evals: Optional[int] = None,
                    roofline_fraction: Optional[float] = None,
                    **extra) -> dict:
    """The shared BENCH_*.json schema block (``"telemetry"`` key):
    timing is declared fenced because ``obs.Timer`` fences by
    construction -- hand-rolled timers must not use this constructor."""
    import jax
    blk = dict(schema_version=SCHEMA_VERSION,
               backend=jax.default_backend(), fenced=True)
    if wall_us is not None:
        blk["wall_us"] = float(wall_us)
    else:
        blk["wall_us"] = None
    if dispatch_us is not None:
        blk["dispatch_us"] = float(dispatch_us)
    if realized_evals is not None:
        blk["realized_evals"] = int(realized_evals)
    if roofline_fraction is not None:
        blk["roofline_fraction"] = float(roofline_fraction)
    blk.update(extra)
    return blk


def validate_metrics_line(obj: dict) -> None:
    """Raise ``ValueError`` when a serve.py JSON-lines record does not
    match the pinned schema version / required keys."""
    for k in METRICS_REQUIRED:
        if k not in obj:
            raise ValueError(f"metrics line missing required key {k!r}")
    if obj["schema_version"] != SCHEMA_VERSION:
        raise ValueError(
            f"metrics schema_version {obj['schema_version']!r} != pinned "
            f"{SCHEMA_VERSION}")
    need = METRICS_MODE_REQUIRED.get(obj["mode"], ())
    missing = [k for k in need if k not in obj]
    if missing:
        raise ValueError(
            f"metrics line (mode={obj['mode']!r}) missing keys {missing}")


def validate_telemetry_block(blk: dict, path: str = "?") -> None:
    """Raise ``ValueError`` when a BENCH artifact's telemetry block is
    malformed."""
    missing = [k for k in TELEMETRY_REQUIRED if k not in blk]
    if missing:
        raise ValueError(f"{path}: telemetry block missing keys {missing}")
    if blk["schema_version"] != SCHEMA_VERSION:
        raise ValueError(
            f"{path}: telemetry schema_version {blk['schema_version']!r} "
            f"!= pinned {SCHEMA_VERSION}")
    if blk["fenced"] is not True:
        raise ValueError(f"{path}: telemetry block not fenced")


def prometheus_text(registry: Optional[dict] = None) -> str:
    """Prometheus text-format dump of the live registry: counters as
    ``counter``, gauges as ``gauge``, histograms as ``summary``
    (count / sum / p50 / p99 quantiles)."""
    reg = registry if registry is not None else _m.get_registry()
    out = []

    def _name(n: str) -> str:
        return "repro_" + "".join(
            c if c.isalnum() or c == "_" else "_" for c in n)

    for k in sorted(reg["counters"]):
        nm = _name(k)
        out.append(f"# TYPE {nm} counter")
        out.append(f"{nm} {reg['counters'][k]}")
    for k in sorted(reg["gauges"]):
        nm = _name(k)
        out.append(f"# TYPE {nm} gauge")
        out.append(f"{nm} {reg['gauges'][k]:.6g}")
    for k in sorted(reg["histograms"]):
        h = reg["histograms"][k]
        nm = _name(k)
        out.append(f"# TYPE {nm} summary")
        out.append(f'{nm}{{quantile="0.5"}} {h["p50"]:.6g}')
        out.append(f'{nm}{{quantile="0.99"}} {h["p99"]:.6g}')
        out.append(f"{nm}_sum {h['sum']:.6g}")
        out.append(f"{nm}_count {h['count']}")
    return "\n".join(out) + "\n"
