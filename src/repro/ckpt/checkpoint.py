"""Sharded checkpointing with atomic commit + elastic restore.

Layout:
  <dir>/step_000123.tmp/   -> written, fsync'd, then renamed to
  <dir>/step_000123/       (rename is the atomic commit point)
      meta.json            (step, config hash, tree structure)
      arrays.npz           (flat param/opt leaves, host-gathered)
  <dir>/LATEST             (text file with the last committed step)

Host-gathered npz keeps the format trivially portable across mesh sizes --
restore re-shards onto whatever mesh the restart came up with (elastic
resize of the 'data' axis is exercised in tests/test_ft.py).  On a real
multi-host cluster the same layout is written per-process with
process-sliced keys; single-controller here.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        leaves.append(arr.astype(leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(paths[1], leaves)


def save(directory: str, step: int, state: Any,
         extra_meta: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    meta = {"step": step, "keys": sorted(flat.keys())}
    meta.update(extra_meta or {})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic commit
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    # prune older checkpoints (keep last 3)
    kept = sorted(d for d in os.listdir(directory) if d.startswith("step_")
                  and not d.endswith(".tmp"))
    for old in kept[:-3]:
        shutil.rmtree(os.path.join(directory, old), ignore_errors=True)
    return final


def latest_step(directory: str) -> Optional[int]:
    path = os.path.join(directory, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        name = f.read().strip()
    full = os.path.join(directory, name)
    if not os.path.isdir(full):
        return None
    return int(name.split("_")[1])


def restore(directory: str, template: Any, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int]:
    """Load the latest (or given) step and re-shard onto ``shardings``
    (any mesh size -- elastic restore)."""
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoint in {directory}"
    name = f"step_{step:08d}"
    with np.load(os.path.join(directory, name, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    state = _unflatten_like(template, flat)
    if shardings is not None:
        state = jax.tree.map(
            lambda a, s: jax.device_put(a, s), state, shardings)
    return state, step
