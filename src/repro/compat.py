"""Small cross-version JAX shims.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, and its replication-check flag was renamed
``check_rep`` -> ``check_vma`` along the way.  Callers in this repo use the
new-style spelling (``jax.shard_map`` semantics, ``check_vma=``); this
module maps it onto whichever API the installed jax provides.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6: top-level export, check_vma flag
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - exercised only on older jax
    import inspect

    from jax.experimental.shard_map import shard_map as _shard_map

    _PARAMS = frozenset(inspect.signature(_shard_map).parameters)

    def shard_map(f, **kw):
        if "check_vma" in kw and "check_vma" not in _PARAMS:
            kw["check_rep"] = kw.pop("check_vma")
        return _shard_map(f, **kw)
