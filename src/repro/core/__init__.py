"""Public API: the paper's algorithms (Sections 4-6), composable over
black-box KDE queries (Definition 1.1).  See README.md for the full
paper -> module map.

    from repro.core import (gaussian, spectral_sparsify, fkv_lowrank,
                            top_eigenvalue, approximate_spectrum, ...)
"""
from repro.core.kernels_fn import (Kernel, exponential, gaussian, laplacian,
                                   make_kernel, median_bandwidth,
                                   rational_quadratic)
from repro.core.kde.base import (ExactBlockKDE, ExactKDE, RSKDE,
                                 StratifiedKDE, make_estimator)
from repro.core.kde.multilevel import MultiLevelKDE
from repro.core.sampling.vertex import (DegreeSampler, PrefixCDF,
                                        approximate_degrees)
from repro.core.sampling.edge import EdgeSampler, NeighborSampler
from repro.core.sampling.walks import random_walks
from repro.core.sampling.rownorm import RowNormSampler
from repro.core.sparsify import SparseGraph, resparsify, spectral_sparsify
from repro.core.laplacian import cg_laplacian, solve_kernel_laplacian
from repro.core.lowrank import (countsketch_lowrank, fkv_lowrank,
                                subspace_iteration)
from repro.core.spectrum import approximate_spectrum, emd_1d, exact_spectrum
from repro.core.eigen import top_eigenvalue, top_eigenvalue_exact
from repro.core.cluster.local import same_cluster_test
from repro.core.cluster.spectral import (cluster_accuracy,
                                         laplacian_eigenvectors, kmeans,
                                         spectral_cluster)
from repro.core.graph.arboricity import estimate_arboricity, exact_arboricity
from repro.core.graph.triangles import (estimate_triangle_weight,
                                        exact_triangle_weight)
