"""Top eigenvalue/eigenvector approximation -- Algorithm 5.18 / Theorem 5.22.

Step 1 (BMR21, Lemma 5.21): a random t x t principal submatrix K_S, scaled by
n/t, preserves eigenvalues to +- n/sqrt(t); with lambda_1 >= n tau
(Lemma 5.19) choosing t = O(1/(eps^2 tau^2)) keeps a (1 - eps) factor.

Step 2: top eigenvalue of K_S via either the standard gap-independent power
method (MM15) or the BIMW21 kernel *noisy* power method, whose matvec is
estimated with sampled kernel evaluations only (our TPU-adapted stand-in for
their KDE-query matvec: importance-sample indices j ~ |v_j|, evaluate
k(x_i, x_j) on the sample -- an unbiased estimate of (K v)_i).

The returned eigenvector is sparse: supported only on S (Remark after
Alg 5.18).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import Kernel


@dataclasses.dataclass
class EigenResult:
    eigenvalue: float
    eigenvector: np.ndarray      # (n,) sparse: nonzero only on sampled set
    support: np.ndarray
    kernel_evals: int


def _noisy_matvec(ksub: np.ndarray, v: np.ndarray, num_samples: int,
                  rng) -> Tuple[np.ndarray, int]:
    """Unbiased (K v)_i estimate via importance sampling j ~ |v_j|."""
    t = len(v)
    absv = np.abs(v)
    z = absv.sum()
    if z <= 0:
        return np.zeros_like(v), 0
    p = absv / z
    idx = rng.choice(t, size=min(num_samples, 4 * t), p=p)
    contrib = np.sign(v[idx]) * z / len(idx)
    # In the KDE setting each (i, j) pair is one kernel evaluation; here the
    # submatrix is materialized, so we count t * |idx| evals-equivalent.
    out = ksub[:, idx] @ contrib
    return out, t * len(idx)


def power_method(ksub: np.ndarray, iters: int, rng) -> Tuple[float, np.ndarray]:
    v = rng.standard_normal(ksub.shape[0])
    v /= np.linalg.norm(v)
    for _ in range(iters):
        w = ksub @ v
        nw = np.linalg.norm(w)
        if nw <= 0:
            break
        v = w / nw
    lam = float(v @ (ksub @ v))
    return lam, v


def noisy_power_method(ksub: np.ndarray, iters: int, num_samples: int,
                       rng) -> Tuple[float, np.ndarray, int]:
    """BIMW21 Algorithm 1 (noisy power method) on the submatrix."""
    t = ksub.shape[0]
    v = rng.standard_normal(t)
    v /= np.linalg.norm(v)
    evals = 0
    for _ in range(iters):
        w, e = _noisy_matvec(ksub, v, num_samples, rng)
        evals += e
        nw = np.linalg.norm(w)
        if nw <= 0:
            break
        v = w / nw
    # Rayleigh quotient with an exact final matvec (t^2 evals).
    lam = float(v @ (ksub @ v))
    evals += t * t
    return lam, v, evals


def top_eigenvalue(x, kernel: Kernel, eps: float = 0.25, tau: float = 0.1,
                   t: Optional[int] = None, method: str = "power",
                   seed: int = 0) -> EigenResult:
    """Algorithm 5.18."""
    n = int(x.shape[0])
    rng = np.random.default_rng(seed)
    t = int(t if t is not None else min(n, int(np.ceil(1.0 / (eps * eps * tau * tau)))))
    support = rng.choice(n, size=t, replace=False)
    xj = jnp.asarray(x)
    ksub = np.asarray(kernel.pairwise(xj[jnp.asarray(support)],
                                      xj[jnp.asarray(support)]), np.float64)
    evals = t * t
    iters = max(int(np.ceil(np.log(max(t, 2) / eps) / np.sqrt(eps))), 8)
    if method == "noisy_power":
        lam, v, extra = noisy_power_method(ksub, iters,
                                           num_samples=max(t // 2, 8), rng=rng)
        evals += extra
    else:
        lam, v = power_method(ksub, iters, rng)
    vec = np.zeros(n)
    vec[support] = v
    return EigenResult(eigenvalue=float(lam * n / t), eigenvector=vec,
                       support=support, kernel_evals=evals)


def top_eigenvalue_exact(kernel: Kernel, x) -> float:
    """Oracle: lambda_1(K) by dense eigendecomposition."""
    k = np.asarray(kernel.matrix(jnp.asarray(x)), np.float64)
    return float(np.linalg.eigvalsh(k)[-1])
