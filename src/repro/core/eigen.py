"""Top eigenvalue/eigenvector approximation -- Algorithm 5.18 / Theorem 5.22.

Step 1 (BMR21, Lemma 5.21): a random t x t principal submatrix K_S, scaled by
n/t, preserves eigenvalues to +- n/sqrt(t); with lambda_1 >= n tau
(Lemma 5.19) choosing t = O(1/(eps^2 tau^2)) keeps a (1 - eps) factor.

Step 2: top eigenvalue of K_S via either the standard gap-independent power
method (MM15) or the BIMW21 kernel *noisy* power method, whose matvec is
estimated with sampled kernel evaluations only (our TPU-adapted stand-in for
their KDE-query matvec: importance-sample indices j ~ |v_j|, evaluate
k(x_i, x_j) on the sample -- an unbiased estimate of (K v)_i).  The noisy
iteration runs entirely on device as ONE ``lax.scan`` program
(``kde_sampler.ops.noisy_power_scan``, DESIGN.md §7): the inverse-CDF
importance draw, the sampled-column matvec, and the renormalization never
round-trip to the host.

The returned eigenvector is sparse: supported only on S (Remark after
Alg 5.18).

Cost accounting (the PR-3 fix): the t x t submatrix is materialized ONCE, so
``kernel_evals = t^2`` regardless of iteration count; the per-iteration
sampled matvec touches only already-materialized entries and is reported
separately as ``matvec_sampled_evals`` -- the cost the BIMW21 KDE-query
matvec *would* pay (iters * t * num_samples pair lookups).  The seed
conflated the two, inflating every "evals vs dense" comparison.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import Kernel


@dataclasses.dataclass
class EigenResult:
    """Algorithm 5.18 output.

    ``kernel_evals`` counts actual kernel evaluations (the one-time t x t
    submatrix materialization); ``matvec_sampled_evals`` counts the
    (i, j) pair lookups of the sampled noisy matvecs, reported separately
    so eval comparisons against dense baselines are not inflated."""

    eigenvalue: float
    eigenvector: np.ndarray      # (n,) sparse: nonzero only on sampled set
    support: np.ndarray
    kernel_evals: int
    matvec_sampled_evals: int = 0


def power_method(ksub: np.ndarray, iters: int, rng) -> Tuple[float, np.ndarray]:
    """Gap-independent power method (MM15) on the materialized submatrix;
    returns (Rayleigh quotient, unit vector).  Costs no kernel evals
    beyond the submatrix the caller already materialized."""
    v = rng.standard_normal(ksub.shape[0])
    v /= np.linalg.norm(v)
    for _ in range(iters):
        w = ksub @ v
        nw = np.linalg.norm(w)
        if nw <= 0:
            break
        v = w / nw
    lam = float(v @ (ksub @ v))
    return lam, v


def noisy_power_method(ksub: jnp.ndarray, iters: int, num_samples: int,
                       key, mesh=None) -> Tuple[float, np.ndarray, int]:
    """BIMW21 Algorithm 1 (noisy power method) on the submatrix, fused:
    all ``iters`` iterations run as one jitted ``lax.scan`` program
    (DESIGN.md §7).  With ``mesh=`` the submatrix is sharded over columns
    and each iteration's sampled matvec is a local masked gather + one
    psum (DESIGN.md §9); the key stream and math are identical.  Returns
    (eigenvalue, vector, matvec_sampled_evals) where the last is the
    per-iteration sampled-pair lookup count ``iters * t * num_samples``
    (not fresh kernel evaluations -- the submatrix is already
    materialized).

    >>> lam, v, _ = noisy_power_method(ksub, 12, 32, jax.random.PRNGKey(0))
    """
    from repro.ft import guards as _g
    from repro.kernels.kde_sampler import ops as _ops
    from repro.kernels.kde_sampler.sharded import sharded_noisy_power

    t = int(ksub.shape[0])
    k_init, k_iter = jax.random.split(key)
    v0 = jax.random.normal(k_init, (t,), ksub.dtype)
    v0 = v0 / jnp.linalg.norm(v0)
    keys = jax.random.split(k_iter, iters)
    if mesh is not None:
        lam, v, st = sharded_noisy_power(mesh, ksub, v0, keys,
                                         num_samples=num_samples)
    else:
        lam, v, st = _ops.noisy_power_scan(ksub, v0, keys,
                                           num_samples=num_samples)
    # stalled iterations (ZERO_MASS) keep the previous iterate -- benign;
    # NaN/Inf anywhere in the scan is fatal under REPRO_CHECKS=1
    _g.raise_on_status(st, context="noisy_power_method",
                       allow=_g.ZERO_MASS)
    return float(lam), np.asarray(v, np.float64), iters * t * num_samples


def top_eigenvalue(x, kernel: Kernel, eps: float = 0.25, tau: float = 0.1,
                   t: Optional[int] = None, method: str = "power",
                   seed: int = 0, mesh=None) -> EigenResult:
    """Algorithm 5.18 / Theorem 5.22: (1 - eps)-approximate top eigenvalue
    of the n x n kernel matrix from a t x t principal submatrix,
    t = O(1/(eps^2 tau^2)) -- cost independent of n.

    Cost: ``t^2`` kernel evals (submatrix materialization); with
    ``method="noisy_power"`` additionally ``iters * t * num_samples``
    sampled pair lookups, reported in ``matvec_sampled_evals``.

    >>> res = top_eigenvalue(x, gaussian(1.0), t=180, method="noisy_power")
    """
    n = int(x.shape[0])
    if mesh is not None and method != "noisy_power":
        raise ValueError("mesh= shards the noisy power iteration; use "
                         "method='noisy_power' (the plain power method is "
                         "a host post-processing step)")
    rng = np.random.default_rng(seed)
    t = int(t if t is not None else min(n, int(np.ceil(1.0 / (eps * eps * tau * tau)))))
    support = rng.choice(n, size=t, replace=False)
    xj = jnp.asarray(x)
    ksub_dev = kernel.pairwise(xj[jnp.asarray(support)],
                               xj[jnp.asarray(support)])
    evals = t * t
    iters = max(int(np.ceil(np.log(max(t, 2) / eps) / np.sqrt(eps))), 8)
    sampled = 0
    if method == "noisy_power":
        lam, v, sampled = noisy_power_method(
            ksub_dev, iters, num_samples=max(t // 2, 8),
            key=jax.random.PRNGKey(seed + 1), mesh=mesh)
    else:
        ksub = np.asarray(ksub_dev, np.float64)
        lam, v = power_method(ksub, iters, rng)
    vec = np.zeros(n)
    vec[support] = v
    return EigenResult(eigenvalue=float(lam * n / t), eigenvector=vec,
                       support=support, kernel_evals=evals,
                       matvec_sampled_evals=sampled)


def top_eigenvalue_exact(kernel: Kernel, x) -> float:
    """Oracle: lambda_1(K) by dense eigendecomposition (n^2 evals)."""
    k = np.asarray(kernel.matrix(jnp.asarray(x)), np.float64)
    return float(np.linalg.eigvalsh(k)[-1])
