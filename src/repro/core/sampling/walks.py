"""Random walks on the kernel graph -- Algorithm 4.16 / Theorem 4.15.

T steps = T neighbor-sampling calls; total variation error O(T * eps), or the
true walk distribution with the rejection-sampling exactness step.  Walks are
vectorized over the frontier; in blocked mode the whole T-step walk is one
compiled ``lax.scan`` program -- the frontier stays on device between steps
(DESIGN.md §3), with one transfer in (starts) and one out (endpoints/path).
Tree mode falls back to the host step loop.

Streaming-safe by construction (DESIGN.md §12): every step goes through
the ``NeighborSampler``, which epoch-checks its attached ``DynamicDataset``
and patches its level-1 state before the first draw -- walks launched
after a mutation batch run entirely at the new epoch, and a stale
``starts`` frontier raises ``EPOCH_STALE`` under ``REPRO_CHECKS=1``.
"""
from __future__ import annotations

import numpy as np

from repro.core.sampling.edge import NeighborSampler


def random_walks(sampler: NeighborSampler, starts: np.ndarray, length: int,
                 exact: bool = False, record_path: bool = False):
    """Algorithm 4.16: run |starts| = w walks of ``length`` steps.  Returns
    endpoints (and the full (length+1, w) path if requested).

    Cost: ``length`` fused steps, each one level-1 read (w*B*s stratified /
    w*n exact kernel evals) plus w exact level-2 rows; ``exact=True`` adds
    the Theorem 4.12 rejection rounds per step.

    >>> ends = random_walks(nbr, np.zeros(64, np.int64), length=8)
    """
    starts = np.asarray(starts)
    if length <= 0:
        cur = starts.copy()
        return (cur, starts[None].copy()) if record_path else cur
    if getattr(sampler, "mode", None) == "blocked":
        end, path = sampler.walk(starts, length, exact=exact,
                                 record_path=record_path)
        if record_path:
            return end, np.concatenate([starts[None], np.asarray(path)])
        return end
    cur = starts.copy()
    path = [cur.copy()] if record_path else None
    for _ in range(length):
        if exact:
            cur = sampler.sample_exact(cur)
        else:
            cur, _ = sampler.sample(cur)
        if record_path:
            path.append(cur.copy())
    if record_path:
        return cur, np.stack(path)
    return cur


def endpoint_counts(sampler: NeighborSampler, start: int, length: int,
                    num_walks: int, n: int, exact: bool = False) -> np.ndarray:
    """Empirical endpoint distribution p_u^t from ``num_walks`` walks
    (the Theorem 6.9 ingredient; cost = one ``random_walks`` call)."""
    ends = random_walks(sampler, np.full(num_walks, start, np.int64), length,
                        exact=exact)
    return np.bincount(ends, minlength=n).astype(np.float64)
