"""Weighted vertex (degree) sampling -- Algorithms 4.3, 4.5, 4.6.

Preprocessing: n KDE queries give (1 +- eps) weighted degrees p_i
(Theorem 4.7).  Sampling from the array {p_i} is then exact (Lemma 4.8): the
paper's binary-tree descent over partial sums is mathematically identical to
inverse-CDF sampling over the prefix-sum array, which is the dense form we
use (one cumsum + searchsorted; O(log n) per sample, vectorized).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.kde.base import KDEBase


def approximate_degrees(estimator: KDEBase, batch: int = 1024) -> np.ndarray:
    """Algorithm 4.3: p_i = KDE_X(x_i) - k(x_i, x_i)  (self kernel = 1)."""
    n = estimator.n
    out = np.zeros(n, np.float32)
    for lo in range(0, n, batch):
        hi = min(lo + batch, n)
        out[lo:hi] = np.asarray(estimator.query(estimator.x[lo:hi]))
    out = out - 1.0  # k(x, x) = 1 for all our kernels
    return np.maximum(out, 1e-12)


class DegreeSampler:
    """Algorithm 4.6: sample vertices proportional to (approximate) degree."""

    def __init__(self, estimator: KDEBase, seed: int = 0):
        self.degrees = approximate_degrees(estimator)
        self._prefix = np.cumsum(self.degrees)
        self.total = float(self._prefix[-1])
        self._rng = np.random.default_rng(seed)

    def sample(self, size: int) -> np.ndarray:
        u = self._rng.uniform(0.0, self.total, size=size)
        return np.searchsorted(self._prefix, u, side="right").clip(0, len(self.degrees) - 1)

    def prob(self, idx) -> np.ndarray:
        """Probability this sampler assigns to vertex idx (p_i / sum p_j)."""
        return self.degrees[idx] / self.total


def sample_from_positive_array(a: np.ndarray, size: int, rng) -> np.ndarray:
    """Algorithm 4.5 in its dense form (used directly in tests against the
    explicit tree-descent reference)."""
    prefix = np.cumsum(a)
    u = rng.uniform(0.0, prefix[-1], size=size)
    return np.searchsorted(prefix, u, side="right").clip(0, len(a) - 1)


def tree_descent_sample(a: np.ndarray, rng) -> int:
    """Literal Algorithm 4.5 (binary descent on segment sums) -- reference
    implementation used by property tests to certify the dense form."""
    lo, hi = 0, len(a)
    prefix = np.concatenate([[0.0], np.cumsum(a)])

    def seg(l, h):  # A_{l,h} query via prefix sums (O(1), as Thm 4.9 notes)
        return prefix[h] - prefix[l]

    while hi - lo > 1:
        mid = lo + (hi - lo) // 2
        wl, wr = seg(lo, mid), seg(mid, hi)
        if rng.uniform() <= wl / max(wl + wr, 1e-30):
            hi = mid
        else:
            lo = mid
    return lo
