"""Weighted vertex (degree) sampling -- Algorithms 4.3, 4.5, 4.6.

Preprocessing: n KDE queries give (1 +- eps) weighted degrees p_i
(Theorem 4.7).  Sampling from the array {p_i} is then exact (Lemma 4.8): the
paper's binary-tree descent over partial sums is mathematically identical to
inverse-CDF sampling over the prefix-sum array, which is the dense form we
use (one cumsum + searchsorted; O(log n) per sample, vectorized).

``PrefixCDF`` is the shared preprocessing path behind ``DegreeSampler`` and
``RowNormSampler``: prefix sums are accumulated in float64 (a float32 cumsum
drifts from the target distribution as n grows -- the accumulated rounding
error is O(n) ulps, which at production scales visibly biases the inverse
CDF; see tests/test_sampling.py::test_prefix_cdf_float32_bias_regression),
and the normalized CDF is exported once as a float32 device array for the
fused edge-batch op (per-entry rounding of an exactly-accumulated CDF is
O(eps) and unbiased).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.kde.base import KDEBase


class PrefixCDF:
    """Inverse-CDF sampler over a positive weight array.

    Host path: float64 prefix sums + ``np.searchsorted``.  Device path:
    ``cdf_device`` / ``probs_device`` are lazily-exported float32 arrays for
    jitted consumers (``kde_sampler.ops.fused_edge_batch``); both are
    rounded from the float64 accumulation, never re-accumulated in float32.
    """

    def __init__(self, weights: np.ndarray, seed: int = 0):
        w = np.asarray(weights, np.float64)
        self.weights = w
        self._prefix = np.cumsum(w)           # float64 accumulation
        self.total = float(self._prefix[-1])
        self._rng = np.random.default_rng(seed)
        self._cdf_dev: Optional[jnp.ndarray] = None
        self._probs_dev: Optional[jnp.ndarray] = None
        self._weights_dev: Optional[jnp.ndarray] = None

    def __len__(self) -> int:
        return len(self.weights)

    def sample(self, size: int) -> np.ndarray:
        """Draw ``size`` iid indices i ~ w_i / sum w (Lemma 4.8: the dense
        inverse-CDF form of the Algorithm 4.5 tree descent)."""
        u = self._rng.uniform(0.0, self.total, size=size)
        return np.searchsorted(self._prefix, u, side="right").clip(
            0, len(self.weights) - 1)

    def prob(self, idx) -> np.ndarray:
        """Probability this sampler assigns to index idx (w_i / sum w_j)."""
        return self.weights[np.asarray(idx)] / self.total

    @property
    def cdf_device(self) -> jnp.ndarray:
        """Normalized float32 prefix array for jitted inverse-CDF draws."""
        if self._cdf_dev is None:
            self._cdf_dev = jnp.asarray(
                (self._prefix / self.total).astype(np.float32))
        return self._cdf_dev

    @property
    def probs_device(self) -> jnp.ndarray:
        """Float32 probability array w_i / sum w for jitted consumers."""
        if self._probs_dev is None:
            self._probs_dev = jnp.asarray(
                (self.weights / self.total).astype(np.float32))
        return self._probs_dev

    @property
    def weights_device(self) -> jnp.ndarray:
        """Raw float32 weight array for jitted consumers."""
        if self._weights_dev is None:
            self._weights_dev = jnp.asarray(self.weights.astype(np.float32))
        return self._weights_dev


def host_degree_loop(estimator: KDEBase, batch: int = 1024) -> np.ndarray:
    """Algorithm 4.3 as batched estimator queries of the dataset against
    itself, minus the kernel's *actual* per-point diagonal
    (``Kernel.pairs(x, x)``, a constant 1.0 only for the Table-1 kinds).
    The ONE host fallback shared by ``approximate_degrees`` and the
    estimator adapters that expose a ``degrees()`` method."""
    from repro.kernels.kde_sampler.ref import BUILTIN_KINDS
    n = estimator.n
    out = np.zeros(n, np.float64)
    for lo in range(0, n, batch):
        hi = min(lo + batch, n)
        out[lo:hi] = np.asarray(estimator.query(estimator.x[lo:hi]))
    if estimator.kernel.name in BUILTIN_KINDS:
        return out - 1.0         # k(x, x) = 1 exactly for Table-1 kernels
    return out - np.asarray(
        estimator.kernel.pairs(estimator.x, estimator.x), np.float64)


def streaming_degrees(estimator: KDEBase, dataset,
                      batch: int = 1024) -> np.ndarray:
    """Algorithm 4.3 over a mutable padded dataset (DESIGN.md §12): only
    LIVE rows are queried (a sentinel query against a sentinel data row
    evaluates ``inf - inf``), dead slots get weight exactly 0 -- the
    inverse CDF then never draws them -- and the 1e-12 positivity clamp of
    ``approximate_degrees`` applies to live entries only.  Estimators
    attached to the same dataset answer through their own streaming-aware
    ``degrees()``."""
    from repro.kernels.kde_sampler.ref import BUILTIN_KINDS
    if getattr(estimator, "_dataset", None) is dataset \
            and hasattr(estimator, "degrees"):
        out = np.asarray(estimator.degrees(), np.float64)
    else:
        sync = getattr(estimator, "_sync", None)
        if sync is not None:
            sync()
        ls = np.asarray(dataset.live_slots())
        out = np.zeros(estimator.n, np.float64)
        x = estimator.x
        for lo in range(0, len(ls), batch):
            sel = jnp.asarray(ls[lo:lo + batch])
            out[ls[lo:lo + batch]] = np.asarray(estimator.query(x[sel]))
        if estimator.kernel.name in BUILTIN_KINDS:
            out[ls] -= 1.0
        else:
            lv = jnp.asarray(ls)
            out[ls] -= np.asarray(estimator.kernel.pairs(x[lv], x[lv]),
                                  np.float64)
    live = np.zeros(len(out), bool)
    live[np.asarray(dataset.live_slots())] = True
    return np.where(live, np.maximum(out, 1e-12), 0.0)


def approximate_degrees(estimator: KDEBase, batch: int = 1024) -> np.ndarray:
    """Algorithm 4.3: p_i = KDE_X(x_i) - k(x_i, x_i).

    The self kernel is the estimator kernel's *actual* per-point diagonal
    (``Kernel.pairs(x, x)``), not a hardcoded 1.0 -- custom kernels with
    k(u, u) != 1 previously got biased degrees.  Estimators exposing a
    ``degrees()`` method (mesh-resident ``ShardedKDE``, the hashed
    ``HashedKDE``) are dispatched to it instead of the host batch loop."""
    if hasattr(estimator, "degrees"):
        return np.maximum(np.asarray(estimator.degrees(), np.float64),
                          1e-12)
    return np.maximum(host_degree_loop(estimator, batch), 1e-12)


class DegreeSampler:
    """Algorithm 4.6: sample vertices proportional to (approximate) degree.

    With ``mesh=`` the estimator must be mesh-resident (a ``ShardedKDE``)
    and the Algorithm 4.3 preprocessing runs as ONE collective device
    program (the ring for exact reads, one batched query for stratified)
    instead of a host batch loop; the prefix CDF then accumulates in
    float64 on the host exactly as on the single-device path."""

    def __init__(self, estimator: KDEBase, seed: int = 0, mesh=None,
                 dataset=None):
        if mesh is not None and not hasattr(estimator, "degrees"):
            raise ValueError("DegreeSampler(mesh=...) needs a mesh-resident"
                             " estimator (core.kde.distributed.ShardedKDE)")
        self._estimator = estimator
        self._seed = seed
        self._dataset = dataset
        self._ds_epoch = int(dataset.epoch) if dataset is not None else 0
        self.rebuilds = 0
        if dataset is not None:
            self.degrees = streaming_degrees(estimator, dataset)
        else:
            self.degrees = approximate_degrees(estimator)
        self._cdf = PrefixCDF(self.degrees, seed=seed)
        self.total = self._cdf.total

    # ------------------------------------------------------------------ #
    # streaming contract (DESIGN.md §12)
    def _rebuild_estimator(self) -> None:
        """Journal-gap path: estimators attached to the same dataset
        rebuild themselves; plain dense estimators are reconstructed over
        the dataset's current padded array (same class, same layout
        knobs).  Sub-sampling estimators (``rs`` / ``grid_hbe``) have no
        live-mass-preserving rebuild and are rejected."""
        est = self._estimator
        ds = self._dataset
        if getattr(est, "_dataset", None) is ds and hasattr(est, "_sync"):
            est._sync()
            return
        from repro.core.kde.base import (ExactBlockKDE, ExactKDE,
                                         StratifiedKDE)
        if isinstance(est, StratifiedKDE):
            self._estimator = StratifiedKDE(
                ds.x_pad, est.kernel, block_size=est.block_size,
                samples_per_block=est.samples_per_block, seed=self._seed)
        elif isinstance(est, ExactBlockKDE):
            self._estimator = ExactBlockKDE(ds.x_pad, est.kernel,
                                            block_size=est.block_size)
        elif isinstance(est, ExactKDE):
            self._estimator = ExactKDE(ds.x_pad, est.kernel)
        else:
            raise ValueError(
                f"{type(est).__name__} has no streaming rebuild; attach "
                "the dataset to the estimator (HashedKDE(dataset=...)) or "
                "use a dense estimator")

    def _sync(self) -> None:
        """Epoch check at every public entry: patch the degree vector by
        the coalesced mutation delta (``ops.degree_delta``, O(n m) evals
        for an m-row batch) and re-accumulate the float64 prefix CDF
        (O(n)); journal gaps recompute degrees from scratch.  Mutated
        slots get exact recomputes, so repeated patching does not drift
        beyond the estimator's own error on untouched rows."""
        ds = self._dataset
        if ds is None or self._ds_epoch == int(ds.epoch):
            return
        from repro.core.dataset import coalesce_mutations
        est = self._estimator
        batches = ds.mutations_since(self._ds_epoch)
        if batches is None:
            self._rebuild_estimator()
            self.degrees = streaming_degrees(self._estimator, ds)
            self.rebuilds += 1
        else:
            slots, old_x, new_x, old_live, new_live = \
                coalesce_mutations(batches)
            if hasattr(est, "patch_rows"):     # mesh adapter: idempotent
                est.patch_rows(jnp.asarray(slots),
                               jnp.asarray(new_x, jnp.float32))
                x, x_sq = est.x, est.x_sq
            elif getattr(est, "_dataset", None) is ds:
                est._sync()                    # self-syncing (HashedKDE)
                x, x_sq = ds.x_pad, ds.x_sq_pad
            else:                              # dense: refresh stale views
                est.x = ds.x_pad
                est.x_sq = ds.x_sq_pad
                x, x_sq = est.x, est.x_sq
            from repro.kernels.kde_sampler import ops as _ops
            from repro.kernels.kde_sampler.ref import static_pairwise
            k = est.kernel
            d, cw = _ops.degree_delta(
                jnp.asarray(self.degrees, jnp.float32), x, x_sq,
                jnp.asarray(slots), jnp.asarray(old_x, jnp.float32),
                jnp.asarray(new_x, jnp.float32),
                jnp.asarray(old_live), jnp.asarray(new_live),
                kind=k.name, inv_bw=1.0 / k.bandwidth,
                beta=getattr(k, "beta", 1.0),
                pairwise=static_pairwise(k))
            d = np.asarray(d, np.float64)
            est.evals += 2 * len(np.asarray(slots)) * len(d)
            if hasattr(est, "device_counters"):
                est.device_counters.note(cw)
            live = np.zeros(len(d), bool)
            live[np.asarray(ds.live_slots())] = True
            self.degrees = np.where(live, np.maximum(d, 1e-12), 0.0)
        # seed varies by epoch so rebuilds do not replay the draw stream
        self._cdf = PrefixCDF(self.degrees,
                              seed=self._seed + int(ds.epoch))
        self.total = self._cdf.total
        self._ds_epoch = int(ds.epoch)

    def sample(self, size: int) -> np.ndarray:
        """Draw ``size`` vertices u ~ deg(u) / sum deg (Algorithm 4.6).

        >>> u = DegreeSampler(est).sample(1024)
        """
        self._sync()
        return self._cdf.sample(size)

    def prob(self, idx) -> np.ndarray:
        """Probability this sampler assigns to vertex idx (p_i / sum p_j)."""
        self._sync()
        return self._cdf.prob(idx)

    @property
    def cdf_device(self) -> jnp.ndarray:
        """Normalized float32 prefix array for the fused edge-batch op."""
        self._sync()
        return self._cdf.cdf_device

    @property
    def degrees_device(self) -> jnp.ndarray:
        """Raw float32 degree array for the fused edge-batch op."""
        self._sync()
        return self._cdf.weights_device


def sample_from_positive_array(a: np.ndarray, size: int, rng) -> np.ndarray:
    """Algorithm 4.5 in its dense form (used directly in tests against the
    explicit tree-descent reference)."""
    prefix = np.cumsum(np.asarray(a, np.float64))
    u = rng.uniform(0.0, prefix[-1], size=size)
    return np.searchsorted(prefix, u, side="right").clip(0, len(a) - 1)


def tree_descent_sample(a: np.ndarray, rng) -> int:
    """Literal Algorithm 4.5 (binary descent on segment sums) -- reference
    implementation used by property tests to certify the dense form."""
    lo, hi = 0, len(a)
    prefix = np.concatenate([[0.0], np.cumsum(np.asarray(a, np.float64))])

    def seg(l, h):  # A_{l,h} query via prefix sums (O(1), as Thm 4.9 notes)
        return prefix[h] - prefix[l]

    while hi - lo > 1:
        mid = lo + (hi - lo) // 2
        wl, wr = seg(lo, mid), seg(mid, hi)
        if rng.uniform() <= wl / max(wl + wr, 1e-30):
            hi = mid
        else:
            lo = mid
    return lo
