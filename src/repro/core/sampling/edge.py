"""Weighted neighbor / edge sampling -- Algorithms 4.11 and 4.13.

Given a vertex u, sample a neighbor v with Pr[v] ~= k(u, v) / deg(u)
(Definition 4.10) using segment KDE estimates only.

Two interchangeable factorizations of the same telescoping product
(Theorem 4.12):

* ``mode="tree"``   -- the paper's dyadic descent: at every internal node,
  query the two child-segment KDE structures and branch proportionally;
  O(log n) KDE queries per sample, error (1 +- eps')^depth.
* ``mode="blocked"``-- TPU-adapted depth-2 tree (DESIGN.md §2), executed by
  the fused device engine (``repro.kernels.kde_sampler``): level-1 masked
  block sums + Gumbel-max block draw + exact level-2 row + in-block draw
  are ONE compiled program keyed on a ``jax.random.PRNGKey``.  No per-call
  Python loops over blocks, one host->device transfer per batch (the
  frontier indices), one device->host transfer for the results.

Both modes vectorize over a batch of source vertices (random-walk frontier).
``sample`` returns the *realized* sampling probability of each drawn
neighbor, and ``prob_of`` evaluates the probability the sampler would assign
to an arbitrary (u, v) -- both are required by the sparsifier (Alg 5.1 steps
(c)-(d)).

Level-1 caching contract (DESIGN.md §4): the masked block sums of the most
recent frontier are kept on device; ``sample`` / ``prob_of`` /
``sample_exact`` on the *same* frontier reuse them instead of re-sweeping
the dataset, which makes ``prob_of`` exactly consistent with the estimates
``sample`` realized and collapses the rejection rounds of Theorem 4.12 to
one level-1 read.

Theorem 4.12's exactness step (O(1/tau) rejection rounds) is implemented in
``sample_exact`` as a fixed-round vectorized accept/reject program.

Every fused program also returns a ``repro.ft.guards`` status bitmask; the
sampler or-folds them into ``self.status`` / ``self.flag_counts`` and, under
``REPRO_CHECKS=1``, raises ``EstimationError`` on fatal flags.  Rejection
fallbacks (Theorem 4.12's all-rounds-reject event) are counted in
``exact_fallbacks`` and compared against the (1 - 1/c)^rounds prediction.
"""
from __future__ import annotations

from collections import Counter
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kde.base import ExactBlockKDE, StratifiedKDE
from repro.core.kde.multilevel import MultiLevelKDE
from repro.core.kernels_fn import Kernel
from repro.ft import guards as _g
from repro.obs import counters as _c

# Flags a healthy pipeline may legitimately raise: truncated buckets and
# heavy HT samples are accuracy (not validity) signals, and rejection
# exhaustion has a documented fallback (Theorem 4.12).
_BENIGN = _g.BUCKET_OVERFLOW | _g.HT_HEAVY | _g.REJECT_EXHAUSTED


class NeighborSampler:
    """Algorithm 4.11 / Theorem 4.12: sample v ~ k(u, v)/deg(u) given u.

    ``mode="blocked"`` is the fused depth-2 device engine (DESIGN.md §2-§4,
    one compiled program per batch, one level-1 read per frontier);
    ``mode="tree"`` is the paper's literal dyadic descent over a
    ``MultiLevelKDE``.  Cost per blocked sample: one level-1 read (w*B*s
    stratified / w*n exact kernel evals for a w-frontier) plus w exact
    level-2 rows of ``block_size`` columns.

    With ``mesh=`` (blocked mode only) the level-1 block structure lives
    sharded over the mesh's ``data_axes`` and every draw is the two-stage
    collective program of DESIGN.md §9 (one psum per draw batch) --
    distribution-identical to the flat draw, same §4 caching contract,
    same eval counters.

    With ``level1="hash"`` the level-1 block masses are estimated by the
    ``kde_hash`` padded-bucket estimator (exact NEAR members + HT FAR
    samples scattered into their blocks, O(max_bucket + num_far) evals
    per frontier row, DESIGN.md §10); the block draw, the exact level-2
    read and the Theorem 4.12 rejection-exact mode are unchanged, so the
    §2 sampling contract and the §4 cache carry over verbatim.

    >>> nbr = NeighborSampler(x, gaussian(1.0), mode="blocked")
    >>> v, q = nbr.sample(np.array([0, 1, 2]))
    """

    def __init__(self, x: jnp.ndarray, kernel: Kernel, mode: str = "blocked",
                 block_size: Optional[int] = None, samples_per_block: int = 16,
                 exact_blocks: bool = False, tree: Optional[MultiLevelKDE] = None,
                 seed: int = 0, use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None, mesh=None,
                 data_axes=("data",), level1: str = "blocked",
                 hash_opts: Optional[dict] = None, dataset=None,
                 precision: str = "f32"):
        from repro.kernels.kde_sampler import ops as _ops
        self._ops = _ops
        # streaming attach (DESIGN.md §12): engines build over the padded
        # capacity; every public entry epoch-checks and patches/rebuilds
        self._dataset = dataset
        self._ds_epoch = int(dataset.epoch) if dataset is not None else 0
        if dataset is not None:
            if mode != "blocked":
                raise ValueError("dataset= needs the blocked engine")
            x = dataset.x_pad
        self.x = jnp.asarray(x, jnp.float32)
        self.kernel = kernel
        self.n = int(x.shape[0])
        self.mode = mode
        self.level1 = level1
        # level-1 sweep dtype policy (DESIGN.md §14); validated against the
        # kernel kind up front so bad configs fail at construction.
        self.precision = precision
        if precision != "f32":
            from repro.kernels.kde_sampler.ref import (check_precision,
                                                       static_pairwise)
            check_precision(precision, kernel.name, static_pairwise(kernel))
            if mesh is not None:
                raise ValueError(
                    "precision='bf16' is single-device for now: the "
                    "sharded one-psum schedule is pinned f32 (its jaxpr "
                    "is contract-asserted; see DESIGN.md §14)")
        self._rng = np.random.default_rng(seed)
        self._key = jax.random.PRNGKey(seed)
        # or-fold of every program's status word + per-flag event counts
        # (DESIGN.md §11); rejection-fallback accounting for Theorem 4.12.
        self.status = 0
        self.flag_counts: Counter = Counter()
        # realized device totals (DESIGN.md §15.1): every fused program's
        # counter word folds in through ``_note``; ``device_counters
        # ["evals"]`` reconciles against the analytic ``.evals`` on the
        # flat blocked/exact pipelines (asserted in tests)
        self.device_counters = _c.HostTotals()
        self.exact_draws = 0
        self.exact_fallbacks = 0
        self._engine = None
        self._hash = None
        self._hstate = None
        if level1 not in ("blocked", "hash"):
            raise ValueError(f"unknown level1 {level1!r}")
        if level1 == "hash" and exact_blocks:
            raise ValueError("level1='hash' replaces the level-1 read with "
                             "hashed estimates; exact_blocks=True (the "
                             "reproducible exact read) cannot be honored "
                             "-- pick one")
        if mesh is not None:
            assert mode == "blocked", "mesh= needs the blocked engine"
            if level1 == "hash":
                raise ValueError("level1='hash' is single-device for now; "
                                 "the sharded hash table covers queries "
                                 "(kde_hash.sharded), not draws")
        if mode == "blocked":
            bs = block_size or max(int(np.sqrt(self.n)), 16)
            # kept for the streaming rebuild path (journal gap / capacity
            # growth re-runs this construction over the new padded array)
            self._mesh0 = mesh
            self._axes0 = data_axes
            self._spb0 = samples_per_block
            self._seed0 = seed
            if mesh is not None:
                # Mesh construction path (DESIGN.md §9): the level-1 block
                # structure lives sharded inside a ShardedKDE; draws are
                # two-stage collective programs.  The §4 caching contract
                # and every eval-counter formula below are unchanged.
                from repro.core.kde.distributed import ShardedKDE
                self._blocks = ShardedKDE(
                    mesh, self.x, kernel, block_size=bs,
                    samples_per_block=samples_per_block, exact=exact_blocks,
                    data_axes=data_axes, seed=seed)
                self._engine = self._blocks.engine
            elif exact_blocks:
                self._blocks = ExactBlockKDE(self.x, kernel, block_size=bs,
                                             precision=precision)
            else:
                self._blocks = StratifiedKDE(self.x, kernel, block_size=bs,
                                             samples_per_block=samples_per_block,
                                             seed=seed, precision=precision)
            # ONE device dataset + one precomputed-norms sweep, shared with
            # the block KDE structure (and, through ``blocks``, with any
            # degree sampler built on top of it -- DESIGN.md §6).
            self.x = self._blocks.x
            self.x_sq = self._blocks.x_sq
            self.block_size = self._blocks.block_size
            self.num_blocks = self._blocks.num_blocks
            self.exact_blocks = exact_blocks
            if use_pallas is None:
                use_pallas = (_ops.default_use_pallas()
                              if self._engine is None else False)
            if interpret is None:
                interpret = (jax.default_backend() != "tpu"
                             and self._engine is None)
            self._far_per_block = 1
            if level1 == "hash":
                # Hashed level-1 (DESIGN.md §10): block masses estimated
                # from the kde_hash padded-bucket layout (exact NEAR
                # scatter + ``far_per_block`` stratified FAR slots per
                # block) at O(max_bucket + B far_per_block) evals per
                # frontier row; level-2 stays the exact in-block read, so
                # the §2 contract and every consumer of cached block sums
                # are unchanged.
                from repro.core.kde.hashed import HashedKDE
                hopts = dict(hash_opts or {})
                # Defaults tuned so the full degrees->sparsify pipeline at
                # n=16k spends ~20% of the stratified eval budget while
                # keeping spectral error within 1.25x (BENCH_kde.json).
                self._far_per_block = int(hopts.pop("far_per_block", 2))
                hopts.setdefault("max_bucket", 128)
                self._hash = HashedKDE(self.x, kernel,
                                       seed=seed + 7919,
                                       use_pallas=bool(use_pallas),
                                       interpret=bool(interpret),
                                       dataset=dataset,
                                       precision=precision,
                                       **hopts)
                self._hstate = self._hash.state
            from repro.kernels.kde_sampler.ref import static_pairwise
            # Static engine configuration shared by every jitted entry point.
            self._cfg = dict(
                kind=kernel.name, inv_bw=1.0 / kernel.bandwidth,
                beta=getattr(kernel, "beta", 1.0),
                pairwise=static_pairwise(kernel),
                block_size=self.block_size, num_blocks=self.num_blocks,
                n=self.n, s=self._blocks.samples_per_block,
                exact=exact_blocks, use_pallas=bool(use_pallas),
                interpret=bool(interpret),
                bm=32 if level1 == "hash" else 128,
                level1=level1, num_far=self._far_per_block,
                precision=precision)
            self._l2_cfg = {k: self._cfg[k] for k in
                            ("kind", "inv_bw", "beta", "pairwise",
                             "block_size", "n")}
            # (digest, block sums, frontier indices) -- the indices let the
            # streaming sync decide patch-vs-drop when the dataset mutates
            self._l1_cache: Optional[
                Tuple[bytes, jnp.ndarray, np.ndarray]] = None
        elif mode == "tree":
            assert tree is not None, "tree mode needs a MultiLevelKDE"
            self.x_sq = jnp.sum(self.x * self.x, axis=-1)
            self._tree = tree
        else:
            raise ValueError(mode)

    # ------------------------------------------------------------------ #
    @property
    def blocks(self):
        """The level-1 KDE structure (blocked mode) -- exposed so consumers
        (the sparsifier's degree preprocessing) can share it instead of
        building a second structure over the same dataset."""
        assert self.mode == "blocked"
        return self._blocks

    @property
    def evals(self) -> int:
        """Total kernel evaluations across the level-1 structure and every
        sampling call -- the paper's Section 7 cost metric."""
        if self.mode == "blocked":
            return self._blocks.evals + getattr(self, "_extra_evals", 0)
        return self._tree.evals + getattr(self, "_extra_evals", 0)

    def _count(self, k: int):
        self._extra_evals = getattr(self, "_extra_evals", 0) + k

    def _next_key(self) -> jnp.ndarray:
        self._key, k = jax.random.split(self._key)
        return k

    def _note(self, st, context: str) -> int:
        """Fold one program's counter word (or a legacy scalar status)
        into the counters, then apply the ``REPRO_CHECKS`` policy (fatal
        flags raise, benign ones pass)."""
        if _c.is_word(st):
            s = self.device_counters.note(jax.device_get(st))
        else:
            s = int(np.uint32(jax.device_get(st)))
        self.status |= s
        _g.count_flags(self.flag_counts, s)
        _g.raise_on_status(s, context=context, allow=_BENIGN)
        return s

    # ------------------------------------------------------------------ #
    # streaming contract (DESIGN.md §12)
    def _rebuild(self) -> None:
        """Full level-1 rebuild over the dataset's current padded array --
        the journal-gap / capacity-growth path of the streaming contract.
        Block size is kept; the block count follows the new capacity."""
        ds = self._dataset
        self.x = jnp.asarray(ds.x_pad, jnp.float32)
        self.n = int(self.x.shape[0])
        bs = self.block_size
        if self._engine is not None:
            from repro.core.kde.distributed import ShardedKDE
            self._blocks = ShardedKDE(
                self._mesh0, self.x, self.kernel, block_size=bs,
                samples_per_block=self._spb0, exact=self.exact_blocks,
                data_axes=self._axes0, seed=self._seed0)
            self._engine = self._blocks.engine
        elif self.exact_blocks:
            self._blocks = ExactBlockKDE(self.x, self.kernel, block_size=bs)
        else:
            self._blocks = StratifiedKDE(
                self.x, self.kernel, block_size=bs,
                samples_per_block=self._spb0, seed=self._seed0)
        self.x = self._blocks.x
        self.x_sq = self._blocks.x_sq
        self.num_blocks = self._blocks.num_blocks
        self._cfg.update(n=self.n, num_blocks=self.num_blocks)
        self._l2_cfg["n"] = self.n
        self._l1_cache = None

    def _sync(self) -> None:
        """Epoch check at every public entry: refresh the dataset views,
        patch the cached level-1 read by the coalesced mutation delta
        (O(w m) evals; dropped instead when a cached frontier row itself
        mutated), patch the sharded engine's device copies (zero
        collectives), and let a hashed level-1 run its own patch-or-
        rebuild.  A journal gap falls back to ``_rebuild``."""
        ds = self._dataset
        if ds is None or self._ds_epoch == int(ds.epoch):
            return
        from repro.core.dataset import coalesce_mutations
        batches = ds.mutations_since(self._ds_epoch)
        if batches is None:
            self._rebuild()
            if self._hash is not None:
                self._hash._sync()
                self._hstate = self._hash.state
            self._ds_epoch = int(ds.epoch)
            return
        slots, old_x, new_x, _, _ = coalesce_mutations(batches)
        if self._engine is not None:
            # mesh path: one zero-collective scatter program patches the
            # sharded + replicated dataset copies; the cached level-1 sums
            # live in flat layout only, so the sharded cache is dropped
            self._blocks.patch_rows(jnp.asarray(slots),
                                    jnp.asarray(new_x, jnp.float32))
            self.x = self._blocks.x
            self.x_sq = self._blocks.x_sq
            self._l1_cache = None
        else:
            # jnp arrays rebind on mutation -- refresh every shared view
            self.x = ds.x_pad
            self.x_sq = ds.x_sq_pad
            self._blocks.x = self.x
            self._blocks.x_sq = self.x_sq
            if self._l1_cache is not None:
                dig, bs, src32 = self._l1_cache
                if np.intersect1d(src32,
                                  np.asarray(slots, np.int64)).size:
                    self._l1_cache = None   # frontier row itself mutated
                else:
                    bs, cw = self._ops.patch_block_sums(
                        bs, self.x, jnp.asarray(src32),
                        jnp.asarray(slots), jnp.asarray(old_x, jnp.float32),
                        jnp.asarray(new_x, jnp.float32),
                        kind=self._cfg["kind"], inv_bw=self._cfg["inv_bw"],
                        beta=self._cfg["beta"],
                        pairwise=self._cfg["pairwise"],
                        block_size=self.block_size)
                    self._count(2 * len(src32) * len(slots))
                    self._note(cw, "NeighborSampler.sync")
                    self._l1_cache = (dig, bs, src32)
        if self._hash is not None:
            self._hash._sync()
            self._hstate = self._hash.state
        self._ds_epoch = int(ds.epoch)

    def _check_frontier(self, src32: np.ndarray, context: str) -> None:
        """Liveness gate for caller-supplied frontiers: referencing a
        deleted slot folds ``EPOCH_STALE`` into the status word (an
        ``EstimationError`` under ``REPRO_CHECKS=1`` -- the flag is not in
        ``_BENIGN``)."""
        ds = self._dataset
        if ds is None:
            return
        if not bool(np.all(ds.is_live(np.asarray(src32)))):
            self._note(_g.EPOCH_STALE, context)

    @property
    def hash_estimator(self):
        """The shared hashed-KDE estimator behind ``level1="hash"`` --
        exposed so consumers (Algorithm 4.3 degree preprocessing) reuse
        the one bucket layout instead of hashing the dataset twice."""
        assert self._hash is not None, "level1='hash' sampler required"
        return self._hash

    # ------------------------------------------------------------------ #
    # blocked mode: fused device engine
    def _level1_evals(self, w: int) -> int:
        if self.level1 == "hash":
            # the frontier gather sweeps the realized bucket-member width,
            # the streaming overflow region (previously omitted -- the
            # host counter drifted below the device word on streaming
            # hash pipelines), and far_per_block FAR slots per block --
            # the same static shapes the device counter word is built from
            mb = (int(self._hstate.members.shape[1])
                  if self._hstate is not None else self._hash.max_bucket)
            ov = (int(self._hstate.overflow.shape[0])
                  if self._hstate is not None
                  and self._hstate.overflow is not None else 0)
            return w * (mb + ov + self.num_blocks * self._cfg["num_far"])
        if self.exact_blocks:
            return w * self.n
        return w * self.num_blocks * self._cfg["s"]

    @staticmethod
    def _digest(src32: np.ndarray) -> bytes:
        """Cache key for a frontier: dtype-normalized indices + length (raw
        tobytes of caller-supplied arrays would collide across dtypes)."""
        return src32.shape[0].to_bytes(8, "little") + src32.tobytes()

    def _level1(self, src32: np.ndarray, src_dev: jnp.ndarray) -> jnp.ndarray:
        """Masked level-1 block sums for a frontier, cached per frontier."""
        dig = self._digest(src32)
        if self._l1_cache is not None and self._l1_cache[0] == dig:
            return self._l1_cache[1]
        if self._engine is not None:
            bs, cw = self._engine.masked_block_sums(src_dev,
                                                    self._next_key())
        else:
            bs, cw = self._ops.masked_block_sums(self.x, self.x_sq, src_dev,
                                                 self._next_key(),
                                                 hstate=self._hstate,
                                                 **self._cfg)
        self._count(self._level1_evals(len(src32)))
        self._note(cw, "NeighborSampler.level1")
        self._l1_cache = (dig, bs, src32)
        return bs

    def sample(self, src: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Sample one neighbor per source.  Returns (neighbors, probs)."""
        src = np.asarray(src)
        if self.mode == "tree":
            return self._sample_tree(src)
        self._sync()
        self._check_frontier(src, "NeighborSampler.sample")
        src32 = np.ascontiguousarray(src, np.int32)
        src_dev = jnp.asarray(src32)
        dig = self._digest(src32)
        if self._l1_cache is not None and self._l1_cache[0] == dig:
            if self._engine is not None:
                nb, prob, st = self._engine.sample_from_block_sums(
                    src_dev, self._l1_cache[1], self._next_key())
            else:
                nb, prob, st = self._ops.sample_from_block_sums(
                    self.x, self.x_sq, src_dev, self._l1_cache[1],
                    self._next_key(), **self._l2_cfg)
        else:
            if self._engine is not None:
                nb, prob, bs, st = self._engine.fused_sample(
                    src_dev, self._next_key())
            else:
                nb, prob, bs, st = self._ops.fused_sample(
                    self.x, self.x_sq, src_dev, self._next_key(),
                    hstate=self._hstate, **self._cfg)
            self._count(self._level1_evals(len(src)))
            self._l1_cache = (dig, bs, src32)
        self._count(len(src) * self.block_size)
        self._note(st, "NeighborSampler.sample")
        return np.asarray(nb), np.asarray(prob)

    def prob_of(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Probability the sampler assigns to edge (src -> dst)."""
        src, dst = np.asarray(src), np.asarray(dst)
        if self.mode == "tree":
            return self._prob_of_tree(src, dst)
        self._sync()
        self._check_frontier(np.concatenate([src, dst]),
                             "NeighborSampler.prob_of")
        src32 = np.ascontiguousarray(src, np.int32)
        src_dev = jnp.asarray(src32)
        bs = self._level1(src32, src_dev)
        if self._engine is not None:
            out, cw = self._engine.prob_of_from_block_sums(
                src_dev, jnp.asarray(dst, jnp.int32), bs)
        else:
            out, cw = self._ops.prob_of_from_block_sums(
                self.x, self.x_sq, src_dev, jnp.asarray(dst, jnp.int32), bs,
                **self._l2_cfg)
        self._count(len(src) * self.block_size)
        self._note(cw, "NeighborSampler.prob_of")
        return np.asarray(out)

    # ------------------------------------------------------------------ #
    # tree mode (faithful Algorithm 4.11)
    def _sample_tree(self, src: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        out = np.zeros(len(src), np.int64)
        probs = np.ones(len(src), np.float64)
        for i, s in enumerate(src):
            lo, hi = 0, self._tree.n
            p = 1.0
            q = self.x[int(s)][None, :]
            while not self._tree.is_leaf(lo, hi):
                (l0, l1), (r0, r1) = self._tree.children(lo, hi)
                a = float(self._tree.segment_query(q, l0, l1)[0])
                b = float(self._tree.segment_query(q, r0, r1)[0])
                if l0 <= s < l1:
                    a = max(a - 1.0, 1e-12)
                if r0 <= s < r1:
                    b = max(b - 1.0, 1e-12)
                pa = a / max(a + b, 1e-30)
                if self._rng.uniform() <= pa:
                    lo, hi, p = l0, l1, p * pa
                else:
                    lo, hi, p = r0, r1, p * (1.0 - pa)
            kv = np.array(self.kernel.pairwise(q, self.x[lo:hi]))[0]
            self._count(hi - lo)
            idx = np.arange(lo, hi)
            kv[idx == s] = 0.0
            pin = kv / max(kv.sum(), 1e-30)
            j = self._rng.choice(len(pin), p=pin / pin.sum())
            out[i] = lo + j
            probs[i] = p * pin[j]
        return out, probs

    def _prob_of_tree(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        out = np.zeros(len(src), np.float64)
        for i, (s, t) in enumerate(zip(src, dst)):
            lo, hi = 0, self._tree.n
            p = 1.0
            q = self.x[int(s)][None, :]
            while not self._tree.is_leaf(lo, hi):
                (l0, l1), (r0, r1) = self._tree.children(lo, hi)
                a = float(self._tree.segment_query(q, l0, l1)[0])
                b = float(self._tree.segment_query(q, r0, r1)[0])
                if l0 <= s < l1:
                    a = max(a - 1.0, 1e-12)
                if r0 <= s < r1:
                    b = max(b - 1.0, 1e-12)
                pa = a / max(a + b, 1e-30)
                if l0 <= t < l1:
                    lo, hi, p = l0, l1, p * pa
                else:
                    lo, hi, p = r0, r1, p * (1.0 - pa)
            kv = np.array(self.kernel.pairwise(q, self.x[lo:hi]))[0]
            self._count(hi - lo)
            idx = np.arange(lo, hi)
            kv[idx == s] = 0.0
            out[i] = p * kv[t - lo] / max(kv.sum(), 1e-30)
        return out

    # ------------------------------------------------------------------ #
    def sample_exact(self, src: np.ndarray, rounds: int = 8,
                     slack: float = 2.0) -> np.ndarray:
        """Theorem 4.12 exactness: rejection-sample against exact weights.

        Proposal = this sampler; target ~ k(u, v).  Accept v with probability
        k(u,v) / (c * q(v) * Z_hat) where Z_hat estimates deg(u) and c covers
        the estimator distortion.  Vectorized fixed-round accept/reject; falls
        back to the last proposal if all rounds reject (prob (1-1/c)^rounds).

        The level-1 read happens ONCE; all proposal rounds and the degree
        estimate Z_hat share it (blocked mode).  The k(u, v) accept weights
        are evaluated as w aligned pairs, not a (w, w) matrix diagonal.
        """
        src = np.asarray(src)
        if self.mode == "tree":
            return self._sample_exact_host(src, rounds, slack)
        self._sync()
        self._check_frontier(src, "NeighborSampler.sample_exact")
        src32 = np.ascontiguousarray(src, np.int32)
        src_dev = jnp.asarray(src32)
        bs = self._level1(src32, src_dev)
        if self._engine is not None:
            cur, st, fb = self._engine.sample_exact(
                src_dev, bs, self._next_key(), rounds=rounds, slack=slack)
        else:
            cur, st, fb = self._ops.fused_sample_exact(
                self.x, self.x_sq, src_dev, bs, self._next_key(),
                rounds=rounds, slack=slack, **self._l2_cfg)
        self._count((rounds + 1) * len(src) * self.block_size
                    + rounds * len(src))
        self._note(st, "NeighborSampler.sample_exact")
        self.exact_draws += len(src)
        self.exact_fallbacks += int(jax.device_get(fb))
        _g.warn_fallback_rate(self.exact_fallbacks, self.exact_draws,
                              rounds, slack,
                              context="NeighborSampler.sample_exact")
        return np.asarray(cur)

    def _sample_exact_host(self, src: np.ndarray, rounds: int,
                           slack: float) -> np.ndarray:
        cur, _ = self.sample(src)
        zs = np.maximum(np.asarray(
            self._tree.segment_query(self.x[jnp.asarray(src)], 0,
                                     self._tree.n)) - 1.0, 1e-12)
        accepted = np.zeros(len(src), bool)
        for _ in range(rounds):
            cand, q = self.sample(src)
            kuv = np.asarray(self.kernel.pairs(self.x[jnp.asarray(src)],
                                               self.x[jnp.asarray(cand)]))
            self._count(len(src))
            ratio = kuv / np.maximum(slack * q * zs, 1e-30)
            acc = (~accepted) & (self._rng.uniform(size=len(src))
                                 < np.minimum(ratio, 1.0))
            cur = np.where(acc, cand, cur)
            accepted |= acc
        return cur

    # ------------------------------------------------------------------ #
    def edge_batches(self, cdf_device: jnp.ndarray, degs_device: jnp.ndarray,
                     total_degree: float, t: int, batch: int = 1024,
                     key: Optional[jnp.ndarray] = None):
        """Algorithm 5.1 edge sampling, fully fused (blocked mode): draws
        ``ceil(t / batch)`` iid edge batches in ONE ``lax.scan`` device
        program -- u ~ degrees via the device prefix CDF, v | u via the
        depth-2 engine, the (algebraically collapsed) reverse probability
        q_vu = k(u,v)/deg(v), and the importance weight ``k(u,v) / (t q_e)``
        -- and returns the first t edges as (u, v, weight, q_uv, q_vu)
        numpy arrays.

        ``cdf_device`` / ``degs_device`` come from a ``PrefixCDF``
        (float64-accumulated, rounded to f32); extra draws from the final
        partial batch are discarded, which leaves the estimator unbiased
        (edges are iid)."""
        assert self.mode == "blocked", "fused edge batches need blocked mode"
        self._sync()
        t = int(t)
        num_batches = max((t + batch - 1) // batch, 1)
        keys = jax.random.split(self._next_key() if key is None else key,
                                num_batches)
        if self._engine is not None:
            out = self._engine.edge_batch_scan(
                jnp.asarray(cdf_device), jnp.asarray(degs_device),
                1.0 / float(total_degree), 1.0 / t, keys, batch=int(batch))
        else:
            out = self._ops.edge_batch_scan(
                self.x, self.x_sq, jnp.asarray(cdf_device),
                jnp.asarray(degs_device), 1.0 / float(total_degree), 1.0 / t,
                keys, hstate=self._hstate, batch=int(batch), **self._cfg)
        drawn = num_batches * batch
        # per edge: one level-1 read of the u frontier, one exact level-2
        # row, and one aligned k(u, v) pair (the reverse probability
        # reuses the pair and the preprocessed degrees -- no extra reads).
        self._count(self._level1_evals(drawn)
                    + drawn * self.block_size + drawn)
        self._l1_cache = None  # frontier moved; cached sums are stale
        *data, st = out
        self._note(st, "NeighborSampler.edge_batches")
        return tuple(np.asarray(a).reshape(-1)[:t] for a in data)

    # ------------------------------------------------------------------ #
    def triangle_batches(self, u: np.ndarray, v: np.ndarray,
                         degs_device: jnp.ndarray, num_draws: int,
                         key: Optional[jnp.ndarray] = None):
        """Theorem 6.17's inner loop, fully fused (blocked mode): orient
        the (u, v) vertex pairs by the degree-then-index order, read the
        oriented v frontier's level-1 sums ONCE, draw ``num_draws``
        neighbors w ~ k(v, .)/deg(v) under ``lax.scan``, and reweight --
        one program, one device->host transfer of (u', v', W_e).

        Cost: one level-1 read of the m-edge frontier plus, per draw, m
        exact level-2 rows and m aligned k(u, w) pairs -- ``m*(B*s + 1) +
        num_draws*m*(bs + 1)`` kernel evals for stratified reads
        (``m*(n + 1) + ...`` exact)."""
        assert self.mode == "blocked", "fused triangle batches need blocked mode"
        self._sync()
        self._check_frontier(np.concatenate([np.asarray(u), np.asarray(v)]),
                             "NeighborSampler.triangle_batches")
        m = len(np.asarray(u))
        keys = jax.random.split(self._next_key() if key is None else key,
                                int(num_draws) + 1)
        if self._engine is not None:
            uu, vv, w_hat, st = self._engine.triangle_edge_scan(
                jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32),
                jnp.asarray(degs_device), keys)
        else:
            uu, vv, w_hat, st = self._ops.triangle_edge_scan(
                self.x, self.x_sq, jnp.asarray(u, jnp.int32),
                jnp.asarray(v, jnp.int32), jnp.asarray(degs_device), keys,
                hstate=self._hstate, **self._cfg)
        self._count(self._level1_evals(m) + m
                    + int(num_draws) * (m * self.block_size + m))
        self._l1_cache = None  # frontier moved; cached sums are stale
        self._note(st, "NeighborSampler.triangle_batches")
        return np.asarray(uu), np.asarray(vv), np.asarray(w_hat)

    # ------------------------------------------------------------------ #
    def walk(self, starts: np.ndarray, length: int, exact: bool = False,
             rounds: int = 8, slack: float = 2.0,
             key: Optional[jnp.ndarray] = None, record_path: bool = False):
        """Run |starts| walks of ``length`` steps entirely on device
        (blocked mode): the frontier is ``lax.scan`` carry and every step is
        one fused depth-2 sample.  Returns (endpoints, (length, w) path) as
        numpy arrays; with ``record_path=False`` (default) the path is
        never stacked on device and None is returned in its place --
        endpoints are bitwise identical either way (same key stream)."""
        assert self.mode == "blocked", "device walks need blocked mode"
        self._sync()
        self._check_frontier(np.asarray(starts), "NeighborSampler.walk")
        starts_dev = jnp.asarray(starts, jnp.int32)
        keys = jax.random.split(self._next_key() if key is None else key,
                                length)
        if self._engine is not None:
            end, path, st, fb = self._engine.walk_scan(
                starts_dev, keys, rounds=rounds if exact else 0,
                slack=slack, record_path=bool(record_path))
        else:
            end, path, st, fb = self._ops.walk_scan(
                self.x, self.x_sq, starts_dev, keys,
                hstate=self._hstate, rounds=rounds if exact else 0,
                slack=slack, record_path=bool(record_path), **self._cfg)
        w = len(np.asarray(starts))
        # the walk-resident level-1 cache (kernels.tuning) caps the per-step
        # level-1 read at B * s_eff cached columns on the jnp blocked path;
        # mirror walk_scan's gate so the eval counter reports true cost
        if (self.level1 == "blocked" and not self.exact_blocks
                and not self._cfg["use_pallas"] and self._engine is None):
            wbs, w_blocks, s_eff = self._ops.walk_layout(
                self.n, self.block_size, self.num_blocks, self._cfg["s"])
            per_step = w * w_blocks * s_eff + w * wbs
            if exact:
                # rejection rounds run on the walk-resident layout too:
                # level-2 rows are wbs wide, not block_size (the old
                # block_size term drifted above the device word whenever
                # tuning picked a different walk block size)
                per_step += rounds * (w * wbs + w)
        else:
            per_step = self._level1_evals(w) + w * self.block_size
            if exact:
                per_step += rounds * (w * self.block_size + w)
        self._count(length * per_step)
        self._l1_cache = None  # frontier moved; cached sums are stale
        self._note(st, "NeighborSampler.walk")
        if exact:
            self.exact_draws += w * length
            self.exact_fallbacks += int(jax.device_get(fb))
            _g.warn_fallback_rate(self.exact_fallbacks, self.exact_draws,
                                  rounds, slack,
                                  context="NeighborSampler.walk")
        return np.asarray(end), (np.asarray(path) if record_path else None)


def shared_level1_estimator(nbr: NeighborSampler, estimator: str,
                            seed: int = 0):
    """Reuse ``nbr``'s level-1 KDE structure as the degree estimator
    whenever it implements the requested one (DESIGN.md §6/§7): one device
    dataset, one ``x_sq`` sweep, one eval counter for the whole pipeline.
    A ``level1="hash"`` sampler shares its hashed bucket layout the same
    way (``estimator="hash"`` -> the sampler's own ``HashedKDE``).
    ``rs`` / ``grid_hbe`` (and exact/stratified mismatches) fall back to a
    standalone ``make_estimator`` over the sampler's device dataset."""
    from repro.core.kde.base import make_estimator

    if estimator == "robust":
        # the staged-fallback wrapper builds its own hash->stratified->
        # exact chain; sharing nbr's level-1 would tie its degradation
        # policy to the sampler's cache, so it gets a standalone build
        return make_estimator("robust", nbr.x, nbr.kernel, seed=seed)
    if estimator == "hash":
        if nbr.level1 == "hash":
            return nbr.hash_estimator
        return make_estimator("hash", nbr.x, nbr.kernel, seed=seed)
    wants_exact = estimator in ("exact", "exact_block")
    if wants_exact == nbr.exact_blocks and estimator not in ("rs",
                                                             "grid_hbe"):
        return nbr.blocks
    return make_estimator(estimator if estimator != "exact_block" else
                          "exact", nbr.x, nbr.kernel, seed=seed)


class EdgeSampler:
    """Algorithm 4.13: vertex by degree, then neighbor by weight."""

    def __init__(self, degree_sampler, neighbor_sampler: NeighborSampler):
        self.deg = degree_sampler
        self.nbr = neighbor_sampler

    def sample(self, size: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (u, v, p) with p the realized directional probability
        p_hat(u) * q_hat(v | u)."""
        u = self.deg.sample(size)
        v, q = self.nbr.sample(u)
        return u, v, self.deg.prob(u) * q


def _categorical_rows(p: np.ndarray, rng) -> np.ndarray:
    """Sample one index per row of a nonnegative matrix (rows need not be
    normalized).  All-zero rows fall back to a uniform draw instead of
    propagating NaN through the division by the row total."""
    c = np.cumsum(p, axis=1)
    tot = c[:, -1:]
    dead = tot <= 0.0
    uniform = np.broadcast_to(
        np.arange(1, p.shape[1] + 1, dtype=np.float64)[None, :] / p.shape[1],
        c.shape)
    c = np.where(dead, uniform, c / np.where(dead, 1.0, tot))
    u = rng.uniform(size=(p.shape[0], 1))
    return (u > c).sum(axis=1).clip(0, p.shape[1] - 1)
