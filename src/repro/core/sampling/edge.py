"""Weighted neighbor / edge sampling -- Algorithms 4.11 and 4.13.

Given a vertex u, sample a neighbor v with Pr[v] ~= k(u, v) / deg(u)
(Definition 4.10) using segment KDE estimates only.

Two interchangeable factorizations of the same telescoping product
(Theorem 4.12):

* ``mode="tree"``   -- the paper's dyadic descent: at every internal node,
  query the two child-segment KDE structures and branch proportionally;
  O(log n) KDE queries per sample, error (1 +- eps')^depth.
* ``mode="blocked"``-- TPU-adapted depth-2 tree (DESIGN.md §2): one dense
  Pallas/jnp sweep yields *all* sqrt(n)-block sums at once (level-1 read),
  then the chosen block's <= sqrt(n) kernel values are computed exactly and
  sampled exactly (level-2).  Same sampling law; one level of estimation
  error instead of log n.

Both modes vectorize over a batch of source vertices (random-walk frontier).
``sample`` returns the *realized* sampling probability of each drawn
neighbor, and ``prob_of`` evaluates the probability the sampler would assign
to an arbitrary (u, v) -- both are required by the sparsifier (Alg 5.1 steps
(c)-(d)).

Theorem 4.12's exactness step (O(1/tau) rejection rounds) is implemented in
``sample_exact`` as fixed-round vectorized accept/reject.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.kde.base import ExactBlockKDE, StratifiedKDE
from repro.core.kde.multilevel import MultiLevelKDE
from repro.core.kernels_fn import Kernel


class NeighborSampler:
    def __init__(self, x: jnp.ndarray, kernel: Kernel, mode: str = "blocked",
                 block_size: Optional[int] = None, samples_per_block: int = 16,
                 exact_blocks: bool = False, tree: Optional[MultiLevelKDE] = None,
                 seed: int = 0):
        self.x = jnp.asarray(x, jnp.float32)
        self.kernel = kernel
        self.n = int(x.shape[0])
        self.mode = mode
        self._rng = np.random.default_rng(seed)
        if mode == "blocked":
            bs = block_size or max(int(np.sqrt(self.n)), 16)
            if exact_blocks:
                self._blocks = ExactBlockKDE(x, kernel, block_size=bs)
            else:
                self._blocks = StratifiedKDE(x, kernel, block_size=bs,
                                             samples_per_block=samples_per_block,
                                             seed=seed)
            self.block_size = self._blocks.block_size
            self.num_blocks = self._blocks.num_blocks
        elif mode == "tree":
            assert tree is not None, "tree mode needs a MultiLevelKDE"
            self._tree = tree
        else:
            raise ValueError(mode)

    # ------------------------------------------------------------------ #
    @property
    def evals(self) -> int:
        if self.mode == "blocked":
            return self._blocks.evals + getattr(self, "_extra_evals", 0)
        return self._tree.evals + getattr(self, "_extra_evals", 0)

    def _count(self, k: int):
        self._extra_evals = getattr(self, "_extra_evals", 0) + k

    # ------------------------------------------------------------------ #
    # blocked mode
    def _masked_block_sums(self, src: np.ndarray) -> np.ndarray:
        """Level-1: (w, B) block-sum estimates with the self-kernel removed
        from each source's own block (Alg 4.11 lines (c)/(d))."""
        q = self.x[jnp.asarray(src)]
        bs = np.array(self._blocks.block_sums(q))            # (w, B) copy
        own = src // self.block_size
        bs[np.arange(len(src)), own] = np.maximum(
            bs[np.arange(len(src)), own] - 1.0, 1e-12)       # k(x,x) = 1
        return np.maximum(bs, 1e-12)

    def _in_block_row(self, src: np.ndarray, blk: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Level-2: exact kernel row of each src against its chosen block."""
        w = len(src)
        lo = blk * self.block_size
        cols = lo[:, None] + np.arange(self.block_size)[None, :]
        valid = cols < self.n
        cols_c = np.minimum(cols, self.n - 1)
        xs = self.x[jnp.asarray(src)]                        # (w, d)
        xb = self.x[jnp.asarray(cols_c.reshape(-1))].reshape(w, self.block_size, -1)
        kv = np.asarray(_pairwise_rows(self.kernel, xs, xb))
        self._count(w * self.block_size)
        kv = kv * valid
        kv[cols_c == src[:, None]] = 0.0                     # mask self edge
        return kv, cols_c

    def sample(self, src: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Sample one neighbor per source.  Returns (neighbors, probs)."""
        src = np.asarray(src)
        if self.mode == "tree":
            return self._sample_tree(src)
        bs = self._masked_block_sums(src)                    # (w, B)
        pb = bs / bs.sum(axis=1, keepdims=True)
        blk = _categorical_rows(pb, self._rng)
        kv, cols = self._in_block_row(src, blk)
        rowsum = kv.sum(axis=1)
        pin = kv / np.maximum(rowsum, 1e-30)[:, None]
        j = _categorical_rows(pin, self._rng)
        nb = cols[np.arange(len(src)), j]
        prob = pb[np.arange(len(src)), blk] * pin[np.arange(len(src)), j]
        return nb, prob

    def prob_of(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Probability the sampler assigns to edge (src -> dst)."""
        src, dst = np.asarray(src), np.asarray(dst)
        if self.mode == "tree":
            return self._prob_of_tree(src, dst)
        bs = self._masked_block_sums(src)
        pb = bs / bs.sum(axis=1, keepdims=True)
        blk = dst // self.block_size
        kv, cols = self._in_block_row(src, blk)
        rowsum = np.maximum(kv.sum(axis=1), 1e-30)
        kd = kv[np.arange(len(src)), dst - blk * self.block_size]
        return pb[np.arange(len(src)), blk] * kd / rowsum

    # ------------------------------------------------------------------ #
    # tree mode (faithful Algorithm 4.11)
    def _sample_tree(self, src: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        out = np.zeros(len(src), np.int64)
        probs = np.ones(len(src), np.float64)
        for i, s in enumerate(src):
            lo, hi = 0, self._tree.n
            p = 1.0
            q = self.x[int(s)][None, :]
            while not self._tree.is_leaf(lo, hi):
                (l0, l1), (r0, r1) = self._tree.children(lo, hi)
                a = float(self._tree.segment_query(q, l0, l1)[0])
                b = float(self._tree.segment_query(q, r0, r1)[0])
                if l0 <= s < l1:
                    a = max(a - 1.0, 1e-12)
                if r0 <= s < r1:
                    b = max(b - 1.0, 1e-12)
                pa = a / max(a + b, 1e-30)
                if self._rng.uniform() <= pa:
                    lo, hi, p = l0, l1, p * pa
                else:
                    lo, hi, p = r0, r1, p * (1.0 - pa)
            kv = np.array(self.kernel.pairwise(q, self.x[lo:hi]))[0]
            self._count(hi - lo)
            idx = np.arange(lo, hi)
            kv[idx == s] = 0.0
            pin = kv / max(kv.sum(), 1e-30)
            j = self._rng.choice(len(pin), p=pin / pin.sum())
            out[i] = lo + j
            probs[i] = p * pin[j]
        return out, probs

    def _prob_of_tree(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        out = np.zeros(len(src), np.float64)
        for i, (s, t) in enumerate(zip(src, dst)):
            lo, hi = 0, self._tree.n
            p = 1.0
            q = self.x[int(s)][None, :]
            while not self._tree.is_leaf(lo, hi):
                (l0, l1), (r0, r1) = self._tree.children(lo, hi)
                a = float(self._tree.segment_query(q, l0, l1)[0])
                b = float(self._tree.segment_query(q, r0, r1)[0])
                if l0 <= s < l1:
                    a = max(a - 1.0, 1e-12)
                if r0 <= s < r1:
                    b = max(b - 1.0, 1e-12)
                pa = a / max(a + b, 1e-30)
                if l0 <= t < l1:
                    lo, hi, p = l0, l1, p * pa
                else:
                    lo, hi, p = r0, r1, p * (1.0 - pa)
            kv = np.array(self.kernel.pairwise(q, self.x[lo:hi]))[0]
            self._count(hi - lo)
            idx = np.arange(lo, hi)
            kv[idx == s] = 0.0
            out[i] = p * kv[t - lo] / max(kv.sum(), 1e-30)
        return out

    # ------------------------------------------------------------------ #
    def sample_exact(self, src: np.ndarray, rounds: int = 8,
                     slack: float = 2.0) -> np.ndarray:
        """Theorem 4.12 exactness: rejection-sample against exact weights.

        Proposal = this sampler; target ~ k(u, v).  Accept v with probability
        k(u,v) / (c * q(v) * Z_hat) where Z_hat estimates deg(u) and c covers
        the estimator distortion.  Vectorized fixed-round accept/reject; falls
        back to the last proposal if all rounds reject (prob (1-1/c)^rounds).
        """
        src = np.asarray(src)
        cur, _ = self.sample(src)
        if self.mode == "blocked":
            zs = self._masked_block_sums(src).sum(axis=1)
        else:
            zs = np.maximum(np.asarray(
                self._tree.segment_query(self.x[jnp.asarray(src)], 0, self._tree.n)) - 1.0, 1e-12)
        accepted = np.zeros(len(src), bool)
        for _ in range(rounds):
            cand, q = self.sample(src)
            kuv = np.asarray(self.kernel.pairwise(
                self.x[jnp.asarray(src)], self.x[jnp.asarray(cand)]))
            kuv = np.diagonal(kuv)
            self._count(len(src))
            ratio = kuv / np.maximum(slack * q * zs, 1e-30)
            acc = (~accepted) & (self._rng.uniform(size=len(src)) < np.minimum(ratio, 1.0))
            cur = np.where(acc, cand, cur)
            accepted |= acc
        return cur


class EdgeSampler:
    """Algorithm 4.13: vertex by degree, then neighbor by weight."""

    def __init__(self, degree_sampler, neighbor_sampler: NeighborSampler):
        self.deg = degree_sampler
        self.nbr = neighbor_sampler

    def sample(self, size: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (u, v, p) with p the realized directional probability
        p_hat(u) * q_hat(v | u)."""
        u = self.deg.sample(size)
        v, q = self.nbr.sample(u)
        return u, v, self.deg.prob(u) * q


def _pairwise_rows(kernel: Kernel, xs: jnp.ndarray, xb: jnp.ndarray) -> jnp.ndarray:
    """k(xs_i, xb_i_j) for batched per-row blocks: xs (w, d), xb (w, bs, d)."""
    import jax

    def one(a, b):
        return kernel.pairwise(a[None, :], b)[0]

    return jax.vmap(one)(xs, xb)


def _categorical_rows(p: np.ndarray, rng) -> np.ndarray:
    """Sample one index per row of a row-stochastic matrix."""
    c = np.cumsum(p, axis=1)
    c = c / c[:, -1:]
    u = rng.uniform(size=(p.shape[0], 1))
    return (u > c).sum(axis=1).clip(0, p.shape[1] - 1)
