"""Row-norm^2 (length-squared) sampling of the kernel matrix -- Section 5.2.

For kernels with k(x,y)^2 = k(cx, cy) (Laplacian/exponential/Gaussian), the
squared row norms of K are the degrees (+1 for the diagonal) of the kernel
graph of the *scaled* dataset cX.  n KDE queries against cX therefore give
the FKV sampling distribution p_i >= Omega(1) ||K_i||^2 / ||K||_F^2.

The sampler is device-resident end to end: the original dataset stays on
device next to the scaled one, prefix sums accumulate in float64 through the
shared ``PrefixCDF`` path (DESIGN.md §6), and the FKV sketch rows
``K_{idx,*} / sqrt(s p_i)`` are produced by ONE jitted program
(``kde_sampler.ops.kernel_rows``) instead of a chunk=16 host loop over
``kernel.pairwise``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.kde.base import KDEBase, make_estimator
from repro.core.kernels_fn import Kernel, squared_kernel_dataset
from repro.core.sampling.vertex import PrefixCDF


class RowNormSampler:
    """Section 5.2: sample row indices i ~ ||K_i,*||_2^2 / ||K||_F^2 via n
    KDE queries against the scaled dataset cX, and read the FKV sketch
    rows as one jitted program.  Cost: n KDE queries preprocessing +
    ``len(idx) * n`` evals per ``rows`` call.

    >>> s = RowNormSampler(x, gaussian(1.0)); idx = s.sample(150)
    """

    def __init__(self, x, kernel: Kernel, estimator: str = "exact",
                 seed: int = 0, mesh=None, data_axes=("data",),
                 dataset=None, **est_kw):
        # streaming attach (DESIGN.md §12): flat dense estimators only --
        # the row-norm structure lives over the SCALED padded array, which
        # is recomputed row-wise (cX) at every sync
        if dataset is not None:
            if mesh is not None:
                raise ValueError("RowNormSampler(dataset=) is single-"
                                 "device; drop mesh= or the dataset")
            if estimator not in ("exact", "exact_block", "stratified"):
                raise ValueError(
                    f"streaming row norms need a dense estimator "
                    f"(exact/exact_block/stratified), got {estimator!r}")
            x = dataset.x_pad
        self._dataset = dataset
        self._ds_epoch = int(dataset.epoch) if dataset is not None else 0
        self._est_name = estimator
        self._est_kw = dict(est_kw)
        self._seed = seed
        self.rebuilds = 0
        self.x = jnp.asarray(x, jnp.float32)   # shared device dataset
        self.x_sq = jnp.sum(self.x * self.x, axis=-1)
        self.kernel = kernel
        xs = squared_kernel_dataset(kernel, self.x)
        self._rows_engine = None
        if mesh is not None:
            # Mesh path (DESIGN.md §9): the row-norm KDE structure over cX
            # AND the sketch-row reads over X both live sharded; queries
            # and rows are collective programs, the prefix CDF stays the
            # float64 host accumulation.
            if estimator not in ("exact", "exact_block", "stratified"):
                raise ValueError(
                    f"mesh= supports exact/exact_block/stratified row-norm "
                    f"estimators, got {estimator!r}")
            from repro.core.kde.distributed import ShardedKDE
            from repro.kernels.kde_sampler.sharded import ShardedBlocks
            self._est: KDEBase = ShardedKDE(
                mesh, xs, kernel,
                exact=(estimator in ("exact", "exact_block")),
                data_axes=data_axes, seed=seed, **est_kw)
            self._rows_engine = ShardedBlocks(
                mesh, self.x, kernel, block_size=self._est.block_size,
                exact=True, data_axes=data_axes)
            self.x = self._rows_engine.x_rep[: int(xs.shape[0])]
        else:
            self._est = make_estimator(estimator, xs, kernel, seed=seed,
                                       **est_kw)
        n = int(xs.shape[0])
        self.n = n
        self.row_norms_sq = self._init_probs(xs)
        self._cdf = PrefixCDF(self.row_norms_sq, seed=seed)
        self.total = self._cdf.total          # ~= ||K||_F^2
        self._row_evals = 0
        from repro.kernels.kde_sampler.ref import static_pairwise
        self._row_cfg = dict(kind=kernel.name,
                             inv_bw=1.0 / kernel.bandwidth,
                             beta=getattr(kernel, "beta", 1.0),
                             pairwise=static_pairwise(kernel))

    def _init_probs(self, xs: jnp.ndarray) -> np.ndarray:
        """n KDE queries against cX -> squared row norms.  KDE on cX
        returns sum_j k(cx_i, cx_j) = sum_j k(x_i, x_j)^2, the squared row
        norm *including* the diagonal (k(x,x)^2 = 1) -- exactly
        ||K_i,*||_2^2; no self-subtraction.  With a streaming dataset only
        LIVE rows are queried (scaled sentinels stay query-safe as data
        columns but not as queries); dead slots get weight exactly 0."""
        probs = np.zeros(self.n, np.float64)
        batch = 1024
        if self._dataset is None:
            for lo in range(0, self.n, batch):
                hi = min(lo + batch, self.n)
                probs[lo:hi] = np.asarray(self._est.query(xs[lo:hi]))
            return np.maximum(probs, 1e-12)
        ls = np.asarray(self._dataset.live_slots())
        for lo in range(0, len(ls), batch):
            sel = ls[lo:lo + batch]
            probs[sel] = np.asarray(self._est.query(xs[jnp.asarray(sel)]))
        probs[ls] = np.maximum(probs[ls], 1e-12)
        return probs

    # ------------------------------------------------------------------ #
    # streaming contract (DESIGN.md §12)
    def _sync(self) -> None:
        """Epoch check at every public entry: rescale the coalesced
        mutation rows by the squaring constant, patch the squared row
        norms through the same ``degree_delta`` program as the degree
        path (plus the diagonal the row norms keep), and re-accumulate
        the prefix CDF; journal gaps rebuild the estimator over the
        freshly scaled padded array."""
        ds = self._dataset
        if ds is None or self._ds_epoch == int(ds.epoch):
            return
        from repro.core.dataset import coalesce_mutations
        self.x = jnp.asarray(ds.x_pad, jnp.float32)
        self.x_sq = ds.x_sq_pad
        xs = squared_kernel_dataset(self.kernel, self.x)
        xs_sq = jnp.sum(xs * xs, axis=-1)
        batches = ds.mutations_since(self._ds_epoch)
        if batches is None:
            self.n = int(xs.shape[0])
            self._est = make_estimator(self._est_name, xs, self.kernel,
                                       seed=self._seed, **self._est_kw)
            self.row_norms_sq = self._init_probs(xs)
            self.rebuilds += 1
        else:
            self._est.x = xs               # dense views rebind on mutation
            self._est.x_sq = xs_sq
            slots, old_x, new_x, old_live, new_live = \
                coalesce_mutations(batches)
            c = float(self.kernel.squaring_constant)
            from repro.kernels.kde_sampler import ops as _ops
            d, cw = _ops.degree_delta(
                jnp.asarray(self.row_norms_sq, jnp.float32), xs, xs_sq,
                jnp.asarray(slots),
                jnp.asarray(old_x, jnp.float32) * c,
                jnp.asarray(new_x, jnp.float32) * c,
                jnp.asarray(old_live), jnp.asarray(new_live),
                **self._row_cfg)
            d = np.asarray(d, np.float64)
            if hasattr(self._est, "device_counters"):
                self._est.device_counters.note(cw)
            # degree_delta recomputes mutated rows as row sum MINUS the
            # self kernel; row norms keep the diagonal (k(x,x)^2 = 1)
            sl = np.asarray(slots)
            d[sl] += np.asarray(new_live, np.float64)
            self._est.evals += 2 * len(sl) * self.n
            live = np.zeros(self.n, bool)
            live[np.asarray(ds.live_slots())] = True
            self.row_norms_sq = np.where(live, np.maximum(d, 1e-12), 0.0)
        self._cdf = PrefixCDF(self.row_norms_sq,
                              seed=self._seed + int(ds.epoch))
        self.total = self._cdf.total
        self._ds_epoch = int(ds.epoch)

    @property
    def evals(self) -> int:
        """Kernel evaluations spent on preprocessing + row reads."""
        return self._est.evals + self._row_evals

    def sample(self, size: int) -> np.ndarray:
        """Draw ``size`` iid row indices i ~ ||K_i,*||^2 (Section 5.2)."""
        self._sync()
        return self._cdf.sample(size)

    def prob(self, idx) -> np.ndarray:
        """Probability this sampler assigns to row idx."""
        self._sync()
        return self._cdf.prob(idx)

    # ------------------------------------------------------------------ #
    # batched device row evaluation (Section 5.2 post-processing)
    def rows(self, idx: np.ndarray) -> np.ndarray:
        """Exact kernel rows K_{idx,*} as one jitted device program (the
        mesh path computes them shard-local against the sharded dataset)."""
        from repro.kernels.kde_sampler import ops as sampler_ops
        self._sync()
        sel = jnp.asarray(np.ascontiguousarray(idx, np.int32))
        self._row_evals += len(idx) * self.n
        if self._rows_engine is not None:
            out, cw = self._rows_engine.kernel_rows(self.x[sel])
        else:
            out, cw = sampler_ops.kernel_rows(self.x[sel], self.x,
                                              self.x_sq, **self._row_cfg)
        if hasattr(self._est, "device_counters"):
            self._est.device_counters.note(cw)
        return np.asarray(out)

    def sketch_rows(self, idx: np.ndarray) -> np.ndarray:
        """The FKV sketch S: rows K_{idx,*} rescaled by 1/sqrt(s p_i)."""
        scale = 1.0 / np.sqrt(np.maximum(len(idx) * self.prob(idx), 1e-30))
        return self.rows(idx) * scale[:, None]
