"""Row-norm^2 (length-squared) sampling of the kernel matrix -- Section 5.2.

For kernels with k(x,y)^2 = k(cx, cy) (Laplacian/exponential/Gaussian), the
squared row norms of K are the degrees (+1 for the diagonal) of the kernel
graph of the *scaled* dataset cX.  n KDE queries against cX therefore give
the FKV sampling distribution p_i >= Omega(1) ||K_i||^2 / ||K||_F^2.
"""
from __future__ import annotations

import numpy as np

from repro.core.kde.base import KDEBase, make_estimator
from repro.core.kernels_fn import Kernel, squared_kernel_dataset


class RowNormSampler:
    def __init__(self, x, kernel: Kernel, estimator: str = "exact",
                 seed: int = 0, **est_kw):
        xs = squared_kernel_dataset(kernel, x)
        self._est: KDEBase = make_estimator(estimator, xs, kernel, seed=seed,
                                            **est_kw)
        n = xs.shape[0]
        # KDE on cX returns sum_j k(cx_i, cx_j) = sum_j k(x_i, x_j)^2, the
        # squared row norm *including* the diagonal (k(x,x)^2 = 1) -- which is
        # exactly ||K_i,*||_2^2; no self-subtraction here.
        probs = np.zeros(n, np.float32)
        batch = 1024
        for lo in range(0, n, batch):
            hi = min(lo + batch, n)
            probs[lo:hi] = np.asarray(self._est.query(xs[lo:hi]))
        self.row_norms_sq = np.maximum(probs, 1e-12)
        self._prefix = np.cumsum(self.row_norms_sq)
        self.total = float(self._prefix[-1])  # ~= ||K||_F^2
        self._rng = np.random.default_rng(seed)

    @property
    def evals(self) -> int:
        return self._est.evals

    def sample(self, size: int) -> np.ndarray:
        u = self._rng.uniform(0.0, self.total, size=size)
        return np.searchsorted(self._prefix, u, side="right").clip(
            0, len(self.row_norms_sq) - 1)

    def prob(self, idx) -> np.ndarray:
        return self.row_norms_sq[idx] / self.total
