"""Row-norm^2 (length-squared) sampling of the kernel matrix -- Section 5.2.

For kernels with k(x,y)^2 = k(cx, cy) (Laplacian/exponential/Gaussian), the
squared row norms of K are the degrees (+1 for the diagonal) of the kernel
graph of the *scaled* dataset cX.  n KDE queries against cX therefore give
the FKV sampling distribution p_i >= Omega(1) ||K_i||^2 / ||K||_F^2.

The sampler is device-resident end to end: the original dataset stays on
device next to the scaled one, prefix sums accumulate in float64 through the
shared ``PrefixCDF`` path (DESIGN.md §6), and the FKV sketch rows
``K_{idx,*} / sqrt(s p_i)`` are produced by ONE jitted program
(``kde_sampler.ops.kernel_rows``) instead of a chunk=16 host loop over
``kernel.pairwise``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.kde.base import KDEBase, make_estimator
from repro.core.kernels_fn import Kernel, squared_kernel_dataset
from repro.core.sampling.vertex import PrefixCDF


class RowNormSampler:
    """Section 5.2: sample row indices i ~ ||K_i,*||_2^2 / ||K||_F^2 via n
    KDE queries against the scaled dataset cX, and read the FKV sketch
    rows as one jitted program.  Cost: n KDE queries preprocessing +
    ``len(idx) * n`` evals per ``rows`` call.

    >>> s = RowNormSampler(x, gaussian(1.0)); idx = s.sample(150)
    """

    def __init__(self, x, kernel: Kernel, estimator: str = "exact",
                 seed: int = 0, mesh=None, data_axes=("data",), **est_kw):
        self.x = jnp.asarray(x, jnp.float32)   # shared device dataset
        self.x_sq = jnp.sum(self.x * self.x, axis=-1)
        self.kernel = kernel
        xs = squared_kernel_dataset(kernel, self.x)
        self._rows_engine = None
        if mesh is not None:
            # Mesh path (DESIGN.md §9): the row-norm KDE structure over cX
            # AND the sketch-row reads over X both live sharded; queries
            # and rows are collective programs, the prefix CDF stays the
            # float64 host accumulation.
            if estimator not in ("exact", "exact_block", "stratified"):
                raise ValueError(
                    f"mesh= supports exact/exact_block/stratified row-norm "
                    f"estimators, got {estimator!r}")
            from repro.core.kde.distributed import ShardedKDE
            from repro.kernels.kde_sampler.sharded import ShardedBlocks
            self._est: KDEBase = ShardedKDE(
                mesh, xs, kernel,
                exact=(estimator in ("exact", "exact_block")),
                data_axes=data_axes, seed=seed, **est_kw)
            self._rows_engine = ShardedBlocks(
                mesh, self.x, kernel, block_size=self._est.block_size,
                exact=True, data_axes=data_axes)
            self.x = self._rows_engine.x_rep[: int(xs.shape[0])]
        else:
            self._est = make_estimator(estimator, xs, kernel, seed=seed,
                                       **est_kw)
        n = int(xs.shape[0])
        self.n = n
        # KDE on cX returns sum_j k(cx_i, cx_j) = sum_j k(x_i, x_j)^2, the
        # squared row norm *including* the diagonal (k(x,x)^2 = 1) -- which is
        # exactly ||K_i,*||_2^2; no self-subtraction here.
        probs = np.zeros(n, np.float64)
        batch = 1024
        for lo in range(0, n, batch):
            hi = min(lo + batch, n)
            probs[lo:hi] = np.asarray(self._est.query(xs[lo:hi]))
        self.row_norms_sq = np.maximum(probs, 1e-12)
        self._cdf = PrefixCDF(self.row_norms_sq, seed=seed)
        self.total = self._cdf.total          # ~= ||K||_F^2
        self._row_evals = 0
        from repro.kernels.kde_sampler.ref import static_pairwise
        self._row_cfg = dict(kind=kernel.name,
                             inv_bw=1.0 / kernel.bandwidth,
                             beta=getattr(kernel, "beta", 1.0),
                             pairwise=static_pairwise(kernel))

    @property
    def evals(self) -> int:
        """Kernel evaluations spent on preprocessing + row reads."""
        return self._est.evals + self._row_evals

    def sample(self, size: int) -> np.ndarray:
        """Draw ``size`` iid row indices i ~ ||K_i,*||^2 (Section 5.2)."""
        return self._cdf.sample(size)

    def prob(self, idx) -> np.ndarray:
        """Probability this sampler assigns to row idx."""
        return self._cdf.prob(idx)

    # ------------------------------------------------------------------ #
    # batched device row evaluation (Section 5.2 post-processing)
    def rows(self, idx: np.ndarray) -> np.ndarray:
        """Exact kernel rows K_{idx,*} as one jitted device program (the
        mesh path computes them shard-local against the sharded dataset)."""
        from repro.kernels.kde_sampler import ops as sampler_ops
        sel = jnp.asarray(np.ascontiguousarray(idx, np.int32))
        self._row_evals += len(idx) * self.n
        if self._rows_engine is not None:
            return np.asarray(self._rows_engine.kernel_rows(self.x[sel]))
        out = sampler_ops.kernel_rows(self.x[sel], self.x, self.x_sq,
                                      **self._row_cfg)
        return np.asarray(out)

    def sketch_rows(self, idx: np.ndarray) -> np.ndarray:
        """The FKV sketch S: rows K_{idx,*} rescaled by 1/sqrt(s p_i)."""
        scale = 1.0 / np.sqrt(np.maximum(len(idx) * self.prob(idx), 1e-30))
        return self.rows(idx) * scale[:, None]
