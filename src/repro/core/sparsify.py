"""Spectral sparsification of the kernel graph -- Algorithm 5.1 / Theorem 5.3.

Length-squared sampling of the edge-vertex incidence matrix H
(||H_{uv}||^2 = 2 k(u,v)) approximates leverage-score sampling up to the
condition number kappa(H)^2 <= 32/tau^3 (Lemma 5.6), so
t = O(n log n / (eps^2 tau^3)) sampled edges give a (1 +- eps) spectral
sparsifier (Lemma 5.5).

Per Algorithm 5.1 we do NOT use the perfect edge sampler -- we sample
u ~ p_hat (degrees), v ~ q_hat(.|u) (neighbor sampler), and reweight each
drawn edge by 1 / (t * (p_u q_uv + p_v q_vu)), querying the samplers for the
exact probabilities they used.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import Kernel
from repro.core.sampling.edge import NeighborSampler, shared_level1_estimator
from repro.core.sampling.vertex import DegreeSampler


@dataclasses.dataclass
class SparseGraph:
    """Fixed-size COO edge list (undirected; i < j not enforced)."""
    n: int
    src: np.ndarray       # (m,) int64
    dst: np.ndarray       # (m,) int64
    weight: np.ndarray    # (m,) float64
    kde_queries: int = 0
    kernel_evals: int = 0

    @property
    def num_edges(self) -> int:
        """Number of (possibly repeated) sampled edges."""
        return len(self.src)

    def laplacian_dense(self) -> np.ndarray:
        """Dense Laplacian (evaluation only)."""
        a = np.zeros((self.n, self.n))
        np.add.at(a, (self.src, self.dst), self.weight)
        np.add.at(a, (self.dst, self.src), self.weight)
        d = a.sum(axis=1)
        return np.diag(d) - a

    def adjacency_dense(self) -> np.ndarray:
        """Dense symmetric adjacency (evaluation only)."""
        a = np.zeros((self.n, self.n))
        np.add.at(a, (self.src, self.dst), self.weight)
        np.add.at(a, (self.dst, self.src), self.weight)
        return a

    def matvec(self, v: np.ndarray) -> np.ndarray:
        """L v without materializing L."""
        av = np.zeros_like(v)
        wsrc = self.weight * v[self.dst]
        wdst = self.weight * v[self.src]
        np.add.at(av, self.src, wsrc)
        np.add.at(av, self.dst, wdst)
        deg = np.zeros_like(v)
        np.add.at(deg, self.src, self.weight)
        np.add.at(deg, self.dst, self.weight)
        return deg * v - av


def spectral_sparsify(x, kernel: Kernel, num_edges: int,
                      estimator: str = "stratified", seed: int = 0,
                      batch: int = 1024, exact_blocks: bool = False,
                      samples_per_block: int = 16,
                      mesh=None) -> SparseGraph:
    """Algorithm 5.1 with edge budget ``num_edges`` (= t).

    Fully fused (DESIGN.md §6): ONE device dataset + level-1 structure is
    shared between degree preprocessing and the neighbor sampler, the
    degree CDF lives on device (float64-accumulated prefix, rounded to
    f32), and all edge batches -- steps (a)-(d) including the reverse
    probability q_vu and the reweighting -- run as one ``lax.scan``
    program with a single device->host transfer of the edge list.  With
    ``mesh=`` the same program runs sharded (DESIGN.md §9): the level-1
    state is mesh-resident and each edge batch performs one psum.

    With ``estimator="hash"`` BOTH the Algorithm 4.3 degree preprocessing
    and the per-edge level-1 reads run on the sub-linear hashed estimator
    (one shared bucket layout, DESIGN.md §10): total kernel evals drop
    from O((n + t) B s) to O((n + t)(max_bucket + num_far)).  On the
    ``mesh=`` path the hashed hybrid covers degrees only (the collective
    draws stay on the §9 blocked engine).
    """
    n = int(x.shape[0])
    t = int(num_edges)
    nbr = NeighborSampler(x, kernel, mode="blocked", seed=seed + 2,
                          exact_blocks=exact_blocks,
                          samples_per_block=samples_per_block, mesh=mesh,
                          level1="hash" if estimator == "hash"
                          and mesh is None else "blocked")
    # Degree preprocessing (Algorithm 4.3) against the sampler's own
    # level-1 structure whenever it implements the requested estimator --
    # one KDE build and one preprocessing sweep over x, not two.  The
    # sampler's structure is exact (ExactBlockKDE) iff exact_blocks.
    est = shared_level1_estimator(nbr, estimator, seed=seed)
    deg = DegreeSampler(est, seed=seed + 1,
                        mesh=mesh if est is nbr.blocks else None)
    u, v, w, _, _ = nbr.edge_batches(deg.cdf_device, deg.degrees_device,
                                     deg.total, t, batch=batch)
    g = SparseGraph(n, np.asarray(u, np.int64), np.asarray(v, np.int64),
                    np.asarray(w, np.float64))
    g.kernel_evals = nbr.evals + (0 if est is nbr.blocks else est.evals)
    # degree preprocessing + one forward level-1 read per drawn edge (the
    # reverse probability collapses onto the preprocessed degrees)
    drawn = ((t + batch - 1) // batch) * batch
    g.kde_queries = n + drawn
    return g


def resparsify(g: SparseGraph, num_edges: int, seed: int = 0) -> SparseGraph:
    """Second-stage size reduction (the paper invokes Lee-Sun to reach
    O(n/eps^2) edges; we re-apply length-squared sampling on the explicit
    graph, which needs no KDE queries -- same role, simpler machinery)."""
    rng = np.random.default_rng(seed)
    p = g.weight / g.weight.sum()
    idx = rng.choice(g.num_edges, size=num_edges, p=p, replace=True)
    w = g.weight[idx] / (num_edges * p[idx])
    return SparseGraph(g.n, g.src[idx], g.dst[idx], w,
                       kde_queries=g.kde_queries, kernel_evals=g.kernel_evals)


def incidence_row_norms(kernel: Kernel, x) -> np.ndarray:
    """||H_{uv}||^2 = 2 k(u, v) -- test helper for Lemma 5.6 invariants."""
    k = np.asarray(kernel.matrix(jnp.asarray(x)))
    iu = np.triu_indices(k.shape[0], 1)
    return 2.0 * k[iu]
