"""Multi-tenant batched serving layer for the kernel-graph primitives
(DESIGN.md §13).

The paper's value proposition is answering many KDE / sampling queries
cheaply after one sub-quadratic preprocessing pass -- exactly the shape of
a serving workload.  :class:`KernelGraphServable` is the saxml-style
servable on top of the fused engines: callers :meth:`~KernelGraphServable.
submit` ``query`` / ``sample`` / ``walk`` / ``prob_of`` requests against
named tenants (each one ``DynamicDataset`` + estimator state), and every
:meth:`~KernelGraphServable.tick` drains the queue into as few padded
device batches as the static shapes allow:

* **continuous batching** -- concurrent requests are grouped by
  ``(op, tenant signature, shape bucket)`` and run as ONE program via the
  ``batched_*`` entry points of ``kernels/kde_sampler`` / ``kde_hash``
  (``jax.vmap`` over the request axis), with per-request PRNG keys and
  per-request uint32 status words.  Request widths are padded up to a
  static bucket (powers of two by default), so the number of compiled
  programs is bounded by ``len(buckets)`` per (tenant signature, op) --
  not by the workload's request shapes.
* **tenant lifecycle** -- tenants' level-1 block structures and hash
  states are admitted on first use and evicted least-recently-used when
  more than ``max_resident`` tenants hold device state; the backing
  ``DynamicDataset`` (source of truth) always stays, so a re-admitted
  tenant simply rebuilds its derived state.  Mutating a tenant's dataset
  between ticks is safe: admission syncs through the ``(dataset_id,
  epoch)`` contract, and requests whose frontier rows died get a
  per-request ``EPOCH_STALE`` error without poisoning the rest of the
  batch.
* **guard semantics** -- the per-request status words flow through
  ``guards.raise_per_request``: under ``REPRO_CHECKS=1`` a flagged
  request carries its own ``EstimationError`` in ``Request.error`` while
  the other lanes of the tick complete normally.
* **mesh tenants** -- a tenant built with ``mesh=`` serves draws through
  its sharded engine: same-op requests are concatenated into one draw
  batch, preserving the one-psum-per-draw-batch schedule of DESIGN.md §9
  (the batching layer adds zero extra collectives, asserted in
  ``tests/test_serving.py``).

Distributional contract (``tests/test_serving.py``): a served request is
the SAME computation as the sequential single-tenant call with the same
key -- bitwise for keyed walks and draws when the request width equals
its shape bucket, and distribution-identical (each padded lane still
consumes iid uniforms) otherwise.  Mesh ``sample``/``prob_of`` groups
fold every co-batched request's seed into one key stream (see
:meth:`KernelGraphServable.submit`).  :meth:`~KernelGraphServable.tick`
itself never raises: admission, grouping, and each group's program are
fault-isolated, attaching failures to exactly the affected requests.

>>> srv = KernelGraphServable(max_resident=2)
>>> srv.add_tenant("a", xa, gaussian(1.0))
>>> r = srv.submit("a", "sample", src=np.arange(8), seed=0)
>>> srv.tick(); nb, prob = r.result
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import Counter, OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataset import DynamicDataset
from repro.core.kernels_fn import Kernel
from repro.core.sampling.edge import _BENIGN, NeighborSampler
from repro.ft import guards as _g
from repro.obs import counters as _c
from repro.obs import metrics as _m

#: ops a request may name, and the payload key(s) each one takes
REQUEST_OPS = ("query", "sample", "walk", "prob_of")

#: default request-width buckets (powers of two); a request of width w is
#: padded to the smallest bucket >= w, bounding compiles per group
DEFAULT_BUCKETS = (4, 8, 16, 32, 64, 128, 256)


def shape_bucket(w: int, buckets=DEFAULT_BUCKETS) -> int:
    """Smallest static bucket >= ``w`` (next power of two past the table).
    Padding to buckets is what bounds recompiles: every compiled program
    is keyed by its padded shapes, so the program count per (tenant
    signature, op) is at most ``len(buckets)`` plus the overflow tail."""
    for b in buckets:
        if w <= b:
            return b
    p = 1
    while p < w:
        p <<= 1
    return p


_HOST_KEYS: Optional[str] = None


def _host_key_layout() -> str:
    """Probed-once layout of ``jax.random.PRNGKey(s)`` for the default
    threefry2x32 impl: ``"x64"`` -> ``[s >> 32, s & 0xffffffff]``,
    ``"x32"`` -> ``[0, s & 0xffffffff]`` (seeds truncated to 32 bits when
    ``jax_enable_x64`` is off), ``"opaque"`` -> unknown (custom PRNG)."""
    global _HOST_KEYS
    if _HOST_KEYS is None:
        probe = np.asarray(jax.random.PRNGKey((11 << 32) | 13))
        if probe.dtype != np.uint32 or probe.shape != (2,):
            _HOST_KEYS = "opaque"
        elif probe[0] == 11 and probe[1] == 13:
            _HOST_KEYS = "x64"
        elif probe[0] == 0 and probe[1] == 13:
            _HOST_KEYS = "x32"
        else:                                          # pragma: no cover
            _HOST_KEYS = "opaque"
    return _HOST_KEYS


def _batch_keys(seeds):
    """Per-request PRNG keys, stacked into one ``(R, 2)`` uint32 array.

    Seeding is on the per-tick critical path: ``jax.random.PRNGKey`` is a
    jitted program per call, so R requests would pay R dispatches before
    the batch even runs.  With the default threefry layout the keys are
    assembled in numpy (the jitted batch entry point transfers them with
    the rest of its arguments) -- bitwise identical to stacking
    ``PRNGKey(seed)`` per request (asserted in ``tests/test_serving.py``
    parity tests, which compare served draws against sequential calls
    keyed by ``PRNGKey``)."""
    layout = _host_key_layout()
    if layout != "opaque" and all(0 <= s < (1 << 31) for s in seeds):
        a = np.asarray(seeds, np.uint64)
        hi = ((a >> np.uint64(32)) if layout == "x64"
              else np.zeros_like(a)).astype(np.uint32)
        return np.stack(
            [hi, (a & np.uint64(0xFFFFFFFF)).astype(np.uint32)], axis=-1)
    return jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])


@functools.partial(jax.jit, static_argnames=("num",))
def _split_batch(keys: jax.Array, num: int) -> jax.Array:
    """``jax.random.split`` every key in a ``(R, 2)`` stack into ``num``
    subkeys as ONE program -- same bits as R sequential split calls."""
    return jax.vmap(lambda k: jax.random.split(k, num))(keys)


@dataclasses.dataclass
class Request:
    """One submitted serving request and, after its tick, its outcome.

    ``result`` mirrors the sequential API: ``sample`` -> (neighbors,
    probs); ``walk`` -> (endpoints, path-or-None); ``prob_of`` -> probs;
    ``query`` -> estimates.  ``status`` is the request's own uint32 flag
    word; ``error`` is the per-request ``EstimationError`` under
    ``REPRO_CHECKS=1`` (the tick itself never raises)."""

    tenant: str
    op: str
    payload: dict
    seed: int
    rid: int
    submitted: float
    status: int = 0
    result: object = None
    error: Optional[Exception] = None
    finished: Optional[float] = None

    @property
    def done(self) -> bool:
        """True once a tick produced a result or an error."""
        return self.finished is not None

    @property
    def latency(self) -> float:
        """Submit -> completion wall time in seconds (nan until done)."""
        return (self.finished - self.submitted) if self.done else float("nan")


class ServedTenant:
    """One tenant: a mutable ``DynamicDataset`` plus lazily-admitted
    estimator state (``NeighborSampler`` level-1 cache / hash layout).

    ``admit()`` builds or syncs the device state; ``release()`` drops it
    (LRU eviction) -- the dataset is the source of truth, so eviction
    never loses data, it only trades the rebuild cost back in."""

    def __init__(self, name: str, dataset: DynamicDataset, kernel: Kernel,
                 seed: int, opts: dict):
        self.name = name
        self.dataset = dataset
        self.kernel = kernel
        self.seed = int(seed)
        self.opts = dict(opts)
        self.nbr: Optional[NeighborSampler] = None
        self.builds = 0

    @property
    def resident(self) -> bool:
        """True while the tenant's derived device state is admitted."""
        return self.nbr is not None

    @property
    def mesh(self):
        """The tenant's mesh (None for flat single-device tenants)."""
        return self.opts.get("mesh")

    def admit(self) -> NeighborSampler:
        """Build (first use / after eviction) or epoch-sync the sampler."""
        if self.nbr is None:
            self.nbr = NeighborSampler(
                self.dataset.x_pad, self.kernel, dataset=self.dataset,
                seed=self.seed, **self.opts)
            self.builds += 1
        else:
            self.nbr._sync()
        return self.nbr

    def release(self) -> None:
        """Drop the derived device state (level-1 cache, hash layout)."""
        self.nbr = None

    # ------------------------------------------------------------------ #
    def _state_sig(self):
        """Hashable shape signature of the hash state (None when absent);
        part of the group key so only stack-compatible tenants batch."""
        hs = self.nbr._hstate
        if hs is None:
            return None
        return tuple((tuple(a.shape), str(a.dtype))
                     for a in jax.tree_util.tree_leaves(hs))

    def draw_sig(self):
        """Static signature of the tenant's draw programs: equal
        signatures => the stacked arena traces ONE program for the
        whole group.  Includes the padded dataset shape (not just the
        ``n`` config key): tenants must agree on the feature dimension
        ``d`` too, or the arena's ``jnp.stack`` would reject them."""
        c = self.nbr._cfg
        return (tuple(sorted(c.items())) + (tuple(self.nbr.x.shape),)
                + (self._state_sig(),))

    def query_sig(self):
        """Static signature of the tenant's query program (the dense
        level-1 read, or the hashed estimator's config + layout shapes);
        both carry the padded dataset shape so only stack-compatible
        tenants (same ``n_pad`` AND ``d``) share a group."""
        nbr = self.nbr
        if nbr.level1 == "hash":
            hq = nbr.hash_estimator
            return ("hash-query", tuple(sorted(hq._cfg.items())),
                    tuple(nbr.x.shape), self._state_sig())
        keys = ("kind", "inv_bw", "beta", "pairwise", "block_size",
                "num_blocks", "n", "s", "exact")
        return ("dense-query", tuple((k, nbr._cfg[k]) for k in keys),
                tuple(nbr.x.shape))


def _pad_idx(a, wb: int) -> np.ndarray:
    """Pad a 1-d index payload to its bucket by repeating the first
    element -- padded lanes sample from a real live row (no spurious
    flags) and are sliced off before the result is returned."""
    a = np.ascontiguousarray(np.asarray(a).reshape(-1), np.int32)
    if len(a) == wb:
        return a
    fill = a[0] if len(a) else np.int32(0)
    return np.concatenate([a, np.full(wb - len(a), fill, np.int32)])


def _pad_pts(y, qb: int) -> np.ndarray:
    """Pad a (q, d) query-point payload to its bucket with row 0."""
    y = np.ascontiguousarray(np.asarray(y, np.float32))
    if y.ndim == 1:
        y = y[None, :]
    if len(y) == qb:
        return y
    fill = y[:1] if len(y) else np.zeros((1, y.shape[1]), np.float32)
    return np.concatenate([y, np.repeat(fill, qb - len(y), axis=0)])


class KernelGraphServable:
    """Batched multi-tenant front end over the kernel-graph engines.

    Lifecycle: :meth:`add_tenant` registers datasets; :meth:`submit`
    enqueues requests (non-blocking); :meth:`tick` drains the queue into
    padded batch groups, runs each group as one device program, and
    scatters per-request results / status words / errors back onto the
    :class:`Request` objects.  Cost per tick: one ``batched_*`` program
    per (tenant signature, op, bucket) group -- compiled once per group
    shape and cached by jit thereafter -- plus O(R) host bookkeeping.

    ``max_resident`` bounds how many tenants hold derived device state
    (level-1 blocks + hash layouts) at once; the LRU policy evicts idle
    tenants first and never evicts a tenant needed by the current tick
    (the resident set may transiently overshoot if one tick touches more
    than ``max_resident`` tenants).
    """

    def __init__(self, max_resident: int = 4, buckets=DEFAULT_BUCKETS,
                 arena_cache: int = 16):
        self.max_resident = int(max_resident)
        self.buckets = tuple(buckets)
        self._tenants: dict = {}
        self._lru: OrderedDict = OrderedDict()
        self._queue: list = []
        self._arenas: OrderedDict = OrderedDict()
        self._arena_cap = int(arena_cache)
        self._rid = 0
        self.ticks = 0
        self.admissions = 0
        self.evictions = 0
        self.served = 0
        self.failed = 0
        self.status = 0
        self.flag_counts: Counter = Counter()
        # realized device totals folded from every served group's counter
        # words (DESIGN.md §15.1) -- the serving-side eval budget ledger
        self.device_counters = _c.HostTotals()

    # ------------------------------------------------------------------ #
    # tenant lifecycle
    def add_tenant(self, name: str, x, kernel: Kernel, *,
                   capacity: Optional[int] = None, level1: str = "blocked",
                   block_size: Optional[int] = None,
                   samples_per_block: int = 16, exact_blocks: bool = False,
                   hash_opts: Optional[dict] = None, mesh=None,
                   data_axes=("data",), seed: int = 0) -> ServedTenant:
        """Register a tenant: wraps ``x`` in a ``DynamicDataset`` (so the
        caller can mutate it between ticks) and records the estimator
        configuration; device state is built lazily at first admission."""
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        ds = DynamicDataset(x, capacity=capacity)
        opts = dict(level1=level1, block_size=block_size,
                    samples_per_block=samples_per_block,
                    exact_blocks=exact_blocks, hash_opts=hash_opts,
                    mesh=mesh, data_axes=data_axes)
        t = ServedTenant(name, ds, kernel, seed, opts)
        self._tenants[name] = t
        return t

    def dataset(self, name: str) -> DynamicDataset:
        """The tenant's mutable dataset (insert/delete/update between
        ticks; consumers re-sync through the epoch contract)."""
        return self._tenants[name].dataset

    def tenant(self, name: str) -> ServedTenant:
        """The registered :class:`ServedTenant` handle."""
        return self._tenants[name]

    def _admit(self, name: str, needed) -> None:
        """LRU-touch ``name`` (building its state if evicted) and evict
        the least-recently-used tenants beyond ``max_resident`` -- but
        never one the current tick needs."""
        t = self._tenants[name]
        was = t.resident
        t.admit()
        if not was:
            self.admissions += 1
        self._lru[name] = True
        self._lru.move_to_end(name)
        while len(self._lru) > self.max_resident:
            victim = next((c for c in self._lru if c not in needed), None)
            if victim is None:
                break
            self._lru.pop(victim)
            self._tenants[victim].release()
            self.evictions += 1

    # ------------------------------------------------------------------ #
    # request intake
    def submit(self, tenant: str, op: str, *, seed: Optional[int] = None,
               **payload) -> Request:
        """Enqueue one request; returns its :class:`Request` handle (the
        next :meth:`tick` fills ``result`` / ``status`` / ``error``).
        ``seed`` pins the request's PRNG key -- equal seeds on equal
        payloads reproduce draws bitwise; default is a running counter.
        One caveat: a MESH tenant's ``sample``/``prob_of`` requests that
        land in the same tick concatenate into one draw batch whose key
        stream folds in every co-batched request's seed, so bitwise
        reproducibility there additionally requires the same co-batch
        composition (a request served alone always reproduces)."""
        if tenant not in self._tenants:
            raise KeyError(f"unknown tenant {tenant!r}")
        if op not in REQUEST_OPS:
            raise ValueError(f"unknown op {op!r}; expected {REQUEST_OPS}")
        if op == "prob_of":
            ns = np.asarray(payload["src"]).reshape(-1).shape[0]
            nd = np.asarray(payload["dst"]).reshape(-1).shape[0]
            if ns != nd:
                raise ValueError(
                    f"prob_of src/dst widths differ ({ns} != {nd}): "
                    "q(dst | src) pairs one destination per source row")
        self._rid += 1
        r = Request(tenant=tenant, op=op, payload=dict(payload),
                    seed=int(self._rid * 7919 if seed is None else seed),
                    rid=self._rid, submitted=time.perf_counter())
        self._queue.append(r)
        return r

    def pending(self) -> int:
        """Requests waiting for the next tick."""
        return len(self._queue)

    # ------------------------------------------------------------------ #
    # the serving tick
    def tick(self) -> dict:
        """Drain the queue into padded batch groups and serve each group
        as one device program.  Returns tick stats (requests, groups,
        stale, admissions/evictions deltas, wall time)."""
        reqs, self._queue = self._queue, []
        t0 = time.perf_counter()
        adm0, ev0 = self.admissions, self.evictions
        evals0 = self.device_counters["evals"]
        stats = dict(requests=len(reqs), groups=0, served=0, failed=0,
                     stale=0)
        if not reqs:
            stats.update(admissions=0, evictions=0, tick_ms=0.0,
                         realized_evals=0)
            return stats
        needed = {r.tenant for r in reqs}
        admit_errors: dict = {}
        for name in sorted(needed):
            try:
                self._admit(name, needed)
            except Exception as e:     # noqa: BLE001 -- per-tenant isolation
                admit_errors[name] = e
        groups: dict = {}
        for r in reqs:
            if r.tenant in admit_errors:
                self._fail(r, admit_errors[r.tenant])
                continue
            t = self._tenants[r.tenant]
            try:
                if not self._gate_stale(r, t, stats):
                    continue
                gkey = self._group_key(r, t)
            except Exception as e:     # noqa: BLE001 -- bad payload
                self._fail(r, e)
                continue
            groups.setdefault(gkey, []).append(r)
        for key, grp in groups.items():
            # per-group fault isolation: one group blowing up (bad payload
            # dims, engine failure) fails ITS requests only -- the other
            # groups of the tick still serve ("never poisons a batch")
            try:
                if key[0] == "mesh":
                    self._serve_mesh_group(key, grp)
                else:
                    self._serve_flat_group(key, grp)
            except Exception as e:     # noqa: BLE001 -- per-group isolation
                for r in grp:
                    if r.finished is None:
                        self._fail(r, e)
            stats["groups"] += 1
        for r in reqs:
            if r.finished is None:       # defensive: mark unserved as failed
                r.error = r.error or RuntimeError("request not served")
                r.finished = time.perf_counter()
            if r.error is None:
                stats["served"] += 1
            else:
                stats["failed"] += 1
        self.served += stats["served"]
        self.failed += stats["failed"]
        self.ticks += 1
        stats.update(admissions=self.admissions - adm0,
                     evictions=self.evictions - ev0,
                     tick_ms=1e3 * (time.perf_counter() - t0),
                     realized_evals=self.device_counters["evals"] - evals0)
        if _m.enabled():
            self._record_metrics(reqs, stats)
        return stats

    def _record_metrics(self, reqs, stats) -> None:
        """Per-tenant / per-op latency histograms plus tick counters into
        the obs registry (DESIGN.md §15.3); called only while the registry
        is enabled, so the disabled-mode tick cost is one branch."""
        for r in reqs:
            if r.finished is not None:
                _m.observe(f"serve.latency.{r.tenant}.{r.op}.us",
                           (r.finished - r.submitted) * 1e6)
        for k in ("served", "failed", "stale", "admissions", "evictions",
                  "realized_evals"):
            _m.counter_inc(f"serve.{k}", stats[k])
        _m.observe("serve.tick.us", stats["tick_ms"] * 1e3)
        _m.gauge_set("serve.resident", float(len(self._lru)))

    # ------------------------------------------------------------------ #
    @staticmethod
    def _fail(r: Request, e: Exception) -> None:
        """Finish ``r`` with ``e`` -- the tick itself never raises."""
        r.error = e
        r.finished = time.perf_counter()

    def _frontier_rows(self, r: Request) -> Optional[np.ndarray]:
        """Dataset rows the request dereferences (None for point queries)."""
        if r.op == "sample":
            return np.asarray(r.payload["src"])
        if r.op == "walk":
            return np.asarray(r.payload["starts"])
        if r.op == "prob_of":
            return np.concatenate([np.asarray(r.payload["src"]),
                                   np.asarray(r.payload["dst"])])
        return None

    def _gate_stale(self, r: Request, t: ServedTenant, stats: dict) -> bool:
        """Per-request liveness gate (the serving twin of
        ``NeighborSampler._check_frontier``): a frontier referencing dead
        slots gets ``EPOCH_STALE`` on ITS status word only.  Under
        ``REPRO_CHECKS=1`` the request errors out and skips the batch;
        otherwise the flag is advisory and the request is still served
        (dead slots carry exactly zero kernel mass)."""
        rows = self._frontier_rows(r)
        if rows is None or bool(np.all(t.dataset.is_live(rows))):
            return True
        r.status |= _g.EPOCH_STALE
        stats["stale"] += 1
        self.status |= _g.EPOCH_STALE
        self.flag_counts["EPOCH_STALE"] += 1
        if _g.checks_enabled():
            r.error = _g.EstimationError(
                f"serve:{r.op}:{r.tenant}: status flags ['EPOCH_STALE'] "
                f"(frontier references dead slots at epoch "
                f"{int(t.dataset.epoch)})")
            r.finished = time.perf_counter()
            return False
        return True

    def _group_key(self, r: Request, t: ServedTenant):
        """The static batch-group key: requests sharing a key run as one
        padded program (tenant signature + op + shape bucket)."""
        if t.mesh is not None:
            extra = (int(r.payload["length"]),) if r.op == "walk" else ()
            return ("mesh", r.tenant, r.op) + extra
        if r.op == "query":
            qb = shape_bucket(len(np.atleast_2d(r.payload["y"])),
                              self.buckets)
            return ("flat", "query", qb, t.query_sig())
        wb = shape_bucket(len(self._frontier_rows(r)) // (2 if r.op ==
                          "prob_of" else 1), self.buckets)
        extra = (int(r.payload["length"]),) if r.op == "walk" else ()
        return ("flat", r.op, wb) + extra + (t.draw_sig(),)

    # ------------------------------------------------------------------ #
    def _arena(self, tenants):
        """Stacked device arena for a group's tenants, cached by
        ``(name, epoch)`` pairs -- the serving face of the
        ``(dataset_id, epoch)`` invalidation contract."""
        key = tuple((t.name, int(t.dataset.epoch)) for t in tenants)
        hit = self._arenas.get(key)
        if hit is not None:
            self._arenas.move_to_end(key)
            return hit
        xa = jnp.stack([t.nbr.x for t in tenants])
        xa_sq = jnp.stack([t.nbr.x_sq for t in tenants])
        hstate = None
        if tenants[0].nbr._hstate is not None:
            # one stack serves draws AND hashed queries: the sampler's
            # _hstate IS hash_estimator.state (one bucket layout per
            # tenant), so the arena entry is reused by both paths
            from repro.kernels.kde_hash.ops import stack_hash_states
            hstate = stack_hash_states([t.nbr._hstate for t in tenants])
        self._arenas[key] = (xa, xa_sq, hstate)
        while len(self._arenas) > self._arena_cap:
            self._arenas.popitem(last=False)
        return xa, xa_sq, hstate

    def _scatter(self, grp, results, statuses):
        """Slice each request's lanes out of the padded batch outputs and
        fan the per-request status words through the checks policy."""
        if _c.is_word(statuses):
            # batched (R, WIDTH) counter words, one row per request: fold
            # the realized device work into the serving ledger before the
            # status fan-out (DESIGN.md §15.1)
            self.device_counters.note(statuses)
        ctxs = [f"serve:{r.op}:{r.tenant}" for r in grp]
        words, errors = _g.raise_per_request(statuses, ctxs, allow=_BENIGN)
        now = time.perf_counter()
        for i, r in enumerate(grp):
            r.status |= words[i]
            self.status |= words[i]
            _g.count_flags(self.flag_counts, words[i])
            r.error = errors[i]
            r.result = results[i] if errors[i] is None else None
            r.finished = now

    def _serve_flat_group(self, key, grp) -> None:
        """Serve one (tenant signature, op, bucket) group as ONE padded
        vmap program over the stacked tenant arena."""
        from repro.kernels.kde_sampler import ops as _ops
        op, wb = key[1], key[2]
        names = sorted({r.tenant for r in grp})
        tenants = [self._tenants[nm] for nm in names]
        tmap = {nm: i for i, nm in enumerate(names)}
        xa, xa_sq, hstate = self._arena(tenants)
        # numpy inputs go straight to the jitted batch entry points: the
        # C++ jit dispatch path stages them faster than per-array
        # device_put, and this is the per-tick hot path
        tidx = np.asarray([tmap[r.tenant] for r in grp], np.int32)
        keys = _batch_keys([r.seed for r in grp])
        cfg = tenants[0].nbr._cfg
        if op == "sample":
            widths = [len(np.asarray(r.payload["src"]).reshape(-1))
                      for r in grp]
            src = np.stack([_pad_idx(r.payload["src"], wb) for r in grp])
            nb, prob, _, st = _ops.batched_fused_sample(
                xa, xa_sq, tidx, src, keys, hstate=hstate, **cfg)
            nb, prob = np.asarray(nb), np.asarray(prob)
            res = [(nb[i, :w], prob[i, :w]) for i, w in enumerate(widths)]
        elif op == "walk":
            length = key[3]
            widths = [len(np.asarray(r.payload["starts"]).reshape(-1))
                      for r in grp]
            starts = np.stack([_pad_idx(r.payload["starts"], wb)
                               for r in grp])
            wkeys = _split_batch(keys, length)
            end, _, st, _ = _ops.batched_walk_scan(
                xa, xa_sq, tidx, starts, wkeys, hstate=hstate,
                rounds=0, slack=2.0, record_path=False, **cfg)
            end = np.asarray(end)
            res = [(end[i, :w], None) for i, w in enumerate(widths)]
        elif op == "prob_of":
            widths = [len(np.asarray(r.payload["src"]).reshape(-1))
                      for r in grp]
            src = np.stack([_pad_idx(r.payload["src"], wb) for r in grp])
            dst = np.stack([_pad_idx(r.payload["dst"], wb) for r in grp])
            prob, st = _ops.batched_prob_of(
                xa, xa_sq, tidx, src, dst, keys, hstate=hstate, **cfg)
            prob = np.asarray(prob)
            res = [prob[i, :w] for i, w in enumerate(widths)]
        elif op == "query":
            widths = [len(np.atleast_2d(r.payload["y"])) for r in grp]
            y = np.stack([_pad_pts(r.payload["y"], wb) for r in grp])
            if tenants[0].nbr.level1 == "hash":
                from repro.kernels.kde_hash import ops as _hops
                hq = tenants[0].nbr.hash_estimator
                est, _, st = _hops.batched_hashed_query(
                    xa, tidx, y, hstate, keys, **hq._cfg)
            else:
                qkeys = ("kind", "inv_bw", "beta", "pairwise", "block_size",
                         "num_blocks", "n", "s", "exact", "precision")
                est, st = _ops.batched_kde_query(
                    xa, xa_sq, tidx, y, keys,
                    **{k: cfg[k] for k in qkeys})
            est = np.asarray(est)
            res = [est[i, :w] for i, w in enumerate(widths)]
        else:                                          # pragma: no cover
            raise ValueError(op)
        self._scatter(grp, res, st)

    def _serve_mesh_group(self, key, grp) -> None:
        """Serve a mesh tenant's group through its sharded engine: draws
        and probability reads concatenate the group's frontiers into ONE
        draw batch (one psum -- the §9 schedule; batching adds zero extra
        collectives), walks run per request (each walk step is its own
        collective batch either way).  The group shares ONE key stream
        that folds in every request's seed (first seed -> ``PRNGKey``,
        the rest ``fold_in`` in queue order): distribution-identical,
        deterministic in all submitted seeds, and bitwise-reproducible
        given equal seeds AND equal co-batch composition (documented on
        :meth:`KernelGraphServable.submit`)."""
        _, name, op = key[0], key[1], key[2]
        t = self._tenants[name]
        nbr = t.nbr
        engine = nbr._engine
        if op == "walk":
            length = key[3]
            res, words = [], []
            for r in grp:
                starts = jnp.asarray(np.asarray(r.payload["starts"]),
                                     jnp.int32)
                wkeys = jax.random.split(jax.random.PRNGKey(r.seed), length)
                end, _, st, _ = engine.walk_scan(starts, wkeys, rounds=0,
                                                 slack=2.0,
                                                 record_path=False)
                res.append((np.asarray(end), None))
                words.append(np.asarray(st, np.uint32))
            self._scatter(grp, res, np.asarray(words))
            return
        if op == "query":
            widths = [len(np.atleast_2d(r.payload["y"])) for r in grp]
            y = jnp.asarray(np.concatenate(
                [np.atleast_2d(np.asarray(r.payload["y"], np.float32))
                 for r in grp]))
            est = np.asarray(nbr.blocks.query(y))
            offs = np.cumsum([0] + widths)
            res = [est[offs[i]:offs[i + 1]] for i in range(len(grp))]
            st = np.full(len(grp), np.uint32(
                getattr(nbr.blocks, "last_status", 0)), np.uint32)
            self._scatter(grp, res, st)
            return
        key0 = jax.random.PRNGKey(grp[0].seed)
        for r in grp[1:]:
            key0 = jax.random.fold_in(key0, r.seed)
        widths = [len(np.asarray(r.payload["src"]).reshape(-1))
                  for r in grp]
        src = jnp.asarray(np.concatenate(
            [np.asarray(r.payload["src"]).reshape(-1) for r in grp]),
            jnp.int32)
        offs = np.cumsum([0] + widths)
        if op == "sample":
            nb, prob, _, cw = engine.fused_sample(src, key0)
            nb, prob = np.asarray(nb), np.asarray(prob)
            res = [(nb[offs[i]:offs[i + 1]], prob[offs[i]:offs[i + 1]])
                   for i in range(len(grp))]
        else:                                          # prob_of
            dst = jnp.asarray(np.concatenate(
                [np.asarray(r.payload["dst"]).reshape(-1) for r in grp]),
                jnp.int32)
            bs, cw = engine.masked_block_sums(src, key0)
            prob_dev, cw2 = engine.prob_of_from_block_sums(src, dst, bs)
            # fold the level-1 read word into the prob-of word and flag
            # the read itself -- NONFINITE_RESULT on NaN/Inf
            cw = _c.fold_status(_c.fold(cw, cw2),
                                _g.result_status(prob_dev))
            prob = np.asarray(prob_dev)
            res = [prob[offs[i]:offs[i + 1]] for i in range(len(grp))]
        # ONE counter word covers the whole concatenated draw batch: note
        # it once (replicating it per request would multiply-count the
        # realized work) and fan only its status bits out to the group
        st = self.device_counters.note(cw)
        self._scatter(grp, res, np.full(len(grp), np.uint32(st), np.uint32))

    # ------------------------------------------------------------------ #
    def report(self) -> dict:
        """Lifetime counters + or-folded flags for ops dashboards."""
        return dict(ticks=self.ticks, served=self.served,
                    failed=self.failed, admissions=self.admissions,
                    evictions=self.evictions,
                    resident=[n for n in self._lru],
                    tenants=len(self._tenants),
                    flags=_g.decode_status(self.status),
                    flag_counts=dict(self.flag_counts),
                    device_counters=self.device_counters.as_dict())
