"""Spectrum approximation in EMD -- Theorem 5.17 (CKSV18 on kernel graphs).

ApproxSpectralMoment: sample uniform vertices, run random walks of length
<= L from each, and record the empirical return probabilities
p^l_{uu} ~ E_u[(M^l)_{uu}] = tr(M^l)/n = sum_i mu_i^l / n, where
M = D^{-1} A is the walk matrix and mu_i = 1 - lambda_i are the eigenvalues
of M <-> normalized-Laplacian eigenvalues lambda_i.

Moment inversion: fit a distribution q on a grid over [-1, 1] with simplex-
projected least squares against the estimated moments, then read the
eigenvalue vector off the quantiles of q.  EMD between spectra (Def 5.16) in
1D is the L1 distance of sorted values / n.

The number of walks/length is independent of n -- the paper's headline
property.  Walk steps are the Section 4.4 primitive (KDE-query powered).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.kernels_fn import Kernel
from repro.core.laplacian import normalized_laplacian_dense
from repro.core.sampling.edge import NeighborSampler


@dataclasses.dataclass
class SpectrumResult:
    """Theorem 5.17 output: the EMD-approximated spectrum, the walk-return
    moments it was inverted from, and the kernel-eval budget."""

    eigenvalues: np.ndarray      # (n,) approximated normalized-Laplacian spectrum
    moments: np.ndarray          # estimated walk-return moments
    kernel_evals: int


def estimate_return_moments(sampler: NeighborSampler, n: int, length: int,
                            num_sources: int, walks_per_source: int,
                            seed: int = 0) -> np.ndarray:
    """m_l = E_u[p^l_{uu}] for l = 1..length (m_0 = 1 implicitly).

    Fused (DESIGN.md §7): ALL sources' walk ensembles run as one
    ``walk_scan`` program with ``record_path=True`` -- the (length, S*w)
    path comes back in one transfer and the return-hit averages are read
    off it, where the seed ran ``num_sources * length`` host sampling
    round-trips.  Cost: S*w*length walk steps (one level-1 read + w
    level-2 rows each)."""
    rng = np.random.default_rng(seed)
    sources = rng.integers(0, n, size=num_sources)
    starts = np.repeat(sources, walks_per_source)
    if getattr(sampler, "mode", None) == "blocked":
        _, path = sampler.walk(starts, length, record_path=True)
        return (np.asarray(path) == starts[None, :]).mean(axis=1)
    hits = np.zeros(length, np.float64)  # tree-mode fallback: host steps
    cur = starts.copy()
    for step in range(length):
        cur, _ = sampler.sample(cur)
        hits[step] = float((cur == starts).mean())
    return hits


def invert_moments(moments: np.ndarray, n: int, grid: int = 201,
                   iters: int = 4000, lr: float = 0.5) -> np.ndarray:
    """Simplex-projected least squares: find q >= 0, sum q = 1 on a mu-grid
    matching the moments; return the n sorted eigenvalues 1 - mu."""
    ls = np.arange(1, len(moments) + 1)
    mu = np.linspace(-1.0, 1.0, grid)
    vand = mu[None, :] ** ls[:, None]              # (L, G)
    # include the 0th moment (= 1) as a constraint row for scale stability
    v = np.concatenate([np.ones((1, grid)), vand], axis=0)
    m = np.concatenate([[1.0], moments])
    q = np.full(grid, 1.0 / grid)
    step = lr / (np.linalg.norm(v, 2) ** 2 + 1e-12)
    for _ in range(iters):
        grad = v.T @ (v @ q - m)
        q = _project_simplex(q - step * grad)
    # quantile read-out -> n eigenvalues
    cdf = np.cumsum(q)
    targets = (np.arange(n) + 0.5) / n
    pos = np.searchsorted(cdf, targets).clip(0, grid - 1)
    lams = 1.0 - mu[pos]
    return np.sort(lams)


def _project_simplex(v: np.ndarray) -> np.ndarray:
    u = np.sort(v)[::-1]
    css = np.cumsum(u)
    rho = np.nonzero(u * np.arange(1, len(v) + 1) > (css - 1.0))[0]
    rho = rho[-1] if len(rho) else 0
    theta = (css[rho] - 1.0) / (rho + 1.0)
    return np.maximum(v - theta, 0.0)


def approximate_spectrum(x, kernel: Kernel, length: int = 10,
                         num_sources: int = 32, walks_per_source: int = 64,
                         seed: int = 0,
                         sampler: Optional[NeighborSampler] = None,
                         mesh=None) -> SpectrumResult:
    """Theorem 5.17 (ApproxSpectralMoment): the normalized-Laplacian
    spectrum in EMD from walk-return moments -- walk budget independent of
    n.  Cost: ``num_sources * walks_per_source * length`` fused walk steps
    (each one level-1 read plus exact level-2 rows).

    >>> sp = approximate_spectrum(x, gaussian(1.0), length=8)
    """
    n = int(x.shape[0])
    if sampler is None:
        sampler = NeighborSampler(x, kernel, mode="blocked", seed=seed,
                                  exact_blocks=True, mesh=mesh)
    moments = estimate_return_moments(sampler, n, length, num_sources,
                                      walks_per_source, seed=seed + 1)
    lams = invert_moments(moments, n)
    return SpectrumResult(eigenvalues=lams, moments=moments,
                          kernel_evals=sampler.evals)


def exact_spectrum(kernel: Kernel, x) -> np.ndarray:
    """Oracle: eigenvalues of the normalized Laplacian, ascending."""
    nl = normalized_laplacian_dense(kernel, x)
    return np.sort(np.linalg.eigvalsh(nl))


def emd_1d(a: np.ndarray, b: np.ndarray) -> float:
    """Definition 5.16 for scalar multisets: EMD = mean |sorted a - sorted b|
    (the per-point matching cost, matching the Thm 5.17 normalization)."""
    return float(np.mean(np.abs(np.sort(a) - np.sort(b))))
