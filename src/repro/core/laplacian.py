"""Laplacian utilities + approximate Laplacian system solver (Section 5.1.1).

Solve L_G x = b by (1) building an eps-sparsifier G' (Theorem 5.3), then
(2) running preconditioned CG on L_{G'} (our stand-in for the fast KMP11/ST04
solver -- CG on an m-edge graph costs O(m) per iteration and Theorem 5.11
bounds the sparsifier-induced error by 2 sqrt(eps) ||L^+ b||_L).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.kernels_fn import Kernel
from repro.core.sparsify import SparseGraph, spectral_sparsify


def project_ones(v: np.ndarray) -> np.ndarray:
    """Project onto 1^perp (Laplacian range for connected graphs)."""
    return v - v.mean()


def cg_laplacian(g: SparseGraph, b: np.ndarray, iters: int = 200,
                 tol: float = 1e-10) -> Tuple[np.ndarray, float]:
    """Jacobi-preconditioned CG for L_G' x = b with b ⟂ 1."""
    b = project_ones(np.asarray(b, np.float64))
    deg = np.zeros(g.n)
    np.add.at(deg, g.src, g.weight)
    np.add.at(deg, g.dst, g.weight)
    dinv = 1.0 / np.maximum(deg, 1e-30)

    x = np.zeros_like(b)
    r = b.copy()
    z = project_ones(dinv * r)
    p = z.copy()
    rz = float(r @ z)
    for _ in range(iters):
        ap = g.matvec(p)
        denom = float(p @ ap)
        if denom <= 0:
            break
        alpha = rz / denom
        x = x + alpha * p
        r = r - alpha * ap
        if float(np.linalg.norm(r)) < tol * max(np.linalg.norm(b), 1e-30):
            break
        z = project_ones(dinv * r)
        rz_new = float(r @ z)
        p = z + (rz_new / max(rz, 1e-300)) * p
        rz = rz_new
    return project_ones(x), float(np.linalg.norm(r))


def solve_kernel_laplacian(x, kernel: Kernel, b: np.ndarray,
                           num_edges: Optional[int] = None,
                           estimator: str = "stratified", seed: int = 0,
                           iters: int = 300) -> Tuple[np.ndarray, SparseGraph]:
    """End-to-end Section 5.1.1: sparsify the kernel graph, solve on it."""
    n = int(x.shape[0])
    if num_edges is None:
        num_edges = int(8 * n * max(np.log(n), 1.0))
    g = spectral_sparsify(x, kernel, num_edges, estimator=estimator, seed=seed)
    sol, res = cg_laplacian(g, b, iters=iters)
    return sol, g


def laplacian_dense(kernel: Kernel, x) -> np.ndarray:
    """Exact dense Laplacian of the kernel graph (oracle for tests)."""
    import jax.numpy as jnp

    k = np.asarray(kernel.matrix(jnp.asarray(x)), np.float64)
    np.fill_diagonal(k, 0.0)
    return np.diag(k.sum(1)) - k


def normalized_laplacian_dense(kernel: Kernel, x) -> np.ndarray:
    """I - D^{-1/2} K_offdiag D^{-1/2} (used by spectrum/clustering oracles)."""
    import jax.numpy as jnp

    k = np.asarray(kernel.matrix(jnp.asarray(x)), np.float64)
    np.fill_diagonal(k, 0.0)
    d = np.maximum(k.sum(1), 1e-30)
    dm = 1.0 / np.sqrt(d)
    return np.eye(k.shape[0]) - (dm[:, None] * k) * dm[None, :]
