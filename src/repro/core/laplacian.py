"""Laplacian utilities + approximate Laplacian system solver (Section 5.1.1).

Solve L_G x = b by (1) building an eps-sparsifier G' (Theorem 5.3), then
(2) running preconditioned CG on L_{G'} (our stand-in for the fast KMP11/ST04
solver -- CG on an m-edge graph costs O(m) per iteration and Theorem 5.11
bounds the sparsifier-induced error by 2 sqrt(eps) ||L^+ b||_L).

The CG loop is device-resident (DESIGN.md §7): the whole iteration runs as
ONE jitted ``lax.while_loop`` program (``kde_sampler.ops.laplacian_cg``)
whose ``L_{G'} p`` matvec is a pair of segment-sum scatters over the COO
edge list -- no ``np.add.at``, no per-iteration host round-trips.  The edge
list of the PR-2 fused sparsifier is uploaded once and reused by every
iteration.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import Kernel
from repro.core.sparsify import SparseGraph, spectral_sparsify


def project_ones(v: np.ndarray) -> np.ndarray:
    """Project onto 1^perp (Laplacian range for connected graphs)."""
    return v - v.mean()


def cg_laplacian(g: SparseGraph, b: np.ndarray, iters: int = 200,
                 tol: float = 1e-10) -> Tuple[np.ndarray, float]:
    """Jacobi-preconditioned CG for L_G' x = b with b perp 1 (the solve
    step of Section 5.1.1), fused: one ``lax.while_loop`` program on
    device, segment-sum matvecs, best-iterate tracking for float32
    stability.  Costs no kernel evals (operates on the materialized
    sparsifier); O(m) work per iteration.  Non-finite flags in the
    program's status word raise under ``REPRO_CHECKS=1``;
    ``CG_NO_CONVERGE`` stays advisory because the returned residual
    already tells callers how far the solve got.

    >>> sol, res = cg_laplacian(g, b, iters=300)
    """
    from repro.ft import guards as _g
    from repro.kernels.kde_sampler import ops as _ops

    b = np.asarray(b, np.float64)
    sol, res, st = _ops.laplacian_cg(
        jnp.asarray(g.src, jnp.int32), jnp.asarray(g.dst, jnp.int32),
        jnp.asarray(g.weight, jnp.float32), jnp.asarray(b, jnp.float32),
        jnp.float32(tol), n=int(g.n), iters=int(iters))
    _g.raise_on_status(st, context="cg_laplacian",
                       allow=_g.CG_NO_CONVERGE)
    return project_ones(np.asarray(sol, np.float64)), float(res)


def solve_kernel_laplacian(x, kernel: Kernel, b: np.ndarray,
                           num_edges: Optional[int] = None,
                           estimator: str = "stratified", seed: int = 0,
                           iters: int = 300) -> Tuple[np.ndarray, SparseGraph]:
    """End-to-end Section 5.1.1 / Theorem 5.11: sparsify the kernel graph
    (Algorithm 5.1, ``num_edges`` defaults to 8 n log n), then solve on the
    sparsifier with the fused device CG.  Cost: the sparsifier's kernel
    evals (see ``spectral_sparsify``); the solve itself adds none.

    >>> sol, g = solve_kernel_laplacian(x, gaussian(1.0), b)
    """
    n = int(x.shape[0])
    if num_edges is None:
        num_edges = int(8 * n * max(np.log(n), 1.0))
    g = spectral_sparsify(x, kernel, num_edges, estimator=estimator, seed=seed)
    sol, res = cg_laplacian(g, b, iters=iters)
    return sol, g


def laplacian_dense(kernel: Kernel, x) -> np.ndarray:
    """Exact dense Laplacian of the kernel graph (oracle for tests;
    n^2 kernel evals)."""
    import jax.numpy as jnp

    k = np.asarray(kernel.matrix(jnp.asarray(x)), np.float64)
    np.fill_diagonal(k, 0.0)
    return np.diag(k.sum(1)) - k


def normalized_laplacian_dense(kernel: Kernel, x) -> np.ndarray:
    """I - D^{-1/2} K_offdiag D^{-1/2} (used by spectrum/clustering
    oracles; n^2 kernel evals)."""
    import jax.numpy as jnp

    k = np.asarray(kernel.matrix(jnp.asarray(x)), np.float64)
    np.fill_diagonal(k, 0.0)
    d = np.maximum(k.sum(1), 1e-30)
    dm = 1.0 / np.sqrt(d)
    return np.eye(k.shape[0]) - (dm[:, None] * k) * dm[None, :]
