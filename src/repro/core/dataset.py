"""Versioned mutable dataset state for the streaming kernel-graph engine.

Every structure the paper's estimators freeze at build time -- the §2
level-1 block sums, the Section 4 per-frontier cache, the GridHBE
``HashState``, the sharded layouts -- is keyed on the *dataset*, so a
mutable dataset needs an identity the caches can be validated against.
:class:`DynamicDataset` provides exactly that (DESIGN.md §12):

* a **capacity-padded** point array ``x_pad`` of fixed shape
  ``(capacity, d)`` with precomputed norms ``x_sq_pad``, so insert /
  delete / update are pure jitted scatters that never change program
  shapes (no retraces, no recompiles);
* **delete = masked sentinel**: a deleted slot's coordinates are moved to
  the engines' far-offset pad convention (``kde_rowsum._PAD_OFFSET``),
  where every builtin kernel evaluates to exactly ``0.0`` in float32 --
  dead slots are bitwise-transparent to block sums and degrees;
* **insert = append at the tail watermark**: freed holes are never reused
  before an explicit :meth:`compact`, so slot ids stay monotone in
  insertion order and patched hash buckets keep the slot-sorted member
  order a fresh rebuild would produce (the bitwise-parity contract);
* a monotone **epoch** counter plus a bounded mutation **journal**:
  consumers cache ``(dataset_id, epoch)`` next to any derived state and
  either *patch* (replaying ``mutations_since(their_epoch)``) or
  *rebuild* (when the journal no longer covers the gap).

Cost model: a mutation batch of ``m`` rows costs O(m·d) device work and
O(1) host bookkeeping; consumers patch level-1 sums in O(w·m) kernel
evals (Theorem 4.12 frontier width ``w``) instead of the O(w·n) rebuild.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.kde_rowsum.ops import _PAD_OFFSET

_DATASET_IDS = itertools.count(1)


def coalesce_mutations(batches):
    """Telescope a journal slice into ONE effective mutation batch.

    Per touched slot the old side is its state at the *first* touch and
    the new side its state at the *last* -- intermediate hops cancel
    (Section 2's kernel sums are linear in the rows), so consumers patch
    against the post-mutation arrays exactly once instead of replaying
    batch-by-batch (which would double-count rows mutated twice).
    Returns ``(slots, old_x, new_x, old_live, new_live)`` host arrays.
    """
    first, last = {}, {}
    for b in batches:
        for i, s in enumerate(np.asarray(b.slots)):
            s = int(s)
            if s not in first:
                first[s] = (b.old_x[i], b.old_live[i])
            last[s] = (b.new_x[i], b.new_live[i])
    slots = np.array(sorted(first), np.int32)
    if slots.size == 0:
        d = batches[0].old_x.shape[1] if batches else 0
        return (slots, np.zeros((0, d), np.float32),
                np.zeros((0, d), np.float32), np.zeros(0, bool),
                np.zeros(0, bool))
    old_x = np.stack([first[int(s)][0] for s in slots]).astype(np.float32)
    new_x = np.stack([last[int(s)][0] for s in slots]).astype(np.float32)
    old_live = np.array([first[int(s)][1] for s in slots], bool)
    new_live = np.array([last[int(s)][1] for s in slots], bool)
    return slots, old_x, new_x, old_live, new_live


@dataclasses.dataclass(frozen=True)
class MutationBatch:
    """One journaled mutation batch: everything a consumer needs to patch.

    ``old_x``/``new_x`` hold the touched rows' coordinates before/after
    (sentinel coordinates for the dead side of inserts/deletes), and the
    ``old_live``/``new_live`` masks say which side is real -- together
    they reduce every mutation kind to "slot moved from old to new",
    which is the only shape the §2 delta-patch ops need.
    """

    epoch: int
    kind: str                       # "insert" | "delete" | "update"
    slots: np.ndarray               # (m,) int32
    old_x: np.ndarray               # (m, d) float32
    new_x: np.ndarray               # (m, d) float32
    old_live: np.ndarray            # (m,) bool
    new_live: np.ndarray            # (m,) bool


@jax.jit
def _apply_rows(x, x_sq, live, slots, rows, live_val):
    """Jitted device-resident mutation core: scatter ``rows`` (and their
    precomputed norms, and the liveness value) into the padded arrays."""
    rows = jnp.asarray(rows, jnp.float32)
    rsq = jnp.sum(rows * rows, axis=-1)
    return (x.at[slots].set(rows),
            x_sq.at[slots].set(rsq),
            live.at[slots].set(live_val))


class DynamicDataset:
    """Mutable point set with epoch versioning (DESIGN.md §12).

    The logical dataset consumers build engines over is the full padded
    array: ``n = capacity`` everywhere, with dead slots at sentinel
    coordinates contributing exactly zero kernel mass.  That keeps every
    static shape (block counts, shard sizes, hash-table extents) frozen
    across mutations, which is what makes O(m) patching possible at all.
    """

    def __init__(self, x, capacity: Optional[int] = None,
                 journal_limit: int = 64):
        """Build from an (n0, d) initial point set; ``capacity`` bounds the
        total slot count (default: n0 plus 25% insert headroom)."""
        x0 = np.asarray(x, np.float32)
        if x0.ndim != 2 or x0.shape[0] < 1:
            raise ValueError("DynamicDataset needs a non-empty (n, d) array")
        n0, d = x0.shape
        if capacity is None:
            capacity = n0 + max(n0 // 4, 64)
        capacity = int(capacity)
        if capacity < n0:
            raise ValueError(f"capacity {capacity} < initial rows {n0}")
        self.d = int(d)
        self.capacity = capacity
        self.dataset_id = next(_DATASET_IDS)
        self.epoch = 0
        self._watermark = n0
        # the engines' far-offset pad convention: sentinel rows sit
        # _PAD_OFFSET away from a real row, every builtin kernel value
        # underflows to exactly 0.0 in f32 (kde_rowsum._pad_rows)
        self._sentinel = x0[-1] + np.float32(_PAD_OFFSET)
        pad = np.broadcast_to(self._sentinel, (capacity - n0, d))
        xp = np.concatenate([x0, pad], axis=0)
        self.x_pad = jnp.asarray(xp, jnp.float32)
        self.x_sq_pad = jnp.sum(self.x_pad * self.x_pad, axis=-1)
        self.live_host = np.zeros((capacity,), bool)
        self.live_host[:n0] = True
        self.live_dev = jnp.asarray(self.live_host)
        self._journal: collections.deque = collections.deque(
            maxlen=int(journal_limit))
        self._journal_floor = 0     # oldest epoch the journal can bridge

    # ------------------------------------------------------------ views
    @property
    def n(self) -> int:
        """Logical (padded) length -- the static ``n`` consumers build with."""
        return self.capacity

    @property
    def num_live(self) -> int:
        """Number of live (non-sentinel) rows."""
        return int(self.live_host.sum())

    @property
    def version(self) -> Tuple[int, int]:
        """The cache key contract: ``(dataset_id, epoch)``."""
        return (self.dataset_id, self.epoch)

    def live_slots(self) -> np.ndarray:
        """Host int32 slot ids of the live rows, ascending."""
        return np.where(self.live_host)[0].astype(np.int32)

    def live_x(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Compact device ``(x, x_sq)`` over live rows only (O(n) gather);
        for consumers that rebuild rather than patch."""
        idx = jnp.asarray(self.live_slots())
        return self.x_pad[idx], self.x_sq_pad[idx]

    def is_live(self, slots) -> bool:
        """True iff every slot in ``slots`` is currently live -- the
        consumer-side epoch-mismatch check (``guards.EPOCH_STALE``)."""
        return bool(self.live_host[np.asarray(slots, np.int64)].all())

    # -------------------------------------------------------- mutations
    def _record(self, kind: str, slots: np.ndarray, old_x: np.ndarray,
                new_x: np.ndarray, old_live: np.ndarray,
                new_live: np.ndarray) -> None:
        self.epoch += 1
        if len(self._journal) == self._journal.maxlen:
            self._journal_floor = self._journal[0].epoch
        self._journal.append(MutationBatch(
            epoch=self.epoch, kind=kind, slots=slots, old_x=old_x,
            new_x=new_x, old_live=old_live, new_live=new_live))

    def _reset_journal(self) -> None:
        """Structural change (compact/grow): patching is impossible, every
        consumer behind the new epoch must rebuild."""
        self._journal.clear()
        self._journal_floor = self.epoch

    def insert_rows(self, rows) -> np.ndarray:
        """Append new points at the tail watermark; returns their slots.

        Holes left by deletes are deliberately *not* reused (slot-order
        monotonicity is what keeps patched hash buckets bitwise equal to
        a rebuild); run :meth:`compact` to reclaim them.
        """
        rows = np.asarray(rows, np.float32).reshape(-1, self.d)
        m = rows.shape[0]
        if m == 0:
            return np.zeros((0,), np.int32)
        if self._watermark + m > self.capacity:
            self._grow(self._watermark + m)
        slots = np.arange(self._watermark, self._watermark + m,
                          dtype=np.int32)
        old_x = np.broadcast_to(self._sentinel, (m, self.d)).copy()
        self._watermark += m
        self.live_host[slots] = True
        self.x_pad, self.x_sq_pad, self.live_dev = _apply_rows(
            self.x_pad, self.x_sq_pad, self.live_dev,
            jnp.asarray(slots), jnp.asarray(rows), True)
        self._record("insert", slots, old_x, rows.copy(),
                     np.zeros(m, bool), np.ones(m, bool))
        return slots

    def delete_rows(self, slots) -> None:
        """Mask slots out of the dataset (sentinel coordinates: every
        kernel value against them is exactly 0.0)."""
        slots = np.unique(np.asarray(slots, np.int32))
        if slots.size == 0:
            return
        if not self.live_host[slots].all():
            raise ValueError("delete_rows: some slots are not live")
        m = slots.shape[0]
        old_x = np.asarray(self.x_pad[jnp.asarray(slots)], np.float32)
        new_x = np.broadcast_to(self._sentinel, (m, self.d)).copy()
        self.live_host[slots] = False
        self.x_pad, self.x_sq_pad, self.live_dev = _apply_rows(
            self.x_pad, self.x_sq_pad, self.live_dev,
            jnp.asarray(slots), jnp.asarray(new_x), False)
        self._record("delete", slots, old_x, new_x,
                     np.ones(m, bool), np.zeros(m, bool))

    def update_rows(self, slots, rows) -> None:
        """Move live points to new coordinates in place."""
        slots = np.asarray(slots, np.int32)
        rows = np.asarray(rows, np.float32).reshape(-1, self.d)
        if slots.shape[0] != rows.shape[0]:
            raise ValueError("update_rows: slots/rows length mismatch")
        if slots.size == 0:
            return
        if np.unique(slots).size != slots.size:
            raise ValueError("update_rows: duplicate slots in one batch")
        if not self.live_host[slots].all():
            raise ValueError("update_rows: some slots are not live")
        m = slots.shape[0]
        old_x = np.asarray(self.x_pad[jnp.asarray(slots)], np.float32)
        self.x_pad, self.x_sq_pad, self.live_dev = _apply_rows(
            self.x_pad, self.x_sq_pad, self.live_dev,
            jnp.asarray(slots), jnp.asarray(rows), True)
        self._record("update", slots, old_x, rows.copy(),
                     np.ones(m, bool), np.ones(m, bool))

    # ------------------------------------------------- structural moves
    def compact(self) -> None:
        """Pack live rows into the lowest slots and reset the watermark.

        Slot ids change, so this is a *structural* epoch bump: the journal
        resets and every consumer rebuilds from scratch.  Lazy by design
        -- only needed once deletes have riddled the tail with holes and
        an insert would otherwise overflow capacity.
        """
        live = self.live_slots()
        x_live = np.asarray(self.x_pad[jnp.asarray(live)], np.float32)
        n_live = x_live.shape[0]
        pad = np.broadcast_to(self._sentinel,
                              (self.capacity - n_live, self.d))
        xp = np.concatenate([x_live, pad], axis=0)
        self.x_pad = jnp.asarray(xp, jnp.float32)
        self.x_sq_pad = jnp.sum(self.x_pad * self.x_pad, axis=-1)
        self.live_host = np.zeros((self.capacity,), bool)
        self.live_host[:n_live] = True
        self.live_dev = jnp.asarray(self.live_host)
        self._watermark = n_live
        self.epoch += 1
        self._reset_journal()

    def _grow(self, min_capacity: int) -> None:
        """Reallocate at >= ``min_capacity`` (doubling): shapes change, so
        like :meth:`compact` this forces consumers to rebuild."""
        new_cap = max(2 * self.capacity, int(min_capacity))
        pad = np.broadcast_to(self._sentinel,
                              (new_cap - self.capacity, self.d))
        xp = np.concatenate([np.asarray(self.x_pad, np.float32), pad],
                            axis=0)
        self.capacity = new_cap
        self.x_pad = jnp.asarray(xp, jnp.float32)
        self.x_sq_pad = jnp.sum(self.x_pad * self.x_pad, axis=-1)
        live = np.zeros((new_cap,), bool)
        live[:len(self.live_host)] = self.live_host
        self.live_host = live
        self.live_dev = jnp.asarray(self.live_host)
        self.epoch += 1
        self._reset_journal()

    # ----------------------------------------------------- consumer API
    def mutations_since(self, epoch: int) -> Optional[List[MutationBatch]]:
        """Journal slice a consumer at ``epoch`` must replay to catch up,
        oldest first; ``None`` when the journal can no longer bridge the
        gap (journal overflow, compact, grow, or a foreign dataset) --
        the consumer must rebuild."""
        epoch = int(epoch)
        if epoch == self.epoch:
            return []
        if epoch > self.epoch or epoch < self._journal_floor:
            return None
        out = [b for b in self._journal if b.epoch > epoch]
        if not out or out[0].epoch != epoch + 1:
            return None
        return out
