"""Local clustering -- Algorithm 6.1 / Theorem 6.9.

Same-cluster test for vertices (u, w) of a (k, phi_in, phi_out)-clusterable
kernel graph: compare the endpoint distributions of length-t random walks
with the CDVV14 l2 distribution tester.  Same cluster => ||p_u - p_w||_2^2
<= 1/(8n) (Lemma 6.7); different clusters => >= 2/n (disjoint supports up to
escape probability, Lemma 6.8).  We threshold the unbiased collision
statistic at 1/n, the geometric midpoint of the two regimes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sampling.edge import NeighborSampler
from repro.core.sampling.walks import random_walks


def l2_distance_statistic(counts_p: np.ndarray, counts_q: np.ndarray,
                          r_p: int, r_q: int) -> float:
    """Unbiased ||p - q||_2^2 estimator from Poissonized sample counts
    (CDVV14): E[(X_i - Y_i)^2 - X_i - Y_i] = r^2 (p_i - q_i)^2 for
    X_i ~ Poi(r p_i), Y_i ~ Poi(r q_i) with equal rates r."""
    r = float((r_p + r_q) / 2)
    z = np.sum((counts_p - counts_q) ** 2 - counts_p - counts_q)
    return float(z / (r * r))


@dataclasses.dataclass
class LocalClusterResult:
    same_cluster: bool
    statistic: float
    threshold: float
    num_walks: int
    walk_length: int
    kernel_evals: int


def same_cluster_test(x, kernel, u: int, w: int, walk_length: int,
                      num_walks: int, seed: int = 0,
                      sampler: NeighborSampler | None = None,
                      threshold: float | None = None) -> LocalClusterResult:
    """Algorithm 6.1.  num_walks ~ O(sqrt(n k / eps) log(1/eps)) per Thm 6.9."""
    n = int(x.shape[0])
    rng = np.random.default_rng(seed)
    if sampler is None:
        sampler = NeighborSampler(x, kernel, mode="blocked", seed=seed,
                                  exact_blocks=True)
    # Poissonize the sample sizes so the collision statistic is unbiased.
    r_u = int(rng.poisson(num_walks))
    r_w = int(rng.poisson(num_walks))
    ends_u = random_walks(sampler, np.full(max(r_u, 1), u, np.int64), walk_length)
    ends_w = random_walks(sampler, np.full(max(r_w, 1), w, np.int64), walk_length)
    cu = np.bincount(ends_u, minlength=n).astype(np.float64)
    cw = np.bincount(ends_w, minlength=n).astype(np.float64)
    stat = l2_distance_statistic(cu, cw, num_walks, num_walks)
    thr = threshold if threshold is not None else 1.0 / n
    return LocalClusterResult(same_cluster=bool(stat <= thr), statistic=stat,
                              threshold=thr, num_walks=num_walks,
                              walk_length=walk_length,
                              kernel_evals=sampler.evals)
