"""Local clustering -- Algorithm 6.1 / Theorem 6.9.

Same-cluster test for vertices (u, w) of a (k, phi_in, phi_out)-clusterable
kernel graph: compare the endpoint distributions of length-t random walks
with the CDVV14 l2 distribution tester.  Same cluster => ||p_u - p_w||_2^2
<= 1/(8n) (Lemma 6.7); different clusters => >= 2/n (disjoint supports up to
escape probability, Lemma 6.8).  We threshold the unbiased collision
statistic at 1/n, the geometric midpoint of the two regimes.

Fused (DESIGN.md §7): BOTH endpoints' Poissonized walk ensembles run as one
``walk_scan`` program (the seed launched two separate host walk calls), and
the collision part of the statistic -- sum_i (X_i - Y_i)^2 over endpoint
counts -- is one segment-sum program (``ops.signed_endpoint_stat``) instead
of two host ``np.bincount`` passes.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.sampling.edge import NeighborSampler
from repro.core.sampling.walks import random_walks


def l2_distance_statistic(counts_p: np.ndarray, counts_q: np.ndarray,
                          r_p: int, r_q: int) -> float:
    """Unbiased ||p - q||_2^2 estimator from Poissonized sample counts
    (CDVV14): E[(X_i - Y_i)^2 - X_i - Y_i] = r^2 (p_i - q_i)^2 for
    X_i ~ Poi(r p_i), Y_i ~ Poi(r q_i) with equal rates r."""
    r = float((r_p + r_q) / 2)
    z = np.sum((counts_p - counts_q) ** 2 - counts_p - counts_q)
    return float(z / (r * r))


@dataclasses.dataclass
class LocalClusterResult:
    """Algorithm 6.1 output: the thresholded CDVV14 decision plus the raw
    statistic, the walk budget spent, and the kernel-eval cost."""

    same_cluster: bool
    statistic: float
    threshold: float
    num_walks: int
    walk_length: int
    kernel_evals: int


def same_cluster_test(x, kernel, u: int, w: int, walk_length: int,
                      num_walks: int, seed: int = 0,
                      sampler: NeighborSampler | None = None,
                      threshold: float | None = None,
                      mesh=None) -> LocalClusterResult:
    """Algorithm 6.1 / Theorem 6.9: decide whether u and w share a cluster
    with num_walks ~ O(sqrt(n k / eps) log(1/eps)) walks of length t per
    endpoint.  Both endpoints' walks are ONE fused ``walk_scan`` program
    and the collision statistic is computed on device.

    Cost: (r_u + r_w) * walk_length walk steps; per step one level-1 read
    (w*n exact / w*B*s stratified) plus w exact level-2 rows.

    >>> res = same_cluster_test(x, gaussian(1.0), 0, 5, walk_length=6,
    ...                         num_walks=400)
    """
    n = int(x.shape[0])
    rng = np.random.default_rng(seed)
    if sampler is None:
        sampler = NeighborSampler(x, kernel, mode="blocked", seed=seed,
                                  exact_blocks=True, mesh=mesh)
    # Poissonize the sample sizes so the collision statistic is unbiased.
    r_u = max(int(rng.poisson(num_walks)), 1)
    r_w = max(int(rng.poisson(num_walks)), 1)
    starts = np.concatenate([np.full(r_u, u, np.int64),
                             np.full(r_w, w, np.int64)])
    if getattr(sampler, "mode", None) == "blocked":
        ends, _ = sampler.walk(starts, walk_length)
        signs = np.concatenate([np.ones(r_u, np.float32),
                                -np.ones(r_w, np.float32)])
        sq_dev, cw = sampler._ops.signed_endpoint_stat(
            jnp.asarray(ends, jnp.int32), jnp.asarray(signs), n=n)
        sampler._note(cw, "same_cluster_test")
        sq = float(sq_dev)
        # CDVV14: z = sum (X_i - Y_i)^2 - X_i - Y_i; sum X_i = r_u etc.
        stat = (sq - r_u - r_w) / float(num_walks) ** 2
    else:  # tree-mode fallback: host walks + host counts
        ends = random_walks(sampler, starts, walk_length)
        cu = np.bincount(ends[:r_u], minlength=n).astype(np.float64)
        cw = np.bincount(ends[r_u:], minlength=n).astype(np.float64)
        stat = l2_distance_statistic(cu, cw, num_walks, num_walks)
    thr = threshold if threshold is not None else 1.0 / n
    return LocalClusterResult(same_cluster=bool(stat <= thr), statistic=stat,
                              threshold=thr, num_walks=num_walks,
                              walk_length=walk_length,
                              kernel_evals=sampler.evals)
