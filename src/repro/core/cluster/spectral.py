"""Spectral clustering on the sparsified kernel graph -- Section 6.2.

Theorem 6.12: a cut sparsifier preserves (k, phi_out)-clusterability, so
clustering the sparsifier matches clustering the full graph.  Theorem 6.13:
the top-k Laplacian eigenvectors of the (sparse) graph come from a
MM15-style block power method -- implemented here as subspace iteration on
the normalized adjacency using only edge-list matvecs (O(m) per iteration).

k-means (with k-means++ seeding) is hand-rolled in numpy -- no scipy/sklearn
in this environment.
"""
from __future__ import annotations

import dataclasses
from itertools import permutations
from typing import Optional, Tuple

import numpy as np

from repro.core.sparsify import SparseGraph


def _normalized_adj_matvec(g: SparseGraph, dinv_sqrt: np.ndarray,
                           v: np.ndarray) -> np.ndarray:
    """N v with N = D^{-1/2} A D^{-1/2}, via the COO edge list; v is (n, k).

    Per-column ``np.bincount`` scatter (C-speed) instead of ``np.add.at``
    (which is ~10x slower and made the sparse path lose to dense BLAS)."""
    sv = dinv_sqrt[:, None] * v
    out = np.empty_like(v)
    for j in range(v.shape[1]):
        out[:, j] = (np.bincount(g.src, weights=g.weight * sv[g.dst, j],
                                 minlength=g.n)
                     + np.bincount(g.dst, weights=g.weight * sv[g.src, j],
                                   minlength=g.n))
    return dinv_sqrt[:, None] * out


def laplacian_eigenvectors(g: SparseGraph, k: int, iters: int = 100,
                           seed: int = 0, guard: int = 4
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Bottom-k eigenvectors of the normalized Laplacian = top-k of N.

    Block subspace iteration with ``guard`` extra vectors: near-degenerate
    cluster eigenvalues (lambda_2 ~ 1e-4 on the Nested dataset) converge
    orders of magnitude faster when the block over-spans the target space.

    Returns (eigvals of L~ ascending (k,), vectors (n, k))."""
    deg = np.zeros(g.n)
    np.add.at(deg, g.src, g.weight)
    np.add.at(deg, g.dst, g.weight)
    dinv_sqrt = 1.0 / np.sqrt(np.maximum(deg, 1e-30))
    rng = np.random.default_rng(seed)
    kk = min(k + guard, g.n)
    q, _ = np.linalg.qr(rng.standard_normal((g.n, kk)))
    for _ in range(iters):
        # shift by +I to make the operator PSD (eigs of N are in [-1, 1])
        q = _normalized_adj_matvec(g, dinv_sqrt, q) + q
        q, _ = np.linalg.qr(q)
    small = q.T @ _normalized_adj_matvec(g, dinv_sqrt, q)
    val, vec = np.linalg.eigh(small)
    order = np.argsort(val)[::-1][:k]           # largest of N first
    return 1.0 - val[order], q @ vec[:, order]


def kmeans(points: np.ndarray, k: int, iters: int = 50,
           seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """k-means with k-means++ init; returns (labels, centers)."""
    rng = np.random.default_rng(seed)
    n = points.shape[0]
    centers = [points[rng.integers(n)]]
    for _ in range(1, k):
        d2 = np.min(
            [np.sum((points - c) ** 2, axis=1) for c in centers], axis=0)
        p = d2 / max(d2.sum(), 1e-30)
        centers.append(points[rng.choice(n, p=p)])
    centers = np.stack(centers)
    labels = np.zeros(n, np.int64)
    for _ in range(iters):
        d2 = ((points[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        new = d2.argmin(1)
        if np.array_equal(new, labels):
            break
        labels = new
        for j in range(k):
            sel = labels == j
            if sel.any():
                centers[j] = points[sel].mean(0)
    return labels, centers


@dataclasses.dataclass
class SpectralClusterResult:
    """Section 6.2 output: labels, the NJW spectral embedding, and the
    bottom normalized-Laplacian eigenvalues."""

    labels: np.ndarray
    embedding: np.ndarray
    eigenvalues: np.ndarray


def spectral_cluster(g: SparseGraph, k: int, seed: int = 0,
                     iters: int = 150, restarts: int = 4) -> SpectralClusterResult:
    """Theorems 6.12/6.13: NJW spectral clustering on the sparsifier --
    bottom-k eigenvectors by subspace iteration (O(m) edge-list matvecs,
    no kernel evals), row-normalized embedding, k-means with restarts.

    >>> res = spectral_cluster(spectral_sparsify(x, ker, 10 * n), 2)
    """
    vals, vecs = laplacian_eigenvectors(g, k, iters=iters, seed=seed)
    # Row-normalize the spectral embedding (standard NJW step).
    emb = vecs / np.maximum(np.linalg.norm(vecs, axis=1, keepdims=True), 1e-12)
    best, best_inertia = None, np.inf
    for r in range(restarts):
        labels, centers = kmeans(emb, k, seed=seed + 1000 * r)
        inertia = float(((emb - centers[labels]) ** 2).sum())
        if inertia < best_inertia:
            best, best_inertia = labels, inertia
    return SpectralClusterResult(labels=best, embedding=emb,
                                 eigenvalues=vals)


def cluster_accuracy(pred: np.ndarray, truth: np.ndarray, k: int) -> float:
    """Best label-permutation agreement (k <= 6: brute force)."""
    best = 0.0
    for perm in permutations(range(k)):
        mapped = np.array([perm[p] for p in pred])
        best = max(best, float((mapped == truth).mean()))
    return best
