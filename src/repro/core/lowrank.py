"""Additive-error low-rank approximation of K -- Algorithm 5.15 / Cor 5.14.

FKV (Frieze-Kannan-Vempala) over rows sampled from the squared-row-norm
distribution, which Section 5.2 obtains with n KDE queries against the scaled
dataset cX.  Post-processing constructs only O(r/eps) rows explicitly.

Baselines (Section 7): input-sparsity-time CountSketch LRA (Clarkson-
Woodruff) and iterative SVD (block subspace iteration) -- both require the
full kernel matrix (n^2 kernel evaluations), which is the paper's headline
comparison (9x fewer evaluations for KDE-LRA).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import Kernel
from repro.core.sampling.rownorm import RowNormSampler


@dataclasses.dataclass
class LowRankResult:
    """Algorithm 5.15 output: the factors plus the eval/query budget."""

    u: np.ndarray            # (r, n) right factor, rows ~ orthonormal
    v: Optional[np.ndarray]  # (n, r) left factor (CP17 fit), or None
    kernel_evals: int
    kde_queries: int
    row_indices: np.ndarray

    def approx(self) -> np.ndarray:
        """B = V @ U (requires v)."""
        assert self.v is not None
        return self.v @ self.u


def fkv_lowrank(x, kernel: Kernel, rank: int, num_rows: Optional[int] = None,
                estimator: str = "exact", seed: int = 0,
                fit_cols: Optional[int] = None, mesh=None) -> LowRankResult:
    """Theorem 5.12 pipeline.  num_rows defaults to 25*rank (the paper's
    experimental setting, Section 7.1).

    Device-resident (DESIGN.md §6): the sampler owns the one device copy of
    x, its row-norm prefix CDF accumulates in float64, and the FKV sketch
    rows come from one jitted batched program (``sketch_rows``) instead of a
    chunk=16 host loop over ``kernel.pairwise``; the CP17 column fit reads
    its columns through the same program (K is symmetric)."""
    n = int(x.shape[0])
    s = int(num_rows if num_rows is not None else 25 * rank)
    sampler = RowNormSampler(x, kernel, estimator=estimator, seed=seed,
                             mesh=mesh)
    idx = sampler.sample(s)
    sk = sampler.sketch_rows(idx)                    # (s, n), one program

    # Top right-singular directions of the sketch.
    w = sk @ sk.T                                    # (s, s)
    eigval, eigvec = np.linalg.eigh(w)
    order = np.argsort(eigval)[::-1][:rank]
    sig = np.sqrt(np.maximum(eigval[order], 1e-30))
    u = (sk.T @ eigvec[:, order] / sig[None, :]).T   # (r, n)

    v = None
    if fit_cols:
        v, _ = fit_left_factor(x, kernel, u, num_cols=fit_cols,
                               seed=seed + 1, sampler=sampler)
    return LowRankResult(u=u, v=v, kernel_evals=sampler.evals,
                         kde_queries=n, row_indices=idx)


def fit_left_factor(x, kernel: Kernel, u: np.ndarray, num_cols: int,
                    seed: int = 0,
                    sampler: Optional[RowNormSampler] = None
                    ) -> Tuple[np.ndarray, int]:
    """Theorem 5.13 (CP17): fit V = argmin ||K - V U||_F reading only
    O(r/eps) columns of K, via uniformly subsampled least squares.

    With a ``sampler``, the columns are read as batched device rows
    (K symmetric: K[:, cols] = K[cols, :].T) and the evaluations are
    counted on the sampler (the returned eval count is then 0 so callers
    summing ``sampler.evals + extra`` never double-count); standalone
    calls fall back to one pairwise sweep and return its cost."""
    n = int(x.shape[0])
    rng = np.random.default_rng(seed)
    cols = rng.choice(n, size=min(num_cols, n), replace=False)
    if sampler is not None:
        k_cols = sampler.rows(cols).T                                # (n, c)
        extra = 0
    else:
        xj = jnp.asarray(x, jnp.float32)
        k_cols = np.asarray(kernel.pairwise(xj, xj[jnp.asarray(cols)]))
        extra = n * len(cols)
    u_cols = u[:, cols]                                              # (r, c)
    # V = K_cols U_cols^T (U_cols U_cols^T)^{-1}
    gram = u_cols @ u_cols.T
    rhs = k_cols @ u_cols.T
    v = rhs @ np.linalg.pinv(gram)
    return v, extra


def projection_error(k: np.ndarray, u: np.ndarray) -> float:
    """||K - K U^T U||_F^2 (evaluation oracle)."""
    proj = (k @ u.T) @ u
    return float(np.linalg.norm(k - proj, "fro") ** 2)


def factored_error(k: np.ndarray, v: np.ndarray, u: np.ndarray) -> float:
    """||K - V U||_F^2 (evaluation oracle for the Theorem 5.13 fit)."""
    return float(np.linalg.norm(k - v @ u, "fro") ** 2)


# --------------------------------------------------------------------- #
# Baselines (need the materialized matrix -> n^2 kernel evaluations)

def countsketch_lowrank(k: np.ndarray, rank: int, sketch_size: int,
                        seed: int = 0) -> np.ndarray:
    """Clarkson-Woodruff input-sparsity LRA: U = top-r right singular
    directions of the CountSketch S K."""
    n = k.shape[0]
    rng = np.random.default_rng(seed)
    h = rng.integers(0, sketch_size, size=n)
    s = rng.choice([-1.0, 1.0], size=n)
    sk = np.zeros((sketch_size, n))
    np.add.at(sk, h, s[:, None] * k)                 # S K
    _, _, vt = np.linalg.svd(sk, full_matrices=False)
    return vt[:rank]                                 # (r, n)


def subspace_iteration(k: np.ndarray, rank: int, iters: int = 12,
                       seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Iterative SVD baseline: block power iteration with QR; returns
    (eigvals ~ (r,), U (r, n))."""
    n = k.shape[0]
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, rank)))
    for _ in range(iters):
        q, _ = np.linalg.qr(k @ q)
    small = q.T @ (k @ q)
    val, vec = np.linalg.eigh(small)
    order = np.argsort(np.abs(val))[::-1]
    return val[order], (q @ vec[:, order]).T


def optimal_error(k: np.ndarray, rank: int) -> float:
    """||K - K_r||_F^2 via full eigendecomposition (oracle)."""
    val = np.linalg.eigvalsh(k)
    val = np.sort(np.abs(val))[::-1]
    return float(np.sum(val[rank:] ** 2))
