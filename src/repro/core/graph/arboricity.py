"""Arboricity (densest-subgraph density) estimation -- Alg 6.14 / Thm 6.15.

Sample m = O(n Delta log n / eps^2) edges with probability proportional to
(an upper bound on) their weight, add each with weight w_e / (m p_e), and
return the densest-subgraph density of the sample.  (The Algorithm-6.14 box
writes the added weight as 1/(m p_e); the Theorem-6.15 proof analyses
X_i = w_e/(p_e m), which is the unbiased version -- we implement the proof's
estimator.)

Fused (DESIGN.md §7): the edge-sampling loop IS the sparsifier's fused
Algorithm 5.1 pipeline -- ``NeighborSampler.edge_batches`` draws every
(u, v, w_e/(m p_e)) tuple in one ``lax.scan`` program over a shared device
degree CDF, with the reverse probability collapsed to k(u,v)/deg(v)
(DESIGN.md §6).  The seed ran a host batch loop with five device
round-trips per batch.

Offline solver: Charikar's greedy peel.  The paper calls an exact LP
[Cha00]; with no LP solver in this environment we use the standard greedy
2-approximation applied identically to both the sampled graph and the exact
oracle, so the sampling claim (density preserved under subsampling) is
evaluated apples-to-apples.  Documented in DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import Kernel
from repro.core.sampling.edge import NeighborSampler, shared_level1_estimator
from repro.core.sampling.vertex import DegreeSampler
from repro.core.sparsify import SparseGraph


def greedy_densest_subgraph(n: int, src: np.ndarray, dst: np.ndarray,
                            weight: np.ndarray) -> float:
    """Charikar peel: repeatedly remove the min-weighted-degree vertex;
    return the max density w(E(U))/|U| seen (2-approximation, O(n^2 + m);
    the offline solver of Alg 6.14 -- no kernel evals)."""
    deg = np.zeros(n)
    np.add.at(deg, src, weight)
    np.add.at(deg, dst, weight)
    total = float(weight.sum())
    active = np.ones(n, bool)
    best = total / n
    alive = n
    # simple O(n^2 + m) peel: argmin over active degrees each round
    dd = deg.copy()
    incident_by_src = {}
    for e in range(len(src)):
        incident_by_src.setdefault(int(src[e]), []).append(e)
        incident_by_src.setdefault(int(dst[e]), []).append(e)
    edge_alive = np.ones(len(src), bool)
    w_alive = total
    for _ in range(n - 1):
        u = int(np.where(active, dd, np.inf).argmin())
        active[u] = False
        alive -= 1
        for e in incident_by_src.get(u, ()):  # remove incident edges
            if edge_alive[e]:
                edge_alive[e] = False
                w_alive -= float(weight[e])
                other = int(dst[e]) if int(src[e]) == u else int(src[e])
                dd[other] -= float(weight[e])
        if alive > 0:
            best = max(best, w_alive / alive)
    return best


@dataclasses.dataclass
class ArboricityResult:
    """Alg 6.14 output: the greedy density of the sampled graph, the
    sample itself, and the kernel-eval budget spent drawing it."""

    density: float
    graph: SparseGraph
    kernel_evals: int


def estimate_arboricity(x, kernel: Kernel, num_edges: int,
                        estimator: str = "stratified",
                        seed: int = 0, batch: int = 512,
                        mesh=None) -> ArboricityResult:
    """Algorithm 6.14 / Theorem 6.15 with the weighted edge sampler of
    Section 4.3, fused: all ``num_edges`` draws and their importance
    weights come from one ``edge_batch_scan`` device program (sharded
    over ``mesh`` when given -- one psum per batch, DESIGN.md §9).

    Cost (stratified, m = num_edges rounded up to a batch multiple):
    ``n*B*s`` degree preprocessing + ``m*(B*s + bs + 1)`` edge draws.

    >>> res = estimate_arboricity(x, gaussian(1.0), num_edges=8 * len(x))
    """
    n = int(x.shape[0])
    m = int(num_edges)
    nbr = NeighborSampler(x, kernel, mode="blocked", seed=seed + 2,
                          exact_blocks=(estimator in ("exact",
                                                      "exact_block")),
                          mesh=mesh,
                          level1="hash" if estimator == "hash"
                          and mesh is None else "blocked")
    est = shared_level1_estimator(nbr, estimator, seed=seed)
    deg = DegreeSampler(est, seed=seed + 1,
                        mesh=mesh if est is nbr.blocks else None)
    # edge_batches reweights by k(u,v) / (m (p_u q_uv + p_v q_vu)) -- the
    # Theorem-6.15 estimator X_i = w_e / (p_e m) with the Section 4.3 law.
    u, v, w, _, _ = nbr.edge_batches(deg.cdf_device, deg.degrees_device,
                                     deg.total, m, batch=batch)
    g = SparseGraph(n, np.asarray(u, np.int64), np.asarray(v, np.int64),
                    np.asarray(w, np.float64))
    dens = greedy_densest_subgraph(n, g.src, g.dst, g.weight)
    evals = nbr.evals + (0 if est is nbr.blocks else est.evals)
    return ArboricityResult(density=dens, graph=g, kernel_evals=evals)


def exact_arboricity(kernel: Kernel, x) -> float:
    """Oracle: greedy peel on the full kernel graph (n^2 evals)."""
    k = np.asarray(kernel.matrix(jnp.asarray(x)), np.float64)
    n = k.shape[0]
    iu, ju = np.triu_indices(n, 1)
    return greedy_densest_subgraph(n, iu, ju, k[iu, ju])
