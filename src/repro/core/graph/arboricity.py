"""Arboricity (densest-subgraph density) estimation -- Alg 6.14 / Thm 6.15.

Sample m = O(n Delta log n / eps^2) edges with probability proportional to
(an upper bound on) their weight, add each with weight w_e / (m p_e), and
return the densest-subgraph density of the sample.  (The Algorithm-6.14 box
writes the added weight as 1/(m p_e); the Theorem-6.15 proof analyses
X_i = w_e/(p_e m), which is the unbiased version -- we implement the proof's
estimator.)

Offline solver: Charikar's greedy peel.  The paper calls an exact LP
[Cha00]; with no LP solver in this environment we use the standard greedy
2-approximation applied identically to both the sampled graph and the exact
oracle, so the sampling claim (density preserved under subsampling) is
evaluated apples-to-apples.  Documented in DESIGN.md §9.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.kde.base import make_estimator
from repro.core.kernels_fn import Kernel
from repro.core.sampling.edge import NeighborSampler
from repro.core.sampling.vertex import DegreeSampler
from repro.core.sparsify import SparseGraph


def greedy_densest_subgraph(n: int, src: np.ndarray, dst: np.ndarray,
                            weight: np.ndarray) -> float:
    """Charikar peel: repeatedly remove the min-weighted-degree vertex;
    return the max density w(E(U))/|U| seen."""
    deg = np.zeros(n)
    np.add.at(deg, src, weight)
    np.add.at(deg, dst, weight)
    total = float(weight.sum())
    active = np.ones(n, bool)
    best = total / n
    # adjacency lists for incremental updates
    order = np.argsort(src, kind="stable")
    order2 = np.argsort(dst, kind="stable")
    alive = n
    # simple O(n^2 + m) peel: argmin over active degrees each round
    dd = deg.copy()
    incident_by_src = {}
    for e in range(len(src)):
        incident_by_src.setdefault(int(src[e]), []).append(e)
        incident_by_src.setdefault(int(dst[e]), []).append(e)
    edge_alive = np.ones(len(src), bool)
    w_alive = total
    for _ in range(n - 1):
        u = int(np.where(active, dd, np.inf).argmin())
        active[u] = False
        alive -= 1
        for e in incident_by_src.get(u, ()):  # remove incident edges
            if edge_alive[e]:
                edge_alive[e] = False
                w_alive -= float(weight[e])
                other = int(dst[e]) if int(src[e]) == u else int(src[e])
                dd[other] -= float(weight[e])
        if alive > 0:
            best = max(best, w_alive / alive)
    return best


@dataclasses.dataclass
class ArboricityResult:
    density: float
    graph: SparseGraph
    kernel_evals: int


def estimate_arboricity(x, kernel: Kernel, num_edges: int,
                        estimator: str = "stratified",
                        seed: int = 0, batch: int = 512) -> ArboricityResult:
    """Algorithm 6.14 with the weighted edge sampler of Section 4.3."""
    n = int(x.shape[0])
    est = make_estimator(estimator, x, kernel, seed=seed)
    deg = DegreeSampler(est, seed=seed + 1)
    nbr = NeighborSampler(x, kernel, mode="blocked", seed=seed + 2,
                          exact_blocks=(estimator == "exact"))
    m = int(num_edges)
    srcs, dsts, ws = [], [], []
    xj = jnp.asarray(x)
    for lo in range(0, m, batch):
        b = min(batch, m - lo)
        u = deg.sample(b)
        v, q_uv = nbr.sample(u)
        q_vu = nbr.prob_of(v, u)
        p_e = deg.prob(u) * q_uv + deg.prob(v) * q_vu
        kuv = np.diagonal(np.asarray(kernel.pairwise(
            xj[jnp.asarray(u)], xj[jnp.asarray(v)])))
        srcs.append(u)
        dsts.append(v)
        ws.append(kuv / (m * np.maximum(p_e, 1e-30)))
    g = SparseGraph(n, np.concatenate(srcs), np.concatenate(dsts),
                    np.concatenate(ws))
    dens = greedy_densest_subgraph(n, g.src, g.dst, g.weight)
    return ArboricityResult(density=dens, graph=g,
                            kernel_evals=est.evals + nbr.evals + m)


def exact_arboricity(kernel: Kernel, x) -> float:
    """Oracle: greedy peel on the full kernel graph."""
    k = np.asarray(kernel.matrix(jnp.asarray(x)), np.float64)
    n = k.shape[0]
    iu, ju = np.triu_indices(n, 1)
    return greedy_densest_subgraph(n, iu, ju, k[iu, ju])
