"""Total weighted-triangle estimation -- Theorem 6.17 (ELRS17 adapted).

Weight of a triangle = product of its three edge weights (Definition 6.16).
Estimator: sample a uniform set R of (vertex-pair) edges; for each e = (u, v)
with u < v in the degree ordering, estimate the weight W_e of triangles
*assigned* to e (third vertex w with u < v < w) by sampling neighbors
w ~ k(v, .)/deg(v) (the Section 4.3 primitive) and averaging
deg(v) * 1{v < w} * k(u,v) k(u,w); scale by #pairs / |R|.

Fused (DESIGN.md §7): the whole per-edge inner loop -- orientation, ONE
level-1 read of the v frontier shared by every draw, the neighbor draws
under ``lax.scan``, the ordering mask, and the reweighting -- is one device
program (``NeighborSampler.triangle_batches``).  The seed re-sampled the
frontier and materialized an (m, m) pairwise matrix per draw just to read
its diagonal.  The degree estimates come from the sampler's own level-1
structure (one KDE build for the whole pipeline).

Oracle: w_T = (1/6) sum_{i != j != l} K_ij K_jl K_il via one dense matmul.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import Kernel
from repro.core.sampling.edge import NeighborSampler, shared_level1_estimator
from repro.core.sampling.vertex import approximate_degrees


@dataclasses.dataclass
class TriangleResult:
    """Theorem 6.17 output: the estimate and its sampling/eval budget."""

    total_weight: float
    kernel_evals: int
    num_edges_sampled: int
    neighbor_samples: int


def estimate_triangle_weight(x, kernel: Kernel, num_edges: int,
                             neighbor_samples: int, estimator: str = "stratified",
                             seed: int = 0, mesh=None) -> TriangleResult:
    """Theorem 6.17: (1 +- eps) total triangle weight from ``num_edges``
    uniform vertex pairs and ``neighbor_samples`` weighted neighbor draws
    per pair -- query budget independent of n.

    Cost (stratified level-1, m = num_edges, ns = neighbor_samples):
    ``n*B*s`` degree preprocessing + ``m*(B*s + 1)`` frontier read and
    k(u,v) pairs + ``ns*m*(bs + 1)`` draw/reweight evals.

    >>> res = estimate_triangle_weight(x, gaussian(1.0), 400, 24)
    """
    n = int(x.shape[0])
    rng = np.random.default_rng(seed)
    nbr = NeighborSampler(x, kernel, mode="blocked", seed=seed + 1,
                          exact_blocks=(estimator in ("exact",
                                                      "exact_block")),
                          mesh=mesh,
                          level1="hash" if estimator == "hash"
                          and mesh is None else "blocked")
    est = shared_level1_estimator(nbr, estimator, seed=seed)
    deg = approximate_degrees(est)

    # R: uniform vertex pairs (every pair is an edge of the kernel graph);
    # orientation to u < v in the degree order happens in-program.
    u = rng.integers(0, n, size=num_edges)
    v = rng.integers(0, n - 1, size=num_edges)
    v = np.where(v >= u, v + 1, v)

    _, _, w_hat = nbr.triangle_batches(u, v,
                                       jnp.asarray(deg, jnp.float32),
                                       neighbor_samples)

    pairs = n * (n - 1) / 2.0
    total = float(w_hat.mean() * pairs)
    evals = nbr.evals + (0 if est is nbr.blocks else est.evals)
    return TriangleResult(total_weight=total, kernel_evals=evals,
                          num_edges_sampled=num_edges,
                          neighbor_samples=neighbor_samples)


def exact_triangle_weight(kernel: Kernel, x) -> float:
    """Oracle: (1/6) sum over ordered distinct triples of K_ij K_jl K_il
    (n^2 evals + one dense matmul)."""
    k = np.asarray(kernel.matrix(jnp.asarray(x)), np.float64)
    np.fill_diagonal(k, 0.0)
    # sum_{i,j} K_ij (K^2)_ij counts each unordered triangle 6 times.
    k2 = k @ k
    return float((k * k2).sum() / 6.0)
