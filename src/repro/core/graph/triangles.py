"""Total weighted-triangle estimation -- Theorem 6.17 (ELRS17 adapted).

Weight of a triangle = product of its three edge weights (Definition 6.16).
Estimator: sample a uniform set R of (vertex-pair) edges; for each e = (u, v)
with u < v in the degree ordering, estimate the weight W_e of triangles
*assigned* to e (third vertex w with u < v < w) by sampling neighbors
w ~ k(v, .)/deg(v) (the Section 4.3 primitive) and averaging
deg(v) * 1{v < w} * k(u,v) k(u,w); scale by #pairs / |R|.

Oracle: w_T = (1/6) sum_{i != j != l} K_ij K_jl K_il via one dense matmul.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.kde.base import make_estimator
from repro.core.kernels_fn import Kernel
from repro.core.sampling.edge import NeighborSampler
from repro.core.sampling.vertex import approximate_degrees


@dataclasses.dataclass
class TriangleResult:
    total_weight: float
    kernel_evals: int
    num_edges_sampled: int
    neighbor_samples: int


def _precedes(deg: np.ndarray, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Degree-then-index ordering from Theorem 6.17's proof."""
    return (deg[a] < deg[b]) | ((deg[a] == deg[b]) & (a < b))


def estimate_triangle_weight(x, kernel: Kernel, num_edges: int,
                             neighbor_samples: int, estimator: str = "stratified",
                             seed: int = 0) -> TriangleResult:
    n = int(x.shape[0])
    rng = np.random.default_rng(seed)
    est = make_estimator(estimator, x, kernel, seed=seed)
    deg = approximate_degrees(est)
    nbr = NeighborSampler(x, kernel, mode="blocked", seed=seed + 1,
                          exact_blocks=(estimator == "exact"))
    xj = jnp.asarray(x)

    # R: uniform vertex pairs (every pair is an edge of the kernel graph).
    u = rng.integers(0, n, size=num_edges)
    v = rng.integers(0, n - 1, size=num_edges)
    v = np.where(v >= u, v + 1, v)
    # orient so that u < v in the ordering
    swap = ~_precedes(deg, u, v)
    u2 = np.where(swap, v, u)
    v2 = np.where(swap, u, v)
    u, v = u2, v2

    kuv = np.diagonal(np.asarray(
        kernel.pairwise(xj[jnp.asarray(u)], xj[jnp.asarray(v)])))
    evals = num_edges

    # Estimate W_e by neighbor sampling from v.
    w_hat = np.zeros(num_edges)
    for _ in range(neighbor_samples):
        w, _ = nbr.sample(v)
        valid = _precedes(deg, v, w) & (w != u)
        kuw = np.diagonal(np.asarray(
            kernel.pairwise(xj[jnp.asarray(u)], xj[jnp.asarray(w)])))
        evals += num_edges
        w_hat += valid * kuv * kuw
    w_hat *= deg[v] / neighbor_samples

    pairs = n * (n - 1) / 2.0
    total = float(w_hat.mean() * pairs)
    return TriangleResult(total_weight=total,
                          kernel_evals=evals + est.evals + nbr.evals,
                          num_edges_sampled=num_edges,
                          neighbor_samples=neighbor_samples)


def exact_triangle_weight(kernel: Kernel, x) -> float:
    """(1/6) sum over ordered distinct triples of K_ij K_jl K_il."""
    k = np.asarray(kernel.matrix(jnp.asarray(x)), np.float64)
    np.fill_diagonal(k, 0.0)
    # sum_{i,j} K_ij (K^2)_ij counts each unordered triangle 6 times.
    k2 = k @ k
    return float((k * k2).sum() / 6.0)
