"""Multi-level KDE (Algorithm 4.1) -- estimators over a dyadic partition tree.

Faithful construction: one KDE structure on X, then recursively on each half.
Lemma 4.2: if a single structure costs f(n) linear in n, the tree costs
f(n log n).  The tree is consumed by the faithful (``mode="tree"``) neighbor
sampler, which descends it with two child-segment queries per level
(Algorithm 4.11).

The TPU-adapted depth-2 variant lives in ``base.StratifiedKDE/ExactBlockKDE``
(per-block sums in one dense sweep); see DESIGN.md §2.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import jax.numpy as jnp

from repro.core.kde.base import KDEBase
from repro.core.kernels_fn import Kernel


class MultiLevelKDE:
    """KDE structures over dyadic segments [lo, hi) of X.

    ``factory(x_segment, seed)`` builds a Definition-1.1 estimator for one
    segment.  Level l has 2^l segments; depth stops when segments reach
    ``leaf_size`` (leaves are evaluated exactly -- a leaf *is* its points).
    """

    def __init__(self, x: jnp.ndarray, kernel: Kernel,
                 factory: Callable[[jnp.ndarray, int], KDEBase],
                 leaf_size: int = 32, seed: int = 0):
        self.x = jnp.asarray(x, jnp.float32)
        self.kernel = kernel
        self.n = int(x.shape[0])
        self.leaf_size = leaf_size
        self._nodes: Dict[Tuple[int, int], KDEBase] = {}
        self.depth = 0
        # Build breadth-first over dyadic segments.
        frontier: List[Tuple[int, int]] = [(0, self.n)]
        level = 0
        while frontier:
            nxt: List[Tuple[int, int]] = []
            for (lo, hi) in frontier:
                self._nodes[(lo, hi)] = factory(self.x[lo:hi],
                                                seed + 977 * lo + hi)
                if hi - lo > leaf_size:
                    mid = lo + (hi - lo) // 2
                    nxt.extend([(lo, mid), (mid, hi)])
            frontier = nxt
            level += 1
        self.depth = level

    @property
    def evals(self) -> int:
        """Kernel evaluations summed over every tree node."""
        return sum(node.evals for node in self._nodes.values())

    def segment_query(self, y: jnp.ndarray, lo: int, hi: int) -> jnp.ndarray:
        """Estimate sum_{j in [lo, hi)} k(y_i, x_j) via the node estimator."""
        return self._nodes[(lo, hi)].query(y)

    def children(self, lo: int, hi: int):
        """The two dyadic child segments of [lo, hi)."""
        mid = lo + (hi - lo) // 2
        return (lo, mid), (mid, hi)

    def is_leaf(self, lo: int, hi: int) -> bool:
        """True when [lo, hi) is evaluated exactly (Algorithm 4.1)."""
        return hi - lo <= self.leaf_size
