"""KDE data structures (Definition 1.1).

A KDE structure over a fixed dataset ``X`` answers queries
``KDE_X(y) ~= sum_{x in X} k(x, y)`` within ``(1 +- eps)`` multiplicative
error, assuming ``k(x, y) >= tau``.  The paper uses these strictly as black
boxes; everything in ``repro.core`` is written against this interface.

Backends
--------
* ``ExactKDE``      -- brute force oracle (the Pallas ``kde_rowsum`` kernel on
                       TPU; a blocked jnp sweep on CPU).
* ``RSKDE``         -- uniform random sampling, the ``p = 1`` estimator the
                       paper describes in Section 3.1.
* ``StratifiedKDE`` -- beyond-paper variance reduction: the dataset is split
                       into contiguous blocks and each block contributes an
                       independent uniform subsample (same cost as RS, strictly
                       lower variance; on TPU every block is one VMEM tile).
* ``GridHBE``       -- practical hash-based estimator (``hbe.py``), host
                       per-query loop; kept as the oracle of
* ``HashedKDE``     -- the device-resident hashed estimator
                       (``hashed.py`` / ``kernels/kde_hash``): the same
                       KAP22 near/far decomposition as ONE jitted program
                       per query batch, O(max_bucket + num_far) kernel
                       evals per query (the paper's sub-linear black box).

All estimators count kernel evaluations (``.evals``) -- the paper's headline
cost metric in Section 7.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels_fn import Kernel
from repro.obs import counters as _c


@functools.partial(jax.jit, static_argnames=("pairwise",))
def _rowsum(pairwise, y, x):
    return jnp.sum(pairwise(y, x), axis=1)


@functools.partial(jax.jit, static_argnames=("kind", "inv_bw", "beta", "bn"))
def _bf16_rowsum(y, x, kind, inv_bw, beta, bn):
    """bf16 level-1 sweep reduced to row sums: the blocked column-tile scan
    of ``kv_block_sums_bf16`` (bf16 operand tiles, f32 accumulation) keeps
    peak memory at O(m * n / bn) instead of the full (m, n) value matrix."""
    from repro.kernels.kde_sampler.ref import kv_block_sums_bf16
    return jnp.sum(kv_block_sums_bf16(y, x, kind, inv_bw, beta, bn=bn),
                   axis=-1)


class KDEBase:
    """Common interface: query(y: (m, d)) -> (m,) estimated row sums.

    ``precision`` (DESIGN.md §14) selects the dtype policy of the level-1
    dataset sweeps: ``"f32"`` (default; bitwise-stable legacy path) or
    ``"bf16"`` (rounded operand tiles, f32 accumulators).  Level-2 rows,
    CDFs, and sampling probabilities always stay f32.
    """

    def __init__(self, x: jnp.ndarray, kernel: Kernel,
                 precision: str = "f32"):
        self.x = jnp.asarray(x, jnp.float32)
        # ||x_j||^2, computed once and reused by every L2-kernel query
        # (the level-1/level-2 reads never recompute dataset norms).
        self.x_sq = jnp.sum(self.x * self.x, axis=-1)
        self.kernel = kernel
        self.n = int(x.shape[0])
        self.d = int(x.shape[1])
        self.evals = 0  # number of kernel evaluations performed (analytic)
        # realized device-side totals (DESIGN.md §15.1), folded from the
        # counter words of every fused program this estimator runs
        self.device_counters = _c.HostTotals()
        self.precision = precision
        if precision != "f32":
            from repro.kernels.kde_sampler.ref import (check_precision,
                                                       static_pairwise)
            check_precision(precision, kernel.name, static_pairwise(kernel))

    def query(self, y: jnp.ndarray) -> jnp.ndarray:
        """(m, d) queries -> (m,) estimated row sums sum_j k(y_i, x_j)."""
        raise NotImplementedError

    def query1(self, y: jnp.ndarray) -> float:
        """Single-point convenience wrapper around ``query``."""
        return float(self.query(y[None, :])[0])


class ExactKDE(KDEBase):
    """Brute-force oracle; the Pallas kernel computes this on TPU."""

    def __init__(self, x, kernel: Kernel, chunk: int = 8192,
                 use_pallas: bool = False, precision: str = "f32"):
        super().__init__(x, kernel, precision=precision)
        self.chunk = chunk
        self.use_pallas = use_pallas

    def query(self, y: jnp.ndarray) -> jnp.ndarray:
        """Exact row sums; m*n kernel evals per call."""
        y = jnp.asarray(y, jnp.float32)
        self.evals += y.shape[0] * self.n
        if self.use_pallas:
            from repro.kernels.kde_rowsum import ops as rs_ops
            return rs_ops.kde_rowsum(y, self.x, self.kernel,
                                     precision=self.precision)
        if self.precision != "f32":
            return _bf16_rowsum(y, self.x, self.kernel.name,
                                1.0 / self.kernel.bandwidth,
                                getattr(self.kernel, "beta", 1.0),
                                bn=min(self.chunk, 1024))
        out = jnp.zeros((y.shape[0],), jnp.float32)
        for lo in range(0, self.n, self.chunk):
            out = out + _rowsum(self.kernel.pairwise, y, self.x[lo:lo + self.chunk])
        return out


class RSKDE(KDEBase):
    """Random-sampling estimator (p = 1): n/|R| * sum_{x in R} k(x, y).

    ``num_samples = O(1/(tau * eps^2))`` per Section 3.1.
    """

    def __init__(self, x, kernel: Kernel, num_samples: int, seed: int = 0,
                 precision: str = "f32"):
        super().__init__(x, kernel, precision=precision)
        self.num_samples = min(int(num_samples), self.n)
        self._rng = np.random.default_rng(seed)

    def query(self, y: jnp.ndarray) -> jnp.ndarray:
        """(1 +- eps) row-sum estimates; m*num_samples evals per call."""
        y = jnp.asarray(y, jnp.float32)
        idx = self._rng.integers(0, self.n, size=self.num_samples)
        self.evals += y.shape[0] * self.num_samples
        sub = self.x[jnp.asarray(idx)]
        if self.precision != "f32":
            return _bf16_rowsum(y, sub, self.kernel.name,
                                1.0 / self.kernel.bandwidth,
                                getattr(self.kernel, "beta", 1.0),
                                bn=min(self.num_samples, 1024)) \
                * (self.n / self.num_samples)
        return _rowsum(self.kernel.pairwise, y, sub) * (self.n / self.num_samples)


class StratifiedKDE(KDEBase):
    """Blocked stratified sampling: per-block uniform subsamples.

    Unbiased: each block contributes |block| * mean(sampled kernel values) --
    the tail block scales by its *realized* sample count, so padded slots
    never inflate the estimate.  Variance is the within-block variance only
    -- strictly <= RS variance at equal sample count (law of total
    variance).  This is the TPU-native estimator: each block is a contiguous
    VMEM tile and the subsample is a strided load.

    ``block_sums`` is a single jitted device program (subsample indices are
    drawn with ``jax.random`` inside the trace); no per-block host loop.
    """

    def __init__(self, x, kernel: Kernel, block_size: int = 256,
                 samples_per_block: int = 16, seed: int = 0,
                 precision: str = "f32"):
        super().__init__(x, kernel, precision=precision)
        self.block_size = int(block_size)
        self.num_blocks = (self.n + self.block_size - 1) // self.block_size
        self.samples_per_block = min(int(samples_per_block), self.block_size)
        self._key = jax.random.PRNGKey(seed)

    def _block_bounds(self, b: int):
        lo = b * self.block_size
        return lo, min(lo + self.block_size, self.n)

    def _split(self) -> jnp.ndarray:
        self._key, k = jax.random.split(self._key)
        return k

    def _static_cfg(self) -> dict:
        from repro.kernels.kde_sampler.ref import static_pairwise
        return dict(kind=self.kernel.name, inv_bw=1.0 / self.kernel.bandwidth,
                    beta=getattr(self.kernel, "beta", 1.0),
                    pairwise=static_pairwise(self.kernel),
                    block_size=self.block_size,
                    num_blocks=self.num_blocks, n=self.n,
                    precision=self.precision)

    def block_sums(self, y: jnp.ndarray) -> jnp.ndarray:
        """(m, B) estimated per-block kernel sums -- the level-1 'tree' read."""
        from repro.kernels.kde_sampler import ops as sampler_ops
        y = jnp.asarray(y, jnp.float32)
        self.evals += y.shape[0] * self.num_blocks * self.samples_per_block
        bs, cw = sampler_ops.stratified_block_sums(
            y, self.x, self.x_sq, self._split(), s=self.samples_per_block,
            **self._static_cfg())
        self.device_counters.note(cw)
        return bs

    def query(self, y: jnp.ndarray) -> jnp.ndarray:
        """Stratified row-sum estimates; m*B*s evals per call."""
        return jnp.sum(self.block_sums(y), axis=-1)


class ExactBlockKDE(StratifiedKDE):
    """Exact per-block sums (one dense sweep); deterministic ``block_sums``.

    Used where the sparsifier needs *reproducible* sampling probabilities
    (Algorithm 5.1 computes the probability q_uv with which the sampler picks
    an edge; a deterministic level-1 read makes q exactly recomputable).

    With ``use_pallas=True`` the sweep dispatches to the ``blocksum_pallas``
    TPU kernel; otherwise it is one jitted jnp program reusing the
    precomputed ``x_sq`` norms.
    """

    def __init__(self, x, kernel: Kernel, block_size: int = 256,
                 use_pallas: bool = False, precision: str = "f32"):
        super().__init__(x, kernel, block_size=block_size,
                         samples_per_block=block_size, precision=precision)
        self.use_pallas = use_pallas

    def block_sums(self, y: jnp.ndarray) -> jnp.ndarray:
        """Exact (m, B) per-block sums; m*n evals per call."""
        y = jnp.asarray(y, jnp.float32)
        self.evals += y.shape[0] * self.n
        if self.use_pallas:
            from repro.kernels.kde_rowsum import ops as rs_ops
            return rs_ops.kde_blocksum(y, self.x, self.kernel,
                                       bn=self.block_size,
                                       precision=self.precision)
        from repro.kernels.kde_sampler import ops as sampler_ops
        bs, cw = sampler_ops.exact_block_sums(y, self.x, self.x_sq,
                                              **self._static_cfg())
        self.device_counters.note(cw)
        return bs


def make_estimator(name: str, x, kernel: Kernel, seed: int = 0,
                   tau: float = 0.05, eps: float = 0.5, **kw) -> KDEBase:
    """Factory.  ``rs``/``stratified`` budgets default to O(1/(tau eps^2)).

    All estimators accept ``precision="f32"|"bf16"`` (forwarded via ``kw``):
    the level-1 sweep dtype policy of DESIGN.md §14."""
    if name == "exact":
        return ExactKDE(x, kernel, **kw)
    if name == "rs":
        ns = kw.pop("num_samples", int(np.ceil(1.0 / (tau * eps * eps))))
        return RSKDE(x, kernel, num_samples=ns, seed=seed, **kw)
    if name == "stratified":
        return StratifiedKDE(x, kernel, seed=seed, **kw)
    if name == "exact_block":
        return ExactBlockKDE(x, kernel, **kw)
    if name == "grid_hbe":
        from repro.core.kde.hbe import GridHBE
        return GridHBE(x, kernel, seed=seed, **kw)
    if name == "hash":
        from repro.core.kde.hashed import HashedKDE
        return HashedKDE(x, kernel, seed=seed, **kw)
    if name == "robust":
        from repro.ft.guards import RobustEstimator
        return RobustEstimator(x, kernel, seed=seed, **kw)
    raise ValueError(f"unknown estimator {name!r}")
