"""Device-resident hashed KDE estimator -- the Section 3.1 black-box slot.

``HashedKDE`` adapts the ``repro.kernels.kde_hash`` engine to the
Definition 1.1 estimator interface: the KAP22/DEANN near/far decomposition
(exact NEAR term over the query's random-shifted grid bucket + a
Horvitz-Thompson FAR term over uniform complement samples) as ONE jitted
device program per query batch -- the sub-linear per-query cost the
paper's framework assumes (O(max_bucket + num_far_samples) kernel evals
per query instead of the dense backends' O(n)).

``GridHBE`` (``hbe.py``) remains the host oracle of the same estimator
family; ``HashedKDE`` is what the fused pipelines consume
(``estimator="hash"``), and with ``mesh=`` the bucket tables live sharded
(each shard hashes its own rows) with exactly one psum per query batch
(DESIGN.md §10).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kde.base import KDEBase
from repro.core.kernels_fn import Kernel
from repro.ft import guards as _g


class HashedKDE(KDEBase):
    """Definition 1.1 estimator over the static padded-bucket layout.

    Per query: <= ``max_bucket`` exact NEAR evals + ``num_far_samples``
    HT-weighted FAR evals, all inside one compiled program (Pallas bucket
    kernel on TPU).  ``evals`` counts the *realized* NEAR reads plus the
    FAR budget -- the paper's Section 7 cost metric.

    >>> est = HashedKDE(x, gaussian(1.0)); est.query(x[:32])
    """

    def __init__(self, x, kernel: Kernel, cell_width: float | None = None,
                 num_hash_dims: int = 8, num_far_samples: int = 64,
                 max_bucket: int = 256, seed: int = 0,
                 use_pallas: bool | None = None,
                 interpret: bool | None = None, mesh=None,
                 data_axes=("data",)):
        super().__init__(x, kernel)
        from repro.kernels.kde_hash import ops as _ops
        from repro.kernels.kde_sampler.ref import static_pairwise
        self._ops = _ops
        self.num_far_samples = int(num_far_samples)
        self.max_bucket = int(max_bucket)
        self._key = jax.random.PRNGKey(seed)
        self.engine = None
        # guards (DESIGN.md §11): last_status is the most recent batch's
        # word, status the or-fold over the estimator's lifetime
        self.last_status = 0
        self.status = 0
        self.flag_counts: dict = {}
        if mesh is not None:
            from repro.kernels.kde_hash.sharded import ShardedHashTable
            self.engine = ShardedHashTable(
                mesh, self.x, kernel, cell_width=cell_width,
                num_hash_dims=num_hash_dims, max_bucket=max_bucket,
                num_far_samples=num_far_samples, data_axes=data_axes,
                seed=seed)
            self.state = None
            self.cell_width = self.engine.spec.cell_width
            return
        self.state, self.cell_width = _ops.build_hash_state(
            self.x, kernel, cell_width=cell_width,
            num_hash_dims=num_hash_dims, max_bucket=max_bucket, seed=seed)
        if use_pallas is None:
            use_pallas = _ops._sops.default_use_pallas()
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self._cfg = dict(kind=kernel.name, inv_bw=1.0 / kernel.bandwidth,
                         beta=getattr(kernel, "beta", 1.0),
                         pairwise=static_pairwise(kernel),
                         cell_width=self.cell_width,
                         num_far=min(self.num_far_samples, self.n),
                         n=self.n, use_pallas=bool(use_pallas),
                         interpret=bool(interpret))

    def _split(self) -> jnp.ndarray:
        self._key, k = jax.random.split(self._key)
        return k

    def _note(self, st) -> int:
        s = int(np.uint32(jax.device_get(st)))
        self.last_status = s
        self.status |= s
        _g.count_flags(self.flag_counts, s)
        _g.raise_on_status(s, context="HashedKDE.query",
                           allow=_g.BUCKET_OVERFLOW | _g.HT_HEAVY)
        return s

    def query(self, y: jnp.ndarray) -> jnp.ndarray:
        """NEAR-exact + FAR-sampled row-sum estimates (Section 3.1): one
        device program (one psum on the mesh path) per batch.  The batch's
        status word lands in ``last_status`` (or-folded into ``status``);
        fatal flags raise under ``REPRO_CHECKS=1``."""
        y = jnp.asarray(y, jnp.float32)
        if self.engine is not None:
            est, cnt, st = self.engine.query(y, self._split())
            self.evals += int(np.asarray(cnt).sum()) \
                + y.shape[0] * self.engine.num_far * self.engine.num_shards
            self._note(st)
            return est
        est, cnt, st = self._ops.hashed_query(self.x, y, self.state,
                                              self._split(), **self._cfg)
        self.evals += int(np.asarray(cnt).sum()) \
            + y.shape[0] * self._cfg["num_far"]
        self._note(st)
        return est

    def degrees(self, batch: int = 1024) -> np.ndarray:
        """Algorithm 4.3 over the hashed structure: n queries of the
        dataset against itself minus the kernel's actual diagonal --
        O(n (max_bucket + num_far_samples)) kernel evals total.  (Defined
        so ``DegreeSampler(mesh=...)`` accepts the mesh adapter; the body
        is the shared host loop.)"""
        from repro.core.sampling.vertex import host_degree_loop
        return host_degree_loop(self, batch)
