"""Device-resident hashed KDE estimator -- the Section 3.1 black-box slot.

``HashedKDE`` adapts the ``repro.kernels.kde_hash`` engine to the
Definition 1.1 estimator interface: the KAP22/DEANN near/far decomposition
(exact NEAR term over the query's random-shifted grid bucket + a
Horvitz-Thompson FAR term over uniform complement samples) as ONE jitted
device program per query batch -- the sub-linear per-query cost the
paper's framework assumes (O(max_bucket + num_far_samples) kernel evals
per query instead of the dense backends' O(n)).

``GridHBE`` (``hbe.py``) remains the host oracle of the same estimator
family; ``HashedKDE`` is what the fused pipelines consume
(``estimator="hash"``), and with ``mesh=`` the bucket tables live sharded
(each shard hashes its own rows) with exactly one psum per query batch
(DESIGN.md §10).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kde.base import KDEBase
from repro.core.kernels_fn import Kernel
from repro.ft import guards as _g


class HashedKDE(KDEBase):
    """Definition 1.1 estimator over the static padded-bucket layout.

    Per query: <= ``max_bucket`` exact NEAR evals + ``num_far_samples``
    HT-weighted FAR evals, all inside one compiled program (Pallas bucket
    kernel on TPU).  ``evals`` counts the *realized* NEAR reads plus the
    FAR budget -- the paper's Section 7 cost metric.

    >>> est = HashedKDE(x, gaussian(1.0)); est.query(x[:32])
    """

    def __init__(self, x, kernel: Kernel, cell_width: float | None = None,
                 num_hash_dims: int = 8, num_far_samples: int = 64,
                 max_bucket: int = 256, seed: int = 0,
                 use_pallas: bool | None = None,
                 interpret: bool | None = None, mesh=None,
                 data_axes=("data",), dataset=None,
                 overflow_cap: int | None = None, precision: str = "f32"):
        if dataset is not None:
            x = dataset.x_pad      # engines build over the padded capacity
        super().__init__(x, kernel, precision=precision)
        from repro.kernels.kde_hash import ops as _ops
        self._ops = _ops
        self.num_far_samples = int(num_far_samples)
        self.max_bucket = int(max_bucket)
        self._key = jax.random.PRNGKey(seed)
        self.engine = None
        # guards (DESIGN.md §11): last_status is the most recent batch's
        # word, status the or-fold over the estimator's lifetime
        self.last_status = 0
        self.status = 0
        self.flag_counts: dict = {}
        # streaming attach (DESIGN.md §12): derived state is keyed on the
        # dataset's (id, epoch); queries transparently patch-or-rebuild
        self._dataset = dataset
        self._ds_epoch = int(dataset.epoch) if dataset is not None else 0
        self._patcher = None
        self.rebuilds = 0
        if overflow_cap is None:
            overflow_cap = max(64, self.n // 64) if dataset is not None \
                else 0
        self._build_kw = dict(cell_width=cell_width,
                              num_hash_dims=int(num_hash_dims),
                              max_bucket=int(max_bucket), seed=int(seed),
                              overflow_cap=int(overflow_cap))
        self._mesh = mesh
        self._data_axes = data_axes
        self._use_pallas = use_pallas
        self._interpret = interpret
        self._build()

    def _build(self) -> None:
        """(Re)build the bucket layout at the current dataset epoch; also
        the ``needs_rebuild`` compaction path of the streaming contract."""
        from repro.kernels.kde_sampler.ref import static_pairwise
        _ops = self._ops
        kernel = self.kernel
        live = (self._dataset.live_host if self._dataset is not None
                else None)
        if self._dataset is not None:
            self.x = self._dataset.x_pad
            self.x_sq = self._dataset.x_sq_pad
            self.n = int(self.x.shape[0])
        if self._mesh is not None:
            from repro.kernels.kde_hash.sharded import ShardedHashTable
            self.engine = ShardedHashTable(
                self._mesh, self.x, kernel,
                cell_width=self._build_kw["cell_width"],
                num_hash_dims=self._build_kw["num_hash_dims"],
                max_bucket=self._build_kw["max_bucket"],
                num_far_samples=self.num_far_samples,
                data_axes=self._data_axes, seed=self._build_kw["seed"],
                live=live, overflow_cap=self._build_kw["overflow_cap"])
            self.state = None
            self.cell_width = self.engine.spec.cell_width
            return
        self.state, self.cell_width = _ops.build_hash_state(
            self.x, kernel, cell_width=self._build_kw["cell_width"],
            num_hash_dims=self._build_kw["num_hash_dims"],
            max_bucket=self._build_kw["max_bucket"],
            seed=self._build_kw["seed"], live=live,
            overflow_cap=self._build_kw["overflow_cap"])
        self._patcher = (_ops.HashPatcher(self.state, self.cell_width)
                         if self._dataset is not None else None)
        use_pallas = self._use_pallas
        interpret = self._interpret
        if use_pallas is None:
            use_pallas = _ops._sops.default_use_pallas()
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self._cfg = dict(kind=kernel.name, inv_bw=1.0 / kernel.bandwidth,
                         beta=getattr(kernel, "beta", 1.0),
                         pairwise=static_pairwise(kernel),
                         cell_width=self.cell_width,
                         num_far=min(self.num_far_samples, self.n),
                         n=self.n, use_pallas=bool(use_pallas),
                         interpret=bool(interpret),
                         precision=self.precision)

    def compact(self) -> None:
        """Fold the overflow region back into a fresh bucket layout at the
        current epoch (the lazy compaction of DESIGN.md §12)."""
        self._build()
        self.rebuilds += 1
        if self._dataset is not None:
            self._ds_epoch = int(self._dataset.epoch)

    def _sync(self) -> None:
        """Epoch check at query entry: patch the bucket layout by the
        coalesced mutation delta, or rebuild when the journal cannot
        bridge the gap / the overflow region saturated.  Saturation sets
        ``guards.OVERFLOW_SATURATED`` (an ``EstimationError`` under
        ``REPRO_CHECKS=1``; otherwise an automatic compaction)."""
        ds = self._dataset
        if ds is None or self._ds_epoch == int(ds.epoch):
            return
        from repro.core.dataset import coalesce_mutations
        batches = ds.mutations_since(self._ds_epoch)
        if batches is None:        # journal overflow / compact / grow
            self.compact()
            return
        self.x = ds.x_pad
        self.x_sq = ds.x_sq_pad
        slots, old_x, new_x, old_live, new_live = \
            coalesce_mutations(batches)
        if self.engine is not None:
            ok = self.engine.patch_rows(slots, old_x, new_x, old_live,
                                        new_live)
            saturated = not ok
        else:
            new_state = self._patcher.apply(self.state, slots, old_x,
                                            new_x, old_live, new_live)
            saturated = self._patcher.needs_rebuild
            if not saturated:
                self.state = new_state
        if saturated:
            s = _g.OVERFLOW_SATURATED
            self.last_status = s
            self.status |= s
            _g.count_flags(self.flag_counts, s)
            _g.raise_on_status(s, context="HashedKDE.sync",
                               allow=_g.BUCKET_OVERFLOW | _g.HT_HEAVY)
            self.compact()
            return
        self._ds_epoch = int(ds.epoch)

    def _split(self) -> jnp.ndarray:
        self._key, k = jax.random.split(self._key)
        return k

    def _note(self, st) -> int:
        """Fold one program return -- a counter word or a legacy scalar
        status -- into the guard state and ``device_counters``."""
        from repro.obs import counters as _c
        if _c.is_word(st):
            s = self.device_counters.note(jax.device_get(st))
        else:
            s = int(np.uint32(jax.device_get(st)))
        self.last_status = s
        self.status |= s
        _g.count_flags(self.flag_counts, s)
        _g.raise_on_status(s, context="HashedKDE.query",
                           allow=_g.BUCKET_OVERFLOW | _g.HT_HEAVY)
        return s

    def query(self, y: jnp.ndarray) -> jnp.ndarray:
        """NEAR-exact + FAR-sampled row-sum estimates (Section 3.1): one
        device program (one psum on the mesh path) per batch.  The batch's
        status word lands in ``last_status`` (or-folded into ``status``);
        fatal flags raise under ``REPRO_CHECKS=1``."""
        y = jnp.asarray(y, jnp.float32)
        self._sync()
        if self.engine is not None:
            est, cnt, st = self.engine.query(y, self._split())
            self.evals += int(np.asarray(cnt).sum()) \
                + y.shape[0] * self.engine.num_far * self.engine.num_shards
            self._note(st)
            return est
        est, cnt, st = self._ops.hashed_query(self.x, y, self.state,
                                              self._split(), **self._cfg)
        self.evals += int(np.asarray(cnt).sum()) \
            + y.shape[0] * self._cfg["num_far"]
        self._note(st)
        return est

    def degrees(self, batch: int = 1024) -> np.ndarray:
        """Algorithm 4.3 over the hashed structure: n queries of the
        dataset against itself minus the kernel's actual diagonal --
        O(n (max_bucket + num_far_samples)) kernel evals total.  (Defined
        so ``DegreeSampler(mesh=...)`` accepts the mesh adapter; the body
        is the shared host loop.)  With a streaming dataset attached only
        the LIVE rows are queried (sentinel queries against sentinel FAR
        samples would evaluate ``inf - inf``); dead slots report degree
        exactly 0."""
        from repro.core.sampling.vertex import host_degree_loop
        if self._dataset is None:
            return host_degree_loop(self, batch)
        self._sync()
        ls = self._dataset.live_slots()
        out = np.zeros(self.n, np.float64)
        for lo in range(0, len(ls), batch):
            sel = ls[lo:lo + batch]
            out[sel] = np.asarray(self.query(self.x[jnp.asarray(sel)]))
        out[ls] -= 1.0           # k(x, x) = 1 for the Table-1 kernels
        return out
