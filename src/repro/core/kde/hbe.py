"""Practical hash-based KDE estimator (DEANN-style, [KAP22] cited in §3.1).

The theory estimators (CKNS20/BIW19) use LSH bucket sampling with
data-dependent collision probabilities -- pointer-chasing structures with no
TPU analogue.  Section 3.1 of the paper explicitly allows swapping in
practical estimators "via black box access".  We implement the
KAP22/DEANN decomposition:

    KDE(y) =  sum_{x in NEAR(y)} k(x, y)        (exact, few points)
            + (n - |NEAR|) * E_{x ~ FAR}[k(x,y)] (uniform sampling)

with NEAR(y) found by a random-shifted grid hash (one hash per scale).  The
grid hash is dense integer arithmetic -- TPU-friendly -- and the FAR term is
the RS estimator restricted to the complement.  Near points carry most of the
mass for rapidly decaying kernels, so the high-variance part of RS is removed.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.kde.base import KDEBase, _rowsum
from repro.core.kernels_fn import Kernel


class GridHBE(KDEBase):
    """KAP22/DEANN-style estimator (Section 3.1 black-box slot):
    exact NEAR term over a random-shifted grid bucket + RS FAR term;
    per query <= max_bucket + num_far_samples kernel evals."""

    def __init__(self, x, kernel: Kernel, cell_width: float | None = None,
                 num_hash_dims: int = 8, num_far_samples: int = 64,
                 max_bucket: int = 256, seed: int = 0):
        super().__init__(x, kernel)
        self._rng = np.random.default_rng(seed)
        w = cell_width if cell_width is not None else 2.0 * kernel.bandwidth
        self.cell_width = float(w)
        self.num_far_samples = int(num_far_samples)
        self.max_bucket = int(max_bucket)
        dims = self._rng.choice(self.d, size=min(num_hash_dims, self.d),
                                replace=False)
        self.hash_dims = np.asarray(dims)
        self.shift = self._rng.uniform(0.0, w, size=len(dims)).astype(np.float32)
        xn = np.asarray(x, np.float32)
        codes = np.floor((xn[:, self.hash_dims] + self.shift) / w).astype(np.int64)
        # Pack the integer grid coordinates into one bucket key.
        self._keys = self._pack(codes)
        order = np.argsort(self._keys, kind="stable")
        self._sorted_keys = self._keys[order]
        self._sorted_idx = order

    @staticmethod
    def _pack(codes: np.ndarray) -> np.ndarray:
        h = np.zeros(codes.shape[0], np.uint64)
        for j in range(codes.shape[1]):
            h = h * np.uint64(0x9E3779B97F4A7C15) + codes[:, j].astype(np.uint64)
        return h

    def _bucket(self, key: np.uint64) -> np.ndarray:
        lo = np.searchsorted(self._sorted_keys, key, side="left")
        hi = np.searchsorted(self._sorted_keys, key, side="right")
        idx = self._sorted_idx[lo:hi]
        if len(idx) > self.max_bucket:
            idx = self._rng.choice(idx, size=self.max_bucket, replace=False)
        return idx

    def query(self, y: jnp.ndarray) -> jnp.ndarray:
        """NEAR-exact + FAR-sampled row-sum estimates (Section 3.1)."""
        y = jnp.asarray(y, jnp.float32)
        yn = np.asarray(y)
        m = yn.shape[0]
        codes = np.floor((yn[:, self.hash_dims] + self.shift)
                         / self.cell_width).astype(np.int64)
        keys = self._pack(codes)
        out = np.zeros(m, np.float32)
        for i in range(m):
            near = self._bucket(keys[i])
            n_near = len(near)
            yi = y[i:i + 1]
            total = 0.0
            if n_near:
                self.evals += n_near
                total += float(jnp.sum(self.kernel.pairwise(yi, self.x[jnp.asarray(near)])))
            n_far = self.n - n_near
            if n_far > 0 and self.num_far_samples > 0:
                s = min(self.num_far_samples, self.n)
                samp = self._rng.integers(0, self.n, size=s)
                self.evals += s
                kv = np.asarray(self.kernel.pairwise(yi, self.x[jnp.asarray(samp)]))[0]
                if n_near:
                    near_set = np.zeros(self.n, bool)
                    near_set[near] = True
                    hits = near_set[samp]
                    if hits.all():
                        # Degenerate case: every FAR sample landed in the
                        # NEAR bucket (a bucket holding most of the
                        # dataset), so the masked ratio estimate would be
                        # 0/0 -> 0 and the FAR mass silently dropped.
                        # Resample from the explicit complement (an exact
                        # sweep when it is no larger than the budget).
                        comp = np.flatnonzero(~near_set)
                        if len(comp) <= s:
                            samp2 = comp
                        else:
                            samp2 = self._rng.choice(comp, size=s,
                                                     replace=False)
                        self.evals += len(samp2)
                        kv2 = np.asarray(self.kernel.pairwise(
                            yi, self.x[jnp.asarray(samp2)]))[0]
                        total += n_far * float(kv2.mean())
                    else:
                        kv = kv * (~hits)
                        frac = 1.0 - hits.mean()
                        total += n_far * float(kv.sum()) / (s * frac)
                else:
                    total += self.n * float(kv.mean())
            out[i] = total
        return jnp.asarray(out)
