"""Distributed KDE queries -- the multi-pod substrate for every reduction.

The dataset X is sharded over the ("pod", "data") mesh axes (each device
holds n/shards points); a KDE query computes local partial kernel row sums
and one psum.  Degree vectors, squared-row-norm distributions (Section 5.2),
and level-1 block sums all reduce to this primitive, so every paper
algorithm distributes the same way: sampling decisions happen on the host
against the psum'd totals while the O(n d) sweeps stay sharded.

Built with shard_map so the collective schedule is explicit (one
psum per query batch; no resharding of X ever).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.kernels_fn import Kernel

from repro.compat import shard_map


def sharded_kde_query(mesh: Mesh, kernel: Kernel,
                      data_axes: Sequence[str] = ("data",)):
    """Returns a jitted f(y: (m, d), x: (n, d)) -> (m,) with x sharded along
    ``data_axes`` and y replicated."""
    axes = tuple(data_axes)

    def local(y, x_shard):
        part = jnp.sum(kernel.pairwise(y, x_shard), axis=1)
        return jax.lax.psum(part, axes)

    shmap = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(axes)),
        out_specs=P(),
    )
    return jax.jit(shmap)


def sharded_block_sums(mesh: Mesh, kernel: Kernel, num_blocks_per_shard: int,
                       data_axes: Sequence[str] = ("data",)):
    """Level-1 read of the depth-2 sampler, distributed: each shard returns
    its local per-block sums; the global block-sum matrix is the concat over
    shards (no collective needed -- sampling uses the psum of totals only).

    f(y: (m, d), x: (n, d)) -> (m, shards * B) block sums, fully addressable.
    """
    axes = tuple(data_axes)

    def local(y, x_shard):
        ns = x_shard.shape[0]
        bs = ns // num_blocks_per_shard
        kv = kernel.pairwise(y, x_shard)              # (m, ns)
        kv = kv.reshape(y.shape[0], num_blocks_per_shard, bs).sum(-1)
        return kv

    shmap = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(axes)),
        out_specs=P(None, axes),
    )
    return jax.jit(shmap)


def degree_preprocessing(mesh: Mesh, kernel: Kernel,
                         data_axes: Sequence[str] = ("data",)):
    """Algorithm 4.3 distributed: every shard queries its own points against
    the full (sharded) dataset via a ring of collective permutes -- O(n^2/P)
    work per device, the optimal balance; returns the degree vector sharded
    the same way as X.

    With multiple ``data_axes`` the ring runs over the *flattened* device
    index across all of those axes (``ppermute`` with a tuple of axis names
    linearizes them row-major, matching how ``P(axes)`` lays out the
    shards), so every one of ``prod(axis sizes)`` shards visits every other
    shard exactly once.  A ring built over ``axis_size(axes[0])`` alone --
    the previous behavior -- silently dropped the contributions of the
    remaining axes' shards.
    """
    axes = tuple(data_axes)
    size = 1
    for a in axes:
        size *= int(mesh.shape[a])
    perm = [(i, (i + 1) % size) for i in range(size)]
    axis = axes[0] if len(axes) == 1 else axes

    def local(x_shard):
        # Ring all-to-all accumulation: rotate shards around the flattened
        # ring, each step adds the kernel sums against one remote shard.
        def step(carry, _):
            acc, blk = carry
            acc = acc + jnp.sum(kernel.pairwise(x_shard, blk), axis=1)
            blk = jax.lax.ppermute(blk, axis, perm=perm)
            return (acc, blk), None

        # derive from x_shard so the carry is 'varying' over the mesh axes
        acc0 = jnp.sum(x_shard, axis=1) * 0.0
        (acc, _), _ = jax.lax.scan(step, (acc0, x_shard), None, length=size)
        return acc - 1.0  # remove self kernel

    shmap = shard_map(local, mesh=mesh, in_specs=(P(axes),),
                      out_specs=P(axes))
    return jax.jit(shmap)


def make_sharded_dataset(mesh: Mesh, x, data_axes: Sequence[str] = ("data",)):
    """Place the dataset on the mesh, sharded over ``data_axes``
    (Section 3 KDE queries then never reshard X)."""
    sharding = NamedSharding(mesh, P(tuple(data_axes)))
    return jax.device_put(x, sharding)
