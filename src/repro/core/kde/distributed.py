"""Distributed KDE structures -- thin wrappers over the sharded engine.

The dataset X is sharded over mesh ``data_axes`` (each device holds n/P
rows); Section 3 KDE queries, Algorithm 4.3 degree preprocessing and the
level-1 block-sum reads of the depth-2 sampler all run as shard_map
programs built by ``repro.kernels.kde_sampler.sharded`` -- the ONE engine
behind both the single- and multi-device paths.  Sampling decisions no
longer happen on the host: the two-stage collective draw of DESIGN.md §9
(psum-of-totals owner selection) lives in the engine, and this module only
adapts it to the Definition 1.1 estimator interface.

``ShardedKDE`` is that adapter: a drop-in ``KDEBase`` for
``NeighborSampler`` / ``DegreeSampler`` / ``RowNormSampler`` whose
``query`` is one collective program and whose ``engine`` carries the
mesh-resident level-1 block structure every fused pipeline shares.

The functional API (``sharded_kde_query`` / ``sharded_block_sums`` /
``degree_preprocessing`` / ``make_sharded_dataset``) is kept for callers
that manage their own sharded arrays; the collective schedule is unchanged
(one psum per query batch; X is never resharded, the degree ring moves
shard-sized blocks only).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.kernels_fn import Kernel
from repro.kernels.kde_sampler import sharded as _sh
from repro.obs import counters as _c


def sharded_kde_query(mesh: Mesh, kernel: Kernel,
                      data_axes: Sequence[str] = ("data",)):
    """Returns a jitted f(y: (m, d), x: (n, d)) -> (m,) with x sharded
    along ``data_axes`` and y replicated (Section 3 query; one psum)."""
    return _sh.make_kde_query(mesh, kernel, data_axes)


def sharded_block_sums(mesh: Mesh, kernel: Kernel, num_blocks_per_shard: int,
                       data_axes: Sequence[str] = ("data",)):
    """Level-1 read of the depth-2 sampler, distributed: each shard
    returns its local per-block sums; the global block-sum matrix is the
    concat over shards (no collective -- the §9 draw psums totals itself).

    f(y: (m, d), x: (n, d)[, own: (m,)]) -> (m, shards * B) block sums.
    Ragged shards (shard size not divisible by the block count) are padded
    in-body with the far-offset sentinel rows, so tail blocks sum only
    their real rows instead of crashing the reshape.  Passing ``own``
    (each query's global block index) applies the §2 sampling contract:
    self-block correction and the 1e-12 floor, matching the single-device
    ``ops.masked_block_sums`` bitwise on aligned layouts."""
    return _sh.make_block_sums(mesh, kernel, num_blocks_per_shard, data_axes)


def degree_preprocessing(mesh: Mesh, kernel: Kernel,
                         data_axes: Sequence[str] = ("data",)):
    """Algorithm 4.3 distributed: every shard queries its own points
    against the full (sharded) dataset via a ring of collective permutes
    -- O(n^2/P) work per device; returns the degree vector sharded the
    same way as X.

    The ring runs over the *flattened* device index across all
    ``data_axes`` (row-major, matching ``P(axes)``), and the self kernel
    is removed by subtracting the kernel's *actual* per-point diagonal
    k(x_i, x_i) -- custom kernels with non-unit diagonals get unbiased
    degrees (the previous hardcoded ``- 1.0`` biased them)."""
    return _sh.make_degree_ring(mesh, kernel, data_axes)


def make_sharded_dataset(mesh: Mesh, x, data_axes: Sequence[str] = ("data",)):
    """Place the dataset on the mesh, sharded over ``data_axes``
    (Section 3 KDE queries then never reshard X)."""
    sharding = NamedSharding(mesh, P(tuple(data_axes)))
    return jax.device_put(x, sharding)


class ShardedKDE:
    """Definition 1.1 estimator over a mesh-sharded dataset.

    A drop-in for ``StratifiedKDE`` / ``ExactBlockKDE`` in every pipeline:
    same attributes (``x``, ``x_sq``, ``block_size``, ``num_blocks``,
    ``samples_per_block``, ``evals``), same ``query`` semantics, but the
    level-1 state lives sharded on ``mesh`` inside ``self.engine`` (a
    ``kde_sampler.sharded.ShardedBlocks``), which ``NeighborSampler``'s
    mesh path shares for its collective draws (DESIGN.md §9).

    ``evals`` counts the single-device-equivalent logical cost (m*n exact
    / m*B*s stratified per m-query batch) so counter audits agree with the
    flat engine exactly.

    >>> est = ShardedKDE(mesh, x, gaussian(1.0), exact=True)
    """

    def __init__(self, mesh: Mesh, x, kernel: Kernel,
                 block_size: Optional[int] = None,
                 samples_per_block: int = 16, exact: bool = False,
                 data_axes: Sequence[str] = ("data",), seed: int = 0):
        n = int(x.shape[0])
        bs = block_size or max(int(np.sqrt(n)), 16)
        self.engine = _sh.ShardedBlocks(
            mesh, x, kernel, block_size=bs,
            samples_per_block=samples_per_block, exact=exact,
            data_axes=data_axes)
        self.kernel = kernel
        self.n = n
        self.d = self.engine.d
        # replicated views of the real rows (frontier gathers, fallbacks)
        self.x = self.engine.x_rep[: n]
        self.x_sq = self.engine.x_sq_rep[: n]
        self.block_size = self.engine.block_size
        self.num_blocks = self.engine.num_blocks
        self.samples_per_block = self.engine.samples_per_block
        self.exact = bool(exact)
        self.evals = 0
        # realized device totals folded from the engine's counter words
        # (DESIGN.md §15.1; counts include the sentinel-padded sweeps)
        self.device_counters = _c.HostTotals()
        self._key = jax.random.PRNGKey(seed)

    def _split(self) -> jnp.ndarray:
        self._key, k = jax.random.split(self._key)
        return k

    def patch_rows(self, slots, rows) -> None:
        """Streaming mutation passthrough (DESIGN.md §12): scatter the
        mutated rows into the engine's sharded + replicated dataset copies
        (zero collectives -- each shard patches only its own rows) and
        refresh the replicated views consumers hold."""
        self.engine.patch_rows(slots, rows)
        self.x = self.engine.x_rep[: self.n]
        self.x_sq = self.engine.x_sq_rep[: self.n]

    def _query_evals(self, m: int) -> int:
        if self.exact:
            return m * self.n
        return m * self.num_blocks * self.samples_per_block

    def query(self, y: jnp.ndarray) -> jnp.ndarray:
        """(m, d) replicated queries -> (m,) row-sum estimates; one local
        sweep + one psum (Section 3)."""
        y = jnp.asarray(y, jnp.float32)
        self.evals += self._query_evals(y.shape[0])
        est, cw = self.engine.kde_query(y, self._split())
        self.device_counters.note(cw)
        return est

    def query1(self, y: jnp.ndarray) -> float:
        """Single-point convenience wrapper around ``query``."""
        return float(self.query(y[None, :])[0])

    def degrees(self, batch: int = 1024) -> np.ndarray:
        """Algorithm 4.3 on the mesh: exact estimators run the
        memory-optimal ring as ONE program (O(shard^2) live memory per
        device), the stratified path runs batched collective queries
        (``batch`` rows each, the same memory bound as the single-device
        host loop); both subtract the kernel's actual diagonal."""
        if self.exact:
            self.evals += self.n * self.n
            deg, cw = self.engine.degrees_ring(self.kernel)
            self.device_counters.note(cw)
            return np.asarray(deg, np.float64)
        from repro.kernels.kde_sampler.ref import BUILTIN_KINDS
        total = np.zeros(self.n, np.float64)
        for lo in range(0, self.n, batch):
            hi = min(lo + batch, self.n)
            total[lo:hi] = np.asarray(self.query(self.x[lo:hi]))
        if self.kernel.name in BUILTIN_KINDS:
            return total - 1.0
        return total - np.asarray(self.kernel.pairs(self.x, self.x),
                                  np.float64)
