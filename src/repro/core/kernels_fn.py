"""Kernel functions (Table 1 of the paper) and their algebraic properties.

Every kernel maps to [0, 1] with k(x, x) = 1.  The paper parameterizes all
algorithms by ``tau = min_ij k(x_i, x_j)``.

The low-rank reduction (Section 5.2) needs the *squaring constant* ``c`` with
``k(x, y)^2 == k(c*x, c*y)``:

  - Laplacian  exp(-||x-y||_1 / sigma):  k^2 = exp(-2||x-y||_1/sigma)  -> c = 2
  - Exponential exp(-||x-y||_2 / sigma): same argument                  -> c = 2
  - Gaussian   exp(-||x-y||_2^2 / sigma^2): k^2 = exp(-2||.||^2/s^2)    -> c = sqrt(2)

(The paper's prose says "c = 2, 2, and 4 respectively"; for the Gaussian the
correct constant under k(x,y)=exp(-||x-y||^2) is sqrt(2) -- exp(-||cx-cy||^2)
= exp(-c^2 ||x-y||^2) so c^2 = 2.  We implement the mathematically correct
value and verify it by property test.)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Kernel:
    """A kernel function with the metadata the paper's reductions need."""

    name: str
    # pairwise(x: (m, d), y: (n, d)) -> (m, n) kernel matrix block
    pairwise: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    # Constant c with k(x,y)^2 = k(cx, cy); None if no such constant exists.
    squaring_constant: Optional[float]
    # Exponent p of tau in the state-of-the-art KDE query time (Table 1).
    kde_exponent: float
    bandwidth: float = 1.0
    # Shape parameter (rational quadratic only); 1.0 elsewhere.
    beta: float = 1.0

    def matrix(self, x: jnp.ndarray) -> jnp.ndarray:
        """Full kernel matrix K (for oracles / evaluation only)."""
        return self.pairwise(x, x)

    def pairs(self, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        """Elementwise k(x_i, y_i) for aligned (w, d) batches -- O(w d), not
        the (w, w) matrix whose diagonal would be thrown away."""
        return jax.vmap(lambda a, b: self.pairwise(a[None, :], b[None, :])[0, 0])(
            jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32))

    def __call__(self, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        return self.pairwise(x, y)


def _sq_dists(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    # ||x - y||^2 = ||x||^2 + ||y||^2 - 2 x.y ; clamp for numerical safety.
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    d2 = xx + yy - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


def gaussian(bandwidth: float = 1.0) -> Kernel:
    """exp(-||x-y||_2^2 / sigma^2) (Table 1; squaring constant sqrt(2)).

    >>> ker = gaussian(bandwidth=1.0)
    """
    inv = 1.0 / (bandwidth * bandwidth)

    def pw(x, y):
        return jnp.exp(-_sq_dists(x, y) * inv)

    return Kernel("gaussian", pw, squaring_constant=float(jnp.sqrt(2.0)),
                  kde_exponent=0.173, bandwidth=bandwidth)


def exponential(bandwidth: float = 1.0) -> Kernel:
    """exp(-||x-y||_2 / sigma) (Table 1; squaring constant 2)."""
    inv = 1.0 / bandwidth

    def pw(x, y):
        return jnp.exp(-jnp.sqrt(_sq_dists(x, y)) * inv)

    return Kernel("exponential", pw, squaring_constant=2.0,
                  kde_exponent=0.1, bandwidth=bandwidth)


def laplacian(bandwidth: float = 1.0) -> Kernel:
    """exp(-||x-y||_1 / sigma): the kernel used in the paper's experiments."""
    inv = 1.0 / bandwidth
    budget = 1 << 28  # cap the (m, n, d) broadcast at ~1 GiB of f32

    def pw(x, y):
        m, d = x.shape[0], x.shape[-1]
        n = y.shape[0]
        chunk = max(int(budget // max(n * d, 1)), 1)
        if m <= chunk:
            d1 = jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)
            return jnp.exp(-d1 * inv)
        outs = [pw(x[lo:lo + chunk], y) for lo in range(0, m, chunk)]
        return jnp.concatenate(outs, axis=0)

    return Kernel("laplacian", pw, squaring_constant=2.0,
                  kde_exponent=0.5, bandwidth=bandwidth)


def rational_quadratic(beta: float = 1.0, bandwidth: float = 1.0) -> Kernel:
    """(1 + ||x-y||_2^2/sigma^2)^(-beta) (Table 1; no squaring constant,
    so the Section 5.2 low-rank reduction does not apply to it)."""
    inv = 1.0 / (bandwidth * bandwidth)

    def pw(x, y):
        return (1.0 + _sq_dists(x, y) * inv) ** (-beta)

    # k^2 = (1+z)^{-2beta}: no squaring constant in general.
    return Kernel("rational_quadratic", pw, squaring_constant=None,
                  kde_exponent=0.0, bandwidth=bandwidth, beta=beta)


_REGISTRY = {
    "gaussian": gaussian,
    "exponential": exponential,
    "laplacian": laplacian,
    "rational_quadratic": rational_quadratic,
}


def make_kernel(name: str, bandwidth: float = 1.0, **kw) -> Kernel:
    """Factory over the Table-1 kernels by name.

    >>> ker = make_kernel("laplacian", bandwidth=2.0)
    """
    return _REGISTRY[name](bandwidth=bandwidth, **kw)


def squared_kernel_dataset(kernel: Kernel, x: jnp.ndarray) -> jnp.ndarray:
    """Transform dataset X -> cX so that row sums of K' give ||K_i,*||_2^2.

    Section 5.2: k(x,y)^2 = k(cx, cy), so KDE queries against cX with query
    c*y return sum_j k(x_j, y)^2, i.e. squared row norms of K.
    """
    c = kernel.squaring_constant
    if c is None:
        raise ValueError(f"kernel {kernel.name} admits no squaring constant")
    return x * c


def median_bandwidth(x: jnp.ndarray, ord: int = 2, sample: int = 2048,
                     seed: int = 0) -> float:
    """The 'median rule' (Section 3.1): bandwidth = median pairwise distance."""
    n = x.shape[0]
    if n > sample:
        idx = jax.random.choice(jax.random.PRNGKey(seed), n, (sample,),
                                replace=False)
        x = x[idx]
    if ord == 2:
        d = jnp.sqrt(_sq_dists(x, x))
    else:
        d = jnp.sum(jnp.abs(x[:, None, :] - x[None, :, :]), axis=-1)
    off = d[jnp.triu_indices(x.shape[0], k=1)]
    return float(jnp.median(off))
