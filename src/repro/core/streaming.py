"""Streaming kernel-graph engine -- the online face of the paper's toolkit.

Glues :class:`repro.core.dataset.DynamicDataset` to the Table-1 sampling
stack (DESIGN.md §12): ONE mutable versioned dataset feeds a
``NeighborSampler`` (depth-2 fused draws, Algorithm 4.11), a
``DegreeSampler`` (Algorithm 4.6 inverse-CDF over patched degrees) and --
on demand -- a ``HashedKDE`` (Section 3.1 bucket estimator with the
overflow region).  Mutations are O(m) journal appends plus jitted device
scatters; every consumer patches its derived state lazily at its next
query, so a burst of inserts costs one coalesced patch, not one rebuild
per batch.

Cost model per mutation batch of m rows over w-frontier consumers:
O(m·d) device scatter + O(w·m) level-1 patch + O(n·m) degree patch +
O(m·log) hash splices, vs. the frozen engines' O(w·n + n²/budget + n)
rebuild -- the sublinear-update regime of Shah-Silwal-Xu 2025 that
BENCH_streaming.json quantifies.

>>> g = StreamingKernelGraph(x0, gaussian(1.0))
>>> g.insert(new_points); g.delete(dead_slots)
>>> u = g.sample_vertices(256); v, q = g.sample_neighbors(u)
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.dataset import DynamicDataset
from repro.core.kernels_fn import Kernel
from repro.core.sampling.edge import NeighborSampler
from repro.core.sampling.vertex import DegreeSampler
from repro.ft import guards as _g


class StreamingKernelGraph:
    """Versioned mutable kernel graph with patch-on-read consumers.

    All sampling entry points answer at the dataset's CURRENT epoch --
    the samplers sync themselves through the ``(dataset_id, epoch)``
    cache contract, so interleaving mutations and queries is safe by
    construction (a stale externally-held frontier raises
    ``guards.EPOCH_STALE`` under ``REPRO_CHECKS=1`` instead of sampling
    from dead slots).

    Cost: construction is the usual frozen-engine build over the padded
    capacity; each mutation batch then costs O(m) bookkeeping and each
    post-mutation query adds one coalesced patch (O(w·m) level-1 /
    O(n·m) degrees / O(m) hash splices) before the normal fused draw.
    """

    def __init__(self, x, kernel: Kernel, capacity: Optional[int] = None,
                 level1: str = "blocked", seed: int = 0,
                 block_size: Optional[int] = None,
                 samples_per_block: int = 16,
                 hash_opts: Optional[dict] = None, mesh=None,
                 data_axes=("data",)):
        self.dataset = DynamicDataset(x, capacity=capacity)
        self.kernel = kernel
        self.nbr = NeighborSampler(
            self.dataset.x_pad, kernel, mode="blocked",
            block_size=block_size, samples_per_block=samples_per_block,
            seed=seed, level1=level1, hash_opts=hash_opts, mesh=mesh,
            data_axes=data_axes, dataset=self.dataset)
        est = (self.nbr.hash_estimator if level1 == "hash"
               else self.nbr.blocks)
        self.deg = DegreeSampler(est, seed=seed + 1, dataset=self.dataset)
        self.mutation_batches = 0
        self.rows_mutated = 0

    # ------------------------------------------------------- mutations
    def insert(self, rows) -> np.ndarray:
        """Append points; returns their slot ids.  O(m) -- consumers
        patch lazily at their next query."""
        slots = self.dataset.insert_rows(rows)
        self.mutation_batches += 1
        self.rows_mutated += len(slots)
        return slots

    def delete(self, slots) -> None:
        """Mask slots out of the graph (sentinel coordinates: exactly
        zero kernel mass; the slot ids are retired until ``compact``)."""
        self.dataset.delete_rows(slots)
        self.mutation_batches += 1
        self.rows_mutated += len(np.unique(np.asarray(slots)))

    def update(self, slots, rows) -> None:
        """Move live points to new coordinates in place."""
        self.dataset.update_rows(slots, rows)
        self.mutation_batches += 1
        self.rows_mutated += len(np.asarray(slots))

    # --------------------------------------------------------- queries
    @property
    def num_live(self) -> int:
        """Live point count (capacity minus retired slots)."""
        return self.dataset.num_live

    @property
    def epoch(self) -> int:
        """The dataset's monotone version counter."""
        return int(self.dataset.epoch)

    def degrees(self) -> np.ndarray:
        """Current approximate degree vector (dead slots exactly 0);
        patched by ``ops.degree_delta`` since the last read."""
        self.deg._sync()
        return self.deg.degrees

    def sample_vertices(self, size: int) -> np.ndarray:
        """u ~ deg(u) / sum deg at the current epoch (Algorithm 4.6)."""
        return self.deg.sample(size)

    def sample_neighbors(self, src: np.ndarray
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """v ~ k(u, v)/deg(u) per source (Algorithm 4.11); the frontier
        must be live at the current epoch (else ``EPOCH_STALE``)."""
        return self.nbr.sample(src)

    def sample_edges(self, t: int, batch: int = 1024):
        """Algorithm 5.1 iid edge batches against the patched degree CDF
        -- (u, v, weight, q_uv, q_vu) numpy arrays of length ``t``."""
        self.deg._sync()
        return self.nbr.edge_batches(self.deg.cdf_device,
                                     self.deg.degrees_device,
                                     self.deg.total, t, batch=batch)

    def walk(self, starts: np.ndarray, length: int, **kw):
        """Algorithm 4.16 device walks from a live frontier."""
        return self.nbr.walk(starts, length, **kw)

    def status_report(self) -> dict:
        """Or-folded status flags + rebuild/patch counters for ops
        dashboards (names via ``guards.decode_status``)."""
        st = self.nbr.status
        hashed = self.nbr._hash
        if hashed is not None:
            st |= hashed.status
        return dict(epoch=self.epoch, num_live=self.num_live,
                    mutation_batches=self.mutation_batches,
                    rows_mutated=self.rows_mutated,
                    flags=_g.decode_status(st),
                    degree_rebuilds=self.deg.rebuilds,
                    hash_rebuilds=(hashed.rebuilds if hashed is not None
                                   else 0))
