"""Pure-jnp oracle for flash attention (causal, GQA), with lse output."""
from __future__ import annotations

import jax.numpy as jnp

_NEG_INF = -1.0e30


def attention_ref(q, k, v, *, causal: bool, scale: float,
                  kv_valid: int | None = None):
    """q (b, hq, sq, dh); k, v (b, hkv, skv, dh) -> (out, lse)."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if kv_valid is not None:
        mask = mask & (kpos < kv_valid)
    if causal:
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask[None, None], s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p / jnp.maximum(l, 1e-30), vv)
    lse = (m + jnp.log(jnp.maximum(l, 1e-30)))[..., 0]
    return out.astype(q.dtype), lse
