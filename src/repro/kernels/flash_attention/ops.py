"""jit'd wrapper for flash attention with padding + custom_vjp.

Forward = Pallas kernel (on TPU; interpret on CPU).  Backward recomputes
attention with the jnp reference and differentiates through it (flash
backward recomputation strategy; the fwd memory win is what matters for
training, the bwd is standard rematerialization).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention import ref as _ref


def _pad_seq(a, mult, axis):
    s = a.shape[axis]
    rem = (-s) % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, rem)
    return jnp.pad(a, pad)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, bq=128, bk=128, interpret=None,
                    with_lse=False):
    out, lse = _fwd_impl(q, k, v, causal, bq, bk, interpret)
    return (out, lse) if with_lse else out


def _fwd_impl(q, k, v, causal, bq, bk, interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, hq, sq, dh = q.shape
    skv = k.shape[2]
    scale = 1.0 / (dh ** 0.5)
    bq_ = min(bq, max(_next_mult(sq), 8))
    bk_ = min(bk, max(_next_mult(skv), 8))
    qp = _pad_seq(q, bq_, 2)
    kp = _pad_seq(k, bk_, 2)
    vp = _pad_seq(v, bk_, 2)
    out, lse = _k.flash_attention_pallas(
        qp, kp, vp, causal=causal, scale=scale, kv_valid=skv,
        bq=bq_, bk=bk_, interpret=interpret)
    return out[:, :, :sq], lse[:, :, :sq]


def _next_mult(s, base=128):
    return base if s >= base else 1 << max(s - 1, 0).bit_length()


def _fwd(q, k, v, causal, bq, bk, interpret, with_lse):
    out, lse = _fwd_impl(q, k, v, causal, bq, bk, interpret)
    res = (q, k, v)
    return ((out, lse) if with_lse else out), res


def _bwd(causal, bq, bk, interpret, with_lse, res, g):
    q, k, v = res
    scale = 1.0 / (q.shape[-1] ** 0.5)

    def f(q, k, v):
        out, lse = _ref.attention_ref(q, k, v, causal=causal, scale=scale)
        return (out, lse) if with_lse else out

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)

attention_ref = _ref.attention_ref
