"""Pallas TPU kernel: blocked online-softmax attention (flash), causal + GQA.

Used by train/prefill paths and as the exact stage of kde_attention.  Tiling:
one (batch, q-head, q-block) owns a VMEM accumulator (bq, dh) plus running
max/sum vectors; key/value tiles (bk, dh) stream along the innermost grid
dimension.  GQA is expressed in the k/v index_map (q-head -> kv-head via
integer division), so no head replication ever materializes.

Also emits the log-sum-exp per query row -- kde_attention uses it to combine
exact top-P mass with the KDE-estimated residual mass (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1.0e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                 m_scr, l_scr, acc_scr, *, scale, causal, offset, bq, bk,
                 kv_valid):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, dh)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, dh)
    v = v_ref[0, 0].astype(jnp.float32)              # (bk, dh)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < kv_valid
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == pl.num_programs(3) - 1)
    def _():
        l = l_scr[...]
        safe = jnp.maximum(l, 1e-30)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = jnp.where(l > 0, m_scr[...] + jnp.log(safe), _NEG_INF)


def flash_attention_pallas(q, k, v, *, causal: bool, scale: float,
                           kv_valid: int, bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q (b, hq, sq, dh); k, v (b, hkv, skv, dh); sq % bq == skv % bk == 0.

    Returns (out (b, hq, sq, dh), lse (b, hq, sq))."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    offset = skv - sq  # decode: queries sit at the end of the key timeline
    body = functools.partial(_attn_kernel, scale=scale, causal=causal,
                             offset=offset, bq=bq, bk=bk, kv_valid=kv_valid)
    grid = (b, hq, sq // bq, skv // bk)
    out, lse = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda bi, hi, qi, kj: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda bi, hi, qi, kj, g=group: (bi, hi // g, kj, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda bi, hi, qi, kj, g=group: (bi, hi // g, kj, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda bi, hi, qi, kj: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda bi, hi, qi, kj: (bi, hi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse
