"""Pallas TPU kernel: fused level-1 read of the depth-2 neighbor sampler.

One pass over the dataset per query tile computes the masked per-block
kernel sums (self-kernel k(x, x) = 1 subtracted from each source's own
block, Alg 4.11 lines (c)/(d)) AND draws the block index by Gumbel-max over
``log(block_sum) + g`` -- so the sampler's block choice never materializes
an (m, B) matrix round-trip through the host (DESIGN.md §3).

Grid: (m/bm, B) with one x block per j-step.  The running Gumbel argmax,
the winning block's sum, and the total (= masked degree estimate) live in
VMEM scratch and are flushed on the last j-step (revisiting output
pattern, identical to ``kde_rowsum``).  Gumbel noise is drawn outside with
``jax.random`` and streamed in as an (m, B) input so compiled and
interpret-mode runs are reproducible from one PRNGKey.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.kde_rowsum.kernel import (_tile_kernel_values,
                                             exp_table_operand,
                                             exp_table_spec, needs_exp_table)

_FLOOR = 1e-12  # == ref.BLOCK_SUM_FLOOR


def _sample_block_kernel(q_ref, own_ref, g_ref, x_ref, *rest,
                         kind, inv_bw, beta, precision, has_table):
    if has_table:
        t_ref = rest[0]
        rest = rest[1:]
        table = t_ref[...]
    else:
        table = None
    (blk_ref, pb_ref, tot_ref, bs_ref,
     max_ref, arg_ref, best_ref, acc_ref) = rest
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        max_ref[...] = jnp.full_like(max_ref, -jnp.inf)
        arg_ref[...] = jnp.zeros_like(arg_ref)
        best_ref[...] = jnp.zeros_like(best_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv = _tile_kernel_values(q_ref[...], x_ref[...], kind, inv_bw, beta,
                             precision=precision, table=table)
    s = jnp.sum(kv, axis=1)                         # (bm,) this block's sums
    own = own_ref[...][:, 0]
    s = jnp.where(own == j, s - 1.0, s)             # k(x, x) = 1 self mask
    s = jnp.maximum(s, _FLOOR)
    bs_ref[...] = s[:, None]

    score = jnp.log(s) + g_ref[...][:, 0]
    upd = score > max_ref[...]
    arg_ref[...] = jnp.where(upd, jnp.full_like(arg_ref, j), arg_ref[...])
    best_ref[...] = jnp.where(upd, s, best_ref[...])
    max_ref[...] = jnp.maximum(max_ref[...], score)
    acc_ref[...] += s

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        blk_ref[...] = arg_ref[...]
        tot_ref[...] = acc_ref[...]
        pb_ref[...] = best_ref[...] / acc_ref[...]


def _masked_blocksum_kernel(q_ref, own_ref, x_ref, *rest, kind, inv_bw,
                            beta, precision, has_table):
    if has_table:
        t_ref, bs_ref = rest
        table = t_ref[...]
    else:
        (bs_ref,) = rest
        table = None
    j = pl.program_id(1)
    kv = _tile_kernel_values(q_ref[...], x_ref[...], kind, inv_bw, beta,
                             precision=precision, table=table)
    s = jnp.sum(kv, axis=1)
    own = own_ref[...][:, 0]
    s = jnp.where(own == j, s - 1.0, s)             # k(x, x) = 1 self mask
    bs_ref[...] = jnp.maximum(s, _FLOOR)[:, None]


def masked_blocksum_pallas(q: jnp.ndarray, x: jnp.ndarray, own: jnp.ndarray,
                           kind: str, inv_bw: float, beta: float = 1.0,
                           bm: int = 128, bn: int = 256,
                           interpret: bool = False,
                           precision: str = "f32") -> jnp.ndarray:
    """Masked level-1 block sums WITHOUT the in-pass block draw: the reverse
    probability read of the fused Algorithm 5.1 edge op (the sparsifier
    evaluates q(u | v) for already-drawn edges, so no Gumbel state is
    needed).  q (m, d), x (n, d), own (m, 1) int32 -> (m, n/bn) sums,
    self-corrected and floored exactly like ``sample_block_pallas``.
    m, n must be multiples of bm, bn; padded queries use own = -1."""
    m, d = q.shape
    n = x.shape[0]
    nb = n // bn
    has_table = needs_exp_table(kind, precision)
    body = functools.partial(_masked_blocksum_kernel, kind=kind,
                             inv_bw=inv_bw, beta=beta, precision=precision,
                             has_table=has_table)
    in_specs = [pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
                pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, d), lambda i, j: (j, 0))]
    operands = [q, own, x]
    if has_table:
        in_specs.append(exp_table_spec(lambda i, j: (0,)))
        operands.append(exp_table_operand())
    return pl.pallas_call(
        body,
        grid=(m // bm, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, nb), jnp.float32),
        # every (i, j) cell writes its own output block -- both grid axes
        # are revisit-free, so the pipeline double-buffers freely
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(*operands)


def sample_block_pallas(q: jnp.ndarray, x: jnp.ndarray, own: jnp.ndarray,
                        gumbel: jnp.ndarray, kind: str, inv_bw: float,
                        beta: float = 1.0, bm: int = 128, bn: int = 256,
                        interpret: bool = False, precision: str = "f32"):
    """q (m, d), x (n, d), own (m, 1) int32, gumbel (m, n/bn) ->
    (blk (m,) int32, p_blk (m,), tot (m,), block_sums (m, n/bn)).
    m, n must be multiples of bm, bn; padded queries use own = -1."""
    m, d = q.shape
    n = x.shape[0]
    nb = n // bn
    has_table = needs_exp_table(kind, precision)
    body = functools.partial(_sample_block_kernel, kind=kind, inv_bw=inv_bw,
                             beta=beta, precision=precision,
                             has_table=has_table)
    in_specs = [pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
                pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
                pl.BlockSpec((bm, 1), lambda i, j: (i, j)),
                pl.BlockSpec((bn, d), lambda i, j: (j, 0))]
    operands = [q, own, gumbel, x]
    if has_table:
        in_specs.append(exp_table_spec(lambda i, j: (0,)))
        operands.append(exp_table_operand())
    return pl.pallas_call(
        body,
        grid=(m // bm, nb),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((bm,), lambda i, j: (i,)),
                   pl.BlockSpec((bm,), lambda i, j: (i,)),
                   pl.BlockSpec((bm,), lambda i, j: (i,)),
                   pl.BlockSpec((bm, 1), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((m,), jnp.int32),
                   jax.ShapeDtypeStruct((m,), jnp.float32),
                   jax.ShapeDtypeStruct((m,), jnp.float32),
                   jax.ShapeDtypeStruct((m, nb), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bm,), jnp.float32),
                        pltpu.VMEM((bm,), jnp.int32),
                        pltpu.VMEM((bm,), jnp.float32),
                        pltpu.VMEM((bm,), jnp.float32)],
        # the Gumbel argmax carries VMEM state across j, so the x-block
        # axis is "arbitrary" (sequential revisit); query tiles pipeline
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
