"""Fused device-resident depth-2 neighbor sampling engine (DESIGN.md §3)."""
