"""Fused depth-2 neighbor sampling engine: single-device programs in
``ops`` (DESIGN.md §3), the mesh-resident collective engine in ``sharded``
(DESIGN.md §9), shared pure-jnp oracles in ``ref``."""
