"""Device-resident fused depth-2 neighbor sampling engine (DESIGN.md §3).

One walk step = ONE compiled program: level-1 masked block sums (Pallas on
TPU, jnp sweep elsewhere), Gumbel-max block draw, level-2 exact in-block
row, and the in-block categorical draw -- no host sync between stages.
``jax.random`` keys drive all randomness, so every path is jit-compatible
and reproducible.

Public entry points (all jitted; static config is passed by keyword):

* ``stratified_block_sums`` / ``exact_block_sums`` -- vectorized level-1
  reads used by ``core.kde.base`` (the stratified path masks padded tail
  samples out of the sum and scales by the *realized* per-block sample
  count, fixing the seed's padding bias).
* ``fused_sample``            -- full depth-2 step; also returns the masked
  level-1 sums so callers can cache them (DESIGN.md §4).
* ``sample_from_block_sums``  -- depth-2 step reusing cached level-1 sums.
* ``prob_of_from_block_sums`` -- q(dst | src) from cached level-1 sums.
* ``fused_sample_exact``      -- Theorem 4.12 rejection rounds, one program.
* ``walk_scan``               -- T walk steps under ``lax.scan``; the
  frontier never leaves the device.

``TRACE_COUNTS`` increments only while a function is being traced --
tests use it to certify that repeated calls hit the compiled path.
"""
from __future__ import annotations

import collections
import functools
import inspect

import jax
import jax.numpy as jnp

from repro.kernels.kde_rowsum.ops import _PAD_OFFSET, _pad_rows
from repro.kernels.kde_sampler import kernel as _k
from repro.kernels.kde_sampler import ref as _ref

TRACE_COUNTS = collections.Counter()

# Static (hashable) configuration forwarded to every jitted entry point.
_STATIC = frozenset((
    "kind", "inv_bw", "beta", "pairwise", "block_size", "num_blocks",
    "n", "s", "exact", "use_pallas", "interpret", "bm", "rounds", "slack"))


def _jit(fn):
    """jit with the subset of _STATIC names this function actually takes."""
    names = tuple(p for p in inspect.signature(fn).parameters if p in _STATIC)
    return jax.jit(fn, static_argnames=names)


def default_use_pallas() -> bool:
    return jax.default_backend() == "tpu"


# --------------------------------------------------------------------- #
# level-1: (m, B) block-sum reads
# --------------------------------------------------------------------- #
@_jit
def stratified_block_sums(y, x, x_sq, key, *, kind, inv_bw, beta, pairwise,
                          block_size, num_blocks, n, s):
    """Per-block uniform-subsample estimates of the block sums, (m, B).

    Each block contributes ``size_b / s_b * sum(sampled kernel values)``
    where ``s_b = min(s, size_b)`` counts only *real* (non-padded) samples:
    the tail block is no longer inflated by duplicated pad indices.
    """
    TRACE_COUNTS["stratified_block_sums"] += 1
    m = y.shape[0]
    base = jnp.arange(num_blocks, dtype=jnp.int32) * block_size
    pos = base[:, None] + jnp.arange(block_size, dtype=jnp.int32)[None, :]
    valid_pos = pos < n
    u = jax.random.uniform(key, (num_blocks, block_size))
    u = jnp.where(valid_pos, u, jnp.inf)          # invalid slots sort last
    _, order = jax.lax.top_k(-u, s)               # (B, s) w/o replacement
    idx = jnp.take_along_axis(pos, order, axis=1)
    sel_valid = jnp.take_along_axis(valid_pos, order, axis=1)
    idx = jnp.minimum(idx, n - 1)
    flat = idx.reshape(-1)
    kv = _ref.kv_matrix(y, x[flat], x_sq[flat], kind, inv_bw, beta, pairwise)
    kv = kv.reshape(m, num_blocks, s) * sel_valid[None]
    sizes = jnp.minimum(n - base, block_size).astype(jnp.float32)
    s_b = jnp.minimum(sizes, float(s))
    return kv.sum(-1) * (sizes / jnp.maximum(s_b, 1.0))[None, :]


@_jit
def exact_block_sums(y, x, x_sq, *, kind, inv_bw, beta, pairwise,
                     block_size, num_blocks, n):
    """Exact (m, B) block sums: one dense vectorized sweep, zero host loops."""
    TRACE_COUNTS["exact_block_sums"] += 1
    m = y.shape[0]
    kv = _ref.kv_matrix(y, x, x_sq, kind, inv_bw, beta, pairwise)
    pad = num_blocks * block_size - n
    if pad:
        kv = jnp.pad(kv, ((0, 0), (0, pad)))
    return kv.reshape(m, num_blocks, block_size).sum(-1)


def _masked_block_sums(x, x_sq, src, key, *, kind, inv_bw, beta, pairwise,
                       block_size, num_blocks, n, s, exact):
    """Level-1 sums for a frontier of dataset indices, own-block corrected
    (k(x, x) = 1 subtracted) and floored -- the cacheable object."""
    q = x[src]
    if exact:
        bs = exact_block_sums(q, x, x_sq, kind=kind, inv_bw=inv_bw, beta=beta,
                              pairwise=pairwise, block_size=block_size,
                              num_blocks=num_blocks, n=n)
    else:
        bs = stratified_block_sums(q, x, x_sq, key, kind=kind, inv_bw=inv_bw,
                                   beta=beta, pairwise=pairwise,
                                   block_size=block_size,
                                   num_blocks=num_blocks, n=n, s=s)
    own = (src // block_size).astype(jnp.int32)
    corr = jnp.arange(num_blocks, dtype=jnp.int32)[None, :] == own[:, None]
    bs = jnp.where(corr, bs - 1.0, bs)
    return jnp.maximum(bs, _ref.BLOCK_SUM_FLOOR)


@_jit
def masked_block_sums(x, x_sq, src, key, *, kind, inv_bw, beta, pairwise,
                      block_size, num_blocks, n, s, exact):
    TRACE_COUNTS["masked_block_sums"] += 1
    return _masked_block_sums(x, x_sq, src, key, kind=kind, inv_bw=inv_bw,
                              beta=beta, pairwise=pairwise,
                              block_size=block_size, num_blocks=num_blocks,
                              n=n, s=s, exact=exact)


# --------------------------------------------------------------------- #
# level-2: exact in-block rows
# --------------------------------------------------------------------- #
def _block_views(x, x_sq, block_size):
    """(B, bs, d) / (B, bs) contiguous views of the (padded) dataset.
    Built once per compiled program (hoisted out of walk-scan bodies); the
    level-2 read then gathers w whole block *slices* instead of w*bs
    random rows."""
    pad = -x.shape[0] % block_size
    xb_all = jnp.pad(x, ((0, pad), (0, 0))).reshape(-1, block_size,
                                                    x.shape[1])
    xb_sq_all = jnp.pad(x_sq, (0, pad)).reshape(-1, block_size)
    return xb_all, xb_sq_all


def _level2_kv(x, x_sq, views, src, blk, *, kind, inv_bw, beta, pairwise,
               block_size, n):
    """Exact kernel row of each source against its chosen block, with the
    self edge and out-of-range tail columns masked to 0."""
    xb_all, xb_sq_all = views
    lo = blk * block_size
    cols = lo[:, None] + jnp.arange(block_size, dtype=jnp.int32)[None, :]
    valid = cols < n
    cols_c = jnp.minimum(cols, n - 1)
    xs = x[src]
    kv = _ref.kv_rows(xs, xb_all[blk], x_sq[src], xb_sq_all[blk], kind,
                      inv_bw, beta, pairwise)
    live = valid & (cols_c != src[:, None])
    return jnp.where(live, kv, 0.0), live, cols_c


def _level2_draw(kv, live, cols_c, u2):
    """Inverse-CDF draw from each row of ``kv``; all-zero rows (numerically
    underflowed blocks) fall back to uniform over the live columns instead
    of producing NaN."""
    rowsum = kv.sum(axis=1)
    use = jnp.where((rowsum > 0.0)[:, None], kv, live.astype(jnp.float32))
    c = jnp.cumsum(use, axis=1)
    tot = c[:, -1]
    j = jnp.sum((u2 * tot)[:, None] > c, axis=1).clip(0, kv.shape[1] - 1)
    nb = jnp.take_along_axis(cols_c, j[:, None], axis=1)[:, 0]
    pin = jnp.take_along_axis(use, j[:, None], axis=1)[:, 0] \
        / jnp.maximum(tot, 1e-30)
    return nb, pin


def _choose_block(bs, key):
    """Exact inverse-CDF categorical over rows of the (floored) block
    sums.  (The Pallas kernel uses Gumbel-max instead because it streams
    blocks one at a time; both are exact samplers of the same law.)"""
    c = jnp.cumsum(bs, axis=1)
    tot = c[:, -1]
    u = jax.random.uniform(key, (bs.shape[0],))
    blk = jnp.sum((u * tot)[:, None] > c, axis=1).astype(jnp.int32)
    blk = blk.clip(0, bs.shape[1] - 1)
    pb = jnp.take_along_axis(bs, blk[:, None], axis=1)[:, 0] / tot
    return blk, pb


def _sample_core(x, x_sq, views, src, bs, key, *, kind, inv_bw, beta,
                 pairwise, block_size, n):
    """(block draw -> level-2 row -> neighbor draw) from given level-1 sums."""
    k_blk, k_in = jax.random.split(key)
    blk, pb = _choose_block(bs, k_blk)
    kv, live, cols_c = _level2_kv(x, x_sq, views, src, blk, kind=kind,
                                  inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                                  block_size=block_size, n=n)
    nb, pin = _level2_draw(kv, live, cols_c,
                           jax.random.uniform(k_in, (src.shape[0],)))
    return nb, pb * pin


def _fused_sample(x, x_sq, src, key, *, kind, inv_bw, beta, pairwise,
                  block_size, num_blocks, n, s, exact, use_pallas, interpret,
                  bm, views=None):
    if views is None:
        views = _block_views(x, x_sq, block_size)
    k_l1, k_rest = jax.random.split(key)
    if exact and use_pallas:
        # Fully fused level-1: block sums + Gumbel-max draw in one Pallas pass.
        w = src.shape[0]
        rem = (-w) % bm
        k_g, k_in = jax.random.split(k_rest)
        q = _pad_rows(x[src], bm, 0.0)
        own = jnp.pad((src // block_size).astype(jnp.int32), (0, rem),
                      constant_values=-1)[:, None]
        gp = jnp.pad(jax.random.gumbel(k_g, (w, num_blocks)),
                     ((0, rem), (0, 0)))
        xp = _pad_rows(x, block_size, _PAD_OFFSET)
        blk, pb, _, bs = _k.sample_block_pallas(
            q, xp, own, gp, kind, inv_bw, beta, bm=bm, bn=block_size,
            interpret=interpret)
        blk, pb, bs = blk[:w], pb[:w], bs[:w]
        kv, live, cols_c = _level2_kv(x, x_sq, views, src, blk, kind=kind,
                                      inv_bw=inv_bw, beta=beta,
                                      pairwise=pairwise,
                                      block_size=block_size, n=n)
        nb, pin = _level2_draw(kv, live, cols_c,
                               jax.random.uniform(k_in, (w,)))
        return nb, pb * pin, bs
    bs = _masked_block_sums(x, x_sq, src, k_l1, kind=kind, inv_bw=inv_bw,
                            beta=beta, pairwise=pairwise,
                            block_size=block_size, num_blocks=num_blocks,
                            n=n, s=s, exact=exact)
    nb, prob = _sample_core(x, x_sq, views, src, bs, k_rest, kind=kind,
                            inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                            block_size=block_size, n=n)
    return nb, prob, bs


@_jit
def fused_sample(x, x_sq, src, key, *, kind, inv_bw, beta, pairwise,
                 block_size, num_blocks, n, s, exact, use_pallas, interpret,
                 bm):
    """One depth-2 sampling step: (neighbors, realized probs, level-1 sums)."""
    TRACE_COUNTS["fused_sample"] += 1
    return _fused_sample(x, x_sq, src, key, kind=kind, inv_bw=inv_bw,
                         beta=beta, pairwise=pairwise, block_size=block_size,
                         num_blocks=num_blocks, n=n, s=s, exact=exact,
                         use_pallas=use_pallas, interpret=interpret, bm=bm)


@_jit
def sample_from_block_sums(x, x_sq, src, bs, key, *, kind, inv_bw, beta,
                           pairwise, block_size, n):
    """Depth-2 step reusing cached level-1 sums (no dataset re-sweep)."""
    TRACE_COUNTS["sample_from_block_sums"] += 1
    views = _block_views(x, x_sq, block_size)
    return _sample_core(x, x_sq, views, src, bs, key, kind=kind,
                        inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                        block_size=block_size, n=n)


@_jit
def prob_of_from_block_sums(x, x_sq, src, dst, bs, *, kind, inv_bw, beta,
                            pairwise, block_size, n):
    """q(dst | src) the sampler assigns, from cached level-1 sums."""
    TRACE_COUNTS["prob_of_from_block_sums"] += 1
    views = _block_views(x, x_sq, block_size)
    blk = (dst // block_size).astype(jnp.int32)
    pb = jnp.take_along_axis(bs, blk[:, None], axis=1)[:, 0] / bs.sum(axis=1)
    kv, _, _ = _level2_kv(x, x_sq, views, src, blk, kind=kind, inv_bw=inv_bw,
                          beta=beta, pairwise=pairwise,
                          block_size=block_size, n=n)
    kd = jnp.take_along_axis(kv, (dst - blk * block_size)[:, None],
                             axis=1)[:, 0]
    return pb * kd / jnp.maximum(kv.sum(axis=1), 1e-30)


def _sample_exact_core(x, x_sq, views, src, bs, key, *, kind, inv_bw, beta,
                       pairwise, block_size, n, rounds, slack):
    zs = bs.sum(axis=1)
    keys = jax.random.split(key, 2 * rounds + 1)
    cur, _ = _sample_core(x, x_sq, views, src, bs, keys[0], kind=kind,
                          inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                          block_size=block_size, n=n)
    accepted = jnp.zeros(src.shape[0], bool)
    xs = x[src]
    for r in range(rounds):
        cand, q = _sample_core(x, x_sq, views, src, bs, keys[2 * r + 1],
                               kind=kind, inv_bw=inv_bw, beta=beta,
                               pairwise=pairwise, block_size=block_size, n=n)
        kuv = _ref.kv_pairs(xs, x[cand], kind, inv_bw, beta, pairwise)
        ratio = kuv / jnp.maximum(slack * q * zs, 1e-30)
        u = jax.random.uniform(keys[2 * r + 2], (src.shape[0],))
        acc = (~accepted) & (u < jnp.minimum(ratio, 1.0))
        cur = jnp.where(acc, cand, cur)
        accepted |= acc
    return cur


@_jit
def fused_sample_exact(x, x_sq, src, bs, key, *, kind, inv_bw, beta, pairwise,
                       block_size, n, rounds, slack):
    """Theorem 4.12 rejection rounds in one program.  The cached level-1
    sums ``bs`` are shared across every proposal round AND the degree
    estimate -- the seed re-swept the dataset once per round."""
    TRACE_COUNTS["fused_sample_exact"] += 1
    views = _block_views(x, x_sq, block_size)
    return _sample_exact_core(x, x_sq, views, src, bs, key, kind=kind,
                              inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                              block_size=block_size, n=n, rounds=rounds,
                              slack=slack)


@_jit
def walk_scan(x, x_sq, starts, keys, *, kind, inv_bw, beta, pairwise,
              block_size, num_blocks, n, s, exact, use_pallas, interpret, bm,
              rounds, slack):
    """T-step random walk entirely on device: the frontier is scan carry,
    each step is one fused depth-2 sample (or rejection-exact step when
    ``rounds > 0``).  Returns (endpoints, (T, w) path)."""
    TRACE_COUNTS["walk_scan"] += 1
    views = _block_views(x, x_sq, block_size)  # hoisted out of the step body

    def body(cur, k):
        if rounds > 0:
            k_l1, k_rs = jax.random.split(k)
            bs = _masked_block_sums(x, x_sq, cur, k_l1, kind=kind,
                                    inv_bw=inv_bw, beta=beta,
                                    pairwise=pairwise, block_size=block_size,
                                    num_blocks=num_blocks, n=n, s=s,
                                    exact=exact)
            nxt = _sample_exact_core(x, x_sq, views, cur, bs, k_rs, kind=kind,
                                     inv_bw=inv_bw, beta=beta,
                                     pairwise=pairwise, block_size=block_size,
                                     n=n, rounds=rounds, slack=slack)
        else:
            nxt, _, _ = _fused_sample(x, x_sq, cur, k, kind=kind,
                                      inv_bw=inv_bw, beta=beta,
                                      pairwise=pairwise,
                                      block_size=block_size,
                                      num_blocks=num_blocks, n=n, s=s,
                                      exact=exact, use_pallas=use_pallas,
                                      interpret=interpret, bm=bm, views=views)
        return nxt, nxt

    end, path = jax.lax.scan(body, starts, keys)
    return end, path
