"""Device-resident fused depth-2 neighbor sampling engine (DESIGN.md §3).

One walk step = ONE compiled program: level-1 masked block sums (Pallas on
TPU, jnp sweep elsewhere), Gumbel-max block draw, level-2 exact in-block
row, and the in-block categorical draw -- no host sync between stages.
``jax.random`` keys drive all randomness, so every path is jit-compatible
and reproducible.

Public entry points (all jitted; static config is passed by keyword):

* ``stratified_block_sums`` / ``exact_block_sums`` -- vectorized level-1
  reads used by ``core.kde.base`` (the stratified path masks padded tail
  samples out of the sum and scales by the *realized* per-block sample
  count, fixing the seed's padding bias).
* ``fused_sample``            -- full depth-2 step; also returns the masked
  level-1 sums so callers can cache them (DESIGN.md §4).
* ``sample_from_block_sums``  -- depth-2 step reusing cached level-1 sums.
* ``prob_of_from_block_sums`` -- q(dst | src) from cached level-1 sums.
* ``fused_sample_exact``      -- Theorem 4.12 rejection rounds, one program.
* ``walk_scan``               -- T walk steps under ``lax.scan``; the
  frontier never leaves the device (``record_path=False`` skips the
  (T, w) path stack entirely).
* ``fused_edge_batch``        -- one Algorithm 5.1 edge batch: u ~ degrees
  (inverse CDF over a device prefix array), v | u, reverse probability,
  and the importance weight, all in one program (DESIGN.md §6).
* ``edge_batch_scan``         -- ALL edge batches of a sparsifier call as
  one ``lax.scan`` program (one dispatch, one transfer out).
* ``kernel_rows``             -- exact batched kernel rows for the FKV /
  CP17 low-rank pipeline (Section 5.2).
* ``batched_fused_sample`` / ``batched_walk_scan`` / ``batched_prob_of``
  / ``batched_kde_query``    -- the multi-tenant serving entry points
  (DESIGN.md §13): vmap over a request axis with per-request PRNG keys,
  per-request status words, and a stacked tenant arena.

Every sampling / application program additionally returns a ``(obs.WIDTH,)``
uint32 **counter word** (``repro.obs.counters``, DESIGN.md §15): slot 0 is
the PR-6 status bitmask (``repro.ft.guards``) -- cheap in-program
reductions over values the program already computed (NaN/Inf sums,
zero-mass rows at the ``BLOCK_SUM_FLOOR``, rejection exhaustion, CG
non-convergence) -- and slots 1+ count the realized device work (kernel
evals, level-1 reads, draws, rejection retries, FAR samples).  The
counters are trace-time constants derived from static shapes (plus the
data-dependent rejection-retry count), so the word costs nothing at run
time and adds zero collectives; scan programs fold per-step words through
their carries.  Flags stay advisory; consumers escalate via
``guards.raise_on_status`` under ``REPRO_CHECKS=1`` (DESIGN.md §11) and
reconcile the eval counters against the host-side ``.evals`` accounting.

``TRACE_COUNTS`` increments only while a function is being traced --
tests use it to certify that repeated calls hit the compiled path.
"""
from __future__ import annotations

import collections
import functools
import inspect

import jax
import jax.numpy as jnp

from repro.ft import guards as _g
from repro.kernels import tuning as _tuning
from repro.kernels.kde_rowsum.ops import _PAD_OFFSET, _pad_rows
from repro.kernels.kde_sampler import kernel as _k
from repro.kernels.kde_sampler import ref as _ref
from repro.obs import counters as _c

TRACE_COUNTS = collections.Counter()


def _l1_cols(level1, exact, num_blocks, s, n, num_far, hstate):
    """(cols, far, overflow) realized PER FRONTIER ROW by one level-1
    read -- the static shape products the counter words are built from,
    mirroring the host accounting in ``core.sampling.edge`` exactly:
    hashed reads sweep ``max_bucket + overflow_cap`` exact columns plus
    ``B * num_far`` stratified FAR slots (``ref.frontier_gather``),
    blocked reads sweep ``n`` (exact) or ``B * s`` (stratified)."""
    if level1 == "hash":
        mb = int(hstate.members.shape[1])
        ov = (int(hstate.overflow.shape[0])
              if hstate.overflow is not None else 0)
        far = int(num_blocks) * int(num_far)
        return mb + ov + far, far, ov
    return (int(n) if exact else int(num_blocks) * int(s)), 0, 0

# Static (hashable) configuration forwarded to every jitted entry point.
# ``level1`` selects the frontier read: "blocked" (the §2 depth-2 block
# structure) or "hash" (the kde_hash padded-bucket estimator, whose
# ``HashState`` arrays ride along as the ``hstate`` operand pytree and
# whose FAR budget is the ``num_far`` static -- DESIGN.md §10).
# ``precision`` selects the level-1 eval dtype policy (DESIGN.md §14):
# "f32" (default, bitwise-stable) or "bf16" (rounded operand tiles, f32
# accumulators/CDFs; level-2 rows and pairwise corrections stay f32).
_STATIC = frozenset((
    "kind", "inv_bw", "beta", "pairwise", "block_size", "num_blocks",
    "n", "s", "exact", "use_pallas", "interpret", "bm", "rounds", "slack",
    "batch", "record_path", "iters", "num_samples", "level1", "num_far",
    "precision"))


def _jit(fn):
    """jit with the subset of _STATIC names this function actually takes."""
    names = tuple(p for p in inspect.signature(fn).parameters if p in _STATIC)
    return jax.jit(fn, static_argnames=names)


def default_use_pallas() -> bool:
    return jax.default_backend() == "tpu"


# --------------------------------------------------------------------- #
# level-1: (m, B) block-sum reads
# --------------------------------------------------------------------- #
@_jit
def stratified_block_sums(y, x, x_sq, key, *, kind, inv_bw, beta, pairwise,
                          block_size, num_blocks, n, s, precision="f32"):
    """Per-block uniform-subsample estimates of the block sums, (m, B).

    Each block contributes ``size_b / s_b * sum(sampled kernel values)``
    where ``s_b = min(s, size_b)`` counts only *real* (non-padded) samples:
    the tail block is no longer inflated by duplicated pad indices.  The
    subsample *draw* is precision-independent; only the gathered kernel
    evals honor ``precision``.  Returns ``(block sums, counter word)``.
    """
    TRACE_COUNTS["stratified_block_sums"] += 1
    m = y.shape[0]

    def _word(bs):
        return _c.word(status=_g.nonfinite_status(bs),
                       evals=m * num_blocks * s, l1_reads=m)

    base = jnp.arange(num_blocks, dtype=jnp.int32) * block_size
    u = jax.random.uniform(key, (num_blocks, block_size))
    if n == num_blocks * block_size:
        # tail-free fast path (static shape property): every slot is valid,
        # so the pad masking/clamping passes are skipped entirely.  The
        # subsample draw consumes the identical randomness, so estimates
        # match the general path bit-for-bit.
        _, order = jax.lax.top_k(-u, s)           # (B, s) w/o replacement
        flat = (base[:, None] + order).reshape(-1)
        kv = _ref.kv_matrix(y, x[flat], x_sq[flat], kind, inv_bw, beta,
                            pairwise, precision=precision)
        bs = kv.reshape(m, num_blocks, s).sum(-1) * (block_size / float(s))
        return bs, _word(bs)
    pos = base[:, None] + jnp.arange(block_size, dtype=jnp.int32)[None, :]
    valid_pos = pos < n
    u = jnp.where(valid_pos, u, jnp.inf)          # invalid slots sort last
    _, order = jax.lax.top_k(-u, s)               # (B, s) w/o replacement
    idx = jnp.take_along_axis(pos, order, axis=1)
    sel_valid = jnp.take_along_axis(valid_pos, order, axis=1)
    idx = jnp.minimum(idx, n - 1)
    flat = idx.reshape(-1)
    kv = _ref.kv_matrix(y, x[flat], x_sq[flat], kind, inv_bw, beta, pairwise,
                        precision=precision)
    kv = kv.reshape(m, num_blocks, s) * sel_valid[None]
    sizes = jnp.minimum(n - base, block_size).astype(jnp.float32)
    s_b = jnp.minimum(sizes, float(s))
    bs = kv.sum(-1) * (sizes / jnp.maximum(s_b, 1.0))[None, :]
    return bs, _word(bs)


@_jit
def exact_block_sums(y, x, x_sq, *, kind, inv_bw, beta, pairwise,
                     block_size, num_blocks, n, precision="f32"):
    """Exact (m, B) block sums: one dense vectorized sweep, zero host loops.
    The bf16 policy swaps in the blocked column-tile scan (f32 accumulator,
    O(m * tile) peak memory) instead of materializing the (m, n) matrix.
    Returns ``(block sums, counter word)``."""
    TRACE_COUNTS["exact_block_sums"] += 1
    m = y.shape[0]

    def _word(bs):
        return _c.word(status=_g.nonfinite_status(bs), evals=m * n,
                       l1_reads=m)

    if precision == "bf16":
        _ref.check_precision(precision, kind, pairwise)
        bs = _ref.kv_block_sums_bf16(y, x, kind, inv_bw, beta,
                                     bn=block_size)
        return bs, _word(bs)
    kv = _ref.kv_matrix(y, x, x_sq, kind, inv_bw, beta, pairwise)
    pad = num_blocks * block_size - n
    if pad:
        kv = jnp.pad(kv, ((0, 0), (0, pad)))
    bs = kv.reshape(m, num_blocks, block_size).sum(-1)
    return bs, _word(bs)


def _pallas_pad(x, src, bm, block_size):
    """Shared Pallas preamble: query rows padded to a bm multiple, own-block
    indices padded with the -1 sentinel, dataset padded to a block_size
    multiple at the far offset (kernel values ~0)."""
    rem = (-src.shape[0]) % bm
    q = _pad_rows(x[src], bm, 0.0)
    own = jnp.pad((src // block_size).astype(jnp.int32), (0, rem),
                  constant_values=-1)[:, None]
    xp = _pad_rows(x, block_size, _PAD_OFFSET)
    return q, own, xp, rem


def _masked_block_sums(x, x_sq, src, key, *, kind, inv_bw, beta, pairwise,
                       block_size, num_blocks, n, s, exact, precision="f32"):
    """Level-1 sums for a frontier of dataset indices, own-block corrected
    (k(x, x) = 1 subtracted) and floored -- the cacheable object."""
    q = x[src]
    # inner counter words are discarded: the public program boundary
    # (masked_block_sums / fused_sample / ...) rebuilds the counts from
    # the same static shapes, so nothing is double-counted
    if exact:
        bs, _ = exact_block_sums(q, x, x_sq, kind=kind, inv_bw=inv_bw,
                                 beta=beta, pairwise=pairwise,
                                 block_size=block_size,
                                 num_blocks=num_blocks, n=n,
                                 precision=precision)
    else:
        bs, _ = stratified_block_sums(q, x, x_sq, key, kind=kind,
                                      inv_bw=inv_bw, beta=beta,
                                      pairwise=pairwise,
                                      block_size=block_size,
                                      num_blocks=num_blocks, n=n, s=s,
                                      precision=precision)
    own = (src // block_size).astype(jnp.int32)
    corr = jnp.arange(num_blocks, dtype=jnp.int32)[None, :] == own[:, None]
    bs = jnp.where(corr, bs - 1.0, bs)
    return jnp.maximum(bs, _ref.BLOCK_SUM_FLOOR)


@_jit
def masked_block_sums(x, x_sq, src, key, hstate=None, *, kind, inv_bw, beta,
                      pairwise, block_size, num_blocks, n, s, exact,
                      use_pallas=False, interpret=False, bm=128,
                      level1="blocked", num_far=64, precision="f32"):
    """Level-1 frontier read; dispatches to the Pallas masked-blocksum
    kernel (no Gumbel state) on the exact+Pallas path, or to the hashed
    read when ``level1="hash"``.  Returns ``(block sums, counter word)``."""
    TRACE_COUNTS["masked_block_sums"] += 1
    bs, st = _masked_sums_any(x, x_sq, src, key, hstate, kind=kind,
                              inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                              block_size=block_size, num_blocks=num_blocks,
                              n=n, s=s, exact=exact, use_pallas=use_pallas,
                              interpret=interpret, bm=bm, level1=level1,
                              num_far=num_far, precision=precision)
    w = src.shape[0]
    cols, far, ov = _l1_cols(level1, exact, num_blocks, s, n, num_far,
                             hstate)
    return bs, _c.word(status=st, evals=w * cols, l1_reads=w,
                       far_samples=w * far, overflow=w * ov)


# --------------------------------------------------------------------- #
# level-2: exact in-block rows
# --------------------------------------------------------------------- #
def _block_views(x, x_sq, block_size):
    """See ``ref.block_views`` -- shared with the oracles."""
    return _ref.block_views(x, x_sq, block_size)


def _level2_kv(x, x_sq, views, src, blk, *, kind, inv_bw, beta, pairwise,
               block_size, n):
    """See ``ref.level2_row`` -- shared with the oracles."""
    return _ref.level2_row(x, x_sq, views, src, blk, kind, inv_bw, beta,
                           block_size, n, pairwise)


_level2_draw = _ref.level2_draw


_choose_block = _ref.choose_block


def _sample_core(x, x_sq, views, src, bs, key, *, kind, inv_bw, beta,
                 pairwise, block_size, n):
    """(block draw -> level-2 row -> neighbor draw) from given level-1 sums.
    Delegates to ``ref.sample_from_sums`` so every fused program and its
    oracle consume the identical key stream and math."""
    return _ref.sample_from_sums(x, x_sq, views, src, bs, key, kind, inv_bw,
                                 beta, block_size, n, pairwise)


def _walk_sample_core(x, x_sq, views, src, bs, key, *, kind, inv_bw, beta,
                      pairwise, block_size, n, num_blocks):
    """``sample_from_sums`` with the two-level inverse-CDF draws
    (``ref.grouped_inverse_cdf``) at both depths -- the walk-resident-cache
    step's hot path, where the flat (w, B) and (w, bs) cumsums were the
    dominant n-scaling cost.  Same key-split discipline and sampling law
    as ``_sample_core``; the realized index can differ from the flat
    search only by fp regrouping of the partial sums."""
    k_blk, k_in = jax.random.split(key)
    blk, pb = _ref.choose_block_grouped(bs, k_blk, _ref.cdf_group(num_blocks))
    kv, live, cols_c = _ref.level2_row(x, x_sq, views, src, blk, kind,
                                       inv_bw, beta, block_size, n, pairwise)
    nb, pin = _ref.level2_draw_grouped(kv, live, cols_c,
                                       jax.random.uniform(k_in,
                                                          (src.shape[0],)),
                                       _ref.cdf_group(block_size))
    return nb, pb * pin


def _fused_sample(x, x_sq, src, key, hstate=None, *, kind, inv_bw, beta,
                  pairwise, block_size, num_blocks, n, s, exact, use_pallas,
                  interpret, bm, level1="blocked", num_far=64,
                  precision="f32", views=None):
    if views is None:
        views = _block_views(x, x_sq, block_size)
    w = src.shape[0]
    k_l1, k_rest = jax.random.split(key)
    if level1 == "hash":
        bs, st = _masked_sums_any(x, x_sq, src, k_l1, hstate=hstate,
                                  kind=kind, inv_bw=inv_bw, beta=beta,
                                  pairwise=pairwise, block_size=block_size,
                                  num_blocks=num_blocks, n=n, s=s,
                                  exact=exact, use_pallas=use_pallas,
                                  interpret=interpret, bm=bm, level1=level1,
                                  num_far=num_far, precision=precision)
        nb, prob = _sample_core(x, x_sq, views, src, bs, k_rest, kind=kind,
                                inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                                block_size=block_size, n=n)
        st = _g.merge(st, _g.result_status(prob))
    elif exact and use_pallas:
        # Fully fused level-1: block sums + Gumbel-max draw in one Pallas pass.
        k_g, k_in = jax.random.split(k_rest)
        q, own, xp, rem = _pallas_pad(x, src, bm, block_size)
        gp = jnp.pad(jax.random.gumbel(k_g, (w, num_blocks)),
                     ((0, rem), (0, 0)))
        blk, pb, _, bs = _k.sample_block_pallas(
            q, xp, own, gp, kind, inv_bw, beta, bm=bm, bn=block_size,
            interpret=interpret, precision=precision)
        blk, pb, bs = blk[:w], pb[:w], bs[:w]
        kv, live, cols_c = _level2_kv(x, x_sq, views, src, blk, kind=kind,
                                      inv_bw=inv_bw, beta=beta,
                                      pairwise=pairwise,
                                      block_size=block_size, n=n)
        nb, pin = _level2_draw(kv, live, cols_c,
                               jax.random.uniform(k_in, (w,)))
        prob = pb * pin
        st = _g.merge(_g.sums_status(bs, _ref.BLOCK_SUM_FLOOR),
                      _g.result_status(prob))
    else:
        bs = _masked_block_sums(x, x_sq, src, k_l1, kind=kind, inv_bw=inv_bw,
                                beta=beta, pairwise=pairwise,
                                block_size=block_size, num_blocks=num_blocks,
                                n=n, s=s, exact=exact, precision=precision)
        nb, prob = _sample_core(x, x_sq, views, src, bs, k_rest, kind=kind,
                                inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                                block_size=block_size, n=n)
        st = _g.merge(_g.sums_status(bs, _ref.BLOCK_SUM_FLOOR),
                      _g.result_status(prob))
    # one level-1 read of the w-frontier + w exact level-2 rows -- the
    # host accounting in NeighborSampler.sample, verbatim
    cols, far, ov = _l1_cols(level1, exact, num_blocks, s, n, num_far,
                             hstate)
    cw = _c.word(status=st, evals=w * (cols + block_size), l1_reads=w,
                 draws=w, far_samples=w * far, overflow=w * ov)
    return nb, prob, bs, cw


@_jit
def fused_sample(x, x_sq, src, key, hstate=None, *, kind, inv_bw, beta,
                 pairwise, block_size, num_blocks, n, s, exact, use_pallas,
                 interpret, bm, level1="blocked", num_far=64,
                 precision="f32"):
    """One depth-2 sampling step: (neighbors, realized probs, level-1 sums,
    counter word)."""
    TRACE_COUNTS["fused_sample"] += 1
    return _fused_sample(x, x_sq, src, key, hstate, kind=kind, inv_bw=inv_bw,
                         beta=beta, pairwise=pairwise, block_size=block_size,
                         num_blocks=num_blocks, n=n, s=s, exact=exact,
                         use_pallas=use_pallas, interpret=interpret, bm=bm,
                         level1=level1, num_far=num_far, precision=precision)


@_jit
def sample_from_block_sums(x, x_sq, src, bs, key, *, kind, inv_bw, beta,
                           pairwise, block_size, n):
    """Depth-2 step reusing cached level-1 sums (no dataset re-sweep).
    Returns (neighbors, realized probs, counter word)."""
    TRACE_COUNTS["sample_from_block_sums"] += 1
    views = _block_views(x, x_sq, block_size)
    nb, prob = _sample_core(x, x_sq, views, src, bs, key, kind=kind,
                            inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                            block_size=block_size, n=n)
    st = _g.merge(_g.sums_status(bs, _ref.BLOCK_SUM_FLOOR),
                  _g.result_status(prob))
    w = src.shape[0]
    return nb, prob, _c.word(status=st, evals=w * block_size, draws=w)


def _prob_core(x, x_sq, views, src, dst, bs, *, kind, inv_bw, beta, pairwise,
               block_size, n):
    """q(dst | src) from given level-1 sums of the src frontier.  Mirrors
    ``ref.level2_draw``'s zero-row guard: if dst's block row underflows to
    all zeros the sampler draws uniformly over the live columns, so the
    probability reported here is the matching 1/|live| -- not 0."""
    blk = (dst // block_size).astype(jnp.int32)
    pb = jnp.take_along_axis(bs, blk[:, None], axis=1)[:, 0] / bs.sum(axis=1)
    kv, live, _ = _level2_kv(x, x_sq, views, src, blk, kind=kind,
                             inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                             block_size=block_size, n=n)
    col = (dst - blk * block_size)[:, None]
    kd = jnp.take_along_axis(kv, col, axis=1)[:, 0]
    rowsum = kv.sum(axis=1)
    live_d = jnp.take_along_axis(live, col, axis=1)[:, 0]
    pin_fallback = live_d / jnp.maximum(live.sum(axis=1), 1.0)
    pin = jnp.where(rowsum > 0.0, kd / jnp.maximum(rowsum, 1e-30),
                    pin_fallback)
    return pb * pin


@_jit
def prob_of_from_block_sums(x, x_sq, src, dst, bs, *, kind, inv_bw, beta,
                            pairwise, block_size, n):
    """q(dst | src) the sampler assigns, from cached level-1 sums.
    Returns ``(probs, counter word)``."""
    TRACE_COUNTS["prob_of_from_block_sums"] += 1
    views = _block_views(x, x_sq, block_size)
    prob = _prob_core(x, x_sq, views, src, dst, bs, kind=kind, inv_bw=inv_bw,
                      beta=beta, pairwise=pairwise, block_size=block_size,
                      n=n)
    st = _g.merge(_g.sums_status(bs, _ref.BLOCK_SUM_FLOOR),
                  _g.result_status(prob))
    return prob, _c.word(status=st, evals=src.shape[0] * block_size)


# --------------------------------------------------------------------- #
# fused Algorithm 5.1 edge batches + batched LRA sketch rows
# --------------------------------------------------------------------- #
def _masked_sums_any(x, x_sq, src, key, hstate=None, *, kind, inv_bw, beta,
                     pairwise, block_size, num_blocks, n, s, exact,
                     use_pallas, interpret, bm, level1="blocked", num_far=64,
                     precision="f32"):
    """Masked level-1 sums for a frontier, dispatching to the Pallas
    masked-blocksum kernel on the exact+Pallas path (no Gumbel state --
    probability evaluation needs sums only), or to the hashed-KDE read
    (``level1="hash"``: O(max_bucket + num_far) evals per row instead of
    the blocked O(B s) / O(n), DESIGN.md §10).  Returns ``(bs, status)``;
    on the blocked paths the status covers NaN/Inf and zero-mass rows."""
    if level1 == "hash":
        from repro.kernels.kde_hash import ops as _hops
        return _hops._hashed_block_sums(
            x, src, hstate, key, kind=kind, inv_bw=inv_bw, beta=beta,
            pairwise=pairwise, num_far=num_far, block_size=block_size,
            num_blocks=num_blocks, n=n, use_pallas=use_pallas,
            interpret=interpret, bm=bm, precision=precision)
    if exact and use_pallas:
        w = src.shape[0]
        q, own, xp, _ = _pallas_pad(x, src, bm, block_size)
        bs = _k.masked_blocksum_pallas(q, xp, own, kind, inv_bw, beta, bm=bm,
                                       bn=block_size, interpret=interpret,
                                       precision=precision)
        bs = bs[:w]
        return bs, _g.sums_status(bs, _ref.BLOCK_SUM_FLOOR)
    bs = _masked_block_sums(x, x_sq, src, key, kind=kind, inv_bw=inv_bw,
                            beta=beta, pairwise=pairwise,
                            block_size=block_size, num_blocks=num_blocks,
                            n=n, s=s, exact=exact, precision=precision)
    return bs, _g.sums_status(bs, _ref.BLOCK_SUM_FLOOR)


def _edge_batch_core(x, x_sq, views, cdf, degs, inv_total, inv_t, key,
                     hstate=None, *, batch, kind, inv_bw, beta, pairwise,
                     block_size, num_blocks, n, s, exact, use_pallas,
                     interpret, bm, level1="blocked", num_far=64,
                     precision="f32"):
    """One Algorithm 5.1 edge batch, steps (a)-(d), as straight-line device
    code: u ~ degrees (inverse CDF over the device prefix array), v | u by
    the depth-2 engine, the reverse probability, and the importance weight
    ``k(u,v) / (t (p_u q_uv + p_v q_vu))``.

    The reverse probability collapses algebraically (DESIGN.md §6): the
    depth-2 factorization gives q(u | v) = S_v(blk_u)/deg(v) *
    k(v,u)/S_v(blk_u) = k(u,v)/deg(v), so no level-1 read of the v
    frontier is needed -- ``degs`` is the degree array the vertex sampler
    already preprocessed, and p_v * q_vu further reduces to
    k(u,v)/sum(deg).  The forward q_uv stays the *realized* sampling
    probability (from the same level-1 sums that drew v)."""
    k_u, k_fwd = jax.random.split(key)
    u = _ref.inverse_cdf_index(cdf, jax.random.uniform(k_u, (batch,)))
    v, q_uv, _, cw = _fused_sample(x, x_sq, u, k_fwd, hstate, kind=kind,
                                   inv_bw=inv_bw, beta=beta,
                                   pairwise=pairwise, block_size=block_size,
                                   num_blocks=num_blocks, n=n, s=s,
                                   exact=exact, use_pallas=use_pallas,
                                   interpret=interpret, bm=bm, level1=level1,
                                   num_far=num_far, precision=precision,
                                   views=views)
    kuv = _ref.kv_pairs(x[u], x[v], kind, inv_bw, beta, pairwise)
    q_vu = kuv / jnp.maximum(degs[v], _ref.BLOCK_SUM_FLOOR)
    # q_e = p_u q_uv + p_v q_vu with p_i = deg_i / sum(deg); the second
    # term telescopes to k(u,v) / sum(deg).
    q_edge = inv_total * (degs[u] * q_uv + kuv)
    wgt = kuv * inv_t / jnp.maximum(q_edge, 1e-30)
    # fused_sample's word + the batch aligned k(u,v) pairs + the batch
    # inverse-CDF u draws (host accounting: level1 + batch*bs + batch)
    cw = _c.fold(cw, _c.word(status=_g.result_status(wgt, q_vu),
                             evals=batch, draws=batch))
    return u, v, wgt, q_uv, q_vu, cw


@_jit
def fused_edge_batch(x, x_sq, cdf, degs, inv_total, inv_t, key, hstate=None,
                     *, batch, kind, inv_bw, beta, pairwise, block_size,
                     num_blocks, n, s, exact, use_pallas, interpret, bm,
                     level1="blocked", num_far=64, precision="f32"):
    """One fused Algorithm 5.1 edge batch: (u, v, weight, q_uv, q_vu,
    counter word)."""
    TRACE_COUNTS["fused_edge_batch"] += 1
    views = _block_views(x, x_sq, block_size)
    return _edge_batch_core(x, x_sq, views, cdf, degs, inv_total, inv_t, key,
                            hstate, batch=batch, kind=kind, inv_bw=inv_bw,
                            beta=beta, pairwise=pairwise,
                            block_size=block_size, num_blocks=num_blocks,
                            n=n, s=s, exact=exact, use_pallas=use_pallas,
                            interpret=interpret, bm=bm, level1=level1,
                            num_far=num_far, precision=precision)


@_jit
def edge_batch_scan(x, x_sq, cdf, degs, inv_total, inv_t, keys, hstate=None,
                    *, batch, kind, inv_bw, beta, pairwise, block_size,
                    num_blocks, n, s, exact, use_pallas, interpret, bm,
                    level1="blocked", num_far=64, precision="f32"):
    """All T = len(keys) edge batches of the sparsifier in ONE program: a
    ``lax.scan`` over per-batch keys whose body is one fused edge batch.
    The whole Algorithm 5.1 sampling loop runs with a single dispatch and
    a single device->host transfer of the (T, batch) edge lists.  The
    per-batch counter words are folded (status ors, counters add) through
    the scan carry -- the last output is the run's merged word."""
    TRACE_COUNTS["edge_batch_scan"] += 1
    views = _block_views(x, x_sq, block_size)

    def body(cw, k):
        u, v, wgt, q_uv, q_vu, cw_b = _edge_batch_core(
            x, x_sq, views, cdf, degs, inv_total, inv_t, k, hstate,
            batch=batch, kind=kind, inv_bw=inv_bw, beta=beta,
            pairwise=pairwise, block_size=block_size, num_blocks=num_blocks,
            n=n, s=s, exact=exact, use_pallas=use_pallas,
            interpret=interpret, bm=bm, level1=level1, num_far=num_far,
            precision=precision)
        return _c.fold(cw, cw_b), (u, v, wgt, q_uv, q_vu)

    word, out = jax.lax.scan(body, _c.word(), keys)
    return out + (word,)


@_jit
def kernel_rows(q, x, x_sq, *, kind, inv_bw, beta, pairwise,
                precision="f32"):
    """Exact (m, n) kernel rows in one program -- the FKV sketch rows and
    the CP17 column reads of Section 5.2, replacing the host chunk loop
    over ``kernel.pairwise``.  Returns ``(rows, counter word)``."""
    TRACE_COUNTS["kernel_rows"] += 1
    kv = _ref.kv_matrix(q, x, x_sq, kind, inv_bw, beta, pairwise,
                        precision=precision)
    return kv, _c.word(status=_g.nonfinite_status(kv),
                       evals=q.shape[0] * x.shape[0])


def _sample_exact_core(x, x_sq, views, src, bs, key, *, kind, inv_bw, beta,
                       pairwise, block_size, n, rounds, slack):
    zs = bs.sum(axis=1)
    keys = jax.random.split(key, 2 * rounds + 1)
    cur, _ = _sample_core(x, x_sq, views, src, bs, keys[0], kind=kind,
                          inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                          block_size=block_size, n=n)
    accepted = jnp.zeros(src.shape[0], bool)
    xs = x[src]
    for r in range(rounds):
        cand, q = _sample_core(x, x_sq, views, src, bs, keys[2 * r + 1],
                               kind=kind, inv_bw=inv_bw, beta=beta,
                               pairwise=pairwise, block_size=block_size, n=n)
        kuv = _ref.kv_pairs(xs, x[cand], kind, inv_bw, beta, pairwise)
        ratio = kuv / jnp.maximum(slack * q * zs, 1e-30)
        u = jax.random.uniform(keys[2 * r + 2], (src.shape[0],))
        acc = (~accepted) & (u < jnp.minimum(ratio, 1.0))
        cur = jnp.where(acc, cand, cur)
        accepted |= acc
    fallbacks = jnp.sum(~accepted).astype(jnp.int32)
    st = _g.flag_if(fallbacks > 0, _g.REJECT_EXHAUSTED)
    return cur, st, fallbacks


@_jit
def fused_sample_exact(x, x_sq, src, bs, key, *, kind, inv_bw, beta, pairwise,
                       block_size, n, rounds, slack):
    """Theorem 4.12 rejection rounds in one program.  The cached level-1
    sums ``bs`` are shared across every proposal round AND the degree
    estimate -- the seed re-swept the dataset once per round.  Returns
    (neighbors, counter word, fallback count): draws whose rounds all
    rejected keep the round-0 proposal (biased) and are counted in the
    word's RETRIES slot, not hidden."""
    TRACE_COUNTS["fused_sample_exact"] += 1
    views = _block_views(x, x_sq, block_size)
    cur, st, fallbacks = _sample_exact_core(
        x, x_sq, views, src, bs, key, kind=kind, inv_bw=inv_bw, beta=beta,
        pairwise=pairwise, block_size=block_size, n=n, rounds=rounds,
        slack=slack)
    st = _g.merge(st, _g.sums_status(bs, _ref.BLOCK_SUM_FLOOR))
    w = src.shape[0]
    # (rounds + 1) level-2 rows + rounds aligned accept pairs -- the host
    # accounting in NeighborSampler.sample_exact, verbatim
    cw = _c.word(status=st,
                 evals=(rounds + 1) * w * block_size + rounds * w,
                 draws=(rounds + 1) * w, retries=fallbacks)
    return cur, cw, fallbacks


# fold_in constant deriving a walk program's cache key from its first
# step key (any fixed value works; it only has to be distinct from the
# per-step split stream).
_WALK_CACHE_FOLD = 97


def walk_cache_samples(num_blocks: int, s: int) -> int:
    """Per-block subsample width ``s_eff`` of the walk-resident cache --
    exposed so eval accounting (``core.sampling.edge``) and the benchmarks
    report the true per-step level-1 cost."""
    return _tuning.walk_samples_per_block(num_blocks, s)


def walk_layout(n: int, block_size: int, num_blocks: int, s: int):
    """(stratum width, stratum count, per-stratum cache width) of the
    walk-resident layout (``tuning.walk_block_size``): the walk step's own
    block granularity, decoupled from the sampler's query layout so the
    exact level-2 read stays narrow as n grows.  Shared by ``walk_scan``
    and the eval accounting in ``core.sampling.edge``.

    When the sampler's own layout already fits the cache budget
    (``num_blocks * s <= WALK_CACHE_COLS``) it is returned unchanged, so
    small problems keep the query layout -- and the per-step eval count
    stays EXACTLY the mesh engine's ``B * s + block_size`` (the sharded
    walk has no resident cache; counter parity across backends is a
    pinned contract)."""
    if num_blocks * s <= _tuning.WALK_CACHE_COLS:
        return block_size, num_blocks, s
    wbs = _tuning.walk_block_size(n, block_size)
    w_blocks = -(-int(n) // wbs)
    return wbs, w_blocks, _tuning.walk_samples_per_block(w_blocks, s)


def _walk_level1_cache(x, x_sq, key, *, block_size, num_blocks, n, s):
    """Walk-resident compact level-1 subsample (DESIGN.md §14).

    ONE stratified per-block draw per walk program -- ``s_eff =
    walk_cache_samples(B, s)`` columns per block, total capped at
    ~``tuning.WALK_CACHE_COLS`` columns -- gathered into a compact
    (B * s_eff, d) array that every step's level-1 read sweeps instead of
    re-gathering a fresh O(B s) subsample from the full dataset.  This is
    the n=65536 walk-cliff fix: the per-step level-1 cost becomes
    O(w * WALK_CACHE_COLS), independent of n, and the gather touches a
    dataset-sized array once per *program* instead of once per *step*.
    The cache key is ``fold_in(keys[0], const)`` so the draw is a pure
    function of the walk's key stream (vmap-safe for the serving lanes;
    re-running with the same keys reuses the identical subsample).
    Returns ``(xs, xs_sq, sel, scale)``; ``sel`` is None on the tail-free
    layout.  The cache is laid out SAMPLE-major -- column ``j`` holds
    sample ``j // B`` of block ``j % B`` -- so the per-step reduction is
    ``reshape(w, s_eff, B).sum(1)``: a middle-axis sum with the B blocks
    contiguous in the minor axis, which vectorizes ~2x better than the
    narrow trailing ``(w, B, s_eff).sum(-1)`` when ``s_eff`` is small."""
    ck = jax.random.fold_in(key, _WALK_CACHE_FOLD)
    base = jnp.arange(num_blocks, dtype=jnp.int32) * block_size
    u = jax.random.uniform(ck, (num_blocks, block_size))
    if n == num_blocks * block_size:
        _, order = jax.lax.top_k(-u, s)           # (B, s_eff) w/o repl.
        flat = (base[:, None] + order).T.reshape(-1)
        sel = None
        scale = jnp.full((num_blocks,), block_size / float(s), jnp.float32)
    else:
        pos = base[:, None] + jnp.arange(block_size, dtype=jnp.int32)[None, :]
        valid_pos = pos < n
        u = jnp.where(valid_pos, u, jnp.inf)
        _, order = jax.lax.top_k(-u, s)
        idx = jnp.take_along_axis(pos, order, axis=1)
        sel = jnp.take_along_axis(valid_pos, order, axis=1).T.reshape(-1)
        flat = jnp.minimum(idx, n - 1).T.reshape(-1)
        sizes = jnp.minimum(n - base, block_size).astype(jnp.float32)
        s_b = jnp.minimum(sizes, float(s))
        scale = sizes / jnp.maximum(s_b, 1.0)
    return x[flat], x_sq[flat], sel, scale


def _cached_block_sums(cache, x, src, *, kind, inv_bw, beta, pairwise,
                       block_size, num_blocks, s, precision):
    """Masked level-1 read against the walk-resident cache: one compact
    (w, B * s_eff) kernel eval, per-block reduction and rescale, then the
    §2 own-block correction + floor (identical post-processing to
    ``_masked_block_sums``)."""
    xs, xs_sq, sel, scale = cache
    q = x[src]
    kv = _ref.kv_matrix(q, xs, xs_sq, kind, inv_bw, beta, pairwise,
                        precision=precision)
    if sel is not None:
        kv = kv * sel[None, :]
    bs = kv.reshape(q.shape[0], s, num_blocks).sum(1) * scale[None, :]
    own = (src // block_size).astype(jnp.int32)
    corr = jnp.arange(num_blocks, dtype=jnp.int32)[None, :] == own[:, None]
    bs = jnp.where(corr, bs - 1.0, bs)
    return jnp.maximum(bs, _ref.BLOCK_SUM_FLOOR)


@_jit
def walk_scan(x, x_sq, starts, keys, hstate=None, *, kind, inv_bw, beta,
              pairwise, block_size, num_blocks, n, s, exact, use_pallas,
              interpret, bm, rounds, slack, record_path=True,
              level1="blocked", num_far=64, precision="f32"):
    """T-step random walk entirely on device: the frontier is scan carry,
    each step is one fused depth-2 sample (or rejection-exact step when
    ``rounds > 0``).  Returns (endpoints, (T, w) path); with
    ``record_path=False`` the path is never materialized (the scan emits no
    per-step output, so long walks cost O(w) device memory, not O(T w))
    and None is returned in its place.  The key stream is identical either
    way, so endpoints match bitwise.  Returns (endpoints, path, counter
    word, rejection-fallback count) -- per-step words are fold-reduced
    (status ors, counters add) across the T steps inside the scan carry.

    On the stratified blocked path (``exact=False``, jnp level-1) the
    level-1 read runs against the walk-resident subsample cache built ONCE
    before the scan (see ``_walk_level1_cache``); every step still draws
    its own level-2 randomness from the per-step key stream."""
    TRACE_COUNTS["walk_scan"] += 1
    views = _block_views(x, x_sq, block_size)  # hoisted out of the step body
    cache = None
    wbs, w_blocks, s_eff = block_size, num_blocks, s
    if level1 == "blocked" and not exact and not use_pallas:
        # walk-resident layout: same ~WALK_CACHE_COLS cached level-1
        # columns spread over finer strata, so the exact level-2 read is
        # O(wbs) << O(block_size) at large n (tuning.walk_block_size)
        wbs, w_blocks, s_eff = walk_layout(n, block_size, num_blocks, s)
        cache = _walk_level1_cache(x, x_sq, keys[0], block_size=wbs,
                                   num_blocks=w_blocks, n=n, s=s_eff)
        views = _block_views(x, x_sq, wbs)

    w = starts.shape[0]
    cols, far, ov = _l1_cols(level1, exact, num_blocks, s, n, num_far,
                             hstate)

    def body(carry, k):
        cur, cw, fb = carry
        if rounds > 0:
            k_l1, k_rs = jax.random.split(k)
            if cache is not None:
                bs = _cached_block_sums(cache, x, cur, kind=kind,
                                        inv_bw=inv_bw, beta=beta,
                                        pairwise=pairwise,
                                        block_size=wbs,
                                        num_blocks=w_blocks, s=s_eff,
                                        precision=precision)
                st1 = _g.sums_status(bs, _ref.BLOCK_SUM_FLOOR)
                l1_evals, l1_far, l1_ov = w * w_blocks * s_eff, 0, 0
            else:
                bs, st1 = _masked_sums_any(x, x_sq, cur, k_l1, hstate,
                                           kind=kind, inv_bw=inv_bw,
                                           beta=beta, pairwise=pairwise,
                                           block_size=block_size,
                                           num_blocks=num_blocks, n=n, s=s,
                                           exact=exact,
                                           use_pallas=use_pallas,
                                           interpret=interpret, bm=bm,
                                           level1=level1, num_far=num_far,
                                           precision=precision)
                l1_evals, l1_far, l1_ov = w * cols, w * far, w * ov
            nxt, st2, fb_k = _sample_exact_core(
                x, x_sq, views, cur, bs, k_rs, kind=kind, inv_bw=inv_bw,
                beta=beta, pairwise=pairwise, block_size=wbs, n=n,
                rounds=rounds, slack=slack)
            cw_k = _c.word(
                status=st1 | st2,
                evals=l1_evals + (rounds + 1) * w * wbs + rounds * w,
                l1_reads=w, draws=(rounds + 1) * w, retries=fb_k,
                far_samples=l1_far, overflow=l1_ov)
            fb = fb + fb_k
        elif cache is not None:
            # mirrors _fused_sample's (k_l1, k_rest) discipline; k_l1 is
            # unused because the level-1 subsample is the walk-resident one
            _, k_rest = jax.random.split(k)
            bs = _cached_block_sums(cache, x, cur, kind=kind, inv_bw=inv_bw,
                                    beta=beta, pairwise=pairwise,
                                    block_size=wbs,
                                    num_blocks=w_blocks, s=s_eff,
                                    precision=precision)
            nxt, prob = _walk_sample_core(x, x_sq, views, cur, bs, k_rest,
                                          kind=kind, inv_bw=inv_bw,
                                          beta=beta, pairwise=pairwise,
                                          block_size=wbs, n=n,
                                          num_blocks=w_blocks)
            cw_k = _c.word(
                status=_g.merge(_g.sums_status(bs, _ref.BLOCK_SUM_FLOOR),
                                _g.result_status(prob)),
                evals=w * w_blocks * s_eff + w * wbs, l1_reads=w, draws=w)
        else:
            nxt, _, _, cw_k = _fused_sample(x, x_sq, cur, k, hstate,
                                           kind=kind, inv_bw=inv_bw,
                                           beta=beta, pairwise=pairwise,
                                           block_size=block_size,
                                           num_blocks=num_blocks, n=n, s=s,
                                           exact=exact, use_pallas=use_pallas,
                                           interpret=interpret, bm=bm,
                                           level1=level1, num_far=num_far,
                                           precision=precision, views=views)
        return (nxt, _c.fold(cw, cw_k), fb), (nxt if record_path else None)

    (end, word, fallbacks), path = jax.lax.scan(
        body, (starts, _c.word(), jnp.int32(0)), keys)
    return end, path, word, fallbacks


# --------------------------------------------------------------------- #
# fused application programs (DESIGN.md §7): eigen / Laplacian / local
# clustering / triangles run their inner loops as single programs too
# --------------------------------------------------------------------- #
@_jit
def noisy_power_scan(ksub, v0, keys, *, num_samples):
    """BIMW21 noisy power method (Algorithm 5.18 step 2) as ONE program:
    every iteration importance-samples ``num_samples`` indices j ~ |v_j|
    by inverse CDF, forms the unbiased matvec estimate
    ``sum_j sign(v_j) z / S * ksub[:, j]``, and renormalizes -- all under
    ``lax.scan`` with no host round-trips.  Returns (Rayleigh quotient
    from one exact final matvec, final unit vector, counter word --
    iterations whose sampled matvec collapsed or went non-finite are
    flagged, not silently skipped; the DRAWS slot counts the sampled
    matvec lookups into the precomputed ``ksub``, which are NOT fresh
    kernel evals).  Oracle: ``ref.noisy_power_ref`` (identical key
    stream, unrolled)."""
    TRACE_COUNTS["noisy_power_scan"] += 1
    t = ksub.shape[0]

    def body(carry, k):
        v, st = carry
        absv = jnp.abs(v)
        z = jnp.sum(absv)
        cdf = jnp.cumsum(absv)
        u = jax.random.uniform(k, (num_samples,)) * jnp.maximum(z, 1e-30)
        idx = jnp.clip(jnp.searchsorted(cdf, u, side="right"),
                       0, t - 1).astype(jnp.int32)
        contrib = jnp.sign(v[idx]) * z / num_samples
        w = ksub[:, idx] @ contrib
        nw = jnp.linalg.norm(w)
        ok = (nw > 0.0) & (z > 0.0)
        st = st | _g.flag_if(~ok, _g.ZERO_MASS) | _g.nonfinite_status(w)
        return (jnp.where(ok, w / jnp.maximum(nw, 1e-30), v), st), None

    (v, st), _ = jax.lax.scan(body, (v0, jnp.uint32(0)), keys)
    lam = v @ (ksub @ v)
    st = _g.merge(st, _g.result_status(lam, v))
    return lam, v, _c.word(status=st,
                           draws=keys.shape[0] * num_samples)


@_jit
def laplacian_matvec(src, dst, w, p, *, n):
    """L_{G'} p = D p - A p over a COO edge list as segment-sum scatters
    (no ``np.add.at``); one jitted program per (n, m) shape pair."""
    TRACE_COUNTS["laplacian_matvec"] += 1
    return _ref.laplacian_matvec_ref(src, dst, w, p, n)


@_jit
def laplacian_cg(src, dst, w, b, tol, *, n, iters):
    """Jacobi-preconditioned CG for ``L_{G'} x = b`` (b perp 1) as ONE
    ``lax.while_loop`` program: the segment-sum matvec, the dot products,
    and the convergence test all stay on device (Section 5.1.1's solve
    step -- the seed ran one host iteration per CG step).

    Float32-safe: the loop tracks the best iterate seen (CG in f32 stalls
    near machine precision instead of hitting ``tol``) and stops on
    stagnation -- non-positive curvature / preconditioned residual, a
    non-finite residual, or 32 consecutive iterations without improving
    the best residual (the f32 plateau; without this exit a sub-f32
    ``tol`` would burn the full ``iters`` budget after convergence).
    Returns (best iterate, projected to 1^perp, its residual norm, and a
    counter word flagging non-convergence / non-finite output; the DRAWS
    slot records the realized CG iteration count -- the one
    data-dependent cost of this program)."""
    TRACE_COUNTS["laplacian_cg"] += 1
    deg = jnp.zeros((n,), w.dtype).at[src].add(w).at[dst].add(w)
    dinv = 1.0 / jnp.maximum(deg, 1e-30)

    def proj(v):
        return v - jnp.mean(v)

    def matvec(p):
        av = jnp.zeros((n,), w.dtype).at[src].add(w * p[dst]).at[dst].add(
            w * p[src])
        return deg * p - av

    bb = proj(b)
    x0 = jnp.zeros((n,), w.dtype)
    r0 = bb
    z0 = proj(dinv * r0)
    rz0 = jnp.dot(r0, z0)
    bnorm = jnp.maximum(jnp.linalg.norm(bb), 1e-30)

    def cond(c):
        return (c[0] < iters) & (~c[-1])

    def body(c):
        i, x_, r_, p_, rz_, bx, br, stall, _ = c
        ap = matvec(p_)
        denom = jnp.dot(p_, ap)
        ok = (denom > 0.0) & (rz_ > 0.0)
        alpha = jnp.where(ok, rz_ / jnp.maximum(denom, 1e-30), 0.0)
        x2 = x_ + alpha * p_
        r2 = r_ - alpha * ap
        rn = jnp.linalg.norm(r2)
        better = ok & (rn < br)
        bx2 = jnp.where(better, x2, bx)
        br2 = jnp.where(better, rn, br)
        stall2 = jnp.where(better, 0, stall + 1)
        z2 = proj(dinv * r2)
        rz2 = jnp.dot(r2, z2)
        p2 = z2 + jnp.where(ok, rz2 / jnp.maximum(rz_, 1e-30), 0.0) * p_
        stop = (~ok) | (rn < tol * bnorm) | (~jnp.isfinite(rn)) \
            | (rz2 <= 0.0) | (stall2 >= 32)
        return i + 1, x2, r2, p2, rz2, bx2, br2, stall2, stop

    init = (0, x0, r0, z0, rz0, x0, jnp.linalg.norm(r0), 0, False)
    out = jax.lax.while_loop(cond, body, init)
    sol, res = proj(out[5]), out[6]
    st = _g.merge(_g.flag_if(res >= tol * bnorm, _g.CG_NO_CONVERGE),
                  _g.result_status(sol, res))
    return sol, res, _c.word(status=st, draws=out[0])


@_jit
def signed_endpoint_stat(ends, signs, *, n):
    """``sum_i (sum_j signs_j [ends_j = i])^2`` -- the collision part of
    the CDVV14 l2 statistic computed on device: with signs +1 for the u
    walks and -1 for the w walks this is ``sum_i (X_i - Y_i)^2`` over the
    endpoint count vectors, one segment-sum and one reduction.  Returns
    ``(statistic, counter word)`` -- zero kernel evals by construction."""
    TRACE_COUNTS["signed_endpoint_stat"] += 1
    c = jnp.zeros((n,), signs.dtype).at[ends].add(signs)
    stat = jnp.sum(c * c)
    return stat, _c.word(status=_g.result_status(stat))


@_jit
def triangle_edge_scan(x, x_sq, u, v, degs, keys, hstate=None, *, kind,
                       inv_bw, beta, pairwise, block_size, num_blocks, n, s,
                       exact, use_pallas, interpret, bm, level1="blocked",
                       num_far=64, precision="f32"):
    """Theorem 6.17's per-edge inner loop as ONE program: degree-ordered
    orientation of the (u, v) pairs, ONE masked level-1 read of the
    oriented v frontier (keys[0], shared by every draw -- the §4 caching
    contract inside a single trace), then a ``lax.scan`` over keys[1:]
    where each step draws w ~ k(v, .)/deg(v), masks by the ordering
    ``v < w`` and ``w != u``, and accumulates k(u,v) k(u,w); the final
    reweighting by deg(v)/num_draws also happens in-program.  Returns
    (oriented u, oriented v, per-edge weight estimates W_e, counter
    word).  Oracle: ``ref.triangle_batch_ref``."""
    TRACE_COUNTS["triangle_edge_scan"] += 1
    views = _block_views(x, x_sq, block_size)
    prec = _ref.degree_precedes(degs, u, v)
    uu = jnp.where(prec, u, v)
    vv = jnp.where(prec, v, u)
    kuv = _ref.kv_pairs(x[uu], x[vv], kind, inv_bw, beta, pairwise)
    bs, st = _masked_sums_any(x, x_sq, vv, keys[0], hstate, kind=kind,
                              inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                              block_size=block_size, num_blocks=num_blocks,
                              n=n, s=s, exact=exact, use_pallas=use_pallas,
                              interpret=interpret, bm=bm, level1=level1,
                              num_far=num_far, precision=precision)

    def body(acc, k):
        w, _ = _sample_core(x, x_sq, views, vv, bs, k, kind=kind,
                            inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                            block_size=block_size, n=n)
        valid = _ref.degree_precedes(degs, vv, w) & (w != uu)
        kuw = _ref.kv_pairs(x[uu], x[w], kind, inv_bw, beta, pairwise)
        return acc + jnp.where(valid, kuv * kuw, 0.0), None

    acc, _ = jax.lax.scan(body, jnp.zeros_like(kuv), keys[1:])
    num_draws = keys.shape[0] - 1
    w_hat = acc * degs[vv] / num_draws
    m = u.shape[0]
    cols, far, ov = _l1_cols(level1, exact, num_blocks, s, n, num_far,
                             hstate)
    # one level-1 read of the m-edge frontier + m k(u,v) pairs + per draw
    # m level-2 rows and m k(u,w) pairs -- NeighborSampler.triangle_batches
    cw = _c.word(status=_g.merge(st, _g.result_status(w_hat)),
                 evals=m * cols + m + num_draws * (m * block_size + m),
                 l1_reads=m, draws=num_draws * m, far_samples=m * far,
                 overflow=m * ov)
    return uu, vv, w_hat, cw


# --------------------------------------------------------------------- #
# batched multi-tenant entry points (DESIGN.md §13)
#
# One serving tick aggregates R concurrent requests -- possibly from
# different tenants -- into ONE padded device batch: ``tidx (R,)`` indexes
# the stacked tenant arena ``xa (T, n, d)`` / ``xa_sq (T, n)`` (and, for
# hashed level-1 tenants, a stacked ``HashState`` pytree), every request
# carries its OWN PRNG key, and every program returns a PER-REQUEST uint32
# status word.  ``jax.vmap`` over the request axis reduces each lane to
# the identical op sequence the single-request entry point runs, so lanes
# match the sequential calls bitwise on the jnp paths (the parity contract
# ``tests/test_serving.py`` asserts).  All request-axis shapes are padded
# to static buckets by the serving layer, which bounds recompiles to one
# program per (tenant signature, op, bucket) group.
# --------------------------------------------------------------------- #
def _tenant(xa, xa_sq, hstate, ti):
    """Gather one request's tenant slice out of the stacked arena.  Runs
    under vmap, so ``ti`` is a traced per-request scalar and the hash
    state (when present) is gathered leaf-wise from the stacked pytree."""
    hs = (jax.tree_util.tree_map(lambda a: a[ti], hstate)
          if hstate is not None else None)
    return xa[ti], xa_sq[ti], hs


@_jit
def batched_fused_sample(xa, xa_sq, tidx, src, keys, hstate=None, *, kind,
                         inv_bw, beta, pairwise, block_size, num_blocks, n,
                         s, exact, use_pallas, interpret, bm,
                         level1="blocked", num_far=64, precision="f32"):
    """One serving tick's depth-2 draws for R requests across T tenants as
    ONE program: ``src (R, w)`` padded frontiers, ``keys (R, 2)``
    per-request PRNG keys, ``tidx (R,)`` tenant indices.  Returns
    (neighbors (R, w), probs (R, w), level-1 sums (R, w, B), per-request
    counter words (R, obs.WIDTH)).  Lane r is exactly ``fused_sample`` on
    tenant ``tidx[r]`` with key ``keys[r]``."""
    TRACE_COUNTS["batched_fused_sample"] += 1

    def one(ti, src_r, key_r):
        x, x_sq, hs = _tenant(xa, xa_sq, hstate, ti)
        return _fused_sample(x, x_sq, src_r, key_r, hs, kind=kind,
                             inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                             block_size=block_size, num_blocks=num_blocks,
                             n=n, s=s, exact=exact, use_pallas=use_pallas,
                             interpret=interpret, bm=bm, level1=level1,
                             num_far=num_far, precision=precision)

    return jax.vmap(one)(tidx, src, keys)


@_jit
def batched_walk_scan(xa, xa_sq, tidx, starts, keys, hstate=None, *, kind,
                      inv_bw, beta, pairwise, block_size, num_blocks, n, s,
                      exact, use_pallas, interpret, bm, rounds, slack,
                      record_path=False, level1="blocked", num_far=64,
                      precision="f32"):
    """R independent T-step walks (``starts (R, w)``, ``keys (R, T, 2)``)
    across stacked tenants in ONE program.  Returns (endpoints (R, w),
    path ((R, T, w) or None), counter words (R, obs.WIDTH), rejection
    fallbacks (R,)) -- lane r is ``walk_scan`` on its tenant with its own
    key stream, so endpoints are bitwise equal to the sequential
    per-request calls."""
    TRACE_COUNTS["batched_walk_scan"] += 1

    def one(ti, st_r, keys_r):
        x, x_sq, hs = _tenant(xa, xa_sq, hstate, ti)
        return walk_scan(x, x_sq, st_r, keys_r, hs, kind=kind, inv_bw=inv_bw,
                         beta=beta, pairwise=pairwise, block_size=block_size,
                         num_blocks=num_blocks, n=n, s=s, exact=exact,
                         use_pallas=use_pallas, interpret=interpret, bm=bm,
                         rounds=rounds, slack=slack, record_path=record_path,
                         level1=level1, num_far=num_far,
                         precision=precision)

    return jax.vmap(one)(tidx, starts, keys)


@_jit
def batched_prob_of(xa, xa_sq, tidx, src, dst, keys, hstate=None, *, kind,
                    inv_bw, beta, pairwise, block_size, num_blocks, n, s,
                    exact, use_pallas, interpret, bm, level1="blocked",
                    num_far=64, precision="f32"):
    """q(dst | src) for R requests (``src``/``dst`` (R, w)) in ONE
    program: per lane one masked level-1 read of the src frontier (the
    same read ``prob_of`` performs when its cache is cold) followed by the
    exact level-2 probability.  Returns (probs (R, w), counter words
    (R, obs.WIDTH))."""
    TRACE_COUNTS["batched_prob_of"] += 1

    def one(ti, src_r, dst_r, key_r):
        x, x_sq, hs = _tenant(xa, xa_sq, hstate, ti)
        views = _block_views(x, x_sq, block_size)
        bs, st = _masked_sums_any(x, x_sq, src_r, key_r, hs, kind=kind,
                                  inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                                  block_size=block_size,
                                  num_blocks=num_blocks, n=n, s=s,
                                  exact=exact, use_pallas=use_pallas,
                                  interpret=interpret, bm=bm, level1=level1,
                                  num_far=num_far, precision=precision)
        prob = _prob_core(x, x_sq, views, src_r, dst_r, bs, kind=kind,
                          inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                          block_size=block_size, n=n)
        wq = src_r.shape[0]
        cols, far, ov = _l1_cols(level1, exact, num_blocks, s, n, num_far,
                                 hs)
        return prob, _c.word(status=_g.merge(st, _g.result_status(prob)),
                             evals=wq * (cols + block_size), l1_reads=wq,
                             far_samples=wq * far, overflow=wq * ov)

    return jax.vmap(one)(tidx, src, dst, keys)


@_jit
def batched_kde_query(xa, xa_sq, tidx, y, keys, *, kind, inv_bw, beta,
                      pairwise, block_size, num_blocks, n, s, exact,
                      precision="f32"):
    """Definition 1.1 row-sum estimates for R query requests (``y``
    (R, q, d) external points) in ONE program -- the dense level-1 read
    per lane (exact or stratified, matching ``ExactBlockKDE`` /
    ``StratifiedKDE.query``).  Hash tenants are served by
    ``kde_hash.ops.batched_hashed_query`` instead.  Returns (estimates
    (R, q), counter words (R, obs.WIDTH))."""
    TRACE_COUNTS["batched_kde_query"] += 1

    def one(ti, y_r, key_r):
        x, x_sq = xa[ti], xa_sq[ti]
        if exact:
            bs, cw = exact_block_sums(y_r, x, x_sq, kind=kind,
                                      inv_bw=inv_bw, beta=beta,
                                      pairwise=pairwise,
                                      block_size=block_size,
                                      num_blocks=num_blocks, n=n,
                                      precision=precision)
        else:
            bs, cw = stratified_block_sums(y_r, x, x_sq, key_r, kind=kind,
                                           inv_bw=inv_bw, beta=beta,
                                           pairwise=pairwise,
                                           block_size=block_size,
                                           num_blocks=num_blocks, n=n, s=s,
                                           precision=precision)
        est = bs.sum(-1)
        st = _g.merge(_g.sums_status(bs, _ref.BLOCK_SUM_FLOOR),
                      _g.result_status(est))
        return est, _c.fold_status(cw, st)

    return jax.vmap(one)(tidx, y, keys)


# --------------------------------------------------------------------- #
# streaming patches (DESIGN.md §12)
# --------------------------------------------------------------------- #
@_jit
def patch_block_sums(bs, x, src, slots, old_x, new_x, *, kind, inv_bw, beta,
                     pairwise, block_size):
    """Incrementally update a cached (w, B) level-1 read after a dataset
    mutation batch: O(w m) kernel evals instead of the O(w n) rebuild.
    The jitted body IS ``ref.patch_block_sums_ref`` (same delta scatter),
    so the oracle parity is structural; equivalence vs a fresh rebuild is
    what the streaming tests assert.  Frontier rows that mutated must NOT
    be patched -- the consumer drops the cache instead (the ``src``
    operand is only read for the frontier coordinates).  Returns
    ``(patched sums, counter word)``."""
    TRACE_COUNTS["patch_block_sums"] += 1
    out = _ref.patch_block_sums_ref(bs, x[src], slots, old_x, new_x, kind,
                                    inv_bw, beta, block_size, pairwise)
    # old + new kernel values per (frontier row, mutated slot) pair --
    # the host accounting in NeighborSampler._sync, verbatim
    return out, _c.word(status=_g.nonfinite_status(out),
                        evals=2 * src.shape[0] * slots.shape[0])


@_jit
def degree_delta(degs, x, x_sq, slots, old_x, new_x, old_live, new_live, *,
                 kind, inv_bw, beta, pairwise):
    """Incremental Algorithm 4.3 degree update after a mutation batch:
    O(n m) evals against the post-mutation padded arrays (column deltas
    for untouched rows, exact recompute for the mutated slots), replacing
    the O(n^2 / estimator-budget) degree rebuild.  Returns ``(degrees,
    counter word)``."""
    TRACE_COUNTS["degree_delta"] += 1
    out = _ref.degree_delta_ref(degs, x, x_sq, slots, old_x, new_x,
                                old_live, new_live, kind, inv_bw, beta,
                                pairwise)
    # old + new kernel column per mutated slot against all n rows -- the
    # host accounting in DegreeSampler._sync / RowNormSampler._sync
    return out, _c.word(status=_g.nonfinite_status(out),
                        evals=2 * slots.shape[0] * degs.shape[0])
