"""Mesh-resident fused depth-2 sampling engine (DESIGN.md §9).

``ShardedBlocks`` is the multi-device twin of the single-device engine in
``ops.py``: the level-1 block structure lives sharded over a mesh (each
shard owns a contiguous run of dataset rows, padded with the far-offset
sentinel used everywhere else in this repo so every shard holds the same
number of whole blocks), and one depth-2 draw is a two-stage collective
program:

1. every shard computes its *local* masked block sums ``S_b^(p)`` (w, B_p)
   and a speculative local candidate -- block by inverse CDF over the local
   sums, level-2 row gathered from the shard's own ``(B_p, bs, d)`` block
   views, in-block draw -- all from replicated uniforms;
2. ONE ``psum`` of the one-hot payload ``(t_p, nb_p, S_b * p_in)`` makes
   the per-shard totals and candidates replicated, and the owning shard is
   picked by inverse CDF over the totals (the hierarchical decomposition
   ``p(shard) * p(block | shard) * p(col | block)`` of the flat categorical
   -- identical distribution to the single-device draw).

The realized probability returned is ``S_b * p_in / sum_p t_p`` -- exactly
the flat engine's ``(S_b / sum S) * p_in``.  Per draw batch the collective
schedule is exactly one ``psum`` and zero ``ppermute`` (asserted by
``collective_counts`` in tests); no stage ever moves dataset rows between
shards, so the O(n d / P) block views and the O(w n / P) level-1 sweeps are
the only per-device memory/compute.

Layout: ``n`` rows are padded to ``P * shard_size`` where ``shard_size``
is ``ceil(n / P)`` rounded up to a whole number of ``block_size`` blocks.
Padding sits at the global tail, so dataset indices are unchanged, global
block ``b`` covers rows ``[b * bs, (b+1) * bs)`` exactly as on one device,
and the extra all-sentinel blocks carry zero mass (they are excluded from
the 1e-12 floor, so they can never be drawn).

All entry points consume ``jax.random`` keys with the same split
discipline as their pure-jnp oracles in ``ref.py`` (ints must agree
bit-for-bit, floats to f32 tolerance).  ``ops.TRACE_COUNTS`` is shared, so
the no-retrace tests cover the sharded programs too.  Compiled programs
are cached at module level keyed on the full static config (mesh, layout,
kernel) -- dataset arrays are always call arguments, so successive
pipeline constructions over the same mesh share every program.

Every public program returns an ``obs.counters`` ``(WIDTH,)`` counter
word in the status position (DESIGN.md §15.1).  The words are assembled
OUTSIDE the shard_map programs -- counter slots are trace-time constants
from static shard shapes, status is the program's replicated post-psum
scalar -- so widening provably adds ZERO collectives (``psum_total`` per
draw batch is pinned by ``collective_counts`` in tests); the ``PSUMS``
slot records the §9 collective budget each call realizes.  Counts are
*global* realized work summed over shards, including the sentinel
padding shards sweep (device-realized evals, which on padded meshes
exceed the host's analytic per-row counts).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.ft import guards as _g
from repro.kernels.kde_rowsum.ops import _PAD_OFFSET
from repro.kernels.kde_sampler import ops as _ops
from repro.kernels.kde_sampler import ref as _ref
from repro.obs import counters as _c

TRACE_COUNTS = _ops.TRACE_COUNTS

_COLLECTIVES = ("psum", "ppermute", "all_gather", "all_to_all",
                "reduce_scatter", "pmax", "pmin")

# jitted shard_map programs, keyed by (engine spec, program name,
# per-program statics) -- shared across ShardedBlocks instances.  The
# closures capture only the stateless _EngineSpec, never device arrays.
_PROGRAM_CACHE: dict = {}


def collective_counts(fn, *args, **kwargs):
    """Count collective primitive binds in ``fn``'s jaxpr (recursing into
    scan/while/call sub-jaxprs).  Each bind counts once regardless of loop
    trip count, so the result is the collective schedule *per draw batch*
    of a scanned program -- the object DESIGN.md §9 pins down."""
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    acc: dict = {}

    def visit(jx):
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if any(name.startswith(c) for c in _COLLECTIVES):
                acc[name] = acc.get(name, 0) + 1
            for v in eqn.params.values():
                if isinstance(v, jax.core.ClosedJaxpr):
                    visit(v.jaxpr)
                elif hasattr(v, "eqns"):
                    visit(v)
                elif isinstance(v, (tuple, list)):
                    for w in v:
                        if isinstance(w, jax.core.ClosedJaxpr):
                            visit(w.jaxpr)
    visit(jaxpr.jaxpr)
    acc["psum_total"] = sum(v for k, v in acc.items() if k.startswith("psum"))
    acc["ppermute_total"] = sum(v for k, v in acc.items()
                                if k.startswith("ppermute"))
    return acc


def _flat_index(mesh: Mesh, axes: Sequence[str]):
    """Flattened (row-major over ``axes``) shard index inside a shard_map
    body -- matches how ``P(axes)`` linearizes the shards."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * int(mesh.shape[a]) + jax.lax.axis_index(a)
    return idx


@dataclasses.dataclass(frozen=True)
class _EngineSpec:
    """Static configuration + shard-local math of a sharded engine.

    Stateless (no device arrays), hashable, and the ONLY thing program
    closures capture -- so module-level program caching never pins a
    dataset, and two engines with equal specs share compiled programs.
    """

    mesh: Mesh
    axes: tuple
    num_shards: int
    n: int
    d: int
    block_size: int
    shard_size: int
    blocks_per_shard: int
    samples_per_block: int
    exact: bool
    kind: str
    inv_bw: float
    beta: float
    pairwise: object

    # ------------------------------------------------------------------ #
    # shard-local building blocks (called inside shard_map bodies)
    # ------------------------------------------------------------------ #
    def _local_block_sizes(self, pidx):
        """(B_p,) number of *real* (non-sentinel) rows per local block."""
        gbase = pidx * self.shard_size + jnp.arange(
            self.blocks_per_shard, dtype=jnp.int32) * self.block_size
        return jnp.clip(self.n - gbase, 0, self.block_size)

    def _raw_sums(self, q, x_l, xsq_l, key, pidx):
        """Uncorrected, unfloored stratified local block sums (the raw
        Definition 1.1 read -- estimators apply their own corrections)."""
        w = q.shape[0]
        bl, bs = self.blocks_per_shard, self.block_size
        s = self.samples_per_block
        kk = jax.random.fold_in(key, pidx)
        base = jnp.arange(bl, dtype=jnp.int32) * bs
        u = jax.random.uniform(kk, (bl, bs))
        pos = base[:, None] + jnp.arange(bs, dtype=jnp.int32)[None, :]
        valid = (pidx * self.shard_size + pos) < self.n
        u = jnp.where(valid, u, jnp.inf)
        _, order = jax.lax.top_k(-u, s)
        idx = jnp.take_along_axis(pos, order, axis=1)
        sel_valid = jnp.take_along_axis(valid, order, axis=1)
        flat = idx.reshape(-1)
        kv = _ref.kv_matrix(q, x_l[flat], xsq_l[flat], self.kind,
                            self.inv_bw, self.beta, self.pairwise)
        kv = kv.reshape(w, bl, s) * sel_valid[None]
        sizes_f = self._local_block_sizes(pidx).astype(jnp.float32)
        s_b = jnp.minimum(sizes_f, float(s))
        return kv.sum(-1) * (sizes_f / jnp.maximum(s_b, 1.0))[None, :]

    def _local_sums(self, q, own, x_l, xsq_l, key, pidx):
        """Masked §2-contract level-1 sums of the local shard: (w, B_p)
        with the self-kernel subtracted from each query's own block, real
        blocks floored at 1e-12, all-sentinel blocks pinned to 0.  The
        self-kernel is the repo-wide Kernel contract k(x, x) = 1 --
        identical to ``ops._masked_block_sums`` (bitwise parity)."""
        w = q.shape[0]
        bl, bs = self.blocks_per_shard, self.block_size
        if self.exact:
            kv = _ref.kv_matrix(q, x_l, xsq_l, self.kind, self.inv_bw,
                                self.beta, self.pairwise)
            sums = kv.reshape(w, bl, bs).sum(-1)
        else:
            sums = self._raw_sums(q, x_l, xsq_l, key, pidx)
        gblk = pidx * bl + jnp.arange(bl, dtype=jnp.int32)
        corr = gblk[None, :] == own[:, None]
        sums = jnp.where(corr, sums - 1.0, sums)
        real = self._local_block_sizes(pidx) > 0
        return jnp.where(real[None, :], jnp.maximum(sums,
                                                    _ref.BLOCK_SUM_FLOOR),
                         0.0)

    def _local_draw(self, src, q, qsq, sums_l, key, x_l, xsq_l, pidx):
        """One two-stage collective draw (the §9 schedule: exactly one
        psum).  Returns (nb, prob, T, status) replicated, T = global
        degree estimate sum_p t_p.  The status word is computed from the
        post-psum replicated values only (totals, probabilities), so the
        flags add ZERO collectives and are identical on every shard."""
        w = src.shape[0]
        bl, bs = self.blocks_per_shard, self.block_size
        k_shard, k_blk, k_in = jax.random.split(key, 3)
        t_l = sums_l.sum(axis=1)
        c = jnp.cumsum(sums_l, axis=1)
        u1 = jax.random.uniform(k_blk, (w,))
        blk_l = jnp.sum((u1 * t_l)[:, None] > c, axis=1).clip(
            0, bl - 1).astype(jnp.int32)
        s_b = jnp.take_along_axis(sums_l, blk_l[:, None], axis=1)[:, 0]
        xb = x_l.reshape(bl, bs, self.d)[blk_l]
        xbsq = xsq_l.reshape(bl, bs)[blk_l]
        kv = _ref.kv_rows(q, xb, qsq, xbsq, self.kind, self.inv_bw,
                          self.beta, self.pairwise)
        gcols = (pidx * self.shard_size + blk_l[:, None] * bs
                 + jnp.arange(bs, dtype=jnp.int32)[None, :])
        live = (gcols < self.n) & (gcols != src[:, None])
        kv = jnp.where(live, kv, 0.0)
        nb_l, pin = _ref.level2_draw(kv, live, jnp.minimum(gcols, self.n - 1),
                                     jax.random.uniform(k_in, (w,)))
        qnum = s_b * pin
        oh_f = (jnp.arange(self.num_shards) == pidx).astype(jnp.float32)
        oh_i = (jnp.arange(self.num_shards) == pidx).astype(jnp.int32)
        t_all, q_all, nb_all = jax.lax.psum(
            (t_l[:, None] * oh_f[None, :], qnum[:, None] * oh_f[None, :],
             nb_l[:, None] * oh_i[None, :]), self.axes)
        ct = jnp.cumsum(t_all, axis=1)
        tot = ct[:, -1]
        u0 = jax.random.uniform(k_shard, (w,))
        owner = jnp.sum((u0 * tot)[:, None] > ct, axis=1).clip(
            0, self.num_shards - 1)
        nb = jnp.take_along_axis(nb_all, owner[:, None], axis=1)[:, 0]
        prob = jnp.take_along_axis(q_all, owner[:, None], axis=1)[:, 0] \
            / jnp.maximum(tot, 1e-30)
        num_real = -(-self.n // self.block_size)
        st = _g.merge(_g.totals_status(tot, num_real, _ref.BLOCK_SUM_FLOOR),
                      _g.result_status(prob))
        return nb, prob, tot, st

    def _local_sample_exact(self, src, q, qsq, sums_l, key, x_l, xsq_l,
                            x_rep, pidx, rounds, slack):
        """Theorem 4.12 rejection rounds on the sharded draw -- the same
        accept/reject math as ``ops._sample_exact_core`` with the global
        degree estimate coming from each draw's psum'd totals.  Returns
        (cur, status, fallback count); the acceptance mask is computed
        from replicated values, so the counters need no collective."""
        keys = jax.random.split(key, 2 * rounds + 1)
        cur, _, zs, st = self._local_draw(src, q, qsq, sums_l, keys[0], x_l,
                                          xsq_l, pidx)
        accepted = jnp.zeros(src.shape[0], bool)
        for r in range(rounds):
            cand, qd, _, st_r = self._local_draw(src, q, qsq, sums_l,
                                                 keys[2 * r + 1], x_l, xsq_l,
                                                 pidx)
            st = st | st_r
            kuv = _ref.kv_pairs(q, x_rep[cand], self.kind, self.inv_bw,
                                self.beta, self.pairwise)
            ratio = kuv / jnp.maximum(slack * qd * zs, 1e-30)
            u = jax.random.uniform(keys[2 * r + 2], (src.shape[0],))
            acc = (~accepted) & (u < jnp.minimum(ratio, 1.0))
            cur = jnp.where(acc, cand, cur)
            accepted |= acc
        fallbacks = jnp.sum(~accepted).astype(jnp.int32)
        st = st | _g.flag_if(fallbacks > 0, _g.REJECT_EXHAUSTED)
        return cur, st, fallbacks


class ShardedBlocks:
    """Sharded level-1 block structure + fused collective draw programs.

    Construction pads and places the dataset once (one sharded copy for
    the level-1 sweeps and block views, one replicated copy for frontier
    coordinate gathers); every method is a jitted ``shard_map`` program
    cached at module level by static config, so repeated same-shape calls
    -- across instances too -- never retrace.
    """

    def __init__(self, mesh: Mesh, x, kernel, *, block_size: int,
                 samples_per_block: int = 16, exact: bool = False,
                 data_axes: Sequence[str] = ("data",)):
        axes = tuple(data_axes)
        num_shards = 1
        for a in axes:
            num_shards *= int(mesh.shape[a])
        x = jnp.asarray(x, jnp.float32)
        n, d = int(x.shape[0]), int(x.shape[1])
        bs = int(block_size)
        per = -(-n // num_shards)                             # ceil(n / P)
        shard_size = -(-per // bs) * bs
        self.spec = _EngineSpec(
            mesh=mesh, axes=axes, num_shards=num_shards, n=n, d=d,
            block_size=bs, shard_size=shard_size,
            blocks_per_shard=shard_size // bs,
            samples_per_block=min(int(samples_per_block), bs),
            exact=bool(exact), kind=kernel.name,
            inv_bw=1.0 / kernel.bandwidth,
            beta=float(getattr(kernel, "beta", 1.0)),
            pairwise=_ref.static_pairwise(kernel))
        self.mesh = mesh
        self.axes = axes
        self.num_shards = num_shards
        self.n = n
        self.d = d
        self.block_size = bs
        self.shard_size = shard_size
        self.blocks_per_shard = self.spec.blocks_per_shard
        self.num_blocks_pad = num_shards * self.spec.blocks_per_shard
        self.num_blocks = -(-n // bs)                         # real blocks
        self.samples_per_block = self.spec.samples_per_block
        self.exact = bool(exact)
        self.n_pad = num_shards * shard_size
        pad = self.n_pad - n
        if pad:
            sent = jnp.full((pad, d), _PAD_OFFSET, jnp.float32) + x[-1:]
            xp = jnp.concatenate([x, sent], axis=0)
        else:
            xp = x
        xsq = jnp.sum(xp * xp, axis=-1)
        self.x_sh = jax.device_put(xp, NamedSharding(mesh, P(axes)))
        self.x_sq_sh = jax.device_put(xsq, NamedSharding(mesh, P(axes)))
        self.x_rep = jax.device_put(xp, NamedSharding(mesh, P()))
        self.x_sq_rep = jax.device_put(xsq, NamedSharding(mesh, P()))

    # ------------------------------------------------------------------ #
    # program builders (cached at module level per static config)
    # ------------------------------------------------------------------ #
    def _build(self, name, body, in_specs, out_specs):
        mesh = self.mesh   # bind locally: the cached closure must capture
                           # only statics, never self (and its arrays)

        def outer(*args):
            TRACE_COUNTS[name] += 1
            # check_vma=False: the replication checker cannot follow a
            # psum-in-scan-body carry; replication of the outputs is pinned
            # by the ref-oracle tests instead.
            return shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)(*args)
        return jax.jit(outer)

    def _program(self, key, factory):
        full = (self.spec, key)
        if full not in _PROGRAM_CACHE:
            _PROGRAM_CACHE[full] = factory()
        return _PROGRAM_CACHE[full]

    def _sharded_args(self):
        return self.x_sh, self.x_sq_sh, self.x_rep, self.x_sq_rep

    def _specs4(self):
        ax = self.axes
        return (P(ax), P(ax), P(), P())

    def _l1_evals(self, w: int) -> int:
        """Global realized level-1 kernel evals of one frontier sweep:
        every shard sweeps its whole padded slice (exact) or its
        ``B_p * s`` stratified subsample -- trace-time constant."""
        if self.exact:
            return w * self.n_pad
        return w * self.num_blocks_pad * self.samples_per_block

    # ------------------------------------------------------------------ #
    # public fused programs
    # ------------------------------------------------------------------ #
    def _patch_program(self):
        """The jitted zero-collective mutation program (exposed so tests
        can jaxpr-assert its collective schedule)."""
        sp = self.spec

        def factory():
            def body(x_l, xsq_l, x_rep, xsq_rep, slots, rows):
                pidx = _flat_index(sp.mesh, sp.axes)
                rows_sq = jnp.sum(rows * rows, axis=-1)
                # each shard scatters ONLY its own rows: non-local slots
                # map to the out-of-range local index and are dropped
                lidx = slots - pidx * sp.shard_size
                lidx = jnp.where((lidx >= 0) & (lidx < sp.shard_size),
                                 lidx, sp.shard_size)
                x_l = x_l.at[lidx].set(rows, mode="drop")
                xsq_l = xsq_l.at[lidx].set(rows_sq, mode="drop")
                x_rep = x_rep.at[slots].set(rows)
                xsq_rep = xsq_rep.at[slots].set(rows_sq)
                return x_l, xsq_l, x_rep, xsq_rep
            return self._build("sharded_patch_rows", body,
                               self._specs4() + (P(), P()),
                               self._specs4())
        return self._program("patch_rows", factory)

    def patch_rows(self, slots, rows):
        """Scatter a mutation batch into the mesh-resident dataset copies
        (DESIGN.md §12): each shard patches its own rows, the replicated
        frontier copy is patched in place on every device -- ZERO new
        collectives per mutation batch, so the §9 one-psum-per-draw
        schedule is untouched.  Derived level-1 caches are the caller's
        to patch or drop (``ops.patch_block_sums`` / the §4 cache).
        Returns a zero-eval counter word (scatters are not kernel
        evals)."""
        fn = self._patch_program()
        self.x_sh, self.x_sq_sh, self.x_rep, self.x_sq_rep = fn(
            *self._sharded_args(), jnp.asarray(slots, jnp.int32),
            jnp.asarray(rows, jnp.float32))
        return _c.word()

    def masked_block_sums(self, src, key):
        """Global §2-contract level-1 sums of a frontier: ``(sums, word)``
        with sums (w, B_pad) sharded along columns, no collective at all
        (sampling needs only the psum of totals, which each draw performs
        itself).  The counter word is assembled host-side from static
        shard shapes plus the non-finite check of the returned sums."""
        sp = self.spec

        def factory():
            def body(x_l, xsq_l, x_rep, xsq_rep, src, key):
                pidx = _flat_index(sp.mesh, sp.axes)
                q = x_rep[src]
                return sp._local_sums(q, (src // sp.block_size)
                                      .astype(jnp.int32), x_l, xsq_l,
                                      key, pidx)
            return self._build("sharded_masked_block_sums", body,
                               self._specs4() + (P(), P()),
                               P(None, self.axes))
        fn = self._program("masked_block_sums", factory)
        w = int(jnp.shape(src)[0])
        sums = fn(*self._sharded_args(), jnp.asarray(src, jnp.int32), key)
        cw = _c.fold_status(
            _c.word(evals=self._l1_evals(w), l1_reads=w),
            _g.nonfinite_status(sums))
        return sums, cw

    def fused_sample(self, src, key):
        """One depth-2 collective draw: (nb, prob, global level-1 sums,
        counter word) -- the sharded twin of ``ops.fused_sample`` (and
        the §4 cache producer).  The status is post-psum replicated and
        the counters are static, so the §9 one-psum schedule is
        unchanged (PSUMS slot = 1)."""
        sp = self.spec

        def factory():
            def body(x_l, xsq_l, x_rep, xsq_rep, src, key):
                pidx = _flat_index(sp.mesh, sp.axes)
                q = x_rep[src]
                qsq = xsq_rep[src]
                k_l1, k_rest = jax.random.split(key)
                sums_l = sp._local_sums(q, (src // sp.block_size)
                                        .astype(jnp.int32), x_l, xsq_l,
                                        k_l1, pidx)
                nb, prob, _, st = sp._local_draw(src, q, qsq, sums_l,
                                                 k_rest, x_l, xsq_l, pidx)
                return nb, prob, sums_l, st
            return self._build("sharded_fused_sample", body,
                               self._specs4() + (P(), P()),
                               (P(), P(), P(None, self.axes), P()))
        fn = self._program("fused_sample", factory)
        w = int(jnp.shape(src)[0])
        nb, prob, sums, st = fn(*self._sharded_args(),
                                jnp.asarray(src, jnp.int32), key)
        cw = _c.fold_status(
            _c.word(evals=self._l1_evals(w)
                    + w * self.block_size * self.num_shards,
                    l1_reads=w, draws=w, psums=1), st)
        return nb, prob, sums, cw

    def sample_from_block_sums(self, src, sums, key):
        """Depth-2 collective draw reusing cached global level-1 sums
        (the §4 caching contract: no dataset re-sweep).  Returns
        (nb, prob, counter word) -- PSUMS slot = 1, no level-1 evals."""
        sp = self.spec

        def factory():
            def body(x_l, xsq_l, x_rep, xsq_rep, src, sums_l, key):
                pidx = _flat_index(sp.mesh, sp.axes)
                nb, prob, _, st = sp._local_draw(
                    src, x_rep[src], xsq_rep[src], sums_l, key, x_l, xsq_l,
                    pidx)
                return nb, prob, st
            return self._build("sharded_sample_from_block_sums", body,
                               self._specs4() + (P(), P(None, self.axes),
                                                 P()),
                               (P(), P(), P()))
        fn = self._program("sample_cached", factory)
        w = int(jnp.shape(src)[0])
        nb, prob, st = fn(*self._sharded_args(), jnp.asarray(src, jnp.int32),
                          sums, key)
        cw = _c.fold_status(
            _c.word(evals=w * self.block_size * self.num_shards,
                    draws=w, psums=1), st)
        return nb, prob, cw

    def prob_of_from_block_sums(self, src, dst, sums):
        """q(dst | src) from cached global sums.  The global (w, B_pad)
        sums are directly addressable, so this is the single-device
        ``ops.prob_of_from_block_sums`` on the padded replicated dataset
        -- an O(w bs) read, no collective."""
        sp = self.spec
        return _ops.prob_of_from_block_sums(
            self.x_rep, self.x_sq_rep, jnp.asarray(src, jnp.int32),
            jnp.asarray(dst, jnp.int32), sums, kind=sp.kind,
            inv_bw=sp.inv_bw, beta=sp.beta, pairwise=sp.pairwise,
            block_size=sp.block_size, n=sp.n)

    def sample_exact(self, src, sums, key, *, rounds: int, slack: float):
        """Theorem 4.12 rejection-exact draw from cached global sums.
        Returns (cur, counter word, fallback count) -- PSUMS slot =
        ``rounds + 1`` (one psum per realized draw)."""
        sp = self.spec

        def factory():
            def body(x_l, xsq_l, x_rep, xsq_rep, src, sums_l, key):
                pidx = _flat_index(sp.mesh, sp.axes)
                return sp._local_sample_exact(
                    src, x_rep[src], xsq_rep[src], sums_l, key, x_l, xsq_l,
                    x_rep, pidx, rounds, slack)
            return self._build("sharded_sample_exact", body,
                               self._specs4() + (P(), P(None, self.axes),
                                                 P()),
                               (P(), P(), P()))
        fn = self._program(("sample_exact", rounds, float(slack)), factory)
        w = int(jnp.shape(src)[0])
        cur, st, fb = fn(*self._sharded_args(), jnp.asarray(src, jnp.int32),
                         sums, key)
        # level-2 draws on every shard + the replicated accept-ratio
        # kv_pairs each rejection round computes on all shards
        cw = _c.fold_status(
            _c.word(evals=(rounds + 1) * w * self.block_size
                    * self.num_shards + rounds * w * self.num_shards,
                    draws=(rounds + 1) * w, retries=fb,
                    psums=rounds + 1), st)
        return cur, cw, fb

    def walk_scan(self, starts, keys, *, rounds: int = 0, slack: float = 2.0,
                  record_path: bool = False):
        """T walk steps under ``lax.scan`` inside one shard_map program:
        the frontier is replicated scan carry, every step one two-stage
        draw (exactly one psum per step).  Returns (end, path, counter
        word, fallbacks): the per-step status bits and rejection-fallback
        counts fold into the carry (replicated, zero extra collectives);
        the word's counters are static per-step costs scaled by the step
        count (PSUMS = steps, or steps * (rounds + 1) on the
        rejection-exact path)."""
        sp = self.spec

        def factory():
            def body(x_l, xsq_l, x_rep, xsq_rep, starts, keys):
                pidx = _flat_index(sp.mesh, sp.axes)

                def step(carry, k):
                    cur, st, fb = carry
                    k_l1, k_rs = jax.random.split(k)
                    q = x_rep[cur]
                    qsq = xsq_rep[cur]
                    sums_l = sp._local_sums(
                        q, (cur // sp.block_size).astype(jnp.int32), x_l,
                        xsq_l, k_l1, pidx)
                    if rounds > 0:
                        nxt, st_k, fb_k = sp._local_sample_exact(
                            cur, q, qsq, sums_l, k_rs, x_l, xsq_l, x_rep,
                            pidx, rounds, slack)
                        fb = fb + fb_k
                    else:
                        nxt, _, _, st_k = sp._local_draw(
                            cur, q, qsq, sums_l, k_rs, x_l, xsq_l, pidx)
                    return (nxt, st | st_k, fb), \
                        (nxt if record_path else None)

                (end, st, fb), path = jax.lax.scan(
                    step, (starts, jnp.uint32(0), jnp.int32(0)), keys)
                return end, path, st, fb

            out_path = P() if record_path else None
            return self._build("sharded_walk_scan", body,
                               self._specs4() + (P(), P()),
                               (P(), out_path, P(), P()))
        fn = self._program(("walk_scan", rounds, float(slack),
                            bool(record_path)), factory)
        end, path, st, fb = fn(*self._sharded_args(),
                               jnp.asarray(starts, jnp.int32), keys)
        w = int(jnp.shape(starts)[0])
        steps = int(jnp.shape(keys)[0])
        draws_per = (rounds + 1) if rounds > 0 else 1
        per_step = (self._l1_evals(w)
                    + draws_per * w * self.block_size * self.num_shards
                    + rounds * w * self.num_shards)
        cw = _c.fold_status(
            _c.word(evals=steps * per_step, l1_reads=steps * w,
                    draws=steps * draws_per * w, retries=fb,
                    psums=steps * draws_per), st)
        return end, path, cw, fb

    def edge_batch_scan(self, cdf, degs, inv_total, inv_t, keys, *,
                        batch: int):
        """All Algorithm 5.1 edge batches as one scanned collective
        program -- u by replicated inverse CDF over the device degree
        prefix, v | u by the two-stage draw (one psum per batch), the
        collapsed reverse probability and reweighting replicated.  The
        last output is the counter word of the whole scan (status
        or-folded over batches, PSUMS = number of batches)."""
        sp = self.spec

        def factory():
            def body(x_l, xsq_l, x_rep, xsq_rep, cdf, degs, inv_total,
                     inv_t, keys):
                pidx = _flat_index(sp.mesh, sp.axes)

                def step(st, k):
                    k_u, k_fwd = jax.random.split(k)
                    u = _ref.inverse_cdf_index(
                        cdf, jax.random.uniform(k_u, (batch,)))
                    q = x_rep[u]
                    qsq = xsq_rep[u]
                    k_l1, k_rest = jax.random.split(k_fwd)
                    sums_l = sp._local_sums(q, (u // sp.block_size)
                                            .astype(jnp.int32), x_l,
                                            xsq_l, k_l1, pidx)
                    v, q_uv, _, st_b = sp._local_draw(u, q, qsq, sums_l,
                                                      k_rest, x_l, xsq_l,
                                                      pidx)
                    kuv = _ref.kv_pairs(q, x_rep[v], sp.kind, sp.inv_bw,
                                        sp.beta, sp.pairwise)
                    q_vu = kuv / jnp.maximum(degs[v], _ref.BLOCK_SUM_FLOOR)
                    q_edge = inv_total * (degs[u] * q_uv + kuv)
                    wgt = kuv * inv_t / jnp.maximum(q_edge, 1e-30)
                    st = st | st_b | _g.result_status(wgt, q_vu)
                    return st, (u, v, wgt, q_uv, q_vu)

                st, out = jax.lax.scan(step, jnp.uint32(0), keys)
                return out + (st,)
            return self._build("sharded_edge_batch_scan", body,
                               self._specs4() + (P(), P(), P(), P(), P()),
                               (P(), P(), P(), P(), P(), P()))
        fn = self._program(("edge_batch_scan", int(batch)), factory)
        out = fn(*self._sharded_args(), jnp.asarray(cdf),
                 jnp.asarray(degs), jnp.float32(inv_total),
                 jnp.float32(inv_t), keys)
        *data, st = out
        steps = int(jnp.shape(keys)[0])
        # per batch: one level-1 sweep + the speculative level-2 rows on
        # every shard + the replicated k(u, v) pair eval per shard
        per_batch = (self._l1_evals(batch)
                     + batch * self.block_size * self.num_shards
                     + batch * self.num_shards)
        cw = _c.fold_status(
            _c.word(evals=steps * per_batch, l1_reads=steps * batch,
                    draws=steps * batch, psums=steps), st)
        return tuple(data) + (cw,)

    def triangle_edge_scan(self, u, v, degs, keys):
        """Theorem 6.17's per-edge inner loop sharded: orientation
        replicated, ONE local level-1 read of the oriented v frontier
        (keys[0]) shared by every draw, then a scan over keys[1:] of
        two-stage draws (one psum each) with the ordering mask and the
        in-program reweighting.  The last output is the counter word
        (PSUMS = number of draws)."""
        sp = self.spec

        def factory():
            def body(x_l, xsq_l, x_rep, xsq_rep, u, v, degs, keys):
                pidx = _flat_index(sp.mesh, sp.axes)
                prec = _ref.degree_precedes(degs, u, v)
                uu = jnp.where(prec, u, v)
                vv = jnp.where(prec, v, u)
                q = x_rep[vv]
                qsq = xsq_rep[vv]
                kuv = _ref.kv_pairs(x_rep[uu], q, sp.kind, sp.inv_bw,
                                    sp.beta, sp.pairwise)
                sums_l = sp._local_sums(q, (vv // sp.block_size)
                                        .astype(jnp.int32), x_l, xsq_l,
                                        keys[0], pidx)

                def step(carry, k):
                    acc, st = carry
                    w, _, _, st_k = sp._local_draw(vv, q, qsq, sums_l, k,
                                                   x_l, xsq_l, pidx)
                    valid = _ref.degree_precedes(degs, vv, w) & (w != uu)
                    kuw = _ref.kv_pairs(x_rep[uu], x_rep[w], sp.kind,
                                        sp.inv_bw, sp.beta, sp.pairwise)
                    return (acc + jnp.where(valid, kuv * kuw, 0.0),
                            st | st_k), None

                (acc, st), _ = jax.lax.scan(
                    step, (jnp.zeros_like(kuv), jnp.uint32(0)), keys[1:])
                num_draws = keys.shape[0] - 1
                w_hat = acc * degs[vv] / num_draws
                return uu, vv, w_hat, _g.merge(st, _g.result_status(w_hat))
            return self._build("sharded_triangle_edge_scan", body,
                               self._specs4() + (P(), P(), P(), P()),
                               (P(), P(), P(), P()))
        fn = self._program("triangle_edge_scan", factory)
        uu, vv, w_hat, st = fn(*self._sharded_args(),
                               jnp.asarray(u, jnp.int32),
                               jnp.asarray(v, jnp.int32),
                               jnp.asarray(degs), keys)
        m = int(jnp.shape(u)[0])
        num_draws = int(jnp.shape(keys)[0]) - 1
        # one shared level-1 read + per-shard k(u, v) pairs + per draw the
        # per-shard level-2 rows and k(u, w) pairs
        cw = _c.fold_status(
            _c.word(evals=self._l1_evals(m) + m * self.num_shards
                    + num_draws * (m * self.block_size * self.num_shards
                                   + m * self.num_shards),
                    l1_reads=m, draws=num_draws * m, psums=num_draws), st)
        return uu, vv, w_hat, cw

    # ------------------------------------------------------------------ #
    # KDE-structure reads (the Definition 1.1 surface)
    # ------------------------------------------------------------------ #
    def kde_query(self, y, key):
        """Row-sum estimates of replicated queries: ``((m,), word)`` --
        local sweep (or local stratified block sums) + one psum,
        Definition 1.1 over the sharded dataset (PSUMS slot = 1)."""
        sp = self.spec

        def factory():
            def body(x_l, xsq_l, y, key):
                pidx = _flat_index(sp.mesh, sp.axes)
                if sp.exact:
                    kv = _ref.kv_matrix(y, x_l, xsq_l, sp.kind, sp.inv_bw,
                                        sp.beta, sp.pairwise)
                    part = kv.sum(axis=1)
                else:
                    part = sp._raw_sums(y, x_l, xsq_l, key, pidx).sum(
                        axis=1)
                return jax.lax.psum(part, sp.axes)
            return self._build("sharded_kde_query", body,
                               (P(self.axes), P(self.axes), P(), P()), P())
        fn = self._program("kde_query", factory)
        est = fn(self.x_sh, self.x_sq_sh, jnp.asarray(y, jnp.float32), key)
        m = int(jnp.shape(y)[0])
        cw = _c.fold_status(
            _c.word(evals=self._l1_evals(m), l1_reads=m, psums=1),
            _g.nonfinite_status(est))
        return est, cw

    def kernel_rows(self, q):
        """Exact (m, n) kernel rows against the sharded dataset -- the FKV
        sketch / CP17 column reads, computed shard-local and returned
        with a counter word (no collective; evals count the padded
        sweep each shard realizes)."""
        sp = self.spec

        def factory():
            def body(x_l, xsq_l, q):
                return _ref.kv_matrix(q, x_l, xsq_l, sp.kind, sp.inv_bw,
                                      sp.beta, sp.pairwise)
            return self._build("sharded_kernel_rows", body,
                               (P(self.axes), P(self.axes), P()),
                               P(None, self.axes))
        fn = self._program("kernel_rows", factory)
        out = fn(self.x_sh, self.x_sq_sh, jnp.asarray(q, jnp.float32))
        out = out[:, :self.n]
        m = int(jnp.shape(q)[0])
        cw = _c.fold_status(_c.word(evals=m * self.n_pad),
                            _g.nonfinite_status(out))
        return out, cw

    def degrees_ring(self, kernel):
        """Algorithm 4.3 over the sharded dataset: the ring-permute
        all-to-all accumulation (O(n^2 / P) work and O(shard^2) memory per
        device), minus the kernel's *actual* per-point diagonal.  Returns
        the ((n,) degree vector, counter word) -- the ring uses ppermute
        only, so the PSUMS slot is 0."""
        def factory():
            body = _ring_degrees_body(kernel, self.axes, self.num_shards)
            return self._build("sharded_degrees_ring", body,
                               (P(self.axes),), P(self.axes))
        fn = self._program("degrees_ring", factory)
        deg = fn(self.x_sh)[:self.n]
        cw = _c.fold_status(_c.word(evals=self.n_pad * self.n_pad),
                            _g.nonfinite_status(deg))
        return deg, cw


def _ring_degrees_body(kernel, axes, size: int):
    """Shared ring-accumulation body for Algorithm 4.3: every shard visits
    every other shard exactly once over the flattened ring, then subtracts
    the kernel's actual per-point diagonal k(x_i, x_i) (NOT a hardcoded
    1.0 -- custom kernels with non-unit diagonals get unbiased degrees;
    Table-1 kernels have an exactly-unit diagonal, kept as the constant
    to avoid float noise)."""
    perm = [(i, (i + 1) % size) for i in range(size)]
    axis = axes[0] if len(axes) == 1 else axes
    unit_diag = kernel.name in _ref.BUILTIN_KINDS

    def body(x_l):
        def step(carry, _):
            acc, blk = carry
            acc = acc + jnp.sum(kernel.pairwise(x_l, blk), axis=1)
            blk = jax.lax.ppermute(blk, axis, perm=perm)
            return (acc, blk), None

        acc0 = jnp.sum(x_l, axis=1) * 0.0
        (acc, _), _ = jax.lax.scan(step, (acc0, x_l), None, length=size)
        return acc - (1.0 if unit_diag else kernel.pairs(x_l, x_l))
    return body


# --------------------------------------------------------------------- #
# builders for caller-sharded datasets (the `core.kde.distributed` API)
# --------------------------------------------------------------------- #
def make_kde_query(mesh: Mesh, kernel, data_axes: Sequence[str] = ("data",)):
    """Definition 1.1 over a caller-sharded dataset: jitted
    f(y replicated, x sharded) -> (m,) row sums, local sweep + one psum."""
    axes = tuple(data_axes)

    def body(y, x_l):
        part = jnp.sum(kernel.pairwise(y, x_l), axis=1)
        return jax.lax.psum(part, axes)

    return jax.jit(shard_map(body, mesh=mesh, in_specs=(P(), P(axes)),
                             out_specs=P()))


def make_block_sums(mesh: Mesh, kernel, num_blocks_per_shard: int,
                    data_axes: Sequence[str] = ("data",)):
    """Level-1 block sums over a caller-sharded dataset, ragged-safe:
    shards whose row count does not divide ``num_blocks_per_shard`` are
    padded in-body with the far-offset sentinel rows (kernel values are
    exactly 0), so the reshape never crashes and tail blocks sum only
    their real rows.  Returns jitted f(y, x[, own]) -> (m, shards * B);
    with ``own`` (each query's global block index, or -1) the §2 sampling
    contract is applied: the self kernel k(y, y) = 1 (the repo-wide
    Kernel contract, matching the single-device engine bitwise)
    subtracted from the own block and every real block floored at
    1e-12."""
    axes = tuple(data_axes)

    def local(y, x_l, own):
        m = y.shape[0]
        ns = x_l.shape[0]
        bs_l = -(-ns // num_blocks_per_shard)
        pad = num_blocks_per_shard * bs_l - ns
        if pad:
            sent = jnp.full((pad, x_l.shape[1]), _PAD_OFFSET,
                            x_l.dtype) + x_l[-1:]
            x_l = jnp.concatenate([x_l, sent], axis=0)
        kv = kernel.pairwise(y, x_l)
        sums = kv.reshape(m, num_blocks_per_shard, bs_l).sum(-1)
        if own is None:
            return sums
        pidx = _flat_index(mesh, axes)
        gblk = pidx * num_blocks_per_shard + jnp.arange(
            num_blocks_per_shard, dtype=jnp.int32)
        corr = gblk[None, :] == own[:, None]
        sums = jnp.where(corr, sums - 1.0, sums)
        base = jnp.arange(num_blocks_per_shard, dtype=jnp.int32) * bs_l
        real = jnp.clip(ns - base, 0, bs_l) > 0
        return jnp.where(real[None, :],
                         jnp.maximum(sums, _ref.BLOCK_SUM_FLOOR), 0.0)

    raw = jax.jit(shard_map(lambda y, x_l: local(y, x_l, None), mesh=mesh,
                            in_specs=(P(), P(axes)),
                            out_specs=P(None, axes)))
    masked = jax.jit(shard_map(local, mesh=mesh,
                               in_specs=(P(), P(axes), P()),
                               out_specs=P(None, axes)))

    def f(y, x, own=None):
        if own is None:
            return raw(y, x)
        return masked(y, x, jnp.asarray(own, jnp.int32))

    return f


def make_degree_ring(mesh: Mesh, kernel,
                     data_axes: Sequence[str] = ("data",)):
    """Algorithm 4.3 over a caller-sharded dataset: jitted f(x sharded) ->
    degrees sharded the same way, via the flattened-ring ppermute schedule
    with the actual-diagonal correction."""
    axes = tuple(data_axes)
    size = 1
    for a in axes:
        size *= int(mesh.shape[a])
    body = _ring_degrees_body(kernel, axes, size)
    return jax.jit(shard_map(body, mesh=mesh, in_specs=(P(axes),),
                             out_specs=P(axes)))


# --------------------------------------------------------------------- #
# standalone sharded programs (no block structure needed)
# --------------------------------------------------------------------- #
@functools.lru_cache(maxsize=32)
def _noisy_power_program(mesh: Mesh, axes, num_samples: int, cols_per: int):
    num = 1
    for a in axes:
        num *= int(mesh.shape[a])
    t_pad = num * cols_per

    def body(ksub_l, v0, keys):
        pidx = _flat_index(mesh, axes)
        off = pidx * cols_per
        t = v0.shape[0]

        def step(carry, k):
            v, st = carry
            absv = jnp.abs(v)
            z = jnp.sum(absv)
            cdf = jnp.cumsum(absv)
            u = jax.random.uniform(k, (num_samples,)) * jnp.maximum(z, 1e-30)
            idx = jnp.clip(jnp.searchsorted(cdf, u, side="right"),
                           0, t - 1).astype(jnp.int32)
            sel = (idx >= off) & (idx < off + cols_per)
            lidx = jnp.clip(idx - off, 0, cols_per - 1)
            contrib = jnp.sign(v[idx]) * z / num_samples * sel
            w_p = ksub_l[:, lidx] @ contrib
            w = jax.lax.psum(w_p, axes)
            nw = jnp.linalg.norm(w)
            ok = (nw > 0.0) & (z > 0.0)
            st = st | _g.flag_if(~ok, _g.ZERO_MASS) | _g.nonfinite_status(w)
            return (jnp.where(ok, w / jnp.maximum(nw, 1e-30), v), st), None

        (v, st), _ = jax.lax.scan(step, (v0, jnp.uint32(0)), keys)
        # pad v to the column-padded width so the last shard's slice is
        # never clamped out of alignment
        vp = jnp.pad(v, (0, t_pad - t))
        av = jax.lax.psum(
            ksub_l @ jax.lax.dynamic_slice(vp, (off,), (cols_per,)), axes)
        lam = v @ av
        return lam, v, _g.merge(st, _g.result_status(lam, v))

    def outer(ksub_sh, v0, keys):
        TRACE_COUNTS["sharded_noisy_power_scan"] += 1
        return shard_map(body, mesh=mesh,
                         in_specs=(P(None, axes), P(), P()),
                         out_specs=(P(), P(), P()),
                         check_vma=False)(ksub_sh, v0, keys)
    return jax.jit(outer)


def sharded_noisy_power(mesh: Mesh, ksub, v0, keys, *, num_samples: int,
                        data_axes: Sequence[str] = ("data",)):
    """BIMW21 noisy power method with the t x t submatrix sharded over
    columns: the importance draw and renormalization are replicated, the
    sampled matvec is a local masked gather + partial matvec + ONE psum
    per iteration (the §9 collective budget).  Same math and key stream
    as ``ops.noisy_power_scan`` (per-shard partial sums reorder the float
    accumulation, so floats agree to f32 tolerance, not bitwise).
    Returns ``(lam, v, counter word)``; slot 0 folds the stalled-iterate
    (zero mass) and non-finite flags across all iterations, DRAWS counts
    the importance draws, PSUMS the one-per-iteration matvec psums plus
    the final Rayleigh-quotient psum."""
    axes = tuple(data_axes)
    num = 1
    for a in axes:
        num *= int(mesh.shape[a])
    t = int(ksub.shape[0])
    t_pad = -(-t // num) * num
    ksub = jnp.asarray(ksub, jnp.float32)
    if t_pad != t:
        ksub = jnp.pad(ksub, ((0, 0), (0, t_pad - t)))
    ksub_sh = jax.device_put(ksub, NamedSharding(mesh, P(None, axes)))
    fn = _noisy_power_program(mesh, axes, int(num_samples), t_pad // num)
    lam, v, st = fn(ksub_sh, jnp.asarray(v0, jnp.float32), keys)
    iters = int(jnp.shape(keys)[0])
    cw = _c.fold_status(
        _c.word(draws=iters * int(num_samples), psums=iters + 1), st)
    return lam, v, cw
