"""Pure-jnp oracle + shared kernel-value math for the fused depth-2 sampler.

``sample_block_ref`` is the bit-for-bit reference of the Pallas kernel in
``kernel.py``: masked per-block sums with the self-block correction applied
in the same pass, plus a Gumbel-max draw of the block index.  The kernel
values reuse squared norms precomputed once over the dataset (``x_sq``) --
the level-1 read never recomputes ``||x_j||^2`` (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_L2_KINDS = ("gaussian", "exponential", "rational_quadratic")
# Kinds with closed-form math in this module: their jitted programs don't
# need (and must not be keyed on) a Kernel's pairwise closure.
BUILTIN_KINDS = _L2_KINDS + ("laplacian",)


def static_pairwise(kernel):
    """The ``pairwise`` value to put in a jit static config for ``kernel``:
    None for built-in kinds (stable jit cache across Kernel instances),
    the kernel's own callable for custom kinds."""
    return None if kernel.name in BUILTIN_KINDS else kernel.pairwise

# Floor applied to every (corrected) block-sum estimate, matching the seed
# host sampler: keeps log() finite and the own-block sum positive after the
# k(x, x) = 1 subtraction.
BLOCK_SUM_FLOOR = 1e-12


def _finish_l2(d2, kind: str, inv_bw: float, beta: float):
    d2 = jnp.maximum(d2, 0.0)
    if kind == "gaussian":
        return jnp.exp(-d2 * (inv_bw * inv_bw))
    if kind == "exponential":
        return jnp.exp(-jnp.sqrt(d2) * inv_bw)
    return (1.0 + d2 * (inv_bw * inv_bw)) ** (-beta)


def kv_matrix(q, x, x_sq, kind: str, inv_bw: float, beta: float,
              pairwise=None) -> jnp.ndarray:
    """(m, n) kernel values; L2 kinds reuse precomputed ``x_sq = ||x_j||^2``.

    Built-in kinds never touch ``pairwise`` -- keeping it out of the jit
    static key means one compiled program per (kind, inv_bw, beta), not one
    per ``Kernel`` instance.  Unknown kinds (custom ``Kernel`` objects) fall
    back to the ``pairwise`` callable.
    """
    if kind in _L2_KINDS:
        qq = jnp.sum(q * q, axis=1, keepdims=True)
        d2 = qq + x_sq[None, :] - 2.0 * (q @ x.T)
        return _finish_l2(d2, kind, inv_bw, beta)
    if kind == "laplacian":
        # cap the (m, n, d) broadcast at ~1 GiB of f32 (static unroll)
        m, d = q.shape
        n = x.shape[0]
        chunk = max(int((1 << 28) // max(n * d, 1)), 1)
        outs = [jnp.exp(-jnp.sum(jnp.abs(q[lo:lo + chunk, None, :]
                                         - x[None, :, :]), axis=-1) * inv_bw)
                for lo in range(0, m, chunk)]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    return pairwise(q, x)


def kv_rows(xs, xb, xs_sq, xb_sq, kind: str, inv_bw: float, beta: float,
            pairwise=None) -> jnp.ndarray:
    """Per-row block values k(xs_i, xb_i_j): xs (w, d), xb (w, bs, d) ->
    (w, bs).  The level-2 read of the depth-2 sampler."""
    if kind in _L2_KINDS:
        cross = jnp.einsum("wd,wbd->wb", xs, xb)
        d2 = xs_sq[:, None] + xb_sq - 2.0 * cross
        return _finish_l2(d2, kind, inv_bw, beta)
    if kind == "laplacian":
        d1 = jnp.sum(jnp.abs(xs[:, None, :] - xb), axis=-1)
        return jnp.exp(-d1 * inv_bw)
    return jax.vmap(lambda a, b: pairwise(a[None, :], b)[0])(xs, xb)


def kv_pairs(a, b, kind: str, inv_bw: float, beta: float,
             pairwise=None) -> jnp.ndarray:
    """Elementwise k(a_i, b_i) for aligned (w, d) arrays -- O(w d)."""
    if kind in _L2_KINDS:
        d2 = jnp.sum((a - b) ** 2, axis=-1)
        return _finish_l2(d2, kind, inv_bw, beta)
    if kind == "laplacian":
        d1 = jnp.sum(jnp.abs(a - b), axis=-1)
        return jnp.exp(-d1 * inv_bw)
    return jax.vmap(lambda u, v: pairwise(u[None, :], v[None, :])[0, 0])(a, b)


def masked_block_sums_ref(q, x, x_sq, own, kind: str, inv_bw: float,
                          beta: float, bn: int, pairwise=None) -> jnp.ndarray:
    """(m, B) per-block sums over a padded dataset (n multiple of ``bn``;
    padding rows are far-offset so their kernel values are ~0), with
    k(x, x) = 1 subtracted from each query's own block and the result
    floored at BLOCK_SUM_FLOOR."""
    m, n = q.shape[0], x.shape[0]
    kv = kv_matrix(q, x, x_sq, kind, inv_bw, beta, pairwise)
    bs = kv.reshape(m, n // bn, bn).sum(-1)
    corr = jnp.arange(n // bn, dtype=jnp.int32)[None, :] == own[:, None]
    bs = jnp.where(corr, bs - 1.0, bs)
    return jnp.maximum(bs, BLOCK_SUM_FLOOR)


def sample_block_ref(q, x, x_sq, own, gumbel, kind: str, inv_bw: float,
                     beta: float, bn: int, pairwise=None):
    """Oracle for ``kernel.sample_block_pallas``: returns
    (blk, p_blk, tot, block_sums) with blk = argmax_b log(bs_b) + g_b."""
    bs = masked_block_sums_ref(q, x, x_sq, own, kind, inv_bw, beta, bn,
                               pairwise)
    score = jnp.log(bs) + gumbel
    blk = jnp.argmax(score, axis=1).astype(jnp.int32)
    tot = jnp.sum(bs, axis=1)
    pb = jnp.take_along_axis(bs, blk[:, None], axis=1)[:, 0] / tot
    return blk, pb, tot, bs
