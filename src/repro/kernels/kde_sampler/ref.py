"""Pure-jnp oracle + shared kernel-value math for the fused depth-2 sampler.

``sample_block_ref`` is the bit-for-bit reference of the Pallas kernel in
``kernel.py``: masked per-block sums with the self-block correction applied
in the same pass, plus a Gumbel-max draw of the block index.  The kernel
values reuse squared norms precomputed once over the dataset (``x_sq``) --
the level-1 read never recomputes ``||x_j||^2`` (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_L2_KINDS = ("gaussian", "exponential", "rational_quadratic")
# Kinds with closed-form math in this module: their jitted programs don't
# need (and must not be keyed on) a Kernel's pairwise closure.
BUILTIN_KINDS = _L2_KINDS + ("laplacian",)


def static_pairwise(kernel):
    """The ``pairwise`` value to put in a jit static config for ``kernel``:
    None for built-in kinds (stable jit cache across Kernel instances),
    the kernel's own callable for custom kinds."""
    return None if kernel.name in BUILTIN_KINDS else kernel.pairwise

# Floor applied to every (corrected) block-sum estimate, matching the seed
# host sampler: keeps log() finite and the own-block sum positive after the
# k(x, x) = 1 subtraction.
BLOCK_SUM_FLOOR = 1e-12


def _finish_l2(d2, kind: str, inv_bw: float, beta: float):
    d2 = jnp.maximum(d2, 0.0)
    if kind == "gaussian":
        return jnp.exp(-d2 * (inv_bw * inv_bw))
    if kind == "exponential":
        return jnp.exp(-jnp.sqrt(d2) * inv_bw)
    return (1.0 + d2 * (inv_bw * inv_bw)) ** (-beta)


# --------------------------------------------------------------------- #
# mixed precision (DESIGN.md §14)
#
# ``precision="bf16"`` rounds the dataset/query tiles to bfloat16 before
# the level-1 distance GEMM and keeps EVERYTHING downstream in f32: the
# cross term accumulates in f32 (``preferred_element_type``), the norms
# are recomputed in f32 from the *rounded* coordinates (so d2 is the exact
# f32 distance of the bf16-rounded points, never a mixed-rounding hybrid),
# and the CDF/prefix sums of the draw stages are untouched -- the PR-2
# prefix-sum bias fix is precision-independent.  ``"f32"`` is the default
# and stays bitwise identical to the pre-policy code path.
# --------------------------------------------------------------------- #
PRECISIONS = ("f32", "bf16")

# Documented accuracy bound of the bf16 eval path for Table-1 kernels.
# The error is INPUT-rounding dominated: each coordinate picks up one bf16
# rounding (eps = 2^-8), so the squared distance of the rounded points
# drifts by |Δd2| <~ 2 eps d2, and for the exponential-family kernels
# k = exp(-c d2) the per-value relative error is ~ Δd2 = 2^-7 d2.  Terms
# with d2 large enough to push that bound past ~6% (d2 > 8) contribute
# k < 3e-4 of the row mass, so the row-sum relative error is bounded by
# the d2 <~ 8 envelope: 8 * 2^-7 = 2^-4.  (The bf16 exp table adds only
# 2^-9 on top.)  Measured on gaussian n=262144 d=16: 4.1e-2 max over 256
# queries -- inside this bound, outside any tighter one.
# tests/test_precision.py pins estimator outputs to 2 * this bound.
BF16_REL_ERR = 2.0 ** -4

# Mirrors kde_rowsum.ops._PAD_OFFSET (imported there, duplicated here to
# keep ref.py import-free of the ops layer): bf16-representable, and its
# squared norm overflows f32 to inf, so padded rows evaluate to exactly 0
# on the bf16 path too.
_FAR_OFFSET = 1.0e30

_EXP_TABLE = None


def bf16_exp_table():
    """(65536,) f32 table of exp() over every bfloat16 bit pattern.

    A bf16 argument has only 2^16 distinct values, so exp on a bf16-rounded
    argument is an exact table gather -- one f32 load instead of a
    transcendental per element, which is what makes the bf16 sweep
    bandwidth-bound instead of exp-bound on the host backend.  -inf maps
    to 0.0 and NaN patterns stay NaN (corruption propagates, the status
    guards still fire).  Built lazily once per process.
    """
    global _EXP_TABLE
    if _EXP_TABLE is None:
        import numpy as np
        with np.errstate(over="ignore", invalid="ignore"):
            args = (np.arange(65536, dtype=np.uint32) << 16).view(np.float32)
            # cache as NUMPY: a jnp constant materialized inside a trace
            # would be a tracer, and caching a tracer across traces leaks
            _EXP_TABLE = np.exp(args.astype(np.float64)).astype(np.float32)
    return _EXP_TABLE


def exp_bf16(y, table=None):
    """exp() of ``y`` after rounding it to bf16, as an exact table read.

    ``table`` lets Pallas kernel bodies pass the table in as a VMEM ref
    value -- a closed-over numpy array would be a captured constant, which
    ``pallas_call`` rejects.  jnp callers leave it None.
    """
    yb = y.astype(jnp.bfloat16)
    bits = jax.lax.bitcast_convert_type(yb, jnp.uint16).astype(jnp.int32)
    if table is None:
        table = jnp.asarray(bf16_exp_table())
    return jnp.take(table, bits)


def check_precision(precision: str, kind: str, pairwise=None) -> None:
    """Reject unsupported precision configs at trace time (not mid-run)."""
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; "
                         f"expected one of {PRECISIONS}")
    if precision == "bf16" and (kind not in _L2_KINDS or pairwise is not None):
        raise ValueError(
            "precision='bf16' supports the built-in L2 kernels only "
            f"(gaussian / exponential / rational_quadratic); got {kind!r}")


def _finish_l2_bf16(d2, kind: str, inv_bw: float, beta: float, table=None):
    """L2-kind finisher of the bf16 path: f32 d2 in, table-exp out.  This
    exact function runs inside the Pallas kernel bodies AND the jnp refs,
    so interpret-mode bf16 runs match the oracles bitwise.  Pallas bodies
    pass the exp table as a streamed input via ``table``."""
    d2 = jnp.maximum(d2, 0.0)
    if kind == "gaussian":
        return exp_bf16(-d2 * (inv_bw * inv_bw), table)
    if kind == "exponential":
        return exp_bf16(-jnp.sqrt(d2) * inv_bw, table)
    return (1.0 + d2 * (inv_bw * inv_bw)) ** (-beta)


def kv_matrix_bf16(q, x, kind: str, inv_bw: float, beta: float):
    """(m, n) kernel values with bf16 operand tiles and f32 accumulation.
    The passed-in dataset norms are NOT reused: they describe the unrounded
    rows, so the bf16 path recomputes both norm vectors in f32 from the
    rounded coordinates (O((m + n) d), amortized by the O(m n d) GEMM)."""
    qb = q.astype(jnp.bfloat16)
    xb = x.astype(jnp.bfloat16)
    qf = qb.astype(jnp.float32)
    xf = xb.astype(jnp.float32)
    qq = jnp.sum(qf * qf, axis=1, keepdims=True)
    xx = jnp.sum(xf * xf, axis=1)
    cross = jax.lax.dot_general(qb, xb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    d2 = qq + xx[None, :] - 2.0 * cross
    return _finish_l2_bf16(d2, kind, inv_bw, beta)


def kv_block_sums_bf16(q, x, kind: str, inv_bw: float, beta: float,
                       bn: int, blocks_per_tile: int | None = None):
    """(m, ceil(n/bn)) per-block sums as a bf16 column-tile scan.

    The bandwidth-optimal level-1 sweep: the dataset is rounded to bf16,
    pre-transposed into (tile, d, tile_cols) GEMM layout ONCE, and a
    ``lax.scan`` walks the column tiles -- each step is one
    (m, d) x (d, tile_cols) bf16 GEMM with an f32 accumulator, the table
    exp, and an in-register per-block reduction.  Peak live memory is the
    (m, tile_cols) f32 value tile instead of the dense (m, n) matrix, so
    the sweep streams the dataset at memory bandwidth.  The tail is padded
    at the far offset (kernel values exactly 0) and sliced off.
    """
    from repro.kernels import tuning
    m = q.shape[0]
    n, d = x.shape
    num_b = -(-n // bn)
    t = blocks_per_tile or tuning.sweep_blocks_per_tile(bn, d)
    ntiles = -(-num_b // t)
    pad = ntiles * t * bn - n
    if pad:
        x = jnp.concatenate(
            [x, jnp.full((pad, d), _FAR_OFFSET, x.dtype)], axis=0)
    xb = x.astype(jnp.bfloat16)
    xf = xb.astype(jnp.float32)
    x_sq = jnp.sum(xf * xf, axis=-1)
    xt = xb.T.reshape(d, ntiles, t * bn).transpose(1, 0, 2)  # (T, d, cols)
    xsq_t = x_sq.reshape(ntiles, t * bn)
    qb = q.astype(jnp.bfloat16)
    qf = qb.astype(jnp.float32)
    qq = jnp.sum(qf * qf, axis=1, keepdims=True)

    def body(_, operand):
        xt_i, xsq_i = operand
        cross = jax.lax.dot_general(qb, xt_i, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        d2 = qq + xsq_i[None, :] - 2.0 * cross
        kv = _finish_l2_bf16(d2, kind, inv_bw, beta)
        return None, kv.reshape(m, t, bn).sum(-1)

    _, out = jax.lax.scan(body, None, (xt, xsq_t))           # (T, m, t)
    out = out.transpose(1, 0, 2).reshape(m, ntiles * t)
    return out[:, :num_b]


def kv_matrix(q, x, x_sq, kind: str, inv_bw: float, beta: float,
              pairwise=None, precision: str = "f32") -> jnp.ndarray:
    """(m, n) kernel values; L2 kinds reuse precomputed ``x_sq = ||x_j||^2``.

    Built-in kinds never touch ``pairwise`` -- keeping it out of the jit
    static key means one compiled program per (kind, inv_bw, beta), not one
    per ``Kernel`` instance.  Unknown kinds (custom ``Kernel`` objects) fall
    back to the ``pairwise`` callable.  ``precision="bf16"`` dispatches to
    the mixed-precision evaluator (L2 kinds only; ``x_sq`` is recomputed
    from the rounded rows there).
    """
    if precision != "f32":
        check_precision(precision, kind, pairwise)
        return kv_matrix_bf16(q, x, kind, inv_bw, beta)
    if kind in _L2_KINDS:
        qq = jnp.sum(q * q, axis=1, keepdims=True)
        d2 = qq + x_sq[None, :] - 2.0 * (q @ x.T)
        return _finish_l2(d2, kind, inv_bw, beta)
    if kind == "laplacian":
        # cap the (m, n, d) broadcast at ~1 GiB of f32 (static unroll)
        m, d = q.shape
        n = x.shape[0]
        chunk = max(int((1 << 28) // max(n * d, 1)), 1)
        outs = [jnp.exp(-jnp.sum(jnp.abs(q[lo:lo + chunk, None, :]
                                         - x[None, :, :]), axis=-1) * inv_bw)
                for lo in range(0, m, chunk)]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    return pairwise(q, x)


def kv_rows(xs, xb, xs_sq, xb_sq, kind: str, inv_bw: float, beta: float,
            pairwise=None) -> jnp.ndarray:
    """Per-row block values k(xs_i, xb_i_j): xs (w, d), xb (w, bs, d) ->
    (w, bs).  The level-2 read of the depth-2 sampler."""
    if kind in _L2_KINDS:
        # broadcast multiply-reduce -- the batched dot_general lowering is
        # ~8x slower on the host backend for these thin (w, 1, d) x
        # (w, d, bs) shapes (it was the hidden per-step cost of the walk
        # level-2 read at large n)
        cross = jnp.sum(xs[:, None, :] * xb, axis=-1)
        d2 = xs_sq[:, None] + xb_sq - 2.0 * cross
        return _finish_l2(d2, kind, inv_bw, beta)
    if kind == "laplacian":
        d1 = jnp.sum(jnp.abs(xs[:, None, :] - xb), axis=-1)
        return jnp.exp(-d1 * inv_bw)
    return jax.vmap(lambda a, b: pairwise(a[None, :], b)[0])(xs, xb)


def kv_pairs(a, b, kind: str, inv_bw: float, beta: float,
             pairwise=None) -> jnp.ndarray:
    """Elementwise k(a_i, b_i) for aligned (w, d) arrays -- O(w d)."""
    if kind in _L2_KINDS:
        d2 = jnp.sum((a - b) ** 2, axis=-1)
        return _finish_l2(d2, kind, inv_bw, beta)
    if kind == "laplacian":
        d1 = jnp.sum(jnp.abs(a - b), axis=-1)
        return jnp.exp(-d1 * inv_bw)
    return jax.vmap(lambda u, v: pairwise(u[None, :], v[None, :])[0, 0])(a, b)


def inverse_cdf_index(cdf, u) -> jnp.ndarray:
    """Vectorized inverse-CDF lookup over a normalized prefix array
    (Algorithm 4.5 in its dense device form): cdf (n,) nondecreasing with
    cdf[-1] ~= 1, u (w,) uniforms -> (w,) int32 indices.

    The prefix array is accumulated in float64 on the host (see
    ``core.sampling.vertex.PrefixCDF``) and only *rounded* to float32 for
    the device lookup -- per-entry rounding is O(eps) and unbiased, unlike
    float32 prefix accumulation whose error grows with n."""
    idx = jnp.searchsorted(cdf, u, side="right")
    return jnp.clip(idx, 0, cdf.shape[0] - 1).astype(jnp.int32)


def block_views(x, x_sq, block_size: int):
    """(B, bs, d) / (B, bs) contiguous views of the (padded) dataset.
    Built once per compiled program; the level-2 read then gathers whole
    block *slices* instead of w*bs random rows."""
    pad = -x.shape[0] % block_size
    xb_all = jnp.pad(x, ((0, pad), (0, 0))).reshape(-1, block_size,
                                                    x.shape[1])
    xb_sq_all = jnp.pad(x_sq, (0, pad)).reshape(-1, block_size)
    return xb_all, xb_sq_all


def level2_row(x, x_sq, views, src, blk, kind: str, inv_bw: float,
               beta: float, block_size: int, n: int, pairwise=None):
    """Exact kernel row of each source against its chosen block, with the
    self edge and out-of-range tail columns masked to 0.  Shared by the
    fused ops and the ref oracles (the level-2 math is identical on every
    path; only the level-1 read differs)."""
    xb_all, xb_sq_all = views
    lo = blk * block_size
    cols = lo[:, None] + jnp.arange(block_size, dtype=jnp.int32)[None, :]
    xs = x[src]
    kv = kv_rows(xs, xb_all[blk], x_sq[src], xb_sq_all[blk], kind,
                 inv_bw, beta, pairwise)
    if n % block_size == 0:
        # tail-free fast path: every column is in range, so only the self
        # edge needs masking
        live = cols != src[:, None]
        return jnp.where(live, kv, 0.0), live, cols
    valid = cols < n
    cols_c = jnp.minimum(cols, n - 1)
    live = valid & (cols_c != src[:, None])
    return jnp.where(live, kv, 0.0), live, cols_c


def level2_draw(kv, live, cols_c, u2):
    """Inverse-CDF draw from each row of ``kv``; all-zero rows (numerically
    underflowed blocks) fall back to uniform over the live columns instead
    of producing NaN."""
    rowsum = kv.sum(axis=1)
    use = jnp.where((rowsum > 0.0)[:, None], kv, live.astype(jnp.float32))
    c = jnp.cumsum(use, axis=1)
    tot = c[:, -1]
    j = jnp.sum((u2 * tot)[:, None] > c, axis=1).clip(0, kv.shape[1] - 1)
    nb = jnp.take_along_axis(cols_c, j[:, None], axis=1)[:, 0]
    pin = jnp.take_along_axis(use, j[:, None], axis=1)[:, 0] \
        / jnp.maximum(tot, 1e-30)
    return nb, pin


def choose_block(bs, key):
    """Exact inverse-CDF categorical over rows of the (floored) block sums;
    returns (block index, realized block probability).  (The Pallas kernel
    uses Gumbel-max instead because it streams blocks one at a time; both
    are exact samplers of the same law.)"""
    c = jnp.cumsum(bs, axis=1)
    tot = c[:, -1]
    u = jax.random.uniform(key, (bs.shape[0],))
    blk = jnp.sum((u * tot)[:, None] > c, axis=1).astype(jnp.int32)
    blk = blk.clip(0, bs.shape[1] - 1)
    pb = jnp.take_along_axis(bs, blk[:, None], axis=1)[:, 0] / tot
    return blk, pb


def cdf_group(m: int) -> int:
    """Largest divisor of ``m`` that is <= sqrt(m) -- the inner group width
    of the two-level inverse CDF.  1 for prime ``m`` (degenerates to the
    flat search, still correct)."""
    g = max(int(m ** 0.5), 1)
    while m % g:
        g -= 1
    return g


def grouped_inverse_cdf(vals, u, group: int):
    """Two-level inverse-CDF categorical over each row of ``vals``
    (contiguous groups of ``group`` columns): pick the group by the group
    CDF, then the column inside it.  The SAME sampling law as the flat
    ``cumsum`` inverse CDF -- nested search over contiguous groups visits
    the same index up to fp regrouping of partial sums -- but the per-row
    cumsum touches O(m/group + group) lanes instead of O(m), which is the
    walk step's hot-path win (DESIGN.md §14).  Returns
    (index, vals[index], row total)."""
    w, m = vals.shape
    ng = m // group
    v3 = vals.reshape(w, ng, group)
    grp = v3.sum(-1)
    cg = jnp.cumsum(grp, axis=1)
    tot = cg[:, -1]
    t = u * tot
    g = jnp.sum(t[:, None] > cg, axis=1).clip(0, ng - 1).astype(jnp.int32)
    prev = (jnp.take_along_axis(cg, g[:, None], axis=1)
            - jnp.take_along_axis(grp, g[:, None], axis=1))[:, 0]
    sub = jnp.take_along_axis(v3, g[:, None, None], axis=1)[:, 0]
    cs = jnp.cumsum(sub, axis=1)
    j = jnp.sum((t - prev)[:, None] > cs, axis=1).clip(0, group - 1)
    idx = (g * group + j.astype(jnp.int32))
    val = jnp.take_along_axis(sub, j[:, None], axis=1)[:, 0]
    return idx, val, tot


def choose_block_grouped(bs, key, group: int):
    """``choose_block`` by the two-level inverse CDF -- same categorical
    law, O(B/group + group) cumsum lanes per draw.  Used by the walk's
    resident-cache step where the flat (w, B) cumsum dominated."""
    u = jax.random.uniform(key, (bs.shape[0],))
    blk, val, tot = grouped_inverse_cdf(bs, u, group)
    return blk, val / tot


def level2_draw_grouped(kv, live, cols_c, u2, group: int):
    """``level2_draw`` by the two-level inverse CDF (same all-zero-row
    fallback to uniform-over-live)."""
    rowsum = kv.sum(axis=1)
    use = jnp.where((rowsum > 0.0)[:, None], kv, live.astype(jnp.float32))
    j, val, tot = grouped_inverse_cdf(use, u2, group)
    nb = jnp.take_along_axis(cols_c, j[:, None], axis=1)[:, 0]
    pin = val / jnp.maximum(tot, 1e-30)
    return nb, pin


def sample_from_sums(x, x_sq, views, src, bs, key, kind: str, inv_bw: float,
                     beta: float, block_size: int, n: int, pairwise=None):
    """One depth-2 draw from given level-1 sums ``bs`` of the ``src``
    frontier: (block draw -> exact level-2 row -> in-block draw), with the
    PR-2 key-split discipline (k_blk, k_in = split(key)).  Shared verbatim
    by ``ops._sample_core`` and the application oracles, so fused programs
    and their ref loops consume identical randomness."""
    k_blk, k_in = jax.random.split(key)
    blk, pb = choose_block(bs, k_blk)
    kv, live, cols_c = level2_row(x, x_sq, views, src, blk, kind, inv_bw,
                                  beta, block_size, n, pairwise)
    nb, pin = level2_draw(kv, live, cols_c,
                          jax.random.uniform(k_in, (src.shape[0],)))
    return nb, pb * pin


def masked_exact_sums_ref(q, x, x_sq, own, kind: str, inv_bw: float,
                          beta: float, bn: int, n: int, pairwise=None):
    """Masked level-1 sums on the *exact non-Pallas* path, matching
    ``ops._masked_block_sums(exact=True)`` bit-for-bit: one dense sweep over
    the unpadded dataset, zero-padded to a block multiple, own-block
    corrected by the self kernel k(x, x) = 1, floored."""
    m = q.shape[0]
    kv = kv_matrix(q, x, x_sq, kind, inv_bw, beta, pairwise)
    pad = -n % bn
    if pad:
        kv = jnp.pad(kv, ((0, 0), (0, pad)))
    bs = kv.reshape(m, -1, bn).sum(-1)
    corr = jnp.arange(bs.shape[1], dtype=jnp.int32)[None, :] == own[:, None]
    bs = jnp.where(corr, bs - 1.0, bs)
    return jnp.maximum(bs, BLOCK_SUM_FLOOR)


def degree_precedes(degs, a, b):
    """Degree-then-index total vertex order from Theorem 6.17's proof:
    a < b iff (deg_a, a) < (deg_b, b) lexicographically."""
    return (degs[a] < degs[b]) | ((degs[a] == degs[b]) & (a < b))


def noisy_power_ref(ksub, v0, keys, num_samples: int):
    """Oracle of ``ops.noisy_power_scan`` -- the BIMW21 noisy power method
    with the identical per-iteration math and key stream, as a host loop
    over the unrolled iterations instead of a ``lax.scan``.  Returns
    (Rayleigh quotient, final unit vector)."""
    t = ksub.shape[0]
    v = v0
    for i in range(keys.shape[0]):
        absv = jnp.abs(v)
        z = jnp.sum(absv)
        cdf = jnp.cumsum(absv)
        u = jax.random.uniform(keys[i], (num_samples,)) * jnp.maximum(z, 1e-30)
        idx = jnp.clip(jnp.searchsorted(cdf, u, side="right"),
                       0, t - 1).astype(jnp.int32)
        contrib = jnp.sign(v[idx]) * z / num_samples
        w = ksub[:, idx] @ contrib
        nw = jnp.linalg.norm(w)
        v = jnp.where((nw > 0.0) & (z > 0.0), w / jnp.maximum(nw, 1e-30), v)
    lam = v @ (ksub @ v)
    return lam, v


def laplacian_matvec_ref(src, dst, w, p, n: int):
    """Oracle of ``ops.laplacian_matvec``: L p = D p - A p via two
    segment-sum scatters over the COO edge list (the jnp transcription of
    ``SparseGraph.matvec``)."""
    av = jnp.zeros((n,), w.dtype).at[src].add(w * p[dst]).at[dst].add(
        w * p[src])
    deg = jnp.zeros((n,), w.dtype).at[src].add(w).at[dst].add(w)
    return deg * p - av


def triangle_batch_ref(x, x_sq, u, v, degs, keys, kind: str, inv_bw: float,
                       beta: float, block_size: int, n: int, pairwise=None):
    """Oracle of ``ops.triangle_edge_scan`` on its exact level-1 path:
    Theorem 6.17's per-edge estimator with the identical key discipline --
    degree-ordered orientation, ONE masked level-1 read of the v frontier
    (keys[0]), then one ``sample_from_sums`` neighbor draw per remaining
    key, validity mask ``v < w`` (degree order) and ``w != u``, and the
    in-program reweighting by deg(v) / num_draws."""
    views = block_views(x, x_sq, block_size)
    prec = degree_precedes(degs, u, v)
    uu = jnp.where(prec, u, v)
    vv = jnp.where(prec, v, u)
    kuv = kv_pairs(x[uu], x[vv], kind, inv_bw, beta, pairwise)
    bs = masked_exact_sums_ref(x[vv], x, x_sq,
                               (vv // block_size).astype(jnp.int32),
                               kind, inv_bw, beta, block_size, n, pairwise)
    acc = jnp.zeros_like(kuv)
    num_draws = keys.shape[0] - 1
    for i in range(1, keys.shape[0]):
        w, _ = sample_from_sums(x, x_sq, views, vv, bs, keys[i], kind,
                                inv_bw, beta, block_size, n, pairwise)
        valid = degree_precedes(degs, vv, w) & (w != uu)
        kuw = kv_pairs(x[uu], x[w], kind, inv_bw, beta, pairwise)
        acc = acc + jnp.where(valid, kuv * kuw, 0.0)
    return uu, vv, acc * degs[vv] / num_draws


def masked_block_sums_ref(q, x, x_sq, own, kind: str, inv_bw: float,
                          beta: float, bn: int, pairwise=None) -> jnp.ndarray:
    """(m, B) per-block sums over a padded dataset (n multiple of ``bn``;
    padding rows are far-offset so their kernel values are ~0), with
    k(x, x) = 1 subtracted from each query's own block and the result
    floored at BLOCK_SUM_FLOOR."""
    m, n = q.shape[0], x.shape[0]
    kv = kv_matrix(q, x, x_sq, kind, inv_bw, beta, pairwise)
    bs = kv.reshape(m, n // bn, bn).sum(-1)
    corr = jnp.arange(n // bn, dtype=jnp.int32)[None, :] == own[:, None]
    bs = jnp.where(corr, bs - 1.0, bs)
    return jnp.maximum(bs, BLOCK_SUM_FLOOR)


def sample_block_ref(q, x, x_sq, own, gumbel, kind: str, inv_bw: float,
                     beta: float, bn: int, pairwise=None):
    """Oracle for ``kernel.sample_block_pallas``: returns
    (blk, p_blk, tot, block_sums) with blk = argmax_b log(bs_b) + g_b."""
    bs = masked_block_sums_ref(q, x, x_sq, own, kind, inv_bw, beta, bn,
                               pairwise)
    score = jnp.log(bs) + gumbel
    blk = jnp.argmax(score, axis=1).astype(jnp.int32)
    tot = jnp.sum(bs, axis=1)
    pb = jnp.take_along_axis(bs, blk[:, None], axis=1)[:, 0] / tot
    return blk, pb, tot, bs


def sharded_masked_sums_ref(x_pad, x_sq_pad, src, key, kind: str,
                            inv_bw: float, beta: float, block_size: int,
                            blocks_per_shard: int, num_shards: int, n: int,
                            exact: bool = True, s: int = 16, pairwise=None):
    """Single-device oracle of ``sharded.ShardedBlocks._local_sums``,
    concatenated over shards: the §2-contract level-1 read on the padded
    ``P * shard_size`` layout -- own-block corrected, real blocks floored
    at 1e-12, all-sentinel blocks pinned to 0.  The stratified path
    replicates the per-shard ``fold_in(key, p)`` subsample key discipline
    (so shard-local draws match the device program bit-for-bit)."""
    w = src.shape[0]
    bs = block_size
    shard_size = blocks_per_shard * bs
    num_blocks_pad = num_shards * blocks_per_shard
    q = x_pad[src]
    if exact:
        kv = kv_matrix(q, x_pad, x_sq_pad, kind, inv_bw, beta, pairwise)
        sums = kv.reshape(w, num_blocks_pad, bs).sum(-1)
    else:
        parts = []
        for p in range(num_shards):
            kk = jax.random.fold_in(key, p)
            lo = p * shard_size
            base = jnp.arange(blocks_per_shard, dtype=jnp.int32) * bs
            u = jax.random.uniform(kk, (blocks_per_shard, bs))
            pos = base[:, None] + jnp.arange(bs, dtype=jnp.int32)[None, :]
            valid = (lo + pos) < n
            u = jnp.where(valid, u, jnp.inf)
            _, order = jax.lax.top_k(-u, s)
            idx = jnp.take_along_axis(pos, order, axis=1)
            sel_valid = jnp.take_along_axis(valid, order, axis=1)
            flat = lo + idx.reshape(-1)
            kv = kv_matrix(q, x_pad[flat], x_sq_pad[flat], kind, inv_bw,
                           beta, pairwise)
            kv = kv.reshape(w, blocks_per_shard, s) * sel_valid[None]
            sizes = jnp.clip(n - (lo + base), 0, bs).astype(jnp.float32)
            s_b = jnp.minimum(sizes, float(s))
            parts.append(kv.sum(-1)
                         * (sizes / jnp.maximum(s_b, 1.0))[None, :])
        sums = jnp.concatenate(parts, axis=1)
    own = (src // bs).astype(jnp.int32)
    corr = jnp.arange(num_blocks_pad, dtype=jnp.int32)[None, :] == own[:, None]
    sums = jnp.where(corr, sums - 1.0, sums)
    gbase = jnp.arange(num_blocks_pad, dtype=jnp.int32) * bs
    real = jnp.clip(n - gbase, 0, bs) > 0
    return jnp.where(real[None, :], jnp.maximum(sums, BLOCK_SUM_FLOOR), 0.0)


def sharded_sample_from_sums_ref(x_pad, x_sq_pad, views, src, sums, key,
                                 kind: str, inv_bw: float, beta: float,
                                 block_size: int, blocks_per_shard: int,
                                 n: int, pairwise=None):
    """Single-device oracle of the two-stage collective draw
    (``sharded.ShardedBlocks._local_draw``): hierarchical inverse-CDF over
    (shard totals -> owner's local block sums -> in-block columns) with
    the identical ``(k_shard, k_blk, k_in) = split(key, 3)`` discipline.
    Returns (nb, prob, total); ints match the device program bit-for-bit,
    floats to f32 tolerance."""
    w, num_blocks_pad = sums.shape
    num_shards = num_blocks_pad // blocks_per_shard
    k_shard, k_blk, k_in = jax.random.split(key, 3)
    by_shard = sums.reshape(w, num_shards, blocks_per_shard)
    t = by_shard.sum(-1)                                  # (w, P)
    ct = jnp.cumsum(t, axis=1)
    tot = ct[:, -1]
    u0 = jax.random.uniform(k_shard, (w,))
    owner = jnp.sum((u0 * tot)[:, None] > ct, axis=1).clip(0, num_shards - 1)
    local = jnp.take_along_axis(by_shard, owner[:, None, None],
                                axis=1)[:, 0]             # (w, B_p)
    t_o = jnp.take_along_axis(t, owner[:, None], axis=1)[:, 0]
    c = jnp.cumsum(local, axis=1)
    u1 = jax.random.uniform(k_blk, (w,))
    blk_l = jnp.sum((u1 * t_o)[:, None] > c, axis=1).clip(
        0, blocks_per_shard - 1).astype(jnp.int32)
    s_b = jnp.take_along_axis(local, blk_l[:, None], axis=1)[:, 0]
    gblk = (owner * blocks_per_shard).astype(jnp.int32) + blk_l
    kv, live, cols_c = level2_row(x_pad, x_sq_pad, views, src, gblk, kind,
                                  inv_bw, beta, block_size, n, pairwise)
    nb, pin = level2_draw(kv, live, cols_c,
                          jax.random.uniform(k_in, (w,)))
    return nb, s_b * pin / jnp.maximum(tot, 1e-30), tot


def sharded_fused_sample_ref(x_pad, x_sq_pad, src, key, kind: str,
                             inv_bw: float, beta: float, block_size: int,
                             blocks_per_shard: int, num_shards: int, n: int,
                             exact: bool = True, s: int = 16, pairwise=None):
    """Oracle of ``sharded.ShardedBlocks.fused_sample``: the §2 level-1
    read (``k_l1``) followed by the two-stage draw (``k_rest``) with the
    engine's ``k_l1, k_rest = split(key)`` discipline."""
    k_l1, k_rest = jax.random.split(key)
    sums = sharded_masked_sums_ref(x_pad, x_sq_pad, src, k_l1, kind, inv_bw,
                                   beta, block_size, blocks_per_shard,
                                   num_shards, n, exact=exact, s=s,
                                   pairwise=pairwise)
    views = block_views(x_pad, x_sq_pad, block_size)
    nb, prob, _ = sharded_sample_from_sums_ref(
        x_pad, x_sq_pad, views, src, sums, k_rest, kind, inv_bw, beta,
        block_size, blocks_per_shard, n, pairwise)
    return nb, prob, sums


def sharded_walk_ref(x_pad, x_sq_pad, starts, keys, kind: str, inv_bw: float,
                     beta: float, block_size: int, blocks_per_shard: int,
                     num_shards: int, n: int, exact: bool = True, s: int = 16,
                     pairwise=None):
    """Oracle of ``sharded.ShardedBlocks.walk_scan`` (rounds = 0): a host
    loop of per-step ``split -> level-1 read -> two-stage draw`` with the
    identical key stream; endpoints must match bit-for-bit."""
    cur = starts
    for i in range(keys.shape[0]):
        cur, _, _ = sharded_fused_sample_ref(
            x_pad, x_sq_pad, cur, keys[i], kind, inv_bw, beta, block_size,
            blocks_per_shard, num_shards, n, exact=exact, s=s,
            pairwise=pairwise)
    return cur


def fused_edge_batch_ref(x, x_sq, cdf, degs, inv_total, inv_t, key,
                         batch: int, kind: str, inv_bw: float, beta: float,
                         block_size: int, num_blocks: int, n: int,
                         pairwise=None):
    """Oracle of ``ops.fused_edge_batch`` on its Pallas (exact level-1)
    path: Algorithm 5.1 steps (a)-(d) for one batch, with the identical
    key-split discipline -- u ~ degrees by inverse CDF, v by Gumbel-max
    block draw + exact in-block draw, the collapsed reverse probability
    q(u | v) = k(u,v)/deg(v), and the reweighting
    ``k(u,v) / (t (p_u q_uv + p_v q_vu))``.

    The level-1 sums come from ``sample_block_ref`` (pure jnp) where the
    op runs the Pallas kernel; everything else is shared code, so
    interpret-mode runs of the op must reproduce (u, v) bit-for-bit and
    the floats to f32 tolerance."""
    from repro.kernels.kde_rowsum.ops import _PAD_OFFSET, _pad_rows
    views = block_views(x, x_sq, block_size)
    xp = _pad_rows(x, block_size, _PAD_OFFSET)
    xp_sq = jnp.sum(xp * xp, axis=-1)
    k_u, k_fwd = jax.random.split(key)
    u = inverse_cdf_index(cdf, jax.random.uniform(k_u, (batch,)))
    # forward draw v | u -- mirrors _fused_sample's Pallas branch
    _, k_rest = jax.random.split(k_fwd)
    k_g, k_in = jax.random.split(k_rest)
    g = jax.random.gumbel(k_g, (batch, num_blocks))
    blk, pb, _, _ = sample_block_ref(x[u], xp, xp_sq,
                                     (u // block_size).astype(jnp.int32), g,
                                     kind, inv_bw, beta, block_size, pairwise)
    kv, live, cols_c = level2_row(x, x_sq, views, u, blk, kind, inv_bw, beta,
                                  block_size, n, pairwise)
    v, pin = level2_draw(kv, live, cols_c,
                         jax.random.uniform(k_in, (batch,)))
    q_uv = pb * pin
    kuv = kv_pairs(x[u], x[v], kind, inv_bw, beta, pairwise)
    q_vu = kuv / jnp.maximum(degs[v], BLOCK_SUM_FLOOR)
    q_edge = inv_total * (degs[u] * q_uv + kuv)
    wgt = kuv * inv_t / jnp.maximum(q_edge, 1e-30)
    return u, v, wgt, q_uv, q_vu


# --------------------------------------------------------------------- #
# streaming patches (DESIGN.md §12)
# --------------------------------------------------------------------- #
def patch_block_sums_ref(bs, q, slots, old_x, new_x, kind: str,
                         inv_bw: float, beta: float, bn: int, pairwise=None):
    """Oracle of ``ops.patch_block_sums``: incremental §2 level-1 update.

    Subtracts the mutated slots' *old* kernel contributions from the
    cached (w, B) block sums and adds the *new* ones -- O(w m) evals for
    an m-row mutation batch instead of the O(w n) rebuild.  Sentinel
    coordinates (dead side of inserts/deletes) evaluate to exactly 0.0,
    so one delta formula covers insert, delete and update.  The stored
    sums are post-floor, so a block clamped at BLOCK_SUM_FLOOR cannot be
    un-clamped exactly; callers keep patched caches only while the §2
    floor is not binding (the consumer drops the cache when the frontier
    itself mutates).
    """
    old_sq = jnp.sum(old_x * old_x, axis=-1)
    new_sq = jnp.sum(new_x * new_x, axis=-1)
    kv_new = kv_matrix(q, new_x, new_sq, kind, inv_bw, beta, pairwise)
    kv_old = kv_matrix(q, old_x, old_sq, kind, inv_bw, beta, pairwise)
    blk = (slots // bn).astype(jnp.int32)
    out = bs.at[:, blk].add(kv_new - kv_old)
    return jnp.maximum(out, BLOCK_SUM_FLOOR)


def live_degrees_ref(x, x_sq, live, kind: str, inv_bw: float, beta: float,
                     pairwise=None):
    """Exact degrees of a live-masked padded dataset (the rebuild oracle
    for ``ops.degree_delta``): dead slots get degree 0 and contribute no
    mass; live rows get the usual Algorithm 4.3 row sum minus the self
    kernel k(x, x) = 1."""
    q = jnp.where(live[:, None], x, 0.0)     # dead-vs-dead would be NaN
    kv = kv_matrix(q, x, x_sq, kind, inv_bw, beta, pairwise)
    return jnp.where(live, kv.sum(axis=1) - 1.0, 0.0)


def degree_delta_ref(degs, x, x_sq, slots, old_x, new_x, old_live, new_live,
                     kind: str, inv_bw: float, beta: float, pairwise=None):
    """Oracle of ``ops.degree_delta``: O(n m) incremental degree update.

    ``x``/``x_sq`` are the *post-mutation* padded arrays.  Unmutated rows
    receive the exact column delta sum_j [k(x_i, new_j) - k(x_i, old_j)];
    the mutated slots' own degrees are recomputed exactly from their new
    rows (dead slots get 0).  Matches ``live_degrees_ref`` of the new
    dataset whenever ``degs`` matched it for the old one.
    """
    old_q = jnp.where(old_live[:, None], old_x, 0.0)
    new_q = jnp.where(new_live[:, None], new_x, 0.0)
    a_new = kv_matrix(new_q, x, x_sq, kind, inv_bw, beta, pairwise) \
        * new_live[:, None]
    a_old = kv_matrix(old_q, x, x_sq, kind, inv_bw, beta, pairwise) \
        * old_live[:, None]
    out = degs + (a_new - a_old).sum(axis=0)
    row_new = jnp.where(new_live, a_new.sum(axis=1) - 1.0, 0.0)
    return out.at[slots].set(row_new)
