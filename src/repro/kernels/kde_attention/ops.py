"""Sub-quadratic KDE decode attention -- the paper's technique as a serving
feature (DESIGN.md §3).

Pipeline (one decode step, KV cache of length S):
  1. level-1 Pallas sweep: per-key-block strided-subsample lse estimates
     (cost S/stride per head instead of S);
  2. top-P block selection per kv-head (GQA group consensus);
  3. exact flash attention over the P gathered blocks (cost P*bk per head);
  4. denominator correction: the *estimated* residual mass of the unselected
     blocks enters the softmax normalizer -- the KDE row-sum estimate of the
     attention kernel matrix.

Total cost per step: O(S/stride + P*bk) vs O(S) exact -- sub-quadratic
end-to-end decode for S >> P*bk, with multiplicative-error mass coverage
controlled by (stride, P) exactly like (eps, tau) in Definition 1.1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.kde_attention import kernel as _k
from repro.kernels.kde_attention import ref as _ref

_NEG_INF = -1.0e30


@functools.partial(jax.jit,
                   static_argnames=("top_p", "bk", "stride", "kv_valid",
                                    "interpret"))
def kde_attention(q, k, v, *, top_p: int, bk: int = 256, stride: int = 8,
                  kv_valid: int | None = None,
                  interpret: bool | None = None) -> jnp.ndarray:
    """q (b, hq, dh); k, v (b, hkv, S, dh) -> (b, hq, dh).  S % bk == 0."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, hq, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = hq // hkv
    nb = s // bk
    top_p = min(top_p, nb)
    scale = 1.0 / (dh ** 0.5)
    kv_valid = s if kv_valid is None else kv_valid

    # (1) level-1 KDE estimates per block
    est = _k.block_lse_pallas(q, k, scale=scale, stride=stride,
                              kv_valid=kv_valid, bk=bk, interpret=interpret)

    # (2) block selection (shared within each GQA group)
    est_kv = _ref._group_lse(est, group)                  # (b, hkv, nb)
    _, sel = jax.lax.top_k(est_kv, top_p)                 # (b, hkv, P)

    # (3) gather + exact attention over selected blocks
    elem = (sel[..., None] * bk + jnp.arange(bk)).reshape(b, hkv, -1)
    kg = jnp.take_along_axis(k, elem[..., None], axis=2)  # (b, hkv, P*bk, dh)
    vg = jnp.take_along_axis(v, elem[..., None], axis=2)
    # treat the GQA group as the query axis; non-causal over gathered keys
    qg = q.reshape(b, hkv, group, dh)
    # mask out-of-range gathered keys by pushing their scores to -inf via
    # a large negative value bias: zero keys would alias position 0, so we
    # instead mask through kv_valid positions folded into the gather.
    valid = (elem < kv_valid)                             # (b, hkv, P*bk)
    kg = jnp.where(valid[..., None], kg, 0.0)
    vg = jnp.where(valid[..., None], vg, 0.0)
    sc = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(jnp.float32),
                    kg.astype(jnp.float32)) * scale
    sc = jnp.where(valid[:, :, None, :], sc, _NEG_INF)
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    l_sel = p.sum(-1)                                     # (b, hkv, g)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, vg.astype(jnp.float32))
    out = out / jnp.maximum(l_sel, 1e-30)[..., None]

    # (4) denominator correction with the estimated residual mass
    sel_q = jnp.repeat(sel, group, axis=1)                # (b, hq, P)
    chosen = jnp.any(jnp.arange(nb)[None, None, :, None] ==
                     sel_q[:, :, None, :], axis=-1)       # (b, hq, nb)
    est_resid = jnp.where(chosen, _NEG_INF, est)
    m_q = m.reshape(b, hq, 1)
    resid_mass = jnp.exp(est_resid - m_q).sum(-1)         # (b, hq)
    l_q = l_sel.reshape(b, hq)
    frac = l_q / jnp.maximum(l_q + resid_mass, 1e-30)
    out = out.reshape(b, hq, dh) * frac[..., None]
    return out.astype(q.dtype)


exact_decode_attention = _ref.exact_decode_attention
kde_attention_ref = _ref.kde_attention_ref
