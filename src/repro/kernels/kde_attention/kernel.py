"""Pallas TPU kernel: per-block KDE estimates of attention mass (level-1).

The paper's reduction, applied to attention (DESIGN.md §3): the softmax
denominator sum_j exp(q . k_j) is a KDE query against the keys under the
exponential-dot kernel, and each key block's mass is a segment estimate.
This kernel computes, for every key block, a *strided stratified subsample*
logsumexp estimate:

    est_lse[block] = log( stride * sum_{j in block, j % stride == 0}
                          exp(q . k_j * scale) )

-- an unbiased (in exp space) estimate of the block's true mass using
bk/stride of its keys, i.e. the StratifiedKDE estimator fused into one VMEM
pass.  ops.py then attends exactly over the top-P blocks and folds the
estimated residual mass into the denominator.

One query per (batch, q-head) -- this is a decode-step kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_lse_kernel(q_ref, k_ref, o_ref, *, scale, stride, kv_valid, bk):
    j = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)                 # (dh,)
    ks = k_ref[0, 0, ::stride, :].astype(jnp.float32)   # (bk/stride, dh)
    s = jnp.sum(ks * q[None, :], axis=1) * scale        # (bk/stride,)
    kpos = j * bk + jax.lax.iota(jnp.int32, ks.shape[0]) * stride
    s = jnp.where(kpos < kv_valid, s, -1.0e30)
    m = jnp.max(s)
    lse = m + jnp.log(jnp.maximum(jnp.sum(jnp.exp(s - m)), 1e-30))
    o_ref[0, 0, 0] = lse + jnp.log(float(stride))


def block_lse_pallas(q, k, *, scale: float, stride: int, kv_valid: int,
                     bk: int = 256, interpret: bool = False):
    """q (b, hq, dh); k (b, hkv, S, dh) -> (b, hq, S/bk) block lse estimates."""
    b, hq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    nb = skv // bk
    body = functools.partial(_block_lse_kernel, scale=scale, stride=stride,
                             kv_valid=kv_valid, bk=bk)
    return pl.pallas_call(
        body,
        grid=(b, hq, nb),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda bi, hi, j: (bi, hi, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda bi, hi, j, g=group: (bi, hi // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1), lambda bi, hi, j: (bi, hi, j)),
        out_shape=jax.ShapeDtypeStruct((b, hq, nb), jnp.float32),
        interpret=interpret,
    )(q, k)
