"""Pure-jnp oracles for kde_attention.

``exact_decode_attention`` is the ground truth; ``kde_attention_ref`` mirrors
the sampled algorithm (deterministic strided subsample -> identical block
selection), so the Pallas pipeline can be asserted allclose against it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1.0e30


def exact_decode_attention(q, k, v, kv_valid: int | None = None):
    """q (b, hq, dh); k, v (b, hkv, S, dh) -> (b, hq, dh)."""
    b, hq, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = 1.0 / (dh ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    sc = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                    kk.astype(jnp.float32)) * scale
    if kv_valid is not None:
        sc = jnp.where(jnp.arange(s)[None, None] < kv_valid, sc, _NEG_INF)
    p = _softmax(sc)
    return jnp.einsum("bhs,bhsd->bhd", p, vv).astype(q.dtype)


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)


def block_lse_ref(q, k, *, scale, stride, kv_valid, bk):
    """Mirror of the Pallas level-1 kernel."""
    b, hq, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = hq // hkv
    kk = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    sc = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), kk) * scale
    sc = jnp.where(jnp.arange(s)[None, None] < kv_valid, sc, _NEG_INF)
    nb = s // bk
    sc = sc.reshape(b, hq, nb, bk)[..., ::stride]      # strided subsample
    m = jnp.max(sc, axis=-1)
    lse = m + jnp.log(jnp.maximum(
        jnp.sum(jnp.exp(sc - m[..., None]), axis=-1), 1e-30))
    return lse + jnp.log(float(stride))


def kde_attention_ref(q, k, v, *, top_p, bk, stride, kv_valid=None):
    """Pure-jnp mirror of ops.kde_attention (same block selection)."""
    b, hq, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = 1.0 / (dh ** 0.5)
    kv_valid = s if kv_valid is None else kv_valid
    est = block_lse_ref(q, k, scale=scale, stride=stride, kv_valid=kv_valid,
                        bk=bk)                              # (b, hq, nb)
    est_kv = _group_lse(est, group)                         # (b, hkv, nb)
    nb = est.shape[-1]
    sel = jnp.argsort(-est_kv, axis=-1)[..., :top_p]        # (b, hkv, P)

    # gather blocks and attend exactly
    elem = (sel[..., None] * bk + jnp.arange(bk)).reshape(b, hkv, -1)
    kg = jnp.take_along_axis(k, elem[..., None], axis=2)
    vg = jnp.take_along_axis(v, elem[..., None], axis=2)
    kpos_valid = elem < kv_valid                            # (b, hkv, P*bk)

    kk = jnp.repeat(kg, group, axis=1).astype(jnp.float32)
    vv = jnp.repeat(vg, group, axis=1)
    valid = jnp.repeat(kpos_valid, group, axis=1)
    sc = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), kk) * scale
    sc = jnp.where(valid, sc, _NEG_INF)
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    l_sel = p.sum(-1)
    out = jnp.einsum("bhs,bhsd->bhd", p, vv) / jnp.maximum(l_sel, 1e-30)[..., None]

    # residual mass from the unselected blocks' estimates
    sel_q = jnp.repeat(sel, group, axis=1)                  # (b, hq, P)
    mask = jnp.any(jnp.arange(nb)[None, None, :, None] == sel_q[:, :, None, :],
                   axis=-1)                                 # (b, hq, nb) selected?
    est_resid = jnp.where(mask, _NEG_INF, est)
    resid_mass = jnp.exp(est_resid - m[..., 0][..., None]).sum(-1)
    frac = l_sel / jnp.maximum(l_sel + resid_mass, 1e-30)
    return (out * frac[..., None]).astype(q.dtype)


def _group_lse(est, group):
    b, hq, nb = est.shape
    e = est.reshape(b, hq // group, group, nb)
    m = jnp.max(e, axis=2)
    return m + jnp.log(jnp.maximum(
        jnp.sum(jnp.exp(e - m[:, :, None, :]), axis=2), 1e-30))
