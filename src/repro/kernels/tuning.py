"""Analytic tile-size / budget autotuning for the kernel layer (DESIGN.md §14).

Every chooser here is a pure function of *static* shape/config values and is
memoized with ``functools.lru_cache``, so a tuned size is a compile-time
constant: it feeds straight into the same static-config program cache the
jitted entry points already key on (``kde_sampler.ops._STATIC`` etc.) and can
never force a retrace at call time.

Three budgets are tuned:

* ``sweep_blocks_per_tile`` -- column-tile width of the bf16 level-1 sweep
  (``kde_sampler.ref.kv_block_sums_bf16``): wide enough to amortize the f32
  accumulator flush, small enough that the (m, tile) value tile stays cache
  resident.
* ``pallas_tiles`` -- (bm, bn) for the Pallas rowsum/blocksum grids under a
  double-buffered VMEM budget (two in-flight copies of each operand tile
  plus the accumulator).
* ``walk_samples_per_block`` -- the per-block subsample width of the
  walk-resident level-1 cache: capped so the cached compact dataset read is
  O(WALK_CACHE_COLS) columns per step *independent of n*, which is what
  removes the n=65536 walk-throughput cliff (the per-step level-1 re-read
  used to grow as num_blocks * s = O(n)).
"""
from __future__ import annotations

import functools

# Column budget of the bf16 sweep tile: the knee measured on the host
# backend (one (m, 2048) f32 value tile + the (d, 2048) bf16 operand tile
# fit in L2 for the benchmarked m <= 1024, d <= 64 range).
SWEEP_TILE_COLS = 2048

# Level-1 columns resident in a walk program's subsample cache.  At the
# default block layout (bs = sqrt(n)) this equals num_blocks * s for
# n = 4096 (B=64, s=16), so small problems are untouched; past that the
# per-block width shrinks instead of the per-step cost growing.
WALK_CACHE_COLS = 1024
WALK_CACHE_MIN_S = 2

# Narrowest walk-layout stratum: below this the per-step fixed costs
# (key splits, status folds) dominate the level-2 read they amortize.
WALK_MIN_BLOCK = 64

# Double-buffered VMEM budget for the Pallas tile chooser (bytes).  ~16 MiB
# of VMEM per core on current TPUs; keep tiles under half of it so the
# pipelined (two in-flight) copies of every operand fit.
VMEM_BUDGET = 8 * 1024 * 1024


@functools.lru_cache(maxsize=None)
def sweep_blocks_per_tile(bn: int, d: int,
                          target_cols: int = SWEEP_TILE_COLS) -> int:
    """Blocks per column tile of the bf16 blocked sweep (>= 1)."""
    return max(1, int(target_cols) // max(int(bn), 1))


@functools.lru_cache(maxsize=None)
def walk_samples_per_block(num_blocks: int, s: int,
                           cap: int = WALK_CACHE_COLS) -> int:
    """Per-block subsample width of the walk-resident level-1 cache.

    ``min(s, max(cap // num_blocks, WALK_CACHE_MIN_S))``: never more than
    the configured stratified width ``s``, never fewer than
    ``WALK_CACHE_MIN_S`` rows per block (the estimate must keep some
    within-block variance reduction), and at most ~``cap`` total columns.
    """
    return min(int(s), max(int(cap) // max(int(num_blocks), 1),
                           WALK_CACHE_MIN_S))


@functools.lru_cache(maxsize=None)
def walk_block_size(n: int, block_size: int) -> int:
    """Stratum width of the walk-resident layout -- at most half the next
    power of two above ``sqrt(n)``, floored at ``WALK_MIN_BLOCK`` and never
    wider than the sampler's own blocks.

    The walk step pays O(cached cols) at level 1 (flat in n once the cache
    cap binds) plus O(walk_block_size) for the exact level-2 read, so the
    level-2 stratum is the only per-step term still growing with n under
    the sqrt layout.  Halving it (while the same ~WALK_CACHE_COLS cached
    points spread over twice as many strata) halves that term without
    shrinking the cache: same level-1 coverage, finer strata, exact
    within-stratum draw -- the identical stratified depth-2 scheme at a
    finer level-1 granularity.  n = 4096 stays at 64 (unchanged layout);
    n = 65536 drops 256 -> 128; n = 10^6 uses 512.
    """
    p = 1
    while p * p < n:
        p *= 2
    return max(WALK_MIN_BLOCK, min(int(block_size), p // 2))


def _tile_bytes(bm: int, bn: int, d: int, in_bytes: int) -> int:
    # double-buffered q tile + x tile, plus the f32 value/accumulator tile
    return 2 * (bm * d + bn * d) * in_bytes + bm * bn * 4 + bm * 4


@functools.lru_cache(maxsize=None)
def pallas_tiles(m: int, n: int, d: int, precision: str = "f32"):
    """(bm, bn) for the Pallas rowsum/blocksum grids.

    Prefers the widest MXU-aligned x tile whose double-buffered staging
    fits ``VMEM_BUDGET``; bf16 operands halve the staged bytes, so the
    tuner widens the tiles (more reuse per HBM byte) exactly when the
    precision policy makes that free.  Callers pad their operands to the
    returned multiples, so small shapes stick to the narrow tiles (padding
    a 512-row dataset to a 1024 tile would be pure waste).
    """
    in_bytes = 2 if precision == "bf16" else 4
    bm = 256 if m >= 256 else 128
    for bn in (1024, 512, 256):
        if bn > max(n, 256):
            continue
        if _tile_bytes(bm, bn, d, in_bytes) <= VMEM_BUDGET:
            return bm, bn
    return bm, 256
