"""Device-resident hashed-KDE engine (KAP22/DEANN near/far decomposition).

Layout mirrors ``kde_sampler``: ``kernel.py`` Pallas bucket kernels,
``ref.py`` pure-jnp oracles + the ``HashState`` padded-bucket layout,
``ops.py`` host layout build + jitted query/level-1 programs,
``sharded.py`` the mesh-resident one-psum table (DESIGN.md §10)."""
