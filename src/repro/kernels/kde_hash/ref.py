"""Pure-jnp oracle + shared hashing math for the device hashed-KDE engine.

The KAP22/DEANN decomposition (Section 3.1 black-box slot) splits a KDE
query into an exact NEAR term over the query's random-shifted grid bucket
and a Horvitz-Thompson FAR term over uniform samples of the complement:

    KDE(y) = sum_{x in NEAR(y)} k(x, y)  +  (n/s) * sum_j k(x_{i_j}, y) *
                                             1{x_{i_j} not in NEAR(y)}

Unlike ``GridHBE``'s ratio correction, the HT weight ``n/s`` has a *known*
inclusion probability, so the FAR term is exactly unbiased for ANY bucket
assignment (including truncated buckets whose overflow members simply stay
FAR-eligible) and has no degenerate all-samples-collide case -- the
estimate is then 0, still unbiased over the draw.

Everything here is shared verbatim by ``ops.py`` (the jnp fallback path IS
these functions) and by the Pallas kernel body (``rowwise_kv`` runs inside
the kernel), so interpret-mode runs match the oracle bitwise.  The bucket
layout itself (``HashState``) is built once on the host by
``ops.build_hash_state`` and passed to every jitted program as a pytree of
device arrays -- bucket membership of a *dataset* point is a dense
``point_bucket`` gather, never a ``searchsorted``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.kde_sampler.ref import (BLOCK_SUM_FLOOR, _L2_KINDS,
                                           _finish_l2, _finish_l2_bf16,
                                           check_precision)

# Knuth's 2^32 golden-ratio multiplier; uint32 multiply-add wraps
# identically in numpy (host build) and jnp (device query hashing).
HASH_MULT = 2654435761


class HashState(NamedTuple):
    """Device-resident padded-bucket layout (one pytree, all arrays).

    ``members`` holds GLOBAL dataset row indices, ``max_bucket`` slots per
    bucket with slot >= counts[b] as sentinel padding; buckets larger than
    ``max_bucket`` store a seeded subsample and their overflow members stay
    FAR-eligible (the HT weight needs no correction for this).
    """

    dims: jnp.ndarray          # (h,)  int32  hashed coordinate subset
    shift: jnp.ndarray         # (h,)  f32    random grid shift
    keys: jnp.ndarray          # (U,)  uint32 sorted packed bucket keys
    members: jnp.ndarray       # (U, max_bucket) int32 global row indices
    counts: jnp.ndarray        # (U,)  int32  stored member count
    point_bucket: jnp.ndarray  # (n,)  int32  bucket id of each dataset row
    self_stored: jnp.ndarray   # (n,)  f32    1.0 iff the row is stored in
    #                                         its own bucket's slots
    truncated: jnp.ndarray = None  # (U,) bool  bucket overflowed max_bucket
    #                                (optional so older pickled/sharded
    #                                layouts keep working; None reads as
    #                                "no bucket truncated")
    overflow: jnp.ndarray = None   # (ov_cap,) int32 streaming overflow
    #                                region: row ids whose bucket is full or
    #                                whose grid cell is not in the frozen
    #                                ``keys`` (-1 = free slot).  Queries and
    #                                frontier reads sweep it EXACTLY (weight
    #                                1) until a lazy compaction folds it
    #                                back into the bucket layout
    #                                (DESIGN.md §12); None = static dataset.


def pack_codes(codes: jnp.ndarray) -> jnp.ndarray:
    """(m, h) int32 grid codes -> (m,) uint32 keys by wraparound
    multiply-add hashing (one multiplier pass per hashed dimension)."""
    h = jnp.zeros(codes.shape[0], jnp.uint32)
    mult = jnp.uint32(HASH_MULT)
    for j in range(codes.shape[1]):
        h = h * mult + codes[:, j].astype(jnp.uint32)
    return h


def query_codes(y, dims, shift, cell_width: float) -> jnp.ndarray:
    """(m, h) int32 grid codes of query rows under the random-shifted grid
    (float32 add + divide, bitwise identical to the host layout build)."""
    yh = jnp.take(y, dims, axis=1)
    return jnp.floor((yh + shift[None, :]) / cell_width).astype(jnp.int32)


def rowwise_kv(q, xr, kind: str, inv_bw: float, beta: float, pairwise=None,
               precision: str = "f32", table=None):
    """Per-row kernel values k(q_i, xr_i_j): q (w, d), xr (w, t, d) ->
    (w, t), accumulated over a static d-loop.  This exact function runs
    inside the Pallas kernel body AND in the jnp oracles, so compiled
    (interpret) and oracle values agree bitwise.

    ``precision="bf16"`` rounds both operand rows to bf16 (DESIGN.md §14)
    and runs the identical f32-accumulated d-loop on the rounded values;
    the HT weights applied downstream stay f32."""
    if precision != "f32":
        check_precision(precision, kind, pairwise)
        q = q.astype(jnp.bfloat16).astype(jnp.float32)
        xr = xr.astype(jnp.bfloat16).astype(jnp.float32)
    if kind in _L2_KINDS:
        d = q.shape[-1]
        cross = jnp.zeros(xr.shape[:2], jnp.float32)
        xx = jnp.zeros(xr.shape[:2], jnp.float32)
        qq = jnp.zeros((q.shape[0],), jnp.float32)
        for k in range(d):
            c = xr[:, :, k]
            cross = cross + q[:, k:k + 1] * c
            xx = xx + c * c
            qq = qq + q[:, k] * q[:, k]
        d2 = jnp.maximum(qq[:, None] + xx - 2.0 * cross, 0.0)
        if precision != "f32":
            return _finish_l2_bf16(d2, kind, inv_bw, beta, table)
        return _finish_l2(d2, kind, inv_bw, beta)
    if kind == "laplacian":
        d = q.shape[-1]
        acc = jnp.zeros(xr.shape[:2], jnp.float32)
        for k in range(d):
            acc = acc + jnp.abs(q[:, k:k + 1] - xr[:, :, k])
        return jnp.exp(-acc * inv_bw)
    return jax.vmap(lambda a, b: pairwise(a[None, :], b)[0])(q, xr)


# --------------------------------------------------------------------- #
# shared gathers: (rows to evaluate, HT weights) for queries / frontiers
# --------------------------------------------------------------------- #
def _far_collide(fidx, mem, mvalid):
    """(w, s) mask: far sample j of row i hits a stored NEAR member."""
    return jnp.any((fidx[:, :, None] == mem[:, None, :])
                   & mvalid[:, None, :], axis=-1)


def _overflow_cols(state: HashState, w: int):
    """Broadcast the (global) overflow region to per-row exact columns:
    (w, ov_cap) clipped row ids + (w, ov_cap) 0/1 validity weights.
    Returns ``(None, None)`` for static (overflow-free) states."""
    if state.overflow is None:
        return None, None
    ov = state.overflow
    ovvalid = (ov >= 0)[None, :]
    ovc = jnp.broadcast_to(jnp.maximum(ov, 0)[None, :], (w, ov.shape[0]))
    return ovc, jnp.broadcast_to(ovvalid, (w, ov.shape[0]))


def _far_hits_overflow(fidx, state: HashState):
    """(w, s) mask: far sample hits a live overflow row (those are already
    counted exactly by the overflow sweep)."""
    if state.overflow is None:
        return jnp.zeros(fidx.shape, bool)
    ov = state.overflow
    return jnp.any((fidx[:, :, None] == ov[None, None, :])
                   & (ov >= 0)[None, None, :], axis=-1)


def num_exact_cols(state: HashState) -> int:
    """Static count of exact (NEAR member + overflow) evaluation columns
    in the gathers below -- FAR columns start here."""
    mb = int(state.members.shape[1])
    return mb + (int(state.overflow.shape[0])
                 if state.overflow is not None else 0)


def query_gather(x, y, state: HashState, key, cell_width: float,
                 num_far: int, n: int):
    """Bucket lookup + FAR draw for arbitrary queries: hash ``y`` on
    device, find the bucket by one vectorized ``searchsorted`` over the
    sorted keys, and return the (w, max_bucket + num_far) evaluation rows
    ``xr``, their summation weights ``wgt`` (1 for valid NEAR slots,
    ``n/num_far`` for non-colliding FAR samples), the realized NEAR
    counts (Definition 1.1 eval accounting), and the per-row
    bucket-truncation flag (False everywhere for legacy states)."""
    qkey = pack_codes(query_codes(y, state.dims, state.shift, cell_width))
    b = jnp.clip(jnp.searchsorted(state.keys, qkey), 0,
                 state.keys.shape[0] - 1).astype(jnp.int32)
    hit = state.keys[b] == qkey
    cnt = jnp.where(hit, state.counts[b], 0)
    mem = state.members[b]
    mb = mem.shape[1]
    mvalid = jnp.arange(mb, dtype=jnp.int32)[None, :] < cnt[:, None]
    trunc = (hit & state.truncated[b] if state.truncated is not None
             else jnp.zeros(hit.shape, bool))
    ovc, ovvalid = _overflow_cols(state, y.shape[0])
    if ovc is not None:                    # streaming: extra exact sweep
        mem = jnp.concatenate([mem, ovc], axis=1)
        mvalid = jnp.concatenate([mvalid, ovvalid], axis=1)
    if num_far == 0:                       # static: NEAR-only estimate
        return mem, x[mem], mvalid.astype(jnp.float32), cnt, trunc
    fidx = jax.random.randint(key, (y.shape[0], num_far), 0, n)
    collide = (_far_collide(fidx, mem[:, :mb], mvalid[:, :mb])
               | _far_hits_overflow(fidx, state))
    cols = jnp.concatenate([mem, fidx], axis=1)
    wgt = jnp.concatenate(
        [mvalid.astype(jnp.float32),
         (float(n) / num_far) * (1.0 - collide.astype(jnp.float32))], axis=1)
    return cols, x[cols], wgt, cnt, trunc


def frontier_gather(x, src, state: HashState, key, num_far: int,
                    block_size: int, num_blocks: int, n: int):
    """Bucket lookup + STRATIFIED FAR draw for a frontier of DATASET
    indices (the level-1 read): the bucket id is a dense ``point_bucket``
    gather (no hashing, no searchsorted), and the FAR term draws
    ``num_far`` uniform slots PER BLOCK (a stratified draw, so every
    block's estimate is backed by a real sample -- a global FAR draw
    leaves most blocks at the 1e-12 floor and makes the sparsifier's
    importance weights heavy-tailed).  The HT weight is the constant
    ``block_size/num_far`` (slot-uniform inclusion; out-of-range tail
    slots and collisions with stored NEAR members or the query itself are
    masked to weight 0, which the constant weight keeps unbiased).  The
    fifth output is the per-row bucket-truncation flag."""
    w = src.shape[0]
    b = state.point_bucket[src]
    # streaming states mark rows with no frozen bucket (overflow rows in a
    # brand-new grid cell, dead slots) with point_bucket = -1: their NEAR
    # set is empty and the FAR/overflow terms carry the whole estimate
    nohit = b < 0
    bc = jnp.maximum(b, 0)
    cnt = jnp.where(nohit, 0, state.counts[bc])
    mem = state.members[bc]
    mb = mem.shape[1]
    mvalid = jnp.arange(mb, dtype=jnp.int32)[None, :] < cnt[:, None]
    trunc = (state.truncated[bc] & ~nohit if state.truncated is not None
             else jnp.zeros(b.shape, bool))
    ovc, ovvalid = _overflow_cols(state, w)
    if ovc is not None:                    # streaming: extra exact sweep
        mem = jnp.concatenate([mem, ovc], axis=1)
        mvalid = jnp.concatenate([mvalid, ovvalid], axis=1)
    base = jnp.arange(num_blocks, dtype=jnp.int32) * block_size
    off = jax.random.randint(key, (w, num_blocks, num_far), 0, block_size)
    fidx = (base[None, :, None] + off).reshape(w, num_blocks * num_far)
    dead = (_far_collide(fidx, mem[:, :mb], mvalid[:, :mb])
            | _far_hits_overflow(fidx, state) | (fidx == src[:, None])
            | (fidx >= n))
    fidx = jnp.minimum(fidx, n - 1)
    cols = jnp.concatenate([mem, fidx], axis=1)
    wgt = jnp.concatenate(
        [mvalid.astype(jnp.float32),
         (float(block_size) / num_far)
         * (1.0 - dead.astype(jnp.float32))], axis=1)
    return cols, x[cols], wgt, cnt, trunc


# --------------------------------------------------------------------- #
# oracles (the jnp fallback path of ops.py IS these functions)
# --------------------------------------------------------------------- #
def hashed_query_ref(x, y, state: HashState, key, kind: str, inv_bw: float,
                     beta: float, cell_width: float, num_far: int, n: int,
                     pairwise=None):
    """NEAR-exact + HT-FAR row-sum estimates: (m,) estimates and the (m,)
    realized NEAR eval counts.  One weighted kernel-value pass over the
    concatenated (member, far-sample) rows -- the identical summation
    order the Pallas kernel uses, so interpret-mode runs match bitwise."""
    _, xr, wgt, cnt, _ = query_gather(x, y, state, key, cell_width, num_far,
                                      n)
    kv = rowwise_kv(y, xr, kind, inv_bw, beta, pairwise)
    return jnp.sum(kv * wgt, axis=1), cnt


def hashed_block_sums_ref(x, src, state: HashState, key, kind: str,
                          inv_bw: float, beta: float, num_far: int,
                          block_size: int, num_blocks: int, n: int,
                          pairwise=None):
    """Hashed level-1 frontier read: (w, B) §2-contract block-sum
    estimates from O(max_bucket + B num_far) kernel evals per row.  NEAR
    members contribute exactly to their own blocks (a scatter-add over the
    member block ids); the stratified FAR samples are block-indexed by
    construction, so their HT-weighted values reduce with one reshape.
    The query's self kernel (k(x, x) = 1, the repo-wide contract) is
    subtracted from its own block iff stored (otherwise the FAR mask
    already excluded it), and every block is floored at 1e-12 exactly
    like ``ops.masked_block_sums``."""
    q = x[src]
    cols, xr, wgt, _, _ = frontier_gather(x, src, state, key, num_far,
                                          block_size, num_blocks, n)
    kv = rowwise_kv(q, xr, kind, inv_bw, beta, pairwise) * wgt
    return scatter_block_sums(kv, cols, src, state, num_far, block_size,
                              num_blocks)


def scatter_block_sums(kv, cols, src, state: HashState, num_far: int,
                       block_size: int, num_blocks: int):
    """Shared §2 finish of the hashed level-1 read (consumed verbatim by
    the ops path too, so oracle and fused programs cannot drift): scatter
    the weighted NEAR values into their blocks, reshape-reduce the
    block-indexed FAR values, subtract the self kernel from the own block
    iff stored, floor every block at 1e-12.  Streaming states contribute
    their overflow region as extra exact columns (already weight-masked by
    the gather), scattered by block exactly like NEAR members."""
    nex = num_exact_cols(state)
    w = src.shape[0]
    blk_near = (cols[:, :nex] // block_size).astype(jnp.int32)
    bs = kv[:, nex:].reshape(w, num_blocks, num_far).sum(-1)
    bs = bs.at[jnp.arange(w, dtype=jnp.int32)[:, None], blk_near].add(
        kv[:, :nex])
    own = (src // block_size).astype(jnp.int32)
    corr = jnp.arange(num_blocks, dtype=jnp.int32)[None, :] == own[:, None]
    bs = jnp.where(corr, bs - state.self_stored[src][:, None], bs)
    return jnp.maximum(bs, BLOCK_SUM_FLOOR)


def sharded_hashed_query_ref(x_pad, y, shard_states, key, kind: str,
                             inv_bw: float, beta: float, cell_width: float,
                             num_far: int, n: int, shard_size: int,
                             pairwise=None):
    """Single-device oracle of ``sharded.ShardedHashTable.query``: every
    shard looks up its OWN bucket table (each shard hashed its own rows),
    draws ``num_far`` uniforms over its ``shard_size`` row slots with the
    per-shard ``fold_in(key, p)`` discipline (sentinel rows sit at the far
    offset, so their kernel values are exactly 0 and the HT weight is
    ``shard_size/num_far``), and the estimate is the plain sum of the
    per-shard NEAR+FAR partials -- what ONE psum produces on the mesh.
    Streaming shard states carry a per-shard ``overflow`` region of row
    ids owned by that shard; its live entries join the shard's exact
    sweep (weight 1) and are masked out of its FAR draw, mirroring the
    flat ``query_gather`` contract.  Returns (estimates, NEAR counts);
    ints match the device program bitwise, floats to f32 tolerance
    (psum reorders the accumulation)."""
    num_shards = len(shard_states)
    m = y.shape[0]
    est = jnp.zeros((m,), jnp.float32)
    cnt = jnp.zeros((m,), jnp.int32)
    for p in range(num_shards):
        st = shard_states[p]
        qkey = pack_codes(query_codes(y, st.dims, st.shift, cell_width))
        b = jnp.clip(jnp.searchsorted(st.keys, qkey), 0,
                     st.keys.shape[0] - 1).astype(jnp.int32)
        hit = st.keys[b] == qkey
        c = jnp.where(hit, st.counts[b], 0)
        mem = st.members[b]
        mb = mem.shape[1]
        mvalid = jnp.arange(mb, dtype=jnp.int32)[None, :] < c[:, None]
        ovc, ovvalid = _overflow_cols(st, m)
        if ovc is not None:                # streaming: extra exact sweep
            mem_cat = jnp.concatenate([mem, ovc], axis=1)
            wexact = jnp.concatenate(
                [mvalid.astype(jnp.float32), ovvalid.astype(jnp.float32)],
                axis=1)
        else:
            mem_cat = mem
            wexact = mvalid.astype(jnp.float32)
        if num_far == 0:                   # static: NEAR-only estimate
            cols, wgt = mem_cat, wexact
        else:
            kk = jax.random.fold_in(key, p)
            fidx = (p * shard_size
                    + jax.random.randint(kk, (m, num_far), 0, shard_size))
            collide = (_far_collide(fidx, mem, mvalid)
                       | _far_hits_overflow(fidx, st))
            cols = jnp.concatenate([mem_cat, fidx], axis=1)
            wgt = jnp.concatenate(
                [wexact,
                 (float(shard_size) / num_far)
                 * (1.0 - collide.astype(jnp.float32))], axis=1)
        kv = rowwise_kv(y, x_pad[cols], kind, inv_bw, beta, pairwise)
        est = est + jnp.sum(kv * wgt, axis=1)
        cnt = cnt + c
    return est, cnt
