"""Device-resident hashed-KDE engine: layout build + jitted programs.

``build_hash_state`` runs ONCE on the host: hash every dataset row with a
random-shifted grid (the KAP22/DEANN scheme of ``core.kde.hbe``), sort by
packed key, and freeze the buckets into the static padded layout of
``ref.HashState`` -- ``max_bucket`` slots per bucket, sentinel padding,
global row indices.  After that every query is ONE jitted device program:

* ``hashed_query``      -- (m,) NEAR-exact + HT-FAR row-sum estimates plus
  the realized NEAR eval counts; O(max_bucket + num_far) kernel evals per
  query instead of the dense backends' O(n) (Definition 1.1 / §3.1).
* ``hashed_block_sums`` -- (w, B) §2-contract level-1 block-sum estimates
  for a frontier of dataset indices (bucket membership is a dense
  ``point_bucket`` gather; the FAR term is a stratified per-block draw so
  no block is left at the floor); the ``level1="hash"`` read of the
  depth-2 sampler (DESIGN.md §10).

Both dispatch the weighted kernel-value pass to the Pallas bucket kernel
on the TPU path and run the ``ref.py`` oracle math elsewhere; interpret
mode matches the oracle bitwise.  ``TRACE_COUNTS`` is shared with
``kde_sampler.ops`` so the no-retrace tests cover these programs too.
"""
from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft import guards as _g
from repro.kernels.kde_hash import kernel as _k
from repro.kernels.kde_hash import ref as _ref
from repro.kernels.kde_sampler import ops as _sops
from repro.kernels.kde_sampler.ref import BLOCK_SUM_FLOOR, BUILTIN_KINDS
from repro.obs import counters as _c

TRACE_COUNTS = _sops.TRACE_COUNTS

_STATIC = frozenset((
    "kind", "inv_bw", "beta", "pairwise", "cell_width", "num_far", "n",
    "block_size", "num_blocks", "use_pallas", "interpret", "bm",
    # precision selects the weighted-pass eval dtype (DESIGN.md §14):
    # "f32" (default, bitwise-stable) or "bf16" (rounded operand rows,
    # f32 weights/accumulators/scatters)
    "precision"))


def _jit(fn):
    names = tuple(p for p in inspect.signature(fn).parameters if p in _STATIC)
    return jax.jit(fn, static_argnames=names)


def default_cell_width(kernel) -> float:
    """The ``GridHBE`` default: two bandwidths per grid cell, so NEAR
    buckets cover the region where Table-1 kernels carry most mass."""
    return 2.0 * float(kernel.bandwidth)


def draw_grid(rng, d: int, num_hash_dims: int, cell_width: float):
    """Draw the random-shifted grid (hash-dim subset + per-dim shift) with
    the exact ``GridHBE(seed=...)`` RNG call order -- the ONE place this
    discipline lives (``build_hash_state`` and the sharded table both call
    it, so equal seeds always mean the identical grid)."""
    dims = rng.choice(d, size=min(int(num_hash_dims), d),
                      replace=False).astype(np.int32)
    shift = rng.uniform(0.0, cell_width, size=len(dims)).astype(np.float32)
    return dims, shift


def grid_keys(xn: np.ndarray, dims, shift, cell_width: float) -> np.ndarray:
    """(k,) uint32 packed grid keys of rows ``xn`` (float32 shift/floor
    arithmetic bitwise-equal to the device-side ``ref.query_codes``)."""
    codes = np.floor((xn[:, dims] + shift) / cell_width).astype(np.int32)
    keys = np.zeros(len(xn), np.uint32)
    for j in range(codes.shape[1]):
        keys = keys * np.uint32(_ref.HASH_MULT) + codes[:, j].astype(np.uint32)
    return keys


def bucket_table(keys: np.ndarray, rows: np.ndarray, max_bucket: int, rng):
    """Freeze the buckets of one key slice into the padded layout:
    (sorted unique keys, (U, max_bucket) member table of GLOBAL row ids,
    stored counts, concatenated stored row ids, per-bucket truncation
    flags).  Oversized buckets store a seeded subsample; overflow members
    stay FAR-eligible -- the flags let queries report that truncation
    happened (``guards.BUCKET_OVERFLOW``)."""
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    uniq, counts_full = np.unique(sk, return_counts=True)
    starts = np.concatenate([[0], np.cumsum(counts_full)[:-1]])
    mb = int(max_bucket)
    members = np.zeros((max(len(uniq), 1), mb), np.int32)
    counts = np.zeros(max(len(uniq), 1), np.int32)
    counts[:len(uniq)] = np.minimum(counts_full, mb)
    truncated = np.zeros(max(len(uniq), 1), bool)
    truncated[:len(uniq)] = counts_full > mb
    stored = [np.zeros(0, np.int64)]
    for b in range(len(uniq)):
        seg = rows[order[starts[b]:starts[b] + counts_full[b]]]
        if counts_full[b] > mb:
            seg = rng.choice(seg, size=mb, replace=False)
        members[b, :len(seg)] = seg
        stored.append(seg)
    return uniq, members, counts, np.concatenate(stored), truncated


def build_hash_state(x, kernel, cell_width: float | None = None,
                     num_hash_dims: int = 8, max_bucket: int = 256,
                     seed: int = 0, live=None, overflow_cap: int = 0):
    """Host-side layout build (once per dataset): returns
    ``(HashState, cell_width)``.

    The RNG call order (hash-dim choice, then shift, then per-bucket
    overflow subsampling) matches ``GridHBE(seed=...)``, so a ``GridHBE``
    built with the same seed/width hashes with the identical grid --
    bucket membership agrees up to the packed-key width (uint32 here,
    uint64 there; a collision would merely merge two cells, and the
    HT-corrected estimator stays unbiased under ANY bucket assignment).
    Buckets larger than ``max_bucket`` store a seeded subsample; overflow
    members remain FAR-eligible.

    Streaming extensions (DESIGN.md §12): ``live`` masks the padded rows
    actually hashed -- dead (sentinel) slots get ``point_bucket = -1``
    and never enter a bucket; ``overflow_cap > 0`` attaches an (empty)
    overflow region of that static capacity, the landing zone
    :class:`HashPatcher` appends mutated rows into between compactions.
    """
    xn = np.asarray(x, np.float32)
    n, d = xn.shape
    rng = np.random.default_rng(seed)
    w = float(cell_width if cell_width is not None
              else default_cell_width(kernel))
    dims, shift = draw_grid(rng, d, num_hash_dims, w)
    if live is None:
        rows = np.arange(n, dtype=np.int64)
    else:
        rows = np.where(np.asarray(live, bool))[0].astype(np.int64)
    keys = grid_keys(xn[rows], dims, shift, w)
    uniq, members, counts, stored_rows, truncated = bucket_table(
        keys, rows, max_bucket, rng)
    stored = np.zeros(n, bool)
    stored[stored_rows] = True
    point_bucket = np.full(n, -1, np.int32)
    point_bucket[rows] = np.searchsorted(uniq, keys).astype(np.int32)
    state = _ref.HashState(
        dims=jnp.asarray(dims),
        shift=jnp.asarray(shift),
        keys=jnp.asarray(uniq),
        members=jnp.asarray(members),
        counts=jnp.asarray(counts),
        point_bucket=jnp.asarray(point_bucket),
        self_stored=jnp.asarray(stored.astype(np.float32)),
        truncated=jnp.asarray(truncated),
        overflow=(jnp.full((int(overflow_cap),), -1, jnp.int32)
                  if overflow_cap else None))
    return state, w


def _weighted_pass(q, xr, wgt, *, kind, inv_bw, beta, pairwise, use_pallas,
                   interpret, bm, reduce_sum, precision="f32"):
    """One weighted kernel-value pass: Pallas bucket kernel on the TPU
    path (padded to a ``bm`` query multiple), the shared ``ref.rowwise_kv``
    math elsewhere -- bitwise-identical results in interpret mode."""
    if use_pallas and kind in BUILTIN_KINDS:
        m = q.shape[0]
        rem = (-m) % bm
        if rem:
            q = jnp.pad(q, ((0, rem), (0, 0)))
            wgt = jnp.pad(wgt, ((0, rem), (0, 0)))
            xr = jnp.pad(xr, ((0, rem), (0, 0), (0, 0)))
        fn = (_k.weighted_kv_sum_pallas if reduce_sum
              else _k.weighted_kv_pallas)
        return fn(q, wgt, xr, kind, inv_bw, beta, bm=bm,
                  interpret=interpret, precision=precision)[:m]
    kv = _ref.rowwise_kv(q, xr, kind, inv_bw, beta, pairwise,
                         precision=precision) * wgt
    return jnp.sum(kv, axis=1) if reduce_sum else kv


@_jit
def hashed_query(x, y, state, key, *, kind, inv_bw, beta, pairwise,
                 cell_width, num_far, n, use_pallas=False, interpret=False,
                 bm=32, precision="f32"):
    """(m,) row-sum estimates + (m,) realized NEAR eval counts + a counter
    word -- the Definition 1.1 read at O(max_bucket + num_far) evals
    per query.  The word's status slot flags bucket truncation, out-of-range member
    indices (JAX gathers clamp, so corruption is otherwise silent), and a
    Horvitz-Thompson FAR sample dominating the estimate (on the jnp path
    per element against ``REPRO_HT_FRAC``; the Pallas kernel only sees the
    reduced sum, so there the static weight ``n/num_far`` is checked
    against ``REPRO_HT_BOUND``)."""
    TRACE_COUNTS["hashed_query"] += 1
    cols, xr, wgt, cnt, trunc = _ref.query_gather(x, y, state, key,
                                                  cell_width, num_far, n)
    corrupt = jnp.any((cols < 0) | (cols >= n))
    if use_pallas and kind in BUILTIN_KINDS:
        est = _weighted_pass(y, xr, wgt, kind=kind, inv_bw=inv_bw, beta=beta,
                             pairwise=pairwise, use_pallas=use_pallas,
                             interpret=interpret, bm=bm, reduce_sum=True,
                             precision=precision)
        heavy = jnp.asarray(num_far > 0
                            and float(n) / num_far > _g.ht_bound())
    else:
        kv = _weighted_pass(y, xr, wgt, kind=kind, inv_bw=inv_bw, beta=beta,
                            pairwise=pairwise, use_pallas=use_pallas,
                            interpret=interpret, bm=bm, reduce_sum=False,
                            precision=precision)
        est = jnp.sum(kv, axis=1)
        far = kv[:, _ref.num_exact_cols(state):]
        heavy = (jnp.any(far > _g.ht_frac()
                         * jnp.maximum(jnp.abs(est)[:, None], 1e-30))
                 if num_far > 0 else jnp.asarray(False))
    st = _g.merge(_g.flag_if(corrupt, _g.STATE_CORRUPT),
                  _g.flag_if(jnp.any(trunc), _g.BUCKET_OVERFLOW),
                  _g.flag_if(heavy, _g.HT_HEAVY),
                  _g.result_status(est))
    # realized gather width per query row (ref.query_gather): max_bucket
    # NEAR slots + the overflow sweep + num_far HT samples
    m = y.shape[0]
    ov = (int(state.overflow.shape[0])
          if state.overflow is not None else 0)
    mb = int(state.members.shape[1])
    cw = _c.word(status=st, evals=m * (mb + ov + num_far), l1_reads=m,
                 far_samples=m * num_far, overflow=m * ov)
    return est, cnt, cw


def _hashed_block_sums(x, src, state, key, *, kind, inv_bw, beta, pairwise,
                       num_far, block_size, num_blocks, n, use_pallas,
                       interpret, bm, precision="f32"):
    """Traceable core of ``hashed_block_sums`` (called from inside the
    fused sampler programs of ``kde_sampler.ops``).  Returns
    ``(block sums, status)``."""
    q = x[src]
    cols, xr, wgt, _, trunc = _ref.frontier_gather(x, src, state, key,
                                                   num_far, block_size,
                                                   num_blocks, n)
    kv = _weighted_pass(q, xr, wgt, kind=kind, inv_bw=inv_bw, beta=beta,
                        pairwise=pairwise, use_pallas=use_pallas,
                        interpret=interpret, bm=bm, reduce_sum=False,
                        precision=precision)
    bs = _ref.scatter_block_sums(kv, cols, src, state, num_far,
                                 block_size, num_blocks)
    st = _g.merge(_g.flag_if(jnp.any((cols < 0) | (cols >= n)),
                             _g.STATE_CORRUPT),
                  _g.flag_if(jnp.any(trunc), _g.BUCKET_OVERFLOW),
                  _g.sums_status(bs, BLOCK_SUM_FLOOR))
    return bs, st


@_jit
def hashed_block_sums(x, src, state, key, *, kind, inv_bw, beta, pairwise,
                      num_far, block_size, num_blocks, n, use_pallas=False,
                      interpret=False, bm=32, precision="f32"):
    """(w, B) §2-contract level-1 estimates of a dataset frontier from
    O(max_bucket + B num_far) evals per row: exact NEAR scatter +
    ``num_far`` stratified FAR slots per block (the ``level1="hash"``
    read; DESIGN.md §10).  Returns ``(block sums, counter word)``."""
    TRACE_COUNTS["hashed_block_sums"] += 1
    bs, st = _hashed_block_sums(x, src, state, key, kind=kind, inv_bw=inv_bw,
                                beta=beta, pairwise=pairwise,
                                num_far=num_far, block_size=block_size,
                                num_blocks=num_blocks, n=n,
                                use_pallas=use_pallas, interpret=interpret,
                                bm=bm, precision=precision)
    # realized gather width per frontier row (ref.frontier_gather):
    # max_bucket NEAR slots + the overflow sweep + B*num_far FAR slots
    w = src.shape[0]
    ov = (int(state.overflow.shape[0])
          if state.overflow is not None else 0)
    mb = int(state.members.shape[1])
    far = int(num_blocks) * int(num_far)
    cw = _c.word(status=st, evals=w * (mb + ov + far), l1_reads=w,
                 far_samples=w * far, overflow=w * ov)
    return bs, cw


# --------------------------------------------------------------------- #
# batched multi-tenant serving entry points (DESIGN.md §13)
# --------------------------------------------------------------------- #
def stack_hash_states(states):
    """Stack equal-shape ``HashState`` pytrees along a new leading tenant
    axis for the batched multi-tenant query path.  All layouts must agree
    in every array shape and dtype (bucket count, ``max_bucket``, padded
    row count, overflow capacity, hash dims) -- the serving layer keys its
    batch groups by exactly this shape signature, so unequal tenants never
    share a group.  Raises ``ValueError`` on a mismatch rather than
    silently padding: phantom padded buckets would change the FAR
    complement every Horvitz-Thompson draw sees."""
    if not states:
        raise ValueError("stack_hash_states needs at least one state")
    leaves0, treedef0 = jax.tree_util.tree_flatten(states[0])
    for s in states[1:]:
        leaves, treedef = jax.tree_util.tree_flatten(s)
        if treedef != treedef0 or any(
                a.shape != b.shape or a.dtype != b.dtype
                for a, b in zip(leaves, leaves0)):
            raise ValueError(
                "HashState layouts differ in shape/dtype -- serve these "
                "tenants in separate batch groups")
    return jax.tree_util.tree_map(lambda *a: jnp.stack(a), *states)


@_jit
def batched_hashed_query(xa, tidx, y, state, keys, *, kind, inv_bw, beta,
                         pairwise, cell_width, num_far, n, use_pallas=False,
                         interpret=False, bm=32, precision="f32"):
    """R hashed Definition 1.1 query requests across stacked tenants in
    ONE program: ``xa (T, n, d)`` stacked tenant rows, ``state`` a
    :func:`stack_hash_states` pytree, ``y (R, q, d)`` padded query points,
    ``keys (R, 2)`` per-request PRNG keys.  Returns (estimates (R, q),
    NEAR eval counts (R, q), per-request counter words (R, obs.WIDTH)) --
    each lane is ``hashed_query`` on its own tenant and key, so estimates
    match the sequential single-tenant calls."""
    TRACE_COUNTS["batched_hashed_query"] += 1

    def one(ti, y_r, key_r):
        hs = jax.tree_util.tree_map(lambda a: a[ti], state)
        return hashed_query(xa[ti], y_r, hs, key_r, kind=kind,
                            inv_bw=inv_bw, beta=beta, pairwise=pairwise,
                            cell_width=cell_width, num_far=num_far, n=n,
                            use_pallas=use_pallas, interpret=interpret,
                            bm=bm, precision=precision)

    return jax.vmap(one)(tidx, y, keys)


# --------------------------------------------------------------------- #
# streaming patches (DESIGN.md §12)
# --------------------------------------------------------------------- #
@jax.jit
def _apply_hash_patch(members, counts, point_bucket, self_stored, overflow,
                      bidx, brows, bcnt, pidx, pb, ss, ovidx, ovval):
    """Jitted scatter of a host-computed hash patch: rewrite the touched
    bucket rows wholesale (host already deduplicated them) plus the
    touched per-point and overflow entries.  O(touched) device work, no
    rehash, no sort, no collectives."""
    return (members.at[bidx].set(brows),
            counts.at[bidx].set(bcnt),
            point_bucket.at[pidx].set(pb),
            self_stored.at[pidx].set(ss),
            overflow.at[ovidx].set(ovval))


class HashPatcher:
    """Incremental ``HashState`` maintenance for a mutating dataset.

    Keeps host numpy mirrors of the (host-built anyway) bucket tables and
    patches them in O(m) per mutation batch; the device state is updated
    by ONE jitted scatter over the touched entries.  The placement policy
    (DESIGN.md §12):

    * insert whose grid cell exists in the frozen ``keys`` and whose
      bucket has free slots -> splice into the bucket at its slot-sorted
      position (rows arrive tail-first from ``DynamicDataset``, so the
      patched member table stays bitwise equal to a fresh rebuild);
    * otherwise -> append to the **overflow region**, which every query /
      frontier read sweeps exactly (weight 1) until :meth:`needs_rebuild`
      tells the owner to compact (rebuild via ``build_hash_state``);
    * delete -> left-shift out of its bucket (or clear its overflow slot);
      the row's coordinates are already at the sentinel offset, so even a
      missed removal would contribute exactly 0 mass.

    Saturated overflow sets ``guards.OVERFLOW_SATURATED`` in :attr:`flags`
    and forces :attr:`needs_rebuild`; touching an RNG-subsampled
    (truncated) bucket stays *correct* but loses bitwise rebuild parity,
    which :attr:`exact_parity` records.
    """

    def __init__(self, state, cell_width: float):
        if state.overflow is None:
            raise ValueError("HashPatcher needs a state built with "
                             "overflow_cap > 0")
        self.cell_width = float(cell_width)
        self.dims = np.asarray(state.dims)
        self.shift = np.asarray(state.shift)
        self.keys = np.asarray(state.keys)           # frozen, sorted
        self.members = np.array(state.members, np.int32, copy=True)
        self.counts = np.array(state.counts, np.int32, copy=True)
        self.point_bucket = np.array(state.point_bucket, np.int32,
                                     copy=True)
        self.self_stored = np.array(state.self_stored, np.float32,
                                    copy=True)
        self.truncated = (np.array(state.truncated, bool, copy=True)
                          if state.truncated is not None
                          else np.zeros(len(self.keys), bool))
        self.overflow = np.array(state.overflow, np.int32, copy=True)
        self.max_bucket = int(self.members.shape[1])
        self.flags = 0
        self.needs_rebuild = False
        self.exact_parity = True

    @property
    def overflow_fill(self) -> int:
        """Occupied overflow slots (monitoring / compaction policy)."""
        return int((self.overflow >= 0).sum())

    def _remove(self, slot: int, touched_b: set, touched_ov: set) -> None:
        b = int(self.point_bucket[slot])
        if self.self_stored[slot] > 0.0:
            if b >= 0:                      # stored in its bucket's slots
                cnt = int(self.counts[b])
                row = self.members[b]
                pos = np.where(row[:cnt] == slot)[0]
                if pos.size:
                    p = int(pos[0])
                    row[p:cnt - 1] = row[p + 1:cnt]
                    row[cnt - 1] = 0
                    self.counts[b] = cnt - 1
                    touched_b.add(b)
                    if self.truncated[b]:
                        self.exact_parity = False
            pos = np.where(self.overflow == slot)[0]
            if pos.size:                    # stored in the overflow region
                self.overflow[pos[0]] = -1
                touched_ov.add(int(pos[0]))
        elif b >= 0 and self.truncated[b]:
            # an unstored member of a truncated bucket: nothing to remove,
            # but a rebuild would resample the smaller bucket
            self.exact_parity = False
        self.point_bucket[slot] = -1
        self.self_stored[slot] = 0.0

    def _insert(self, slot: int, row_x: np.ndarray, touched_b: set,
                touched_ov: set) -> None:
        key = grid_keys(row_x[None, :], self.dims, self.shift,
                        self.cell_width)[0]
        pos = int(np.searchsorted(self.keys, key))
        hit = pos < len(self.keys) and self.keys[pos] == key
        b = pos if hit else -1
        if hit and int(self.counts[b]) < self.max_bucket \
                and not self.truncated[b]:
            cnt = int(self.counts[b])
            row = self.members[b]
            at = int(np.searchsorted(row[:cnt], slot))
            row[at + 1:cnt + 1] = row[at:cnt]
            row[at] = slot
            self.counts[b] = cnt + 1
            self.point_bucket[slot] = b
            self.self_stored[slot] = 1.0
            touched_b.add(b)
            return
        free = np.where(self.overflow < 0)[0]
        if free.size == 0:
            self.flags |= _g.OVERFLOW_SATURATED
            self.needs_rebuild = True
            return
        self.overflow[free[0]] = slot
        touched_ov.add(int(free[0]))
        # NEAR reads of this row still see its cell's exact members (if
        # the cell has a frozen bucket); the row itself is swept via the
        # overflow region, so its self kernel IS stored-exactly
        self.point_bucket[slot] = b
        self.self_stored[slot] = 1.0
        self.exact_parity = False

    def apply(self, state, slots, old_x, new_x, old_live, new_live):
        """Patch the mirrors for one coalesced mutation batch and return
        the updated device ``HashState`` (or ``state`` unchanged with
        :attr:`needs_rebuild` set when the overflow region saturates --
        the caller must compact before serving another query)."""
        slots = np.asarray(slots, np.int64)
        old_live = np.asarray(old_live, bool)
        new_live = np.asarray(new_live, bool)
        new_x = np.asarray(new_x, np.float32)
        touched_b: set = set()
        touched_ov: set = set()
        touched_p = [int(s) for s in slots]
        for i, s in enumerate(slots):
            s = int(s)
            if old_live[i]:
                self._remove(s, touched_b, touched_ov)
            if new_live[i]:
                self._insert(s, new_x[i], touched_b, touched_ov)
        if self.needs_rebuild:
            return state
        bidx = np.fromiter(sorted(touched_b), np.int32,
                           count=len(touched_b))
        ovidx = np.fromiter(sorted(touched_ov), np.int32,
                            count=len(touched_ov))
        pidx = np.asarray(touched_p, np.int32)
        members, counts, point_bucket, self_stored, overflow = \
            _apply_hash_patch(
                state.members, state.counts, state.point_bucket,
                state.self_stored, state.overflow,
                jnp.asarray(bidx), jnp.asarray(self.members[bidx]),
                jnp.asarray(self.counts[bidx]),
                jnp.asarray(pidx), jnp.asarray(self.point_bucket[pidx]),
                jnp.asarray(self.self_stored[pidx]),
                jnp.asarray(ovidx), jnp.asarray(self.overflow[ovidx]))
        return state._replace(members=members, counts=counts,
                              point_bucket=point_bucket,
                              self_stored=self_stored, overflow=overflow)
