"""Device-resident hashed-KDE engine: layout build + jitted programs.

``build_hash_state`` runs ONCE on the host: hash every dataset row with a
random-shifted grid (the KAP22/DEANN scheme of ``core.kde.hbe``), sort by
packed key, and freeze the buckets into the static padded layout of
``ref.HashState`` -- ``max_bucket`` slots per bucket, sentinel padding,
global row indices.  After that every query is ONE jitted device program:

* ``hashed_query``      -- (m,) NEAR-exact + HT-FAR row-sum estimates plus
  the realized NEAR eval counts; O(max_bucket + num_far) kernel evals per
  query instead of the dense backends' O(n) (Definition 1.1 / §3.1).
* ``hashed_block_sums`` -- (w, B) §2-contract level-1 block-sum estimates
  for a frontier of dataset indices (bucket membership is a dense
  ``point_bucket`` gather; the FAR term is a stratified per-block draw so
  no block is left at the floor); the ``level1="hash"`` read of the
  depth-2 sampler (DESIGN.md §10).

Both dispatch the weighted kernel-value pass to the Pallas bucket kernel
on the TPU path and run the ``ref.py`` oracle math elsewhere; interpret
mode matches the oracle bitwise.  ``TRACE_COUNTS`` is shared with
``kde_sampler.ops`` so the no-retrace tests cover these programs too.
"""
from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft import guards as _g
from repro.kernels.kde_hash import kernel as _k
from repro.kernels.kde_hash import ref as _ref
from repro.kernels.kde_sampler import ops as _sops
from repro.kernels.kde_sampler.ref import BLOCK_SUM_FLOOR, BUILTIN_KINDS

TRACE_COUNTS = _sops.TRACE_COUNTS

_STATIC = frozenset((
    "kind", "inv_bw", "beta", "pairwise", "cell_width", "num_far", "n",
    "block_size", "num_blocks", "use_pallas", "interpret", "bm"))


def _jit(fn):
    names = tuple(p for p in inspect.signature(fn).parameters if p in _STATIC)
    return jax.jit(fn, static_argnames=names)


def default_cell_width(kernel) -> float:
    """The ``GridHBE`` default: two bandwidths per grid cell, so NEAR
    buckets cover the region where Table-1 kernels carry most mass."""
    return 2.0 * float(kernel.bandwidth)


def draw_grid(rng, d: int, num_hash_dims: int, cell_width: float):
    """Draw the random-shifted grid (hash-dim subset + per-dim shift) with
    the exact ``GridHBE(seed=...)`` RNG call order -- the ONE place this
    discipline lives (``build_hash_state`` and the sharded table both call
    it, so equal seeds always mean the identical grid)."""
    dims = rng.choice(d, size=min(int(num_hash_dims), d),
                      replace=False).astype(np.int32)
    shift = rng.uniform(0.0, cell_width, size=len(dims)).astype(np.float32)
    return dims, shift


def grid_keys(xn: np.ndarray, dims, shift, cell_width: float) -> np.ndarray:
    """(k,) uint32 packed grid keys of rows ``xn`` (float32 shift/floor
    arithmetic bitwise-equal to the device-side ``ref.query_codes``)."""
    codes = np.floor((xn[:, dims] + shift) / cell_width).astype(np.int32)
    keys = np.zeros(len(xn), np.uint32)
    for j in range(codes.shape[1]):
        keys = keys * np.uint32(_ref.HASH_MULT) + codes[:, j].astype(np.uint32)
    return keys


def bucket_table(keys: np.ndarray, rows: np.ndarray, max_bucket: int, rng):
    """Freeze the buckets of one key slice into the padded layout:
    (sorted unique keys, (U, max_bucket) member table of GLOBAL row ids,
    stored counts, concatenated stored row ids, per-bucket truncation
    flags).  Oversized buckets store a seeded subsample; overflow members
    stay FAR-eligible -- the flags let queries report that truncation
    happened (``guards.BUCKET_OVERFLOW``)."""
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    uniq, counts_full = np.unique(sk, return_counts=True)
    starts = np.concatenate([[0], np.cumsum(counts_full)[:-1]])
    mb = int(max_bucket)
    members = np.zeros((max(len(uniq), 1), mb), np.int32)
    counts = np.zeros(max(len(uniq), 1), np.int32)
    counts[:len(uniq)] = np.minimum(counts_full, mb)
    truncated = np.zeros(max(len(uniq), 1), bool)
    truncated[:len(uniq)] = counts_full > mb
    stored = [np.zeros(0, np.int64)]
    for b in range(len(uniq)):
        seg = rows[order[starts[b]:starts[b] + counts_full[b]]]
        if counts_full[b] > mb:
            seg = rng.choice(seg, size=mb, replace=False)
        members[b, :len(seg)] = seg
        stored.append(seg)
    return uniq, members, counts, np.concatenate(stored), truncated


def build_hash_state(x, kernel, cell_width: float | None = None,
                     num_hash_dims: int = 8, max_bucket: int = 256,
                     seed: int = 0):
    """Host-side layout build (once per dataset): returns
    ``(HashState, cell_width)``.

    The RNG call order (hash-dim choice, then shift, then per-bucket
    overflow subsampling) matches ``GridHBE(seed=...)``, so a ``GridHBE``
    built with the same seed/width hashes with the identical grid --
    bucket membership agrees up to the packed-key width (uint32 here,
    uint64 there; a collision would merely merge two cells, and the
    HT-corrected estimator stays unbiased under ANY bucket assignment).
    Buckets larger than ``max_bucket`` store a seeded subsample; overflow
    members remain FAR-eligible.
    """
    xn = np.asarray(x, np.float32)
    n, d = xn.shape
    rng = np.random.default_rng(seed)
    w = float(cell_width if cell_width is not None
              else default_cell_width(kernel))
    dims, shift = draw_grid(rng, d, num_hash_dims, w)
    keys = grid_keys(xn, dims, shift, w)
    uniq, members, counts, stored_rows, truncated = bucket_table(
        keys, np.arange(n, dtype=np.int64), max_bucket, rng)
    stored = np.zeros(n, bool)
    stored[stored_rows] = True
    point_bucket = np.searchsorted(uniq, keys).astype(np.int32)
    state = _ref.HashState(
        dims=jnp.asarray(dims),
        shift=jnp.asarray(shift),
        keys=jnp.asarray(uniq),
        members=jnp.asarray(members),
        counts=jnp.asarray(counts),
        point_bucket=jnp.asarray(point_bucket),
        self_stored=jnp.asarray(stored.astype(np.float32)),
        truncated=jnp.asarray(truncated))
    return state, w


def _weighted_pass(q, xr, wgt, *, kind, inv_bw, beta, pairwise, use_pallas,
                   interpret, bm, reduce_sum):
    """One weighted kernel-value pass: Pallas bucket kernel on the TPU
    path (padded to a ``bm`` query multiple), the shared ``ref.rowwise_kv``
    math elsewhere -- bitwise-identical results in interpret mode."""
    if use_pallas and kind in BUILTIN_KINDS:
        m = q.shape[0]
        rem = (-m) % bm
        if rem:
            q = jnp.pad(q, ((0, rem), (0, 0)))
            wgt = jnp.pad(wgt, ((0, rem), (0, 0)))
            xr = jnp.pad(xr, ((0, rem), (0, 0), (0, 0)))
        fn = (_k.weighted_kv_sum_pallas if reduce_sum
              else _k.weighted_kv_pallas)
        return fn(q, wgt, xr, kind, inv_bw, beta, bm=bm,
                  interpret=interpret)[:m]
    kv = _ref.rowwise_kv(q, xr, kind, inv_bw, beta, pairwise) * wgt
    return jnp.sum(kv, axis=1) if reduce_sum else kv


@_jit
def hashed_query(x, y, state, key, *, kind, inv_bw, beta, pairwise,
                 cell_width, num_far, n, use_pallas=False, interpret=False,
                 bm=32):
    """(m,) row-sum estimates + (m,) realized NEAR eval counts + a status
    bitmask -- the Definition 1.1 read at O(max_bucket + num_far) evals
    per query.  The status flags bucket truncation, out-of-range member
    indices (JAX gathers clamp, so corruption is otherwise silent), and a
    Horvitz-Thompson FAR sample dominating the estimate (on the jnp path
    per element against ``REPRO_HT_FRAC``; the Pallas kernel only sees the
    reduced sum, so there the static weight ``n/num_far`` is checked
    against ``REPRO_HT_BOUND``)."""
    TRACE_COUNTS["hashed_query"] += 1
    cols, xr, wgt, cnt, trunc = _ref.query_gather(x, y, state, key,
                                                  cell_width, num_far, n)
    corrupt = jnp.any((cols < 0) | (cols >= n))
    if use_pallas and kind in BUILTIN_KINDS:
        est = _weighted_pass(y, xr, wgt, kind=kind, inv_bw=inv_bw, beta=beta,
                             pairwise=pairwise, use_pallas=use_pallas,
                             interpret=interpret, bm=bm, reduce_sum=True)
        heavy = jnp.asarray(num_far > 0
                            and float(n) / num_far > _g.ht_bound())
    else:
        kv = _weighted_pass(y, xr, wgt, kind=kind, inv_bw=inv_bw, beta=beta,
                            pairwise=pairwise, use_pallas=use_pallas,
                            interpret=interpret, bm=bm, reduce_sum=False)
        est = jnp.sum(kv, axis=1)
        mb = state.members.shape[1]
        far = kv[:, mb:]
        heavy = (jnp.any(far > _g.ht_frac()
                         * jnp.maximum(jnp.abs(est)[:, None], 1e-30))
                 if num_far > 0 else jnp.asarray(False))
    st = _g.merge(_g.flag_if(corrupt, _g.STATE_CORRUPT),
                  _g.flag_if(jnp.any(trunc), _g.BUCKET_OVERFLOW),
                  _g.flag_if(heavy, _g.HT_HEAVY),
                  _g.result_status(est))
    return est, cnt, st


def _hashed_block_sums(x, src, state, key, *, kind, inv_bw, beta, pairwise,
                       num_far, block_size, num_blocks, n, use_pallas,
                       interpret, bm):
    """Traceable core of ``hashed_block_sums`` (called from inside the
    fused sampler programs of ``kde_sampler.ops``).  Returns
    ``(block sums, status)``."""
    q = x[src]
    cols, xr, wgt, _, trunc = _ref.frontier_gather(x, src, state, key,
                                                   num_far, block_size,
                                                   num_blocks, n)
    kv = _weighted_pass(q, xr, wgt, kind=kind, inv_bw=inv_bw, beta=beta,
                        pairwise=pairwise, use_pallas=use_pallas,
                        interpret=interpret, bm=bm, reduce_sum=False)
    bs = _ref.scatter_block_sums(kv, cols, src, state, num_far,
                                 block_size, num_blocks)
    st = _g.merge(_g.flag_if(jnp.any((cols < 0) | (cols >= n)),
                             _g.STATE_CORRUPT),
                  _g.flag_if(jnp.any(trunc), _g.BUCKET_OVERFLOW),
                  _g.sums_status(bs, BLOCK_SUM_FLOOR))
    return bs, st


@_jit
def hashed_block_sums(x, src, state, key, *, kind, inv_bw, beta, pairwise,
                      num_far, block_size, num_blocks, n, use_pallas=False,
                      interpret=False, bm=32):
    """(w, B) §2-contract level-1 estimates of a dataset frontier from
    O(max_bucket + B num_far) evals per row: exact NEAR scatter +
    ``num_far`` stratified FAR slots per block (the ``level1="hash"``
    read; DESIGN.md §10).  Returns ``(block sums, status bitmask)``."""
    TRACE_COUNTS["hashed_block_sums"] += 1
    return _hashed_block_sums(x, src, state, key, kind=kind, inv_bw=inv_bw,
                              beta=beta, pairwise=pairwise, num_far=num_far,
                              block_size=block_size, num_blocks=num_blocks,
                              n=n, use_pallas=use_pallas, interpret=interpret,
                              bm=bm)
