"""Mesh-resident hashed-KDE table (DESIGN.md §10, sharded schedule).

Each shard owns a contiguous run of dataset rows (the §9 layout: ``n``
rows padded to ``P * shard_size`` with far-offset sentinel rows) and
hashes ITS OWN rows into a local bucket table under the one global
(dims, shift) grid -- a global grid cell's members are partitioned across
shards, so the union of local NEAR sets is exactly the flat engine's NEAR
set.  One query batch is:

1. every shard hashes the replicated queries, looks the keys up in its
   LOCAL sorted table, and evaluates its NEAR members exactly
   (``O(max_bucket)`` rows) -- no collective;
2. every shard draws ``num_far`` uniforms over its OWN ``shard_size`` row
   slots (``fold_in(key, p)`` discipline; sentinel rows have kernel value
   exactly 0) and applies the local HT weight ``shard_size/num_far`` --
   no collective;
3. ONE ``psum`` of the (estimate partial, NEAR-count partial) pair makes
   the Definition 1.1 estimates replicated.

Exactly one psum and zero ppermute per query batch (asserted via
``kde_sampler.sharded.collective_counts``); no dataset row ever moves
between shards.  Oracle: ``ref.sharded_hashed_query_ref`` (identical
key discipline; ints bitwise, floats to f32 tolerance).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.ft import guards as _g
from repro.kernels.kde_hash import ops as _ops
from repro.kernels.kde_hash import ref as _ref
from repro.kernels.kde_rowsum.ops import _PAD_OFFSET
from repro.kernels.kde_sampler.ref import static_pairwise
from repro.kernels.kde_sampler.sharded import _flat_index

TRACE_COUNTS = _ops.TRACE_COUNTS

_PROGRAM_CACHE: dict = {}

# Sorted-key padding: lookups of a real key can never land on a pad slot
# (pad counts are 0 anyway, so even the astronomically unlikely real
# 0xFFFFFFFF key only ever reads an empty bucket).
_PAD_KEY = np.uint32(0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class _TableSpec:
    """Static configuration of a sharded hash table -- the only thing the
    cached program closures capture (never device arrays)."""

    mesh: Mesh
    axes: tuple
    num_shards: int
    n: int
    shard_size: int
    num_far: int
    cell_width: float
    kind: str
    inv_bw: float
    beta: float
    pairwise: object


class ShardedHashTable:
    """Per-shard bucket tables + the one-psum collective query program.

    Construction hashes each shard's rows on the host (same grid as the
    flat ``ops.build_hash_state``) and places the stacked ``(P, U, mb)``
    tables sharded over the mesh; ``query`` is a jitted ``shard_map``
    program cached at module level by static config (Section 3.1 query
    semantics, one psum per batch).
    """

    def __init__(self, mesh: Mesh, x, kernel, *, cell_width: float | None
                 = None, num_hash_dims: int = 8, max_bucket: int = 256,
                 num_far_samples: int = 64,
                 data_axes: Sequence[str] = ("data",), seed: int = 0):
        axes = tuple(data_axes)
        num_shards = 1
        for a in axes:
            num_shards *= int(mesh.shape[a])
        xn = np.asarray(x, np.float32)
        n, d = xn.shape
        shard_size = -(-n // num_shards)
        rng = np.random.default_rng(seed)
        w = float(cell_width if cell_width is not None
                  else _ops.default_cell_width(kernel))
        dims, shift = _ops.draw_grid(rng, d, num_hash_dims, w)
        keys = _ops.grid_keys(xn, dims, shift, w)
        mb = int(max_bucket)
        per_shard = []
        any_trunc = False
        for p in range(num_shards):
            lo, hi = p * shard_size, min((p + 1) * shard_size, n)
            uniq, members, counts, _, trunc = _ops.bucket_table(
                keys[lo:hi], np.arange(lo, hi, dtype=np.int64), mb, rng)
            any_trunc = any_trunc or bool(trunc.any())
            per_shard.append((uniq, members, counts))
        u_pad = max(max(len(s[0]) for s in per_shard), 1)
        keys_s = np.full((num_shards, u_pad), _PAD_KEY, np.uint32)
        members_s = np.zeros((num_shards, u_pad, mb), np.int32)
        counts_s = np.zeros((num_shards, u_pad), np.int32)
        states = []
        for p, (uniq, members, counts) in enumerate(per_shard):
            keys_s[p, :len(uniq)] = uniq
            members_s[p, :len(uniq)] = members[:len(uniq)]
            counts_s[p, :len(uniq)] = counts
            states.append(_ref.HashState(
                dims=jnp.asarray(dims), shift=jnp.asarray(shift),
                keys=jnp.asarray(keys_s[p]),
                members=jnp.asarray(members_s[p]),
                counts=jnp.asarray(counts_s[p]),
                point_bucket=None, self_stored=None))
        # single-device twins of the per-shard tables, for the ref oracle
        self.shard_states = states
        self.spec = _TableSpec(
            mesh=mesh, axes=axes, num_shards=num_shards, n=n,
            shard_size=shard_size, num_far=int(num_far_samples),
            cell_width=w, kind=kernel.name,
            inv_bw=1.0 / kernel.bandwidth,
            beta=float(getattr(kernel, "beta", 1.0)),
            pairwise=static_pairwise(kernel))
        self.n = n
        self.d = d
        self.num_shards = num_shards
        self.shard_size = shard_size
        self.max_bucket = mb
        self.num_far = int(num_far_samples)
        # Table-level overflow bit, frozen at build time: shard-local
        # per-query truncation hits would need a second collective to
        # replicate, so the sharded path reports the coarser "some bucket
        # somewhere was truncated" flag instead (one-psum budget intact).
        self._truncated = any_trunc
        n_pad = num_shards * shard_size
        pad = n_pad - n
        if pad:
            sent = jnp.full((pad, d), _PAD_OFFSET, jnp.float32) \
                + jnp.asarray(xn[-1:])
            xp = jnp.concatenate([jnp.asarray(xn), sent], axis=0)
        else:
            xp = jnp.asarray(xn)
        # every gather in the query program is shard-local (members and
        # FAR draws only ever touch the executing shard's own rows), so
        # the dataset lives sharded -- O(n d / P) per device; the
        # unplaced twin is kept for the ref oracle only.
        self.x_pad = xp
        sh = NamedSharding(mesh, P(axes))
        self.x_sh = jax.device_put(xp, sh)
        self._keys = jax.device_put(jnp.asarray(keys_s), sh)
        self._members = jax.device_put(jnp.asarray(members_s), sh)
        self._counts = jax.device_put(jnp.asarray(counts_s), sh)
        self._dims = jax.device_put(jnp.asarray(dims),
                                    NamedSharding(mesh, P()))
        self._shift = jax.device_put(jnp.asarray(shift),
                                     NamedSharding(mesh, P()))

    def _program(self):
        sp = self.spec
        if sp not in _PROGRAM_CACHE:
            mesh, axes = sp.mesh, sp.axes

            def body(keys_l, members_l, counts_l, dims, shift, x_l, y,
                     key):
                pidx = _flat_index(mesh, axes)
                keys_l, members_l, counts_l = (keys_l[0], members_l[0],
                                               counts_l[0])
                qkey = _ref.pack_codes(
                    _ref.query_codes(y, dims, shift, sp.cell_width))
                b = jnp.clip(jnp.searchsorted(keys_l, qkey), 0,
                             keys_l.shape[0] - 1).astype(jnp.int32)
                hit = keys_l[b] == qkey
                cnt = jnp.where(hit, counts_l[b], 0)
                mem = members_l[b]
                mb = mem.shape[1]
                mvalid = (jnp.arange(mb, dtype=jnp.int32)[None, :]
                          < cnt[:, None])
                if sp.num_far == 0:        # static: NEAR-only estimate
                    cols, wgt = mem, mvalid.astype(jnp.float32)
                else:
                    kk = jax.random.fold_in(key, pidx)
                    fidx = pidx * sp.shard_size + jax.random.randint(
                        kk, (y.shape[0], sp.num_far), 0, sp.shard_size)
                    collide = _ref._far_collide(fidx, mem, mvalid)
                    cols = jnp.concatenate([mem, fidx], axis=1)
                    wgt = jnp.concatenate(
                        [mvalid.astype(jnp.float32),
                         (float(sp.shard_size) / sp.num_far)
                         * (1.0 - collide.astype(jnp.float32))], axis=1)
                # all referenced rows are the shard's own: gather from the
                # LOCAL slice (member-pad slots point at global row 0 --
                # clamped here and masked by their 0 weight)
                cols_l = jnp.clip(cols - pidx * sp.shard_size, 0,
                                  sp.shard_size - 1)
                kv = _ref.rowwise_kv(y, x_l[cols_l], sp.kind, sp.inv_bw,
                                     sp.beta, sp.pairwise)
                part = jnp.sum(kv * wgt, axis=1)
                return jax.lax.psum((part, cnt), axes)

            def outer(*args):
                TRACE_COUNTS["sharded_hashed_query"] += 1
                return shard_map(body, mesh=mesh,
                                 in_specs=(P(axes), P(axes), P(axes), P(),
                                           P(), P(axes), P(), P()),
                                 out_specs=(P(), P()),
                                 check_vma=False)(*args)
            _PROGRAM_CACHE[sp] = jax.jit(outer)
        return _PROGRAM_CACHE[sp]

    def query(self, y, key):
        """(m,) replicated row-sum estimates + (m,) NEAR eval counts + a
        status bitmask: local NEAR lookup + local FAR partials, then
        exactly ONE psum (Definition 1.1 over the sharded hashed table).
        The status is computed from replicated/static values only --
        build-time bucket overflow, the static per-shard HT weight bound,
        and non-finite estimates -- so the collective schedule is
        untouched."""
        est, cnt = self._program()(
            self._keys, self._members, self._counts, self._dims,
            self._shift, self.x_sh, jnp.asarray(y, jnp.float32), key)
        sp = self.spec
        heavy = (sp.num_far > 0
                 and float(sp.shard_size) / sp.num_far > _g.ht_bound())
        st = _g.merge(
            _g.flag_if(jnp.asarray(self._truncated), _g.BUCKET_OVERFLOW),
            _g.flag_if(jnp.asarray(heavy), _g.HT_HEAVY),
            _g.result_status(est))
        return est, cnt, st
