"""Mesh-resident hashed-KDE table (DESIGN.md §10, sharded schedule).

Each shard owns a contiguous run of dataset rows (the §9 layout: ``n``
rows padded to ``P * shard_size`` with far-offset sentinel rows) and
hashes ITS OWN rows into a local bucket table under the one global
(dims, shift) grid -- a global grid cell's members are partitioned across
shards, so the union of local NEAR sets is exactly the flat engine's NEAR
set.  One query batch is:

1. every shard hashes the replicated queries, looks the keys up in its
   LOCAL sorted table, and evaluates its NEAR members exactly
   (``O(max_bucket)`` rows) -- no collective;
2. every shard draws ``num_far`` uniforms over its OWN ``shard_size`` row
   slots (``fold_in(key, p)`` discipline; sentinel rows have kernel value
   exactly 0) and applies the local HT weight ``shard_size/num_far`` --
   no collective;
3. ONE ``psum`` of the (estimate partial, NEAR-count partial) pair makes
   the Definition 1.1 estimates replicated.

Exactly one psum and zero ppermute per query batch (asserted via
``kde_sampler.sharded.collective_counts``); no dataset row ever moves
between shards.  Oracle: ``ref.sharded_hashed_query_ref`` (identical
key discipline; ints bitwise, floats to f32 tolerance).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.ft import guards as _g
from repro.kernels.kde_hash import ops as _ops
from repro.kernels.kde_hash import ref as _ref
from repro.kernels.kde_rowsum.ops import _PAD_OFFSET
from repro.kernels.kde_sampler.ref import static_pairwise
from repro.kernels.kde_sampler.sharded import _flat_index
from repro.obs import counters as _c

TRACE_COUNTS = _ops.TRACE_COUNTS

_PROGRAM_CACHE: dict = {}

# Sorted-key padding: lookups of a real key can never land on a pad slot
# (pad counts are 0 anyway, so even the astronomically unlikely real
# 0xFFFFFFFF key only ever reads an empty bucket).
_PAD_KEY = np.uint32(0xFFFFFFFF)


def _pad_pow2(a: np.ndarray, fill) -> np.ndarray:
    """Pad a write list's leading dim to the next power of two with no-op
    entries (owner -1 / out-of-range slot), so mutation-batch program
    shapes come from a tiny static set and patches rarely retrace."""
    k = max(int(a.shape[0]), 1)
    target = 1 << (k - 1).bit_length()
    if a.shape[0] == target:
        return a
    pad_shape = (target - a.shape[0],) + a.shape[1:]
    return np.concatenate([a, np.full(pad_shape, fill, a.dtype)], axis=0)


@dataclasses.dataclass(frozen=True)
class _TableSpec:
    """Static configuration of a sharded hash table -- the only thing the
    cached program closures capture (never device arrays)."""

    mesh: Mesh
    axes: tuple
    num_shards: int
    n: int
    shard_size: int
    num_far: int
    cell_width: float
    kind: str
    inv_bw: float
    beta: float
    pairwise: object
    ov_cap: int = 0      # per-shard streaming overflow slots (0 = static)


class ShardedHashTable:
    """Per-shard bucket tables + the one-psum collective query program.

    Construction hashes each shard's rows on the host (same grid as the
    flat ``ops.build_hash_state``) and places the stacked ``(P, U, mb)``
    tables sharded over the mesh; ``query`` is a jitted ``shard_map``
    program cached at module level by static config (Section 3.1 query
    semantics, one psum per batch).
    """

    def __init__(self, mesh: Mesh, x, kernel, *, cell_width: float | None
                 = None, num_hash_dims: int = 8, max_bucket: int = 256,
                 num_far_samples: int = 64,
                 data_axes: Sequence[str] = ("data",), seed: int = 0,
                 live=None, overflow_cap: int = 0):
        axes = tuple(data_axes)
        num_shards = 1
        for a in axes:
            num_shards *= int(mesh.shape[a])
        xn = np.asarray(x, np.float32)
        n, d = xn.shape
        shard_size = -(-n // num_shards)
        rng = np.random.default_rng(seed)
        w = float(cell_width if cell_width is not None
                  else _ops.default_cell_width(kernel))
        dims, shift = _ops.draw_grid(rng, d, num_hash_dims, w)
        mb = int(max_bucket)
        live_h = None if live is None else np.asarray(live, bool)
        per_shard = []
        any_trunc = False
        for p in range(num_shards):
            lo, hi = p * shard_size, min((p + 1) * shard_size, n)
            if live_h is None:
                rows = np.arange(lo, hi, dtype=np.int64)
            else:               # streaming: only hash the LIVE local rows
                rows = lo + np.where(live_h[lo:hi])[0].astype(np.int64)
            uniq, members, counts, _, trunc = _ops.bucket_table(
                _ops.grid_keys(xn[rows], dims, shift, w), rows, mb, rng)
            any_trunc = any_trunc or bool(trunc.any())
            per_shard.append((uniq, members, counts, trunc))
        ov_cap = int(overflow_cap)
        u_pad = max(max(len(s[0]) for s in per_shard), 1)
        keys_s = np.full((num_shards, u_pad), _PAD_KEY, np.uint32)
        members_s = np.zeros((num_shards, u_pad, mb), np.int32)
        counts_s = np.zeros((num_shards, u_pad), np.int32)
        trunc_s = np.zeros((num_shards, u_pad), bool)
        overflow_s = np.full((num_shards, max(ov_cap, 1)), -1, np.int32)
        states = []
        for p, (uniq, members, counts, trunc) in enumerate(per_shard):
            keys_s[p, :len(uniq)] = uniq
            members_s[p, :len(uniq)] = members[:len(uniq)]
            counts_s[p, :len(uniq)] = counts
            trunc_s[p, :len(uniq)] = trunc[:len(uniq)]
            states.append(_ref.HashState(
                dims=jnp.asarray(dims), shift=jnp.asarray(shift),
                keys=jnp.asarray(keys_s[p]),
                members=jnp.asarray(members_s[p]),
                counts=jnp.asarray(counts_s[p]),
                point_bucket=None, self_stored=None,
                truncated=jnp.asarray(trunc_s[p]),
                overflow=(jnp.asarray(overflow_s[p])
                          if ov_cap else None)))
        # single-device twins of the per-shard tables, for the ref oracle
        self.shard_states = states
        # host mirrors, patched in place by ``patch_rows`` (DESIGN.md §12)
        self._keys_h = keys_s
        self._members_h = members_s
        self._counts_h = counts_s
        self._trunc_h = trunc_s
        self._overflow_h = overflow_s
        self._dims_h = dims
        self._shift_h = shift
        self.flags = 0
        self.needs_rebuild = False
        self.exact_parity = True
        self.spec = _TableSpec(
            mesh=mesh, axes=axes, num_shards=num_shards, n=n,
            shard_size=shard_size, num_far=int(num_far_samples),
            cell_width=w, kind=kernel.name,
            inv_bw=1.0 / kernel.bandwidth,
            beta=float(getattr(kernel, "beta", 1.0)),
            pairwise=static_pairwise(kernel), ov_cap=ov_cap)
        self.n = n
        self.d = d
        self.num_shards = num_shards
        self.shard_size = shard_size
        self.max_bucket = mb
        self.num_far = int(num_far_samples)
        # Table-level overflow bit, frozen at build time: shard-local
        # per-query truncation hits would need a second collective to
        # replicate, so the sharded path reports the coarser "some bucket
        # somewhere was truncated" flag instead (one-psum budget intact).
        self._truncated = any_trunc
        n_pad = num_shards * shard_size
        pad = n_pad - n
        if pad:
            sent = jnp.full((pad, d), _PAD_OFFSET, jnp.float32) \
                + jnp.asarray(xn[-1:])
            xp = jnp.concatenate([jnp.asarray(xn), sent], axis=0)
        else:
            xp = jnp.asarray(xn)
        # every gather in the query program is shard-local (members and
        # FAR draws only ever touch the executing shard's own rows), so
        # the dataset lives sharded -- O(n d / P) per device; the
        # unplaced twin is kept for the ref oracle only.
        self.x_pad = xp
        sh = NamedSharding(mesh, P(axes))
        self.x_sh = jax.device_put(xp, sh)
        self._keys = jax.device_put(jnp.asarray(keys_s), sh)
        self._members = jax.device_put(jnp.asarray(members_s), sh)
        self._counts = jax.device_put(jnp.asarray(counts_s), sh)
        self.overflow_cap = ov_cap
        # always shaped (P, max(ov_cap, 1)) so the program signature is
        # uniform; the static ``spec.ov_cap == 0`` branch never reads it
        self._overflow = jax.device_put(jnp.asarray(overflow_s), sh)
        self._dims = jax.device_put(jnp.asarray(dims),
                                    NamedSharding(mesh, P()))
        self._shift = jax.device_put(jnp.asarray(shift),
                                     NamedSharding(mesh, P()))

    def _program(self):
        sp = self.spec
        if sp not in _PROGRAM_CACHE:
            mesh, axes = sp.mesh, sp.axes

            def body(keys_l, members_l, counts_l, ov_l, dims, shift, x_l,
                     y, key):
                pidx = _flat_index(mesh, axes)
                keys_l, members_l, counts_l = (keys_l[0], members_l[0],
                                               counts_l[0])
                qkey = _ref.pack_codes(
                    _ref.query_codes(y, dims, shift, sp.cell_width))
                b = jnp.clip(jnp.searchsorted(keys_l, qkey), 0,
                             keys_l.shape[0] - 1).astype(jnp.int32)
                hit = keys_l[b] == qkey
                cnt = jnp.where(hit, counts_l[b], 0)
                mem = members_l[b]
                mb = mem.shape[1]
                m = y.shape[0]
                mvalid = (jnp.arange(mb, dtype=jnp.int32)[None, :]
                          < cnt[:, None])
                if sp.ov_cap:   # streaming: shard-local exact overflow sweep
                    ov = ov_l[0]
                    mem_cat = jnp.concatenate(
                        [mem, jnp.broadcast_to(
                            jnp.maximum(ov, 0)[None, :],
                            (m, sp.ov_cap))], axis=1)
                    wexact = jnp.concatenate(
                        [mvalid.astype(jnp.float32),
                         jnp.broadcast_to((ov >= 0)[None, :],
                                          (m, sp.ov_cap))
                         .astype(jnp.float32)], axis=1)
                else:
                    mem_cat = mem
                    wexact = mvalid.astype(jnp.float32)
                if sp.num_far == 0:        # static: NEAR-only estimate
                    cols, wgt = mem_cat, wexact
                else:
                    kk = jax.random.fold_in(key, pidx)
                    fidx = pidx * sp.shard_size + jax.random.randint(
                        kk, (m, sp.num_far), 0, sp.shard_size)
                    collide = _ref._far_collide(fidx, mem, mvalid)
                    if sp.ov_cap:
                        ov = ov_l[0]
                        collide = collide | jnp.any(
                            (fidx[:, :, None] == ov[None, None, :])
                            & (ov >= 0)[None, None, :], axis=-1)
                    cols = jnp.concatenate([mem_cat, fidx], axis=1)
                    wgt = jnp.concatenate(
                        [wexact,
                         (float(sp.shard_size) / sp.num_far)
                         * (1.0 - collide.astype(jnp.float32))], axis=1)
                # all referenced rows are the shard's own: gather from the
                # LOCAL slice (member-pad slots point at global row 0 --
                # clamped here and masked by their 0 weight)
                cols_l = jnp.clip(cols - pidx * sp.shard_size, 0,
                                  sp.shard_size - 1)
                kv = _ref.rowwise_kv(y, x_l[cols_l], sp.kind, sp.inv_bw,
                                     sp.beta, sp.pairwise)
                part = jnp.sum(kv * wgt, axis=1)
                return jax.lax.psum((part, cnt), axes)

            def outer(*args):
                TRACE_COUNTS["sharded_hashed_query"] += 1
                return shard_map(body, mesh=mesh,
                                 in_specs=(P(axes), P(axes), P(axes),
                                           P(axes), P(), P(), P(axes),
                                           P(), P()),
                                 out_specs=(P(), P()),
                                 check_vma=False)(*args)
            _PROGRAM_CACHE[sp] = jax.jit(outer)
        return _PROGRAM_CACHE[sp]

    def query(self, y, key):
        """(m,) replicated row-sum estimates + (m,) NEAR eval counts + a
        counter word: local NEAR lookup + local FAR partials, then
        exactly ONE psum (Definition 1.1 over the sharded hashed table;
        PSUMS slot = 1).  The word is assembled host-side from
        replicated/static values only -- build-time bucket overflow, the
        static per-shard HT weight bound, non-finite estimates, and the
        static per-shard gather width -- so the collective schedule is
        untouched."""
        est, cnt = self._program()(
            self._keys, self._members, self._counts, self._overflow,
            self._dims, self._shift, self.x_sh,
            jnp.asarray(y, jnp.float32), key)
        sp = self.spec
        heavy = (sp.num_far > 0
                 and float(sp.shard_size) / sp.num_far > _g.ht_bound())
        st = _g.merge(
            _g.flag_if(jnp.asarray(self._truncated), _g.BUCKET_OVERFLOW),
            _g.flag_if(jnp.asarray(heavy), _g.HT_HEAVY),
            _g.flag_if(jnp.asarray(bool(self.flags
                                        & _g.OVERFLOW_SATURATED)),
                       _g.OVERFLOW_SATURATED),
            _g.result_status(est))
        m = int(jnp.shape(y)[0])
        mb = int(self._members.shape[-1])
        per_row = sp.num_shards * (mb + sp.ov_cap + sp.num_far)
        cw = _c.fold_status(
            _c.word(evals=m * per_row, l1_reads=m,
                    far_samples=m * sp.num_shards * sp.num_far,
                    overflow=m * sp.num_shards * sp.ov_cap, psums=1), st)
        return est, cnt, cw

    # ------------------------------------------------------------------ #
    # streaming patches (DESIGN.md §12)
    # ------------------------------------------------------------------ #
    def _patch_program(self):
        """The jitted zero-collective mutation program: every shard applies
        only the bucket / overflow / row writes it owns (``mode='drop'``
        discards the rest), so a mutation batch adds NO collective to the
        one-psum-per-query schedule -- jaxpr-assertable via
        ``kde_sampler.sharded.collective_counts``."""
        sp = self.spec
        full = (sp, "patch")
        if full not in _PROGRAM_CACHE:
            mesh, axes = sp.mesh, sp.axes

            def body(members_l, counts_l, ov_l, x_l, bp, bu, brow, bcnt,
                     ovp, ovpos, ovval, slots, rows):
                pidx = _flat_index(mesh, axes)
                u_cap = members_l.shape[1]
                ul = jnp.where(bp == pidx, bu, u_cap)
                members_l = members_l.at[0, ul].set(brow, mode="drop")
                counts_l = counts_l.at[0, ul].set(bcnt, mode="drop")
                pl = jnp.where(ovp == pidx, ovpos, ov_l.shape[1])
                ov_l = ov_l.at[0, pl].set(ovval, mode="drop")
                lidx = slots - pidx * sp.shard_size
                lidx = jnp.where((lidx >= 0) & (lidx < sp.shard_size),
                                 lidx, sp.shard_size)
                x_l = x_l.at[lidx].set(rows, mode="drop")
                return members_l, counts_l, ov_l, x_l

            def outer(*args):
                TRACE_COUNTS["sharded_hash_patch"] += 1
                return shard_map(body, mesh=mesh,
                                 in_specs=(P(axes), P(axes), P(axes),
                                           P(axes)) + (P(),) * 9,
                                 out_specs=(P(axes),) * 4,
                                 check_vma=False)(*args)
            _PROGRAM_CACHE[full] = jax.jit(outer)
        return _PROGRAM_CACHE[full]

    def _lookup(self, p: int, row_x: np.ndarray):
        """(bucket pos, hit) of a coordinate row in shard ``p``'s frozen
        sorted key table."""
        key = _ops.grid_keys(row_x[None, :], self._dims_h, self._shift_h,
                             self.spec.cell_width)[0]
        u = int(np.searchsorted(self._keys_h[p], key))
        u = min(u, self._keys_h.shape[1] - 1)
        return u, bool(self._keys_h[p, u] == key)

    def _remove_host(self, p: int, slot: int, row_x, touched_b, touched_ov,
                     undo_b, undo_ov) -> None:
        u, hit = self._lookup(p, row_x)
        if hit:
            cnt = int(self._counts_h[p, u])
            row = self._members_h[p, u]
            pos = np.where(row[:cnt] == slot)[0]
            if pos.size:
                if (p, u) not in undo_b:
                    undo_b[(p, u)] = (row.copy(), cnt)
                at = int(pos[0])
                row[at:cnt - 1] = row[at + 1:cnt]
                row[cnt - 1] = 0
                self._counts_h[p, u] = cnt - 1
                touched_b.add((p, u))
                if self._trunc_h[p, u]:
                    self.exact_parity = False
                return
        pos = np.where(self._overflow_h[p] == slot)[0]
        if pos.size:
            at = int(pos[0])
            if (p, at) not in undo_ov:
                undo_ov[(p, at)] = int(self._overflow_h[p, at])
            self._overflow_h[p, at] = -1
            touched_ov.add((p, at))
            return
        # unstored member of a truncated bucket (or a never-hashed row):
        # nothing to remove, but a rebuild would resample -- record it
        self.exact_parity = False

    def _insert_host(self, p: int, slot: int, row_x, touched_b, touched_ov,
                     undo_b, undo_ov) -> bool:
        u, hit = self._lookup(p, row_x)
        if hit and int(self._counts_h[p, u]) < self.max_bucket \
                and not self._trunc_h[p, u]:
            cnt = int(self._counts_h[p, u])
            row = self._members_h[p, u]
            if (p, u) not in undo_b:
                undo_b[(p, u)] = (row.copy(), cnt)
            at = int(np.searchsorted(row[:cnt], slot))
            row[at + 1:cnt + 1] = row[at:cnt]
            row[at] = slot
            self._counts_h[p, u] = cnt + 1
            touched_b.add((p, u))
            return True
        free = np.where(self._overflow_h[p] < 0)[0]
        if free.size == 0:
            return False                        # shard overflow saturated
        at = int(free[0])
        if (p, at) not in undo_ov:
            undo_ov[(p, at)] = int(self._overflow_h[p, at])
        self._overflow_h[p, at] = slot
        touched_ov.add((p, at))
        self.exact_parity = False
        return True

    def patch_rows(self, slots, old_x, new_x, old_live, new_live) -> bool:
        """Apply one COALESCED mutation batch (``dataset.coalesce_mutations``
        output: first-touch old, last-touch new per slot) to the sharded
        table: the flat :class:`ops.HashPatcher` placement policy per
        shard -- splice into the owning shard's frozen bucket when it has
        room, else that shard's overflow region -- followed by ONE
        zero-collective device scatter of the touched bucket rows,
        overflow slots, and dataset rows.  Mutations never cross shards
        (a slot's owner is ``slot // shard_size``), so query gathers stay
        shard-local.  Returns ``False`` (mirrors restored, device state
        untouched, ``needs_rebuild`` set, ``OVERFLOW_SATURATED`` flagged)
        when any shard's overflow region is full -- the owner must
        rebuild before the next batch."""
        if self.spec.ov_cap == 0:
            raise ValueError("patch_rows needs a table built with "
                             "overflow_cap > 0")
        sp = self.spec
        slots = np.asarray(slots, np.int64)
        old_x = np.asarray(old_x, np.float32)
        new_x = np.asarray(new_x, np.float32)
        old_live = np.asarray(old_live, bool)
        new_live = np.asarray(new_live, bool)
        touched_b: set = set()
        touched_ov: set = set()
        undo_b: dict = {}
        undo_ov: dict = {}
        saturated = False
        for i, s in enumerate(slots):
            s = int(s)
            p = s // sp.shard_size
            if old_live[i]:
                self._remove_host(p, s, old_x[i], touched_b, touched_ov,
                                  undo_b, undo_ov)
            if new_live[i]:
                if not self._insert_host(p, s, new_x[i], touched_b,
                                         touched_ov, undo_b, undo_ov):
                    saturated = True
                    break
        if saturated:
            for (p, u), (row, cnt) in undo_b.items():
                self._members_h[p, u] = row
                self._counts_h[p, u] = cnt
            for (p, at), val in undo_ov.items():
                self._overflow_h[p, at] = val
            self.flags |= _g.OVERFLOW_SATURATED
            self.needs_rebuild = True
            return False
        bw = sorted(touched_b)
        ow = sorted(touched_ov)
        bp = _pad_pow2(np.asarray([b[0] for b in bw], np.int32), -1)
        bu = _pad_pow2(np.asarray([b[1] for b in bw], np.int32), 0)
        brow = _pad_pow2(
            np.asarray([self._members_h[b] for b in bw],
                       np.int32).reshape(-1, self.max_bucket), 0)
        bcnt = _pad_pow2(np.asarray([self._counts_h[b] for b in bw],
                                    np.int32), 0)
        ovp = _pad_pow2(np.asarray([o[0] for o in ow], np.int32), -1)
        ovpos = _pad_pow2(np.asarray([o[1] for o in ow], np.int32), 0)
        ovval = _pad_pow2(np.asarray([self._overflow_h[o] for o in ow],
                                     np.int32), 0)
        n_pad = sp.num_shards * sp.shard_size
        wslots = _pad_pow2(slots.astype(np.int32), n_pad)
        wrows = _pad_pow2(new_x, 0.0)
        self._members, self._counts, self._overflow, self.x_sh = \
            self._patch_program()(
                self._members, self._counts, self._overflow, self.x_sh,
                jnp.asarray(bp), jnp.asarray(bu), jnp.asarray(brow),
                jnp.asarray(bcnt), jnp.asarray(ovp), jnp.asarray(ovpos),
                jnp.asarray(ovval), jnp.asarray(wslots),
                jnp.asarray(wrows))
        self.x_pad = self.x_pad.at[jnp.asarray(slots.astype(np.int32))] \
            .set(jnp.asarray(new_x))
        for p in sorted({b[0] for b in bw} | {o[0] for o in ow}):
            self.shard_states[p] = self.shard_states[p]._replace(
                members=jnp.asarray(self._members_h[p]),
                counts=jnp.asarray(self._counts_h[p]),
                overflow=jnp.asarray(self._overflow_h[p]))
        return True

    @property
    def overflow_fill(self) -> int:
        """Occupied overflow slots across all shards (compaction policy)."""
        return int((self._overflow_h >= 0).sum())
