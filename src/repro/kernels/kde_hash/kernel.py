"""Pallas TPU kernel: weighted bucket-gather kernel evaluation.

The hashed estimator's hot loop is "evaluate k(q_i, x_j) over each query's
gathered (bucket member + FAR sample) rows and reduce with per-slot HT
weights".  The gather itself is an XLA gather (dense (w, t, d) member
coordinates); this kernel fuses the kernel-value math and the weighted
reduction over one query tile, keeping the (bm, t, d) gathered rows in
VMEM for a single pass.

Two entry points over the same body:

* ``weighted_kv_sum_pallas`` -- (m,) weighted row sums: the Definition 1.1
  query estimate (NEAR + HT-FAR in one reduction).
* ``weighted_kv_pallas``     -- (m, t) weighted kernel values: consumed by
  the hashed level-1 block-sum scatter (DESIGN.md §10).

The kernel-value math is ``ref.rowwise_kv`` itself (a static d-loop on the
VPU -- per-query-row buckets have no matmul form), so interpret-mode runs
reproduce the jnp oracle bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.kde_hash import ref as _ref
from repro.kernels.kde_rowsum.kernel import (exp_table_operand,
                                             exp_table_spec, needs_exp_table)


def _weighted_kv_kernel(q_ref, w_ref, xr_ref, *rest, kind, inv_bw, beta,
                        reduce_sum, precision, has_table):
    if has_table:
        t_ref, o_ref = rest
        table = t_ref[...]
    else:
        (o_ref,) = rest
        table = None
    kv = _ref.rowwise_kv(q_ref[...], xr_ref[...], kind, inv_bw, beta,
                         precision=precision, table=table)
    kv = kv * w_ref[...]
    if reduce_sum:
        o_ref[...] = jnp.sum(kv, axis=1)
    else:
        o_ref[...] = kv


def _call(q, wgt, xr, kind, inv_bw, beta, bm, interpret, reduce_sum,
          precision="f32"):
    m, d = q.shape
    t = xr.shape[1]
    has_table = needs_exp_table(kind, precision)
    body = functools.partial(_weighted_kv_kernel, kind=kind, inv_bw=inv_bw,
                             beta=beta, reduce_sum=reduce_sum,
                             precision=precision, has_table=has_table)
    if reduce_sum:
        out_specs = pl.BlockSpec((bm,), lambda i: (i,))
        out_shape = jax.ShapeDtypeStruct((m,), jnp.float32)
    else:
        out_specs = pl.BlockSpec((bm, t), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((m, t), jnp.float32)
    in_specs = [pl.BlockSpec((bm, d), lambda i: (i, 0)),
                pl.BlockSpec((bm, t), lambda i: (i, 0)),
                pl.BlockSpec((bm, t, d), lambda i: (i, 0, 0))]
    operands = [q, wgt, xr]
    if has_table:
        in_specs.append(exp_table_spec(lambda i: (0,)))
        operands.append(exp_table_operand())
    return pl.pallas_call(
        body,
        grid=(m // bm,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        # one output tile per query tile, no cross-step state: the single
        # grid axis pipelines with double-buffered gather-row copies
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*operands)


def weighted_kv_sum_pallas(q: jnp.ndarray, wgt: jnp.ndarray, xr: jnp.ndarray,
                           kind: str, inv_bw: float, beta: float = 1.0,
                           bm: int = 32, interpret: bool = False,
                           precision: str = "f32"):
    """q (m, d), wgt (m, t), xr (m, t, d) -> (m,) weighted kernel-value
    sums ``sum_j wgt_ij k(q_i, xr_ij)``; m must be a multiple of bm."""
    return _call(q, wgt, xr, kind, inv_bw, beta, bm, interpret,
                 reduce_sum=True, precision=precision)


def weighted_kv_pallas(q: jnp.ndarray, wgt: jnp.ndarray, xr: jnp.ndarray,
                       kind: str, inv_bw: float, beta: float = 1.0,
                       bm: int = 32, interpret: bool = False,
                       precision: str = "f32"):
    """q (m, d), wgt (m, t), xr (m, t, d) -> (m, t) weighted kernel values
    (the level-1 scatter input); m must be a multiple of bm."""
    return _call(q, wgt, xr, kind, inv_bw, beta, bm, interpret,
                 reduce_sum=False, precision=precision)
