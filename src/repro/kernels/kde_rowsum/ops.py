"""jit'd public wrappers for the kde_rowsum Pallas kernel.

Handles padding to block multiples: padded x rows are placed at +PAD_OFFSET
in every coordinate, which drives the squared distance to f32 ``inf`` and
therefore every supported kernel to exactly 0 -- including heavy-tailed
rational quadratic with small beta, where a merely-large finite distance
would leave a non-negligible value.  No masking is needed inside the kernel.

Tile sizes: the f32 default keeps the legacy (bm, bn) layout so results stay
bitwise stable across releases; under ``precision="bf16"`` unset tiles are
resolved by ``kernels.tuning.pallas_tiles`` (halved operand bytes let the
tuner widen the x tile for more reuse per HBM byte).  Tuned sizes are pure
functions of static shapes, so they land in the same jit program cache keys
as the rest of the static config.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.kernels_fn import Kernel
from repro.kernels import tuning as _tuning
from repro.kernels.kde_rowsum import kernel as _k
from repro.kernels.kde_rowsum import ref as _ref

# ||pad||^2 = d * 1e60 overflows f32 -> d2 = inf -> k = 0 for every kind.
_PAD_OFFSET = 1.0e30


def _pad_rows(a: jnp.ndarray, mult: int, offset: float) -> jnp.ndarray:
    n = a.shape[0]
    rem = (-n) % mult
    if rem == 0:
        return a
    pad = jnp.full((rem, a.shape[1]), offset, a.dtype) + a[-1:]
    return jnp.concatenate([a, pad], axis=0)


def _resolve_tiles(m, n, d, bm, bn, precision, default_bm, default_bn):
    """(bm, bn) with unset sizes filled in: legacy defaults on the f32
    path (bitwise stability), tuner output on the bf16 path."""
    if bm is not None and bn is not None:
        return bm, bn
    if precision == "f32":
        return (default_bm if bm is None else bm,
                default_bn if bn is None else bn)
    tbm, tbn = _tuning.pallas_tiles(m, n, d, precision)
    return (tbm if bm is None else bm), (tbn if bn is None else bn)


@functools.partial(jax.jit, static_argnames=("kind", "inv_bw", "beta", "bm", "bn", "interpret", "precision"))
def _rowsum(q, x, kind, inv_bw, beta, bm, bn, interpret, precision="f32"):
    m = q.shape[0]
    qp = _pad_rows(q, bm, 0.0)  # extra query rows are dropped after the call
    xp = _pad_rows(x, bn, _PAD_OFFSET)
    out = _k.rowsum_pallas(qp, xp, kind, inv_bw, beta, bm=bm, bn=bn,
                           interpret=interpret, precision=precision)
    return out[:m]


def kde_rowsum(q, x, kernel: Kernel, bm: int | None = None,
               bn: int | None = None, interpret: bool | None = None,
               precision: str = "f32") -> jnp.ndarray:
    """KDE oracle: (m,) row sums of the kernel matrix block k(q, x)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    beta = getattr(kernel, "beta", 1.0)
    inv_bw = 1.0 / kernel.bandwidth
    q = jnp.asarray(q, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    bm, bn = _resolve_tiles(q.shape[0], x.shape[0], q.shape[1], bm, bn,
                            precision, 128, 512)
    return _rowsum(q, x, kernel.name, inv_bw, beta, bm, bn, interpret,
                   precision)


@functools.partial(jax.jit, static_argnames=("kind", "inv_bw", "beta", "bm", "bn", "interpret", "precision"))
def _blocksum(q, x, kind, inv_bw, beta, bm, bn, interpret, precision="f32"):
    m = q.shape[0]
    qp = _pad_rows(q, bm, 0.0)
    xp = _pad_rows(x, bn, _PAD_OFFSET)
    out = _k.blocksum_pallas(qp, xp, kind, inv_bw, beta, bm=bm, bn=bn,
                             interpret=interpret, precision=precision)
    return out[:m]


def kde_blocksum(q, x, kernel: Kernel, bm: int = 128, bn: int = 256,
                 interpret: bool | None = None,
                 precision: str = "f32") -> jnp.ndarray:
    """Level-1 read: (m, ceil(n/bn)) per-block kernel sums.  ``bn`` is the
    semantic level-1 block size (it fixes the output width), so it is
    never autotuned."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    inv_bw = 1.0 / kernel.bandwidth
    return _blocksum(jnp.asarray(q, jnp.float32), jnp.asarray(x, jnp.float32),
                     kernel.name, inv_bw, getattr(kernel, "beta", 1.0), bm,
                     bn, interpret, precision)


# re-exported oracles for tests
rowsum_ref = _ref.rowsum_ref
blocksum_ref = _ref.blocksum_ref
