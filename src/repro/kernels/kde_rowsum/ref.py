"""Pure-jnp oracle for the kde_rowsum kernel."""
from __future__ import annotations

import jax.numpy as jnp


def kernel_values(q, x, kind: str, inv_bw: float, beta: float = 1.0):
    if kind == "laplacian":
        d1 = jnp.sum(jnp.abs(q[:, None, :] - x[None, :, :]), axis=-1)
        return jnp.exp(-d1 * inv_bw)
    qq = jnp.sum(q * q, axis=1, keepdims=True)
    xx = jnp.sum(x * x, axis=1, keepdims=True).T
    d2 = jnp.maximum(qq + xx - 2.0 * (q @ x.T), 0.0)
    if kind == "gaussian":
        return jnp.exp(-d2 * (inv_bw * inv_bw))
    if kind == "exponential":
        return jnp.exp(-jnp.sqrt(d2) * inv_bw)
    if kind == "rational_quadratic":
        return (1.0 + d2 * (inv_bw * inv_bw)) ** (-beta)
    raise ValueError(kind)


def rowsum_ref(q, x, kind: str, inv_bw: float, beta: float = 1.0):
    return jnp.sum(kernel_values(q, x, kind, inv_bw, beta), axis=1)


def blocksum_ref(q, x, kind: str, inv_bw: float, beta: float = 1.0,
                 bn: int = 256):
    kv = kernel_values(q, x, kind, inv_bw, beta)
    m, n = kv.shape
    return kv.reshape(m, n // bn, bn).sum(-1)
