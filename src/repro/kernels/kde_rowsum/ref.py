"""Pure-jnp oracle for the kde_rowsum kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.kde_sampler.ref import _finish_l2_bf16, check_precision


def kernel_values(q, x, kind: str, inv_bw: float, beta: float = 1.0,
                  precision: str = "f32"):
    if precision != "f32":
        # bf16 operand rounding + f32 norms from the rounded coordinates:
        # the same contract as the Pallas tile path (DESIGN.md §14).  Note
        # the single whole-array dot here accumulates in a different order
        # than the (bm, bn) tile decomposition, so THIS ref is the
        # tolerance oracle; bitwise parity tests mirror the tile loop.
        check_precision(precision, kind, None)
        qb = q.astype(jnp.bfloat16)
        xb = x.astype(jnp.bfloat16)
        qf = qb.astype(jnp.float32)
        xf = xb.astype(jnp.float32)
        qq = jnp.sum(qf * qf, axis=1, keepdims=True)
        xx = jnp.sum(xf * xf, axis=1, keepdims=True).T
        cross = jnp.matmul(qb, xb.T, preferred_element_type=jnp.float32)
        d2 = jnp.maximum(qq + xx - 2.0 * cross, 0.0)
        return _finish_l2_bf16(d2, kind, inv_bw, beta)
    if kind == "laplacian":
        d1 = jnp.sum(jnp.abs(q[:, None, :] - x[None, :, :]), axis=-1)
        return jnp.exp(-d1 * inv_bw)
    qq = jnp.sum(q * q, axis=1, keepdims=True)
    xx = jnp.sum(x * x, axis=1, keepdims=True).T
    d2 = jnp.maximum(qq + xx - 2.0 * (q @ x.T), 0.0)
    if kind == "gaussian":
        return jnp.exp(-d2 * (inv_bw * inv_bw))
    if kind == "exponential":
        return jnp.exp(-jnp.sqrt(d2) * inv_bw)
    if kind == "rational_quadratic":
        return (1.0 + d2 * (inv_bw * inv_bw)) ** (-beta)
    raise ValueError(kind)


def rowsum_ref(q, x, kind: str, inv_bw: float, beta: float = 1.0,
               precision: str = "f32"):
    return jnp.sum(kernel_values(q, x, kind, inv_bw, beta, precision), axis=1)


def blocksum_ref(q, x, kind: str, inv_bw: float, beta: float = 1.0,
                 bn: int = 256, precision: str = "f32"):
    kv = kernel_values(q, x, kind, inv_bw, beta, precision)
    m, n = kv.shape
    return kv.reshape(m, n // bn, bn).sum(-1)
