"""Pallas TPU kernel: blocked kernel-row-sums (the KDE hot spot).

Computes ``out[i] = sum_j k(q_i, x_j)`` (Definition 1.1 oracle) and the
per-block variant ``out[i, b] = sum_{j in block b} k(q_i, x_j)`` (the level-1
read of the depth-2 sampler, DESIGN.md §2).

Tiling: q tiles (bm, d) and x tiles (bn, d) stream HBM->VMEM; for L2 kernels
(gaussian / exponential / rational quadratic) the pairwise distances use the
MXU via the ||q||^2 + ||x||^2 - 2 q.x factorization; the L1 (laplacian)
kernel has no matmul form, so |q - x| is accumulated over d-chunks on the VPU
with a (bm, bn) accumulator resident in VMEM.

Block sizes default to MXU-aligned 128 lanes; the row accumulator lives in a
VMEM scratch and is flushed on the last j-step (revisiting output pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_L2_KINDS = ("gaussian", "exponential", "rational_quadratic")


def _tile_kernel_values(q, x, kind: str, inv_bw: float, beta: float,
                        d_chunk: int = 128):
    """(bm, bn) kernel values for one (q-tile, x-tile) pair."""
    if kind in _L2_KINDS:
        qq = jnp.sum(q * q, axis=1, keepdims=True)
        xx = jnp.sum(x * x, axis=1, keepdims=True).T
        cross = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        d2 = jnp.maximum(qq + xx - 2.0 * cross, 0.0)
        if kind == "gaussian":
            return jnp.exp(-d2 * (inv_bw * inv_bw))
        if kind == "exponential":
            return jnp.exp(-jnp.sqrt(d2) * inv_bw)
        return (1.0 + d2 * (inv_bw * inv_bw)) ** (-beta)
    # laplacian: accumulate |q - x| over d-chunks (VPU path).
    d = q.shape[1]
    steps = (d + d_chunk - 1) // d_chunk
    acc = jnp.zeros((q.shape[0], x.shape[0]), jnp.float32)
    for s in range(steps):  # static unroll: d is a compile-time constant
        lo = s * d_chunk
        hi = min(lo + d_chunk, d)
        acc = acc + jnp.sum(
            jnp.abs(q[:, None, lo:hi] - x[None, :, lo:hi]), axis=-1)
    return jnp.exp(-acc * inv_bw)


def _rowsum_kernel(q_ref, x_ref, o_ref, acc_ref, *, kind, inv_bw, beta):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv = _tile_kernel_values(q_ref[...], x_ref[...], kind, inv_bw, beta)
    acc_ref[...] += jnp.sum(kv, axis=1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc_ref[...]


def _blocksum_kernel(q_ref, x_ref, o_ref, *, kind, inv_bw, beta):
    kv = _tile_kernel_values(q_ref[...], x_ref[...], kind, inv_bw, beta)
    o_ref[...] = jnp.sum(kv, axis=1, keepdims=True)


def rowsum_pallas(q: jnp.ndarray, x: jnp.ndarray, kind: str, inv_bw: float,
                  beta: float = 1.0, bm: int = 128, bn: int = 512,
                  interpret: bool = False) -> jnp.ndarray:
    """q (m, d), x (n, d) -> (m,); m, n must be multiples of bm, bn."""
    m, d = q.shape
    n = x.shape[0]
    body = functools.partial(_rowsum_kernel, kind=kind, inv_bw=inv_bw,
                             beta=beta)
    return pl.pallas_call(
        body,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((bn, d), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm,), jnp.float32)],
        interpret=interpret,
    )(q, x)


def blocksum_pallas(q: jnp.ndarray, x: jnp.ndarray, kind: str, inv_bw: float,
                    beta: float = 1.0, bm: int = 128, bn: int = 256,
                    interpret: bool = False) -> jnp.ndarray:
    """q (m, d), x (n, d) -> (m, n/bn) per-block sums (level-1 read)."""
    m, d = q.shape
    n = x.shape[0]
    nb = n // bn
    body = functools.partial(_blocksum_kernel, kind=kind, inv_bw=inv_bw,
                             beta=beta)
    return pl.pallas_call(
        body,
        grid=(m // bm, nb),
        in_specs=[pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((bn, d), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, nb), jnp.float32),
        interpret=interpret,
    )(q, x)
