"""Pallas TPU kernel: blocked kernel-row-sums (the KDE hot spot).

Computes ``out[i] = sum_j k(q_i, x_j)`` (Definition 1.1 oracle) and the
per-block variant ``out[i, b] = sum_{j in block b} k(q_i, x_j)`` (the level-1
read of the depth-2 sampler, DESIGN.md §2).

Tiling: q tiles (bm, d) and x tiles (bn, d) stream HBM->VMEM; for L2 kernels
(gaussian / exponential / rational quadratic) the pairwise distances use the
MXU via the ||q||^2 + ||x||^2 - 2 q.x factorization; the L1 (laplacian)
kernel has no matmul form, so |q - x| is accumulated over d-chunks on the VPU
with a (bm, bn) accumulator resident in VMEM.

Block sizes default to MXU-aligned 128 lanes; the row accumulator lives in a
VMEM scratch and is flushed on the last j-step (revisiting output pattern).
Both grids carry ``dimension_semantics`` so the Mosaic pipeliner
double-buffers the HBM->VMEM tile copies: the query axis is "parallel"
everywhere; the x-block axis is "arbitrary" for the rowsum (its VMEM
accumulator is a cross-j carry) and "parallel" for the blocksum (each cell
owns its output block).

``precision="bf16"`` (DESIGN.md §14) rounds both operand tiles to bf16 --
halving the staged bytes, which is what a bandwidth-bound sweep buys from
mixed precision -- while the distance accumulation (MXU ``preferred_element_
type``), the kernel transform, and every downstream sum stay f32.  The norm
terms are recomputed in f32 from the *rounded* coordinates so the bf16 path
is a pure function of the bf16 operands (bitwise-matched by the jnp refs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.kde_sampler.ref import (_finish_l2_bf16, bf16_exp_table,
                                           check_precision)

_L2_KINDS = ("gaussian", "exponential", "rational_quadratic")
_EXP_KINDS = ("gaussian", "exponential")


def needs_exp_table(kind: str, precision: str) -> bool:
    """True when the bf16 finisher gathers from the exp table -- Pallas
    callers must then stream the table in as an input (a closed-over
    numpy constant is rejected by ``pallas_call``)."""
    return precision != "f32" and kind in _EXP_KINDS


def exp_table_operand() -> jnp.ndarray:
    """The (65536,) f32 exp table as a device operand for Pallas calls."""
    return jnp.asarray(bf16_exp_table())


def exp_table_spec(index_map) -> pl.BlockSpec:
    """Whole-table BlockSpec with a constant index map, so the pipeliner
    keeps one resident copy instead of restaging it per grid step."""
    return pl.BlockSpec((65536,), index_map)


def _tile_kernel_values(q, x, kind: str, inv_bw: float, beta: float,
                        d_chunk: int = 128, precision: str = "f32",
                        table=None):
    """(bm, bn) kernel values for one (q-tile, x-tile) pair."""
    if precision != "f32":
        check_precision(precision, kind, None)
        qb = q.astype(jnp.bfloat16)
        xb = x.astype(jnp.bfloat16)
        qf = qb.astype(jnp.float32)
        xf = xb.astype(jnp.float32)
        qq = jnp.sum(qf * qf, axis=1, keepdims=True)
        xx = jnp.sum(xf * xf, axis=1, keepdims=True).T
        cross = jax.lax.dot_general(qb, xb, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        d2 = jnp.maximum(qq + xx - 2.0 * cross, 0.0)
        return _finish_l2_bf16(d2, kind, inv_bw, beta, table)
    if kind in _L2_KINDS:
        qq = jnp.sum(q * q, axis=1, keepdims=True)
        xx = jnp.sum(x * x, axis=1, keepdims=True).T
        cross = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        d2 = jnp.maximum(qq + xx - 2.0 * cross, 0.0)
        if kind == "gaussian":
            return jnp.exp(-d2 * (inv_bw * inv_bw))
        if kind == "exponential":
            return jnp.exp(-jnp.sqrt(d2) * inv_bw)
        return (1.0 + d2 * (inv_bw * inv_bw)) ** (-beta)
    # laplacian: accumulate |q - x| over d-chunks (VPU path).
    d = q.shape[1]
    steps = (d + d_chunk - 1) // d_chunk
    acc = jnp.zeros((q.shape[0], x.shape[0]), jnp.float32)
    for s in range(steps):  # static unroll: d is a compile-time constant
        lo = s * d_chunk
        hi = min(lo + d_chunk, d)
        acc = acc + jnp.sum(
            jnp.abs(q[:, None, lo:hi] - x[None, :, lo:hi]), axis=-1)
    return jnp.exp(-acc * inv_bw)


def _rowsum_kernel(q_ref, x_ref, *rest, kind, inv_bw, beta, precision,
                   has_table):
    if has_table:
        t_ref, o_ref, acc_ref = rest
        table = t_ref[...]
    else:
        o_ref, acc_ref = rest
        table = None
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv = _tile_kernel_values(q_ref[...], x_ref[...], kind, inv_bw, beta,
                             precision=precision, table=table)
    acc_ref[...] += jnp.sum(kv, axis=1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc_ref[...]


def _blocksum_kernel(q_ref, x_ref, *rest, kind, inv_bw, beta, precision,
                     has_table):
    if has_table:
        t_ref, o_ref = rest
        table = t_ref[...]
    else:
        (o_ref,) = rest
        table = None
    kv = _tile_kernel_values(q_ref[...], x_ref[...], kind, inv_bw, beta,
                             precision=precision, table=table)
    o_ref[...] = jnp.sum(kv, axis=1, keepdims=True)


def rowsum_pallas(q: jnp.ndarray, x: jnp.ndarray, kind: str, inv_bw: float,
                  beta: float = 1.0, bm: int = 128, bn: int = 512,
                  interpret: bool = False,
                  precision: str = "f32") -> jnp.ndarray:
    """q (m, d), x (n, d) -> (m,); m, n must be multiples of bm, bn."""
    m, d = q.shape
    n = x.shape[0]
    has_table = needs_exp_table(kind, precision)
    body = functools.partial(_rowsum_kernel, kind=kind, inv_bw=inv_bw,
                             beta=beta, precision=precision,
                             has_table=has_table)
    in_specs = [pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, d), lambda i, j: (j, 0))]
    operands = [q, x]
    if has_table:
        in_specs.append(exp_table_spec(lambda i, j: (0,)))
        operands.append(exp_table_operand())
    return pl.pallas_call(
        body,
        grid=(m // bm, n // bn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm,), jnp.float32)],
        # the row accumulator is a cross-j VMEM carry, so the x-block axis
        # must stay sequential; query tiles double-buffer in parallel
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


def blocksum_pallas(q: jnp.ndarray, x: jnp.ndarray, kind: str, inv_bw: float,
                    beta: float = 1.0, bm: int = 128, bn: int = 256,
                    interpret: bool = False,
                    precision: str = "f32") -> jnp.ndarray:
    """q (m, d), x (n, d) -> (m, n/bn) per-block sums (level-1 read)."""
    m, d = q.shape
    n = x.shape[0]
    nb = n // bn
    has_table = needs_exp_table(kind, precision)
    body = functools.partial(_blocksum_kernel, kind=kind, inv_bw=inv_bw,
                             beta=beta, precision=precision,
                             has_table=has_table)
    in_specs = [pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, d), lambda i, j: (j, 0))]
    operands = [q, x]
    if has_table:
        in_specs.append(exp_table_spec(lambda i, j: (0,)))
        operands.append(exp_table_operand())
    return pl.pallas_call(
        body,
        grid=(m // bm, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, nb), jnp.float32),
        # no cross-step state: every (i, j) cell writes its own output
        # block, so both axes pipeline with double-buffered tile copies
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(*operands)
