"""Point-cloud datasets for the paper's experiments (Section 7).

`nested` and `rings` are reconstructed exactly as described; `mnist_like`
and `glove_like` are offline stand-ins for the MNIST / GloVe clouds used in
the LRA experiments (no network access in this environment): mixtures with
matched dimensionality and scale so the kernel spectra behave comparably.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def nested(n: int = 5000, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Half the points at the origin, half on the unit circle (Figure 2a).
    Small jitter keeps the kernel matrix non-degenerate."""
    rng = np.random.default_rng(seed)
    half = n // 2
    inner = rng.normal(0.0, 0.05, size=(half, 2))
    theta = rng.uniform(0, 2 * np.pi, size=n - half)
    outer = np.stack([np.cos(theta), np.sin(theta)], 1)
    outer += rng.normal(0.0, 0.02, size=outer.shape)
    x = np.concatenate([inner, outer]).astype(np.float32)
    y = np.concatenate([np.zeros(half, np.int64), np.ones(n - half, np.int64)])
    perm = rng.permutation(n)
    return x[perm], y[perm]


def rings(n: int = 2500, minor: float = 5.0, major: float = 100.0,
          seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Two interlocked tori in R^3 (Figure 2b): minor radius 5, major 100."""
    rng = np.random.default_rng(seed)
    half = n // 2

    def torus(m):
        u = rng.uniform(0, 2 * np.pi, size=m)
        v = rng.uniform(0, 2 * np.pi, size=m)
        xx = (major + minor * np.cos(v)) * np.cos(u)
        yy = (major + minor * np.cos(v)) * np.sin(u)
        zz = minor * np.sin(v)
        return np.stack([xx, yy, zz], 1)

    t1 = torus(half)
    t2 = torus(n - half)
    # interlock: rotate the second torus 90 deg about x and shift by major
    rot = np.array([[1, 0, 0], [0, 0, -1], [0, 1, 0]], float)
    t2 = t2 @ rot.T + np.array([major, 0.0, 0.0])
    x = np.concatenate([t1, t2]).astype(np.float32)
    y = np.concatenate([np.zeros(half, np.int64), np.ones(n - half, np.int64)])
    perm = rng.permutation(n)
    return x[perm], y[perm]


def mnist_like(n: int = 4000, d: int = 784, classes: int = 10,
               seed: int = 0) -> np.ndarray:
    """Sparse non-negative class-structured cloud in [0, 1]^784."""
    rng = np.random.default_rng(seed)
    protos = rng.uniform(0, 1, size=(classes, d)) * (rng.uniform(size=(classes, d)) < 0.2)
    lab = rng.integers(0, classes, size=n)
    x = protos[lab] + rng.normal(0, 0.08, size=(n, d))
    return np.clip(x, 0, 1).astype(np.float32)


def glove_like(n: int = 4000, d: int = 200, seed: int = 0) -> np.ndarray:
    """Dense low-intrinsic-dimension embedding cloud (GloVe stand-in)."""
    rng = np.random.default_rng(seed)
    basis = rng.normal(size=(24, d)) / np.sqrt(d)
    coef = rng.normal(size=(n, 24)) * np.geomspace(1.0, 0.05, 24)[None, :]
    x = coef @ basis + 0.02 * rng.normal(size=(n, d))
    return x.astype(np.float32)


def gaussian_clusters(n: int = 1024, d: int = 8, k: int = 2,
                      spread: float = 0.25, sep: float = 3.0,
                      seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Generic k-clusterable point cloud for unit tests."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * sep
    lab = rng.integers(0, k, size=n)
    x = centers[lab] + rng.normal(0, spread, size=(n, d))
    return x.astype(np.float32), lab
