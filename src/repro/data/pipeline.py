"""Deterministic synthetic LM data pipeline + dry-run input specs.

Tokens are drawn from a Zipf-ish distribution with a learnable bigram
structure (so a few hundred training steps visibly reduce loss).  Every
batch is a pure function of (seed, step) -- restart-safe by construction:
resuming from a checkpoint at step k regenerates exactly the batches k+1...

``input_specs`` returns ShapeDtypeStructs for every model input of an
(arch, shape) cell -- the dry-run lowers against these (no allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


def token_split(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, int]:
    """How the cell's seq_len splits into frontend positions vs text tokens."""
    s = shape.seq_len
    if cfg.is_encdec:
        enc = min(cfg.frontend_tokens, s // 4)
        return {"frontend": enc, "tokens": s - enc}
    if cfg.frontend != "none":
        fe = min(cfg.frontend_tokens, s // 4)
        return {"frontend": fe, "tokens": s - fe}
    return {"frontend": 0, "tokens": s}


def make_batch(cfg: ArchConfig, shape: ShapeConfig, step: int, seed: int = 0,
               batch_override: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Host-side batch for one step (train/prefill kinds)."""
    split = token_split(cfg, shape)
    b = batch_override or shape.global_batch
    rng = np.random.default_rng(np.uint32(seed * 1_000_003 + step))
    st = split["tokens"]
    # zipf-ish marginals + deterministic bigram successor structure
    v = cfg.vocab_size
    base = rng.zipf(1.3, size=(b, st)).astype(np.int64) % v
    succ = (np.arange(v) * 31 + 7) % v
    flip = rng.random((b, st)) < 0.65
    tokens = base.copy()
    tokens[:, 1:] = np.where(flip[:, 1:], succ[base[:, :-1]], base[:, 1:])
    out: Dict[str, np.ndarray] = {"tokens": tokens.astype(np.int32)}
    if split["frontend"]:
        out["frontend"] = rng.normal(
            0, 1, size=(b, split["frontend"], cfg.d_model)).astype(np.float32)
    return out


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the cell's step fn."""
    split = token_split(cfg, shape)
    b = shape.global_batch
    if shape.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, split["tokens"]), jnp.int32)}
        if split["frontend"]:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, split["frontend"], cfg.d_model), dtype)
        return specs
    # decode: one new token against a max_len cache
    specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    return specs
