"""Device-side status flags and the staged estimator fallback (DESIGN.md §11).

The paper's guarantees (Definition 1.1's ``(eps, tau)`` contract, the
Theorem 4.12 rejection sampler) assume the KDE oracle returns sane values.
Every fused program therefore returns a compact ``uint32`` **status
bitmask** next to its result -- cheap in-program reductions over values the
program already computed, so the flags cost no extra kernel evaluations and
(on the sharded engines) no extra collectives.

Bit layout (documented in DESIGN.md §11)::

    NONFINITE         1<<0  NaN/Inf in kernel evals / level-1 sums
    ZERO_MASS         1<<1  a query row's blocks all sat at the 1e-12 floor
    REJECT_EXHAUSTED  1<<2  a rejection draw used all rounds without accepting
    BUCKET_OVERFLOW   1<<3  a hash bucket was truncated at max_bucket
    HT_HEAVY          1<<4  a Horvitz-Thompson far-field weight blew up
    STATE_CORRUPT     1<<5  hash-state member indices out of range
    CG_NO_CONVERGE    1<<6  CG finished above its residual tolerance
    NONFINITE_RESULT  1<<7  the program's *output* is NaN/Inf
    OVERFLOW_SATURATED 1<<8 the streaming hash overflow region is full
    EPOCH_STALE       1<<9  a consumer served (or was asked to serve) state
                            built at an older dataset epoch

Flags are advisory by default; with ``REPRO_CHECKS=1`` every consumer turns
them into hard ``EstimationError``s via :func:`raise_on_status`, and
:func:`checked` wraps a program in ``jax.experimental.checkify`` so the
float checks fire inside the trace itself.

:class:`RobustEstimator` is the degradation policy on top of the flags: a
Definition 1.1 estimator that retries flagged draws with re-keyed RNG and
escalates hash -> stratified -> exact per query row, recording the cost in
the ordinary ``.evals`` counters.
"""
from __future__ import annotations

import functools
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np

NONFINITE = 1 << 0
ZERO_MASS = 1 << 1
REJECT_EXHAUSTED = 1 << 2
BUCKET_OVERFLOW = 1 << 3
HT_HEAVY = 1 << 4
STATE_CORRUPT = 1 << 5
CG_NO_CONVERGE = 1 << 6
NONFINITE_RESULT = 1 << 7
OVERFLOW_SATURATED = 1 << 8
EPOCH_STALE = 1 << 9

STATUS_NAMES = {
    NONFINITE: "NONFINITE",
    ZERO_MASS: "ZERO_MASS",
    REJECT_EXHAUSTED: "REJECT_EXHAUSTED",
    BUCKET_OVERFLOW: "BUCKET_OVERFLOW",
    HT_HEAVY: "HT_HEAVY",
    STATE_CORRUPT: "STATE_CORRUPT",
    CG_NO_CONVERGE: "CG_NO_CONVERGE",
    NONFINITE_RESULT: "NONFINITE_RESULT",
    OVERFLOW_SATURATED: "OVERFLOW_SATURATED",
    EPOCH_STALE: "EPOCH_STALE",
}

#: flags that a re-keyed retry can plausibly clear (transient sampling luck)
RETRYABLE = REJECT_EXHAUSTED | HT_HEAVY
#: flags that mean the estimate itself is garbage and must escalate
FATAL = NONFINITE | ZERO_MASS | STATE_CORRUPT | NONFINITE_RESULT


def host_status(status) -> int:
    """Host-side status coercion: python ints and scalar uint32 statuses
    pass through; PR-10 counter words (trailing dim == ``obs.WIDTH``)
    read slot 0; batches of either or-fold over the batch axis."""
    if isinstance(status, (int, np.integer)):
        return int(status)
    arr = np.asarray(jax.device_get(status))
    if arr.ndim == 0:
        return int(arr)
    from repro.obs import counters as _c
    if arr.shape[-1] == _c.WIDTH:
        arr = arr[..., _c.STATUS]
    return int(np.bitwise_or.reduce(arr.astype(np.uint32).reshape(-1)))


def decode_status(status) -> list:
    """Human-readable flag names set in an integer/array status word (or
    in slot 0 of a counter word)."""
    s = host_status(status)
    return [name for bit, name in STATUS_NAMES.items() if s & bit]


def checks_enabled() -> bool:
    """True when ``REPRO_CHECKS=1`` -- flags become hard errors."""
    return os.environ.get("REPRO_CHECKS", "0") not in ("", "0")


def ht_bound() -> float:
    """Static HT inverse-probability weight bound (``REPRO_HT_BOUND``)."""
    return float(os.environ.get("REPRO_HT_BOUND", "4096"))


def ht_frac() -> float:
    """Fraction of |far estimate| one sample may contribute before the
    draw is flagged ``HT_HEAVY`` (``REPRO_HT_FRAC``)."""
    return float(os.environ.get("REPRO_HT_FRAC", "0.95"))


class EstimationError(RuntimeError):
    """A fused program raised a status flag under ``REPRO_CHECKS=1``."""


def raise_on_status(status, context: str = "", allow: int = 0) -> int:
    """Host-side check point: raise when checks are on and flags are set.

    Returns the (python int) status word either way so callers can
    accumulate it into their counters.  ``allow`` masks flags that the
    caller handles itself (e.g. a sampler that counts rejection fallbacks).
    Accepts scalar statuses and PR-10 counter words alike.
    """
    s = host_status(status)
    bad = s & ~allow
    if bad and checks_enabled():
        raise EstimationError(
            f"{context or 'fused program'}: status flags "
            f"{decode_status(bad)} (status=0x{s:x})")
    return s


def raise_per_request(statuses, contexts, allow: int = 0):
    """Per-request fan-out of :func:`raise_on_status` for the serving
    layer's batched status words (DESIGN.md §13): ONE ``device_get`` of
    the (R,) uint32 vector, then the ordinary checks policy applied per
    request.  ``contexts`` is either one string or a sequence aligned with
    the requests.  Returns ``(statuses, errors)`` -- python ints plus,
    aligned, the ``EstimationError`` built for each flagged request (None
    when the request is clean or checks are off).  Never raises itself:
    one poisoned request must not take down the other R-1 lanes of a
    serving tick -- the servable attaches each error to its one request.
    Accepts an (R,) scalar-status vector or an (R, obs.WIDTH) stack of
    counter words (slot 0 is the per-request status).
    """
    arr = np.asarray(jax.device_get(jnp.asarray(statuses, jnp.uint32)))
    if arr.ndim == 2:                       # (R, WIDTH) counter words
        from repro.obs import counters as _c
        arr = arr[:, _c.STATUS]
    arr = arr.reshape(-1)
    on = checks_enabled()
    out, errors = [], []
    for i, s in enumerate(arr.tolist()):
        s = int(s)
        ctx = contexts if isinstance(contexts, str) else contexts[i]
        bad = s & ~allow
        errors.append(EstimationError(
            f"{ctx or 'serving request'}: status flags "
            f"{decode_status(bad)} (status=0x{s:x})")
            if bad and on else None)
        out.append(s)
    return out, errors


def count_flags(counter: dict, status) -> dict:
    """Accumulate per-flag event counts into ``counter`` (name -> int)."""
    s = host_status(status)
    for bit, name in STATUS_NAMES.items():
        if s & bit:
            counter[name] = counter.get(name, 0) + 1
    return counter


# --------------------------------------------------------------- jnp helpers
# All helpers below are trace-safe reductions over values the calling
# program already holds -- no new kernel evaluations, no new collectives.

def flag_if(cond, flag: int):
    """uint32 ``flag`` where ``cond`` (scalar bool) else 0."""
    return jnp.where(cond, jnp.uint32(flag), jnp.uint32(0))


def merge(*statuses):
    """Bitwise-or an arbitrary number of uint32 status words."""
    out = jnp.uint32(0)
    for s in statuses:
        out = out | jnp.asarray(s, jnp.uint32)
    return out


def nonfinite_status(*arrays, flag: int = NONFINITE):
    """``flag`` if any element of any array is NaN/Inf."""
    bad = False
    for a in arrays:
        bad = jnp.logical_or(bad, jnp.any(~jnp.isfinite(a)))
    return flag_if(bad, flag)


def sums_status(bs, floor: float):
    """Status of a (m, B) level-1 block-sum read: NONFINITE for NaN/Inf,
    ZERO_MASS when some row's blocks all sat at the clamping floor."""
    bs = jnp.asarray(bs)
    nf = jnp.any(~jnp.isfinite(bs))
    zero = jnp.any(jnp.all(bs <= 2.0 * floor, axis=-1))
    return merge(flag_if(nf, NONFINITE), flag_if(zero, ZERO_MASS))


def totals_status(tot, num_blocks: int, floor: float):
    """Status from replicated row *totals* (post-psum on the mesh path):
    same contract as :func:`sums_status` without needing the blocks."""
    tot = jnp.asarray(tot)
    nf = jnp.any(~jnp.isfinite(tot))
    zero = jnp.any(tot <= 2.0 * floor * num_blocks)
    return merge(flag_if(nf, NONFINITE), flag_if(zero, ZERO_MASS))


def result_status(*arrays):
    """NONFINITE_RESULT if any program output element is NaN/Inf."""
    return nonfinite_status(*arrays, flag=NONFINITE_RESULT)


# ----------------------------------------------------------- checkify mode
def checked(fn):
    """Wrap a jittable program with ``jax.experimental.checkify`` float
    checks: under the debug mode the NaN/Inf conditions the status bits
    summarize become hard in-trace errors with source locations."""
    from jax.experimental import checkify
    cfn = checkify.checkify(fn, errors=checkify.float_checks)

    @functools.wraps(fn)
    def run(*args, **kw):
        err, out = cfn(*args, **kw)
        err.throw()
        return out
    return run


# -------------------------------------------------------- staged fallback
class RobustEstimator:
    """Definition 1.1 estimator with staged degradation (DESIGN.md §11).

    Wraps the ordinary ``make_estimator`` backends in the escalation chain
    ``hash -> stratified -> exact`` (the hierarchy BIMW21 / SSX25 treat as
    interchangeable oracles).  Per query batch it

    1. runs the cheapest stage and reads its ``last_status`` word,
    2. retries rows whose estimate is non-finite / non-positive (or whose
       batch raised a retryable flag) once with re-keyed RNG -- the
       randomized stages advance their PRNG key per call, so the retry is
       a fresh draw for free,
    3. escalates still-bad rows to the next stage; the final exact stage
       is always accepted.

    Every stage charges the shared ``.evals`` counter, so the cost of
    degradation stays auditable in the Section 7 accounting.  The chain is
    built lazily: a clean workload never pays for the exact oracle.
    """

    def __init__(self, x, kernel, seed: int = 0,
                 stages=("hash", "stratified", "exact"), max_retries: int = 1,
                 stage_kw: dict | None = None, **kw):
        # `x` may be a DynamicDataset (duck-typed: .x_pad/.epoch): the
        # wrapper then tracks the dataset epoch and drops lazily-built
        # stage states on mutation instead of escalating against them
        self._dataset = x if hasattr(x, "live_x") and hasattr(x, "epoch") \
            else None
        if self._dataset is not None:
            self.x, self.x_sq = self._dataset.live_x()
            self._ds_epoch = int(self._dataset.epoch)
        else:
            self.x = jnp.asarray(x, jnp.float32)
            self.x_sq = jnp.sum(self.x * self.x, axis=-1)
            self._ds_epoch = 0
        self.stage_rebuilds = 0
        self.kernel = kernel
        self.n = int(self.x.shape[0])
        self.d = int(self.x.shape[1])
        self.stage_names = tuple(stages)
        self.max_retries = int(max_retries)
        self._seed = int(seed)
        self._kw = dict(kw)
        self._stage_kw = dict(stage_kw or {})
        self._stages = {}
        self.status = 0
        self.flag_counts: dict = {}
        self.retries = 0
        self.escalations = {name: 0 for name in self.stage_names[1:]}

    def _sync(self) -> None:
        """Epoch check at stage entry: if the attached dataset mutated
        since the stages were built, refresh the row arrays and drop every
        lazily-built stage state -- serving them would silently escalate
        against stale data (the PR-7 streaming contract, DESIGN.md §12)."""
        ds = self._dataset
        if ds is None or self._ds_epoch == int(ds.epoch):
            return
        self.x, self.x_sq = ds.live_x()
        self.n = int(self.x.shape[0])
        self.stage_rebuilds += len(self._stages)
        self._stages.clear()
        self._ds_epoch = int(ds.epoch)

    def _stage(self, name: str):
        self._sync()
        if name not in self._stages:
            from repro.core.kde.base import make_estimator
            kw = dict(self._kw)
            kw.update(self._stage_kw.get(name, {}))
            self._stages[name] = make_estimator(name, self.x, self.kernel,
                                                seed=self._seed, **kw)
        return self._stages[name]

    @property
    def evals(self) -> int:
        """Total kernel evaluations across every stage touched so far."""
        return sum(int(s.evals) for s in self._stages.values())

    @evals.setter
    def evals(self, value: int):
        # consumers reset counters by assignment; push the reset down
        for s in self._stages.values():
            s.evals = 0
        if int(value) != 0:
            raise ValueError("RobustEstimator.evals can only be reset to 0")

    @staticmethod
    def _bad_rows(vals) -> np.ndarray:
        v = np.asarray(vals, np.float64)
        return ~np.isfinite(v) | (v <= 0.0)

    def query(self, y: jnp.ndarray) -> jnp.ndarray:
        """(m, d) -> (m,) row-sum estimates, degraded per row as needed.

        A non-final stage that *raises* ``EstimationError`` (its own
        ``REPRO_CHECKS`` policy firing) is treated like an all-bad batch
        and escalated -- the wrapper IS the recovery path, so only a
        failure of the final stage propagates."""
        y = jnp.asarray(y, jnp.float32)
        m = int(y.shape[0])
        out = np.full((m,), np.nan, np.float64)
        pending = np.arange(m)
        for depth, name in enumerate(self.stage_names):
            if pending.size == 0:
                break
            stage = self._stage(name)
            if depth > 0:
                self.escalations[name] += int(pending.size)
            last = depth == len(self.stage_names) - 1
            sub = y[jnp.asarray(pending)]
            try:
                vals = np.asarray(stage.query(sub), np.float64)
            except EstimationError:
                if last:
                    raise
                status = host_status(getattr(stage, "status", 0))
                self.status |= status
                count_flags(self.flag_counts, status)
                continue                    # escalate every pending row
            status = host_status(getattr(stage, "last_status", 0))
            bad = self._bad_rows(vals)
            if (status & FATAL) and not last:
                # batch-level corruption: per-row values may LOOK sane
                # (clamped gathers read the wrong rows), so no row from
                # this batch is trustworthy -- escalate them all
                bad = np.ones_like(bad)
            retryable = ((status & RETRYABLE) or bad.any()) \
                and not (status & FATAL)
            if retryable and not last and self.max_retries > 0 \
                    and hasattr(stage, "_split"):
                redo = np.where(bad)[0] if bad.any() else np.arange(len(vals))
                self.retries += int(redo.size)
                try:
                    vals[redo] = np.asarray(
                        stage.query(y[jnp.asarray(pending[redo])]),
                        np.float64)
                    status |= host_status(getattr(stage, "last_status", 0))
                except EstimationError:
                    pass                    # retry failed too -> escalate
                bad = self._bad_rows(vals)
            self.status |= status
            count_flags(self.flag_counts, status)
            if last:
                bad = np.zeros_like(bad)
            good = ~bad
            out[pending[good]] = vals[good]
            pending = pending[bad]
        # the wrapper's own check point: flags a stage recovered from are
        # history, so only an unrecovered (non-finite) OUTPUT is fatal
        if checks_enabled() and not np.all(np.isfinite(out)):
            raise EstimationError(
                "RobustEstimator.query: non-finite output survived the "
                f"final '{self.stage_names[-1]}' stage "
                f"(accumulated flags {decode_status(self.status)})")
        return jnp.asarray(out, jnp.float32)

    def query1(self, y: jnp.ndarray) -> float:
        """Single-point convenience wrapper around ``query``."""
        return float(self.query(y[None, :])[0])

    def degrees(self, batch: int = 1024) -> np.ndarray:
        """Algorithm 4.3 degree sweep through the staged chain."""
        from repro.core.sampling.vertex import host_degree_loop
        return host_degree_loop(self, batch)


def warn_fallback_rate(fallbacks: int, draws: int, rounds: int,
                       slack: float, context: str = "sample_exact") -> None:
    """Warn when rejection-fallback frequency exceeds the Theorem 4.12
    prediction: accept prob >= 1/c per round -> all-reject rate
    <= (1 - 1/c)^rounds."""
    if draws <= 0 or fallbacks <= 0:
        return
    c = max(float(slack), 1.0 + 1e-9)
    predicted = (1.0 - 1.0 / c) ** int(rounds)
    rate = fallbacks / draws
    if rate > max(2.0 * predicted, 1e-3):
        warnings.warn(
            f"{context}: rejection fallback rate {rate:.3g} exceeds the "
            f"(1-1/c)^rounds prediction {predicted:.3g} "
            f"(c={c:.3g}, rounds={rounds}) -- level-1 estimates are "
            f"under-covering the true row mass", RuntimeWarning,
            stacklevel=3)
