"""Straggler and failure watchdog.

On a real cluster every host reports a heartbeat with its last step wall
time; the controller keeps per-host EWMAs and flags hosts slower than
``threshold`` x the fleet median (straggler mitigation: reroute data shards,
or preemptively checkpoint + evict).  Here hosts are simulated (single
process), but the full decision logic is real and unit-tested -- the driver
consumes `decide()` verbatim.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from repro.obs import metrics as _m


@dataclasses.dataclass
class HostStats:
    ewma_s: float = 0.0
    last_beat: float = 0.0
    steps: int = 0


class Watchdog:
    def __init__(self, hosts: int, alpha: float = 0.3,
                 straggler_factor: float = 1.5,
                 heartbeat_timeout_s: float = 300.0,
                 now: Optional[float] = None):
        # every host's clock starts at construction: a host that NEVER
        # heartbeats is declared dead after heartbeat_timeout_s, instead
        # of being skipped forever (``now=`` for deterministic tests)
        start = now if now is not None else time.monotonic()
        self.stats: Dict[int, HostStats] = {
            h: HostStats(last_beat=start) for h in range(hosts)}
        self.alpha = alpha
        self.factor = straggler_factor
        self.timeout = heartbeat_timeout_s

    def beat(self, host: int, step_time_s: float,
             now: Optional[float] = None):
        st = self.stats[host]
        st.ewma_s = (step_time_s if st.steps == 0
                     else self.alpha * step_time_s + (1 - self.alpha) * st.ewma_s)
        st.steps += 1
        st.last_beat = now if now is not None else time.monotonic()
        _m.event("watchdog.beat", host=host, step_time_s=step_time_s,
                 ewma_s=st.ewma_s)

    def median_ewma(self) -> float:
        vals = sorted(s.ewma_s for s in self.stats.values() if s.steps > 0)
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def decide(self, now: Optional[float] = None) -> Dict[str, List[int]]:
        """-> {"stragglers": [...], "dead": [...]}"""
        now = now if now is not None else time.monotonic()
        med = self.median_ewma()
        stragglers, dead = [], []
        for h, st in self.stats.items():
            if now - st.last_beat > self.timeout:
                dead.append(h)
            elif st.steps > 0 and med > 0 and st.ewma_s > self.factor * med:
                stragglers.append(h)
        _m.event("watchdog.decide", stragglers=stragglers, dead=dead,
                 median_ewma_s=med)
        return {"stragglers": stragglers, "dead": dead}
