"""Fault-injection harness for the estimation runtime (DESIGN.md §11).

Each injector corrupts ONE well-defined thing (dataset rows, the kernel
bandwidth, the frozen hash layout, the heartbeat stream) and each scenario
drives a real pipeline over the corrupted input, asking one question: does
the runtime *detect* the fault (status flag / ``EstimationError``) or
*survive* it (finite, sane output)?  Silent garbage is the only failure.

The scenarios run in CI under ``REPRO_CHECKS=1`` (``tests/test_chaos.py``),
where fatal flags raise -- so "detected" usually means "raised
``EstimationError`` with the right flag name in the message".

>>> from repro.ft import chaos
>>> report = chaos.run_scenario("nan_rows_hashed_query")
>>> report["detected"] or report["survived"]
True
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft import guards
from repro.obs import metrics as _m

# ------------------------------------------------------------- injectors


def nan_rows(x: np.ndarray, rows, value: float = np.nan) -> np.ndarray:
    """Overwrite whole dataset rows with NaN (or ``value=np.inf``)."""
    out = np.array(x, np.float32, copy=True)
    out[np.asarray(rows)] = np.float32(value)
    return out


def duplicate_points(x: np.ndarray, frac: float, rng) -> np.ndarray:
    """Collapse a ``frac`` fraction of rows onto row 0 (mass pile-up)."""
    out = np.array(x, np.float32, copy=True)
    k = max(int(frac * len(out)), 1)
    idx = rng.choice(len(out), size=k, replace=False)
    out[idx] = out[0]
    return out


def tiny_bandwidth_kernel(make, bandwidth: float = 1e-30):
    """A kernel whose bandwidth underflows every pairwise value to 0 --
    the zero-mass degenerate limit.  (Exactly 0.0 is rejected eagerly by
    the kernel constructors' ``1/h`` arithmetic, which is itself the
    first line of defense; the runtime guards cover the *underflow*.)"""
    return make(bandwidth)


def corrupt_hash_state(state, rng, n: int, frac: float = 0.25):
    """Flip a fraction of stored member indices out of ``[0, n)`` -- the
    silent-corruption case: JAX gathers clamp out-of-range indices, so
    without ``guards.STATE_CORRUPT`` the query would return plausible
    numbers computed from the wrong rows."""
    members = np.array(state.members, np.int32, copy=True)
    flat = members.reshape(-1)
    k = max(int(frac * flat.size), 1)
    idx = rng.choice(flat.size, size=k, replace=False)
    flat[idx] = np.int32(n + 1 + rng.integers(0, 7, size=k))
    return state._replace(members=jnp.asarray(members))


def adversarial_far_field(n: int, d: int, rng):
    """Dataset + queries engineered so ONE far-field point carries nearly
    all of the row mass: the bulk sits ~100 bandwidths away (kernel value
    underflows to 0), one point sits a couple of grid cells from the
    queries -- outside every NEAR bucket, close enough to dominate.  A
    Horvitz-Thompson far sample that hits it IS the whole estimate
    (``guards.HT_HEAVY``)."""
    x = rng.standard_normal((n, d)).astype(np.float32) + 100.0
    x[0] = 0.0
    x[0, 0] = 2.0                               # the lone heavy point
    y = rng.standard_normal((8, d)).astype(np.float32) * 1e-3
    return x, y


def silent_hosts(hosts: int, silent, timeout_s: float = 10.0,
                 now0: float = 0.0):
    """Watchdog scenario: ``silent`` hosts never heartbeat.  Returns the
    decision dict after the timeout has elapsed for everyone."""
    from repro.ft.watchdog import Watchdog

    wd = Watchdog(hosts=hosts, heartbeat_timeout_s=timeout_s, now=now0)
    silent = set(int(s) for s in silent)
    for h in range(hosts):
        if h not in silent:
            wd.beat(h, 1.0, now=now0 + 2.0 * timeout_s)
    return wd.decide(now=now0 + 2.5 * timeout_s)


# ------------------------------------------------------------- scenarios
# Every scenario returns {"detected": bool, "survived": bool, "detail": str}
# -- detected = a guard fired (flag observed, or EstimationError raised
# under REPRO_CHECKS); survived = the pipeline produced finite sane output.


def _dataset(rng, n: int = 192, d: int = 3) -> np.ndarray:
    return rng.standard_normal((n, d)).astype(np.float32)


def _outcome(fn: Callable[[], tuple]) -> Dict:
    """Run one scenario body (-> (status int, survived bool, detail));
    an ``EstimationError`` counts as detection, any other exception is a
    genuine harness failure and propagates."""
    try:
        status, survived, detail = fn()
    except guards.EstimationError as e:
        return {"detected": True, "survived": False, "detail": str(e)}
    return {"detected": bool(status), "survived": bool(survived),
            "detail": detail or guards.decode_status(status)}


def _nan_rows_hashed_query(rng):
    from repro.core.kde.hashed import HashedKDE
    from repro.core.kernels_fn import gaussian

    x = nan_rows(_dataset(rng), rows=[3, 17, 40])
    est = HashedKDE(x, gaussian(1.0), seed=0, max_bucket=32,
                    num_far_samples=16)
    vals = np.asarray(est.query(jnp.asarray(x[:16])))
    return est.status, np.all(np.isfinite(vals)), ""


def _inf_rows_sampler(rng):
    from repro.core.kernels_fn import gaussian
    from repro.core.sampling.edge import NeighborSampler

    x = nan_rows(_dataset(rng), rows=[5], value=np.inf)
    nbr = NeighborSampler(x, gaussian(1.0), mode="blocked", block_size=32,
                          seed=0)
    nb, prob = nbr.sample(np.arange(16))
    return nbr.status, np.all(np.isfinite(prob)), ""


def _tiny_bandwidth_zero_mass(rng):
    from repro.core.kernels_fn import gaussian
    from repro.core.sampling.edge import NeighborSampler

    ker = tiny_bandwidth_kernel(gaussian)     # every k(u, v) underflows
    nbr = NeighborSampler(_dataset(rng), ker, mode="blocked",
                          block_size=32, seed=0)
    nb, prob = nbr.sample(np.arange(16))
    return nbr.status, np.all(np.isfinite(prob)), ""


def _duplicate_points_survive(rng):
    from repro.core.kernels_fn import gaussian
    from repro.core.sampling.edge import NeighborSampler

    x = duplicate_points(_dataset(rng), frac=0.5, rng=rng)
    nbr = NeighborSampler(x, gaussian(1.0), mode="blocked", block_size=32,
                          seed=0)
    nb, prob = nbr.sample(np.arange(16))
    ok = (np.all(np.isfinite(prob)) and np.all(prob > 0)
          and np.all(nb != np.arange(16)))
    return int(nbr.status) & guards.FATAL, ok, ""


def _corrupt_hash_state(rng):
    from repro.core.kde.hashed import HashedKDE
    from repro.core.kernels_fn import gaussian

    x = _dataset(rng)
    est = HashedKDE(x, gaussian(1.0), seed=0, max_bucket=32,
                    num_far_samples=16)
    est.state = corrupt_hash_state(est.state, rng, n=len(x))
    vals = np.asarray(est.query(jnp.asarray(x[:16])))
    return est.status & guards.STATE_CORRUPT, np.all(np.isfinite(vals)), ""


def _adversarial_far_field(rng):
    from repro.core.kde.hashed import HashedKDE
    from repro.core.kernels_fn import gaussian

    x, y = adversarial_far_field(512, 3, rng)
    est = HashedKDE(x, gaussian(0.5), seed=0, max_bucket=16,
                    num_far_samples=16)
    seen = 0
    for _ in range(64):                    # the heavy hit is probabilistic
        np.asarray(est.query(jnp.asarray(y)))
        seen |= est.status
        if seen & guards.HT_HEAVY:
            break
    return seen & guards.HT_HEAVY, True, ""


def _reject_exhaustion(rng):
    from repro.core.kernels_fn import gaussian
    from repro.core.sampling.edge import NeighborSampler

    import warnings

    # bandwidth 1.0 keeps every row's mass healthy (no ZERO_MASS); the
    # injected fault is ONLY the zero-headroom accept test below
    nbr = NeighborSampler(_dataset(rng, n=256), gaussian(1.0),
                          mode="blocked", block_size=32,
                          samples_per_block=2, seed=0)
    # slack ~1 gives the accept test no headroom: all-rounds-reject events
    # are near-certain, the documented fallback path must engage + count
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        cur = nbr.sample_exact(np.arange(64), rounds=2, slack=1.0 + 1e-6)
    ok = (np.all(np.isfinite(cur)) and nbr.exact_fallbacks >= 0
          and nbr.exact_draws == 64)
    return nbr.status & guards.REJECT_EXHAUSTED, ok, \
        f"fallbacks={nbr.exact_fallbacks}/{nbr.exact_draws}"


def _robust_escalation(rng):
    from repro.core.kernels_fn import gaussian

    x = _dataset(rng)
    est = guards.RobustEstimator(x, gaussian(1.0), seed=0,
                                 stage_kw={"hash": {"max_bucket": 32,
                                                    "num_far_samples": 16}})
    # poison the first stage AFTER build: its queries go bad, the wrapper
    # must escalate to stratified/exact and still return sane numbers
    hash_stage = est._stage("hash")
    hash_stage.state = corrupt_hash_state(hash_stage.state, rng, n=len(x),
                                          frac=1.0)
    vals = np.asarray(est.query(jnp.asarray(x[:16])))
    recovered = np.all(np.isfinite(vals)) and np.all(vals > 0)
    escalated = sum(est.escalations.values()) > 0 or est.retries > 0
    return est.status, bool(recovered and escalated), \
        f"escalations={est.escalations} retries={est.retries}"


def _silent_host_watchdog(rng):
    res = silent_hosts(hosts=4, silent=[2], timeout_s=10.0)
    detected = 2 in res["dead"]
    return int(detected), res["dead"] == [2], str(res)


def _overflow_insert_storm(rng):
    """Streaming fault (DESIGN.md §12): an insert storm aimed at ONE grid
    cell.  New rows whose cell is absent from the frozen bucket layout
    land in the overflow region; a storm of them must saturate it and
    surface ``OVERFLOW_SATURATED`` (an ``EstimationError`` under
    ``REPRO_CHECKS=1``, an automatic compaction otherwise) -- never a
    silently-dropped row."""
    from repro.core.dataset import DynamicDataset
    from repro.core.kde.hashed import HashedKDE
    from repro.core.kernels_fn import gaussian

    x = _dataset(rng)
    ds = DynamicDataset(x, capacity=1024)
    est = HashedKDE(x, gaussian(1.0), seed=0, max_bucket=8,
                    num_far_samples=16, dataset=ds, overflow_cap=16)
    target = x[0] + np.float32(50.0)     # one far-away (= unhashed) cell
    seen, vals = 0, np.zeros(1)
    for _ in range(8):                   # 8 * 8 rows >> overflow_cap
        ds.insert_rows(np.tile(target, (8, 1))
                       + rng.normal(scale=1e-3, size=(8, 3)).astype(
                           np.float32))
        vals = np.asarray(est.query(jnp.asarray(x[:4])))
        seen |= est.status
        if seen & guards.OVERFLOW_SATURATED:
            break
    return (seen & guards.OVERFLOW_SATURATED,
            np.all(np.isfinite(vals)) and est.rebuilds > 0,
            f"rebuilds={est.rebuilds}")


def _delete_query_race(rng):
    """Streaming fault (DESIGN.md §12): deletes racing a fixed query
    frontier toward an empty dataset.  Once a frontier row dies, the
    sampler must surface ``EPOCH_STALE`` (raising under
    ``REPRO_CHECKS=1``) instead of sampling from sentinel coordinates."""
    from repro.core.dataset import DynamicDataset
    from repro.core.kernels_fn import gaussian
    from repro.core.sampling.edge import NeighborSampler

    x = _dataset(rng)
    ds = DynamicDataset(x, capacity=256)
    nbr = NeighborSampler(x, gaussian(1.0), mode="blocked", block_size=32,
                          seed=0, dataset=ds)
    src = np.arange(8)
    order = rng.permutation(len(x))
    seen = 0
    for lo in range(0, len(x) - 16, 16):
        ds.delete_rows(order[lo:lo + 16])
        nbr.sample(src)
        seen |= nbr.status
        if seen & guards.EPOCH_STALE:
            break
    return seen & guards.EPOCH_STALE, True, guards.decode_status(seen)


def _serve_eviction_mid_stream(rng):
    """Serving fault (DESIGN.md §13): an LRU capacity of ONE under a
    request stream that alternates tenants, so EVERY tick evicts one
    tenant's device state and rebuilds the other's from its dataset.
    Eviction must be invisible to correctness -- each request completes
    with finite sane output and no fatal flag -- because the dataset is
    the source of truth and admission rebuilds derived state."""
    from repro.core.kernels_fn import gaussian
    from repro.core.serving import KernelGraphServable

    srv = KernelGraphServable(max_resident=1)
    for name, shift in (("a", 0.0), ("b", 0.5)):
        srv.add_tenant(name, _dataset(rng) + np.float32(shift),
                       gaussian(1.0), block_size=32, seed=0)
    reqs = []
    for t in range(4):
        reqs.append(srv.submit("ab"[t % 2], "sample", src=np.arange(8),
                               seed=11 * t))
        srv.tick()
    ok = all(r.error is None and np.all(np.isfinite(r.result[1]))
             for r in reqs)
    return (srv.status & guards.FATAL, bool(ok and srv.evictions >= 2),
            f"evictions={srv.evictions}")


def _serve_stale_tenant_mutation(rng):
    """Serving fault (DESIGN.md §13): a tenant's dataset mutates between
    ``submit`` and ``tick``, killing the submitted request's frontier
    rows.  The tick must surface ``EPOCH_STALE`` on THAT request's own
    status word (its own ``EstimationError`` under ``REPRO_CHECKS=1``)
    while a clean tenant's request in the SAME tick is served normally --
    per-request isolation, never a poisoned batch."""
    from repro.core.kernels_fn import gaussian
    from repro.core.serving import KernelGraphServable

    srv = KernelGraphServable()
    srv.add_tenant("mut", _dataset(rng), gaussian(1.0), block_size=32,
                   seed=0)
    srv.add_tenant("ok", _dataset(rng) + np.float32(1.0), gaussian(1.0),
                   block_size=32, seed=1)
    bad = srv.submit("mut", "sample", src=np.arange(8), seed=3)
    good = srv.submit("ok", "sample", src=np.arange(8), seed=4)
    srv.dataset("mut").delete_rows(np.arange(8))   # kill the frontier
    srv.tick()
    clean = good.error is None and not (good.status & guards.EPOCH_STALE)
    return (bad.status & guards.EPOCH_STALE, bool(clean),
            guards.decode_status(bad.status or 0) or "no flag")


SCENARIOS: Dict[str, Callable] = {
    "nan_rows_hashed_query": _nan_rows_hashed_query,
    "inf_rows_sampler": _inf_rows_sampler,
    "tiny_bandwidth_zero_mass": _tiny_bandwidth_zero_mass,
    "duplicate_points_survive": _duplicate_points_survive,
    "corrupt_hash_state": _corrupt_hash_state,
    "adversarial_far_field": _adversarial_far_field,
    "reject_exhaustion": _reject_exhaustion,
    "robust_escalation": _robust_escalation,
    "silent_host_watchdog": _silent_host_watchdog,
    "overflow_insert_storm": _overflow_insert_storm,
    "delete_query_race": _delete_query_race,
    "serve_eviction_mid_stream": _serve_eviction_mid_stream,
    "serve_stale_tenant_mutation": _serve_stale_tenant_mutation,
}

#: scenarios whose point is graceful SURVIVAL (no fatal flag expected);
#: everything else must be DETECTED (flag set or EstimationError raised)
SURVIVE_OK = frozenset((
    "duplicate_points_survive", "reject_exhaustion", "robust_escalation",
    "serve_eviction_mid_stream"))


def run_scenario(name: str, seed: int = 0) -> Dict:
    """Run one registered scenario; returns the outcome dict.  Injection
    and outcome flow through the obs event ring (DESIGN.md §15.2) so a
    chaos campaign is auditable from the same registry as the metrics."""
    rng = np.random.default_rng(seed)
    _m.event("chaos.inject", scenario=name, seed=seed)
    out = _outcome(lambda: SCENARIOS[name](rng))
    _m.event("chaos.outcome", scenario=name, detected=out["detected"],
             survived=out["survived"])
    return out


def run_all(seed: int = 0) -> Dict[str, Dict]:
    """Run every scenario (CI entry point used by ``tests/test_chaos.py``)."""
    return {name: run_scenario(name, seed=seed) for name in SCENARIOS}
