"""Quickstart: sub-quadratic kernel-matrix algorithms in 60 seconds.

Builds a kernel graph over a synthetic point cloud and runs the paper's
pipeline end-to-end using only KDE-query-powered primitives -- no n x n
matrix is ever materialized by the algorithms (oracles are used here only
to *verify* the answers).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.eigen import top_eigenvalue, top_eigenvalue_exact
from repro.core.cluster.spectral import cluster_accuracy, spectral_cluster
from repro.core.kernels_fn import gaussian
from repro.core.lowrank import fkv_lowrank
from repro.core.sparsify import spectral_sparsify
from repro.data.synthetic_points import gaussian_clusters


def main():
    n = 1200
    x, labels = gaussian_clusters(n=n, d=6, k=2, spread=0.3, sep=1.2, seed=0)
    kernel = gaussian(bandwidth=1.0)
    print(f"== kernel graph on {n} points (never materialized: "
          f"{n * n:,} entries) ==")

    # 1. spectral sparsification (Theorem 5.3)
    g = spectral_sparsify(x, kernel, num_edges=8 * n, estimator="stratified",
                          seed=0)
    print(f"sparsifier: {g.num_edges} edges "
          f"({g.num_edges / (n * (n - 1) / 2):.1%} of all pairs), "
          f"{g.kernel_evals:,} kernel evals "
          f"(cost ~ n^1.5: wins over the n^2 matrix beyond ~10^4 points)")

    # 2. spectral clustering on the sparsifier (Section 6.2)
    res = spectral_cluster(g, 2, seed=0)
    print(f"clustering accuracy vs ground truth: "
          f"{cluster_accuracy(res.labels, labels, 2):.3f}")

    # 3. low-rank approximation (Corollary 5.14)
    lra = fkv_lowrank(x, kernel, rank=8, estimator="rs", seed=0)
    print(f"rank-8 LRA: {lra.kernel_evals:,} kernel evals "
          f"({lra.kernel_evals / n**2:.2f} n^2)")

    # 4. top eigenvalue (Theorem 5.22)
    eig = top_eigenvalue(x, kernel, t=200, seed=0)
    truth = top_eigenvalue_exact(kernel, x)
    print(f"top eigenvalue: estimate {eig.eigenvalue:.1f} vs exact "
          f"{truth:.1f} ({abs(eig.eigenvalue / truth - 1):.1%} error, "
          f"{eig.kernel_evals:,} evals)")


if __name__ == "__main__":
    main()
