"""Batched serving with KDE attention: exact vs sub-quadratic decode.

Generates with a small model twice -- once with exact cached attention, once
with the paper's KDE attention (top-P blocks + estimated residual mass) --
and reports the agreement and the compute fraction.

  PYTHONPATH=src python examples/serve_decode.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_reduced
from repro.data.pipeline import make_batch
from repro.models import transformer as T
from repro.train.train_step import make_decode_step


def main():
    cfg = dataclasses.replace(get_reduced("yi_6b"), dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch, prompt_len, gen = 2, 192, 12
    kde_bk = 32
    max_len = ((prompt_len + gen + kde_bk - 1) // kde_bk) * kde_bk
    shape = ShapeConfig("serve", prompt_len, batch, "prefill")
    prompts = jnp.asarray(make_batch(cfg, shape, 0)["tokens"])

    kde_cfg = {"top_p": 5, "bk": kde_bk, "stride": 2}
    outs, logit_traces = {}, {}
    for impl in ("xla", "kde"):
        cache = T.init_cache(cfg, batch, max_len, jnp.float32)
        step = jax.jit(make_decode_step(
            cfg, impl=impl, kde_cfg=kde_cfg if impl == "kde" else None))
        tok = prompts[:, :1]
        toks, lgs = [], []
        for pos in range(prompt_len + gen - 1):
            nxt, logits, cache = step(params, cache, tok, jnp.int32(pos))
            tok = prompts[:, pos + 1:pos + 2] if pos + 1 < prompt_len \
                else nxt[:, None]
            if pos + 1 >= prompt_len:
                toks.append(np.asarray(nxt))
                lgs.append(np.asarray(logits[:, -1, :cfg.vocab_size]))
        outs[impl] = np.stack(toks, 1)
        logit_traces[impl] = np.stack(lgs, 1)
        print(f"{impl:4s}: generated {outs[impl].shape[1]} tokens/seq "
              f"-> {outs[impl][0][:8].tolist()}...")

    a, b = logit_traces["xla"][:, 0], logit_traces["kde"][:, 0]
    cos = np.mean([np.corrcoef(x1, x2)[0, 1] for x1, x2 in zip(a, b)])
    nb = max_len // kde_cfg["bk"]
    frac = (1 / kde_cfg["stride"]) + kde_cfg["top_p"] / nb
    print(f"first-step logits correlation exact vs KDE: {cos:.4f}")
    print(f"KDE attention touches ~{min(frac, 1.0):.0%} of cache entries "
          f"per step at this toy scale; at 500k context with the production "
          f"config (bk=512, top_p=16, stride=16) it touches ~8%")


if __name__ == "__main__":
    main()
