"""Kernel-graph analytics over LM embeddings -- the paper's algorithms run
against framework tensors (DESIGN.md §3), every pipeline on the fused
device engine (DESIGN.md §7).

Trains a tiny LM for a few steps, takes its token-embedding table, and runs
the full Table-1 application suite on the embedding kernel graph: sparsify,
spectral + local clustering, a Laplacian solve, the top eigenvalue,
arboricity, triangle weight.  This is the kind of corpus/embedding analysis
(e.g. vocabulary community structure) the kernel-graph toolkit enables at
scales where the n x n matrix cannot exist.

  PYTHONPATH=src python examples/kernel_graph_analytics.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_reduced
from repro.core.cluster.local import same_cluster_test
from repro.core.cluster.spectral import spectral_cluster
from repro.core.eigen import top_eigenvalue
from repro.core.graph.arboricity import estimate_arboricity
from repro.core.graph.triangles import estimate_triangle_weight
from repro.core.kernels_fn import gaussian, median_bandwidth
from repro.core.laplacian import cg_laplacian
from repro.core.sparsify import spectral_sparsify
from repro.data.pipeline import make_batch
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, init_adamw
from repro.train.train_step import make_train_step


def main():
    cfg = dataclasses.replace(get_reduced("granite_3_2b"), dtype="float32")
    shape = ShapeConfig("t", 128, 4, "train")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    state = init_adamw(params)
    print("== training a small LM for 20 steps ==")
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, i).items()}
        params, state, m = step(params, state, batch)
    print(f"final loss: {float(m['loss']):.3f}")

    emb = np.asarray(params["embed"])[:cfg.vocab_size]
    emb = emb / np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-9)
    n = emb.shape[0]
    bw = median_bandwidth(jnp.asarray(emb))
    kernel = gaussian(bandwidth=bw)
    print(f"== kernel graph over {n} token embeddings (bw={bw:.3f}) ==")

    g = spectral_sparsify(emb, kernel, num_edges=10 * n,
                          estimator="stratified", seed=0)
    print(f"sparsifier: {g.num_edges} edges, {g.kernel_evals:,} kernel evals")

    res = spectral_cluster(g, 2, seed=0)
    sizes = np.bincount(res.labels)
    print(f"token communities: sizes={sizes.tolist()} "
          f"(bottom eigenvalues {np.round(res.eigenvalues, 4).tolist()})")

    # Laplacian solve on the sparsifier (Section 5.1.1, fused device CG).
    b = np.random.default_rng(0).standard_normal(n)
    b -= b.mean()
    sol, resid = cg_laplacian(g, b, iters=200)
    print(f"Laplacian solve on the sparsifier: residual {resid:.2e}")

    # Same-cluster test between two tokens (Algorithm 6.1, one fused walk).
    same = np.where(res.labels == res.labels[0])[0]
    diff = np.where(res.labels != res.labels[0])[0]
    i0 = int(same[1]) if len(same) > 1 else 1
    i1 = int(diff[0]) if len(diff) else n - 1
    lc = same_cluster_test(emb, kernel, 0, i1, walk_length=5, num_walks=200,
                           seed=0)
    print(f"same-cluster(0, {i1})? {lc.same_cluster} "
          f"(stat {lc.statistic:.2e} vs thr {lc.threshold:.2e}); "
          f"same-cluster(0, {i0})? "
          f"{same_cluster_test(emb, kernel, 0, i0, walk_length=5, num_walks=200, seed=1).same_cluster}")

    # Top eigenvalue from a submatrix (Algorithm 5.18, fused noisy power).
    eig = top_eigenvalue(emb, kernel, t=min(192, n), method="noisy_power",
                         seed=0)
    print(f"top eigenvalue ~ {eig.eigenvalue:.1f} "
          f"({eig.kernel_evals:,} evals + "
          f"{eig.matvec_sampled_evals:,} sampled matvec lookups)")

    arb = estimate_arboricity(emb, kernel, num_edges=4 * n,
                              estimator="stratified", seed=0)
    print(f"embedding-graph arboricity (densest community density): "
          f"{arb.density:.2f}")

    tri = estimate_triangle_weight(emb, kernel, num_edges=300,
                                   neighbor_samples=12,
                                   estimator="stratified", seed=0)
    print(f"total triangle weight (clustering-coefficient mass): "
          f"{tri.total_weight:.3e}")


if __name__ == "__main__":
    main()
