"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
CPU, with checkpointing and auto-resume (deliverable b).

Default is a quick preset so the script finishes in minutes; pass
``--preset 100m --steps 300`` for the full run.

  PYTHONPATH=src python examples/train_lm.py                # ~25M, 60 steps
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.configs.base import ArchConfig, ShapeConfig
from repro.data.pipeline import make_batch
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, init_adamw
from repro.train.train_step import make_train_step

PRESETS = {
    # ~26M params: d=512, 8 layers
    "quick": ArchConfig(name="lm26m", family="dense", num_layers=8,
                        d_model=512, num_heads=8, num_kv_heads=4, d_ff=1536,
                        vocab_size=8192, dtype="float32"),
    # ~112M params: d=768, 12 layers (GPT-2-small-ish)
    "100m": ArchConfig(name="lm100m", family="dense", num_layers=12,
                       d_model=768, num_heads=12, num_kv_heads=6, d_ff=3072,
                       vocab_size=32768, dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="quick")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    shape = ShapeConfig("lm", args.seq, args.batch, "train")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"== {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens ==")

    state = init_adamw(params)
    start = 0
    if ckpt.latest_step(args.ckpt_dir) is not None:
        (params, state), start = ckpt.restore(args.ckpt_dir, (params, state))
        print(f"resumed from step {start}")
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=6e-4, warmup_steps=20), remat=True),
        donate_argnums=(0, 1))

    first = None
    t_start = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, i).items()}
        t0 = time.time()
        params, state, m = step(params, state, batch)
        loss = float(m["loss"])
        first = loss if first is None else first
        if i % 10 == 0 or i == args.steps - 1:
            tput = args.batch * args.seq / max(time.time() - t0, 1e-9)
            print(f"step {i:4d}  loss {loss:.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  {tput:,.0f} tok/s",
                  flush=True)
        if (i + 1) % 50 == 0:
            ckpt.save(args.ckpt_dir, i + 1, (params, state))
    ckpt.save(args.ckpt_dir, args.steps, (params, state))
    print(f"done in {time.time() - t_start:.0f}s; "
          f"loss {first:.3f} -> {loss:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
