"""Property tests for kernel functions (Table 1) -- hypothesis-driven where
available; the property tests degrade to a fixed random draw without it."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ImportError:  # pragma: no cover - env without hypothesis
    hypothesis = None

from repro.core.kernels_fn import (exponential, gaussian, laplacian,
                                   make_kernel, median_bandwidth,
                                   rational_quadratic, squared_kernel_dataset)

KERNELS = [gaussian(1.0), exponential(1.3), laplacian(0.8),
           rational_quadratic(beta=1.0)]

if hypothesis is not None:
    points = hnp.arrays(np.float32, (7, 5),
                        elements=st.floats(-3, 3, width=32)).map(np.asarray)

    def property_test(f):
        return hypothesis.settings(max_examples=20, deadline=None)(
            hypothesis.given(x=points)(f))
else:
    _X_FALLBACK = np.random.default_rng(0).uniform(-3, 3, (7, 5)).astype(np.float32)

    def property_test(f):
        return pytest.mark.parametrize("x", [_X_FALLBACK])(f)


@pytest.mark.parametrize("ker", KERNELS, ids=lambda k: k.name)
@property_test
def test_kernel_range_symmetry_diag(ker, x):
    k = np.asarray(ker.matrix(jnp.asarray(x)))
    assert np.all(k <= 1.0 + 1e-5) and np.all(k >= 0.0)
    np.testing.assert_allclose(k, k.T, atol=1e-5)
    # exponential takes sqrt(f32 noise) on the diagonal: |x|^2 ~ 45 at
    # eps_f32 gives sqrt(4.5e-5) ~ 7e-3 absolute
    np.testing.assert_allclose(np.diag(k), 1.0, atol=2e-2)


@pytest.mark.parametrize("name", ["gaussian", "exponential", "laplacian"])
@property_test
def test_squaring_constant(name, x):
    """Section 5.2: k(x,y)^2 == k(cx, cy)."""
    ker = make_kernel(name, bandwidth=1.0)
    xs = squared_kernel_dataset(ker, jnp.asarray(x))
    k = np.asarray(ker.matrix(jnp.asarray(x)))
    k2 = np.asarray(ker.matrix(xs))
    np.testing.assert_allclose(k * k, k2, atol=2e-4)


def test_pairs_matches_matrix_diagonal():
    """Kernel.pairs evaluates aligned pairs without the (w, w) matrix."""
    rng = np.random.default_rng(0)
    a = rng.normal(0, 1, (23, 4)).astype(np.float32)
    b = rng.normal(0, 1, (23, 4)).astype(np.float32)
    for ker in KERNELS:
        full = np.asarray(ker.pairwise(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_allclose(np.asarray(ker.pairs(a, b)),
                                   np.diagonal(full), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("ker", KERNELS[:3], ids=lambda k: k.name)
def test_kernel_matrix_psd(ker):
    """Fact 3.5: reproducing-kernel matrices are PSD."""
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (40, 4)).astype(np.float32)
    k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
    ev = np.linalg.eigvalsh((k + k.T) / 2)
    assert ev.min() > -1e-6


def test_median_bandwidth():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 2.0, (256, 3)).astype(np.float32)
    bw = median_bandwidth(jnp.asarray(x))
    d = np.linalg.norm(x[:, None] - x[None, :], axis=-1)
    med = np.median(d[np.triu_indices(256, 1)])
    assert abs(bw - med) / med < 0.25
