"""KDE estimator correctness (Definition 1.1) + multilevel structure."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kde.base import (ExactBlockKDE, ExactKDE, RSKDE,
                                 StratifiedKDE, make_estimator)
from repro.core.kde.hbe import GridHBE
from repro.core.kde.multilevel import MultiLevelKDE
from repro.core.kernels_fn import gaussian, laplacian


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(1)
    x = rng.normal(0, 0.5, (700, 6)).astype(np.float32)
    ker = gaussian(bandwidth=2.0)
    ex = ExactKDE(x, ker)
    truth = np.asarray(ex.query(x[:48]))
    return x, ker, truth


def test_exact_matches_dense(data):
    x, ker, truth = data
    k = np.asarray(ker.matrix(jnp.asarray(x)))
    np.testing.assert_allclose(truth, k[:48].sum(1), rtol=2e-5)


def test_rs_relative_error(data):
    x, ker, truth = data
    est = RSKDE(x, ker, num_samples=250, seed=0)
    vals = np.asarray(est.query(x[:48]))
    rel = np.abs(vals / truth - 1)
    assert rel.mean() < 0.12, rel.mean()
    assert est.evals == 48 * 250  # eval accounting


def test_stratified_beats_rs_variance(data):
    """Law of total variance: stratified <= RS at equal sample count."""
    x, ker, truth = data
    errs_rs, errs_st = [], []
    for seed in range(12):
        rs = RSKDE(x, ker, num_samples=176, seed=seed)
        st = StratifiedKDE(x, ker, block_size=64, samples_per_block=16,
                           seed=seed)
        errs_rs.append(np.mean((np.asarray(rs.query(x[:16])) - truth[:16]) ** 2))
        errs_st.append(np.mean((np.asarray(st.query(x[:16])) - truth[:16]) ** 2))
    assert np.mean(errs_st) <= np.mean(errs_rs) * 1.25


def test_exact_block_sums(data):
    x, ker, truth = data
    eb = ExactBlockKDE(x, ker, block_size=64)
    bs = np.asarray(eb.block_sums(jnp.asarray(x[:8])))
    assert bs.shape == (8, eb.num_blocks)
    np.testing.assert_allclose(bs.sum(1), truth[:8], rtol=2e-4)


def test_grid_hbe_laplacian():
    rng = np.random.default_rng(2)
    x = rng.normal(0, 1.0, (600, 8)).astype(np.float32)
    ker = laplacian(bandwidth=4.0)
    ex = ExactKDE(x, ker)
    truth = np.asarray(ex.query(x[:24]))
    hbe = GridHBE(x, ker, num_far_samples=128, seed=0)
    vals = np.asarray(hbe.query(x[:24]))
    rel = np.abs(vals / truth - 1)
    assert rel.mean() < 0.15, rel.mean()
    assert hbe.evals < 24 * 600  # sublinear per query


def test_grid_hbe_far_degenerate_regression():
    """One bucket holds >90% of the points and every FAR sample lands in
    it: the seed estimator masked the FAR term to zero (estimate biased
    low by the whole complement mass); the fix sweeps/resamples the
    explicit complement, recovering the exact answer when the complement
    fits in the sample budget."""
    rng = np.random.default_rng(42)
    d = 4
    cluster = rng.normal(0, 0.01, (500, d)).astype(np.float32)
    out = (rng.normal(0, 0.01, (12, d))
           + np.array([0.3] + [0.0] * (d - 1))).astype(np.float32)
    x = np.concatenate([cluster, out]).astype(np.float32)
    ker = laplacian(bandwidth=4.0)
    truth = float(ExactKDE(x, ker).query(jnp.asarray(x[:1]))[0])
    # seed 8: the bucket holds all 500 cluster points (>96% of the data)
    # and all 16 FAR samples collide with it -- the degenerate case.
    hbe = GridHBE(x, ker, cell_width=0.2, num_far_samples=16,
                  max_bucket=512, seed=8)
    est = float(hbe.query(jnp.asarray(x[:1]))[0])
    # complement (12 outliers) <= budget -> exact sweep: 500 NEAR + 16
    # collided FAR + 12 complement evals, and the estimate is exact.
    assert hbe.evals == 500 + 16 + 12
    np.testing.assert_allclose(est, truth, rtol=1e-5)
    # the dropped FAR mass is material: NEAR alone is >2% low
    near = float(GridHBE(x, ker, cell_width=0.2, num_far_samples=0,
                         max_bucket=512, seed=8).query(
                             jnp.asarray(x[:1]))[0])
    assert abs(near / truth - 1) > 0.02


def test_multilevel_structure(data):
    """Alg 4.1: every dyadic segment estimator answers segment sums."""
    x, ker, _ = data
    tree = MultiLevelKDE(x, ker, lambda xs, seed: ExactKDE(xs, ker),
                         leaf_size=64)
    n = x.shape[0]
    q = jnp.asarray(x[:4])
    full = np.asarray(tree.segment_query(q, 0, n))
    (l0, l1), (r0, r1) = tree.children(0, n)
    left = np.asarray(tree.segment_query(q, l0, l1))
    right = np.asarray(tree.segment_query(q, r0, r1))
    np.testing.assert_allclose(left + right, full, rtol=1e-4)
    assert tree.depth >= 3


def test_factory():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (128, 4)).astype(np.float32)
    ker = gaussian(1.0)
    for name in ("exact", "rs", "stratified", "exact_block", "grid_hbe",
                 "hash"):
        est = make_estimator(name, x, ker, seed=0)
        v = np.asarray(est.query(x[:4]))
        assert v.shape == (4,) and np.all(np.isfinite(v))


def test_pallas_backed_exact():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (300, 5)).astype(np.float32)
    ker = gaussian(1.0)
    a = ExactKDE(x, ker, use_pallas=True)
    b = ExactKDE(x, ker, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a.query(x[:8])),
                               np.asarray(b.query(x[:8])), rtol=1e-4)
