"""Graph applications: local clustering (6.1), spectral clustering (6.2),
arboricity (6.3), weighted triangles (6.4)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cluster.local import l2_distance_statistic, same_cluster_test
from repro.core.cluster.spectral import (cluster_accuracy, kmeans,
                                         laplacian_eigenvectors,
                                         spectral_cluster)
from repro.core.graph.arboricity import (estimate_arboricity,
                                         exact_arboricity,
                                         greedy_densest_subgraph)
from repro.core.graph.triangles import (estimate_triangle_weight,
                                        exact_triangle_weight)
from repro.core.kernels_fn import gaussian
from repro.core.sampling.edge import NeighborSampler
from repro.core.sparsify import spectral_sparsify
from repro.data.synthetic_points import gaussian_clusters, nested


@pytest.fixture(scope="module")
def clustered():
    x, lab = gaussian_clusters(n=500, d=4, k=2, spread=0.3, sep=1.2, seed=3)
    ker = gaussian(bandwidth=1.0)
    return x, lab, ker


# ------------------------------------------------------------- local
def test_l2_tester_calibration():
    """CDVV14 statistic: ~0 for equal distributions, ~||p-q||^2 else."""
    rng = np.random.default_rng(0)
    n, r = 200, 4000
    p = rng.dirichlet(np.ones(n))
    q = rng.dirichlet(np.ones(n))
    cp = rng.poisson(r * p)
    cq1 = rng.poisson(r * p)
    cq2 = rng.poisson(r * q)
    same = l2_distance_statistic(cp, cq1, r, r)
    diff = l2_distance_statistic(cp, cq2, r, r)
    true = np.sum((p - q) ** 2)
    assert abs(same) < 0.3 * true
    assert abs(diff - true) < 0.5 * true


def test_local_clustering(clustered):
    """Theorem 6.9: same-cluster detection via walk distribution testing."""
    x, lab, ker = clustered
    nb = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=0)
    i0 = np.where(lab == 0)[0]
    i1 = np.where(lab == 1)[0]
    r_same = same_cluster_test(x, ker, int(i0[0]), int(i0[3]), walk_length=6,
                               num_walks=400, sampler=nb, seed=0)
    r_diff = same_cluster_test(x, ker, int(i0[0]), int(i1[0]), walk_length=6,
                               num_walks=400, sampler=nb, seed=1)
    assert r_same.same_cluster
    assert not r_diff.same_cluster


# ------------------------------------------------------------- spectral
def test_kmeans_separated():
    rng = np.random.default_rng(0)
    pts = np.concatenate([rng.normal(0, 0.1, (50, 2)),
                          rng.normal(3, 0.1, (50, 2))])
    lab, _ = kmeans(pts, 2, seed=0)
    truth = np.array([0] * 50 + [1] * 50)
    assert cluster_accuracy(lab, truth, 2) == 1.0


def test_spectral_clustering_on_sparsifier(clustered):
    """Theorems 6.12/6.13: clustering the sparsifier matches ground truth."""
    x, lab, ker = clustered
    g = spectral_sparsify(x, ker, num_edges=10000, estimator="exact",
                          exact_blocks=True, seed=0)
    res = spectral_cluster(g, 2, seed=0)
    assert cluster_accuracy(res.labels, lab, 2) > 0.95


def test_spectral_clustering_nested():
    """The paper's Nested dataset (Section 7): k-means fails on raw
    coordinates, spectral clustering on the sparsifier succeeds."""
    x, lab = nested(n=900, seed=0)
    ker = gaussian(bandwidth=0.3)
    raw_lab, _ = kmeans(x.astype(np.float64), 2, seed=0)
    raw_acc = cluster_accuracy(raw_lab, lab, 2)
    g = spectral_sparsify(x, ker, num_edges=25000, estimator="exact",
                          exact_blocks=True, seed=0)
    res = spectral_cluster(g, 2, seed=0)
    acc = cluster_accuracy(res.labels, lab, 2)
    assert acc > 0.97, acc
    assert acc > raw_acc  # spectral beats k-means on nested circles


def test_laplacian_eigenvector_quality(clustered):
    """Theorem 6.13: subspace iteration finds the bottom eigenvectors."""
    x, lab, ker = clustered
    g = spectral_sparsify(x, ker, num_edges=10000, estimator="exact",
                          exact_blocks=True, seed=0)
    vals, vecs = laplacian_eigenvectors(g, 3, iters=80, seed=0)
    # the two cluster indicators live in the bottom-2 eigenspace
    assert vals[0] < 0.05
    assert vals[1] < 0.3


# ------------------------------------------------------------- arboricity
def test_greedy_peel_known_graph():
    # K4 (complete graph on 4 nodes, unit weights) density = 6/4
    src, dst = np.triu_indices(4, 1)
    d = greedy_densest_subgraph(4, src, dst, np.ones(6))
    assert abs(d - 1.5) < 1e-9
    # planted dense subgraph
    rng = np.random.default_rng(0)
    n = 60
    s2, d2 = np.triu_indices(10, 1)
    sparse_s = rng.integers(10, n, 80)
    sparse_d = rng.integers(10, n, 80)
    src = np.concatenate([s2, sparse_s])
    dst = np.concatenate([d2, sparse_d])
    w = np.ones(len(src))
    d = greedy_densest_subgraph(n, src, dst, w)
    assert d >= 45 / 10 * 0.5  # at least half the planted density


def test_arboricity_estimation(clustered):
    """Theorem 6.15: (1 +- eps) approximation from sampled edges."""
    x, lab, ker = clustered
    truth = exact_arboricity(ker, x)
    res = estimate_arboricity(x, ker, num_edges=10000, estimator="exact",
                              seed=0)
    assert abs(res.density - truth) / truth < 0.1, (res.density, truth)


# ------------------------------------------------------------- triangles
def test_triangle_estimation(clustered):
    """Theorem 6.17: (1 +- eps) total triangle weight."""
    x, lab, ker = clustered
    truth = exact_triangle_weight(ker, x)
    res = estimate_triangle_weight(x, ker, num_edges=400,
                                   neighbor_samples=24, estimator="exact",
                                   seed=0)
    assert abs(res.total_weight - truth) / truth < 0.2, \
        (res.total_weight, truth)
    # Theorem 6.17: query budget independent of n -- evals grow ~sqrt(n)
    # (blocked level-1 reads), far below the n^2 of materializing K
    big, _ = gaussian_clusters(n=1000, d=4, k=2, spread=0.3, sep=1.2, seed=3)
    res_big = estimate_triangle_weight(big, ker, num_edges=400,
                                       neighbor_samples=24,
                                       estimator="stratified", seed=0)
    res_small = estimate_triangle_weight(x, ker, num_edges=400,
                                         neighbor_samples=24,
                                         estimator="stratified", seed=0)
    assert res_big.kernel_evals < 2.5 * res_small.kernel_evals


def test_exact_triangle_oracle_small():
    """Cross-check the matmul oracle against brute force on a tiny set."""
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (12, 3)).astype(np.float32)
    ker = gaussian(1.0)
    k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
    np.fill_diagonal(k, 0)
    brute = 0.0
    for i in range(12):
        for j in range(i + 1, 12):
            for l in range(j + 1, 12):
                brute += k[i, j] * k[j, l] * k[i, l]
    assert abs(exact_triangle_weight(ker, x) - brute) / brute < 1e-6
