"""Fused application pipelines (DESIGN.md §7): ref-oracle agreement for the
device programs behind eigen / Laplacian / local clustering / triangles /
arboricity, accuracy vs the dense NumPy oracles, and eval-counter audits
against the analytic counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cluster.local import same_cluster_test
from repro.core.eigen import top_eigenvalue, top_eigenvalue_exact
from repro.core.graph.arboricity import estimate_arboricity, exact_arboricity
from repro.core.graph.triangles import (estimate_triangle_weight,
                                        exact_triangle_weight)
from repro.core.kernels_fn import gaussian
from repro.core.laplacian import cg_laplacian
from repro.core.sampling.edge import NeighborSampler
from repro.core.sparsify import spectral_sparsify
from repro.core.spectrum import approximate_spectrum
from repro.data.synthetic_points import gaussian_clusters
from repro.kernels.kde_sampler import ops as sops
from repro.kernels.kde_sampler import ref as sref


@pytest.fixture(scope="module")
def cloud():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.35, (300, 5)).astype(np.float32)
    ker = gaussian(bandwidth=2.0)
    k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
    return x, ker, k


@pytest.fixture(scope="module")
def clustered():
    x, lab = gaussian_clusters(n=400, d=4, k=2, spread=0.3, sep=1.2, seed=3)
    ker = gaussian(bandwidth=1.0)
    return x, lab, ker


# ------------------------------------------------------------- eigen
def test_noisy_power_scan_matches_ref_oracle(cloud):
    """The one-program noisy power method reproduces the unrolled ref
    oracle under the identical key stream."""
    x, ker, k = cloud
    t = 96
    ksub = jnp.asarray(k[:t, :t], jnp.float32)
    key = jax.random.PRNGKey(5)
    v0 = jax.random.normal(key, (t,), jnp.float32)
    v0 = v0 / jnp.linalg.norm(v0)
    keys = jax.random.split(jax.random.PRNGKey(6), 10)
    lam, v, st = sops.noisy_power_scan(ksub, v0, keys, num_samples=48)
    lam_r, v_r = sref.noisy_power_ref(ksub, v0, keys, 48)
    assert int(np.asarray(st)[0]) == 0, \
        "healthy run must come back with a clean status"
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_r), rtol=2e-5,
                               atol=2e-6)
    np.testing.assert_allclose(float(lam), float(lam_r), rtol=2e-5)


def test_top_eigenvalue_lemma_5_21_bound(cloud):
    """Lemma 5.21: |n/t lambda_1(K_S) - lambda_1(K)| <= c n / sqrt(t)."""
    x, ker, k = cloud
    n, t = k.shape[0], 150
    lam_true = top_eigenvalue_exact(ker, x)
    res = top_eigenvalue(x, ker, t=t, method="noisy_power", seed=0)
    assert abs(res.eigenvalue - lam_true) <= 2.0 * n / np.sqrt(t)


def test_eigen_counters_not_inflated(cloud):
    """PR-3 bugfix: kernel_evals counts the one-time t^2 materialization;
    the sampled matvec lookups are reported separately (the seed added
    t * |idx| fresh 'evals' per iteration)."""
    x, ker, _ = cloud
    t, eps = 150, 0.25
    res = top_eigenvalue(x, ker, t=t, eps=eps, method="noisy_power", seed=0)
    assert res.kernel_evals == t * t
    iters = max(int(np.ceil(np.log(max(t, 2) / eps) / np.sqrt(eps))), 8)
    assert res.matvec_sampled_evals == iters * t * max(t // 2, 8)
    res_p = top_eigenvalue(x, ker, t=t, eps=eps, method="power", seed=0)
    assert res_p.kernel_evals == t * t
    assert res_p.matvec_sampled_evals == 0


# ------------------------------------------------------------- laplacian
def test_laplacian_matvec_matches_sparsegraph(cloud):
    """The segment-sum device matvec is the SparseGraph.matvec oracle."""
    x, ker, _ = cloud
    g = spectral_sparsify(x, ker, num_edges=4000, estimator="exact",
                          exact_blocks=True, seed=0)
    p = np.random.default_rng(3).standard_normal(g.n)
    got = np.asarray(sops.laplacian_matvec(
        jnp.asarray(g.src, jnp.int32), jnp.asarray(g.dst, jnp.int32),
        jnp.asarray(g.weight, jnp.float32), jnp.asarray(p, jnp.float32),
        n=g.n), np.float64)
    np.testing.assert_allclose(got, g.matvec(p), rtol=2e-4, atol=2e-4)


def test_device_cg_residual_and_solution(cloud):
    """One-program CG: small residual and agreement with the dense
    pseudoinverse solve on the sparsifier Laplacian."""
    x, ker, _ = cloud
    g = spectral_sparsify(x, ker, num_edges=12000, estimator="exact",
                          exact_blocks=True, seed=0)
    b = np.random.default_rng(1).standard_normal(g.n)
    b -= b.mean()
    sol, res = cg_laplacian(g, b, iters=400)
    assert res < 1e-4 * np.linalg.norm(b)
    x_direct = np.linalg.lstsq(g.laplacian_dense(), b, rcond=None)[0]
    x_direct -= x_direct.mean()
    assert np.linalg.norm(sol - x_direct) / np.linalg.norm(x_direct) < 1e-3


# ------------------------------------------------------------- local
def test_signed_endpoint_stat_matches_bincount():
    """Device collision statistic == the numpy bincount oracle."""
    rng = np.random.default_rng(0)
    n = 120
    ends = rng.integers(0, n, size=500)
    signs = np.where(rng.uniform(size=500) < 0.5, 1.0, -1.0)
    got = float(sops.signed_endpoint_stat(jnp.asarray(ends, jnp.int32),
                                          jnp.asarray(signs, jnp.float32),
                                          n=n)[0])
    c = np.zeros(n)
    np.add.at(c, ends, signs)
    assert abs(got - float((c * c).sum())) < 1e-3


def test_same_cluster_confusion_and_counters(clustered):
    """2-cluster mixture: same-pairs accepted, cross-pairs rejected, and
    the eval counter matches the analytic fused-walk count."""
    x, lab, ker = clustered
    n = x.shape[0]
    i0 = np.where(lab == 0)[0]
    i1 = np.where(lab == 1)[0]
    cases = [(int(i0[0]), int(i0[5]), True), (int(i1[1]), int(i1[7]), True),
             (int(i0[0]), int(i1[0]), False), (int(i0[3]), int(i1[2]), False)]
    for seed, (u, w, want_same) in enumerate(cases):
        nb = NeighborSampler(x, ker, mode="blocked", exact_blocks=True,
                             seed=seed)
        res = same_cluster_test(x, ker, u, w, walk_length=6, num_walks=400,
                                sampler=nb, seed=seed)
        assert res.same_cluster == want_same, (u, w, res.statistic)
        # analytic count: W walks, T steps, each one level-1 read (W * n)
        # plus W exact level-2 rows of block_size columns
        rng = np.random.default_rng(seed)
        walks = (max(int(rng.poisson(400)), 1)
                 + max(int(rng.poisson(400)), 1))
        assert res.kernel_evals == 6 * walks * (n + nb.block_size)


def test_host_device_eval_parity(cloud):
    """DESIGN.md §15.1: on the flat blocked/exact pipelines the realized
    eval count folded off the device counter words must equal the
    analytic host-side ``.evals`` bookkeeping EXACTLY -- any drift means
    one side stopped describing the schedule the device actually ran."""
    x, ker, _ = cloud
    nb = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=0)
    nb.sample(np.arange(64, dtype=np.int64))
    assert nb.device_counters["evals"] == nb.evals
    assert nb.device_counters.status == 0
    e0, r0 = nb.evals, nb.device_counters["evals"]
    from repro.core.sampling.walks import random_walks
    random_walks(nb, np.zeros(16, np.int64), 4)
    assert nb.device_counters["evals"] - r0 == nb.evals - e0
    # stratified level-1 keeps the same contract
    nbs = NeighborSampler(x, ker, mode="blocked", samples_per_block=8,
                          seed=1)
    nbs.sample(np.zeros(32, np.int64))
    assert nbs.device_counters["evals"] == nbs.evals


# ------------------------------------------------------------- triangles
def test_triangle_scan_matches_ref_oracle(cloud):
    """The fused triangle program (exact level-1 path) reproduces the
    ref.py oracle: oriented pairs bit-for-bit, weights to f32 tolerance."""
    x, ker, k = cloud
    n, bs = 300, 32
    nb = (n + bs - 1) // bs
    xd = jnp.asarray(x)
    x_sq = jnp.sum(xd * xd, axis=-1)
    deg = jnp.asarray((k.sum(1) - 1.0).astype(np.float32))
    rng = np.random.default_rng(2)
    u = jnp.asarray(rng.integers(0, n, 64), jnp.int32)
    v = jnp.asarray((rng.integers(0, n - 1, 64) + 1 + np.arange(64)) % n,
                    jnp.int32)
    v = jnp.where(v == u, (v + 1) % n, v)
    keys = jax.random.split(jax.random.PRNGKey(9), 9)
    cfg = dict(kind="gaussian", inv_bw=1.0 / 2.0, beta=1.0, pairwise=None,
               block_size=bs, num_blocks=nb, n=n, s=8, exact=True,
               use_pallas=False, interpret=False, bm=128)
    uu, vv, w_hat, st = sops.triangle_edge_scan(xd, x_sq, u, v, deg, keys,
                                                **cfg)
    ru, rv, rw = sref.triangle_batch_ref(xd, x_sq, u, v, deg, keys,
                                         "gaussian", 1.0 / 2.0, 1.0, bs, n)
    assert int(np.asarray(st)[0]) == 0
    np.testing.assert_array_equal(np.asarray(uu), np.asarray(ru))
    np.testing.assert_array_equal(np.asarray(vv), np.asarray(rv))
    np.testing.assert_allclose(np.asarray(w_hat), np.asarray(rw), rtol=2e-4,
                               atol=1e-7)


def test_triangle_accuracy_and_counters(clustered):
    """Theorem 6.17 accuracy through the fused path + analytic evals."""
    x, lab, ker = clustered
    n = x.shape[0]
    truth = exact_triangle_weight(ker, x)
    m, ns = 400, 24
    res = estimate_triangle_weight(x, ker, num_edges=m, neighbor_samples=ns,
                                   estimator="exact", seed=0)
    assert abs(res.total_weight - truth) / truth < 0.2
    nbr = NeighborSampler(x, ker, mode="blocked", exact_blocks=True)
    bs = nbr.block_size
    # n*n degree preprocessing + m*(n + 1) frontier read and k(u,v) pairs
    # + ns*m*(bs + 1) draws and k(u,w) pairs
    assert res.kernel_evals == n * n + m * (n + 1) + ns * m * (bs + 1)

    spb = 16
    res_s = estimate_triangle_weight(x, ker, num_edges=m,
                                     neighbor_samples=ns,
                                     estimator="stratified", seed=0)
    nb = nbr.num_blocks
    assert res_s.kernel_evals == (n * nb * spb + m * (nb * spb + 1)
                                  + ns * m * (bs + 1))


# ------------------------------------------------------------- arboricity
def test_arboricity_accuracy_and_counters(clustered):
    """Theorem 6.15 accuracy through the fused edge-batch path + analytic
    evals (identical count structure to the sparsifier audit)."""
    x, lab, ker = clustered
    n = x.shape[0]
    truth = exact_arboricity(ker, x)
    m, batch = 8000, 512
    res = estimate_arboricity(x, ker, num_edges=m, estimator="exact",
                              seed=0, batch=batch)
    assert abs(res.density - truth) / truth < 0.1
    nbr = NeighborSampler(x, ker, mode="blocked", exact_blocks=True)
    drawn = ((m + batch - 1) // batch) * batch
    assert res.kernel_evals == n * n + drawn * (n + nbr.block_size + 1)


# ------------------------------------------------------------- spectrum
def test_spectrum_walk_counters(cloud):
    """The fused moment estimator's eval counter matches the analytic
    one-walk-program count."""
    x, ker, _ = cloud
    n = x.shape[0]
    length, srcs, wps = 6, 8, 16
    sp = approximate_spectrum(x, ker, length=length, num_sources=srcs,
                              walks_per_source=wps, seed=0)
    nbr = NeighborSampler(x, ker, mode="blocked", exact_blocks=True)
    walks = srcs * wps
    assert sp.kernel_evals == length * walks * (n + nbr.block_size)


# ------------------------------------------------------------- compiled path
def test_fused_apps_hit_compiled_path(cloud):
    """Repeated same-shape calls of the new application programs never
    retrace."""
    x, ker, k = cloud
    nbr = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=0)
    deg = jnp.asarray((k.sum(1) - 1.0).astype(np.float32))
    rng = np.random.default_rng(0)
    u = rng.integers(0, 300, 32)
    v = (u + 1 + rng.integers(0, 298, 32)) % 300
    g = spectral_sparsify(x, ker, num_edges=2000, estimator="exact",
                          exact_blocks=True, seed=0)
    b = rng.standard_normal(300)
    ksub = jnp.asarray(k[:64, :64], jnp.float32)
    v0 = jnp.ones(64, jnp.float32) / 8.0
    keys = jax.random.split(jax.random.PRNGKey(0), 4)

    def run_all():
        nbr.triangle_batches(u, v, deg, 4)
        cg_laplacian(g, b, iters=50)
        sops.noisy_power_scan(ksub, v0, keys, num_samples=16)
        sops.signed_endpoint_stat(jnp.zeros(10, jnp.int32),
                                  jnp.ones(10, jnp.float32), n=300)

    run_all()  # traces every program once
    before = dict(sops.TRACE_COUNTS)
    for _ in range(2):
        run_all()
    assert dict(sops.TRACE_COUNTS) == before, \
        "a fused application program retraced or fell off the compiled path"
