"""Hashed-KDE subsystem (kernels/kde_hash, DESIGN.md §10): oracle parity,
GridHBE equivalence, §2-contract level-1 reads, the ``level1="hash"``
sampler hybrid, estimator="hash" pipelines, and the sharded one-psum
query schedule (subprocesses own their XLA_FLAGS)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kde.base import ExactKDE, make_estimator
from repro.core.kde.hashed import HashedKDE
from repro.core.kde.hbe import GridHBE
from repro.core.kernels_fn import gaussian, laplacian
from repro.kernels.kde_hash import ops as hops
from repro.kernels.kde_hash import ref as href
from repro.kernels.kde_sampler import ops as sops


def _run(code: str, devices: int = 8) -> str:
    full = (f'import os\nos.environ["XLA_FLAGS"] = '
            f'"--xla_force_host_platform_device_count={devices}"\n'
            f'import sys; sys.path.insert(0, "src")\n' + code)
    p = subprocess.run([sys.executable, "-c", full], capture_output=True,
                       text=True, cwd=".")
    assert p.returncode == 0, p.stderr[-1200:]
    return p.stdout


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1.0, (700, 8)).astype(np.float32)
    ker = laplacian(bandwidth=4.0)
    truth = np.asarray(ExactKDE(x, ker).query(x[:24]))
    return x, ker, truth


def _cfg(ker, cw, num_far, n, **kw):
    base = dict(kind=ker.name, inv_bw=1.0 / ker.bandwidth,
                beta=getattr(ker, "beta", 1.0), pairwise=None,
                cell_width=cw, num_far=num_far, n=n)
    base.update(kw)
    return base


def test_hashed_query_matches_oracle_bitwise(data):
    """ops jnp path AND Pallas interpret path == ref.py oracle, bitwise."""
    x, ker, _ = data
    state, cw = hops.build_hash_state(x, ker, seed=0)
    xd = jnp.asarray(x)
    y = xd[:24]
    key = jax.random.PRNGKey(3)
    want, want_cnt = href.hashed_query_ref(xd, y, state, key, ker.name,
                                           1.0 / ker.bandwidth, 1.0, cw,
                                           64, 700)
    got, cnt, st = hops.hashed_query(xd, y, state, key,
                                     **_cfg(ker, cw, 64, 700))
    got_p, cnt_p, st_p = hops.hashed_query(xd, y, state, key,
                                           **_cfg(ker, cw, 64, 700,
                                                  use_pallas=True,
                                                  interpret=True))
    assert int(np.asarray(st)[0]) == 0 and int(np.asarray(st_p)[0]) == 0
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.array_equal(np.asarray(got_p), np.asarray(want))
    assert np.array_equal(np.asarray(cnt), np.asarray(want_cnt))
    assert np.array_equal(np.asarray(cnt_p), np.asarray(want_cnt))


def test_hashed_query_accuracy_and_sublinear_evals(data):
    """Definition 1.1 accuracy at O(max_bucket + num_far) evals/query."""
    x, ker, truth = data
    est = HashedKDE(x, ker, num_far_samples=128, seed=0)
    vals = np.asarray(est.query(x[:24]))
    rel = np.abs(vals / truth - 1)
    assert rel.mean() < 0.15, rel.mean()
    assert est.evals < 24 * 700            # sublinear per query
    assert est.evals >= 24 * 128           # FAR budget is counted


def test_hashed_query_batches_hit_compiled_path(data):
    """Repeated same-shape queries never retrace (TRACE_COUNTS)."""
    x, ker, _ = data
    est = HashedKDE(x, ker, seed=0)
    est.query(x[:16])
    before = sops.TRACE_COUNTS["hashed_query"]
    est.query(x[16:32])
    est.query(x[32:48])
    assert sops.TRACE_COUNTS["hashed_query"] == before


def test_hashed_matches_gridhbe_buckets_and_near(data):
    """Same seed => same random-shifted grid: the uint32 layout partitions
    the dataset exactly like GridHBE's uint64 keys, and the NEAR-only
    estimates (num_far=0, max_bucket covering every bucket) agree."""
    x, ker, _ = data
    n = x.shape[0]
    hbe = GridHBE(x, ker, num_far_samples=0, max_bucket=n, seed=0)
    est = HashedKDE(x, ker, num_far_samples=0, max_bucket=n, seed=0)
    # identical hash dims + shifts (same RNG call order)
    assert np.array_equal(np.asarray(est.state.dims), hbe.hash_dims)
    np.testing.assert_allclose(np.asarray(est.state.shift), hbe.shift)
    # partition equality: uint64 groups <-> uint32 groups bijectively
    lab64 = np.unique(hbe._keys, return_inverse=True)[1]
    lab32 = np.asarray(est.state.point_bucket)
    pairs = {(int(a), int(b)) for a, b in zip(lab64, lab32)}
    assert len(pairs) == len(np.unique(lab64)) == len(np.unique(lab32))
    # NEAR-only estimates agree (GridHBE with num_far_samples=0 returns
    # the exact bucket sum)
    got = np.asarray(est.query(x[:24]))
    want = np.asarray(hbe.query(jnp.asarray(x[:24])))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=1e-5)


def test_far_distribution_matches_gridhbe_ks(data):
    """On an empty-bucket query both estimators reduce to the plain RS
    law n * mean(k over s uniform draws); two-sample KS over seeds
    (manual D statistic, same style as tests/test_distributed.py)."""
    x, ker, _ = data
    y = np.full((1, x.shape[1]), 50.0, np.float32)   # far from every cell
    a, b = [], []
    m = 160
    for seed in range(m):
        hbe = GridHBE(x, ker, num_far_samples=64, seed=seed)
        a.append(float(hbe.query(jnp.asarray(y))[0]))
        est = HashedKDE(x, ker, num_far_samples=64, seed=seed)
        b.append(float(est.query(y)[0]))
    a, b = np.sort(a), np.sort(b)
    grid = np.union1d(a, b)
    d = np.abs(np.searchsorted(a, grid, side="right") / m
               - np.searchsorted(b, grid, side="right") / m).max()
    assert d < 2.2 * np.sqrt(2.0 / m), (d, np.mean(a), np.mean(b))


def test_hashed_block_sums_oracle_and_contract(data):
    """Level-1 hashed read == ref oracle bitwise (both Pallas-interpret
    and jnp paths); §2 contract: mean over seeds ~= exact masked sums
    (self excluded, floored)."""
    x, ker, _ = data
    n = x.shape[0]
    state, cw = hops.build_hash_state(x, ker, seed=0, max_bucket=128)
    xd = jnp.asarray(x)
    x_sq = jnp.sum(xd * xd, axis=-1)
    src = jnp.asarray(np.arange(0, 64, dtype=np.int32))
    bs_blk, nb = 64, 11
    kw = dict(kind=ker.name, inv_bw=1.0 / ker.bandwidth, beta=1.0,
              pairwise=None, num_far=2, block_size=bs_blk, num_blocks=nb,
              n=n)
    key = jax.random.PRNGKey(7)
    want = href.hashed_block_sums_ref(xd, src, state, key, ker.name,
                                      1.0 / ker.bandwidth, 1.0, 2, bs_blk,
                                      nb, n)
    got, st = hops.hashed_block_sums(xd, src, state, key, **kw)
    got_p, st_p = hops.hashed_block_sums(xd, src, state, key,
                                         use_pallas=True, interpret=True,
                                         **kw)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.array_equal(np.asarray(got_p), np.asarray(want))
    # unbiasedness against the exact §2 read (same masking, same floor)
    exact = np.asarray(sops.masked_block_sums(
        xd, x_sq, src, key, kind=ker.name, inv_bw=1.0 / ker.bandwidth,
        beta=1.0, pairwise=None, block_size=bs_blk, num_blocks=nb, n=n,
        s=16, exact=True)[0])
    acc = np.zeros_like(exact)
    reps = 150
    for i in range(reps):
        acc += np.asarray(hops.hashed_block_sums(
            xd, src, state, jax.random.PRNGKey(100 + i), **kw)[0])
    acc /= reps
    rel = np.abs(acc.sum(1) / exact.sum(1) - 1)
    assert rel.mean() < 0.1, rel.mean()


def test_level1_hash_sampler_consistency(data):
    """level1="hash": prob_of on the cached frontier equals the realized
    sampling probabilities; draws are valid, never the source itself."""
    from repro.core.sampling.edge import NeighborSampler
    x, ker, _ = data
    nbr = NeighborSampler(x, ker, mode="blocked", level1="hash", seed=0)
    src = np.arange(48) * 3
    v, q = nbr.sample(src)
    assert np.all(v >= 0) and np.all(v < x.shape[0])
    assert np.all(v != src)
    q2 = nbr.prob_of(src, v)
    np.testing.assert_allclose(q, q2, rtol=2e-4, atol=1e-8)
    # rejection-exact mode runs off the same cached hashed sums
    ve = nbr.sample_exact(src, rounds=4)
    assert np.all(ve >= 0) and np.all(ve < x.shape[0])
    assert np.all(ve != src)
    # eval counter: hashed level-1 is cheaper than the stratified read
    nbr_s = NeighborSampler(x, ker, mode="blocked", seed=0)
    assert nbr._level1_evals(48) < nbr_s._level1_evals(48)


def test_level1_hash_walk_and_distribution(data):
    """Hashed level-1 walks stay on device and the depth-2 draw law stays
    close to the true k(u, .)/deg(u) law (chi-square on a small n)."""
    from repro.core.sampling.edge import NeighborSampler
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.6, (120, 4)).astype(np.float32)
    ker = gaussian(bandwidth=1.5)
    nbr = NeighborSampler(x, ker, mode="blocked", level1="hash", seed=0,
                          hash_opts={"far_per_block": 4})
    end, path = nbr.walk(np.arange(16), length=5, record_path=True)
    assert end.shape == (16,) and path.shape == (5, 16)
    # draw distribution: chi-square of 4000 draws from one source
    k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
    p = k[7].copy()
    p[7] = 0.0
    p /= p.sum()
    draws = []
    for _ in range(120):
        v, _ = nbr.sample(np.full(40, 7))
        nbr._l1_cache = None            # fresh level-1 noise each batch
        draws.extend(v.tolist())
    counts = np.bincount(draws, minlength=120)
    exp = p * len(draws)
    keep = exp > 8
    chi2 = float(((counts[keep] - exp[keep]) ** 2 / exp[keep]).sum())
    df = int(keep.sum()) - 1
    # hashed level-1 block masses are estimates, so the realized law is
    # only approximately the target -- allow ~2x a generous 1e-4-level
    # normal-approximation chi-square quantile
    assert chi2 < 2.0 * (df + 4.0 * np.sqrt(2.0 * df) + 16.0), (chi2, df)


def test_sparsify_and_triangles_hash_estimator():
    """estimator="hash" end-to-end: fewer kernel evals than stratified,
    spectral error within 1.5x, triangle estimate in range."""
    from repro.core.graph.triangles import (estimate_triangle_weight,
                                            exact_triangle_weight)
    from repro.core.sparsify import spectral_sparsify
    rng = np.random.default_rng(0)
    n = 512
    x = rng.normal(0, 0.35, (n, 8)).astype(np.float32)
    ker = gaussian(bandwidth=3.0)
    t = 12 * n
    g_h = spectral_sparsify(x, ker, num_edges=t, estimator="hash", seed=0)
    g_s = spectral_sparsify(x, ker, num_edges=t, estimator="stratified",
                            seed=0)
    assert g_h.kernel_evals < g_s.kernel_evals
    k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
    np.fill_diagonal(k, 0.0)
    l_true = np.diag(k.sum(1)) - k
    v = np.random.default_rng(1).standard_normal((n, 24))
    v -= v.mean(0)

    def err(g):
        r = np.einsum("ij,ij->j", v, g.laplacian_dense() @ v) \
            / np.einsum("ij,ij->j", v, l_true @ v)
        return np.abs(r - 1.0).max()

    e_h, e_s = err(g_h), err(g_s)
    assert e_h < max(1.5 * e_s, 0.08), (e_h, e_s)
    tri_h = estimate_triangle_weight(x, ker, 500, 24, estimator="hash",
                                     seed=0)
    tri_s = estimate_triangle_weight(x, ker, 500, 24, estimator="stratified",
                                     seed=0)
    tw = exact_triangle_weight(ker, x)
    assert tri_h.kernel_evals < tri_s.kernel_evals
    assert abs(tri_h.total_weight / tw - 1) < 0.2
    # both pipelines share ONE hash layout (degrees + level-1 reads)
    assert g_h.kde_queries == g_s.kde_queries


def test_rownorm_and_factory_hash():
    """make_estimator("hash") and the Section 5.2 row-norm sampler accept
    the hashed backend unchanged."""
    from repro.core.sampling.rownorm import RowNormSampler
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.5, (256, 6)).astype(np.float32)
    ker = gaussian(1.5)
    est = make_estimator("hash", x, ker, seed=0)
    v = np.asarray(est.query(x[:8]))
    assert v.shape == (8,) and np.all(np.isfinite(v))
    s = RowNormSampler(x, ker, estimator="hash", seed=0)
    idx = s.sample(64)
    assert idx.shape == (64,) and np.all(idx < 256)
    k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
    want = (k ** 2).sum(1)
    rel = np.abs(s.row_norms_sq / want - 1)
    assert rel.mean() < 0.2, rel.mean()


def test_degrees_via_hash_match_exact(data):
    """Algorithm 4.3 degrees from the hashed estimator track the exact
    degrees (the DegreeSampler preprocessing path)."""
    from repro.core.sampling.vertex import approximate_degrees
    x, ker, _ = data
    est = HashedKDE(x, ker, num_far_samples=128, seed=0)
    deg = approximate_degrees(est)
    k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
    np.fill_diagonal(k, 0.0)
    want = k.sum(1)
    rel = np.abs(deg / np.maximum(want, 1e-12) - 1)
    assert np.median(rel) < 0.25, np.median(rel)


def test_sharded_hash_one_psum_and_oracle():
    """Sharded hashed query: exactly one psum / zero ppermute per batch,
    NEAR counts bitwise vs the single-device oracle, floats to f32
    tolerance, and NEAR-only estimates equal to the flat engine."""
    out = _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.kernels_fn import gaussian
from repro.kernels.kde_hash.sharded import ShardedHashTable
from repro.kernels.kde_hash import ops as hops, ref as href
from repro.kernels.kde_sampler.sharded import collective_counts

rng = np.random.default_rng(0)
n, d = 700, 8
x = rng.normal(0, 1.0, (n, d)).astype(np.float32)
ker = gaussian(bandwidth=2.0)
mesh = jax.make_mesh((8,), ("data",))
tab = ShardedHashTable(mesh, x, ker, seed=3)
y = jnp.asarray(x[:32])
key = jax.random.PRNGKey(5)
cc = collective_counts(lambda yy, kk: tab._program()(
    tab._keys, tab._members, tab._counts, tab._overflow, tab._dims,
    tab._shift, tab.x_sh, yy, kk), y, key)
assert cc["psum_total"] == 1 and cc["ppermute_total"] == 0, cc
est, cnt, st = tab.query(y, key)
assert int(np.asarray(st)[0]) == 0, st
ref_est, ref_cnt = href.sharded_hashed_query_ref(
    tab.x_pad, y, tab.shard_states, key, ker.name, 1.0 / ker.bandwidth,
    1.0, tab.spec.cell_width, tab.num_far, n, tab.shard_size)
assert np.array_equal(np.asarray(cnt), np.asarray(ref_cnt))
np.testing.assert_allclose(np.asarray(est), np.asarray(ref_est),
                           rtol=2e-5, atol=1e-5)
# NEAR-only: sharded union of local buckets == flat bucket layout
tab0 = ShardedHashTable(mesh, x, ker, seed=3, num_far_samples=0,
                        max_bucket=512)
est0, cnt0, _ = tab0.query(y, key)
state, cw = hops.build_hash_state(x, ker, seed=3, max_bucket=512)
estf, cntf, _ = hops.hashed_query(
    jnp.asarray(x), y, state, key, kind=ker.name,
    inv_bw=1.0 / ker.bandwidth, beta=1.0, pairwise=None, cell_width=cw,
    num_far=0, n=n)
assert np.array_equal(np.asarray(cnt0), np.asarray(cntf))
np.testing.assert_allclose(np.asarray(est0), np.asarray(estf), rtol=2e-5,
                           atol=1e-5)
# estimator adapter: one program per batch, accuracy vs dense truth
from repro.core.kde.hashed import HashedKDE
hk = HashedKDE(x, ker, seed=3, num_far_samples=128, mesh=mesh)
vals = np.asarray(hk.query(x[:32]))
truth = np.asarray(ker.matrix(jnp.asarray(x))[:32].sum(1))
assert np.abs(vals / truth - 1).mean() < 0.15
print("SHARDED_HASH_OK")
""")
    assert "SHARDED_HASH_OK" in out
