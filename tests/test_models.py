"""Per-architecture smoke tests (reduced configs, deliverable f) + layer
equivalence properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, ShapeConfig, get_config, get_reduced
from repro.data.pipeline import input_specs, make_batch, token_split
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.train.optimizer import init_adamw
from repro.train.train_step import make_decode_step, make_train_step

SMOKE = ShapeConfig("smoke", 64, 2, "train")


def _cfg(arch):
    return dataclasses.replace(get_reduced(arch), dtype="float32")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train(arch):
    """One forward + one train step on CPU: shapes correct, no NaNs."""
    cfg = _cfg(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SMOKE, 0).items()}
    logits, aux = T.forward(params, cfg, batch, remat=False)
    st = token_split(cfg, SMOKE)["tokens"]
    assert logits.shape == (2, st, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
    step = make_train_step(cfg)
    p2, o2, m = jax.jit(step)(params, init_adamw(params), batch)
    assert np.isfinite(float(m["loss"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    """Three cached decode steps; logits finite; cache advances."""
    cfg = _cfg(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if cfg.is_encdec or cfg.frontend != "none":
        fe = jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (2, 8, cfg.d_model)).astype(np.float32))
        enc_len = 8
    else:
        enc_len = 1
    cache = T.init_cache(cfg, 2, 32, jnp.float32, enc_len=enc_len)
    if cfg.is_encdec:
        cache["memory"] = T._run_encoder(params, cfg, fe, "xla")
    ds = jax.jit(make_decode_step(cfg))
    tok = jnp.zeros((2, 1), jnp.int32)
    for pos in range(3):
        nxt, logits, cache = ds(params, cache, tok, jnp.int32(pos))
        tok = nxt[:, None]
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_cells(arch):
    """Every (arch x shape) cell has well-defined input specs."""
    cfg = get_config(arch)
    for shape in SHAPES.values():
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        if shape.kind in ("train", "prefill"):
            split = token_split(cfg, shape)
            assert specs["tokens"].shape == (shape.global_batch,
                                             split["tokens"])


def test_param_count_close_to_nominal():
    """Analytic param counts are in the right ballpark for the full configs
    (these are the 6ND inputs for the roofline)."""
    expected = {"yi_6b": 6e9, "qwen2_5_14b": 14e9, "granite_3_2b": 2.5e9,
                "chatglm3_6b": 6e9, "rwkv6_3b": 3e9, "internvl2_1b": 0.6e9,
                "zamba2_7b": 7e9, "seamless_m4t_medium": 1.2e9,
                "qwen3_moe_235b_a22b": 235e9, "granite_moe_1b_a400m": 1.3e9}
    for arch, target in expected.items():
        n = get_config(arch).param_count()
        assert 0.4 * target < n < 2.1 * target, (arch, n, target)
    # MoE active < total
    moe = get_config("qwen3_moe_235b_a22b")
    assert moe.active_param_count() < 0.25 * moe.param_count()


def test_rwkv6_chunked_equals_scan():
    cfg = _cfg("rwkv6_3b")
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    x = jnp.asarray(np.random.default_rng(0).normal(
        0, 1, (2, 64, cfg.d_model)).astype(np.float32))
    y1 = S.rwkv6_chunked(lp["mix"], cfg, x, chunk=16)
    y2, _, _ = S.rwkv6_scan(lp["mix"], cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)


def test_mamba2_chunked_equals_scan():
    cfg = _cfg("zamba2_7b")
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    x = jnp.asarray(np.random.default_rng(0).normal(
        0, 1, (2, 64, cfg.d_model)).astype(np.float32))
    y1 = S.mamba2_chunked(lp["mix"], cfg, x, chunk=16)
    y2, _ = S.mamba2_scan(lp["mix"], cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)


def test_ssm_decode_matches_forward():
    """Sequential decode of rwkv6 reproduces the parallel forward."""
    cfg = _cfg("rwkv6_3b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)
    logits_par, _ = T.forward(params, cfg, {"tokens": toks}, remat=False,
                              seq_mixer="scan")
    cache = T.init_cache(cfg, 1, 16, jnp.float32)
    outs = []
    for pos in range(12):
        lg, cache = T.decode_step(params, cfg, toks[:, pos:pos + 1], cache,
                                  jnp.int32(pos))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_par),
                               atol=2e-3)


def test_dense_decode_matches_forward():
    cfg = _cfg("yi_6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 10)), jnp.int32)
    logits_par, _ = T.forward(params, cfg, {"tokens": toks}, remat=False)
    cache = T.init_cache(cfg, 2, 16, jnp.float32)
    outs = []
    for pos in range(10):
        lg, cache = T.decode_step(params, cfg, toks[:, pos:pos + 1], cache,
                                  jnp.int32(pos))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_par),
                               atol=2e-3)


def test_moe_sparse_matches_dense_at_high_capacity():
    """With capacity >> needed, scatter dispatch == dense reference."""
    cfg = _cfg("granite_moe_1b_a400m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    x = jnp.asarray(np.random.default_rng(0).normal(
        0, 0.5, (2, 16, cfg.d_model)).astype(np.float32))
    y_sparse, _ = L.moe_block(lp["mlp"], cfg, x, capacity_factor=8.0)
    y_dense, _ = L.moe_block_dense(lp["mlp"], cfg, x)
    np.testing.assert_allclose(np.asarray(y_sparse), np.asarray(y_dense),
                               atol=1e-4)


def test_rope_properties():
    """RoPE preserves norms and relative-position inner products."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (1, 2, 8, 16)).astype(np.float32))
    pos = jnp.arange(8)
    y = L.apply_rope(x, pos, "full")
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <R_m q, R_n k> depends only on m - n
    q = jnp.asarray(rng.normal(0, 1, (1, 1, 1, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (1, 1, 1, 16)).astype(np.float32))
    def score(m, n):
        qm = L.apply_rope(q, jnp.array([m]), "full")
        kn = L.apply_rope(k, jnp.array([n]), "full")
        return float(jnp.sum(qm * kn))
    assert abs(score(3, 1) - score(7, 5)) < 1e-4
    # glm2d leaves the second half untouched
    y2 = L.apply_rope(x, pos, "glm2d")
    np.testing.assert_allclose(np.asarray(y2)[..., 8:],
                               np.asarray(x)[..., 8:])


def test_kde_decode_attention_layer():
    """The 'kde' attention impl plugs into decode and approximates exact."""
    cfg = _cfg("yi_6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 1)), jnp.int32)
    cache = T.init_cache(cfg, 1, 128, jnp.float32)
    # warm the cache with 64 tokens
    for pos in range(64):
        _, cache = T.decode_step(params, cfg, toks, cache, jnp.int32(pos))
    lg_exact, _ = T.decode_step(params, cfg, toks, cache, jnp.int32(64),
                                impl="xla")
    lg_kde, _ = T.decode_step(params, cfg, toks, cache, jnp.int32(64),
                              impl="kde",
                              kde_cfg={"top_p": 4, "bk": 16, "stride": 2})
    a = np.asarray(lg_exact[..., :cfg.vocab_size])
    b = np.asarray(lg_kde[..., :cfg.vocab_size])
    # top-4 of 8 blocks with stride 2: close but not identical
    assert np.corrcoef(a.ravel(), b.ravel())[0, 1] > 0.98
