"""Fault-injection (chaos) suite -- DESIGN.md §11.

Drives ``repro.ft.chaos`` scenarios against the real pipelines.  The CI
chaos step runs this file with ``REPRO_CHECKS=1`` (fatal flags raise) on a
host platform faked to 8 devices; every scenario must either DETECT its
fault (status flag observed or ``EstimationError`` raised) or SURVIVE it
with sane output.  Silent garbage fails the suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft import chaos, guards

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name", sorted(chaos.SCENARIOS))
def test_scenario(name):
    report = chaos.run_scenario(name, seed=0)
    if name in chaos.SURVIVE_OK:
        assert report["survived"], (name, report)
    else:
        assert report["detected"], (name, report)


def test_detection_scenarios_raise_under_checks(monkeypatch):
    """With REPRO_CHECKS=1 the fatal-fault scenarios must escalate from
    advisory flags to hard EstimationErrors (the chaos CI contract)."""
    monkeypatch.setenv("REPRO_CHECKS", "1")
    for name in ("nan_rows_hashed_query", "corrupt_hash_state"):
        report = chaos.run_scenario(name, seed=0)
        assert report["detected"], (name, report)


def test_survival_scenarios_survive_under_checks(monkeypatch):
    """Graceful-degradation scenarios must keep working when flags are
    promoted to errors: recovery happens BELOW the check point."""
    monkeypatch.setenv("REPRO_CHECKS", "1")
    for name in sorted(chaos.SURVIVE_OK):
        report = chaos.run_scenario(name, seed=0)
        assert report["survived"], (name, report)


def test_status_flags_decode_round_trip():
    st = guards.NONFINITE | guards.BUCKET_OVERFLOW | guards.CG_NO_CONVERGE
    names = guards.decode_status(st)
    assert names == ["NONFINITE", "BUCKET_OVERFLOW", "CG_NO_CONVERGE"]
    assert guards.decode_status(0) == []


def test_raise_on_status_policy(monkeypatch):
    monkeypatch.setenv("REPRO_CHECKS", "1")
    with pytest.raises(guards.EstimationError, match="ZERO_MASS"):
        guards.raise_on_status(guards.ZERO_MASS, context="unit")
    # allowed flags never raise; the word is still returned for counters
    s = guards.raise_on_status(guards.REJECT_EXHAUSTED,
                               allow=guards.REJECT_EXHAUSTED)
    assert s == guards.REJECT_EXHAUSTED
    monkeypatch.setenv("REPRO_CHECKS", "0")
    assert guards.raise_on_status(guards.NONFINITE) == guards.NONFINITE


def test_checked_wrapper_flags_inf():
    """guards.checked turns in-trace float faults into hard errors."""
    def div(a, b):
        return a / b

    run = guards.checked(div)
    ok = run(jnp.float32(1.0), jnp.float32(2.0))
    assert float(ok) == 0.5
    with pytest.raises(Exception):
        run(jnp.float32(1.0), jnp.float32(0.0))


def test_robust_estimator_clean_path_never_escalates():
    """On healthy data the staged chain stops at its first stage."""
    from repro.core.kernels_fn import gaussian

    rng = np.random.default_rng(1)
    x = rng.standard_normal((160, 3)).astype(np.float32)
    est = guards.RobustEstimator(
        x, gaussian(1.0), seed=0,
        stage_kw={"hash": {"max_bucket": 64, "num_far_samples": 32}})
    vals = np.asarray(est.query(jnp.asarray(x[:24])))
    assert np.all(np.isfinite(vals)) and np.all(vals > 0)
    assert sum(est.escalations.values()) == 0
    assert set(est._stages) == {"hash"}, "later stages must stay unbuilt"
    assert est.evals > 0
    est.evals = 0
    assert est.evals == 0


def test_robust_estimator_factory_and_fallback_counters():
    from repro.core.kde.base import make_estimator
    from repro.core.kernels_fn import gaussian

    rng = np.random.default_rng(2)
    x = rng.standard_normal((96, 3)).astype(np.float32)
    est = make_estimator("robust", x, gaussian(1.0), seed=0)
    assert isinstance(est, guards.RobustEstimator)
    degs = est.degrees(batch=48)
    truth = np.asarray(gaussian(1.0).matrix(jnp.asarray(x)).sum(1)) - 1.0
    rel = np.abs(degs / np.maximum(truth, 1e-9) - 1)
    assert rel.mean() < 0.35, rel.mean()


def test_fallback_rate_warning(recwarn):
    guards.warn_fallback_rate(0, 100, rounds=8, slack=2.0)
    assert not [w for w in recwarn.list
                if issubclass(w.category, RuntimeWarning)]
    with pytest.warns(RuntimeWarning, match="fallback rate"):
        guards.warn_fallback_rate(60, 100, rounds=8, slack=2.0)


def test_serve_robust_dense_fallback_smoke():
    """--robust recomputes a poisoned decode step with dense attention
    (unit-level: the guarded-step policy, not the full CLI)."""
    calls = {"dense": 0}

    def kde_step(params, cache, cur, pos):
        return cur[:, 0], jnp.full((2, 4), jnp.nan), cache

    def dense_step(params, cache, cur, pos):
        calls["dense"] += 1
        return cur[:, 0], jnp.zeros((2, 4)), cache

    # mirror of launch.serve's guarded() policy
    cur = jnp.zeros((2, 1), jnp.int32)
    nxt, logits, _ = kde_step(None, {}, cur, 0)
    if not bool(jnp.all(jnp.isfinite(logits))):
        nxt, logits, _ = dense_step(None, {}, cur, 0)
    assert calls["dense"] == 1
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_serve_cli_has_robust_flag(capsys):
    from repro.launch import serve

    with pytest.raises(SystemExit):
        serve.main(["--help"])
    assert "--robust" in capsys.readouterr().out


def test_edge_batches_status_surfaced():
    """The sampler's status counters accumulate across fused programs and
    stay clean on a healthy pipeline."""
    from repro.core.kernels_fn import gaussian
    from repro.core.sampling.edge import NeighborSampler

    rng = np.random.default_rng(3)
    x = rng.standard_normal((200, 3)).astype(np.float32)
    ker = gaussian(1.0)
    nbr = NeighborSampler(x, ker, mode="blocked", block_size=32, seed=0)
    k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
    np.fill_diagonal(k, 0.0)
    degs = k.sum(1).astype(np.float32)
    cdf = (np.cumsum(degs) / degs.sum()).astype(np.float32)
    u, v, w, q_uv, q_vu = nbr.edge_batches(
        jnp.asarray(cdf), jnp.asarray(degs), float(degs.sum()), 256,
        batch=128)
    assert len(u) == 256 and np.all(np.isfinite(w))
    assert nbr.status & guards.FATAL == 0, guards.decode_status(nbr.status)
    assert isinstance(nbr.flag_counts, dict)


def test_chaos_and_watchdog_events_flow_through_registry():
    """DESIGN.md §15.2: chaos injections and watchdog heartbeat/decision
    traffic land in the obs event ring when the registry is enabled, and
    leave NO trace when it is disabled (the chaos path must not pay for
    telemetry it did not ask for)."""
    from repro.ft.watchdog import Watchdog
    from repro.obs import metrics as M

    M.reset()
    M.disable()
    chaos.run_scenario("silent_host_watchdog", seed=0)
    assert not M.events()                      # disabled -> nothing stored

    M.enable()
    try:
        report = chaos.run_scenario("silent_host_watchdog", seed=0)
        assert report["detected"]
        inj = M.events("chaos.inject")
        out = M.events("chaos.outcome")
        assert inj and inj[0][1]["scenario"] == "silent_host_watchdog"
        assert out and out[0][1]["detected"]
        # the scenario drove a real Watchdog: its beats + decision are in
        # the same ring
        beats = M.events("watchdog.beat")
        decisions = M.events("watchdog.decide")
        assert len(beats) == 3                 # 4 hosts, host 2 silent
        assert decisions and decisions[-1][1]["dead"] == [2]
        # direct decision path: a straggler flags in the event stream too
        M.reset()
        wd = Watchdog(hosts=3, now=0.0)
        for h, t in ((0, 1.0), (1, 1.0), (2, 9.0)):
            wd.beat(h, t, now=1.0)
        wd.decide(now=2.0)
        assert M.events("watchdog.decide")[-1][1]["stragglers"] == [2]
    finally:
        M.reset()
        M.disable()
