"""End-to-end behaviour tests: the paper's pipelines composed, data layer,
and the serving driver."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, ShapeConfig, get_reduced
from repro.core.cluster.spectral import cluster_accuracy, spectral_cluster
from repro.core.kernels_fn import gaussian, laplacian, median_bandwidth
from repro.core.laplacian import cg_laplacian, laplacian_dense
from repro.core.lowrank import fkv_lowrank, projection_error
from repro.core.sparsify import spectral_sparsify
from repro.data.pipeline import make_batch, token_split
from repro.data.synthetic_points import glove_like, mnist_like, nested, rings


def test_paper_pipeline_end_to_end():
    """Nested dataset -> sparsify (few-percent edge budget) -> spectral
    cluster -> solve a Laplacian system on the sparsifier.  The Section 7
    pipeline in miniature."""
    x, lab = nested(n=800, seed=0)
    ker = gaussian(bandwidth=0.3)
    n = x.shape[0]
    budget = int(0.06 * n * (n - 1) / 2)     # a few percent of all edges
    g = spectral_sparsify(x, ker, num_edges=budget, estimator="exact",
                          exact_blocks=True, seed=0)
    assert g.num_edges == budget
    res = spectral_cluster(g, 2, seed=0)
    acc = cluster_accuracy(res.labels, lab, 2)
    assert acc > 0.97, acc
    rng = np.random.default_rng(0)
    b = rng.standard_normal(n)
    b -= b.mean()
    sol, _ = cg_laplacian(g, b, iters=300)
    assert np.isfinite(sol).all()
    # edge-budget savings direction of the 41x claim: edges << n^2/2
    assert g.num_edges < 0.1 * n * n / 2


def test_rings_dataset_clusterable():
    x, lab = rings(n=600, seed=0)
    ker = gaussian(bandwidth=median_bandwidth(jnp.asarray(x)) * 0.25)
    g = spectral_sparsify(x, ker, num_edges=30000, estimator="exact",
                          exact_blocks=True, seed=0)
    res = spectral_cluster(g, 2, seed=1)
    assert cluster_accuracy(res.labels, lab, 2) > 0.9


def test_lra_on_paper_style_datasets():
    """MNIST-like / GloVe-like LRA with the paper's 25*rank rows setting."""
    for maker in (mnist_like, glove_like):
        x = maker(n=700)
        ker = laplacian(bandwidth=median_bandwidth(jnp.asarray(x), ord=1))
        k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
        res = fkv_lowrank(x, ker, rank=8, num_rows=200, estimator="rs",
                          seed=0)
        err = projection_error(k, res.u)
        fro2 = np.linalg.norm(k, "fro") ** 2
        assert err / fro2 < 0.35, err / fro2
        assert res.kernel_evals < 0.7 * k.size


def test_data_pipeline_determinism():
    cfg = get_reduced("yi_6b")
    shape = ShapeConfig("t", 64, 4, "train")
    b1 = make_batch(cfg, shape, step=3, seed=9)
    b2 = make_batch(cfg, shape, step=3, seed=9)
    b3 = make_batch(cfg, shape, step=4, seed=9)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].min() >= 0
    assert b1["tokens"].max() < cfg.vocab_size


def test_token_split_covers_shapes():
    for arch in ("internvl2_1b", "seamless_m4t_medium", "yi_6b"):
        cfg = get_reduced(arch)
        for shape in SHAPES.values():
            sp = token_split(cfg, shape)
            assert sp["tokens"] + sp["frontend"] == shape.seq_len


def test_serve_driver_runs():
    env = dict(os.environ, PYTHONPATH="src")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "yi_6b",
         "--reduced", "--batch", "2", "--prompt-len", "16", "--gen", "4"],
        capture_output=True, text=True, cwd=".", env=env)
    assert p.returncode == 0, p.stderr[-800:]
    assert "tok/s" in p.stdout


def test_serve_driver_kde_attention():
    env = dict(os.environ, PYTHONPATH="src")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "yi_6b",
         "--reduced", "--batch", "2", "--prompt-len", "32", "--gen", "4",
         "--attention", "kde"],
        capture_output=True, text=True, cwd=".", env=env)
    assert p.returncode == 0, p.stderr[-800:]
