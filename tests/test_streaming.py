"""Streaming kernel-graph engine (DESIGN.md §12): randomized
mutation-sequence equivalence against fresh rebuilds.

The contract under test: after ANY interleaving of insert / delete /
update, every consumer's patched derived state answers exactly like an
engine freshly built at the current epoch -- level-1 block sums and
``prob_of`` (deterministic exact level-1: tight allclose), degrees and
row norms (``degree_delta`` patch vs. recomputation), the hashed bucket
layout (same-key ``hashed_query`` parity vs. ``build_hash_state``), walk
draw streams (bitwise, shared PRNG key), and the 8-device sharded path
(subprocess) where the mutation program must also be jaxpr-verifiably
collective-free so the §9 one-psum-per-draw schedule is untouched.

Parity rule (the reason every equivalence test pins ``exact_blocks=True``
or an exact estimator): patched state = old estimate + EXACT delta, so
numeric equality with a fresh build holds only for deterministic level-1
reads.  Randomized (stratified / hashed-FAR) paths agree in distribution,
not per-draw -- those are covered by the TV test and the same-key hashed
parity instead.
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dataset import DynamicDataset, coalesce_mutations
from repro.core.kernels_fn import gaussian
from repro.ft import guards as _g


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    x0 = rng.normal(0, 0.7, size=(192, 6)).astype(np.float32)
    return rng, x0, gaussian(1.0)


def _mutate(ds, rng, n_ins=5, dele=(40, 44), upd=(50, 52), keep=()):
    """One standard interleaving: insert a few, delete a range (minus any
    ``keep`` slots a test still holds as a frontier), move two."""
    ins = rng.normal(0, 0.7, size=(n_ins, ds.d)).astype(np.float32)
    slots = ds.insert_rows(ins)
    dead = np.setdiff1d(np.arange(*dele), np.asarray(keep, np.int64))
    ds.delete_rows(dead)
    us = np.setdiff1d(np.arange(*upd), dead)
    ds.update_rows(us, rng.normal(0, 0.7, size=(len(us), ds.d))
                   .astype(np.float32))
    return slots


# --------------------------------------------------------------------- #
# dataset core: epochs, journal, coalescing
# --------------------------------------------------------------------- #
def test_dataset_journal_contract(data):
    rng, x0, _ = data
    ds = DynamicDataset(x0, capacity=256, journal_limit=4)
    assert ds.epoch == 0 and ds.num_live == 192 and ds.n == 256
    assert ds.mutations_since(0) == []

    slots = ds.insert_rows(x0[:3] + 0.5)
    assert ds.epoch == 1 and list(slots) == [192, 193, 194]
    assert ds.is_live(slots)
    ds.delete_rows(slots[:1])
    assert ds.epoch == 2 and not ds.is_live(slots)

    batches = ds.mutations_since(0)
    assert [b.kind for b in batches] == ["insert", "delete"]
    # journal_limit=4: after 5 batches an epoch-0 consumer must rebuild
    for _ in range(3):
        ds.update_rows(np.array([0]), x0[:1])
    assert ds.mutations_since(0) is None
    assert len(ds.mutations_since(ds.epoch - 2)) == 2

    # structural epoch bumps invalidate the whole journal
    e = ds.epoch
    ds.compact()
    assert ds.epoch == e + 1 and ds.mutations_since(e) is None
    assert ds.num_live == 194 and ds.is_live(np.arange(194))

    ds2 = DynamicDataset(x0[:30], capacity=32)
    e = ds2.epoch
    ds2.insert_rows(x0[:8])            # overflow -> grow (doubling)
    assert ds2.capacity >= 64 and ds2.mutations_since(e) is None
    assert ds2.num_live == 38

    # dead slots sit at sentinel coordinates: exactly zero kernel mass
    k = gaussian(1.0)
    ds3 = DynamicDataset(x0, capacity=256)
    ds3.delete_rows(np.array([7]))
    kv = np.asarray(k.pairwise(ds3.x_pad[:1], ds3.x_pad[7:8]))
    assert kv.item() == 0.0


def test_coalesce_telescopes(data):
    rng, x0, _ = data
    ds = DynamicDataset(x0, capacity=256)
    first = np.asarray(ds.x_pad[5])
    ds.update_rows(np.array([5]), x0[10:11] + 1.0)
    ds.update_rows(np.array([5]), x0[10:11] + 2.0)   # second hop
    ds.delete_rows(np.array([9]))
    slots, old_x, new_x, old_live, new_live = \
        coalesce_mutations(ds.mutations_since(0))
    assert list(slots) == [5, 9]
    i5 = int(np.where(slots == 5)[0][0])
    # old side = FIRST touch, new side = LAST touch; the middle hop cancels
    np.testing.assert_array_equal(old_x[i5], first)
    np.testing.assert_array_equal(new_x[i5], x0[10] + 2.0)
    assert old_live[i5] and new_live[i5]
    i9 = int(np.where(slots == 9)[0][0])
    assert old_live[i9] and not new_live[i9]


# --------------------------------------------------------------------- #
# consumers: patched state answers like a fresh rebuild
# --------------------------------------------------------------------- #
def test_neighbor_prob_of_patch_matches_fresh(data):
    from repro.core.sampling.edge import NeighborSampler
    rng, x0, k = data
    ds = DynamicDataset(x0, capacity=256)
    nbr = NeighborSampler(ds.x_pad, k, dataset=ds, seed=3,
                          exact_blocks=True, block_size=16)
    src = np.arange(16)
    v, _ = nbr.sample(src)             # populates the §4 level-1 cache
    _mutate(ds, rng, dele=(40, 48), keep=np.asarray(v))
    p1 = nbr.prob_of(src, v)           # patch_block_sums on the old cache
    fresh = NeighborSampler(ds.x_pad, k, seed=3, exact_blocks=True,
                            block_size=16)
    p2 = fresh.prob_of(src, v)
    np.testing.assert_allclose(p1, p2, rtol=2e-5, atol=1e-7)

    # journal gap (compact) -> transparent full rebuild, same answers
    ds.compact()
    live = ds.live_slots()[:16]
    q1 = nbr.prob_of(live, np.roll(live, 1))
    q2 = NeighborSampler(ds.x_pad, k, seed=3, exact_blocks=True,
                         block_size=16).prob_of(live, np.roll(live, 1))
    np.testing.assert_allclose(q1, q2, rtol=2e-5, atol=1e-7)


def test_degree_patch_matches_fresh(data):
    from repro.core.sampling.edge import NeighborSampler
    from repro.core.sampling.vertex import DegreeSampler, streaming_degrees
    rng, x0, k = data
    ds = DynamicDataset(x0, capacity=256)
    nbr = NeighborSampler(ds.x_pad, k, dataset=ds, seed=5,
                          exact_blocks=True, block_size=16)
    deg = DegreeSampler(nbr.blocks, seed=7, dataset=ds)
    for i in range(3):                 # several batches, one coalesced patch
        _mutate(ds, rng, dele=(60 + 2 * i, 62 + 2 * i),
                upd=(70 + 2 * i, 72 + 2 * i))
    u = deg.sample(256)
    assert ds.is_live(u)
    d_fresh = streaming_degrees(nbr.blocks, ds)
    np.testing.assert_allclose(deg.degrees, d_fresh, rtol=5e-4, atol=5e-5)
    # dead slots carry exactly zero degree mass
    assert deg.degrees[60] == 0.0 and deg.degrees[61] == 0.0


def test_rownorm_patch_matches_fresh(data):
    from repro.core.sampling.rownorm import RowNormSampler
    rng, x0, k = data
    ds = DynamicDataset(x0, capacity=256)
    rn = RowNormSampler(None, k, estimator="exact", seed=1, dataset=ds)
    _mutate(ds, rng)
    idx = rn.sample(128)
    assert ds.is_live(idx)
    fresh = RowNormSampler(None, k, estimator="exact", seed=1, dataset=ds)
    np.testing.assert_allclose(rn.row_norms_sq, fresh.row_norms_sq,
                               rtol=5e-4, atol=5e-5)
    sk = rn.sketch_rows(idx[:8])
    assert np.isfinite(sk).all()


def test_hashed_patch_parity_same_key(data):
    """Patched ``HashState`` vs ``build_hash_state`` at the new epoch:
    delete + in-place update keep the frozen key set aligned with the
    rebuild, so est AND realized NEAR counts agree under the same PRNG
    key (the bucket members stay slot-sorted -- the bitwise contract)."""
    from repro.core.kde.hashed import HashedKDE
    from repro.kernels.kde_hash import ops as hops
    rng, x0, k = data
    ds = DynamicDataset(x0, capacity=256)
    est = HashedKDE(x0, k, seed=5, max_bucket=64, num_far_samples=32,
                    dataset=ds, overflow_cap=64)
    ds.delete_rows(np.arange(40, 56))
    ds.update_rows(np.array([3]), np.asarray(ds.x_pad[3:4]))  # same cell
    est._sync()
    assert est.rebuilds == 0           # patched, not compacted

    state2, _ = hops.build_hash_state(
        ds.x_pad, k, max_bucket=64, seed=5, live=ds.live_host,
        overflow_cap=64)
    y = jnp.asarray(x0[:16])
    key = jax.random.PRNGKey(123)
    cfg = dict(est._cfg)
    e1, c1, _ = hops.hashed_query(ds.x_pad, y, est.state, key, **cfg)
    e2, c2, _ = hops.hashed_query(ds.x_pad, y, state2, key, **cfg)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-6)

    # inserts land in the overflow region (unhashed cell) and are read by
    # the exact extra sweep: an isolated point reports its own unit mass
    iso = (x0[:1] + 37.0).astype(np.float32)
    ds.insert_rows(iso)
    q = np.asarray(est.query(jnp.asarray(iso)))
    assert abs(q.item() - 1.0) < 1e-2, q


def test_epoch_stale_raises_under_checks(data, monkeypatch):
    from repro.core.sampling.edge import NeighborSampler
    monkeypatch.setenv("REPRO_CHECKS", "1")
    rng, x0, k = data
    ds = DynamicDataset(x0, capacity=256)
    nbr = NeighborSampler(ds.x_pad, k, dataset=ds, seed=3,
                          exact_blocks=True, block_size=16)
    ds.delete_rows(np.array([11]))
    with pytest.raises(_g.EstimationError, match="EPOCH_STALE"):
        nbr.sample(np.array([11]))     # externally-held stale frontier
    assert nbr.status & _g.EPOCH_STALE
    v, _ = nbr.sample(np.array([0, 1]))   # live frontier still serves
    assert ds.is_live(v)


def test_robust_estimator_epoch_sync(data):
    """Satellite regression: a RobustEstimator built over a DynamicDataset
    must answer post-mutation queries at the NEW epoch -- stale stage
    states are dropped, not escalated against."""
    rng, x0, k = data
    ds = DynamicDataset(x0, capacity=400)
    est = _g.RobustEstimator(ds, k, seed=0, stages=("stratified", "exact"))
    base = np.asarray(est.query(jnp.asarray(x0[:2])))
    assert np.isfinite(base).all()

    # a dense far-away cluster only visible after the mutation
    c = x0[:1] + 25.0
    cluster = (c + 0.05 * rng.normal(size=(40, ds.d))).astype(np.float32)
    ds.insert_rows(cluster)
    v = np.asarray(est.query(jnp.asarray(cluster[:1])))
    assert v.item() > 10.0, v          # stale stages would report ~0
    assert est.stage_rebuilds >= 1
    assert est.n == ds.num_live        # compact live view refreshed


def test_walk_draw_stream_bitwise_after_patch(data):
    """Same seed, no draws before the mutation: the patched sampler and a
    fresh rebuild consume identical PRNG streams over identical patched
    coordinates, so walk endpoints match bitwise (the strongest form of
    the distribution-equivalence contract)."""
    from repro.core.sampling.edge import NeighborSampler
    rng, x0, k = data
    ds = DynamicDataset(x0, capacity=256)
    nbr = NeighborSampler(ds.x_pad, k, dataset=ds, seed=9,
                          exact_blocks=True, block_size=16)
    _mutate(ds, rng)
    starts = np.array([0, 1, 2, 3, 20, 21])
    end1, path1 = nbr.walk(starts, 4)
    fresh = NeighborSampler(ds.x_pad, k, seed=9, exact_blocks=True,
                            block_size=16)
    end2, path2 = fresh.walk(starts, 4)
    np.testing.assert_array_equal(np.asarray(end1), np.asarray(end2))
    np.testing.assert_array_equal(np.asarray(path1), np.asarray(path2))
    assert ds.is_live(np.asarray(end1))


def test_neighbor_distribution_tv_after_patch(data):
    """Stochastic level-1 (stratified): patched and fresh samplers with
    diverged keys agree in *distribution* -- total variation over the
    endpoint histogram of single-step draws from one source.  Seeds
    derive from ``stats.ROOT_SEED``; the tolerance is the precomputed
    ``stats.tv_tolerance`` bound (alpha = 1e-3) times a x2 slack because
    the 500 draws of a chunk share ONE stratified level-1 read (8
    independently-keyed chunks, so the iid bound under-counts the
    chunk-level noise; measured statistic under the pinned seed: 0.211
    vs. the inflated bound 0.439)."""
    import stats

    from repro.core.sampling.edge import NeighborSampler
    rng, x0, k = data
    x_small = x0[:96]
    ds = DynamicDataset(x_small, capacity=128)
    nbr = NeighborSampler(ds.x_pad, k, dataset=ds,
                          seed=stats.derive_seed("streaming", "tv-patched"),
                          block_size=16, samples_per_block=8)
    nbr.sample(np.arange(8))           # desync the key streams
    ds.delete_rows(np.arange(64, 80))
    ds.insert_rows((x_small[:4] + 0.3).astype(np.float32))
    fresh = NeighborSampler(ds.x_pad, k,
                            seed=stats.derive_seed("streaming", "tv-fresh"),
                            block_size=16, samples_per_block=8)
    # one stratified level-1 read is shared by a whole batch (one key per
    # frontier), so block-level noise is batch-correlated: average the
    # histograms over several independently-keyed chunks
    src = np.zeros(500, np.int64)
    h1 = np.zeros(ds.n)
    h2 = np.zeros(ds.n)
    reps = 8
    for _ in range(reps):
        v1, _ = nbr.sample(src)
        v2, _ = fresh.sample(src)
        assert ds.is_live(np.asarray(v1)) and ds.is_live(np.asarray(v2))
        h1 += np.bincount(np.asarray(v1), minlength=ds.n)
        h2 += np.bincount(np.asarray(v2), minlength=ds.n)
    tv = stats.tv_distance(h1, h2)
    tol = 2.0 * stats.tv_tolerance(ds.n, len(src) * reps, alpha=1e-3)
    assert tv < tol, (tv, tol)


def test_streaming_graph_end_to_end(data):
    from repro.core.streaming import StreamingKernelGraph
    rng, x0, k = data
    g = StreamingKernelGraph(x0, k, capacity=256, level1="hash", seed=11,
                             hash_opts=dict(max_bucket=64))
    g.insert(rng.normal(0, 0.7, size=(6, 6)).astype(np.float32))
    g.delete(np.arange(5))
    g.update(np.array([30, 31]),
             rng.normal(0, 0.7, size=(2, 6)).astype(np.float32))
    u = g.sample_vertices(64)
    v, q = g.sample_neighbors(u)
    assert g.dataset.is_live(u) and g.dataset.is_live(v)
    assert np.isfinite(np.asarray(q)).all()
    e = g.sample_edges(128)
    assert len(e[0]) == 128
    end, _ = g.walk(u[:8], 3)
    assert g.dataset.is_live(np.asarray(end))
    rep = g.status_report()
    assert rep["num_live"] == g.num_live and rep["mutation_batches"] == 3
    d = g.degrees()
    assert d[0] == 0.0 and (d[np.asarray(g.dataset.live_slots())] > 0).all()


# --------------------------------------------------------------------- #
# 8-device sharded case (subprocess owns its XLA_FLAGS)
# --------------------------------------------------------------------- #
def _run(code: str, devices: int = 8) -> str:
    full = (f'import os\nos.environ["XLA_FLAGS"] = '
            f'"--xla_force_host_platform_device_count={devices}"\n'
            f'import sys; sys.path.insert(0, "src")\n' + code)
    p = subprocess.run([sys.executable, "-c", full], capture_output=True,
                       text=True, cwd=".")
    assert p.returncode == 0, p.stderr[-1500:]
    return p.stdout


def test_sharded_streaming_zero_collective_patch():
    """8-device: the mutation program is jaxpr-verifiably collective-free,
    the per-draw-batch collective schedule is UNCHANGED by patching, and
    patched level-1 sums / prob_of / hashed queries match fresh rebuilds
    at the new epoch."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.dataset import DynamicDataset, coalesce_mutations
from repro.kernels.kde_sampler.sharded import ShardedBlocks, collective_counts
ker = gaussian(1.0)
rng = np.random.default_rng(0)
x0 = rng.normal(0, 0.7, (192, 6)).astype(np.float32)
mesh = jax.make_mesh((8,), ("data",))

ds = DynamicDataset(x0, capacity=256)
eng = ShardedBlocks(mesh, ds.x_pad, ker, block_size=16, exact=True)
key = jax.random.PRNGKey(1)
src = jnp.arange(24, dtype=jnp.int32)
base = collective_counts(lambda s, k: eng.fused_sample(s, k), src, key)
assert base["psum_total"] == 1, base

ds.insert_rows(rng.normal(0, 0.7, (8, 6)).astype(np.float32))
ds.delete_rows(np.arange(120, 128))
ds.update_rows(np.arange(4), rng.normal(0, 0.7, (4, 6)).astype(np.float32))
slots, old_x, new_x, old_live, new_live = coalesce_mutations(ds.mutations_since(0))

pcc = collective_counts(eng._patch_program(), *eng._sharded_args(),
                        jnp.asarray(slots, jnp.int32),
                        jnp.asarray(new_x, jnp.float32))
assert pcc["psum_total"] == 0 and pcc["ppermute_total"] == 0, pcc
eng.patch_rows(slots, new_x)

fresh = ShardedBlocks(mesh, ds.x_pad, ker, block_size=16, exact=True)
s1 = np.asarray(eng.masked_block_sums(src, key)[0])
s2 = np.asarray(fresh.masked_block_sums(src, key)[0])
np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-6)
after = collective_counts(lambda s, k: eng.fused_sample(s, k), src, key)
assert after == base, (base, after)
print("SHARDED_PATCH_OK")
""")
    assert "SHARDED_PATCH_OK" in out


def test_sharded_neighbor_prob_of_patch_matches_fresh():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.dataset import DynamicDataset
from repro.core.sampling.edge import NeighborSampler
ker = gaussian(1.0)
rng = np.random.default_rng(0)
x0 = rng.normal(0, 0.7, (192, 6)).astype(np.float32)
mesh = jax.make_mesh((8,), ("data",))
ds = DynamicDataset(x0, capacity=256)
nbr = NeighborSampler(ds.x_pad, ker, mode="blocked", block_size=16,
                      exact_blocks=True, mesh=mesh, seed=3, dataset=ds)
src = np.arange(16)
v, _ = nbr.sample(src)
ds.insert_rows(rng.normal(0, 0.7, (6, 6)).astype(np.float32))
dead = np.setdiff1d(np.arange(150, 192), np.asarray(v))[:8]
ds.delete_rows(dead)
ds.update_rows(np.arange(8, 10), rng.normal(0, 0.7, (2, 6)).astype(np.float32))
p1 = nbr.prob_of(src, v)
fresh = NeighborSampler(ds.x_pad, ker, mode="blocked", block_size=16,
                        exact_blocks=True, mesh=mesh, seed=3)
p2 = fresh.prob_of(src, v)
np.testing.assert_allclose(p1, p2, rtol=2e-5, atol=1e-7)
print("SHARDED_NBR_OK")
""")
    assert "SHARDED_NBR_OK" in out


def test_sharded_hash_patch_parity_one_psum():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.dataset import DynamicDataset, coalesce_mutations
from repro.kernels.kde_hash.sharded import ShardedHashTable
from repro.kernels.kde_sampler.sharded import collective_counts
ker = gaussian(1.0)
rng = np.random.default_rng(0)
x0 = rng.normal(0, 0.7, (192, 6)).astype(np.float32)
mesh = jax.make_mesh((8,), ("data",))
ds = DynamicDataset(x0, capacity=256)
tab = ShardedHashTable(mesh, np.asarray(ds.x_pad), ker, max_bucket=32,
                       num_far_samples=16, seed=2, live=ds.live_host,
                       overflow_cap=32)
y = jnp.asarray(x0[:8]); k0 = jax.random.PRNGKey(7)
qcc = collective_counts(tab._program(), tab._keys, tab._members,
                        tab._counts, tab._overflow, tab._dims, tab._shift,
                        tab.x_sh, y, k0)
assert qcc["psum_total"] == 1 and qcc["ppermute_total"] == 0, qcc

# delete + in-place update: key set stays aligned with a rebuild
ds.delete_rows(np.arange(16, 32))
ds.update_rows(np.array([3]), np.asarray(ds.x_pad[3:4]))
slots, old_x, new_x, old_live, new_live = coalesce_mutations(ds.mutations_since(0))
assert tab.patch_rows(slots, old_x, new_x, old_live, new_live)
e1, c1, _ = tab.query(y, k0)
tab2 = ShardedHashTable(mesh, np.asarray(ds.x_pad), ker, max_bucket=32,
                        num_far_samples=16, seed=2, live=ds.live_host,
                        overflow_cap=32)
e2, c2, _ = tab2.query(y, k0)
np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-6)

# insert lands in the owning shard's overflow; the exact sweep reads it
iso = (x0[:1] + 37.0).astype(np.float32)
e0 = int(ds.epoch)
ds.insert_rows(iso)
slots, old_x, new_x, old_live, new_live = coalesce_mutations(ds.mutations_since(e0))
assert tab.patch_rows(slots, old_x, new_x, old_live, new_live)
ei, _, _ = tab.query(jnp.asarray(iso), jax.random.PRNGKey(9))
assert abs(float(np.asarray(ei)[0]) - 1.0) < 1e-2, ei
print("SHARDED_HASH_OK")
""")
    assert "SHARDED_HASH_OK" in out
