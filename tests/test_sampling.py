"""The paper's sampling reductions (Section 4) -- distributional tests, plus
regression coverage for the fused device-resident sampling engine
(DESIGN.md §3/§4)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # pragma: no cover - env without hypothesis
    hypothesis = None

from repro.core.kde.base import ExactKDE
from repro.core.kde.multilevel import MultiLevelKDE
from repro.core.kernels_fn import gaussian
from repro.core.sampling.edge import (EdgeSampler, NeighborSampler,
                                      _categorical_rows)
from repro.core.sampling.rownorm import RowNormSampler
from repro.core.sampling.vertex import (DegreeSampler, PrefixCDF,
                                        sample_from_positive_array,
                                        tree_descent_sample)
from repro.core.sampling.walks import random_walks
from repro.kernels.kde_sampler import ops as sampler_ops


@pytest.fixture(scope="module")
def graph():
    rng = np.random.default_rng(3)
    x = rng.normal(0, 0.5, (400, 5)).astype(np.float32)
    ker = gaussian(bandwidth=1.5)
    k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
    return x, ker, k


def tv(p, q):
    return 0.5 * np.abs(p - q).sum()


def _tree_vs_dense_check(a):
    a = np.asarray(a)
    rng = np.random.default_rng(0)
    n_s = 4000
    dense = sample_from_positive_array(a, n_s, np.random.default_rng(1))
    tree = np.array([tree_descent_sample(a, rng) for _ in range(n_s)])
    p = a / a.sum()
    emp_d = np.bincount(dense, minlength=len(a)) / n_s
    emp_t = np.bincount(tree, minlength=len(a)) / n_s
    noise = 3.0 * np.sqrt(len(a) / n_s)
    assert tv(emp_d, p) < noise
    assert tv(emp_t, p) < noise


if hypothesis is not None:
    @hypothesis.given(a=st.lists(st.floats(0.01, 10.0), min_size=2,
                                 max_size=40))
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_tree_descent_equals_dense_sampling(a):
        """Lemma 4.8: the binary-descent sampler (Alg 4.5) samples exactly
        proportional to the array -- agreeing with the dense inverse-CDF
        form."""
        _tree_vs_dense_check(a)
else:
    def test_tree_descent_equals_dense_sampling():
        _tree_vs_dense_check(np.random.default_rng(2).uniform(0.01, 10.0, 17))


def test_prefix_cdf_float32_bias_regression():
    """Float32 prefix accumulation can swallow small weights entirely once
    the running sum is large -- those indices become unsampleable.  The
    shared PrefixCDF path accumulates in float64, so the tail keeps exactly
    its target mass."""
    n = 1 << 16
    a = np.ones(n)
    a[0] = 2.0e7                       # ulp(2e7) = 2 in float32: +1.0 is lost
    bad = np.cumsum(a.astype(np.float32))
    assert bad[-1] == bad[0], "float32 cumsum should exhibit the bias"
    cdf = PrefixCDF(a, seed=0)
    draws = 20000
    s = cdf.sample(draws)
    tail_mass = (n - 1) / (2.0e7 + n - 1)          # ~3.3e-3
    hits = int((s > 0).sum())
    expect = draws * tail_mass                     # ~65; Poisson sigma ~ 8
    assert abs(hits - expect) < 6.0 * np.sqrt(expect), (hits, expect)
    # the float64 prefix is strictly increasing -- no swallowed entries
    assert np.all(np.diff(cdf._prefix) > 0)


def test_prefix_cdf_large_n_empirical_frequencies():
    """Large-n regression: empirical frequencies track the target
    distribution (aggregated into buckets so the test has power)."""
    rng = np.random.default_rng(0)
    n, draws, buckets = 200_000, 50_000, 100
    w = rng.uniform(0.5, 1.5, n)
    cdf = PrefixCDF(w, seed=1)
    s = cdf.sample(draws)
    edges = np.linspace(0, n, buckets + 1).astype(np.int64)
    target = np.add.reduceat(w, edges[:-1]) / w.sum()
    emp = np.histogram(s, bins=edges)[0] / draws
    assert tv(emp, target) < 3.0 * np.sqrt(buckets / draws)
    # device CDF export: rounded from the f64 accumulation, ends at 1
    dev = np.asarray(cdf.cdf_device)
    assert abs(float(dev[-1]) - 1.0) < 1e-6
    np.testing.assert_allclose(np.asarray(cdf.probs_device),
                               w / w.sum(), rtol=1e-4)


def test_degree_sampling_distribution(graph):
    """Theorem 4.9: TV(sampler, degree distribution) = O(eps)."""
    x, ker, k = graph
    est = ExactKDE(x, ker)
    ds = DegreeSampler(est, seed=0)
    deg = k.sum(1) - 1
    np.testing.assert_allclose(ds.degrees, deg, rtol=1e-4)
    s = ds.sample(30000)
    emp = np.bincount(s, minlength=len(deg)) / 30000
    assert tv(emp, deg / deg.sum()) < 3.0 * np.sqrt(len(deg) / 30000)


def test_neighbor_sampler_blocked_exact(graph):
    """Theorem 4.12 with exact level-1 reads: exact neighbor distribution."""
    x, ker, k = graph
    nb = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=0)
    src = 7
    row = k[src].copy()
    row[src] = 0
    p = row / row.sum()
    v, probs = nb.sample(np.full(20000, src))
    emp = np.bincount(v, minlength=len(p)) / 20000
    assert tv(emp, p) < 3.0 * np.sqrt(len(p) / 20000)
    # realized probabilities match the true distribution
    np.testing.assert_allclose(probs, p[v], rtol=1e-3, atol=1e-9)


def test_fused_sampling_law_chi_square(graph):
    """Sampling-law regression for the fused engine: the empirical neighbor
    distribution from a mixed frontier matches k(u, v)/deg(u) under a
    chi-square test (exact level-1 reads, so the law is exact)."""
    x, ker, k = graph
    nb = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=1)
    src = 11
    reps = 20000
    v, _ = nb.sample(np.full(reps, src))
    row = k[src].copy()
    row[src] = 0
    p = row / row.sum()
    obs = np.bincount(v, minlength=len(p)).astype(np.float64)
    exp = reps * p
    # merge cells with tiny expectation into one bucket (chi-square validity)
    big = exp >= 5.0
    chi2 = np.sum((obs[big] - exp[big]) ** 2 / exp[big])
    rest_obs, rest_exp = obs[~big].sum(), exp[~big].sum()
    if rest_exp > 0:
        chi2 += (rest_obs - rest_exp) ** 2 / rest_exp
    df = big.sum() + (1 if rest_exp > 0 else 0) - 1
    # chi2 ~ N(df, sqrt(2 df)) for large df; 4-sigma acceptance
    assert chi2 < df + 4.0 * np.sqrt(2.0 * df), (chi2, df)


def test_neighbor_prob_of_matches_sampling(graph):
    x, ker, k = graph
    nb = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=0)
    src = np.array([3, 3, 11, 200])
    dst = np.array([5, 399, 42, 17])
    got = nb.prob_of(src, dst)
    for s, d, g in zip(src, dst, got):
        row = k[s].copy()
        row[s] = 0
        np.testing.assert_allclose(g, row[d] / row.sum(), rtol=1e-3)


def test_prob_of_consistent_with_realized_probs(graph):
    """The probability ``sample`` reports equals what ``prob_of`` recomputes
    for the drawn edges -- the level-1 cache makes the two reads share one
    set of block sums (DESIGN.md §4)."""
    x, ker, k = graph
    nb = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=3)
    src = np.arange(0, 400, 7)
    v, probs = nb.sample(src)
    recomputed = nb.prob_of(src, v)
    np.testing.assert_allclose(probs, recomputed, rtol=1e-4, atol=1e-10)


def test_prob_of_matches_zero_row_fallback():
    """Underflow regression: with a tiny bandwidth, level-2 rows underflow
    to all zeros and ``sample`` falls back to a uniform draw over the live
    columns -- ``prob_of`` must report that same 1/|live| probability
    instead of 0 (DESIGN.md §3 zero-row guard, both sides)."""
    rng = np.random.default_rng(1)
    x = rng.normal(0, 0.5, (333, 4)).astype(np.float32)
    nb = NeighborSampler(x, gaussian(0.05), mode="blocked",
                         exact_blocks=True, seed=0)
    src = np.full(512, 7, np.int64)
    v, probs = nb.sample(src)
    recomputed = nb.prob_of(src, v)
    np.testing.assert_allclose(probs, recomputed, rtol=2e-4, atol=1e-10)


def test_level1_cache_shared_across_calls(graph):
    """Repeated sample/prob_of/sample_exact on one frontier re-sweep the
    dataset exactly once (the level-1 caching contract)."""
    x, ker, _ = graph
    nb = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=0)
    src = np.arange(0, 400, 4)
    w, n = len(src), nb.n
    nb.sample(src)
    level1 = w * n
    base = nb.evals
    assert base >= level1
    nb.sample(src)                     # cache hit: level-2 evals only
    assert nb.evals - base == w * nb.block_size
    base = nb.evals
    nb.prob_of(src, np.roll(src, 1))   # same frontier: no re-sweep
    assert nb.evals - base == w * nb.block_size
    base = nb.evals
    nb.sample_exact(src, rounds=2)     # all rounds share the cached sums
    assert nb.evals - base == 3 * w * nb.block_size + 2 * w


def test_blocked_sample_hits_compiled_path(graph):
    """Acceptance: the blocked path performs zero per-call Python loops over
    blocks -- after the first (tracing) call, repeated batches reuse the
    compiled device program and never fall back to a host implementation."""
    x, ker, _ = graph
    nb = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=0)
    nb.sample(np.arange(100))          # traces fused_sample for this shape
    before = dict(sampler_ops.TRACE_COUNTS)
    for lo in range(0, 300, 100):
        nb.sample(np.arange(lo, lo + 100))
    assert dict(sampler_ops.TRACE_COUNTS) == before, \
        "fused sampler retraced or fell back off the compiled path"


def test_neighbor_sampler_tree(graph):
    """Faithful Algorithm 4.11 on the dyadic tree (exact node estimators)."""
    x, ker, k = graph
    tree = MultiLevelKDE(x, ker, lambda xs, seed: ExactKDE(xs, ker),
                         leaf_size=50)
    nb = NeighborSampler(x, ker, mode="tree", tree=tree, seed=0)
    src = 0
    row = k[src].copy()
    row[src] = 0
    p = row / row.sum()
    v, probs = nb.sample(np.full(3000, src))
    emp = np.bincount(v, minlength=len(p)) / 3000
    assert tv(emp, p) < 3.0 * np.sqrt(len(p) / 3000)


def test_neighbor_sampler_tree_grid_hbe_factory(graph):
    """Algorithm 4.11 dyadic descent over a MultiLevelKDE built from
    GridHBE node estimators -- the paper's composition of the practical
    hash-based structure with the tree sampler.  The descent's branch
    probabilities are noisy (1 +- eps)^depth, so the realized law is only
    approximately k(u, .)/deg(u); draws must still be valid (never the
    source), carry positive probabilities, and track the target law."""
    from repro.core.kde.hbe import GridHBE
    x, ker, k = graph
    tree = MultiLevelKDE(
        x, ker,
        lambda xs, seed: GridHBE(xs, ker, num_far_samples=48,
                                 max_bucket=64, seed=seed),
        leaf_size=100)
    nb = NeighborSampler(x, ker, mode="tree", tree=tree, seed=0)
    src = 5
    row = k[src].copy()
    row[src] = 0
    p = row / row.sum()
    m = 800
    v, probs = nb.sample(np.full(m, src))
    assert np.all(v != src) and np.all(v >= 0) and np.all(v < len(p))
    assert np.all(probs > 0) and np.all(probs <= 1.0)
    emp = np.bincount(v, minlength=len(p)) / m
    # looser bound than the exact-node test: GridHBE node estimates add
    # (1 +- eps)^depth distortion on top of sampling noise
    assert tv(emp, p) < 4.5 * np.sqrt(len(p) / m), tv(emp, p)
    assert tree.evals > 0 and nb.evals > tree.evals


def test_edge_sampler_weight_proportional(graph):
    """Theorem 4.14: edges ~ k(u,v) / sum(w)."""
    x, ker, k = graph
    est = ExactKDE(x, ker)
    es = EdgeSampler(DegreeSampler(est, seed=1),
                     NeighborSampler(x, ker, exact_blocks=True, seed=2))
    u, v, p = es.sample(30000)
    n = k.shape[0]
    koff = k.copy()
    np.fill_diagonal(koff, 0)
    iu = np.triu_indices(n, 1)
    # weight-proportional sampling visits heavy edges far more often than
    # uniform would: E_sampled[w] ~ E[w^2]/E[w] >> E[w]
    mean_sampled = koff[u, v].mean()
    mean_uniform = koff[iu].mean()
    expected = (koff[iu] ** 2).mean() / koff[iu].mean()
    assert 0.85 * expected < mean_sampled < 1.15 * expected
    assert mean_sampled > 1.1 * mean_uniform
    # and the per-vertex marginal matches the degree distribution
    deg = koff.sum(1)
    marg = np.bincount(np.concatenate([u, v]), minlength=n) / (2 * len(u))
    assert 0.5 * np.abs(marg - deg / deg.sum()).sum() < \
        3.0 * np.sqrt(n / (2 * len(u))) + 0.05


def test_rejection_sampling_exactness(graph):
    x, ker, k = graph
    nb = NeighborSampler(x, ker, mode="blocked", exact_blocks=False,
                         samples_per_block=8, seed=0)
    src = 5
    row = k[src].copy()
    row[src] = 0
    p = row / row.sum()
    v = nb.sample_exact(np.full(8000, src), rounds=6)
    emp = np.bincount(v, minlength=len(p)) / 8000
    v0, _ = nb.sample(np.full(8000, src))
    emp0 = np.bincount(v0, minlength=len(p)) / 8000
    # rejection-corrected distribution at least as close as raw proposals
    assert tv(emp, p) <= tv(emp0, p) + 0.05


def test_random_walk_matches_markov_chain(graph):
    """Theorem 4.15: endpoint distribution ~= e_u M^t."""
    x, ker, k = graph
    koff = k.copy()
    np.fill_diagonal(koff, 0)
    m = koff / koff.sum(1, keepdims=True)
    t = 3
    p_true = np.linalg.matrix_power(m.T, t) @ np.eye(len(k))[0]
    nb = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=0)
    ends = random_walks(nb, np.zeros(20000, np.int64), t)
    emp = np.bincount(ends, minlength=len(k)) / 20000
    assert tv(emp, p_true) < 3.0 * np.sqrt(len(k) / 20000)


def test_random_walk_record_path(graph):
    """Device-scan walks return the full path with starts prepended."""
    x, ker, _ = graph
    nb = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=0)
    starts = np.arange(32, dtype=np.int64)
    ends, path = random_walks(nb, starts, 5, record_path=True)
    assert path.shape == (6, 32)
    np.testing.assert_array_equal(path[0], starts)
    np.testing.assert_array_equal(path[-1], ends)
    # every step moves to a *different* vertex (self edges are masked)
    assert np.all(path[1:] != path[:-1])


def test_random_walk_record_path_off_identical_endpoints(graph):
    """record_path=False skips the (T, w) path stack but consumes the same
    key stream: endpoints are bitwise identical, and no path is returned."""
    x, ker, _ = graph
    starts = np.arange(48, dtype=np.int64)
    nb1 = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=9)
    end1, path = nb1.walk(starts, 6, record_path=True)
    assert path.shape == (6, 48)
    nb2 = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=9)
    end2, nopath = nb2.walk(starts, 6, record_path=False)
    assert nopath is None
    np.testing.assert_array_equal(end1, end2)
    np.testing.assert_array_equal(end1, path[-1])


def test_categorical_rows_zero_row_guard():
    """Regression: an all-zero row must draw a valid index, not NaN."""
    rng = np.random.default_rng(0)
    p = np.array([[0.0, 0.0, 0.0, 0.0],
                  [0.0, 1.0, 0.0, 0.0],
                  [0.2, 0.3, 0.5, 0.0]])
    idx = _categorical_rows(p, rng)
    assert idx.shape == (3,)
    assert np.all((idx >= 0) & (idx < 4))
    assert idx[1] == 1
    draws = np.stack([_categorical_rows(p, rng) for _ in range(500)])
    # the dead row spreads ~uniformly instead of collapsing or NaN-ing
    assert len(np.unique(draws[:, 0])) == 4


def test_rownorm_sampler(graph):
    """Section 5.2: KDE on cX samples rows ~ ||K_i||^2."""
    x, ker, k = graph
    rs = RowNormSampler(x, ker, estimator="exact", seed=0)
    true_norms = (k ** 2).sum(1)
    np.testing.assert_allclose(rs.row_norms_sq, true_norms, rtol=1e-3)
    s = rs.sample(30000)
    emp = np.bincount(s, minlength=len(k)) / 30000
    assert tv(emp, true_norms / true_norms.sum()) < \
        3.0 * np.sqrt(len(k) / 30000)
