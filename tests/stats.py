"""Shared statistical acceptance-test helpers (ISSUE 8 satellite).

Every distributional assertion in the suite draws its keys from ONE
documented root seed (:data:`ROOT_SEED`) via :func:`derive_seed`, and
compares against PRECOMPUTED critical values at a written-down
significance level -- never an ad-hoc "looks small enough" tolerance.

False-positive budget
---------------------
All seeds are pinned, so each test is a deterministic function of the
code under test: it either passes forever or fails forever, and the
alpha below is the probability the PINNED draw landed in the rejection
region when the tested distributions really are equal (i.e. the chance
we shipped a flaky assertion).  Conventions:

* two-sample / one-sample KS: alpha = 1e-3 per assertion
  (``ks_critical``'s default), asymptotic Kolmogorov approximation
  c(alpha) = sqrt(-ln(alpha / 2) / 2);
* total-variation parity: alpha = 1e-3 via the DKW-style bound of
  :func:`tv_tolerance` -- with S support cells and n draws per side,
  the empirical TV between two samples of the SAME law exceeds
  ``sqrt((S ln 2 + ln(2 / alpha)) / (2 n))`` (per side, summed) with
  probability < alpha;
* chi-square goodness of fit: alpha from :data:`CHI2_Z`'s table via the
  Wilson--Hilferty cube-root normal approximation (exact enough for
  dof >= 4, conservative below).

A suite of ~20 such assertions therefore carries a < 2% one-time risk
of having baked in a flaky bound, and zero ongoing flake rate.
"""
import hashlib

import numpy as np

#: The single root seed every distributional test derives from.  Chosen
#: once (the date this harness landed) and never changed casually:
#: changing it re-rolls every pinned draw and re-exposes the suite to
#: the one-time alpha risk documented above.
ROOT_SEED = 20260808

#: upper-tail standard-normal quantiles for the Wilson--Hilferty
#: chi-square approximation (alpha -> z_alpha)
CHI2_Z = {0.05: 1.645, 0.01: 2.326, 1e-3: 3.090, 1e-4: 3.719, 1e-6: 4.753}


def derive_seed(*labels) -> int:
    """A stable uint32 seed derived from :data:`ROOT_SEED` and string
    labels (test name, case, repetition).  sha256-based so adding a new
    label never perturbs sibling tests' streams."""
    h = hashlib.sha256(
        ("|".join([str(ROOT_SEED)] + [str(x) for x in labels])).encode())
    return int.from_bytes(h.digest()[:4], "big")


# ---------------------------------------------------------------- KS #
def ks_statistic(a, b) -> float:
    """Two-sample Kolmogorov--Smirnov statistic sup_t |F_a(t) - F_b(t)|
    over the pooled support (works for discrete samples: ties are
    handled by evaluating both ECDFs at every pooled value)."""
    a = np.sort(np.asarray(a, np.float64))
    b = np.sort(np.asarray(b, np.float64))
    pooled = np.concatenate([a, b])
    fa = np.searchsorted(a, pooled, side="right") / len(a)
    fb = np.searchsorted(b, pooled, side="right") / len(b)
    return float(np.abs(fa - fb).max())


def ks_statistic_against_cdf(samples, cdf_at_support) -> float:
    """One-sample KS of integer-valued ``samples`` in ``[0, S)`` against
    the exact discrete CDF evaluated on ``arange(S)``."""
    cdf = np.asarray(cdf_at_support, np.float64)
    counts = np.bincount(np.asarray(samples, np.int64), minlength=len(cdf))
    ecdf = np.cumsum(counts) / len(np.asarray(samples))
    return float(np.abs(ecdf - cdf).max())


def ks_critical(n: int, m: int = None, alpha: float = 1e-3) -> float:
    """Kolmogorov critical value: one-sample (``m=None``)
    ``c(alpha)/sqrt(n)``; two-sample ``c(alpha) * sqrt((n+m)/(n m))``
    with ``c(alpha) = sqrt(-ln(alpha/2)/2)`` (asymptotic; conservative
    for the sample sizes used here, n >= 500)."""
    c = np.sqrt(-np.log(alpha / 2.0) / 2.0)
    if m is None:
        return float(c / np.sqrt(n))
    return float(c * np.sqrt((n + m) / (n * m)))


# ---------------------------------------------------------------- TV #
def tv_distance(counts_a, counts_b) -> float:
    """Total-variation distance between two empirical histograms."""
    pa = np.asarray(counts_a, np.float64)
    pb = np.asarray(counts_b, np.float64)
    return float(0.5 * np.abs(pa / pa.sum() - pb / pb.sum()).sum())


def tv_tolerance(support: int, n: int, m: int = None,
                 alpha: float = 1e-3) -> float:
    """Upper bound on the empirical TV between two samples of the SAME
    discrete law on ``support`` cells, violated with probability <
    ``alpha``: per side, ``TV(hat p, p) <= sqrt((S ln 2 + ln(2/alpha)) /
    (2 n))`` (union bound over the 2^S events behind the TV sup,
    Hoeffding each), and the two sides add by the triangle
    inequality."""
    def side(k):
        return np.sqrt((support * np.log(2.0) + np.log(2.0 / alpha))
                       / (2.0 * k))
    return float(side(n) + side(m if m is not None else n))


# -------------------------------------------------------- chi-square #
def chi2_statistic(counts, expected) -> float:
    """Pearson chi-square statistic over cells with expected mass."""
    c = np.asarray(counts, np.float64)
    e = np.asarray(expected, np.float64)
    keep = e > 0
    return float(((c[keep] - e[keep]) ** 2 / e[keep]).sum())


def chi2_critical(dof: int, alpha: float = 1e-3) -> float:
    """Wilson--Hilferty upper critical value for chi-square(dof): exact
    to ~1% for dof >= 4 and conservative below; ``alpha`` must be a key
    of :data:`CHI2_Z`."""
    z = CHI2_Z[alpha]
    k = float(dof)
    return float(k * (1.0 - 2.0 / (9.0 * k)
                      + z * np.sqrt(2.0 / (9.0 * k))) ** 3)
