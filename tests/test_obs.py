"""Observability layer (DESIGN.md §15): counter-word algebra and scan-carry
folding, registry enable/disable semantics (disabled mode must be a no-op),
histogram determinism, exporter schema validation, and an 8-device
subprocess proof that the counter payload adds ZERO collectives to the §9
one-psum-per-draw schedule."""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import counters as C
from repro.obs import export
from repro.obs import metrics as M


@pytest.fixture(autouse=True)
def _clean_registry():
    M.reset()
    M.disable()
    yield
    M.reset()
    M.disable()


# ------------------------------------------------------------- counters
def test_counter_word_algebra():
    """word/fold/fold_status/counter/totals: slot 0 ors, the rest add
    (mod 2^32 inside a word; HostTotals promotes to python ints)."""
    a = C.word(status=0x2, evals=10, draws=3)
    b = C.word(status=0x8, evals=5, retries=7)
    f = C.fold(a, b)
    t = C.totals(f)
    assert t["status"] == 0xA and t["evals"] == 15
    assert t["draws"] == 3 and t["retries"] == 7
    g = C.fold_status(a, 0x4)
    assert C.counter(g, "status") == 0x6
    assert C.counter(g, "evals") == 10     # fold_status touches slot 0 only
    s = C.scale(a, 3)
    assert C.counter(s, "evals") == 30 and C.counter(s, "status") == 0x2
    assert C.is_word(a) and not C.is_word(np.zeros(5, np.uint32))


def test_counter_word_uint32_wrap_and_host_totals():
    """Device slots wrap mod 2^32 by design; HostTotals accumulates in
    python ints so the serving ledger never wraps across calls."""
    big = C.word(evals=2**32 - 2)
    wrapped = C.fold(big, C.word(evals=5))
    assert C.counter(wrapped, "evals") == 3          # wrapped on device
    ht = C.HostTotals()
    for _ in range(3):
        ht.note(C.word(evals=2**31, status=0x1))
    assert ht["evals"] == 3 * 2**31                  # no wrap host-side
    assert ht.status == 0x1 and ht.words == 3
    d = ht.as_dict()
    assert d["evals"] == 3 * 2**31 and d["status"] == 0x1


def test_counter_word_scan_carry_interpret():
    """The walk_scan folding discipline -- per-step words fold-reduced
    through a ``lax.scan`` carry -- reproduces the host fold exactly, in
    interpret (eager, jit-disabled) AND compiled mode."""
    rng = np.random.default_rng(0)
    steps = np.stack([np.asarray(C.word(status=int(rng.integers(0, 4)),
                                        evals=int(rng.integers(0, 1000)),
                                        draws=int(rng.integers(0, 50)),
                                        retries=int(rng.integers(0, 9))))
                      for _ in range(16)])
    want = C.word()
    for w in steps:
        want = C.fold(want, w)
    want = C.totals(want)

    def scan_fold(ws):
        return jax.lax.scan(lambda c, w: (C.fold(c, w), None),
                            C.word(), ws)[0]

    with jax.disable_jit():                         # interpret mode
        eager = C.totals(scan_fold(jnp.asarray(steps)))
    compiled = C.totals(jax.jit(scan_fold)(jnp.asarray(steps)))
    assert eager == want and compiled == want


def test_walk_scan_word_matches_analytic(cloud=None):
    """End-to-end scan-carry check on the real program: a T-step walk's
    folded word must be exactly T times the per-step analytic word."""
    from repro.core.kernels_fn import gaussian
    from repro.core.sampling.edge import NeighborSampler
    rng = np.random.default_rng(1)
    x = rng.normal(0, 0.5, (128, 4)).astype(np.float32)
    nb = NeighborSampler(x, gaussian(1.0), mode="blocked",
                         exact_blocks=True, seed=0)
    e0, r0 = nb.evals, nb.device_counters["evals"]
    d0 = nb.device_counters["draws"]
    nb.walk(np.zeros(8, np.int64), 5)
    assert nb.device_counters["evals"] - r0 == nb.evals - e0
    assert nb.device_counters["draws"] - d0 == 5 * 8   # one draw/step/walker
    assert nb.device_counters.status == 0


# ------------------------------------------------------------- registry
def test_disabled_mode_is_noop():
    """Disabled registry: span() hands back the shared null span, and
    counter/gauge/observe/event leave NO state behind -- the enabled()
    branch is the entire cost."""
    assert not M.enabled()
    assert M.span("a") is M.span("b")               # singleton null span
    with M.span("a"):
        pass
    M.counter_inc("c", 5)
    M.gauge_set("g", 1.0)
    M.observe("h", 3.0)
    M.event("e", detail=1)
    reg = M.get_registry()
    assert reg["counters"] == {} and reg["gauges"] == {}
    assert reg["histograms"] == {} and not M.events()


def test_enabled_registry_records():
    M.enable()
    M.counter_inc("c", 2)
    M.counter_inc("c", 3)
    M.gauge_set("g", 7.5)
    M.observe("h", 100.0)
    M.event("e", k="v")
    with M.span("sp"):
        pass
    reg = M.get_registry()
    assert reg["counters"]["c"] == 5 and reg["gauges"]["g"] == 7.5
    assert "h" in reg["histograms"]
    assert M.events("e")[0][1]["k"] == "v"
    assert "span.sp.us" in M.histograms()           # span recorded a timing


def test_histogram_determinism():
    """Identical sample streams -> identical fixed-bucket p50/p99 (the
    quantiles are bucket-edge lookups, not interpolation over floats)."""
    vals = np.random.default_rng(7).lognormal(4, 2, 5000)
    h1, h2 = M.Histogram(), M.Histogram()
    for v in vals:
        h1.record(float(v))
    for v in vals:
        h2.record(float(v))
    assert h1.p50 == h2.p50 and h1.p99 == h2.p99
    assert h1.as_dict() == h2.as_dict()
    # quantiles are monotone and live on the fixed edge grid
    qs = [h1.quantile(q) for q in (0.1, 0.5, 0.9, 0.99)]
    assert qs == sorted(qs)


def test_timer_fences_and_records():
    M.enable()
    t = M.Timer("t")
    out = t.time(lambda: jnp.ones((64, 64)) @ jnp.ones((64, 64)))
    assert out.shape == (64, 64)
    us = t.timeit(lambda: jnp.ones(8) + 1, repeats=3, warmup=1)
    assert us > 0
    assert "timer.t.us" in M.histograms()


# ------------------------------------------------------------- exporters
def test_metrics_line_schema_validation():
    good = dict(schema_version=export.SCHEMA_VERSION, mode="multi-tenant",
                tenants=2, ticks=4, served=10, failed=0, p50_ms=1.0,
                p99_ms=2.0, throughput_rps=100.0, evictions=0, stale=0,
                realized_evals=123, per_tenant={})
    export.validate_metrics_line(good)
    with pytest.raises((ValueError, KeyError)):
        export.validate_metrics_line({k: v for k, v in good.items()
                                      if k != "realized_evals"})
    with pytest.raises((ValueError, KeyError)):
        bad = dict(good)
        bad["schema_version"] = export.SCHEMA_VERSION + 1
        export.validate_metrics_line(bad)


def test_telemetry_block_schema_validation():
    blk = export.telemetry_block(wall_us=12.5, realized_evals=42)
    export.validate_telemetry_block(blk, path="unit")
    assert blk["schema_version"] == export.SCHEMA_VERSION
    assert blk["fenced"] is True and blk["realized_evals"] == 42
    with pytest.raises((ValueError, KeyError)):
        export.validate_telemetry_block({"schema_version": 1}, path="unit")


def test_prometheus_text_dump():
    M.enable()
    M.counter_inc("serve.requests", 3)
    M.gauge_set("resident", 2.0)
    M.observe("lat.us", 50.0)
    txt = export.prometheus_text()
    assert "repro_serve_requests 3" in txt
    assert "repro_resident 2" in txt
    assert "repro_lat_us" in txt                    # histogram summary lines


def test_check_metrics_schema_tool(tmp_path):
    """The CI gate script: accepts a valid serve log, rejects a log with
    no metrics line, and rejects a BENCH artifact with no telemetry."""
    line = export.METRICS_PREFIX + json.dumps(dict(
        schema_version=export.SCHEMA_VERSION, mode="graph-stream", n=8,
        ticks=1, epoch=1, live=8, flags=[]))
    good = tmp_path / "good.log"
    good.write_text("noise\n" + line + "\n")
    bad = tmp_path / "bad.log"
    bad.write_text("no metrics here\n")
    bench = tmp_path / "BENCH_x.json"
    bench.write_text(json.dumps(dict(telemetry=export.telemetry_block())))
    sys.path.insert(0, "tools")
    try:
        import check_metrics_schema as cms
    finally:
        sys.path.pop(0)
    assert cms.main([str(good), "--bench-glob",
                     str(tmp_path / "BENCH_*.json")]) == 0
    assert cms.main([str(bad), "--no-bench"]) == 1
    bench.write_text(json.dumps(dict(results={})))
    assert cms.main(["--bench-glob", str(tmp_path / "BENCH_*.json")]) == 1


# ------------------------------------------------------------- sharded
def test_counter_payload_adds_zero_collectives_8dev():
    """DESIGN.md §15.1 acceptance: on an 8-device mesh the counter word
    leaves the §9 schedule at exactly one psum / zero ppermute per draw
    batch, the word's PSUMS slot records that schedule, and the EVALS
    slot equals the engine's analytic count."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.kernels.kde_sampler.sharded import ShardedBlocks, collective_counts
from repro.obs import counters as C
rng = np.random.default_rng(0)
n, bsz = 200, 16
x = rng.normal(0, 0.6, (n, 5)).astype(np.float32)
mesh = jax.make_mesh((8,), ("data",))
eng = ShardedBlocks(mesh, x, gaussian(1.0), block_size=bsz, exact=True)
src = jnp.asarray(rng.integers(0, n, 48), jnp.int32)
key = jax.random.PRNGKey(1)
cc = collective_counts(lambda s, k: eng.fused_sample(s, k), src, key)
assert cc["psum_total"] == 1 and cc["ppermute_total"] == 0, cc
nb, prob, sums, cw = eng.fused_sample(src, key)
t = C.totals(cw)
assert t["psums"] == cc["psum_total"], t
assert t["status"] == 0 and t["draws"] == 48 and t["l1_reads"] == 48
w = 48
want = eng._l1_evals(w) + w * eng.block_size * eng.num_shards
assert t["evals"] == want, (t["evals"], want)
print("OBS_SHARDED_OK")
"""
    full = ('import os\nos.environ["XLA_FLAGS"] = '
            '"--xla_force_host_platform_device_count=8"\n'
            'import sys; sys.path.insert(0, "src")\n' + code)
    p = subprocess.run([sys.executable, "-c", full], capture_output=True,
                       text=True, cwd=".")
    assert p.returncode == 0, p.stderr[-1200:]
    assert "OBS_SHARDED_OK" in p.stdout
