"""Degenerate-input behavior across every Definition 1.1 estimator.

The contract (DESIGN.md §11): on degenerate but representable inputs --
n=1 datasets, all-identical points, bandwidth under/overflow, all-zero
rows -- every estimator either returns finite values or, under
``REPRO_CHECKS=1``, raises ``EstimationError``.  NaN without a flag is the
one forbidden outcome.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kde.base import make_estimator
from repro.core.kernels_fn import gaussian
from repro.ft import guards

jax.config.update("jax_platform_name", "cpu")

ESTIMATORS = ("exact", "rs", "stratified", "exact_block", "hash", "robust")


def _query(name, x, kernel, y):
    est = make_estimator(name, x, kernel, seed=0)
    return est, np.asarray(est.query(jnp.asarray(y)))


def _finite_or_flagged(est, vals) -> bool:
    if np.all(np.isfinite(vals)):
        return True
    return bool(int(np.asarray(getattr(est, "status", 0))))


@pytest.mark.parametrize("name", ESTIMATORS)
def test_single_point_dataset(name):
    x = np.zeros((1, 3), np.float32)
    est, vals = _query(name, x, gaussian(1.0), x)
    assert vals.shape == (1,)
    assert _finite_or_flagged(est, vals), vals


@pytest.mark.parametrize("name", ESTIMATORS)
def test_identical_points(name):
    x = np.ones((64, 3), np.float32) * 0.5
    est, vals = _query(name, x, gaussian(1.0), x[:8])
    assert _finite_or_flagged(est, vals), vals
    if np.all(np.isfinite(vals)):
        # every pair at distance 0: the row sum is at most n
        assert np.all(vals <= 64.0 + 1e-3)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_bandwidth_underflow(name):
    """h -> 0 (1e-15: small enough that every off-diagonal kernel value
    underflows to exactly 0, large enough that 1/h^2 stays f32-finite).
    Finite (possibly zero/floored) estimates, or a flag."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 3)).astype(np.float32)
    est, vals = _query(name, x, gaussian(1e-15), x[:8])
    assert _finite_or_flagged(est, vals), vals


@pytest.mark.parametrize("name", ESTIMATORS)
def test_bandwidth_overflow(name):
    """h -> inf: every kernel value tends to 1; row sums tend to n."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 3)).astype(np.float32)
    est, vals = _query(name, x, gaussian(1e20), x[:8])
    assert _finite_or_flagged(est, vals), vals
    if np.all(np.isfinite(vals)):
        assert np.all(vals <= 64.0 * 1.01 + 1.0)


@pytest.mark.parametrize("name", ESTIMATORS)
def test_all_zero_rows(name):
    x = np.zeros((32, 4), np.float32)
    est, vals = _query(name, x, gaussian(2.0), x[:4])
    assert _finite_or_flagged(est, vals), vals


def test_zero_bandwidth_rejected_eagerly():
    """Exactly 0.0 bandwidth dies in the kernel constructor (1/h), not as
    silent NaN downstream -- the first line of defense."""
    with pytest.raises(ZeroDivisionError):
        gaussian(0.0)


@pytest.mark.parametrize("name", ("stratified", "hash"))
def test_degenerate_raises_or_flags_under_checks(name, monkeypatch):
    """With REPRO_CHECKS=1 the zero-mass degenerate limit must either be
    flagged fatal (raise) or produce clean finite output -- never flagged
    AND silently returned."""
    monkeypatch.setenv("REPRO_CHECKS", "1")
    rng = np.random.default_rng(1)
    x = rng.standard_normal((64, 3)).astype(np.float32)
    try:
        est, vals = _query(name, x, gaussian(1e-15), x[:8])
    except guards.EstimationError:
        return                                  # flagged fatal: fine
    assert np.all(np.isfinite(vals)), vals


def test_sampler_degenerate_zero_mass_flagged():
    """The blocked sampler over an underflowed kernel must raise the
    ZERO_MASS flag rather than silently drawing from the floor."""
    from repro.core.sampling.edge import NeighborSampler

    rng = np.random.default_rng(2)
    x = rng.standard_normal((128, 3)).astype(np.float32)
    nbr = NeighborSampler(x, gaussian(1e-15), mode="blocked",
                          block_size=32, seed=0)
    nb, prob = nbr.sample(np.arange(8))
    assert nbr.status & guards.ZERO_MASS, \
        guards.decode_status(nbr.status)
    assert np.all(nb >= 0) and np.all(nb < 128)


def test_sampler_single_block_frontier():
    """w=1 frontiers and n < block_size datasets stay in contract."""
    from repro.core.sampling.edge import NeighborSampler

    rng = np.random.default_rng(3)
    x = rng.standard_normal((10, 2)).astype(np.float32)
    nbr = NeighborSampler(x, gaussian(1.0), mode="blocked", block_size=16,
                          seed=0)
    nb, prob = nbr.sample(np.array([0]))
    assert nb.shape == (1,) and 0 <= int(nb[0]) < 10 and int(nb[0]) != 0
    assert np.isfinite(prob[0]) and prob[0] > 0
