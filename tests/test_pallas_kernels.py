"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels_fn import exponential, gaussian, laplacian
from repro.kernels.flash_attention import ops as fa
from repro.kernels.kde_attention import ops as ka
from repro.kernels.kde_rowsum import ops as rs
from repro.kernels.kde_sampler import kernel as sk
from repro.kernels.kde_sampler import ops as sops
from repro.kernels.kde_sampler import ref as sref

RNG = np.random.default_rng(0)


# --------------------------------------------------------------- kde_rowsum
@pytest.mark.parametrize("kind,ker", [
    ("gaussian", gaussian(1.3)), ("exponential", exponential(0.7)),
    ("laplacian", laplacian(2.0))])
@pytest.mark.parametrize("m,n,d", [(5, 64, 3), (37, 301, 19), (128, 512, 64)])
def test_kde_rowsum_sweep(kind, ker, m, n, d):
    q = RNG.normal(0, 0.5, (m, d)).astype(np.float32)
    x = RNG.normal(0, 0.5, (n, d)).astype(np.float32)
    out = rs.kde_rowsum(q, x, ker, bm=32, bn=128, interpret=True)
    ref = rs.rowsum_ref(jnp.asarray(q), jnp.asarray(x), kind,
                        1.0 / ker.bandwidth)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=1e-5)


def test_kde_blocksum():
    ker = gaussian(1.0)
    q = RNG.normal(0, 0.5, (17, 8)).astype(np.float32)
    x = RNG.normal(0, 0.5, (256, 8)).astype(np.float32)
    out = rs.kde_blocksum(q, x, ker, bm=16, bn=64, interpret=True)
    ref = rs.blocksum_ref(jnp.asarray(q), jnp.asarray(x), "gaussian", 1.0,
                          bn=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4)


# -------------------------------------------------------------- kde_sampler
@pytest.mark.parametrize("kind,ker", [
    ("gaussian", gaussian(1.3)), ("exponential", exponential(0.7)),
    ("laplacian", laplacian(2.0))])
@pytest.mark.parametrize("m,n,d,bn,bm", [(16, 128, 4, 32, 8),
                                         (32, 256, 8, 64, 16)])
def test_kde_sampler_block_vs_ref(kind, ker, m, n, d, bn, bm):
    """The fused level-1 Pallas kernel (masked block sums + in-pass
    Gumbel-max block draw) agrees with the jnp oracle on every output."""
    q = jnp.asarray(RNG.normal(0, 0.5, (m, d)).astype(np.float32))
    x = jnp.asarray(RNG.normal(0, 0.5, (n, d)).astype(np.float32))
    own = jnp.asarray(RNG.integers(-1, n // bn, m).astype(np.int32))[:, None]
    g = jnp.asarray(RNG.gumbel(size=(m, n // bn)).astype(np.float32))
    inv_bw = 1.0 / ker.bandwidth
    blk, pb, tot, bs = sk.sample_block_pallas(q, x, own, g, kind, inv_bw,
                                              1.0, bm=bm, bn=bn,
                                              interpret=True)
    x_sq = jnp.sum(x * x, axis=-1)
    rblk, rpb, rtot, rbs = sref.sample_block_ref(q, x, x_sq, own[:, 0], g,
                                                 kind, inv_bw, 1.0, bn,
                                                 ker.pairwise)
    np.testing.assert_array_equal(np.asarray(blk), np.asarray(rblk))
    np.testing.assert_allclose(np.asarray(bs), np.asarray(rbs), rtol=2e-4,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(pb), np.asarray(rpb), rtol=2e-4)
    np.testing.assert_allclose(np.asarray(tot), np.asarray(rtot), rtol=2e-4)


@pytest.mark.parametrize("kind,ker", [
    ("gaussian", gaussian(1.3)), ("laplacian", laplacian(2.0))])
def test_kde_sampler_masked_blocksum_vs_ref(kind, ker):
    """The Gumbel-free masked-blocksum Pallas kernel (the level-1 read of
    prob_of / sample_exact / exact walks on TPU) agrees with the jnp
    oracle."""
    m, n, d, bn, bm = 32, 256, 6, 64, 16
    q = jnp.asarray(RNG.normal(0, 0.5, (m, d)).astype(np.float32))
    x = jnp.asarray(RNG.normal(0, 0.5, (n, d)).astype(np.float32))
    own = jnp.asarray(RNG.integers(-1, n // bn, m).astype(np.int32))[:, None]
    inv_bw = 1.0 / ker.bandwidth
    bs = sk.masked_blocksum_pallas(q, x, own, kind, inv_bw, 1.0, bm=bm,
                                   bn=bn, interpret=True)
    x_sq = jnp.sum(x * x, axis=-1)
    ref = sref.masked_block_sums_ref(q, x, x_sq, own[:, 0], kind, inv_bw,
                                     1.0, bn, ker.pairwise)
    np.testing.assert_allclose(np.asarray(bs), np.asarray(ref), rtol=2e-4,
                               atol=1e-6)


def test_kde_sampler_fused_pallas_engine_law():
    """End-to-end sampler with the Pallas level-1 (interpret mode): the
    neighbor distribution matches the exact k(u, v)/deg(u) law and matches
    the jnp engine.  (The two paths use different categorical samplers --
    Gumbel-max streamed in-kernel vs inverse-CDF -- so streams differ but
    the law must not.)"""
    from repro.core.sampling.edge import NeighborSampler
    x = RNG.normal(0, 0.5, (300, 5)).astype(np.float32)
    ker = gaussian(1.5)
    k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
    src = 13
    row = k[src].copy()
    row[src] = 0
    p = row / row.sum()
    reps = 6000
    a = NeighborSampler(x, ker, exact_blocks=True, seed=7, use_pallas=True,
                        interpret=True)
    va, pa = a.sample(np.full(reps, src))
    emp = np.bincount(va, minlength=len(p)) / reps
    assert 0.5 * np.abs(emp - p).sum() < 3.0 * np.sqrt(len(p) / reps)
    # realized probabilities are the exact law (level-1 reads are exact)
    np.testing.assert_allclose(pa, p[va], rtol=1e-3, atol=1e-9)


def test_kde_sampler_stratified_tail_block_unbiased():
    """Padding-bias regression: with a tail block smaller than
    samples_per_block, the stratified estimate of the tail sum must stay
    unbiased (the seed summed duplicated pad indices into it)."""
    rng = np.random.default_rng(5)
    n, d, bn, s = 5 * 128 + 40, 6, 128, 64        # tail size 40 < s = 64
    x = jnp.asarray(rng.normal(0, 0.5, (n, d)).astype(np.float32))
    x_sq = jnp.sum(x * x, axis=-1)
    ker = gaussian(2.0)
    y = x[:4]
    cfg = dict(kind="gaussian", inv_bw=0.5, beta=1.0, pairwise=ker.pairwise,
               block_size=bn, num_blocks=6, n=n)
    exact = np.asarray(sops.exact_block_sums(y, x, x_sq, **cfg)[0])
    reps = 300
    keys = jax.random.split(jax.random.PRNGKey(0), reps)
    est = np.stack([np.asarray(sops.stratified_block_sums(y, x, x_sq, k,
                                                          s=s, **cfg)[0])
                    for k in keys]).mean(0)
    # the tail block (last column) is exact when s >= tail size; all blocks
    # must match the exact sums in expectation
    np.testing.assert_allclose(est[:, -1], exact[:, -1], rtol=1e-3)
    np.testing.assert_allclose(est, exact, rtol=0.05)


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("b,hq,hkv,sq,skv,dh", [
    (2, 4, 2, 64, 64, 32),       # GQA, square causal
    (1, 8, 2, 1, 300, 64),       # decode: 1 query vs long cache
    (2, 4, 4, 100, 228, 16),     # MHA, ragged shapes
    (1, 2, 1, 17, 17, 8),        # tiny odd
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, sq, skv, dh, dtype):
    q = RNG.normal(0, 1, (b, hq, sq, dh)).astype(dtype)
    k = RNG.normal(0, 1, (b, hkv, skv, dh)).astype(dtype)
    v = RNG.normal(0, 1, (b, hkv, skv, dh)).astype(dtype)
    out = fa.flash_attention(q, k, v, True, 64, 64, True, False)
    ref, _ = fa.attention_ref(q, k, v, causal=True, scale=1 / np.sqrt(dh))
    tol = 2e-5 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_grads():
    b, hq, hkv, sq, skv, dh = 2, 4, 2, 48, 48, 16
    q = RNG.normal(0, 1, (b, hq, sq, dh)).astype(np.float32)
    k = RNG.normal(0, 1, (b, hkv, skv, dh)).astype(np.float32)
    v = RNG.normal(0, 1, (b, hkv, skv, dh)).astype(np.float32)

    def loss_k(q, k, v):
        return jnp.sum(fa.flash_attention(q, k, v, True, 64, 64, True,
                                          False) ** 2)

    def loss_r(q, k, v):
        o, _ = fa.attention_ref(q, k, v, causal=True, scale=1 / np.sqrt(dh))
        return jnp.sum(o ** 2)

    g1 = jax.grad(loss_k, (0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_r, (0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4)


def test_flash_lse_output():
    q = RNG.normal(0, 1, (1, 2, 32, 16)).astype(np.float32)
    k = RNG.normal(0, 1, (1, 2, 32, 16)).astype(np.float32)
    v = RNG.normal(0, 1, (1, 2, 32, 16)).astype(np.float32)
    out, lse = fa.flash_attention(q, k, v, True, 32, 32, True, True)
    ref, lse_ref = fa.attention_ref(q, k, v, causal=True,
                                    scale=1 / np.sqrt(16))
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                               atol=1e-4)


# ------------------------------------------------------------ kde attention
@pytest.mark.parametrize("b,hq,hkv,S,dh,bk,stride,top_p", [
    (2, 8, 2, 2048, 64, 128, 8, 4),
    (1, 4, 4, 1024, 32, 256, 16, 2),
    (2, 2, 1, 512, 16, 64, 4, 3),
])
def test_kde_attention_matches_mirror(b, hq, hkv, S, dh, bk, stride, top_p):
    """The Pallas pipeline is deterministic (strided subsample), so it must
    agree with the jnp mirror exactly."""
    q = RNG.normal(0, 1, (b, hq, dh)).astype(np.float32)
    k = RNG.normal(0, 0.3, (b, hkv, S, dh)).astype(np.float32)
    v = RNG.normal(0, 1, (b, hkv, S, dh)).astype(np.float32)
    out = ka.kde_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                           top_p=top_p, bk=bk, stride=stride, interpret=True)
    ref = ka.kde_attention_ref(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), top_p=top_p, bk=bk,
                               stride=stride)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_kde_attention_approximates_exact_on_peaked():
    """When attention mass is concentrated (the realistic long-context
    regime), top-P blocks + KDE residual get close to exact attention."""
    b, hq, hkv, S, dh = 1, 4, 2, 4096, 32
    q = RNG.normal(0, 1, (b, hq, dh)).astype(np.float32)
    k = RNG.normal(0, 0.05, (b, hkv, S, dh)).astype(np.float32)
    # plant high-score keys inside two blocks (strong enough that the
    # planted mass dominates the 4096-key background)
    for h in range(hkv):
        qv = q.reshape(b, hkv, hq // hkv, dh).mean(2)[0, h]
        k[0, h, 100:140] = 8.0 * qv / np.linalg.norm(qv) + k[0, h, 100:140]
        k[0, h, 3000:3020] = 6.0 * qv / np.linalg.norm(qv) + k[0, h, 3000:3020]
    v = RNG.normal(0, 1, (b, hkv, S, dh)).astype(np.float32)
    out = ka.kde_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                           top_p=8, bk=256, stride=8, interpret=True)
    exact = ka.exact_decode_attention(jnp.asarray(q), jnp.asarray(k),
                                      jnp.asarray(v))
    err = float(jnp.abs(out - exact).max())
    scale = float(jnp.abs(exact).max())
    assert err < 0.2 * scale, (err, scale)


def test_kde_attention_exact_when_all_blocks_selected():
    """top_p = all blocks -> no residual -> exact attention."""
    b, hq, hkv, S, dh = 1, 2, 2, 256, 16
    q = RNG.normal(0, 1, (b, hq, dh)).astype(np.float32)
    k = RNG.normal(0, 0.5, (b, hkv, S, dh)).astype(np.float32)
    v = RNG.normal(0, 1, (b, hkv, S, dh)).astype(np.float32)
    out = ka.kde_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                           top_p=4, bk=64, stride=4, interpret=True)
    exact = ka.exact_decode_attention(jnp.asarray(q), jnp.asarray(k),
                                      jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exact), atol=1e-4)
