"""Roofline machinery: HLO parsing, trip-count correction, analytic FLOPs
validated against XLA cost_analysis on small UNROLLED models."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, ShapeConfig, get_config, get_reduced
from repro.roofline.analysis import (collective_bytes, roofline_terms,
                                     shape_bytes)
from repro.roofline.flops import _head_flops, _layer_fwd_flops, cell_cost


def test_shape_bytes_parser():
    assert shape_bytes("f32[2,3,4]{2,1,0}") == 96
    assert shape_bytes("bf16[128]") == 256
    assert shape_bytes("(f32[2,2]{1,0}, s32[4])") == 32
    assert shape_bytes("pred[]") == 1
    assert shape_bytes("token[]") == 0


def test_while_trip_count_correction():
    """A collective inside a scan body must be multiplied by the trip count."""
    def f(x):
        def body(c, _):
            return c + jax.lax.psum(c, "i") * 0.001, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("i",))
    sh = NamedSharding(mesh, P())
    from repro.compat import shard_map
    g = jax.jit(shard_map(f, mesh=mesh, in_specs=P("i"), out_specs=P("i")))
    comp = g.lower(jax.ShapeDtypeStruct((8, 4), jnp.float32)).compile()
    cs = collective_bytes(comp.as_text())
    # one 8x4 f32 all-reduce (on a 1-device mesh it may be optimized away --
    # accept either 0 or trip-scaled bytes)
    if cs.total_bytes > 0:
        assert cs.total_bytes % 7 == 0 or cs.total_bytes >= 7 * 16


def test_analytic_flops_match_hlo_on_unrolled_tiny_model():
    """The roofline compute term comes from the analytic model; validate it
    against cost_analysis on a 2-layer reduced config with UNROLLED layers
    (no scan -> XLA counts everything)."""
    from repro.models import layers as L
    cfg = dataclasses.replace(get_reduced("yi_6b"), dtype="float32",
                              num_layers=2)
    b, s = 2, 128

    def fwd_unrolled(params, tokens):
        x = params["embed"][tokens]
        positions = jnp.arange(s)
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            from repro.models.transformer import _dense_block
            x, _ = _dense_block(lp, cfg, x, positions, "xla")
        head = params.get("lm_head", params["embed"].T)
        return x @ head

    from repro.models import transformer as T
    params = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
    comp = jax.jit(fwd_unrolled).lower(params, toks).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):  # older jax returns one entry per device
        ca = ca[0]
    hlo_flops = ca["flops"]
    analytic = cfg.num_layers * _layer_fwd_flops(cfg, b, s) \
        + 2.0 * b * s * cfg.d_model * cfg.padded_vocab
    ratio = hlo_flops / analytic
    assert 0.7 < ratio < 1.3, (hlo_flops, analytic)


def test_cell_cost_sanity_all_cells():
    """Every (arch x shape) cell yields positive, ordered cost terms."""
    from repro.configs.base import ARCH_IDS
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            cost = cell_cost(cfg, shape, kde_decode=(shape.name == "long_500k"))
            assert cost.flops > 0 and cost.hbm_bytes > 0, (arch, shape.name)
            assert cost.model_flops <= cost.flops * 1.01, (arch, shape.name)
            if shape.kind == "train":
                # train FLOPs within 3x of 6ND (attention + dispatch overhead)
                assert cost.flops < 6 * cost.model_flops, (arch, shape.name)


def test_kde_decode_reduces_flops():
    cfg = get_config("yi_6b")
    shape = SHAPES["long_500k"]
    exact = cell_cost(cfg, shape, kde_decode=False)
    kde = cell_cost(cfg, shape, kde_decode=True)
    assert kde.flops < 0.35 * exact.flops  # sub-quadratic attention win


def test_roofline_terms():
    rl = roofline_terms(1e15, 9e14, 1e12, 5e9, 256)
    assert rl.dominant in ("compute", "memory", "collective")
    assert 0 < rl.useful_ratio <= 1.0
    assert rl.compute_s == pytest.approx(1e15 / (256 * 197e12))
