"""Regression tests for the §Perf optimizations (EXPERIMENTS.md):
chunked attention, context-parallel prefill, shard_map MoE, shard_map KDE
decode.  Multi-device checks run in subprocesses with their own XLA_FLAGS."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


RNG = np.random.default_rng(7)


def _run(code: str, devices: int = 8) -> str:
    full = (f'import os\nos.environ["XLA_FLAGS"] = '
            f'"--xla_force_host_platform_device_count={devices}"\n'
            f'import sys; sys.path.insert(0, "src")\n' + code)
    p = subprocess.run([sys.executable, "-c", full], capture_output=True,
                       text=True, cwd=".")
    assert p.returncode == 0, p.stderr[-1500:]
    return p.stdout


# ------------------------------------------------------- chunked attention
@pytest.mark.parametrize("b,hq,hkv,sq,skv,chunk", [
    (2, 4, 2, 120, 120, 32),      # GQA, ragged chunking
    (1, 2, 2, 64, 64, 64),        # single chunk
    (2, 8, 4, 33, 97, 16),        # decode-ish offset shapes
])
def test_chunked_attention_equals_dense(b, hq, hkv, sq, skv, chunk):
    hd = 16
    q = jnp.asarray(RNG.normal(0, 1, (b, hq, sq, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, skv, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, skv, hd)).astype(np.float32))
    off = skv - sq
    o1 = L.xla_attention(q, k, v, causal=True, q_offset=off, kv_valid=skv - 3)
    o2 = L.xla_attention_chunked(q, k, v, causal=True, q_offset=off,
                                 kv_valid=skv - 3, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_chunked_attention_bf16():
    q = jnp.asarray(RNG.normal(0, 1, (1, 2, 64, 16))).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.normal(0, 1, (1, 2, 64, 16))).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.normal(0, 1, (1, 2, 64, 16))).astype(jnp.bfloat16)
    o1 = L.xla_attention(q, k, v, causal=True)
    o2 = L.xla_attention_chunked(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=3e-2)


# ------------------------------------------------- context-parallel prefill
def test_seq_mode_prefill_lowers_and_cuts_collectives():
    out = _run("""
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_reduced, ShapeConfig
from repro.data.pipeline import input_specs
from repro.distributed import sharding as shard
from repro.models import transformer as T
from repro.models.layers import activation_sharding
from repro.train.train_step import make_prefill_step
from repro.roofline.analysis import collective_bytes

cfg = get_reduced("yi_6b")
shape = ShapeConfig("p", 256, 4, "prefill")
mesh = jax.make_mesh((2, 4), ("data", "model"))
params_s = jax.eval_shape(lambda: T.cast_params(
    T.init_params(jax.random.PRNGKey(0), cfg), jnp.bfloat16))
p_sh = shard.param_shardings(params_s, mesh)
specs = input_specs(cfg, shape)
b_sh = {k: NamedSharding(mesh, shard.batch_spec(mesh, v.ndim, v.shape[0]))
        for k, v in specs.items()}
res = {}
for mode in (False, True):
    with activation_sharding(mesh, ("data",), seq_mode=mode):
        comp = jax.jit(make_prefill_step(cfg),
                       in_shardings=(p_sh, b_sh)).lower(params_s, specs).compile()
    res[mode] = collective_bytes(comp.as_text(),
                                 default_trip=cfg.num_layers).total_bytes
print("TP:", res[False], "CP:", res[True])
assert res[True] > 0
print("SEQ_MODE_OK")
""")
    assert "SEQ_MODE_OK" in out


def test_seq_mode_numerics_match():
    """CP-sharded prefill produces the same logits as unsharded."""
    out = _run("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_reduced, ShapeConfig
from repro.data.pipeline import make_batch
from repro.models import transformer as T
from repro.models.layers import activation_sharding
cfg = dataclasses.replace(get_reduced("yi_6b"), dtype="float32")
params = T.init_params(jax.random.PRNGKey(0), cfg)
shape = ShapeConfig("p", 64, 2, "train")
batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, 0).items()}
ref, _ = T.forward(params, cfg, batch, remat=False)
mesh = jax.make_mesh((2, 4), ("data", "model"))
with activation_sharding(mesh, ("data",), seq_mode=True):
    got, _ = jax.jit(lambda p, b: T.forward(p, cfg, b, remat=False))(params, batch)
np.testing.assert_allclose(np.asarray(ref), np.asarray(got), atol=2e-3)
print("CP_NUMERICS_OK")
""")
    assert "CP_NUMERICS_OK" in out


# --------------------------------------------------------- shard_map MoE
def test_shardmap_moe_matches_dense_reference():
    out = _run("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_reduced
from repro.models import transformer as T
from repro.models import layers as L
cfg = dataclasses.replace(get_reduced("granite_moe_1b_a400m"), dtype="float32")
params = T.init_params(jax.random.PRNGKey(0), cfg)
lp = jax.tree.map(lambda a: a[0], params["layers"])
x = jnp.asarray(np.random.default_rng(0).normal(
    0, 0.5, (4, 16, cfg.d_model)).astype(np.float32))
mesh = jax.make_mesh((2, 4), ("data", "model"))  # 4 experts over model=4
y_ref, aux_ref = L.moe_block_dense(lp["mlp"], cfg, x)
with L.activation_sharding(mesh, ("data",)):
    y_sm, aux_sm = jax.jit(lambda p, x: L.moe_block(p, cfg, x,
                                                    capacity_factor=8.0))(
        lp["mlp"], x)
np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_ref), atol=1e-4)
assert abs(float(aux_sm) - float(aux_ref)) < 1e-4
print("MOE_SHARDMAP_OK")
""")
    assert "MOE_SHARDMAP_OK" in out


def test_shardmap_moe_grads_match():
    out = _run("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_reduced
from repro.models import layers as L
from repro.models import transformer as T
cfg = dataclasses.replace(get_reduced("granite_moe_1b_a400m"), dtype="float32")
params = T.init_params(jax.random.PRNGKey(0), cfg)
lp = jax.tree.map(lambda a: a[0], params["layers"])
x = jnp.asarray(np.random.default_rng(1).normal(
    0, 0.5, (4, 8, cfg.d_model)).astype(np.float32))
mesh = jax.make_mesh((2, 4), ("data", "model"))

def loss_ref(p, x):
    y, aux = L.moe_block_dense(p, cfg, x)
    return jnp.sum(y ** 2) + 0.01 * aux

def loss_sm(p, x):
    y, aux = L.moe_block(p, cfg, x, capacity_factor=8.0)
    return jnp.sum(y ** 2) + 0.01 * aux

g_ref = jax.grad(loss_ref)(lp["mlp"], x)
with L.activation_sharding(mesh, ("data",)):
    g_sm = jax.jit(jax.grad(loss_sm))(lp["mlp"], x)
for k in ("w1", "w2", "w3", "router"):
    np.testing.assert_allclose(np.asarray(g_sm[k]), np.asarray(g_ref[k]),
                               atol=2e-3)
print("MOE_GRADS_OK")
""")
    assert "MOE_GRADS_OK" in out


# --------------------------------------------------- shard_map KDE decode
@pytest.mark.parametrize("hkv", [2, 4])  # seq-sharded vs heads-sharded layout
def test_shardmap_kde_decode_matches_mirror(hkv):
    out = _run(f"""
import jax, jax.numpy as jnp, numpy as np
from repro.models import layers as L
from repro.kernels.kde_attention.ref import kde_attention_ref
rng = np.random.default_rng(0)
b, hq, hkv, S, hd = 1, 8, {hkv}, 1024, 32
q = jnp.asarray(rng.normal(0, 1, (b, hq, 1, hd)).astype(np.float32))
k = jnp.asarray(rng.normal(0, 0.3, (b, hkv, S, hd)).astype(np.float32))
v = jnp.asarray(rng.normal(0, 1, (b, hkv, S, hd)).astype(np.float32))
mesh = jax.make_mesh((2, 4), ("data", "model"))
kw = dict(top_p=4, bk=64, stride=4)
with L.activation_sharding(mesh, ("data",)):
    out = L.kde_decode_attention_shardmap(q, k, v, 900, mesh=mesh,
                                          baxes=("data",), **kw)
ref = kde_attention_ref(q[:, :, 0, :], k, v, kv_valid=900, **kw)
np.testing.assert_allclose(np.asarray(out[:, :, 0, :]), np.asarray(ref),
                           atol=1e-5)
print("KDE_SHARDMAP_OK")
""")
    assert "KDE_SHARDMAP_OK" in out


def test_shardmap_kde_falls_back_on_indivisible():
    """S not a multiple of bk*shards -> returns None (mirror fallback)."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import layers as L
rng = np.random.default_rng(0)
q = jnp.asarray(rng.normal(0, 1, (1, 4, 1, 16)).astype(np.float32))
k = jnp.asarray(rng.normal(0, 1, (1, 2, 96, 16)).astype(np.float32))
v = jnp.asarray(rng.normal(0, 1, (1, 2, 96, 16)).astype(np.float32))
mesh = jax.make_mesh((2, 4), ("data", "model"))
r = L.kde_decode_attention_shardmap(q, k, v, 90, top_p=2, bk=64, stride=4,
                                    mesh=mesh, baxes=("data",))
assert r is None
print("FALLBACK_OK")
""")
    assert "FALLBACK_OK" in out
