"""Fused Algorithm 5.1 edge pipeline (DESIGN.md §6): ref-oracle agreement
in interpret mode, unbiasedness of E[L'], sample/prob_of consistency through
the fused path, and the kernel_evals / kde_queries counter audit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernels_fn import gaussian
from repro.core.laplacian import laplacian_dense
from repro.core.sampling.edge import NeighborSampler
from repro.core.sampling.vertex import DegreeSampler
from repro.core.sparsify import spectral_sparsify
from repro.kernels.kde_sampler import ops as sops
from repro.kernels.kde_sampler import ref as sref


@pytest.fixture(scope="module")
def graph():
    rng = np.random.default_rng(7)
    x = rng.normal(0, 0.5, (300, 5)).astype(np.float32)
    ker = gaussian(bandwidth=1.5)
    k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
    return x, ker, k


def _degree_cdf(k):
    deg = k.sum(1) - 1.0
    prefix = np.cumsum(deg)
    cdf = jnp.asarray((prefix / prefix[-1]).astype(np.float32))
    degs = jnp.asarray(deg.astype(np.float32))
    return deg, cdf, degs, float(prefix[-1])


def test_fused_edge_batch_matches_ref_oracle_interpret(graph):
    """The fused edge-batch op on its Pallas path (interpret mode on CPU)
    reproduces the ref.py oracle: (u, v) bit-for-bit, floats to f32
    tolerance -- same PRNGKey, same key-split discipline."""
    x, ker, k = graph
    n, bs, bm, batch = 300, 32, 16, 64
    nb = (n + bs - 1) // bs
    xd = jnp.asarray(x)
    x_sq = jnp.sum(xd * xd, axis=-1)
    _, cdf, degs, total = _degree_cdf(k)
    cfg = dict(kind="gaussian", inv_bw=1.0 / 1.5, beta=1.0, pairwise=None,
               block_size=bs, num_blocks=nb, n=n, s=8, exact=True,
               use_pallas=True, interpret=True, bm=bm)
    key = jax.random.PRNGKey(11)
    got = sops.fused_edge_batch(xd, x_sq, cdf, degs, 1.0 / total, 1.0 / 1000,
                                key, batch=batch, **cfg)
    want = sref.fused_edge_batch_ref(xd, x_sq, cdf, degs, 1.0 / total,
                                     1.0 / 1000, key, batch, "gaussian",
                                     1.0 / 1.5, 1.0, bs, nb, n)
    u, v, w, q_uv, q_vu, st = [np.asarray(a) for a in got]
    ru, rv, rw, rq_uv, rq_vu = [np.asarray(a) for a in want]
    assert int(st[0]) == 0, "clean graph, clean status expected"
    np.testing.assert_array_equal(u, ru)
    np.testing.assert_array_equal(v, rv)
    np.testing.assert_allclose(w, rw, rtol=2e-4)
    np.testing.assert_allclose(q_uv, rq_uv, rtol=2e-4)
    np.testing.assert_allclose(q_vu, rq_vu, rtol=2e-4)


def test_fused_edge_batch_realized_probs_are_exact_law(graph):
    """With exact level-1 reads, the q_uv / q_vu the fused op reports ARE
    the true conditional neighbor probabilities k(u,v)/deg(u)."""
    x, ker, k = graph
    nbr = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=3)
    deg, cdf, degs, total = _degree_cdf(k)
    u, v, w, q_uv, q_vu = nbr.edge_batches(cdf, degs, total, 256, batch=256)
    koff = k.copy()
    np.fill_diagonal(koff, 0.0)
    np.testing.assert_allclose(q_uv, koff[u, v] / koff[u].sum(1), rtol=1e-3,
                               atol=1e-9)
    np.testing.assert_allclose(q_vu, koff[v, u] / koff[v].sum(1), rtol=1e-3,
                               atol=1e-9)


def test_fused_prob_of_consistent_through_new_path(graph):
    """prob_of recomputes exactly the probabilities the fused edge op
    realized (exact level-1 reads -> both are deterministic reads of the
    same law)."""
    x, ker, _ = graph
    nbr = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=5)
    _, cdf, degs, total = _degree_cdf(np.asarray(ker.matrix(nbr.x),
                                                 np.float64))
    u, v, _, q_uv, q_vu = nbr.edge_batches(cdf, degs, total, 200, batch=200)
    np.testing.assert_allclose(q_uv, nbr.prob_of(u, v), rtol=1e-4,
                               atol=1e-10)
    np.testing.assert_allclose(q_vu, nbr.prob_of(v, u), rtol=1e-4,
                               atol=1e-10)


def test_fused_vertex_marginal_matches_degrees(graph):
    """The device inverse-CDF vertex draw samples u ~ degrees."""
    x, ker, k = graph
    nbr = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=0)
    deg, cdf, degs, total = _degree_cdf(k)
    reps = 30000
    u, _, _, _, _ = nbr.edge_batches(cdf, degs, total, reps, batch=1024)
    emp = np.bincount(u, minlength=len(deg)) / len(u)
    p = deg / deg.sum()
    assert 0.5 * np.abs(emp - p).sum() < 3.0 * np.sqrt(len(deg) / reps)


def test_sparsifier_expected_laplacian_unbiased():
    """E[L'] = L: averaging independent fused sparsifiers converges to the
    dense Laplacian (Alg 5.1's importance weights cancel the sampling law
    exactly when the realized probabilities are exact)."""
    rng = np.random.default_rng(0)
    n = 64
    x = rng.normal(0, 0.4, (n, 4)).astype(np.float32)
    ker = gaussian(bandwidth=1.5)
    l_true = laplacian_dense(ker, x)
    acc = np.zeros_like(l_true)
    reps = 12
    t = 3000
    for r in range(reps):
        g = spectral_sparsify(x, ker, num_edges=t, estimator="exact_block",
                              exact_blocks=True, seed=100 + r)
        acc += g.laplacian_dense()
    acc /= reps
    rel = np.linalg.norm(acc - l_true, "fro") / np.linalg.norm(l_true, "fro")
    assert rel < 0.05, rel


def test_sparsifier_counters_match_analytic():
    """kernel_evals / kde_queries match the analytic counts of the fused
    pipeline (shared level-1 estimator + one scan program)."""
    rng = np.random.default_rng(1)
    n, t, batch, spb = 400, 1000, 256, 8
    x = rng.normal(0, 0.5, (n, 5)).astype(np.float32)
    ker = gaussian(bandwidth=1.5)
    drawn = ((t + batch - 1) // batch) * batch

    # stratified level-1 reads, shared estimator
    g = spectral_sparsify(x, ker, num_edges=t, estimator="stratified",
                          samples_per_block=spb, seed=0, batch=batch)
    nbr = NeighborSampler(x, ker, mode="blocked", samples_per_block=spb)
    bs, nb = nbr.block_size, nbr.num_blocks
    assert g.kernel_evals == n * nb * spb + drawn * (nb * spb + bs + 1)
    assert g.kde_queries == n + drawn

    # exact level-1 reads, shared estimator
    g = spectral_sparsify(x, ker, num_edges=t, estimator="exact",
                          exact_blocks=True, seed=0, batch=batch)
    assert g.kernel_evals == n * n + drawn * (n + bs + 1)
    assert g.kde_queries == n + drawn


def test_fused_edge_batches_hit_compiled_path(graph):
    """Repeated edge_batches calls with the same shapes never retrace."""
    x, ker, k = graph
    nbr = NeighborSampler(x, ker, mode="blocked", exact_blocks=True, seed=0)
    _, cdf, degs, total = _degree_cdf(k)
    nbr.edge_batches(cdf, degs, total, 512, batch=128)   # traces the scan
    before = dict(sops.TRACE_COUNTS)
    for _ in range(3):
        nbr.edge_batches(cdf, degs, total, 512, batch=128)
    assert dict(sops.TRACE_COUNTS) == before, \
        "fused edge-batch scan retraced or fell off the compiled path"
