"""Training loop, checkpointing, fault tolerance, elastic restore."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.configs.base import ShapeConfig, get_reduced
from repro.data.pipeline import make_batch
from repro.ft.watchdog import Watchdog
from repro.models import transformer as T
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step

SHAPE = ShapeConfig("t", 64, 4, "train")


def _setup(arch="yi_6b", seed=0):
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def test_loss_decreases():
    cfg, params = _setup()
    step = jax.jit(make_train_step(cfg, opt.AdamWConfig(lr=2e-3,
                                                        warmup_steps=5)))
    state = opt.init_adamw(params)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, i).items()}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_microbatch_equivalence():
    """Gradient accumulation over 4 microbatches ~= one big batch."""
    cfg, params = _setup()
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, 0).items()}
    s1 = jax.jit(make_train_step(cfg))
    s4 = jax.jit(make_train_step(cfg, microbatch=4))
    state = opt.init_adamw(params)
    p1, _, m1 = s1(params, state, batch)
    p4, _, m4 = s4(params, state, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-3
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_checkpoint_roundtrip(tmp_path):
    cfg, params = _setup()
    state = opt.init_adamw(params)
    path = str(tmp_path / "ck")
    ckpt.save(path, 7, (params, state))
    assert ckpt.latest_step(path) == 7
    (p2, s2), step = ckpt.restore(path, (params, state))
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_prunes_and_atomic(tmp_path):
    cfg, params = _setup()
    path = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(path, s, params)
    kept = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    assert len(kept) == 3 and ckpt.latest_step(path) == 5
    assert not any(d.endswith(".tmp") for d in os.listdir(path))


def test_resume_determinism(tmp_path):
    """Train 10; vs train 5 + resume + train 5: identical parameters
    (restart-safe data + exact state roundtrip)."""
    cfg, params0 = _setup()
    step = jax.jit(make_train_step(cfg))

    def run(params, state, lo, hi):
        for i in range(lo, hi):
            batch = {k: jnp.asarray(v)
                     for k, v in make_batch(cfg, SHAPE, i).items()}
            params, state, _ = step(params, state, batch)
        return params, state

    pA, sA = run(params0, opt.init_adamw(params0), 0, 10)
    pB, sB = run(params0, opt.init_adamw(params0), 0, 5)
    path = str(tmp_path / "ck")
    ckpt.save(path, 5, (pB, sB))
    (pB, sB), _ = ckpt.restore(path, (pB, sB))
    pB, sB = run(pB, sB, 5, 10)
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_failure_injection_and_resume(tmp_path):
    """Kill the driver mid-run (exit 17); rerun resumes and finishes."""
    env = dict(os.environ, PYTHONPATH="src")
    ckdir = str(tmp_path / "ck")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "yi_6b",
           "--reduced", "--steps", "12", "--batch", "2", "--seq", "32",
           "--ckpt-dir", ckdir, "--ckpt-every", "4", "--log-every", "4"]
    p = subprocess.run(cmd + ["--fail-at-step", "6"], env=env,
                       capture_output=True, text=True, cwd=".")
    assert p.returncode == 17, p.stderr[-500:]
    assert ckpt.latest_step(ckdir) == 4
    p = subprocess.run(cmd, env=env, capture_output=True, text=True, cwd=".")
    assert p.returncode == 0, p.stderr[-500:]
    assert "resumed from step 4" in p.stdout
    assert ckpt.latest_step(ckdir) == 12


def test_elastic_restore_different_mesh(tmp_path):
    """Checkpoint written under one sharding restores onto another mesh
    (data-axis resize) -- subprocess with 8 fake devices."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import dataclasses, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_reduced
from repro.models import transformer as T
from repro.distributed import sharding as shard
from repro.ckpt import checkpoint as ckpt
cfg = dataclasses.replace(get_reduced("yi_6b"), dtype="float32")
params = T.init_params(jax.random.PRNGKey(0), cfg)
mesh4 = jax.make_mesh((4, 2), ("data", "model"))
p4 = jax.tree.map(jax.device_put, params, shard.param_shardings(params, mesh4))
ckpt.save({str(tmp_path)!r}, 3, p4)
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
sh2 = shard.param_shardings(params, mesh2)
restored, step = ckpt.restore({str(tmp_path)!r}, params, shardings=sh2)
assert step == 3
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC_OK")
"""
    p = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, cwd=".")
    assert "ELASTIC_OK" in p.stdout, p.stderr[-800:]


def test_watchdog():
    wd = Watchdog(hosts=4, straggler_factor=1.5, heartbeat_timeout_s=10)
    for step in range(5):
        for h in range(4):
            wd.beat(h, 1.0 if h != 2 else 2.5, now=float(step))
    d = wd.decide(now=5.0)
    assert d["stragglers"] == [2] and d["dead"] == []
    # host 3 stops beating
    for step in range(5, 30):
        for h in (0, 1, 2):
            wd.beat(h, 1.0 if h != 2 else 2.5, now=float(step))
    d = wd.decide(now=30.0)
    assert 3 in d["dead"]


def test_watchdog_flags_host_that_never_heartbeats():
    """Regression: decide() used to skip hosts with steps == 0, so a host
    that died before its FIRST heartbeat was never declared dead.  The
    clock now starts at construction for every host."""
    wd = Watchdog(hosts=3, heartbeat_timeout_s=10, now=0.0)
    wd.beat(0, 1.0, now=12.0)
    wd.beat(1, 1.0, now=12.0)
    # host 2 never beats; inside the window nobody is dead yet
    assert wd.decide(now=9.0)["dead"] == []
    d = wd.decide(now=15.0)
    assert d["dead"] == [2], d
    # silent hosts never enter the straggler EWMA median
    assert d["stragglers"] == []


def test_gradient_compression_error_feedback():
    """int8 compression: biased per step, but error feedback keeps the
    accumulated gradient sum accurate."""
    rng = np.random.default_rng(0)
    g_true = [rng.normal(0, 1, (64, 64)).astype(np.float32)
              for _ in range(20)]
    resid = jnp.zeros((64, 64), jnp.float32)
    acc_comp = np.zeros((64, 64), np.float32)
    for g in g_true:
        q, scale, resid = opt.compress(jnp.asarray(g), resid)
        acc_comp += np.asarray(opt.decompress(q, scale))
    acc_true = np.sum(g_true, axis=0)
    rel = np.abs(acc_comp - acc_true).max() / np.abs(acc_true).max()
    assert rel < 0.02, rel
