"""Linear-algebra applications: sparsify (5.1), solve (5.1.1), LRA (5.2),
spectrum (5.3), top eigenvalue (5.4)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.eigen import top_eigenvalue, top_eigenvalue_exact
from repro.core.kernels_fn import gaussian
from repro.core.laplacian import (cg_laplacian, laplacian_dense,
                                  solve_kernel_laplacian)
from repro.core.lowrank import (countsketch_lowrank, factored_error,
                                fkv_lowrank, optimal_error, projection_error,
                                subspace_iteration)
from repro.core.sparsify import resparsify, spectral_sparsify
from repro.core.spectrum import (approximate_spectrum, emd_1d, exact_spectrum,
                                 invert_moments, _project_simplex)


@pytest.fixture(scope="module")
def cloud():
    # bounded-tau dataset (Parameterization 1.2): tau ~ 0.1
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.35, (500, 5)).astype(np.float32)
    ker = gaussian(bandwidth=2.0)
    k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
    assert k.min() > 0.05
    return x, ker, k


# ------------------------------------------------------------- sparsify
def test_sparsifier_spectral_closeness(cloud):
    """Theorem 5.3: (1-eps) L <= L' <= (1+eps) L on quadratic forms."""
    x, ker, k = cloud
    g = spectral_sparsify(x, ker, num_edges=12000, estimator="exact",
                          exact_blocks=True, seed=0)
    l_true = laplacian_dense(ker, x)
    l_sp = g.laplacian_dense()
    rng = np.random.default_rng(1)
    v = rng.standard_normal((500, 20))
    v -= v.mean(0)
    ratios = np.einsum("ij,ij->j", v, l_sp @ v) / \
        np.einsum("ij,ij->j", v, l_true @ v)
    assert ratios.min() > 0.9 and ratios.max() < 1.1, (ratios.min(), ratios.max())
    # interior eigenvalue preservation (extreme tail needs more samples)
    ev_t = np.sort(np.linalg.eigvalsh(l_true))
    ev_s = np.sort(np.linalg.eigvalsh(l_sp))
    r = ev_s[25:-25] / ev_t[25:-25]
    assert r.min() > 0.75 and r.max() < 1.3


def test_sparsifier_is_sublinear_in_kernel_evals():
    """The whole point: eval growth is ~n^1.5 (blocked level-1 reads), not
    n^2 -- measure the scaling exponent across two sizes."""
    rng = np.random.default_rng(0)
    evals = {}
    for n in (400, 1600):
        x = rng.normal(0, 0.35, (n, 5)).astype(np.float32)
        ker = gaussian(bandwidth=2.0)
        g = spectral_sparsify(x, ker, num_edges=2 * n, estimator="stratified",
                              samples_per_block=4, seed=0)
        evals[n] = g.kernel_evals
    growth = evals[1600] / evals[400]     # quadratic would be 16x
    assert growth < 10.0, evals


def test_resparsify(cloud):
    x, ker, k = cloud
    g = spectral_sparsify(x, ker, num_edges=12000, estimator="exact",
                          exact_blocks=True, seed=0)
    g2 = resparsify(g, 4000, seed=1)
    assert g2.num_edges == 4000
    l_true = laplacian_dense(ker, x)
    rng = np.random.default_rng(2)
    v = rng.standard_normal((500, 10))
    v -= v.mean(0)
    ratios = np.einsum("ij,ij->j", v, g2.laplacian_dense() @ v) / \
        np.einsum("ij,ij->j", v, l_true @ v)
    assert ratios.min() > 0.8 and ratios.max() < 1.2


# ------------------------------------------------------------- solver
def test_laplacian_solver(cloud):
    """Section 5.1.1 / Theorem 5.11: ||x - L+b||_L <= C sqrt(eps) ||L+b||_L."""
    x, ker, k = cloud
    l_true = laplacian_dense(ker, x)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(500)
    b -= b.mean()
    sol, g = solve_kernel_laplacian(x, ker, b, num_edges=20000,
                                    estimator="exact", seed=0)
    x_true = np.linalg.lstsq(l_true, b, rcond=None)[0]
    x_true -= x_true.mean()
    num = np.sqrt((sol - x_true) @ l_true @ (sol - x_true))
    den = np.sqrt(x_true @ l_true @ x_true)
    assert num / den < 0.35, num / den


def test_cg_on_explicit_graph(cloud):
    x, ker, k = cloud
    g = spectral_sparsify(x, ker, num_edges=20000, estimator="exact",
                          exact_blocks=True, seed=0)
    rng = np.random.default_rng(1)
    b = rng.standard_normal(500)
    b -= b.mean()
    sol, res = cg_laplacian(g, b, iters=400)
    l_sp = g.laplacian_dense()
    x_direct = np.linalg.lstsq(l_sp, b, rcond=None)[0]
    x_direct -= x_direct.mean()
    assert np.linalg.norm(sol - x_direct) / np.linalg.norm(x_direct) < 0.05


# ------------------------------------------------------------- low rank
def test_fkv_additive_error(cloud):
    """Corollary 5.14: ||K - B||_F^2 <= ||K - K_r||_F^2 + eps ||K||_F^2."""
    x, ker, k = cloud
    r = 6
    res = fkv_lowrank(x, ker, rank=r, num_rows=150, estimator="exact", seed=0)
    err = projection_error(k, res.u)
    opt = optimal_error(k, r)
    fro2 = np.linalg.norm(k, "fro") ** 2
    assert (err - opt) / fro2 < 0.02, (err, opt, fro2)
    # sublinear eval accounting vs materializing K
    res_rs = fkv_lowrank(x, ker, rank=r, num_rows=150, estimator="rs", seed=0)
    assert res_rs.kernel_evals < 0.6 * k.size


def test_fkv_left_factor_fit(cloud):
    x, ker, k = cloud
    res = fkv_lowrank(x, ker, rank=6, num_rows=150, estimator="exact",
                      seed=0, fit_cols=80)
    err = factored_error(k, res.v, res.u)
    fro2 = np.linalg.norm(k, "fro") ** 2
    assert err / fro2 < 0.05


def test_baselines(cloud):
    x, ker, k = cloud
    opt = optimal_error(k, 6)
    fro2 = np.linalg.norm(k, "fro") ** 2
    u_cw = countsketch_lowrank(k, 6, 60, seed=0)
    val, u_svd = subspace_iteration(k, 6, iters=16, seed=0)
    assert (projection_error(k, u_cw) - opt) / fro2 < 0.05
    assert (projection_error(k, u_svd) - opt) / fro2 < 0.005
    # subspace iteration eigenvalues match dense
    ev = np.sort(np.linalg.eigvalsh(k))[::-1][:3]
    np.testing.assert_allclose(np.sort(val)[::-1][:3], ev, rtol=0.02)


# ------------------------------------------------------------- spectrum
def test_simplex_projection():
    rng = np.random.default_rng(0)
    for _ in range(20):
        v = rng.normal(0, 2, 50)
        p = _project_simplex(v)
        assert abs(p.sum() - 1) < 1e-6 and p.min() >= 0


def test_moment_inversion_exact_moments():
    """Given exact moments of a known spectrum, inversion recovers it in EMD."""
    rng = np.random.default_rng(0)
    mu = rng.uniform(-0.5, 1.0, 60)
    moments = np.array([np.mean(mu ** l) for l in range(1, 13)])
    lam = invert_moments(moments, n=60)
    assert emd_1d(lam, 1.0 - mu) < 0.12


def test_spectrum_emd(cloud):
    """Theorem 5.17 pipeline on a real kernel graph."""
    x, ker, k = cloud
    sp = approximate_spectrum(x, ker, length=8, num_sources=24,
                              walks_per_source=48, seed=0)
    truth = exact_spectrum(ker, x)
    assert emd_1d(sp.eigenvalues, truth) < 0.2


# ------------------------------------------------------------- eigen
def test_lemma_5_19(cloud):
    x, ker, k = cloud
    tau = k.min()
    lam1 = top_eigenvalue_exact(ker, x)
    assert lam1 >= k.shape[0] * tau


@pytest.mark.parametrize("method", ["power", "noisy_power"])
def test_top_eigenvalue(cloud, method):
    """Theorem 5.22: lambda_hat >= (1 - eps) lambda_1."""
    x, ker, k = cloud
    lam_true = top_eigenvalue_exact(ker, x)
    res = top_eigenvalue(x, ker, t=180, method=method, seed=0)
    assert res.eigenvalue >= 0.9 * lam_true
    assert res.eigenvalue <= 1.1 * lam_true
    # the witness vector is sparse and certifies a lower bound on the
    # subsampled matrix scale
    assert np.count_nonzero(res.eigenvector) <= 180
    # Theorem 5.22's headline: cost independent of n (depends on t only)
    big = np.random.default_rng(1).normal(0, 0.35, (2000, 5)).astype(np.float32)
    res_big = top_eigenvalue(big, ker, t=180, method=method, seed=0)
    assert res_big.kernel_evals <= res.kernel_evals * 1.5
