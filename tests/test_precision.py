"""Mixed-precision policy tests (DESIGN.md §14).

Three contracts:

* **bf16 bitwise parity** -- the interpret-mode Pallas kernels under
  ``precision="bf16"`` are bitwise-equal to a jnp reference that mirrors
  the exact (bm, bn) tile decomposition and calls the shared
  ``_tile_kernel_values``; the bf16 path is a pure function of the
  bf16-rounded operands, so there is no tolerance to negotiate.
* **bf16 accuracy** -- every estimator that accepts ``precision="bf16"``
  stays within ``2 * BF16_REL_ERR`` of its f32 twin when both run the same
  seed (identical sample draws, so the only difference is kernel-eval
  precision).  The bound is the input-rounding error model documented next
  to ``BF16_REL_ERR``.
* **f32 bitwise stability** -- threading ``precision`` through the stack
  must not perturb the default path: ``precision="f32"`` output is
  bitwise-identical to the precision-less call.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kde.base import ExactKDE, make_estimator
from repro.core.kernels_fn import gaussian, laplacian, rational_quadratic
from repro.kernels import tuning
from repro.kernels.kde_rowsum import kernel as rk
from repro.kernels.kde_rowsum import ops as rs
from repro.kernels.kde_sampler import ops as sops
from repro.kernels.kde_sampler import ref as sref

RNG = np.random.default_rng(7)
BOUND = 2.0 * sref.BF16_REL_ERR


def _tiled_rowsum_ref(q, x, kind, inv_bw, beta, bm, bn, precision):
    """Mirror of ``ops._rowsum``: same padding, same (bm, bn) tile loop in
    the same accumulation order, calling the kernel's own tile body.  Run
    under jit like the real entry point -- eager transcendentals can
    differ from the compiled ones by an ulp."""
    def mirror(q, x):
        m = q.shape[0]
        qp = rs._pad_rows(q, bm, 0.0)
        xp = rs._pad_rows(x, bn, rs._PAD_OFFSET)
        rows = []
        for i in range(qp.shape[0] // bm):
            acc = jnp.zeros((bm,), jnp.float32)
            for j in range(xp.shape[0] // bn):
                kv = rk._tile_kernel_values(qp[i * bm:(i + 1) * bm],
                                            xp[j * bn:(j + 1) * bn],
                                            kind, inv_bw, beta,
                                            precision=precision)
                acc = acc + jnp.sum(kv, axis=1)
            rows.append(acc)
        return jnp.concatenate(rows)[:m]

    return jax.jit(mirror)(jnp.asarray(q), jnp.asarray(x))


@pytest.mark.parametrize("ker", [gaussian(1.3),
                                 rational_quadratic(bandwidth=2.0)])
@pytest.mark.parametrize("m,n,d", [(37, 300, 19), (64, 512, 16)])
def test_bf16_rowsum_bitwise_parity(ker, m, n, d):
    q = RNG.normal(0, 0.5, (m, d)).astype(np.float32)
    x = RNG.normal(0, 0.5, (n, d)).astype(np.float32)
    bm, bn = 32, 128
    out = rs.kde_rowsum(q, x, ker, bm=bm, bn=bn, interpret=True,
                        precision="bf16")
    ref = _tiled_rowsum_ref(q, x, ker.name, 1.0 / ker.bandwidth,
                            getattr(ker, "beta", 1.0), bm, bn, "bf16")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_bf16_blocksum_bitwise_parity():
    ker = gaussian(2.0)
    q = RNG.normal(0, 0.5, (17, 8)).astype(np.float32)
    x = RNG.normal(0, 0.5, (256, 8)).astype(np.float32)
    out = rs.kde_blocksum(q, x, ker, bm=16, bn=64, interpret=True,
                          precision="bf16")
    # blocksum has no cross-tile carry: each (bm, 1) cell is one tile call
    ref = rs.blocksum_ref(jnp.asarray(q), jnp.asarray(x), "gaussian",
                          1.0 / ker.bandwidth, bn=64, precision="bf16")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-6)


def test_f32_rowsum_bitwise_parity_with_tile_mirror():
    ker = gaussian(1.3)
    q = RNG.normal(0, 0.5, (37, 19)).astype(np.float32)
    x = RNG.normal(0, 0.5, (300, 19)).astype(np.float32)
    out = rs.kde_rowsum(q, x, ker, bm=32, bn=128, interpret=True,
                        precision="f32")
    ref = _tiled_rowsum_ref(q, x, "gaussian", 1.0 / ker.bandwidth, 1.0,
                            32, 128, "f32")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_bf16_rowsum_accuracy_vs_f32():
    ker = gaussian(4.0)
    q = RNG.normal(0, 0.5, (32, 16)).astype(np.float32)
    x = RNG.normal(0, 0.5, (4096, 16)).astype(np.float32)
    f32 = np.asarray(rs.kde_rowsum(q, x, ker, bm=32, bn=256, interpret=True),
                     np.float64)
    b16 = np.asarray(rs.kde_rowsum(q, x, ker, bm=32, bn=256, interpret=True,
                                   precision="bf16"), np.float64)
    assert np.max(np.abs(b16 / f32 - 1.0)) < BOUND


@pytest.mark.parametrize("name", ["exact", "rs", "stratified", "hash"])
def test_estimator_bf16_within_documented_tolerance(name):
    """Same seed => identical sample draws, so f32 vs bf16 isolates the
    kernel-eval precision; the per-query ratio must stay inside the
    documented input-rounding bound."""
    n, d, m = 4096, 16, 32
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.5, (n, d)).astype(np.float32)
    q = rng.normal(0, 0.5, (m, d)).astype(np.float32)
    ker = gaussian(4.0)
    f32 = make_estimator(name, x, ker, seed=3, tau=0.05, eps=0.3)
    b16 = make_estimator(name, x, ker, seed=3, tau=0.05, eps=0.3,
                         precision="bf16")
    v32 = np.asarray(f32.query(jnp.asarray(q)), np.float64)
    v16 = np.asarray(b16.query(jnp.asarray(q)), np.float64)
    assert np.max(np.abs(v16 / v32 - 1.0)) < BOUND, name


def test_f32_estimator_bitwise_unchanged_by_precision_kwarg():
    n, d = 1024, 8
    rng = np.random.default_rng(1)
    x = rng.normal(0, 0.5, (n, d)).astype(np.float32)
    q = rng.normal(0, 0.5, (16, d)).astype(np.float32)
    ker = gaussian(2.0)
    a = ExactKDE(x, ker).query(jnp.asarray(q))
    b = ExactKDE(x, ker, precision="f32").query(jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bf16_rejected_for_non_l2_kernels_and_mesh():
    n, d = 256, 4
    x = RNG.normal(0, 0.5, (n, d)).astype(np.float32)
    with pytest.raises(ValueError):
        ExactKDE(x, laplacian(2.0), precision="bf16")
    ndev = len(jax.devices())
    if ndev >= 2:
        from repro.core.sampling.edge import NeighborSampler
        mesh = jax.make_mesh((ndev,), ("data",))
        with pytest.raises(ValueError):
            NeighborSampler(x, gaussian(2.0), mode="blocked", mesh=mesh,
                            precision="bf16")


# ------------------------------------------------------------------ layout
def test_walk_layout_small_problems_unchanged():
    """Counter-parity contract: when the sampler's own cache already fits
    the column budget the walk layout is the sampler layout, so mesh and
    single-device walks keep identical per-step eval counts."""
    assert sops.walk_layout(4096, 64, 64, 16) == (64, 64, 16)


def test_walk_layout_large_problems_capped():
    wbs, wb, s = sops.walk_layout(65536, 256, 256, 16)
    assert (wbs, wb, s) == (128, 512, 2)
    assert wb * s <= tuning.WALK_CACHE_COLS
    assert wbs * wb >= 65536
    wbs, wb, s = sops.walk_layout(1048576, 1024, 1024, 16)
    assert wbs == 512 and wbs * wb >= 1048576
    # the column cap binds: s bottoms out at the variance-reduction floor
    assert s == tuning.WALK_CACHE_MIN_S


def test_grouped_inverse_cdf_matches_flat_on_exact_sums():
    """With integer-valued weights every partial sum is exact in f32, so
    the two-level grouped draw must pick the identical index as the flat
    inverse-CDF for any u (the law differs only by fp regrouping)."""
    rng = np.random.default_rng(2)
    w, m = 64, 48
    vals = jnp.asarray(rng.integers(0, 64, (w, m)).astype(np.float32))
    u = jnp.asarray(rng.uniform(size=(w,)).astype(np.float32))
    g = sref.cdf_group(m)
    assert m % g == 0
    idx, val, tot = sref.grouped_inverse_cdf(vals, u, g)
    c = jnp.cumsum(vals, axis=1)
    flat = jnp.sum((u * c[:, -1])[:, None] > c, axis=1).clip(0, m - 1)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(flat))
    np.testing.assert_array_equal(
        np.asarray(val),
        np.asarray(jnp.take_along_axis(vals, idx[:, None], axis=1)[:, 0]))
    np.testing.assert_array_equal(np.asarray(tot), np.asarray(c[:, -1]))


def test_pallas_tile_tuner_static_and_wider_for_bf16():
    t1 = tuning.pallas_tiles(1024, 262144, 64, "f32")
    t2 = tuning.pallas_tiles(1024, 262144, 64, "bf16")
    assert t1 == tuning.pallas_tiles(1024, 262144, 64, "f32")  # memoized
    assert t2[1] >= t1[1]  # halved operand bytes never narrow the x tile
